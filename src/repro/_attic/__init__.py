"""Quarantined seed-era model zoo and LM-serving stack.

Everything under ``repro._attic`` is dormant with respect to the DAWN
reproduction (ROADMAP item 3): the transformer/GNN/recsys model zoo,
their launch cells and dry-run matrix, the token/recsys data pipelines,
and the KV-cache LM serving engine.  Nothing here is imported by the
live package — importing ``repro`` never touches this subtree.  The
code still works (its tests import it explicitly) but carries no API
stability promise.
"""
