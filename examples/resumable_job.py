"""Preemption-safe checkpointed APSP — kill a job halfway, resume it
elastically on a SMALLER mesh, get bit-identical results.

A counting-semiring APSP job (dist + path counts — the betweenness
front half) runs in source-tile chunks through the resumable-job layer
(``core/jobs.py``), checkpointing every chunk (async writer, sha256
manifest, atomic rename).  This script:

  1. runs the job uninterrupted on a 4x2 mesh (the reference),
  2. re-runs it with an injected preemption after half the chunks,
  3. "loses a host": plans a survivor mesh with ``plan_remesh`` and
     builds it with ``mesh_from_plan`` (8 chips -> 4),
  4. resumes the SAME call on the 2x2 survivor mesh — the restore
     walks the checkpoint through the new mesh's shardings — and
     asserts distances, path counts and sweep totals bit-identical
     to the uninterrupted run.

MUST run as its own process (device count is locked at jax init):

    PYTHONPATH=src python examples/resumable_job.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import tempfile  # noqa: E402

import numpy as np  # noqa: E402

import repro as dawn  # noqa: E402
from repro.graph import generators as gen  # noqa: E402
from repro.launch.mesh import make_mesh, mesh_from_plan  # noqa: E402
from repro.train.fault_tolerance import plan_remesh  # noqa: E402


class Preempted(RuntimeError):
    pass


def kill_after(chunk_idx):
    def on_chunk(k):
        if k == chunk_idx:
            raise Preempted(f"SIGTERM after chunk {k}")
    return on_chunk


def main():
    g = gen.rmat(8, 8, directed=False, seed=7)        # n = 256
    sources = np.arange(32, dtype=np.int32)
    # direction_counts are only mesh-shape invariant under a fixed mode
    h = dawn.prepare(g, source_batch=8, mode="dense")
    print(f"graph: n={g.n_nodes} m={g.n_edges}, {len(sources)} sources, "
          f"chunks of 8")

    big = make_mesh((4, 2), ("data", "model"))
    full = h.apsp(sources, semiring="counting", mesh=big)
    print(f"reference run on 4x2 mesh: {int(full.sweeps)} sweeps")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        try:
            h.apsp(sources, semiring="counting", mesh=big,
                   checkpoint_dir=ckpt_dir, chunk_size=8,
                   on_chunk=kill_after(1))
        except Preempted as e:
            print(f"preempted: {e}")

        # half the fleet is gone — re-plan onto the 4 survivors
        plan = plan_remesh(4, model_parallel=2)
        small = mesh_from_plan(plan)
        print(f"resuming on survivor mesh {dict(small.shape)}")

        res = h.apsp(sources, semiring="counting", mesh=small,
                     checkpoint_dir=ckpt_dir, chunk_size=8)
        print(f"restored {res.chunks_restored} chunks from step "
              f"{res.restored_step}, recomputed {res.chunks_computed}")

        assert (np.asarray(res.dist) == np.asarray(full.dist)).all()
        assert (np.asarray(res.sigma) == np.asarray(full.sigma)).all()
        assert res.sweeps == int(full.sweeps)

    print("resumed-on-smaller-mesh results bit-identical to the "
          "uninterrupted run ✓")


if __name__ == "__main__":
    main()
