"""Input-shape sets per architecture family (from the assignment brief)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LMShape:
    shape_id: str
    kind: str                 # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


LM_SHAPES = {
    "train_4k": LMShape("train_4k", "train", 4096, 256),
    "prefill_32k": LMShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": LMShape("decode_32k", "decode", 32768, 128),
    "long_500k": LMShape("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class GNNShape:
    shape_id: str
    kind: str                 # "full" | "sampled" | "batched"
    n_nodes: int
    n_edges: int
    d_feat: int
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    n_graphs: int = 1


GNN_SHAPES = {
    "full_graph_sm": GNNShape("full_graph_sm", "full", 2_708, 10_556, 1_433),
    "minibatch_lg": GNNShape("minibatch_lg", "sampled", 232_965,
                             114_615_892, 602, batch_nodes=1_024,
                             fanout=(15, 10)),
    "ogb_products": GNNShape("ogb_products", "full", 2_449_029,
                             61_859_140, 100),
    "molecule": GNNShape("molecule", "batched", 30, 64, 8, n_graphs=128),
}


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    shape_id: str
    kind: str                 # "train" | "serve" | "retrieval"
    batch: int
    n_candidates: int = 0


RECSYS_SHAPES = {
    "train_batch": RecsysShape("train_batch", "train", 65_536),
    "serve_p99": RecsysShape("serve_p99", "serve", 512),
    "serve_bulk": RecsysShape("serve_bulk", "serve", 262_144),
    "retrieval_cand": RecsysShape("retrieval_cand", "retrieval", 1,
                                  n_candidates=1_000_000),
}


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def sampled_sizes(shape: GNNShape) -> Tuple[int, int]:
    """(sub_nodes, sub_edges) of the fanout-sampled subgraph."""
    n, e = shape.batch_nodes, 0
    layer = shape.batch_nodes
    for f in shape.fanout:
        layer *= f
        n += layer
        e += layer
    return n, e
