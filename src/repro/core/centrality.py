"""Batched centrality analytics on the counting semiring — the "general
graph-analytics engine" framing of the paper's conclusion, grown past
distance reductions into exact betweenness.

Shortest-path *counting* is the same sweep as BFS under a different
algebra (Burkhardt's algebraic BFS): the loop state carries the pair
``(dist, sigma)`` and ⊕ adds path counts gated on dist-improvement ties
(:func:`repro.core.sweep.counting_forms`).  One batched counting run
feeds everything here:

  * **closeness / harmonic** — jit-reduced per source tile from the dist
    rows (integer sufficient statistics, finalized in float64 on host so
    results match the old per-block NumPy path exactly);
  * **eccentricity / radius / diameter** — exact per-source max distance
    over reachable targets (sampled bounds kept as
    :func:`eccentricity_sample`);
  * **betweenness** — exact Brandes: the forward counting sweeps produce
    ``(dist, sigma)`` per level (``dist`` IS the per-level frontier
    record: frontier at level t = ``dist == t``), and
    :func:`brandes_dependencies` runs the backward dependency
    accumulation level-by-level as one batched ``fori_loop`` over the
    recorded levels.

The forward engine (:func:`counting_apsp`) mirrors ``weighted_apsp``:
source tiles through the ONE sweep driver in ``core/sweep.py``, push
(f32 counting GEMM — the Pallas kernel on the kernel path) vs sparse
(scatter-add) chosen per sweep by the occupancy cost model or pinned by
per-graph calibration.  Large jobs route through the sharded executor
(``centrality(..., mesh=)``) — sources shard over the mesh's data axes,
sigma partials combine with the masked-add ⊕-reduction in
``core/distributed.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph
from . import autotune
from . import sweep as S
from .engine import (PreparedGraph, _resolve_kernel, frontier_stats,
                     prepare_graph)
from .frontier import UNREACHED, one_hot_frontier
from .options import SweepOptions
from .sssp import multi_source

PUSH, SPARSE = 0, 1
COUNTING_FORM_NAMES = ("push", "sparse")

MEASURES = ("closeness", "harmonic", "eccentricity", "betweenness")


@dataclasses.dataclass(frozen=True)
class CentralityConfig(SweepOptions):
    """Static counting-engine parameters (a :class:`SweepOptions`
    subclass, hashable jit static arg) — the same shape as
    ``WeightedConfig`` with the pull form removed (bit-packing does not
    apply to f32 path counts).

    ``use_kernel=None`` resolves to "Pallas kernels iff on TPU" and
    ``dynamic=None`` to "per-sweep switching iff on the kernel path",
    exactly like the boolean/tropical engines; the calibrated regime
    times the same counting closures the driver dispatches.
    """
    c_push: float = 1.0              # per f32 MAC in a live push tile
    c_sparse: float = 8.0            # per CSR gather + scatter-add lane

    _mode_names = COUNTING_FORM_NAMES  # push | sparse


class CountingResult(NamedTuple):
    dist: jax.Array              # (S, n) int32, -1 unreachable
    sigma: jax.Array             # (S, n) f32 shortest-path counts
    sweeps: jax.Array            # int32 — max sweeps over batches
    direction_counts: jax.Array  # (2,) int32 — push/sparse sweeps run


class CentralityResult(NamedTuple):
    """One batched analytics run.  Per-source arrays align with
    ``sources``; ``betweenness`` is over ALL nodes (the dependency sums
    contributed by the requested sources — exact betweenness when
    sources cover every node, a source-sampled estimate otherwise).
    ``radius``/``diameter`` are exact under the same condition.
    ``sigma_checksum`` is the sum of shortest-path counts over reachable
    pairs — a deterministic work fingerprint the benchmark regression
    gate pins (0.0 when betweenness was not requested)."""
    sources: np.ndarray
    closeness: Optional[np.ndarray]     # (S,) float64
    harmonic: Optional[np.ndarray]      # (S,) float64
    eccentricity: Optional[np.ndarray]  # (S,) int32
    betweenness: Optional[np.ndarray]   # (n,) float64
    radius: Optional[int]
    diameter: Optional[int]
    sweeps: int
    sigma_checksum: float


# --------------------------------------------------------------------------
# the batched counting engine (forward Brandes stage)
# --------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("cfg", "n_real", "n_pad", "max_steps",
                                    "use_kernel", "interpret", "forced_dir",
                                    "fused_steps"))
def _run_counting_batch(adj, src_idx, dst_idx, deg, sources, n_valid, *,
                        cfg: CentralityConfig, n_real: int, n_pad: int,
                        max_steps: int, use_kernel: bool, interpret: bool,
                        forced_dir: Optional[int],
                        fused_steps: int = 0) -> S.SweepState:
    s = sources.shape[0]
    m_pad = src_idx.shape[0]
    bs = min(s, 128)

    f0 = one_hot_frontier(sources, n_pad, dtype=jnp.int8)
    row_ok = (jnp.arange(s) < n_valid)[:, None]
    f0 = jnp.where(row_ok, f0, 0)
    dist0 = jnp.where(f0 != 0, 0, jnp.full((s, n_pad), UNREACHED))
    # pad rows/cols are born "visited" with sigma 0: no sweep form ever
    # discovers them, so they stay inert in both halves of the state
    dist0 = jnp.where(row_ok & (jnp.arange(n_pad)[None, :] < n_real),
                      dist0, 0)
    sigma0 = jnp.where(f0 != 0, 1.0, 0.0).astype(jnp.float32)

    forms = S.counting_forms(adj, src_idx, dst_idx, n_pad=n_pad, s=s,
                             bn=cfg.bn, bk=cfg.bk, use_kernel=use_kernel,
                             interpret=interpret)

    if forced_dir is None:
        def choose(st: S.SweepState):
            stats = frontier_stats(st.frontier, st.dist[0], bs=bs,
                                   bn=128, bk=128)
            push_c = cfg.c_push * s * n_pad * n_pad * stats.live_tile_frac
            sparse_c = jnp.float32(cfg.c_sparse * s * m_pad)
            return (push_c > sparse_c).astype(jnp.int32)
    else:
        choose = None

    fused = None
    if fused_steps:  # resolved upstream: kernel path, push pinned
        fused = S.fused_form("counting", adj, "push", bs=bs,
                             max_sweeps=fused_steps, interpret=interpret)

    st0 = S.make_state(f0, (dist0, sigma0), n_forms=2)
    return S.sweep_loop(forms, st0, max_steps=max_steps, deg=deg,
                        choose=choose,
                        forced_dir=0 if forced_dir is None else forced_dir,
                        fused=fused, fused_steps=fused_steps)


def measure_counting_costs(pg: PreparedGraph, s: int,
                           cfg: CentralityConfig, *,
                           use_kernel: bool = False,
                           interpret: bool = True) -> Tuple[float, float]:
    """Wall-clock one mid-run sweep of each counting form on this graph
    (mirror of ``engine.measure_sweep_costs``; cached on the prepared
    graph under a counting-tagged key)."""
    key = ("counting", s, cfg.bn, cfg.bk, use_kernel, interpret)
    if key in pg.cost_cache:
        return pg.cost_cache[key]
    n_pad = pg.n_pad
    f = np.zeros((s, n_pad), np.int8)
    f[:, ::17] = 1
    dist = np.full((s, n_pad), int(UNREACHED), np.int32)
    dist[:, ::4] = 1
    sigma = (dist >= 0).astype(np.float32)
    forms = S.counting_forms(pg.adj, pg.graph.src, pg.graph.dst,
                             n_pad=n_pad, s=s, bn=cfg.bn, bk=cfg.bk,
                             use_kernel=use_kernel, interpret=interpret)
    result = S.time_sweep_forms(forms, jnp.asarray(f),
                                (jnp.asarray(dist), jnp.asarray(sigma)))
    pg.cost_cache[key] = result
    return result


def _resolve_counting_direction(pg: PreparedGraph, s: int,
                                cfg: CentralityConfig, use_kernel: bool,
                                interpret: bool) -> Optional[int]:
    """None -> per-sweep dynamic switch; int -> form fixed per batch.
    Pin precedence: explicit mode > TuningPlan argmin > wall-clock
    calibration (see engine._resolve_direction)."""
    if cfg.mode != "auto":
        return COUNTING_FORM_NAMES.index(cfg.mode)
    dynamic = use_kernel if cfg.dynamic is None else cfg.dynamic
    if dynamic:
        return None
    if cfg.tuning is not None:
        pinned = cfg.tuning.pinned_direction(
            "counting", s=s, n_pad=pg.n_pad, m_pad=pg.graph.m_pad)
        if pinned is not None:
            return pinned
    return int(np.argmin(measure_counting_costs(
        pg, s, cfg, use_kernel=use_kernel, interpret=interpret)))


def counting_apsp_blocks(g: Union[CSRGraph, PreparedGraph],
                         sources: Optional[Sequence[int]] = None, *,
                         config: CentralityConfig = CentralityConfig()):
    """Stream (source_ids, dist_rows, sigma_rows, raw_state) one source
    tile at a time through the counting engine."""
    pg = g if isinstance(g, PreparedGraph) else prepare_graph(g)
    config = autotune.apply(config, semiring="counting", n_pad=pg.n_pad)
    graph = pg.graph
    n = graph.n_nodes
    srcs = np.arange(n, dtype=np.int32) if sources is None else \
        np.asarray(sources, np.int32)
    if srcs.size == 0:
        raise ValueError("counting_apsp: empty source list")
    if srcs.min() < 0 or srcs.max() >= n:
        raise ValueError(
            f"counting_apsp: sources must be in [0, {n}), got "
            f"[{srcs.min()}, {srcs.max()}]")
    use_kernel, interpret = _resolve_kernel(config)
    max_steps = config.max_steps or n
    B = config.source_batch
    forced = _resolve_counting_direction(pg, B, config, use_kernel,
                                         interpret)
    fused_steps = 0
    if config.fused_steps and forced in (None, PUSH):
        fused_steps = S.resolve_fused_steps(
            "counting", "push", fused_steps=config.fused_steps,
            max_steps=max_steps, use_kernel=use_kernel, n_pad=pg.n_pad,
            bs=min(B, 128),
            budget=None if config.tuning is None
            else config.tuning.vmem_budget) or 0
        if fused_steps:
            forced = PUSH       # fused blocks pin the push form
    # the dense operand only materializes when push can dispatch
    adj = pg.adj if forced in (None, PUSH) else jnp.zeros((1, 1), jnp.int8)
    for lo in range(0, len(srcs), B):
        block = srcs[lo: lo + B]
        valid = len(block)
        padded = np.zeros(B, np.int32)
        padded[:valid] = block
        st = _run_counting_batch(adj, graph.src, graph.dst, pg.deg,
                                 jnp.asarray(padded), jnp.int32(valid),
                                 cfg=config, n_real=n, n_pad=pg.n_pad,
                                 max_steps=max_steps,
                                 use_kernel=use_kernel, interpret=interpret,
                                 forced_dir=forced, fused_steps=fused_steps)
        dist, sigma = st.dist
        yield block, dist[:valid, :n], sigma[:valid, :n], st


def counting_apsp(g: Union[CSRGraph, PreparedGraph],
                  sources: Optional[Sequence[int]] = None, *,
                  config: CentralityConfig = CentralityConfig()
                  ) -> CountingResult:
    """Materialized batched (dist, sigma) — BFS levels plus exact
    shortest-path counts for every requested source."""
    dist_rows, sig_rows = [], []
    sweeps = jnp.int32(0)
    counts = jnp.zeros(2, jnp.int32)
    for _, dist, sigma, st in counting_apsp_blocks(g, sources,
                                                   config=config):
        dist_rows.append(dist)
        sig_rows.append(sigma)
        sweeps = jnp.maximum(sweeps, st.step)
        counts = counts + st.dir_counts
    return CountingResult(dist=jnp.concatenate(dist_rows, axis=0),
                          sigma=jnp.concatenate(sig_rows, axis=0),
                          sweeps=sweeps, direction_counts=counts)


# --------------------------------------------------------------------------
# Brandes backward dependency accumulation
# --------------------------------------------------------------------------

@jax.jit
def _brandes_backward(src_idx: jax.Array, dst_idx: jax.Array,
                      dist: jax.Array, sigma: jax.Array,
                      max_level: jax.Array) -> jax.Array:
    """Batched Brandes dependencies δ (S, n) from (dist, sigma).

    ``dist`` is the per-level frontier record (frontier at level t ==
    ``dist == t``), so the backward pass walks levels deepest-first: for
    every edge (u, v) with ``dist[v] == dist[u] + 1 == t``,

        δ[u] += σ[u] / σ[v] · (1 + δ[v])

    accumulated as one frontier-masked scatter-add over the padded CSR
    lanes per level — the exact mirror of the forward sweeps' work
    shape.  δ[v] for a level-t node is final once all deeper levels have
    run, which the descending ``fori_loop`` guarantees."""
    s, n = dist.shape
    # sentinel column: padded lanes carry src = dst = n; level -2 never
    # matches a real level so their contributions are exactly zero
    d = jnp.concatenate(
        [dist, jnp.full((s, 1), -2, jnp.int32)], axis=1)
    sg = jnp.concatenate([sigma, jnp.ones((s, 1), jnp.float32)], axis=1)
    delta0 = jnp.zeros_like(sg)
    # loop-invariant lane gathers: levels and sigma never change during
    # the backward pass, only delta does
    du, dv = d[:, src_idx], d[:, dst_idx]
    sg_src = sg[:, src_idx]

    def body(i, delta):
        t = max_level - i
        on_level = (du == t - 1) & (dv == t)
        coeff = (1.0 + delta) / jnp.maximum(sg, 1.0)
        contrib = jnp.where(on_level, sg_src * coeff[:, dst_idx], 0.0)
        return delta.at[:, src_idx].add(contrib)

    delta = jax.lax.fori_loop(0, max_level, body, delta0)
    return delta[:, :n]


def brandes_dependencies(g: CSRGraph, dist: jax.Array, sigma: jax.Array
                         ) -> jax.Array:
    """Dependency accumulation δ[s, v] = Σ_{t reachable} σ_st(v)/σ_st for
    a block of sources, from the counting engine's (dist, sigma)."""
    max_level = jnp.maximum(jnp.max(dist), 0)
    return _brandes_backward(g.src, g.dst, jnp.asarray(dist),
                             jnp.asarray(sigma), max_level)


# --------------------------------------------------------------------------
# jit-batched per-tile reductions
# --------------------------------------------------------------------------

# column-chunked partial sums: one chunk's int32 distance total is
# bounded by CHUNK · diameter, so the int32 accumulator cannot wrap for
# any graph whose dense operand fits in memory (n ≲ 5·10^5 even in the
# path-graph worst case); the (S, n/CHUNK) partials finalize in
# int64/float64 on host
_REDUCE_CHUNK = 4096


@jax.jit
def _reduce_block(dist: jax.Array):
    """Per-source sufficient statistics from one (B, n) dist tile:
    reach count r-1 (int32 — counts fit trivially), column-chunked
    distance totals (int32 partials, exact) and harmonic partials (f32
    over ≤ 4096 terms each), eccentricity (int32).  Totals combine on
    host in int64/float64 — see :func:`centrality`."""
    s, n = dist.shape
    reach = dist > 0
    n_reach = reach.sum(axis=1).astype(jnp.int32)
    ecc = jnp.max(jnp.where(reach, dist, 0), axis=1,
                  initial=0).astype(jnp.int32)
    k = -(-n // _REDUCE_CHUNK)
    pad = k * _REDUCE_CHUNK - n
    dpad = jnp.pad(dist, ((0, 0), (0, pad)))     # pad dist 0 -> unreached
    dch = dpad.reshape(s, k, _REDUCE_CHUNK)
    rch = dch > 0
    tot_p = jnp.where(rch, dch, 0).sum(axis=2).astype(jnp.int32)
    har_p = jnp.where(rch, 1.0 / jnp.maximum(dch, 1), 0.0).sum(axis=2)
    return n_reach, tot_p, har_p, ecc


def _sigma_checksum_block(dist: jax.Array, sigma: jax.Array) -> float:
    """Sum of path counts over reachable pairs — the deterministic work
    fingerprint pinned by the benchmark regression gate."""
    return float(jnp.sum(jnp.where(dist >= 0, sigma, 0.0)))


# --------------------------------------------------------------------------
# the public analytics driver
# --------------------------------------------------------------------------

def centrality(g: Union[CSRGraph, PreparedGraph],
               sources: Optional[Sequence[int]] = None, *,
               measures: Sequence[str] = MEASURES,
               config: Optional[CentralityConfig] = None,
               mesh=None,
               method: str = "auto") -> CentralityResult:
    """One batched analytics run computing every requested measure.

    ``sources=None`` runs all nodes (exact betweenness / radius /
    diameter); a subset gives source-restricted sums (the standard
    source-sampled betweenness estimator, unscaled).  When betweenness
    is requested the forward pass runs the counting engine; otherwise
    the plain boolean engine serves the dist rows.  ``mesh=`` routes the
    forward runs through the semiring-generic sharded executor
    (``core/distributed.py``) — sources shard over the data axes and the
    non-idempotent counting ⊕ combines sigma partials with the
    masked-add reduction; the backward pass and reductions stay local.
    """
    measures = tuple(measures)
    unknown = set(measures) - set(MEASURES)
    if unknown:
        raise ValueError(f"unknown measures {sorted(unknown)}; "
                         f"available: {MEASURES}")
    pg = g if isinstance(g, PreparedGraph) else prepare_graph(g)
    graph = pg.graph
    n = graph.n_nodes
    srcs = np.arange(n, dtype=np.int32) if sources is None else \
        np.asarray(sources, np.int32)
    if srcs.size == 0:
        raise ValueError("centrality: empty source list")
    if srcs.min() < 0 or srcs.max() >= n:
        raise ValueError(
            f"centrality: sources must be in [0, {n}), got "
            f"[{srcs.min()}, {srcs.max()}]")
    config = config or CentralityConfig(
        source_batch=min(128, max(8, ((len(srcs) + 7) // 8) * 8)))
    need_sigma = "betweenness" in measures

    n_reach = np.zeros(len(srcs), np.int64)
    tot = np.zeros(len(srcs), np.int64)
    har = np.zeros(len(srcs), np.float64)
    ecc = np.zeros(len(srcs), np.int32)
    bc = np.zeros(n, np.float64) if need_sigma else None
    sweeps = 0
    checksum = 0.0

    def fold(lo, block, dist, sigma):
        nonlocal sweeps, checksum
        hi = lo + len(block)
        r_b, t_p, h_p, e_b = _reduce_block(dist)
        n_reach[lo:hi] = np.asarray(r_b)
        # chunked partials -> exact int64 / float64 totals on host
        tot[lo:hi] = np.asarray(t_p, np.int64).sum(axis=1)
        har[lo:hi] = np.asarray(h_p, np.float64).sum(axis=1)
        ecc[lo:hi] = np.asarray(e_b)
        if need_sigma:
            checksum += _sigma_checksum_block(dist, sigma)
            delta = np.asarray(brandes_dependencies(graph, dist, sigma),
                               np.float64)
            bc_local = delta.sum(axis=0)
            # Brandes never adds a source's own δ row at the source
            np.subtract.at(bc_local, block,
                           delta[np.arange(len(block)), block])
            bc[:] += bc_local

    if mesh is not None:
        from .distributed import ShardedConfig, sharded_apsp
        semiring = "counting" if need_sigma else "boolean"
        # honor the caller's form choice: the sharded executor names the
        # dense GEMM-analogue form "dense" where the counting engine
        # says "push"; "auto" keeps the per-sweep cost-model switch
        mode = {"push": "dense", "sparse": "sparse",
                "auto": "auto"}[config.mode]
        res = sharded_apsp(graph, srcs, mesh=mesh,
                           config=ShardedConfig(semiring=semiring,
                                                mode=mode,
                                                use_kernel=config.use_kernel,
                                                max_sweeps=config.max_steps,
                                                bn=config.bn,
                                                bk=config.bk))
        sweeps = int(res.sweeps)
        B = config.source_batch
        for lo in range(0, len(srcs), B):
            block = srcs[lo: lo + B]
            dist = res.dist[lo: lo + len(block)]
            sigma = res.sigma[lo: lo + len(block)] if need_sigma else None
            fold(lo, block, dist, sigma)
    elif need_sigma:
        lo = 0
        for block, dist, sigma, st in counting_apsp_blocks(
                pg, srcs, config=config):
            sweeps = max(sweeps, int(st.step))
            fold(lo, block, dist, sigma)
            lo += len(block)
    else:
        B = config.source_batch
        for lo in range(0, len(srcs), B):
            block = srcs[lo: lo + B]
            res = multi_source(pg, block, method=method, parents=False)
            sweeps = max(sweeps, int(res.eccentricity))
            fold(lo, block, res.dist, None)

    # finalize in float64 from the exact integer statistics —
    # Wasserman-Faust normalized closeness for disconnected graphs,
    # identical to the old per-block NumPy reduction
    frac = n_reach.astype(np.float64) / max(n - 1, 1)
    clo = np.where(tot > 0,
                   frac * n_reach / np.maximum(tot, 1).astype(np.float64),
                   0.0)

    reach_any = ecc > 0
    return CentralityResult(
        sources=srcs,
        closeness=clo if "closeness" in measures else None,
        harmonic=har if "harmonic" in measures else None,
        eccentricity=ecc if "eccentricity" in measures else None,
        betweenness=bc,
        radius=int(ecc[reach_any].min()) if ("eccentricity" in measures
                                             and reach_any.any()) else
        (0 if "eccentricity" in measures else None),
        diameter=int(ecc.max()) if "eccentricity" in measures else None,
        sweeps=sweeps,
        sigma_checksum=checksum,
    )


# --------------------------------------------------------------------------
# per-measure entry points (the quickstart API)
# --------------------------------------------------------------------------

def closeness(g: Union[CSRGraph, PreparedGraph],
              sources: Optional[np.ndarray] = None, *,
              block: int = 128, method: str = "auto") -> np.ndarray:
    """Closeness centrality C(u) = (r-1) / Σ_v d(u,v) over reachable v
    (Wasserman-Faust normalized for disconnected graphs), jit-batched."""
    cfg = CentralityConfig(source_batch=max(8, ((block + 7) // 8) * 8)
                           if block <= 128 else
                           ((block + 127) // 128) * 128)
    return centrality(g, sources, measures=("closeness",), config=cfg,
                      method=method).closeness


def harmonic(g: Union[CSRGraph, PreparedGraph],
             sources: Optional[np.ndarray] = None, *,
             block: int = 128, method: str = "auto") -> np.ndarray:
    """Harmonic centrality H(u) = Σ_{v≠u} 1/d(u,v), jit-batched."""
    cfg = CentralityConfig(source_batch=max(8, ((block + 7) // 8) * 8)
                           if block <= 128 else
                           ((block + 127) // 128) * 128)
    return centrality(g, sources, measures=("harmonic",), config=cfg,
                      method=method).harmonic


def betweenness(g: Union[CSRGraph, PreparedGraph],
                sources: Optional[np.ndarray] = None, *,
                normalized: bool = False,
                config: Optional[CentralityConfig] = None,
                mesh=None) -> np.ndarray:
    """Exact betweenness centrality (Brandes, directed, endpoints
    excluded) via the counting semiring.  ``sources`` restricts the
    dependency sums (source-sampled estimate); ``normalized=True``
    divides by (n-1)(n-2)."""
    res = centrality(g, sources, measures=("betweenness",), config=config,
                     mesh=mesh)
    bc = res.betweenness
    n = bc.shape[0]
    if normalized and n > 2:
        bc = bc / float((n - 1) * (n - 2))
    return bc


def eccentricity(g: Union[CSRGraph, PreparedGraph],
                 sources: Optional[np.ndarray] = None, *,
                 config: Optional[CentralityConfig] = None,
                 mesh=None) -> dict:
    """Exact eccentricities (over reachable targets) plus radius /
    diameter — exact when ``sources`` covers every node (the default)."""
    res = centrality(g, sources, measures=("eccentricity",), config=config,
                     mesh=mesh)
    return {"ecc": res.eccentricity, "radius": res.radius,
            "diameter": res.diameter}


def eccentricity_sample(g: CSRGraph, n_samples: int = 64, *,
                        seed: int = 0, method: str = "auto"):
    """Sampled eccentricities → (radius_upper, diameter_lower) estimates
    (Takes-Kosters-style bounds from a random source set — the paper's
    ε(i) ≈ log n observation is checkable with this).  For exact values
    use :func:`eccentricity`."""
    rng = np.random.default_rng(seed)
    sources = rng.integers(0, g.n_nodes, n_samples)
    res = centrality(g, sources, measures=("eccentricity",), method=method)
    ecc_arr = res.eccentricity
    return {"radius_upper": int(ecc_arr[ecc_arr > 0].min())
            if (ecc_arr > 0).any() else 0,
            "diameter_lower": int(ecc_arr.max()),
            "ecc_mean": float(ecc_arr.mean())}
