"""Direction-optimizing batched APSP, and graph queries in the serving loop.

    PYTHONPATH=src python examples/apsp_engine.py

Part 1 runs tiled all-pairs shortest paths over a road-network-like graph
through the ``dawn`` facade and prints which sweep forms the engine chose.
Part 2 stands up the tiered, continuously-batching GraphService — built
from the same facade handle — and serves point-to-point queries, a
k-nearest lookup, and a centrality analytic, then mutates the graph and
shows the epoch guard invalidating the serving-tier caches.
"""
import numpy as np

import repro as dawn
from repro.graph import generators as gen
from repro.serve import GraphQuery


def part1_batched_apsp():
    g = gen.grid2d(32, 32)                       # 1024-node road grid
    stats = g.degree_stats()
    print(f"graph: n={stats.n_nodes} m={stats.n_edges} "
          f"avg_deg={stats.avg_degree:.1f} density={stats.density:.2%}")

    h = dawn.prepare(g, source_batch=128)        # dense + packed operands
    res = h.apsp()                               # all sources
    dirs = dict(zip(("push", "pull", "sparse"),
                    np.asarray(res.direction_counts).tolist()))
    print(f"APSP over all {stats.n_nodes} sources: dist {res.dist.shape}, "
          f"{int(res.sweeps)} sweeps/tile max, directions {dirs}")
    ecc = int(res.dist.max())
    print(f"graph diameter (max eccentricity): {ecc}")


def part2_serving():
    dg = dawn.DynamicCSRGraph(gen.watts_strogatz(512, 8, 0.05, seed=1))
    svc = dawn.prepare(dg).serve(max_batch=16, n_landmarks=8)

    for i in range(20):
        svc.submit(GraphQuery(qid=i, source=i * 7 % 512, target=200))
    svc.submit(GraphQuery(qid=20, source=3, k_nearest=5))
    svc.submit(GraphQuery(qid=21, source=200, analytics=("closeness",)))
    done = []
    while svc.pending():                 # each flush serves one batch
        done.extend(svc.flush())

    hops = [q.hops for q in done if q.target is not None]
    tiers = sorted({q.served_by for q in done})
    print(f"graph queries: {len(done)} served via {tiers}, "
          f"hops to node 200: {hops}")
    knn = next(q for q in done if q.k_nearest)
    print(f"5 nearest to node 3: {knn.nearest}")
    cen = next(q for q in done if q.analytics)
    print(f"closeness(200) = {cen.analytics_result['closeness']:.4f}")

    # mutate the live graph — the service notices the epoch change and
    # rebuilds operands / drops stale caches before the next answer
    def ask(qid):
        svc.submit(GraphQuery(qid=qid, source=3, target=200))
        svc.flush()
        q = [x for x in svc.drain_completed() if x.qid == qid][0]
        return q.hops, q.served_by

    svc.drain_completed()
    before, tier_b = ask(22)             # row-cache hit from the k-NN row
    dg.insert_edges([3], [200])
    after, tier_a = ask(23)              # epoch guard forces a fresh sweep
    print(f"insert (3, 200): hops {before} ({tier_b}) → {after} ({tier_a}), "
          f"{svc.epoch_invalidations} epoch invalidation")


if __name__ == "__main__":
    part1_batched_apsp()
    part2_serving()
