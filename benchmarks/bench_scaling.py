"""Paper Tables 5/6 + Figs 3/4 analogue: scalability of the matrix
formulation.

The paper measures CPU-thread efficiency; the TPU-native equivalent of
"more threads" is "more sources per sweep" (multi-source batching) and
"more devices".  We report:

  * batch efficiency  η_S = T(1) · S / T(S)  — how close S-source batched
    sweeps come to S× one-source throughput (paper Eq. 14 analogue);
  * device scaling of the sharded DAWN (when >1 device is available).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bovm_msbfs
from repro.graph import generators as gen


def _time(fn, repeats=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def run(csv: List[str] | None = None):
    g = gen.rmat(10, 8, directed=False, seed=3)   # 1024 nodes
    n = g.n_nodes
    adj = g.to_dense()
    base = None
    out = {}
    for s_batch in (1, 4, 16, 64, 256):
        srcs = jnp.arange(s_batch, dtype=jnp.int32) % n

        def run_batch():
            bovm_msbfs(adj, srcs).dist.block_until_ready()

        t = _time(run_batch)
        per_src = t / s_batch
        if base is None:
            base = per_src
        eff = base / per_src
        out[s_batch] = eff
        if csv is not None:
            csv.append(f"scaling_batch_{s_batch},{per_src*1e6:.1f},"
                       f"batch_efficiency={eff:.2f}")
    return out


if __name__ == "__main__":
    rows: List[str] = []
    print(run(csv=rows))
    print("\n".join(rows))
