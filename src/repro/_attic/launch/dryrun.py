import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes, dump memory/cost analyses + the collective schedule.

The two lines above MUST run before any other import (jax locks the device
count at first init).

Usage:
    python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse      # noqa: E402
import gzip          # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro import compat  # noqa: E402

from ..configs import all_cells, shapes_for          # noqa: E402
from .cells import build_cell, jit_cell              # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every shape literal in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_CONVERT_RE = re.compile(
    r"= f32\[([\d,]+)\]\{[^}]*\} convert\(")


def bf16_promotion_bytes(hlo: str, min_bytes: int = 64 << 20) -> int:
    """XLA:CPU has no bf16 matmul — it f32-converts bf16 dot operands and
    hoists whole stacked-weight conversions out of loops.  A real TPU (bf16
    MXU) never allocates these.  Sum the big f32 convert results so the
    memory report can show a TPU-corrected temp estimate."""
    total = 0
    for m in _CONVERT_RE.finditer(hlo):
        n = 1
        for d in m.group(1).split(","):
            n *= int(d)
        if n * 4 >= min_bytes:
            total += n * 4
    return total


def parse_collectives(hlo: str):
    """Per-op collective inventory from post-SPMD HLO text.

    Returns list of {op, bytes (result, per device), group_size,
    in_entry (bool)} — wire-byte conversion happens in the roofline pass."""
    out = []
    cur_comp = ""
    entry = None
    for line in hlo.splitlines():
        m = re.match(r"^(%?[\w\.\-]+)\s*(\([^)]*\))?\s*->", line.strip())
        if line.startswith("ENTRY"):
            cur_comp = "ENTRY"
            continue
        if m and "=" not in line.split("->")[0]:
            cur_comp = m.group(1)
            continue
        stripped = line.strip()
        for col in _COLLECTIVES:
            # match op kind at the instruction position: "= TYPE op-name("
            if f" {col}(" in stripped or f" {col}-start(" in stripped:
                rhs = stripped.split("=", 1)
                if len(rhs) != 2:
                    continue
                result_type = rhs[1].strip().split(col)[0]
                nbytes = _shape_bytes(result_type)
                g = _GROUP_RE.search(stripped)
                if g:
                    group = len(g.group(1).split(","))
                else:
                    g2 = _GROUP_RE2.search(stripped)
                    group = int(g2.group(2)) if g2 else 1
                out.append({"op": col, "bytes": nbytes,
                            "group_size": group,
                            "comp": cur_comp,
                            "in_entry": cur_comp == "ENTRY"})
                break
    return out


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: str):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    with compat.set_mesh(mesh):
        cell = build_cell(arch, shape, mesh)
        jitted = jit_cell(cell, mesh)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)
    promo = bf16_promotion_bytes(hlo)

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "n_devices": mesh.devices.size,
        "kind": cell.kind, "meta": cell.meta,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            # live peak: args + outputs + temps, minus donated aliases
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "output_size_in_bytes", 0) or 0)
            - (getattr(mem, "alias_size_in_bytes", 0) or 0),
            "bf16_promotion_bytes": promo,
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals")} if cost
        else {},
        "collectives": {
            "n_ops": len(colls),
            "ops": colls[:512],
        },
    }
    os.makedirs(out_dir, exist_ok=True)
    stem = f"{mesh_name}__{arch}__{shape}"
    path = os.path.join(out_dir, stem + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    with gzip.open(os.path.join(out_dir, stem + ".hlo.gz"), "wt") as f:
        f.write(hlo)

    print(f"[dryrun] {arch} × {shape} on {mesh_name}: "
          f"compile {t_compile:.1f}s")
    print(f"  memory_analysis: {mem}")
    if cost:
        print(f"  cost_analysis: flops={cost.get('flops'):.3e} "
              f"bytes={cost.get('bytes accessed'):.3e}")
    print(f"  collectives: {len(colls)} sites, "
          f"{sum(c['bytes'] for c in colls):.3e} result bytes")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    if args.all:
        cells = all_cells()
    else:
        assert args.arch, "--arch required without --all"
        shapes = [args.shape] if args.shape else list(shapes_for(args.arch))
        cells = [(args.arch, s) for s in shapes]

    failures = []
    for arch, shape in cells:
        try:
            run_cell(arch, shape, multi_pod=args.multi_pod, out_dir=args.out)
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape, repr(e)))
            traceback.print_exc()
    if failures:
        print(f"FAILED {len(failures)}/{len(cells)}:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"dry-run OK: {len(cells)} cells")


if __name__ == "__main__":
    main()
