"""Weighted DAWN (paper §5 future work) + centrality analytics."""
import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # the seeded variants below always run
    HAVE_HYPOTHESIS = False

from repro.core import (bucketed_sssp, closeness, dijkstra_oracle,
                        eccentricity_sample, harmonic, minplus_sssp,
                        multi_source)
from repro.graph import generators as gen
from repro.graph.csr import CSRGraph


def _check_minplus_matches_dijkstra(n, seed):
    rng = np.random.default_rng(seed)
    m = n * 3
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    g = CSRGraph.from_edges(src, dst, n)
    w = rng.uniform(0.1, 5.0, g.m_pad).astype(np.float32)
    ref = dijkstra_oracle(g, w, 0)
    got = np.asarray(minplus_sssp(g, jnp.asarray(w), 0).dist)
    np.testing.assert_allclose(got, ref, rtol=1e-5)


def _check_bucketed_matches_dijkstra(n, w_max, seed):
    rng = np.random.default_rng(seed)
    m = n * 3
    g = CSRGraph.from_edges(rng.integers(0, n, m), rng.integers(0, n, m), n)
    w = rng.integers(1, w_max + 1, g.m_pad)
    ref = dijkstra_oracle(g, w.astype(np.float64), 0)
    got = np.asarray(bucketed_sssp(g, w, 0).dist)
    np.testing.assert_allclose(got, ref, rtol=1e-6)


@pytest.mark.parametrize("seed", range(8))
def test_minplus_matches_dijkstra(seed):
    rng = np.random.default_rng(seed * 3001 + 7)
    _check_minplus_matches_dijkstra(int(rng.integers(3, 61)),
                                    int(rng.integers(0, 10**6)))


@pytest.mark.parametrize("seed", range(6))
def test_bucketed_matches_dijkstra(seed):
    rng = np.random.default_rng(seed * 1009 + 11)
    _check_bucketed_matches_dijkstra(int(rng.integers(3, 41)),
                                     int(rng.integers(1, 5)),
                                     int(rng.integers(0, 10**6)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(3, 60), seed=st.integers(0, 10**6))
    def test_minplus_matches_dijkstra_hypothesis(n, seed):
        _check_minplus_matches_dijkstra(n, seed)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(3, 40), w_max=st.integers(1, 4),
           seed=st.integers(0, 10**6))
    def test_bucketed_matches_dijkstra_hypothesis(n, w_max, seed):
        _check_bucketed_matches_dijkstra(n, w_max, seed)


def test_minplus_on_unit_weights_equals_bfs():
    from repro.core import bfs_queue_numpy
    g = gen.rmat(8, 4, directed=False, seed=3)
    w = jnp.ones((g.m_pad,), jnp.float32)
    got = np.asarray(minplus_sssp(g, w, 5).dist)
    ref = bfs_queue_numpy(g, 5).astype(np.float64)
    ref = np.where(ref < 0, np.inf, ref)
    np.testing.assert_allclose(got, ref)


def test_closeness_matches_networkx():
    import networkx as nx
    g = gen.watts_strogatz(120, 6, 0.1, seed=4)
    src, dst = g.edge_arrays_np()
    G = nx.DiGraph()
    G.add_nodes_from(range(g.n_nodes))
    G.add_edges_from(zip(src, dst))
    # networkx closeness uses INCOMING distances; ours uses outgoing —
    # compare on the reversed graph
    ref = nx.closeness_centrality(G.reverse(), wf_improved=True)
    got = closeness(g, np.arange(g.n_nodes))
    ref_arr = np.array([ref[i] for i in range(g.n_nodes)])
    np.testing.assert_allclose(got, ref_arr, rtol=1e-6)


def test_harmonic_positive_and_bounded():
    g = gen.grid2d(8, 8)
    h = harmonic(g, np.arange(16))
    assert (h > 0).all()
    assert (h <= g.n_nodes).all()


def test_eccentricity_sample_bounds():
    g = gen.grid2d(10, 10)   # true diameter 18
    est = eccentricity_sample(g, n_samples=20, seed=1)
    assert est["diameter_lower"] <= 18
    assert est["radius_upper"] >= 9          # true radius is 9 (center)
    assert est["diameter_lower"] >= 9
