"""End-to-end graph analytics driver built on DAWN.

Computes, for any generated or on-disk graph:
  connectivity (WCC sizes) → per-component BFS distances (blocked APSP) →
  eccentricity / diameter estimates → sample shortest paths.

    PYTHONPATH=src python examples/graph_analytics.py --graph rmat \
        --scale 12 --sources 128
"""
import argparse
import time

import numpy as np

from repro.core import multi_source, reconstruct_path, wcc_stats
from repro.graph import generators as gen
from repro.graph.io import load_edgelist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat",
                    choices=["rmat", "grid", "ws", "disconnected", "file"])
    ap.add_argument("--path", help="edge list path for --graph file")
    ap.add_argument("--scale", type=int, default=11)
    ap.add_argument("--sources", type=int, default=64)
    args = ap.parse_args()

    if args.graph == "rmat":
        g = gen.rmat(args.scale, 8, directed=False, seed=1)
    elif args.graph == "grid":
        side = int(2 ** (args.scale / 2))
        g = gen.grid2d(side, side)
    elif args.graph == "ws":
        g = gen.watts_strogatz(2 ** args.scale, 8, 0.05, seed=1)
    elif args.graph == "disconnected":
        g = gen.disconnected(2 ** (args.scale - 7), 128, 4.0, seed=1)
    else:
        g = load_edgelist(args.path, undirected=True)
    print(f"graph: {g.n_nodes} nodes / {g.n_edges} edges")

    t0 = time.perf_counter()
    stats = wcc_stats(g)
    print(f"WCC: {stats['n_components']} components, "
          f"S_wcc={stats['S_wcc']} E_wcc={stats['E_wcc']} "
          f"({time.perf_counter() - t0:.2f}s)")

    rng = np.random.default_rng(0)
    sources = rng.integers(0, g.n_nodes, args.sources).astype(np.int32)
    t0 = time.perf_counter()
    res = multi_source(g, sources)
    dist = np.asarray(res.dist)
    dt = time.perf_counter() - t0
    ecc = np.where((dist >= 0).any(1), dist.max(1, initial=0), 0)
    print(f"{args.sources}-source BFS in {dt:.2f}s "
          f"({dt / args.sources * 1e3:.1f} ms/source)")
    print(f"eccentricity: min={ecc.min()} mean={ecc.mean():.1f} "
          f"max={ecc.max()} (diameter ≥ {ecc.max()})")

    # sample path reconstruction — every SsspResult carries a parent tree
    from repro.core import sssp
    res0 = sssp(g, int(sources[0]))
    d0 = np.asarray(res0.dist)
    far = int(np.argmax(d0))
    path = reconstruct_path(res0.parent, int(sources[0]), far, g.n_nodes)
    print(f"sample shortest path {sources[0]} → {far} "
          f"(len {d0[far]}): {path[:12]}{'...' if len(path) > 12 else ''}")

    # weighted analytics ride the same engine through the tropical semiring
    from repro.core import weighted_apsp
    w = rng.uniform(0.5, 4.0, g.m_pad).astype(np.float32)
    t0 = time.perf_counter()
    wres = weighted_apsp(g, w, sources[: min(32, len(sources))])
    wd = np.asarray(wres.dist)
    print(f"weighted APSP ({wd.shape[0]} sources) in "
          f"{time.perf_counter() - t0:.2f}s — forms "
          f"{dict(zip(('dense', 'sparse'), np.asarray(wres.direction_counts).tolist()))}, "
          f"mean finite dist {wd[np.isfinite(wd)].mean():.2f}")


if __name__ == "__main__":
    main()
