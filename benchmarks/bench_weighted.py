"""Paper §5 extension: the tropical (min,+) engine — fixed-dense vs
fixed-sparse vs auto, plus the scipy-Dijkstra external baseline.

Mirror of ``bench_apsp``: one source tile through the
``core/weighted.py::weighted_apsp`` driver on each family with the form
pinned to dense, pinned to sparse, and chosen by the engine (calibrated
per graph on the CPU reference path), emitting a JSON document with
per-family timings and the acceptance booleans:

  * ``auto_no_slower_than_best_everywhere`` — auto within TOLERANCE of
    min(dense, sparse) on every family;
  * ``auto_beats_worse_on`` — families where auto beats the *worse* fixed
    form by a real margin (>= 1.25x).

    PYTHONPATH=src python -m benchmarks.bench_weighted [--quick] [--out f.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import (WeightedConfig, dijkstra_oracle, minplus_sssp,
                        prepare_weighted)
from repro.core.weighted import weighted_apsp
from repro.graph import generators as gen

from ._timing import (BEAT_MARGIN, TOLERANCE, auto_vs_fixed,
                      time_interleaved_stats)

FAMILIES: Dict[str, Callable] = {
    "grid_road": lambda: gen.grid2d(32, 32),
    "rmat_social": lambda: gen.rmat(10, 8, directed=False, seed=1),
    "ws_citation": lambda: gen.watts_strogatz(1024, 8, 0.05, seed=3),
    "mycielskian": lambda: gen.mycielskian(9),
}

QUICK_FAMILIES = ("grid_road", "mycielskian")

_MODES = ("dense", "sparse", "auto")


def run(quick: bool = False, n_sources: int = 32, repeats: int = 5,
        csv: Optional[List[str]] = None) -> Dict:
    rng = np.random.default_rng(0)
    names = QUICK_FAMILIES if quick else tuple(FAMILIES)
    families = {}
    beats_worse = []
    auto_ok_everywhere = True
    for name in names:
        g = FAMILIES[name]()
        w = rng.uniform(0.5, 4.0, g.m_pad).astype(np.float32)
        pw = prepare_weighted(g, w)
        sources = np.arange(min(n_sources, g.n_nodes), dtype=np.int32)
        row: Dict = {"n_nodes": g.n_nodes, "n_edges": g.n_edges,
                     "n_sources": int(len(sources))}

        last_auto: List = []

        def make_go(mode):
            cfg = WeightedConfig(mode=mode, source_batch=32)

            def go():
                res = weighted_apsp(pw, sources=sources, config=cfg)
                res.dist.block_until_ready()
                if mode == "auto":
                    last_auto[:] = [res]
            return go

        stats = time_interleaved_stats({m: make_go(m) for m in _MODES},
                                       repeats)
        for mode, st in stats.items():
            row[f"t_{mode}"] = st["best"]
            row[f"t_{mode}_median"] = st["median"]
        res = last_auto[0]
        row["sweeps"] = int(res.sweeps)
        row["auto_direction_counts"] = dict(
            zip(("dense", "sparse"),
                np.asarray(res.direction_counts).tolist()))
        auto_vs_fixed(row, ("dense", "sparse"))
        auto_ok_everywhere &= row["auto_no_slower_than_best"]
        if row["auto_beats_worse"]:
            beats_worse.append(name)

        # external baseline: scipy Dijkstra (compiled C) per source, and
        # the single-source minplus path (the non-batched API)
        srcs_dij = sources[: min(4, len(sources))]
        t0 = time.perf_counter()
        for s in srcs_dij:
            dijkstra_oracle(g, w, int(s))
        row["t_scipy_dijkstra_per_source"] = \
            (time.perf_counter() - t0) / len(srcs_dij)
        import jax.numpy as jnp
        wj = jnp.asarray(w)
        minplus_sssp(g, wj, 0).dist.block_until_ready()  # jit
        t0 = time.perf_counter()
        for s in srcs_dij:
            minplus_sssp(g, wj, int(s)).dist.block_until_ready()
        row["t_minplus_sssp_per_source"] = \
            (time.perf_counter() - t0) / len(srcs_dij)

        families[name] = row
        if csv is not None:
            csv.append(f"weighted_{name},{row['t_auto'] * 1e6:.1f},"
                       f"auto_vs_best={row['auto_vs_best']:.2f}")
    return {
        "benchmark": "bench_weighted",
        "tolerance": TOLERANCE,
        "beat_margin": BEAT_MARGIN,
        "families": families,
        "auto_no_slower_than_best_everywhere": auto_ok_everywhere,
        "auto_beats_worse_on": beats_worse,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--sources", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    result = run(quick=args.quick, n_sources=args.sources,
                 repeats=args.repeats)
    text = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
