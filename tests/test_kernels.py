"""Pallas kernel validation (interpret=True): the semiring kernel registry,
shape/dtype sweeps + full BFS drivers vs the pure-jnp oracles for the
boolean kernels, and the tropical min-plus kernels vs their oracles, the
dense reference forms, and scipy Dijkstra.

This module runs without hypothesis (only the property-based test is
guarded) so CI can execute it as its own fast kernel-layer job step.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # seeded variants below always run regardless
    HAVE_HYPOTHESIS = False

from repro.graph import generators as gen
from repro.core import WeightedConfig, pack_bits, weighted_apsp
from oracles import bfs_dists, dijkstra_dists
from repro.kernels import common, registry
from repro.kernels.bovm import (fused_sweep, packed_pull_sweep, sweep_ref,
                                packed_pull_ref, msbfs_kernel, msbfs_packed,
                                pack_adjacency_pull)
from repro.kernels.tropical import (fused_minplus_sweep, sparse_relax_sweep,
                                    minplus_sweep_ref, sparse_relax_ref)
from repro.kernels.counting import fused_counting_sweep, counting_sweep_ref


def _random_state(rng, s, n, density=0.05, visited=0.2):
    f = (rng.random((s, n)) < density).astype(np.int8)
    dist = np.where(rng.random((s, n)) < visited, 1, -1).astype(np.int32)
    return jnp.asarray(f), jnp.asarray(dist)


# --------------------------------------------------------------------------
# the registry: one substrate, N semirings
# --------------------------------------------------------------------------

def test_registry_has_every_semiring():
    assert registry.available() == ("boolean", "counting", "tropical")
    assert registry.has("boolean") and registry.has("tropical")
    assert registry.has("counting")
    assert set(registry.get("boolean").forms) == {"push", "pull"}
    assert set(registry.get("tropical").forms) == {"dense", "sparse"}
    assert set(registry.get("counting").forms) == {"push"}


def test_registry_accepts_semiring_objects():
    from repro.core import BOOLEAN, COUNTING, TROPICAL
    assert registry.get(BOOLEAN).forms["push"] is fused_sweep
    assert registry.get(TROPICAL).forms["dense"] is fused_minplus_sweep
    assert registry.get(COUNTING).forms["push"] is fused_counting_sweep
    with pytest.raises(KeyError, match="min_label"):
        registry.get("min_label")    # no kernels for label propagation


def test_vmem_budgets_under_per_core_limit():
    """Every registered kernel's default tiles sit well under ~16 MB."""
    assert registry.get("boolean").vmem_bytes(form="push") \
        < common.VMEM_BUDGET_BYTES // 4
    assert registry.get("boolean").vmem_bytes(form="pull") \
        < common.VMEM_BUDGET_BYTES // 4
    assert registry.get("tropical").vmem_bytes(form="dense") \
        < common.VMEM_BUDGET_BYTES // 4
    assert registry.get("tropical").vmem_bytes(form="sparse", s=128,
                                               n_pad=2048) \
        < common.VMEM_BUDGET_BYTES // 4
    assert registry.get("counting").vmem_bytes(form="push") \
        < common.VMEM_BUDGET_BYTES // 4


# --------------------------------------------------------------------------
# boolean semiring kernels (paper Algs. 1/2)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("s,n,bs,bn,bk", [
    (64, 256, 64, 128, 128),
    (128, 512, 128, 128, 256),
    (8, 128, 8, 128, 128),
    (256, 384, 64, 128, 128),
])
def test_fused_sweep_shapes(s, n, bs, bn, bk):
    rng = np.random.default_rng(s * n)
    g = gen.erdos_renyi(n, 4.0, seed=n, directed=False)
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    f, dist = _random_state(rng, s, n)
    new_k, dist_k = fused_sweep(f, adj, dist, 5, bs=bs, bn=bn, bk=bk,
                                interpret=True)
    new_r, dist_r = sweep_ref(f, adj, dist, 5)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


@pytest.mark.parametrize("s,n,bs,bn,wk", [
    (8, 256, 8, 128, 8),
    (16, 512, 8, 128, 16),
    (32, 128, 16, 128, 4),
])
def test_packed_pull_shapes(s, n, bs, bn, wk):
    rng = np.random.default_rng(s + n)
    g = gen.erdos_renyi(n, 5.0, seed=n + 1, directed=True)
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    ap = pack_adjacency_pull(adj)
    f, dist = _random_state(rng, s, n)
    fp = pack_bits(f > 0)
    new_k, dist_k = packed_pull_sweep(fp, ap, dist, 3, bs=bs, bn=bn, wk=wk,
                                      interpret=True)
    new_r, dist_r = packed_pull_ref(fp, ap, dist, 3)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


def _fused_sweep_vs_ref(seed, density, visited):
    """kernel == oracle for arbitrary frontier/visited states."""
    rng = np.random.default_rng(seed)
    n, s = 256, 64
    adj = jnp.asarray((rng.random((n, n)) < 0.02).astype(np.int8))
    f = jnp.asarray((rng.random((s, n)) < density).astype(np.int8))
    dist = jnp.asarray(
        np.where(rng.random((s, n)) < visited, 2, -1).astype(np.int32))
    new_k, dist_k = fused_sweep(f, adj, dist, 7, bs=64, bn=128, bk=128,
                                interpret=True)
    new_r, dist_r = sweep_ref(f, adj, dist, 7)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


@pytest.mark.parametrize("seed", range(8))
def test_fused_sweep_randomized(seed):
    """Seeded always-run slice of the property space (the hypothesis
    variant below explores it adaptively when hypothesis is installed)."""
    rng = np.random.default_rng(seed * 7919 + 13)
    _fused_sweep_vs_ref(int(rng.integers(0, 10_000)),
                        float(rng.uniform(0.0, 0.3)),
                        float(rng.uniform(0.0, 1.0)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), density=st.floats(0.0, 0.3),
           visited=st.floats(0.0, 1.0))
    def test_fused_sweep_property(seed, density, visited):
        _fused_sweep_vs_ref(seed, density, visited)


def test_msbfs_kernel_end_to_end():
    g = gen.rmat(8, 5, directed=False, seed=21)
    n = 256
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    srcs = jnp.arange(64, dtype=jnp.int32)
    res = msbfs_kernel(adj, srcs, max_steps=n, interpret=True,
                       bs=64, bn=128, bk=128)
    refs = bfs_dists(g, np.asarray(srcs))
    np.testing.assert_array_equal(
        np.asarray(res.dist)[:, :g.n_nodes], refs)


def test_msbfs_packed_end_to_end():
    g = gen.rmat(8, 5, directed=True, seed=22)
    n = 256
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    ap = pack_adjacency_pull(adj)
    srcs = jnp.arange(16, dtype=jnp.int32)
    res = msbfs_packed(ap, srcs, n, max_steps=n, interpret=True,
                       bs=8, bn=128, wk=8)
    refs = bfs_dists(g, np.asarray(srcs))
    np.testing.assert_array_equal(
        np.asarray(res.dist)[:, :g.n_nodes], refs)


def test_tile_skip_preserves_semantics():
    """All-visited output tiles and empty frontier tiles must not change
    results (the Thm 3.2 tile-skip)."""
    rng = np.random.default_rng(0)
    n, s = 256, 64
    adj = jnp.asarray((rng.random((n, n)) < 0.05).astype(np.int8))
    f = np.zeros((s, n), np.int8)
    f[:, :128] = (rng.random((s, 128)) < 0.1)   # half the k-tiles empty
    dist = np.full((s, n), -1, np.int32)
    dist[:, 128:] = 3                            # half the out-tiles visited
    new_k, dist_k = fused_sweep(jnp.asarray(f), adj, jnp.asarray(dist), 4,
                                bs=64, bn=128, bk=128, interpret=True)
    new_r, dist_r = sweep_ref(jnp.asarray(f), adj, jnp.asarray(dist), 4)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


# --------------------------------------------------------------------------
# tropical semiring kernels (paper §5, min-plus)
# --------------------------------------------------------------------------

def _random_tropical_state(rng, s, n, *, density=0.03, wdensity=0.03):
    w = np.full((n, n), np.inf, np.float32)
    mask = rng.random((n, n)) < wdensity
    w[mask] = rng.uniform(0.5, 4.0, mask.sum())
    dist = np.where(rng.random((s, n)) < 0.3,
                    rng.uniform(0.0, 10.0, (s, n)), np.inf).astype(np.float32)
    f = (rng.random((s, n)) < density).astype(np.int8)
    fdist = np.where(f != 0, dist, np.inf).astype(np.float32)
    finite = w[np.isfinite(w)]
    w_min = np.float32(finite.min() if finite.size else np.inf)
    return (jnp.asarray(f), jnp.asarray(fdist), jnp.asarray(w),
            jnp.asarray(dist), w_min)


@pytest.mark.parametrize("s,n,bs,bn,bk", [
    (64, 256, 64, 128, 128),
    (8, 128, 8, 128, 128),
    (16, 384, 16, 128, 128),
])
def test_minplus_sweep_shapes(s, n, bs, bn, bk):
    rng = np.random.default_rng(s * n + 1)
    _, fdist, w, dist, w_min = _random_tropical_state(rng, s, n)
    new_k, dist_k = fused_minplus_sweep(fdist, w, dist, w_min, bs=bs, bn=bn,
                                        bk=bk, interpret=True)
    new_r, dist_r = minplus_sweep_ref(fdist, w, dist)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


def test_minplus_settled_skip_preserves_semantics():
    """The tropical o_occ table (Dijkstra settled bound at tile rank) must
    be exact: tiles whose distances all sit under min_frontier + w_min are
    skipped, and the result still matches the unskipped oracle."""
    rng = np.random.default_rng(7)
    s, n = 64, 256
    w = np.full((n, n), np.inf, np.float32)
    mask = rng.random((n, n)) < 0.05
    w[mask] = rng.uniform(1.0, 2.0, mask.sum())
    dist = np.full((s, n), np.inf, np.float32)
    dist[:, :128] = rng.uniform(0.0, 0.5, (s, 128))    # settled out-tile
    f = np.zeros((s, n), np.int8)
    f[:, :64] = (rng.random((s, 64)) < 0.2)            # half the k-tiles dead
    fdist = np.where(f != 0, dist, np.inf).astype(np.float32)
    w_min = np.float32(w[np.isfinite(w)].min())
    new_k, dist_k = fused_minplus_sweep(
        jnp.asarray(fdist), jnp.asarray(w), jnp.asarray(dist), w_min,
        bs=64, bn=128, bk=128, interpret=True)
    new_r, dist_r = minplus_sweep_ref(jnp.asarray(fdist), jnp.asarray(w),
                                      jnp.asarray(dist))
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


@pytest.mark.parametrize("s,n_pad,eb", [(8, 128, 128), (16, 256, 128),
                                        (32, 256, 256)])
def test_sparse_relax_shapes(s, n_pad, eb):
    rng = np.random.default_rng(s + n_pad)
    n = n_pad - 1                                     # room for the sentinel
    m = 4 * n
    m_pad = ((m + eb - 1) // eb) * eb
    src = np.full(m_pad, n, np.int32)
    dst = np.full(m_pad, n, np.int32)
    w = np.full(m_pad, np.inf, np.float32)
    src[:m] = rng.integers(0, n, m)
    dst[:m] = rng.integers(0, n, m)
    w[:m] = rng.uniform(0.5, 4.0, m)
    f = (rng.random((s, n_pad)) < 0.1).astype(np.int8)
    dist = np.where(rng.random((s, n_pad)) < 0.4,
                    rng.uniform(0.0, 8.0, (s, n_pad)),
                    np.inf).astype(np.float32)
    args = (jnp.asarray(f), jnp.asarray(dist), jnp.asarray(src),
            jnp.asarray(dst), jnp.asarray(w))
    new_k, dist_k = sparse_relax_sweep(*args, eb=eb, interpret=True)
    new_r, dist_r = sparse_relax_ref(*args)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


# --------------------------------------------------------------------------
# counting semiring kernel (Brandes stage 1 — path counting)
# --------------------------------------------------------------------------

def _random_counting_state(rng, s, n, *, density=0.05, visited=0.3):
    adj = (rng.random((n, n)) < 0.03).astype(np.int8)
    dist = np.where(rng.random((s, n)) < visited, 2, -1).astype(np.int32)
    sigma = np.where(dist >= 0, rng.integers(1, 9, (s, n)), 0
                     ).astype(np.float32)
    f = ((rng.random((s, n)) < density) & (dist >= 0)).astype(np.int8)
    fsigma = np.where(f != 0, sigma, 0.0).astype(np.float32)
    return (jnp.asarray(fsigma), jnp.asarray(adj), jnp.asarray(dist),
            jnp.asarray(sigma))


@pytest.mark.parametrize("s,n,bs,bn,bk", [
    (64, 256, 64, 128, 128),
    (8, 128, 8, 128, 128),
    (16, 384, 16, 128, 128),
])
def test_counting_sweep_shapes(s, n, bs, bn, bk):
    rng = np.random.default_rng(s * n + 3)
    fsigma, adj, dist, sigma = _random_counting_state(rng, s, n)
    k_out = fused_counting_sweep(fsigma, adj, dist, sigma, 5, bs=bs, bn=bn,
                                 bk=bk, interpret=True)
    r_out = counting_sweep_ref(fsigma, adj, dist, sigma, 5)
    for got, ref in zip(k_out, r_out):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def _counting_sweep_vs_ref(seed, density, visited):
    rng = np.random.default_rng(seed)
    fsigma, adj, dist, sigma = _random_counting_state(
        rng, 64, 256, density=density, visited=visited)
    k_out = fused_counting_sweep(fsigma, adj, dist, sigma, 7, bs=64,
                                 bn=128, bk=128, interpret=True)
    r_out = counting_sweep_ref(fsigma, adj, dist, sigma, 7)
    for got, ref in zip(k_out, r_out):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("seed", range(6))
def test_counting_sweep_randomized(seed):
    rng = np.random.default_rng(seed * 6199 + 29)
    _counting_sweep_vs_ref(int(rng.integers(0, 10_000)),
                           float(rng.uniform(0.0, 0.3)),
                           float(rng.uniform(0.0, 1.0)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), density=st.floats(0.0, 0.3),
           visited=st.floats(0.0, 1.0))
    def test_counting_sweep_property(seed, density, visited):
        _counting_sweep_vs_ref(seed, density, visited)


def test_counting_rectangular_partials_sum_to_square():
    """K-row block partials combine with the masked-add ⊕ (sum of gated
    candidates) to the square sweep — the sharded executor's reduction.
    Path counts are integers in f32, so the sum is exact."""
    rng = np.random.default_rng(19)
    s, n, k = 8, 256, 128
    fsigma, adj, dist, sigma = _random_counting_state(rng, s, n)
    new_sq, dist_sq, sig_sq = fused_counting_sweep(
        fsigma, adj, dist, sigma, 5, bs=8, bn=128, bk=128, interpret=True)
    cand = np.zeros((s, n), np.float32)
    for k0 in range(0, n, k):
        new_p, _, nsg_p = fused_counting_sweep(
            fsigma[:, k0: k0 + k], adj[k0: k0 + k], dist, sigma, 5,
            bs=8, bn=128, bk=128, interpret=True)
        cand += np.where(np.asarray(new_p) != 0, np.asarray(nsg_p), 0.0)
    new = (cand > 0) & (np.asarray(dist) < 0)
    np.testing.assert_array_equal(new.astype(np.int8), np.asarray(new_sq))
    np.testing.assert_array_equal(
        np.where(new, 5, np.asarray(dist)), np.asarray(dist_sq))
    np.testing.assert_array_equal(
        np.where(new, cand, np.asarray(sigma)), np.asarray(sig_sq))


def test_counting_tile_skip_preserves_semantics():
    """Dead frontier k-tiles and all-visited output tiles must not
    change either half of the (dist, sigma) state — the boolean o_occ
    is sound for the counting semiring (sigma only moves with dist)."""
    rng = np.random.default_rng(23)
    s, n = 64, 256
    adj = (rng.random((n, n)) < 0.05).astype(np.int8)
    dist = np.full((s, n), -1, np.int32)
    dist[:, 128:] = 3                            # half the out-tiles visited
    sigma = np.where(dist >= 0, 2.0, 0.0).astype(np.float32)
    f = np.zeros((s, n), np.int8)
    f[:, 128: 192] = (rng.random((s, 64)) < 0.2)  # half the k-tiles empty
    fsigma = np.where(f != 0, sigma, 0.0).astype(np.float32)
    args = (jnp.asarray(fsigma), jnp.asarray(adj), jnp.asarray(dist),
            jnp.asarray(sigma))
    k_out = fused_counting_sweep(*args, 4, bs=64, bn=128, bk=128,
                                 interpret=True)
    r_out = counting_sweep_ref(*args, 4)
    for got, ref in zip(k_out, r_out):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# --------------------------------------------------------------------------
# cross-semiring kernel equivalence (acceptance)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["dense", "sparse", "auto"])
def test_weighted_kernel_path_matches_dijkstra(mode, random_weighted):
    """weighted_apsp dispatching the tropical Pallas kernels under
    interpret=True == scipy Dijkstra (the PR's acceptance criterion)."""
    g, w = random_weighted(100, 3.0, 41)
    sources = np.arange(12, dtype=np.int32)
    ref = dijkstra_dists(g, w, sources)
    res = weighted_apsp(g, w, sources,
                        config=WeightedConfig(mode=mode, source_batch=16,
                                              use_kernel=True))
    np.testing.assert_allclose(np.asarray(res.dist), ref, rtol=1e-5)
    assert int(res.direction_counts.sum()) == int(res.sweeps) > 0


def test_weighted_kernel_matches_reference_forms(random_weighted):
    """Kernel forms and XLA reference forms are the same sweeps: identical
    distances AND identical sweep counts on the same graph."""
    g, w = random_weighted(90, 4.0, 43)
    sources = np.arange(8, dtype=np.int32)
    for mode in ("dense", "sparse"):
        kern = weighted_apsp(g, w, sources,
                             config=WeightedConfig(mode=mode, source_batch=8,
                                                   use_kernel=True))
        ref = weighted_apsp(g, w, sources,
                            config=WeightedConfig(mode=mode, source_batch=8,
                                                  use_kernel=False))
        np.testing.assert_array_equal(np.asarray(kern.dist),
                                      np.asarray(ref.dist))
        assert int(kern.sweeps) == int(ref.sweeps)


def test_unit_weight_tropical_kernel_equals_boolean_kernel():
    """(min,+) with unit weights through the tropical kernel == boolean
    BFS through the boolean kernel — the cross-semiring contract at the
    kernel layer."""
    g = gen.rmat(8, 5, directed=False, seed=51)
    n_pad = g.n_padded(128)
    w = jnp.ones((g.m_pad,), jnp.float32)
    sources = np.arange(16, dtype=np.int32)
    trop = weighted_apsp(g, np.asarray(w), sources,
                         config=WeightedConfig(mode="dense", source_batch=16,
                                               use_kernel=True))
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n_pad)), jnp.int8)
    boolean = msbfs_kernel(adj, jnp.asarray(sources), max_steps=n_pad,
                           interpret=True, bs=16, bn=128, bk=128)
    bdist = np.asarray(boolean.dist)[:, :g.n_nodes].astype(np.float64)
    bdist = np.where(bdist < 0, np.inf, bdist)
    np.testing.assert_allclose(np.asarray(trop.dist), bdist)


# --------------------------------------------------------------------------
# interpret-only policy: the registry seam must keep the tropical sparse
# kernel off compiled (real-TPU) backends
# --------------------------------------------------------------------------

def test_tropical_sparse_is_marked_interpret_only():
    ks = registry.get("tropical")
    assert "sparse" in ks.interpret_only
    assert ks.dispatchable("sparse", interpret=True)
    assert not ks.dispatchable("sparse", interpret=False)
    assert ks.dispatchable("dense", interpret=False)
    assert registry.get("boolean").dispatchable("push", interpret=False)


def test_sparse_relax_sweep_refuses_compiled_dispatch():
    """The kernel wrapper itself hard-errors on interpret=False — the
    contract is not just a registry convention."""
    f = jnp.zeros((8, 128), jnp.int8)
    d = jnp.full((8, 128), jnp.inf, jnp.float32)
    idx = jnp.full((128,), 127, jnp.int32)
    w = jnp.full((128,), jnp.inf, jnp.float32)
    with pytest.raises(RuntimeError, match="interpret-only"):
        sparse_relax_sweep(f, d, idx, idx, w, eb=128, interpret=False)


def test_compiled_tropical_dispatch_falls_back_to_xla_sparse():
    """sweep.tropical_forms(use_kernel=True, interpret=False) must route
    the sparse form to XLA: poison the registry's sparse kernel and check
    the returned closure never calls it yet still relaxes correctly."""
    import repro.core.sweep as S
    ks = registry.get("tropical")

    def boom(*a, **k):
        raise AssertionError("sparse kernel dispatched on compiled path")

    registry.register(registry.KernelSet(
        semiring="tropical", forms={**ks.forms, "sparse": boom},
        vmem_bytes=ks.vmem_bytes, notes=ks.notes,
        interpret_only=ks.interpret_only))
    try:
        g = gen.erdos_renyi(100, 3.0, seed=7)
        rng = np.random.default_rng(0)
        w = jnp.asarray(np.where(np.arange(g.m_pad) < g.n_edges,
                                 rng.uniform(0.5, 4.0, g.m_pad),
                                 np.inf).astype(np.float32))
        _, sparse = S.tropical_forms(None, g.src, g.dst, w,
                                     use_kernel=True, interpret=False)
        n_pad = g.n_padded(128)
        f = jnp.zeros((4, n_pad), jnp.int8).at[:, 0].set(1)
        d = jnp.full((4, n_pad), jnp.inf).at[:, 0].set(0.0)
        new, nd, _ = sparse(f, d, jnp.zeros((1,), jnp.int32), jnp.int32(1))
        _, ref_sparse = S.tropical_forms(None, g.src, g.dst, w,
                                         use_kernel=False)
        new_r, nd_r, _ = ref_sparse(f, d, jnp.zeros((1,), jnp.int32),
                                    jnp.int32(1))
        np.testing.assert_array_equal(np.asarray(new), np.asarray(new_r))
        np.testing.assert_array_equal(np.asarray(nd), np.asarray(nd_r))
    finally:
        registry.register(ks)    # restore the real kernel set


# --------------------------------------------------------------------------
# rectangular (K-row block) kernel dispatch — the sharded executor's
# vertex-sharded partial sweeps
# --------------------------------------------------------------------------

@pytest.mark.parametrize("s,k,n", [(64, 128, 256), (8, 128, 384)])
def test_fused_sweep_rectangular_matches_square_slice(s, k, n):
    """fused_sweep on a (k, n) K-row block == the k-rows' contribution:
    OR of the C block partials must equal the square sweep."""
    rng = np.random.default_rng(s + k + n)
    adj = jnp.asarray((rng.random((n, n)) < 0.04).astype(np.int8))
    f, dist = _random_state(rng, s, n)
    new_sq, dist_sq = fused_sweep(f, adj, dist, 5, bs=min(s, 64), bn=128,
                                  bk=128, interpret=True)
    parts = []
    for k0 in range(0, n, k):
        new_p, _ = fused_sweep(f[:, k0: k0 + k], adj[k0: k0 + k], dist, 5,
                               bs=min(s, 64), bn=128, bk=128,
                               interpret=True)
        parts.append(np.asarray(new_p))
    new_or = np.maximum.reduce(parts)
    np.testing.assert_array_equal(new_or, np.asarray(new_sq))
    dist_comb = np.where(new_or != 0, 5, np.asarray(dist))
    np.testing.assert_array_equal(dist_comb, np.asarray(dist_sq))


def test_minplus_rectangular_matches_square_slice():
    """fused_minplus_sweep K-row partials min-combine to the square
    result (⊕ = min is exact in f32)."""
    rng = np.random.default_rng(11)
    s, n, k = 8, 256, 128
    _, fdist, w, dist, w_min = _random_tropical_state(rng, s, n)
    _, dist_sq = fused_minplus_sweep(fdist, w, dist, w_min, bs=8, bn=128,
                                     bk=128, interpret=True)
    parts = []
    for k0 in range(0, n, k):
        _, nd_p = fused_minplus_sweep(fdist[:, k0: k0 + k],
                                      w[k0: k0 + k], dist, w_min, bs=8,
                                      bn=128, bk=128, interpret=True)
        parts.append(np.asarray(nd_p))
    np.testing.assert_array_equal(np.minimum.reduce(parts),
                                  np.asarray(dist_sq))
