"""Real spherical harmonics + rotation (Wigner-D) machinery for eSCN.

EquiformerV2's eSCN trick rotates each edge's irrep features into an
edge-aligned frame where the SO(3) convolution reduces to SO(2) (only
|m| ≤ m_max coefficients interact) — O(L^6) → O(L^3).

We build the rotation matrices *numerically but exactly* (to fp precision):
real SH are evaluated by associated-Legendre recursion; the block-diagonal
Wigner-D matrix for rotation R is recovered by fitting SH coefficients on a
fixed direction set:  D(R) = pinv(B) @ B_R,  B[i,·] = Y(u_i),
B_R[i,·] = Y(Rᵀ u_i).  ``pinv(B)`` is a compile-time constant; the per-edge
cost is one SH evaluation (K×M) and one (M×K)(K×M) matmul, M=(l_max+1)².
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np


def n_coeffs(l_max: int) -> int:
    return (l_max + 1) ** 2


def real_sph_harm(dirs: jax.Array, l_max: int) -> jax.Array:
    """Real spherical harmonics. dirs (..., 3) unit vectors -> (..., M).

    Ordering: (l, m) with m = -l..l, flat index l² + l + m.
    Associated Legendre via stable recursion; Condon-Shortley absorbed.
    """
    x, y, z = dirs[..., 0], dirs[..., 1], dirs[..., 2]
    r_xy = jnp.sqrt(jnp.maximum(x * x + y * y, 1e-30))
    cos_t = jnp.clip(z, -1.0, 1.0)
    sin_t = jnp.sqrt(jnp.maximum(1.0 - cos_t * cos_t, 0.0))
    phi_c = jnp.where(r_xy > 1e-12, x / r_xy, 1.0)
    phi_s = jnp.where(r_xy > 1e-12, y / r_xy, 0.0)

    # cos(m phi), sin(m phi) by recurrence
    cos_m = [jnp.ones_like(phi_c), phi_c]
    sin_m = [jnp.zeros_like(phi_s), phi_s]
    for m in range(2, l_max + 1):
        cos_m.append(2 * phi_c * cos_m[-1] - cos_m[-2])
        sin_m.append(2 * phi_c * sin_m[-1] - sin_m[-2])

    # normalized associated Legendre P̄_l^m (spherical-harmonic normalization)
    p = {}
    p[(0, 0)] = jnp.full_like(cos_t, 0.28209479177387814)  # 1/(2 sqrt(pi))
    for m in range(1, l_max + 1):
        # P̄_m^m = -sqrt((2m+1)/(2m)) sin_t P̄_{m-1}^{m-1}
        p[(m, m)] = -np.sqrt((2 * m + 1.0) / (2 * m)) * sin_t * p[(m - 1, m - 1)]
    for m in range(0, l_max):
        p[(m + 1, m)] = np.sqrt(2 * m + 3.0) * cos_t * p[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            a = np.sqrt((4.0 * l * l - 1.0) / (l * l - m * m))
            b = np.sqrt(((l - 1.0) ** 2 - m * m) / (4.0 * (l - 1.0) ** 2 - 1.0))
            p[(l, m)] = a * (cos_t * p[(l - 1, m)] - b * p[(l - 2, m)])

    out = []
    sqrt2 = np.sqrt(2.0)
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            if m == 0:
                out.append(p[(l, 0)])
            elif m > 0:
                out.append(sqrt2 * ((-1.0) ** m) * p[(l, m)] * cos_m[m])
            else:
                out.append(sqrt2 * ((-1.0) ** (-m)) * p[(l, -m)] * sin_m[-m])
    return jnp.stack(out, axis=-1)


@lru_cache(maxsize=8)
def _fit_basis(l_max: int):
    """Fixed direction set + pseudo-inverse SH design matrix (host consts).

    Runs under ensure_compile_time_eval so first use inside a jit trace
    still produces concrete constants."""
    m = n_coeffs(l_max)
    k = max(2 * m, 64)
    rng = np.random.default_rng(20221203)
    dirs = rng.normal(size=(k, 3))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    with jax.ensure_compile_time_eval():
        b = np.asarray(jax.device_get(
            real_sph_harm(jnp.asarray(dirs), l_max)), dtype=np.float64)
    pinv = np.linalg.pinv(b)
    return jnp.asarray(dirs, jnp.float32), jnp.asarray(pinv, jnp.float32)


def align_to_z(vec: jax.Array) -> jax.Array:
    """Rotation matrix R with R @ unit(vec) = ẑ.  vec (..., 3) -> (..., 3, 3)."""
    v = vec / jnp.maximum(jnp.linalg.norm(vec, axis=-1, keepdims=True), 1e-12)
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    # Rz(-phi) then Ry(-theta): theta = acos(z), phi = atan2(y, x)
    r_xy = jnp.sqrt(jnp.maximum(x * x + y * y, 1e-30))
    cph = jnp.where(r_xy > 1e-12, x / r_xy, 1.0)
    sph = jnp.where(r_xy > 1e-12, y / r_xy, 0.0)
    cth, sth = z, r_xy
    zero = jnp.zeros_like(x)
    one = jnp.ones_like(x)
    rz = jnp.stack([jnp.stack([cph, sph, zero], -1),
                    jnp.stack([-sph, cph, zero], -1),
                    jnp.stack([zero, zero, one], -1)], -2)
    ry = jnp.stack([jnp.stack([cth, zero, -sth], -1),
                    jnp.stack([zero, one, zero], -1),
                    jnp.stack([sth, zero, cth], -1)], -2)
    return ry @ rz


def wigner_d(rot: jax.Array, l_max: int) -> jax.Array:
    """Block-diagonal Wigner-D for real SH. rot (..., 3, 3) -> (..., M, M).

    c' = D @ c rotates coefficients such that  f_rot(u) = f(Rᵀ u)."""
    dirs, pinv = _fit_basis(l_max)
    # (Rᵀ u)_i = R_ji u_j
    rdirs = jnp.einsum("...ji,kj->...ki", rot, dirs)
    b_r = real_sph_harm(rdirs, l_max)                  # (..., K, M)
    return jnp.einsum("nk,...km->...nm", pinv, b_r)


def irrep_slices(l_max: int):
    return [(l * l, (l + 1) * (l + 1)) for l in range(l_max + 1)]


def m_indices(l_max: int):
    """For each |m|, the flat coefficient indices of (l, +m) and (l, -m)."""
    pos, neg = {}, {}
    for m in range(l_max + 1):
        pos[m] = [l * l + l + m for l in range(m, l_max + 1)]
        neg[m] = [l * l + l - m for l in range(m, l_max + 1)]
    return pos, neg
