"""End-to-end training driver: ~100M-param LM for a few hundred steps with
checkpointing and (simulated) fault tolerance.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import tokens as DT
from repro._attic.models import transformer as T
from repro.train import checkpoint as C
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = T.LMConfig(
        name="lm100m", n_layers=args.layers, d_model=args.d_model,
        n_heads=args.d_model // 64, n_kv=max(1, args.d_model // 128),
        d_head=64, d_ff=4 * args.d_model, vocab=32768, act="swiglu")
    print(f"params: {cfg.n_params() / 1e6:.1f}M")

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = O.adamw(peak_lr=3e-4,
                  schedule=O.cosine_schedule(3e-4, warmup=20,
                                             total=args.steps))
    state = opt.init(params)
    step = jax.jit(make_train_step(
        lambda p, b: T.loss_fn(p, b, cfg), opt, accum=2),
        donate_argnums=(0, 1))

    start = 0
    if C.latest_step(args.ckpt_dir):
        s = C.latest_step(args.ckpt_dir)
        restored, _ = C.restore(args.ckpt_dir, s,
                                {"params": params, "opt": state})
        params, state = restored["params"], restored["opt"]
        start = s
        print(f"resumed from step {s}")

    ck = C.CheckpointHook(args.ckpt_dir, interval=50)
    it = DT.lm_iterator(global_batch=args.batch, seq_len=args.seq,
                        vocab=cfg.vocab, start_step=start)
    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, state, m = step(params, state, batch)
        ck(i, params, state, m)
        if (i + 1) % 20 == 0:
            toks = args.batch * args.seq * (i + 1 - start)
            print(f"step {i + 1}: loss {float(m['loss']):.3f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({toks / (time.time() - t0):.0f} tok/s)")
    ck.flush()
    print("done.")


if __name__ == "__main__":
    main()
