"""equiformer-v2 — eSCN equivariant graph attention.
[arXiv:2306.12059; unverified]  12L d_hidden=128 l_max=6 m_max=2 8H."""
from ..models.gnn import EqV2Config

CONFIG = EqV2Config(
    name="equiformer-v2", n_layers=12, d_hidden=128, l_max=6, m_max=2,
    n_heads=8)
