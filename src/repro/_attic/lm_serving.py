"""LM serving engine: prefill/decode split + continuous batching.

A single-host simulation of the production LM serving loop: requests
arrive with prompts; the engine prefills them into free KV-cache slots,
then runs batched decode steps over all active slots, retiring finished
sequences and immediately admitting queued ones (continuous batching).
The decode step is the same jitted ``transformer.decode_step`` the
dry-run lowers at the 32k/500k shapes.

Moved to the attic with the rest of the model zoo (ROADMAP item 3); the
live graph-query serving tier is :class:`repro.serve.GraphService`.  An
engine built with ``graph_service=`` still co-serves
:class:`repro.serve.GraphQuery` traffic on each tick, which is what
``tests/test_serving.py`` exercises.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.engine import GraphQuery, GraphService
from .models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (L,) int32
    max_new: int = 16
    out: Optional[List[int]] = None
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ServingEngine:
    """Fixed-slot continuous batching over a shared KV cache.

    Optionally co-serves graph ``shortest_path`` queries: pass a
    :class:`GraphService` and submit :class:`GraphQuery` objects via
    :meth:`submit_graph`; each engine tick flushes one micro-batch of
    graph queries alongside the decode step.
    """

    def __init__(self, params, cfg: T.LMConfig, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 graph_service: Optional[GraphService] = None):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}
        self.free = list(range(slots))
        self.remaining = np.zeros(slots, np.int32)
        self.cache = T.make_cache(cfg, slots, max_len)
        self.cur_tok = np.zeros((slots, 1), np.int32)
        self._decode = jax.jit(
            lambda p, c, t, a: T.decode_step(p, c, t, cfg, active=a))
        self.completed: List[Request] = []
        self.graph_service = graph_service

    def submit_graph(self, query: GraphQuery):
        if self.graph_service is None:
            raise RuntimeError(
                "construct ServingEngine with graph_service= to serve graphs")
        self.graph_service.submit(query)

    def submit(self, req: Request):
        req.t_submit = time.monotonic()
        req.out = []
        self.queue.append(req)

    def _admit(self):
        while self.queue and self.free:
            req = self.queue.popleft()
            slot = self.free.pop()
            self.active[req.rid] = req
            self.slot_of[req.rid] = slot
            # reset the slot's cache position, then prefill its prompt
            # token-by-token with only this slot active (the production
            # prefill_step lowers the full-sequence path — launch/serve.py)
            self.cache["pos"] = self.cache["pos"].at[slot].set(0)
            mask = np.zeros(self.slots, bool)
            mask[slot] = True
            for tok in req.prompt:
                self.cur_tok[slot, 0] = tok
                self._decode_tick(mask)
            # first generated token comes from the last prefill logits
            first = int(np.argmax(self._last_logits[slot]))
            req.out.append(first)
            req.t_first = time.monotonic()
            self.cur_tok[slot, 0] = first
            self.remaining[slot] = req.max_new - 1
            if self.remaining[slot] == 0:
                req.t_done = req.t_first
                self.completed.append(self.active.pop(req.rid))
                self.free.append(self.slot_of.pop(req.rid))

    def _decode_tick(self, active_mask: np.ndarray):
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.cur_tok),
            jnp.asarray(active_mask))
        self._last_logits = np.asarray(logits[:, 0], np.float32)

    def step(self) -> int:
        """One engine tick: admit, serve one graph micro-batch, decode one
        token for all active slots, retire finished requests.  Returns the
        number of live requests (LM and graph)."""
        graph_live = 0
        if self.graph_service is not None:
            self.graph_service.flush()
            graph_live = self.graph_service.pending()
        self._admit()
        if not self.active:
            return graph_live
        mask = np.zeros(self.slots, bool)
        for rid in self.active:
            mask[self.slot_of[rid]] = True
        self._decode_tick(mask)
        nxt = np.argmax(self._last_logits, axis=-1).astype(np.int32)
        done_rids = []
        for rid, req in self.active.items():
            s = self.slot_of[rid]
            if self.remaining[s] <= 0:
                continue
            req.out.append(int(nxt[s]))
            self.cur_tok[s, 0] = nxt[s]
            self.remaining[s] -= 1
            if self.remaining[s] == 0:
                done_rids.append(rid)
        for rid in done_rids:
            req = self.active.pop(rid)
            req.t_done = time.monotonic()
            self.completed.append(req)
            self.free.append(self.slot_of.pop(rid))
        return len(self.active) + len(self.queue) + graph_live

    def run_to_completion(self, max_ticks: int = 10_000):
        for _ in range(max_ticks):
            if self.step() == 0 and not self.queue:
                break
        return self.completed
