"""Frontier representations for DAWN.

The paper stores frontiers as byte booleans (GPU memory is byte-addressable,
§3.4).  On TPU we keep two forms:

  * unpacked int8/bool  — feeds the MXU matmul path (BOVM) and segment ops;
  * bit-packed uint32   — 32 nodes/word, used for cross-device collectives
    and for the memory-model benchmark (beyond-paper optimization: 8–32×
    collective-byte reduction, DESIGN.md §9.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WORD = 32
UNREACHED = jnp.int32(-1)


def packed_width(n: int) -> int:
    return (n + WORD - 1) // WORD


def pack_bits(x: jax.Array) -> jax.Array:
    """(..., n) bool/int -> (..., ceil(n/32)) uint32 (little-endian bits)."""
    n = x.shape[-1]
    w = packed_width(n)
    pad = w * WORD - n
    xb = x.astype(jnp.uint32)
    if pad:
        xb = jnp.concatenate(
            [xb, jnp.zeros(x.shape[:-1] + (pad,), jnp.uint32)], axis=-1)
    xb = xb.reshape(x.shape[:-1] + (w, WORD))
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(xb << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(p: jax.Array, n: int) -> jax.Array:
    """(..., w) uint32 -> (..., n) bool."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (p[..., :, None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(p.shape[:-1] + (p.shape[-1] * WORD,))
    return flat[..., :n].astype(jnp.bool_)


def popcount(p: jax.Array) -> jax.Array:
    """Number of set bits per packed row (frontier occupancy)."""
    x = p
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return jnp.sum((x * jnp.uint32(0x01010101)) >> 24, axis=-1,
                   dtype=jnp.int32)


def one_hot_frontier(sources: jax.Array, n: int,
                     dtype=jnp.bool_) -> jax.Array:
    """(S,) int source ids -> (S, n) boolean frontier matrix."""
    return (jnp.arange(n)[None, :] == sources[:, None]).astype(dtype)
