"""Fault-tolerance simulation: heartbeats, a straggler, a dead host, and
the elastic re-mesh + checkpoint-restore plan the runner produces.

    PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro._attic.models import transformer as T
from repro.train import checkpoint as C
from repro.train import optimizer as O
from repro.train.fault_tolerance import FaultTolerantRunner
from repro.train.train_loop import make_train_step
from repro.data import tokens as DT


def main():
    n_hosts, tp = 16, 8
    cfg = T.LMConfig(name="ft-demo", n_layers=2, d_model=128, n_heads=4,
                     n_kv=2, d_head=32, d_ff=256, vocab=1024)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = O.adamw(peak_lr=1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(lambda p, b: T.loss_fn(p, b, cfg), opt))
    it = DT.lm_iterator(global_batch=8, seq_len=32, vocab=1024)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        runner = FaultTolerantRunner(n_hosts=n_hosts, model_parallel=tp,
                                     chips_per_host=4, ckpt_dir=ckpt_dir)
        rng = np.random.default_rng(0)
        now = 0.0
        try:
            for i in range(100):
                batch = {k: jnp.asarray(v) for k, v in next(it).items()}
                params, state, m = step(params, state, batch)
                if (i + 1) % 10 == 0:
                    C.save(ckpt_dir, i + 1, {"params": params, "opt": state})
                # synthesize per-host step times: host 2 straggles, host 11
                # dies at step 60
                now += 30.0
                times = {h: 1.0 + rng.random() * 0.05 for h in range(n_hosts)}
                times[2] = 3.0 + rng.random()          # persistent straggler
                if i >= 60:
                    times.pop(11)                       # dead host
                runner.on_step(i, times, now=now)
        except FaultTolerantRunner.ElasticRestart as e:
            print(f"elastic restart triggered at step {i}:")
            print(f"  dropped hosts: {e.plan.dropped_hosts}")
            print(f"  new mesh: {e.plan.mesh_shape} axes {e.plan.axis_names} "
                  f"({e.plan.n_chips} chips)")
            print(f"  restore from checkpoint step: {e.plan.restore_step}")
            restored, s = C.restore(ckpt_dir, e.plan.restore_step,
                                    {"params": params, "opt": state})
            params, state = restored["params"], restored["opt"]
            print(f"  restored step-{s} state; resuming with shrunken mesh")
            for j in range(s, s + 5):
                batch = {k: jnp.asarray(v) for k, v in next(it).items()}
                params, state, m = step(params, state, batch)
            print(f"  resumed OK, loss={float(m['loss']):.3f}")
            return
        raise SystemExit("expected an elastic restart!")


if __name__ == "__main__":
    main()
