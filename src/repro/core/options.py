"""Unified sweep-engine options base.

Every engine in the package (boolean ``apsp_engine``, tropical
``weighted_apsp``, counting ``counting_apsp``/``centrality``, sharded
``sharded_apsp``) takes a frozen, hashable config dataclass as its jit
static argument.  Historically each engine declared its own flat
dataclass; the caller-visible spread (``EngineConfig`` /
``WeightedConfig`` / ``CentralityConfig`` / ``ShardedConfig``) shared
most fields but nothing in the type system said so.

:class:`SweepOptions` is the shared base: the fields every engine
understands (source batching, form selection mode, kernel/dynamic
resolution, sweep bound, fused blocks, kernel tiles).  The per-engine
configs subclass it, adding only their engine-specific knobs (cost-model
constants, extra tile sizes, the sharded semiring selector), so

  * a plain ``SweepOptions`` can be projected onto any engine config via
    :meth:`SweepOptions.to` (the ``dawn`` facade in ``repro/api.py``
    does exactly this), and
  * ``isinstance(cfg, SweepOptions)`` holds for every engine config —
    the old class names keep working unchanged as thin subclasses.

``max_steps`` is the canonical spelling of the sweep/hop bound;
``WeightedConfig``/``ShardedConfig`` historically called it
``max_sweeps`` and keep that spelling as a synchronized alias (setting
either sets both).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, ClassVar, Optional, Tuple

if TYPE_CHECKING:  # import cycle: autotune builds ON options
    from .autotune import TuningPlan

__all__ = ["SweepOptions"]


@dataclasses.dataclass(frozen=True)
class SweepOptions:
    """Engine-agnostic sweep parameters (frozen, hashable — usable as a
    jit static argument).

    ``mode`` names a sweep *form* ("push"/"pull"/"sparse" boolean,
    "dense"/"sparse" tropical) or "auto" (cost-model selection).  The
    base class accepts any string; each engine subclass pins the set it
    dispatches via ``_mode_names`` and asserts membership.
    """
    source_batch: int = 128          # sources per tile (multiple of 8)
    mode: str = "auto"               # "auto" | an engine form name
    use_kernel: Optional[bool] = None  # None -> Pallas kernels iff on TPU
    dynamic: Optional[bool] = None   # per-sweep switch; None -> use_kernel
    max_steps: Optional[int] = None  # None -> n_nodes (hop bound)
    # fused multi-sweep blocks: 0 = off, K > 0 = K sweeps per kernel
    # launch, -1 = whole fixpoint in one launch (kernel path only)
    fused_steps: int = 0
    # kernel tiles (bs adapts to the source batch)
    bn: int = 128
    bk: int = 128
    # optional roofline TuningPlan (core/autotune.py): every engine
    # overlays it via autotune.apply (tiles, fused gate, cost constants)
    # and, on the calibrated mode="auto" path, pins the direction from
    # plan.pinned_direction instead of wall-clock timing — the
    # determinism lock.  Frozen/hashable, so it rides the jit static arg.
    tuning: Optional["TuningPlan"] = None

    # subclasses pin the form names they dispatch; () = accept anything
    _mode_names: ClassVar[Tuple[str, ...]] = ()

    def __post_init__(self):
        if self._mode_names:
            assert self.mode in ("auto",) + self._mode_names, self.mode
        assert self.source_batch % 8 == 0, \
            f"source_batch must be a multiple of 8, got {self.source_batch}"
        # above one stats/push tile the batch must tile exactly (bs = 128)
        assert self.source_batch <= 128 or self.source_batch % 128 == 0, \
            f"source_batch > 128 must be a multiple of 128, " \
            f"got {self.source_batch}"
        assert self.fused_steps >= -1, \
            f"fused_steps must be -1 (whole fixpoint), 0 (off) or a " \
            f"positive sweep count, got {self.fused_steps}"

    def to(self, cls, lenient: bool = False, **extra):
        """Project these options onto engine config class ``cls``.

        Copies every shared base field, overlays ``extra``, and lets
        ``cls.__post_init__`` validate.  With ``lenient=True`` a ``mode``
        the target engine does not dispatch falls back to "auto" instead
        of asserting — the facade uses this when one options object
        parameterizes several engines at once (e.g. ``.serve()`` builds
        both the boolean and tropical configs).
        """
        kw = {f.name: getattr(self, f.name)
              for f in dataclasses.fields(SweepOptions)}
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in kw.items() if k in names}
        kw.update(extra)
        valid = getattr(cls, "_mode_names", ())
        if lenient and valid and kw.get("mode", "auto") not in \
                ("auto",) + tuple(valid):
            kw["mode"] = "auto"
        return cls(**kw)
