from .csr import CSRGraph, DegreeStats, symmetrize
from . import generators, partition, sampler, io

__all__ = ["CSRGraph", "DegreeStats", "symmetrize", "generators",
           "partition", "sampler", "io"]
