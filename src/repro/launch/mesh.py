"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the ``pod``
axis is pure data parallel; cross-pod traffic is one (optionally
compressed) gradient reduction per step.

Defined as functions so importing this module never touches jax device
state (the dry-run overrides the platform device count BEFORE first use).
"""
from __future__ import annotations

from typing import Tuple

import jax

from ..compat import AxisType


def _axis_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_test_mesh(n_devices: int | None = None, model: int = 2):
    """Small mesh over however many (virtual) devices exist — tests only."""
    n = n_devices or len(jax.devices())
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"))


def mesh_from_plan(plan, devices=None):
    """Concrete ``jax.sharding.Mesh`` from a fault-tolerance
    :class:`repro.train.fault_tolerance.ElasticPlan` over whatever
    devices are alive now — the elastic-restart walk is
    ``plan_remesh(alive_chips, ...)`` → ``mesh_from_plan(plan)`` →
    ``checkpoint.restore(..., shardings=on the new mesh)``.

    Builds the Mesh directly from the first ``plan.n_chips`` devices (a
    shrunken plan must work in the same process that drove the larger
    mesh, so it cannot assume the plan covers every visible device)."""
    import numpy as np
    devs = list(jax.devices()) if devices is None else list(devices)
    if len(devs) < plan.n_chips:
        raise ValueError(
            f"elastic plan needs {plan.n_chips} devices, "
            f"only {len(devs)} visible")
    arr = np.empty(plan.n_chips, dtype=object)
    arr[:] = devs[: plan.n_chips]
    return jax.sharding.Mesh(arr.reshape(plan.mesh_shape),
                             plan.axis_names)


def dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out


# Hardware constants (TPU v5e) — used by the roofline model.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-chip usable)
HBM_BYTES = 16 * 1024**3        # 16 GiB per chip
