"""Quickstart: DAWN shortest paths in five lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import sssp, multi_source, bfs_scipy
from repro.graph import generators as gen

# 1. build a graph (or CSRGraph.from_edges / repro.graph.io.load_edgelist)
g = gen.watts_strogatz(5000, 8, 0.05, seed=0)
print(f"graph: {g.n_nodes} nodes, {g.n_edges} edges")

# 2. single-source shortest paths (auto-dispatches BOVM/SOVM)
res = sssp(g, source=0)
dist = np.asarray(res.dist)
print(f"SSSP from 0: eccentricity={int(res.eccentricity)}, "
      f"reachable={int((dist >= 0).sum())}, "
      f"edges touched={int(res.edges_touched)}")

# 3. verify against scipy's C BFS
assert (dist == bfs_scipy(g, 0)).all()
print("matches scipy.sparse.csgraph ✓")

# 4. batched multi-source (the MXU-friendly formulation)
batch = multi_source(g, np.arange(64), method="bovm")
print(f"64-source batch: dist matrix {batch.dist.shape}")
