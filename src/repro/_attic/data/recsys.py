"""Synthetic click-log pipeline for DIEN (deterministic in (seed, step))."""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..models.recsys import DIENConfig


def click_batch(step: int, cfg: DIENConfig, *, batch: int,
                seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    t = cfg.seq_len
    # zipf item popularity; categories derived from items (stable hash)
    items = (rng.zipf(1.2, size=(batch, t)) - 1) % cfg.n_items
    cats = (items * 2654435761) % cfg.n_cats
    hist_len = rng.integers(t // 4, t + 1, size=batch)
    mask = (np.arange(t)[None, :] < hist_len[:, None]).astype(np.float32)
    target_item = (rng.zipf(1.2, size=batch) - 1) % cfg.n_items
    target_cat = (target_item * 2654435761) % cfg.n_cats
    # label correlated with history/target category overlap → learnable
    overlap = (cats == target_cat[:, None]).mean(1)
    label = (overlap + rng.normal(0, 0.1, batch) > 0.05).astype(np.int32)
    neg_items = (rng.zipf(1.2, size=(batch, t)) - 1) % cfg.n_items
    return {
        "hist_items": items.astype(np.int32),
        "hist_cats": cats.astype(np.int32),
        "hist_mask": mask,
        "target_item": target_item.astype(np.int32),
        "target_cat": target_cat.astype(np.int32),
        "profile": rng.integers(0, cfg.n_profile,
                                (batch, cfg.profile_bags, cfg.bag_len)
                                ).astype(np.int32),
        "neg_items": neg_items.astype(np.int32),
        "neg_cats": ((neg_items * 2654435761) % cfg.n_cats).astype(np.int32),
        "label": label,
    }
