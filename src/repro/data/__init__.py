from . import tokens, graphs, pipeline
