from . import layers, transformer, gnn, recsys, spherical
