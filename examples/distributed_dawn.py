"""Sharded DAWN APSP over virtual devices — the multi-pod execution path
at demo scale (8 host-platform devices, mesh (2, 4)).

MUST run as its own process (device count is locked at jax init):

    PYTHONPATH=src python examples/distributed_dawn.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import bfs_queue_numpy, make_sharded_msbfs, shard_inputs \
    # noqa: E402
from repro.graph import generators as gen  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


def main():
    mesh = make_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {mesh.devices.size} devices")

    g = gen.rmat(10, 8, directed=False, seed=7)
    n_pad = 1024
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n_pad)), jnp.int8)
    sources = jnp.arange(32, dtype=jnp.int32)

    for schedule, bitpack in [("psum", False), ("allgather", False),
                              ("allgather", True)]:
        fn = make_sharded_msbfs(mesh, schedule=schedule, bitpack=bitpack)
        a, s = shard_inputs(mesh, adj, sources, schedule)
        out = fn(a, s)                      # compile
        t0 = time.perf_counter()
        out = fn(a, s)
        out.dist.block_until_ready()
        dt = time.perf_counter() - t0
        tag = f"{schedule}{'+bitpack' if bitpack else ''}"
        print(f"{tag:20s}: 32-source sweep set in {dt * 1e3:.1f} ms "
              f"({int(out.sweeps)} sweeps)")

    dist = np.asarray(out.dist)[:, :g.n_nodes]
    refs = np.stack([bfs_queue_numpy(g, i) for i in range(32)])
    assert (dist == refs).all()
    print("distances verified against queue-BFS oracle ✓")


if __name__ == "__main__":
    main()
