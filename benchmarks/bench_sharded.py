"""Sharded executor vs the fixed single-device engine — the collective
overhead / scale-out tradeoff, per semiring, as JSON.

For each family, one source tile runs through (a) the single-device
direction-optimized engine (``apsp_engine`` / ``weighted_apsp``) and
(b) the semiring-generic sharded executor
(``core/distributed.py::sharded_apsp``) over a mesh built from every
device jax can see.  Results are asserted bit-identical before timing —
a sharded run that drifts from the single-device engine is a bug, not a
data point.  The JSON carries the hard-gate fields (``n_nodes``,
``n_edges``, ``n_sources``, ``sweeps`` — sweep counts are identical by
construction, so the gate pins both paths at once) plus interleaved
best/median timings for the regression gate.

Under ``benchmarks.run`` jax is already initialized, so the mesh covers
however many devices exist (1 on CI: the benchmark then measures pure
shard_map overhead).  Standalone invocation forces 8 virtual host
devices BEFORE jax initializes:

    PYTHONPATH=src python -m benchmarks.bench_sharded [--quick] [--out f.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Callable, Dict, List, Optional

import numpy as np

from ._timing import time_interleaved_stats


def _families() -> Dict[str, Callable]:
    # lazy: main() must set XLA_FLAGS before anything imports jax
    from repro.graph import generators as gen
    return {
        "grid_road": lambda: gen.grid2d(32, 32),
        "ws_citation": lambda: gen.watts_strogatz(1024, 8, 0.05, seed=3),
    }


QUICK_FAMILIES = ("grid_road",)


def _mesh():
    import jax
    from repro.launch.mesh import make_mesh
    n_dev = len(jax.devices())
    if n_dev > 1 and n_dev % 2 == 0:
        return make_mesh((n_dev // 2, 2), ("data", "model"))
    return make_mesh((n_dev,), ("data",))


def run(quick: bool = False, n_sources: int = 32, repeats: int = 3,
        csv: Optional[List[str]] = None) -> Dict:
    from repro.core import (EngineConfig, ShardedConfig, WeightedConfig,
                            prepare_graph, prepare_sharded,
                            prepare_weighted)
    from repro.core.engine import apsp_engine
    from repro.core.distributed import sharded_apsp
    from repro.core.weighted import weighted_apsp

    mesh = _mesh()
    rng = np.random.default_rng(0)
    names = QUICK_FAMILIES if quick else tuple(_families())
    families = {}
    for name in names:
        g = _families()[name]()
        w = rng.uniform(0.5, 4.0, g.m_pad).astype(np.float32)
        sources = np.arange(min(n_sources, g.n_nodes), dtype=np.int32)
        row: Dict = {"n_nodes": g.n_nodes, "n_edges": g.n_edges,
                     "n_sources": int(len(sources))}

        pg = prepare_graph(g)
        pw = prepare_weighted(g, w)
        ops_b = prepare_sharded(g, mesh, config=ShardedConfig(
            semiring="boolean", mode="dense"))
        ops_t = prepare_sharded(g, mesh, weights=w, config=ShardedConfig(
            semiring="tropical", mode="dense"))
        bcfg = EngineConfig(mode="push", source_batch=32)
        wcfg = WeightedConfig(mode="dense", source_batch=32)

        # bit-identical before any timing (sweeps recorded as hard gate)
        single_b = apsp_engine(pg, sources, config=bcfg)
        shard_b = sharded_apsp(ops_b, sources)
        np.testing.assert_array_equal(np.asarray(shard_b.dist),
                                      np.asarray(single_b.dist))
        assert int(shard_b.sweeps) == int(single_b.sweeps)
        single_t = weighted_apsp(pw, sources=sources, config=wcfg)
        shard_t = sharded_apsp(ops_t, sources)
        np.testing.assert_array_equal(np.asarray(shard_t.dist),
                                      np.asarray(single_t.dist))
        assert int(shard_t.sweeps) == int(single_t.sweeps)
        row["sweeps"] = int(single_b.sweeps)
        row["sweeps_tropical"] = int(single_t.sweeps)

        def go_single_boolean():
            apsp_engine(pg, sources, config=bcfg).dist.block_until_ready()

        def go_sharded_boolean():
            sharded_apsp(ops_b, sources).dist.block_until_ready()

        def go_single_tropical():
            weighted_apsp(pw, sources=sources,
                          config=wcfg).dist.block_until_ready()

        def go_sharded_tropical():
            sharded_apsp(ops_t, sources).dist.block_until_ready()

        stats = time_interleaved_stats(
            {"single_boolean": go_single_boolean,
             "sharded_boolean": go_sharded_boolean,
             "single_tropical": go_single_tropical,
             "sharded_tropical": go_sharded_tropical}, repeats)
        for mode, st in stats.items():
            row[f"t_{mode}"] = st["best"]
            row[f"t_{mode}_median"] = st["median"]
        row["sharded_overhead_boolean"] = \
            row["t_sharded_boolean"] / row["t_single_boolean"]
        row["sharded_overhead_tropical"] = \
            row["t_sharded_tropical"] / row["t_single_tropical"]
        families[name] = row
        if csv is not None:
            csv.append(
                f"sharded_{name},{row['t_sharded_boolean'] * 1e6:.1f},"
                f"overhead_bool={row['sharded_overhead_boolean']:.2f}x")
    import jax
    return {
        "benchmark": "bench_sharded",
        "n_devices": len(jax.devices()),
        "mesh": {k: int(v) for k, v in dict(mesh.shape).items()},
        "families": families,
    }


def main() -> None:
    if "jax" not in sys.modules:     # standalone: virtual 8-device host
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--sources", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    result = run(quick=args.quick, n_sources=args.sources,
                 repeats=args.repeats)
    text = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
