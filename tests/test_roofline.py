"""launch/roofline.py: the roofline terms, dry-run cell analysis on a
real compiled-HLO fixture, malformed-input rejection, and a golden-file
check of the markdown report.

The HLO fixture is produced in-process (jit a matmul, gzip its optimized
HLO) so the numbers ``analyze_cell`` reports can be cross-checked against
an independent ``analyze_file`` pass over the same artifact — no stored
HLO blobs to rot.
"""
import gzip
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import roofline
from repro.launch.hlo_analysis import analyze_file, analyze_jitted
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.roofline import analyze_cell, markdown_table, roofline_terms

_GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                       "roofline_golden.md")


# --------------------------------------------------------------------------
# roofline_terms: the reusable core
# --------------------------------------------------------------------------

def test_roofline_terms_exact():
    t = roofline_terms(flops=197e12, bytes_accessed=819e9 / 2,
                       wire_bytes=0.0)
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["t_memory_s"] == pytest.approx(0.5)
    assert t["t_collective_s"] == 0.0
    assert t["dominant"] == "compute"


@pytest.mark.parametrize("flops,mem,wire,want", [
    (1e12, 1e9, 0.0, "compute"),      # 5ms compute vs 1.2ms memory
    (1e9, 1e12, 0.0, "memory"),       # 1.2s memory dominates
    (1e9, 1e9, 1e12, "collective"),   # 20s on the wire
    (0.0, 0.0, 0.0, "compute"),       # tie → first term wins, no crash
])
def test_roofline_terms_dominant(flops, mem, wire, want):
    assert roofline_terms(flops, mem, wire)["dominant"] == want


def test_roofline_terms_custom_peaks():
    t = roofline_terms(100.0, 100.0, 100.0, peak_flops=10.0, hbm_bw=20.0,
                       ici_bw=50.0)
    assert t["t_compute_s"] == pytest.approx(10.0)
    assert t["t_memory_s"] == pytest.approx(5.0)
    assert t["t_collective_s"] == pytest.approx(2.0)
    assert t["dominant"] == "compute"


# --------------------------------------------------------------------------
# analyze_cell on a real compiled-HLO fixture
# --------------------------------------------------------------------------

def _write_cell(tmp_path, name="cell", *, record=None, with_hlo=True):
    """A dry-run cell: crafted JSON + the gzipped optimized HLO of a
    512×512 matmul (real compiler output, built in-process)."""
    rec = {
        "arch": "dawn-sweep", "shape": "n512", "mesh": "1x1",
        "kind": "apsp", "n_devices": 1,
        "meta": {"model_flops": 2.0 * 512 ** 3},
        "memory": {"peak_bytes": 3 * 512 * 512 * 4,
                   "bf16_promotion_bytes": 0},
        "compile_s": 0.25,
    }
    if record is not None:
        rec = record
    json_path = str(tmp_path / f"{name}.json")
    with open(json_path, "w") as f:
        json.dump(rec, f)
    if with_hlo:
        a = jnp.zeros((512, 512), jnp.float32)
        text = jax.jit(lambda x, y: x @ y).lower(a, a).compile().as_text()
        with gzip.open(json_path.replace(".json", ".hlo.gz"), "wt") as f:
            f.write(text)
    return json_path


def test_analyze_cell_matches_independent_hlo_pass(tmp_path):
    json_path = _write_cell(tmp_path)
    row = analyze_cell(json_path)
    st = analyze_file(json_path.replace(".json", ".hlo.gz"))
    assert row["hlo_flops_dev"] == st.flops
    assert row["t_compute_s"] == pytest.approx(st.flops / PEAK_FLOPS_BF16)
    assert row["t_memory_s"] == pytest.approx(st.bytes_accessed / HBM_BW)
    assert row["t_collective_s"] == pytest.approx(st.wire_bytes / ICI_BW)
    # a single-device matmul moves no collective traffic
    assert row["wire_bytes_dev"] == 0.0
    assert row["n_collective_sites"] == 0
    # the matmul's 2N³ model flops are all real HLO flops
    assert row["useful_flops_ratio"] == pytest.approx(
        (2.0 * 512 ** 3) / st.flops)
    assert 0.0 < row["roofline_fraction"] <= 1.0 + 1e-9
    assert row["dominant"] in ("compute", "memory", "collective")
    assert row["peak_bytes_dev"] == 3 * 512 * 512 * 4
    assert row["compile_s"] == 0.25


def test_analyze_cell_divides_model_flops_by_chips(tmp_path):
    base = json.loads(json.dumps({
        "arch": "a", "shape": "s", "mesh": "m", "kind": "k",
        "n_devices": 4, "meta": {"model_flops": 8.0 * 512 ** 3},
        "memory": {"peak_bytes": 1}}))
    json_path = _write_cell(tmp_path, "multi", record=base)
    row = analyze_cell(json_path)
    st = analyze_file(json_path.replace(".json", ".hlo.gz"))
    assert row["chips"] == 4
    assert row["useful_flops_ratio"] == pytest.approx(
        (8.0 * 512 ** 3 / 4) / st.flops)


@pytest.mark.parametrize("drop", ["arch", "mesh", "n_devices", "memory"])
def test_analyze_cell_rejects_missing_keys(tmp_path, drop):
    rec = {"arch": "a", "shape": "s", "mesh": "m", "kind": "k",
           "n_devices": 1, "meta": {}, "memory": {"peak_bytes": 1}}
    del rec[drop]
    json_path = _write_cell(tmp_path, f"missing_{drop}", record=rec,
                            with_hlo=False)
    with pytest.raises(ValueError, match=f"missing keys.*{drop}"):
        analyze_cell(json_path)


def test_analyze_cell_rejects_memory_without_peak_bytes(tmp_path):
    rec = {"arch": "a", "shape": "s", "mesh": "m", "kind": "k",
           "n_devices": 1, "meta": {}, "memory": {"live_bytes": 7}}
    json_path = _write_cell(tmp_path, "nopeak", record=rec, with_hlo=False)
    with pytest.raises(ValueError, match="peak_bytes"):
        analyze_cell(json_path)


def test_analyze_cell_requires_hlo_artifact(tmp_path):
    json_path = _write_cell(tmp_path, "nohlo", with_hlo=False)
    with pytest.raises(FileNotFoundError):
        analyze_cell(json_path)


def test_analyze_cell_rejects_malformed_json(tmp_path):
    path = tmp_path / "broken.json"
    path.write_text("{not json")
    with pytest.raises(json.JSONDecodeError):
        analyze_cell(str(path))


# --------------------------------------------------------------------------
# markdown_table: golden file
# --------------------------------------------------------------------------

def _golden_rows():
    """Crafted rows (deliberately unsorted — the table must sort by
    arch/shape/mesh)."""
    def row(arch, shape, mesh, dominant, tc, tm, tcl, useful, frac, gib):
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "dominant": dominant, "t_compute_s": tc, "t_memory_s": tm,
                "t_collective_s": tcl, "useful_flops_ratio": useful,
                "roofline_fraction": frac,
                "peak_bytes_dev": gib * 2 ** 30}
    return [
        row("sweep", "n8192", "4x2", "collective",
            0.0041, 0.0023, 0.0087, 0.62, 0.291, 5.5),
        row("sweep", "n1152", "1x1", "compute",
            0.00125, 0.0004, 0.0, 0.97, 0.968, 0.4),
        row("bfs-baseline", "n1152", "1x1", "memory",
            0.0002, 0.0051, 0.0, 0.18, 0.039, 1.2),
    ]


def test_markdown_table_golden():
    got = markdown_table(_golden_rows())
    with open(_GOLDEN) as f:
        want = f.read().rstrip("\n")
    assert got == want, (
        "markdown_table output drifted from tests/data/roofline_golden.md "
        "— if the format change is intentional, regenerate the golden "
        "file from this test's _golden_rows()")


def test_markdown_table_sorts_rows():
    lines = markdown_table(_golden_rows()).splitlines()
    body = [ln.split("|")[1].strip() for ln in lines[2:]]
    assert body == sorted(body)


def test_markdown_table_empty_is_header_only():
    table = markdown_table([])
    assert len(table.rstrip("\n").splitlines()) == 2  # header + separator
    assert table.startswith("| arch |")


def test_markdown_table_renders_analyze_cell_row(tmp_path):
    """The two halves actually compose: a real analyzed cell renders."""
    table = markdown_table([analyze_cell(_write_cell(tmp_path))])
    assert "| dawn-sweep | n512 | 1x1 |" in table


# --------------------------------------------------------------------------
# analyze_jitted: the autotuner's pricing entry point
# --------------------------------------------------------------------------

def test_analyze_jitted_counts_matmul():
    a = jnp.zeros((256, 128), jnp.float32)
    b = jnp.zeros((128, 128), jnp.float32)
    st = analyze_jitted(lambda x, y: x @ y, a, b)
    # 2·M·N·K exactly (dims MXU-aligned so the compiler can't pad them)
    assert st.flops == pytest.approx(2 * 256 * 128 * 128)
    assert st.bytes_accessed > 0
    assert st.wire_bytes == 0.0


def test_analyze_jitted_accepts_prejitted():
    a = jnp.zeros((64, 64), jnp.float32)
    jitted = jax.jit(lambda x: x @ x)
    st = analyze_jitted(jitted, a)
    assert st.flops > 0
