"""deepseek-v3-671b — MoE LM with MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437; hf]  61L d_model=7168 128H d_ff=2048(expert)
vocab=129280; first 3 layers dense (d_ff=18432)."""
from ..models.layers import MLAConfig, MoEConfig
from ..models.transformer import LMConfig

CONFIG = LMConfig(
    name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
    n_kv=128, d_head=128, d_ff=18432, vocab=129280, act="swiglu",
    moe=MoEConfig(n_experts=256, top_k=8, d_model=7168, d_ff=2048,
                  shared_expert_ff=2048, act="swiglu"),
    n_dense_layers=3,
    mla=MLAConfig(d_model=7168, n_heads=128, q_lora_rank=1536,
                  kv_lora_rank=512, d_nope=128, d_rope=64, d_v=128),
    mtp=True)
