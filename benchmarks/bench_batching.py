"""Beyond-paper: multi-source blocked GEMM vs per-source sweeps (DESIGN §9.1)
and the kernel-path work-skipping ratio (tile-skip effectiveness).

Emits a JSON family row like the other engine benchmarks: interleaved
best/median timings from ``_timing.time_interleaved_stats`` for the
64-source batched BOVM against 64 sequential SOVM runs, plus the
deterministic ``tile_skip_fraction`` (the fraction of (source-tile,
output-tile, frontier-tile) GEMM tiles a frontier/occupancy-aware kernel
may skip, summed over the sweeps of the seeded RMAT fixpoint) — a
hard regression-gate field: it depends only on the graph and the sweep
schedule, not the machine.

    PYTHONPATH=src python -m benchmarks.bench_batching [--out f.json]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

import numpy as np
import jax.numpy as jnp

from repro.core import bovm_msbfs, sovm_sssp
from repro.graph import generators as gen

from ._timing import time_interleaved_stats


def _tile_skip_fraction(g, adj, srcs) -> float:
    """Deterministic per-sweep tile occupancy accounting."""
    from repro.core import one_hot_frontier, UNREACHED
    f = one_hot_frontier(srcs, adj.shape[0], dtype=jnp.int8)
    dist = jnp.where(f > 0, 0, jnp.full(f.shape, UNREACHED))
    total, skipped = 0, 0
    step = 0
    while step < adj.shape[0]:
        step += 1
        gi, gk, gj = 64 // 64, adj.shape[0] // 128, adj.shape[0] // 128
        f_occ = np.asarray(jnp.any(
            f.reshape(gi, 64, gk, 128) != 0, axis=(1, 3)))
        o_occ = np.asarray(jnp.any(
            dist.reshape(gi, 64, gj, 128) < 0, axis=(1, 3)))
        live = f_occ[:, None, :] & o_occ[:, :, None]     # (gi, gj, gk)
        total += live.size
        skipped += live.size - int(live.sum())
        counts = f.astype(jnp.float32) @ adj.astype(jnp.float32)
        new = (counts > 0) & (dist == UNREACHED)
        dist = jnp.where(new, step, dist)
        f = new.astype(jnp.int8)
        if not bool(jnp.any(new)):
            break
    return skipped / max(total, 1)


def run(quick: bool = False, repeats: int = 3,
        csv: Optional[List[str]] = None) -> Dict:
    g = gen.rmat(10, 8, directed=False, seed=5)
    adj = g.to_dense()
    srcs = jnp.arange(64, dtype=jnp.int32)

    def seq():
        for s in range(64):
            sovm_sssp(g, s).dist.block_until_ready()

    stats = time_interleaved_stats(
        {"batched": lambda: bovm_msbfs(adj, srcs).dist.block_until_ready(),
         "seq": seq},
        max(2, repeats))
    row: Dict = {"n_nodes": g.n_nodes, "n_edges": g.n_edges,
                 "n_sources": 64}
    for mode, st in stats.items():
        row[f"t_{mode}"] = st["best"]
        row[f"t_{mode}_median"] = st["median"]
    row["batch_speedup"] = row["t_seq"] / row["t_batched"]
    row["tile_skip_fraction"] = round(
        _tile_skip_fraction(g, adj, srcs), 6)

    if csv is not None:
        csv.append(f"batching_bovm64,{row['t_batched'] * 1e6:.0f},"
                   f"speedup_vs_64xSOVM={row['batch_speedup']:.2f}")
        csv.append(f"tile_skip_fraction,,"
                   f"skipped={row['tile_skip_fraction']:.3f}")
    return {
        "benchmark": "bench_batching",
        "families": {"rmat_64src": row},
        # legacy keys some notebooks read
        "batch_speedup": row["batch_speedup"],
        "tile_skip": row["tile_skip_fraction"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    result = run(quick=args.quick, repeats=args.repeats)
    text = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
