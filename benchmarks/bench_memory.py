"""Paper §3.4 / Eq. 13: DAWN vs BFS memory across the suite.

η = (4D+3)/(4D+8); we report both the model and the *actual allocated
bytes* of our implementations (CSR arrays + frontier/dist buffers)."""
from __future__ import annotations

from typing import List

from repro.configs.dawn import GRAPH_SUITE


def run(csv: List[str] | None = None):
    rows = {}
    for name, make in GRAPH_SUITE.items():
        g = make()
        d_avg = g.n_edges / g.n_nodes
        eta_model = (4 * d_avg + 3) / (4 * d_avg + 8)
        dawn_b = g.memory_bytes(boolean_frontier=True)
        bfs_b = g.memory_bytes(boolean_frontier=False)
        # actual buffers: CSR (indptr+indices) + dist(int32) + 2 bool
        actual_dawn = 4 * (g.n_nodes + 1) + 4 * g.m_pad + 4 * g.n_nodes \
            + 2 * (g.n_nodes + 1)
        actual_bfs = 4 * (g.n_nodes + 1) + 4 * g.m_pad + 8 * g.n_nodes
        rows[name] = (eta_model, dawn_b / bfs_b, actual_dawn / actual_bfs)
        if csv is not None:
            csv.append(f"memory_{name},,eta_model={eta_model:.4f}"
                       f";eta_eq13={dawn_b / bfs_b:.4f}"
                       f";eta_actual={actual_dawn / actual_bfs:.4f}")
    return rows


if __name__ == "__main__":
    out: List[str] = []
    run(csv=out)
    print("\n".join(out))
