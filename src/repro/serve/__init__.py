from .engine import ServingEngine, Request
