"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from typing import Any, Dict

from . import (arctic_480b, deepseek_v3_671b, dien, equiformer_v2,
               granite_34b, graphsage_reddit, meshgraphnet, nemotron_4_15b,
               qwen2_72b, schnet)
from .shapes import GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES

_ARCHS = {
    "granite-34b": ("lm", granite_34b.CONFIG),
    "qwen2-72b": ("lm", qwen2_72b.CONFIG),
    "nemotron-4-15b": ("lm", nemotron_4_15b.CONFIG),
    "arctic-480b": ("lm", arctic_480b.CONFIG),
    "deepseek-v3-671b": ("lm", deepseek_v3_671b.CONFIG),
    "equiformer-v2": ("gnn", equiformer_v2.CONFIG),
    "meshgraphnet": ("gnn", meshgraphnet.CONFIG),
    "graphsage-reddit": ("gnn", graphsage_reddit.CONFIG),
    "schnet": ("gnn", schnet.CONFIG),
    "dien": ("recsys", dien.CONFIG),
}

_SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}


def list_archs():
    return sorted(_ARCHS)


def get_arch(arch_id: str):
    """Returns (family, config)."""
    if arch_id not in _ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
    return _ARCHS[arch_id]


def shapes_for(arch_id: str) -> Dict[str, Any]:
    family, _ = get_arch(arch_id)
    return _SHAPES[family]


def all_cells():
    """Every (arch, shape) pair — the 40-cell dry-run matrix."""
    return [(a, s) for a in list_archs() for s in shapes_for(a)]
