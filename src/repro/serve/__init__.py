from .engine import (GraphQuery, GraphService, Request, ServingEngine)
from .oracle import (DistanceOracle, OracleAnswer, build_landmark_labels,
                     select_top_k)

__all__ = ["GraphQuery", "GraphService", "Request", "ServingEngine",
           "DistanceOracle", "OracleAnswer", "build_landmark_labels",
           "select_top_k"]
