"""Graph partitioning for distributed DAWN.

Two layouts, matched to the two DAWN execution paths:

1. ``block_dense``  — (R, C) grid of dense adjacency tiles for the BOVM /
   MXU path.  Tile (r, c) holds edges src∈row-block r, dst∈col-block c.
   Used by ``core.distributed`` under shard_map: each device owns one
   (or a strip of) tiles.

2. ``edge_partition`` — per-shard padded COO, partitioned by *destination*
   block so the scatter in the SOVM step is shard-local and the only
   collective is the frontier broadcast/psum.

Both produce fixed shapes (max-padded per shard) so they are shard_map-able.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from .csr import CSRGraph, _round_up


def block_dense(g: CSRGraph, r_blocks: int, c_blocks: int,
                dtype=jnp.int8) -> Tuple[jnp.ndarray, int]:
    """Dense (R, C, nb, nb) tile grid.  Returns (tiles, nb)."""
    n = g.n_nodes
    nb = _round_up((n + max(r_blocks, c_blocks) - 1) // max(r_blocks, c_blocks), 128)
    n_pad = nb * max(r_blocks, c_blocks)
    nb_r = n_pad // r_blocks
    nb_c = n_pad // c_blocks
    dense = np.zeros((n_pad, n_pad), dtype=np.int8)
    src, dst = g.edge_arrays_np()
    dense[src, dst] = 1
    tiles = dense.reshape(r_blocks, nb_r, c_blocks, nb_c).transpose(0, 2, 1, 3)
    return jnp.asarray(tiles, dtype=dtype), nb_r


def _dst_block_partition(g: CSRGraph, n_parts: int):
    """Shared dst-block bucketing: (src, dst, per-part selection masks,
    n_local, common multiple-of-128 lane count).  Both partitioners below
    derive from this so the padding/sentinel rules cannot diverge."""
    n = g.n_nodes
    n_local = (n + n_parts - 1) // n_parts
    src, dst = g.edge_arrays_np()
    part = dst // n_local
    sels = [part == p for p in range(n_parts)]
    e_pad = max(_round_up(int(max((int(s.sum()) for s in sels),
                                  default=0)), 128), 128)
    return src, dst, sels, n_local, e_pad


def edge_partition_global(g: CSRGraph, n_parts: int, weights=None):
    """Per-shard padded COO with GLOBAL ids — the sharded executor's
    sparse operand (``core/distributed.py``).  Edges are partitioned by
    destination block (scatter locality: each shard's scatter-⊕ lands in
    one contiguous dst range), every part padded to a common
    multiple-of-128 lane count with the CSR sentinel (src = dst = n,
    w = +inf) so the stack is shard_map-able as-is.  Returns:

      src  (P, e_pad) int32    global source ids (sentinel n)
      dst  (P, e_pad) int32    global destination ids (sentinel n)
      w    (P, e_pad) float32  lane weights, +inf padding (when
                               ``weights`` — per real edge — is given)
      e_pad, n_parts, n_nodes
    """
    n = g.n_nodes
    src, dst, sels, _, e_pad = _dst_block_partition(g, n_parts)
    src_out = np.full((n_parts, e_pad), n, dtype=np.int32)
    dst_out = np.full((n_parts, e_pad), n, dtype=np.int32)
    w_out = np.full((n_parts, e_pad), np.inf, dtype=np.float32)
    w = None if weights is None else \
        np.asarray(weights, np.float32)[: g.n_edges]
    for p, sel in enumerate(sels):
        k = int(sel.sum())
        src_out[p, :k] = src[sel]
        dst_out[p, :k] = dst[sel]
        if w is not None:
            w_out[p, :k] = w[sel]
    out = {
        "src": jnp.asarray(src_out),
        "dst": jnp.asarray(dst_out),
        "e_pad": e_pad,
        "n_parts": n_parts,
        "n_nodes": n,
    }
    if w is not None:
        out["w"] = jnp.asarray(w_out)
    return out


def edge_partition(g: CSRGraph, n_parts: int):
    """Partition COO edges by dst block. Returns dict of stacked padded arrays:

      src  (P, e_pad) int32   global source ids (sentinel n)
      dst  (P, e_pad) int32   *local* destination ids within the part
      n_local (int)           nodes per part (last part padded)
    """
    n = g.n_nodes
    src, dst, sels, n_local, e_pad = _dst_block_partition(g, n_parts)
    src_out = np.full((n_parts, e_pad), n, dtype=np.int32)
    dst_out = np.full((n_parts, e_pad), n_local, dtype=np.int32)
    for p, sel in enumerate(sels):
        k = int(sel.sum())
        src_out[p, :k] = src[sel]
        dst_out[p, :k] = dst[sel] - p * n_local
    return {
        "src": jnp.asarray(src_out),
        "dst": jnp.asarray(dst_out),
        "n_local": n_local,
        "n_parts": n_parts,
        "n_nodes": n,
    }
