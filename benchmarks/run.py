"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows:
  * bench_sssp        — Tables 7/8 (speedup over GAP-standin / queue BFS)
  * bench_scaling     — Tables 5/6 + Figs 3/4 (batch-parallel efficiency)
  * bench_memory      — §3.4 / Eq. 13 memory model
  * bench_complexity  — Eqs. 5/6/10 work-bound verification
  * bench_batching    — beyond-paper: blocked multi-source GEMM + tile-skip
  * bench_weighted    — paper §5 extension: (min,+) DAWN vs scipy Dijkstra
  * bench_apsp        — direction-optimized batched APSP engine:
                        fixed-push vs fixed-pull vs auto (JSON via
                        ``python -m benchmarks.bench_apsp``)
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (bench_apsp, bench_batching, bench_complexity, bench_memory,
               bench_scaling, bench_sssp, bench_weighted)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    rows = ["name,us_per_call,derived"]
    t0 = time.time()
    bench_sssp.run(n_sources=4 if args.quick else 16, csv=rows)
    bench_scaling.run(csv=rows)
    bench_memory.run(csv=rows)
    bench_complexity.run(csv=rows, n_sources=4 if args.quick else 8)
    bench_batching.run(csv=rows)
    bench_weighted.run(csv=rows, n_sources=2 if args.quick else 8)
    bench_apsp.run(quick=args.quick, repeats=3 if args.quick else 10,
                   csv=rows)
    print("\n".join(rows))
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
