from . import dawn
