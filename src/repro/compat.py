"""Version-compatibility shims for jax APIs that moved between releases.

The repo targets current jax, but must degrade gracefully on older
installs (e.g. 0.4.x, where ``shard_map`` still lives in
``jax.experimental`` and meshes have no axis types):

  * ``shard_map``   — ``jax.shard_map`` when present, else the
                      experimental implementation (same call signature).
  * ``set_mesh``    — ``jax.sharding.set_mesh`` when present, else a
                      null context (callers always pass explicit
                      shardings, so the ambient mesh is an optimization,
                      not a correctness requirement).
  * ``AxisType``    — ``jax.sharding.AxisType`` or ``None``; consumers
                      omit ``axis_types`` when it is ``None``.
"""
from __future__ import annotations

import contextlib

import jax

try:
    from jax.sharding import AxisType
except (ImportError, AttributeError):
    AxisType = None

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.6: experimental location, and check_vma was check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, **kw):
        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
        if kw.get("mesh") is None:
            # the experimental API has no ambient-mesh support (set_mesh
            # is a nullcontext on these versions) — fail with the cause
            # rather than a bare TypeError from the missing argument
            raise RuntimeError(
                "compat.shard_map on jax %s requires an explicit mesh= "
                "(no ambient-mesh support before jax.shard_map)"
                % jax.__version__)
        return _shard_map(f, **kw)


def set_mesh(mesh):
    fn = getattr(jax.sharding, "set_mesh", None)
    return fn(mesh) if fn is not None else contextlib.nullcontext()
