"""Batched graph-query serving: tiered admission + bucketed micro-batching.

A :class:`GraphService` answers :class:`GraphQuery` requests through a
three-level serving tier —

  1. **row cache** — an LRU of distance rows earlier sweeps already
     computed: repeated queries from a hot source cost one O(n) lookup;
  2. **landmark oracle** (serve/oracle.py) — O(|landmarks|)
     triangle-inequality bounds with an exactness certificate; only
     *certified* answers are served (bit-identical to a sweep by
     construction);
  3. **exact sweep fallback** — uncertified misses are bucketed by
     predicted sweep count and micro-batched into one direction-optimized
     multi-source run (core/engine.py) per flush, with per-query
     deadlines driving a deadline-aware flush policy (``tick``).

so graph analytics share one continuous-batching loop instead of
needing a separate deployment.

The service also fronts **mutable graphs**: built over a
:class:`repro.graph.dynamic.DynamicCSRGraph`, every entry point
(``submit`` / ``flush`` / ``tick``) first compares the graph's content
``epoch`` against the epoch the cached operands were prepared at.  On a
mismatch the prepared operands are rebuilt from the merged view and
every derived cache — the LRU row cache, the betweenness vector, the
sharded operands, and the landmark label tables behind the oracle — is
invalidated (the oracle rebuilds lazily on next touch).  A stale
certified answer is therefore impossible: admission never consults a
cache whose epoch disagrees with the graph.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import OrderedDict, deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.centrality import (MEASURES, CentralityConfig, betweenness,
                               centrality)
from ..core.distributed import (ShardedConfig, ShardedOperands,
                                prepare_sharded, sharded_apsp)
from ..core.engine import EngineConfig, PreparedGraph, apsp_engine_blocks, \
    prepare_graph
from ..core.weighted import (PreparedWeightedGraph, WeightedConfig,
                             prepare_weighted, weighted_apsp)
from .oracle import DistanceOracle, select_top_k


@dataclasses.dataclass
class GraphQuery:
    """A ``shortest_path`` request served by the batching loop.

    ``target=None`` returns the full distance vector from ``source``;
    otherwise ``hops`` is the shortest unweighted path length (or -1 when
    unreachable).  ``weighted=True`` routes through the tropical-semiring
    engine instead: ``dist`` becomes float32 (inf = unreachable) and a
    target query fills ``cost`` (the weighted distance) rather than
    ``hops``.

    ``analytics`` turns the query into a centrality request: a tuple of
    measure names from :data:`repro.core.centrality.MEASURES`
    ("closeness" / "harmonic" / "eccentricity" / "betweenness").  The
    per-source measures of every analytics query in a flush batch into
    ONE jit-batched multi-source run (core/centrality.py); betweenness —
    a whole-graph analytic — is computed once per service (through the
    sharded executor when a mesh is configured), cached, and answered
    from the cache.  Results land in ``analytics_result`` keyed by
    measure, all for node ``source``.

    ``k_nearest=k`` asks for the k nearest reachable targets instead:
    ``nearest`` is filled with (node, hops) pairs sorted by (distance,
    node id) — ties deterministic, identical whether the answer came
    from the oracle or the exact sweep fallback.

    ``deadline`` is a per-query latency budget in seconds from submit.
    The deadline-aware flush policy (:meth:`GraphService.tick`) tries to
    serve the query before it trips; a query whose deadline has already
    passed when its batch is formed is *surfaced* as ``expired=True``
    (``served_by="expired"``, no result) rather than silently dropped or
    allowed to pad-waste a live batch.

    After completion, ``served_by`` records the serving tier ("cache" /
    "oracle" / "sweep" / "sharded" / "expired") and ``certified`` is
    True when the answer was proven exact *without* running a sweep
    (row-cache or certified-oracle answers — both bit-identical to the
    sweep the fallback would have run).
    """
    qid: int
    source: int
    target: Optional[int] = None
    weighted: bool = False
    analytics: Optional[tuple] = None
    k_nearest: Optional[int] = None
    deadline: Optional[float] = None
    dist: Optional[np.ndarray] = None
    hops: Optional[int] = None
    cost: Optional[float] = None
    analytics_result: Optional[Dict[str, float]] = None
    nearest: Optional[List[Tuple[int, int]]] = None
    certified: bool = False
    served_by: Optional[str] = None
    expired: bool = False
    t_submit: float = 0.0
    t_done: float = 0.0
    t_deadline: float = math.inf
    _seq: int = dataclasses.field(default=0, repr=False)


class GraphService:
    """Tiered serving of shortest-path queries over one prepared graph.

    **Admission (at submit):** queries that can be answered exactly
    without a sweep are completed immediately — from the LRU **row
    cache** of previously computed distance rows (``row_cache_size``
    rows per semiring; repeated point-to-point traffic costs one lookup)
    or, with ``n_landmarks > 0``, from the **landmark oracle**
    (serve/oracle.py) when its triangle-inequality bounds certify the
    answer.  Both tiers are bit-identical to the sweep they avoid;
    uncertified answers are never served.

    **Bucketed batching (the fallback):** uncertified misses queue in
    FIFO buckets keyed by (query kind, predicted-sweep-count bin) — the
    landmark eccentricity bound predicts how many sweeps a source needs,
    so one deep-BFS query doesn't pad-waste a micro-batch of shallow
    ones (the length-bucketed batching idiom).  :meth:`flush` drains up
    to ``max_batch`` queries in global FIFO order (compat path — the
    attic LM ``ServingEngine`` tick uses it); :meth:`tick` applies the
    deadline-aware policy instead: a bucket flushes when it is full,
    when its earliest deadline minus the EWMA-estimated flush time
    leaves no headroom, or when its head has waited ``max_wait``.
    Queries whose deadline already passed when their batch forms are
    surfaced as ``expired`` (never silently dropped, never computed).

    Each flush runs at most one boolean, one tropical, and one
    counting/centrality micro-batch through the shared semiring sweep
    layer, exactly like decode steps amortize across KV slots; computed
    rows feed the row cache.  ``GraphQuery(analytics=...)`` requests
    micro-batch into one centrality run per flush, and the whole-graph
    betweenness vector is built once and served from cache.

    Pass ``mesh`` to scale flushes past one device: micro-batches of at
    least ``sharded_threshold`` queries route through the semiring-generic
    sharded executor (``core/distributed.py::sharded_apsp`` — sources
    sharded over the mesh's data axes, the operand optionally over
    ``model``), whose results are bit-identical to the single-device
    engines; smaller flushes stay on the single-device path where the
    collective overhead isn't worth it.

    Completed queries land in ``completed``, bounded to the most recent
    ``completed_retention`` entries; long-running loops should consume
    results via :meth:`drain_completed` (returns and clears) so nothing
    is lost to the retention cap.  ``clock`` injects a time source
    (default ``time.monotonic``) — deadline tests and the open-loop load
    benchmark drive a virtual clock through it.
    """

    def __init__(self, graph, *,
                 config: Optional[EngineConfig] = None,
                 weights=None,
                 weighted_config: Optional[WeightedConfig] = None,
                 max_batch: int = 32,
                 mesh=None,
                 sharded_threshold: int = 16,
                 sharded_config: Optional[ShardedConfig] = None,
                 sharded_weighted_config: Optional[ShardedConfig] = None,
                 centrality_config: Optional[CentralityConfig] = None,
                 n_landmarks: int = 0,
                 landmark_strategy: str = "mixed",
                 oracle: Optional[DistanceOracle] = None,
                 row_cache_size: int = 128,
                 completed_retention: Optional[int] = 4096,
                 max_wait: Optional[float] = None,
                 deadline_safety: float = 2.0,
                 clock: Callable[[], float] = time.monotonic):
        batch = max(8, ((max_batch + 7) // 8) * 8)
        if batch > 128:  # EngineConfig: above one push tile, multiple of 128
            batch = ((batch + 127) // 128) * 128
        self.config = config or EngineConfig(source_batch=batch)
        # per-flush latency cap: honored even with an explicit config (the
        # source tile stays config.source_batch wide; short flushes pad)
        self.max_batch = min(max_batch, self.config.source_batch)
        if hasattr(graph, "view") and weights is not None:
            raise ValueError(
                "weights= with a DynamicCSRGraph is ambiguous — a static "
                "weight array cannot track mutations; build the dynamic "
                "graph with weights instead")
        self.graph_source = graph
        self._base_weights = weights
        self._build_operands()
        # weighted queries ride the same kernel-path resolution as the
        # boolean engine: both semirings dispatch Pallas kernels through
        # the registry when the config (or TPU detection) says so
        self.weighted_config = weighted_config or \
            WeightedConfig(source_batch=min(self.config.source_batch, 128),
                           use_kernel=self.config.use_kernel)
        self.mesh = mesh
        self.sharded_threshold = max(1, sharded_threshold)
        self._sharded_cfg = {
            "boolean": sharded_config or
            ShardedConfig(semiring="boolean", mode="dense",
                          use_kernel=self.config.use_kernel),
            "tropical": sharded_weighted_config or
            ShardedConfig(semiring="tropical", mode="dense",
                          use_kernel=self.config.use_kernel),
        }
        self._sharded_ops: Dict[str, ShardedOperands] = {}
        self.sharded_flushes = 0
        self.centrality_config = centrality_config or CentralityConfig(
            source_batch=min(self.config.source_batch, 128),
            use_kernel=self.config.use_kernel)
        # betweenness is a whole-graph analytic: computed once (sharded
        # when a mesh is configured), then served from this cache
        self._betweenness: Optional[np.ndarray] = None
        # --- serving tier ----------------------------------------------
        self._clock = clock
        # the oracle is lazily (re)built by the `oracle` property so an
        # epoch invalidation can drop it without paying the label-table
        # sweeps until the next query that would consult it
        self._landmark_strategy = landmark_strategy
        if oracle is not None:
            self._oracle: Optional[DistanceOracle] = oracle
            self._oracle_n_landmarks = oracle.n_landmarks
        else:
            self._oracle = None
            self._oracle_n_landmarks = n_landmarks
        # LRU of exact distance rows keyed (kind, source); every sweep
        # feeds it, so a hot source pays one sweep ever
        self.row_cache_size = max(0, row_cache_size)
        self._row_cache: "OrderedDict[Tuple[str, int], np.ndarray]" = \
            OrderedDict()
        # FIFO buckets keyed (kind, predicted-sweep bin); _seq preserves
        # global submit order for the compat flush() drain
        self.buckets: "OrderedDict[Tuple[str, int], deque]" = OrderedDict()
        self._seq = 0
        self.max_wait = max_wait
        self.deadline_safety = deadline_safety
        self._flush_est = 0.02   # EWMA of sweep-flush seconds
        self.completed_retention = completed_retention
        self.completed: List[GraphQuery] = []
        # serving counters (totals since construction)
        self.cache_hits = 0
        self.oracle_hits = 0
        self.sweep_served = 0
        self.expired_count = 0
        self.n_submitted = 0
        self.n_completed_total = 0
        self.epoch_invalidations = 0

    # -- epoch freshness ---------------------------------------------------

    def _build_operands(self) -> None:
        """(Re)prepare engine operands from the current graph content.

        ``prepare_graph``/``prepare_weighted`` duck-type dynamic graphs
        (merged view + content epoch); for a weighted dynamic graph the
        lane weights come from its ``view_weights()``.
        """
        g = self.graph_source
        self.prepared: PreparedGraph = prepare_graph(g)
        if self._base_weights is not None:
            self.prepared_weighted: Optional[PreparedWeightedGraph] = \
                prepare_weighted(g, self._base_weights)
            self._weights = self._base_weights
        elif getattr(g, "weighted", False) and hasattr(g, "view_weights"):
            self.prepared_weighted = prepare_weighted(g)
            self._weights = g.view_weights()
        else:
            self.prepared_weighted = None
            self._weights = None

    @property
    def oracle(self) -> Optional[DistanceOracle]:
        """Landmark oracle for the *current* epoch, built on demand."""
        if self._oracle is None and self._oracle_n_landmarks > 0:
            self._oracle = DistanceOracle(
                self.prepared, n_landmarks=self._oracle_n_landmarks,
                strategy=self._landmark_strategy, config=self.config)
        return self._oracle

    def _ensure_fresh(self) -> None:
        """Invalidate every cached artifact when the graph has mutated.

        Compares the source graph's content ``epoch`` against the epoch
        ``self.prepared`` was built at (static graphs are always epoch
        0, so this is a no-op for them).  On mismatch: re-prepare the
        engine operands, clear the LRU row cache, the cached
        betweenness vector and the sharded operands, and drop the
        oracle (its landmark label tables rebuild lazily against the
        fresh ``PreparedGraph`` on next touch).  Called at the top of
        every entry point (``submit``/``flush``/``tick``), so no
        admission or batch execution can ever read a stale cache.
        """
        if int(getattr(self.graph_source, "epoch", 0)) == \
                self.prepared.epoch:
            return
        self._build_operands()
        self._row_cache.clear()
        self._betweenness = None
        self._sharded_ops.clear()
        self._oracle = None
        self.epoch_invalidations += 1

    def _sharded_operands(self, semiring: str) -> ShardedOperands:
        """Lazy per-semiring ShardedOperands (dense/partitioned operands
        built and device_put once, reused every sharded flush).  On a
        mesh without vertex sharding the padded size matches the
        single-device operands, so those are handed over instead of
        materializing a second O(n_pad^2) dense copy."""
        if semiring not in self._sharded_ops:
            cfg = self._sharded_cfg[semiring]
            dense_op = None
            if "model" not in self.mesh.axis_names or \
                    dict(self.mesh.shape).get("model", 1) == 1:
                if semiring == "boolean" and cfg.need_dense:
                    dense_op = self.prepared.adj
                elif semiring == "tropical" and cfg.need_dense:
                    dense_op = self.prepared_weighted.wdense
            self._sharded_ops[semiring] = prepare_sharded(
                self.prepared.graph, self.mesh,
                weights=self._weights if semiring == "tropical" else None,
                config=cfg, dense_op=dense_op)
        return self._sharded_ops[semiring]

    def _route_sharded(self, n_queries: int) -> bool:
        return self.mesh is not None and \
            n_queries >= self.sharded_threshold

    # -- admission ---------------------------------------------------------

    def submit(self, query: GraphQuery):
        """Validate, then answer from the cache/oracle tier or enqueue.

        Certified answers (row cache, landmark oracle) complete *at
        submit* — they never occupy a sweep batch.  Everything else
        lands in the FIFO bucket for its (kind, predicted-sweeps) key.
        """
        self._ensure_fresh()
        n = self.prepared.graph.n_nodes
        if not 0 <= query.source < n:
            raise ValueError(f"source {query.source} not in [0, {n})")
        if query.target is not None and not 0 <= query.target < n:
            raise ValueError(f"target {query.target} not in [0, {n})")
        if query.analytics is not None:
            if query.weighted:
                raise ValueError("analytics queries are unweighted "
                                 "(counting/boolean semiring)")
            unknown = set(query.analytics) - set(MEASURES)
            if unknown:
                raise ValueError(f"unknown analytics {sorted(unknown)}; "
                                 f"available: {MEASURES}")
        if query.k_nearest is not None:
            if query.k_nearest < 1:
                raise ValueError(f"k_nearest must be >= 1, "
                                 f"got {query.k_nearest}")
            if query.target is not None or query.analytics is not None \
                    or query.weighted:
                raise ValueError("k_nearest queries are unweighted and "
                                 "exclusive of target=/analytics=")
        if query.weighted and self.prepared_weighted is None:
            raise ValueError(
                "weighted query on a GraphService built without weights=")
        now = self._clock()
        query.t_submit = now
        query.t_deadline = now + query.deadline \
            if query.deadline is not None else math.inf
        query._seq = self._seq
        self._seq += 1
        self.n_submitted += 1
        if self._try_serve_cached(query, now):
            return
        self.buckets.setdefault(self._bucket_key(query),
                                deque()).append(query)

    def _try_serve_cached(self, q: GraphQuery, now: float) -> bool:
        """Row-cache then landmark-oracle admission; True == completed."""
        if q.analytics is not None:
            return False
        kind = "weighted" if q.weighted else "unweighted"
        row = self._row_cache.get((kind, q.source))
        if row is not None:
            self._row_cache.move_to_end((kind, q.source))
            self._fill_from_row(q, row)
            self.cache_hits += 1
            q.certified = True
            self._complete(q, "cache", now)
            return True
        if self.oracle is None or q.weighted:
            return False
        if q.target is not None:
            ans = self.oracle.query(q.source, q.target)
            if not ans.exact:
                return False
            q.hops = ans.hops
        elif q.k_nearest is not None:
            nearest = self.oracle.top_k(q.source, q.k_nearest)
            if nearest is None:
                return False
            q.nearest = nearest
        else:
            lrow = self.oracle.landmark_row(q.source)
            if lrow is None:
                return False
            q.dist = np.array(lrow)
        self.oracle_hits += 1
        q.certified = True
        self._complete(q, "oracle", now)
        return True

    def _fill_from_row(self, q: GraphQuery, row: np.ndarray) -> None:
        """Answer any non-analytics query kind from an exact dist row."""
        if q.target is not None:
            if q.weighted:
                q.cost = float(row[q.target])
            else:
                q.hops = int(row[q.target])
        elif q.k_nearest is not None:
            q.nearest = select_top_k(row, q.source, q.k_nearest)
        else:
            q.dist = np.array(row)

    def _cache_row(self, kind: str, source: int, row: np.ndarray) -> None:
        if self.row_cache_size <= 0:
            return
        self._row_cache[(kind, int(source))] = np.asarray(row)
        self._row_cache.move_to_end((kind, int(source)))
        while len(self._row_cache) > self.row_cache_size:
            self._row_cache.popitem(last=False)

    def _bucket_key(self, q: GraphQuery) -> Tuple[str, int]:
        """(kind, predicted-sweep bin): queries expected to converge in a
        similar sweep count batch together, so a deep-BFS straggler can't
        stretch the while_loop of a shallow batch (pad waste)."""
        if q.analytics is not None:
            return ("analytics", 0)
        if q.weighted:
            return ("weighted", 0)
        bin_ = self.oracle.predicted_sweeps(q.source).bit_length() \
            if self.oracle is not None else 0
        return ("unweighted", bin_)

    def _complete(self, q: GraphQuery, served_by: str, now: float) -> None:
        q.served_by = served_by
        q.t_done = now
        self.completed.append(q)
        self.n_completed_total += 1
        if self.completed_retention is not None and \
                len(self.completed) > self.completed_retention:
            del self.completed[: len(self.completed)
                               - self.completed_retention]

    def drain_completed(self) -> List[GraphQuery]:
        """Return all retained completed queries and clear the buffer —
        the consumption API for long-running serving loops (retention
        only bounds callers that never drain)."""
        out = self.completed
        self.completed = []
        return out

    def pending(self) -> int:
        return sum(len(b) for b in self.buckets.values())

    # -- flush policy ------------------------------------------------------

    def flush(self) -> List[GraphQuery]:
        """Serve up to ``max_batch`` pending queries in global FIFO
        order regardless of buckets or deadlines; returns them.  The
        unconditional drain — the attic ``ServingEngine.step`` calls it
        every tick; :meth:`tick` is the deadline/size-aware
        alternative."""
        self._ensure_fresh()
        batch = self._take_global(self.max_batch)
        return self._serve(batch)

    def tick(self) -> List[GraphQuery]:
        """Deadline-aware flush: serve ONE ripe bucket (FIFO within it),
        or nothing if no bucket is ripe.

        A bucket is ripe when it is full (``max_batch``), when its
        earliest deadline leaves less headroom than ``deadline_safety``
        x the EWMA flush-time estimate, or when its head query has
        waited ``max_wait``.  Serving a single bucket keeps the
        micro-batch homogeneous in predicted sweep count — the whole
        point of bucketing.  Ripest = earliest deadline, then oldest.
        """
        self._ensure_fresh()
        now = self._clock()
        headroom = self.deadline_safety * self._flush_est
        best_key, best_rank = None, None
        for key, bucket in self.buckets.items():
            if not bucket:
                continue
            dl = min(q.t_deadline for q in bucket)
            ripe = (len(bucket) >= self.max_batch
                    or dl - now <= headroom
                    or (self.max_wait is not None
                        and now - bucket[0].t_submit >= self.max_wait))
            if not ripe:
                continue
            rank = (dl, bucket[0]._seq)
            if best_rank is None or rank < best_rank:
                best_key, best_rank = key, rank
        if best_key is None:
            return []
        bucket = self.buckets[best_key]
        batch = [bucket.popleft()
                 for _ in range(min(len(bucket), self.max_batch))]
        return self._serve(batch)

    def _take_global(self, limit: int) -> List[GraphQuery]:
        """Pop up to ``limit`` queries in global submit order (merge the
        per-bucket FIFOs by sequence number)."""
        batch: List[GraphQuery] = []
        while len(batch) < limit:
            best = None
            for key, bucket in self.buckets.items():
                if bucket and (best is None
                               or bucket[0]._seq < self.buckets[best][0]._seq):
                    best = key
            if best is None:
                break
            batch.append(self.buckets[best].popleft())
        return batch

    # -- batch execution ---------------------------------------------------

    def _serve(self, batch: List[GraphQuery]) -> List[GraphQuery]:
        if not batch:
            return []
        now = self._clock()
        live: List[GraphQuery] = []
        for q in batch:
            if q.t_deadline < now:
                # deadline already blown: surface, don't compute — an
                # expired query must neither vanish nor pad a live batch
                q.expired = True
                self.expired_count += 1
                self._complete(q, "expired", now)
            else:
                live.append(q)
        if not live:
            return batch
        # measured with the injected clock so the EWMA below shares a
        # time scale with deadlines/ripeness under a virtual clock
        t0 = self._clock()
        analytics = [q for q in live if q.analytics is not None]
        unweighted = [q for q in live
                      if not q.weighted and q.analytics is None]
        weighted = [q for q in live if q.weighted]
        if unweighted:
            sources = np.asarray([q.source for q in unweighted], np.int32)
            if self._route_sharded(len(unweighted)):
                dist = np.asarray(
                    sharded_apsp(self._sharded_operands("boolean"),
                                 sources).dist)
                self.sharded_flushes += 1
                served_by = "sharded"
            else:
                (_, dist, _), = apsp_engine_blocks(self.prepared, sources,
                                                   config=self.config)
                dist = np.asarray(dist)
                served_by = "sweep"
            for row, q in zip(dist, unweighted):
                self._fill_from_row(q, row)
                self._cache_row("unweighted", q.source, row)
                q.served_by = served_by
        if weighted:
            sources = np.asarray([q.source for q in weighted], np.int32)
            if self._route_sharded(len(weighted)):
                dist = np.asarray(
                    sharded_apsp(self._sharded_operands("tropical"),
                                 sources).dist)
                self.sharded_flushes += 1
                served_by = "sharded"
            else:
                res = weighted_apsp(self.prepared_weighted, sources=sources,
                                    config=self.weighted_config)
                dist = np.asarray(res.dist)
                served_by = "sweep"
            for row, q in zip(dist, weighted):
                self._fill_from_row(q, row)
                self._cache_row("weighted", q.source, row)
                q.served_by = served_by
        if analytics:
            self._flush_analytics(analytics)
            for q in analytics:
                q.served_by = "sweep"
        self.sweep_served += len(live)
        # EWMA of the wall cost of one sweep flush — feeds tick()'s
        # deadline-headroom estimate
        self._flush_est = 0.5 * self._flush_est + \
            0.5 * (self._clock() - t0)
        now = self._clock()
        for q in live:
            q.t_done = now
            self.completed.append(q)
            self.n_completed_total += 1
        if self.completed_retention is not None and \
                len(self.completed) > self.completed_retention:
            del self.completed[: len(self.completed)
                               - self.completed_retention]
        return batch

    def _flush_analytics(self, queries: List[GraphQuery]) -> None:
        """Serve one micro-batch of centrality queries: all per-source
        measures ride ONE batched multi-source run (the analytics
        analogue of the distance micro-batch); betweenness comes from
        the per-service cache, built on first demand — through the
        sharded executor when the service has a mesh."""
        per_source = set()
        want_bc = False
        for q in queries:
            for m in q.analytics:
                if m == "betweenness":
                    want_bc = True
                else:
                    per_source.add(m)
        results: Dict[int, Dict[str, float]] = {
            id(q): {} for q in queries}
        # one batched run over only the queries that need per-source
        # measures (betweenness-only queries are served from the cache),
        # reusing the service's prepared operands and calibration cache
        ps_queries = [q for q in queries
                      if set(q.analytics) - {"betweenness"}]
        if ps_queries:
            sources = np.asarray([q.source for q in ps_queries], np.int32)
            res = centrality(self.prepared, sources,
                             measures=tuple(sorted(per_source)),
                             config=self.centrality_config)
            if res.closeness is not None:
                for i, q in enumerate(ps_queries):
                    results[id(q)]["closeness"] = float(res.closeness[i])
            if res.harmonic is not None:
                for i, q in enumerate(ps_queries):
                    results[id(q)]["harmonic"] = float(res.harmonic[i])
            if res.eccentricity is not None:
                for i, q in enumerate(ps_queries):
                    results[id(q)]["eccentricity"] = \
                        int(res.eccentricity[i])
        if want_bc:
            if self._betweenness is None:
                n = self.prepared.graph.n_nodes
                self._betweenness = betweenness(
                    self.prepared, config=self.centrality_config,
                    mesh=self.mesh if (self.mesh is not None and
                                       n >= self.sharded_threshold)
                    else None)
                if self.mesh is not None and \
                        n >= self.sharded_threshold:
                    self.sharded_flushes += 1
            for q in queries:
                if "betweenness" in q.analytics:
                    results[id(q)]["betweenness"] = \
                        float(self._betweenness[q.source])
        for q in queries:
            q.analytics_result = {m: results[id(q)][m]
                                  for m in q.analytics}
