"""Pure-jnp oracle for the counting-semiring sweep kernel."""
from __future__ import annotations

import jax.numpy as jnp


def counting_sweep_ref(fsigma: jnp.ndarray, adj: jnp.ndarray,
                       dist: jnp.ndarray, sigma: jnp.ndarray, step
                       ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Reference fused counting sweep.

    fsigma : (S, n) f32 — frontier-masked path counts
             (``where(frontier, sigma, 0)``)
    adj    : (n, n) int8 adjacency
    dist   : (S, n) int32 levels, -1 unreached
    sigma  : (S, n) f32 path counts

    cand[s, j] = Σ_k fsigma[s, k] · A[k, j];  new = (cand > 0) & unreached;
    dist' = new ? step : dist;  sigma' = new ? cand : sigma.
    """
    cand = fsigma @ adj.astype(jnp.float32)
    new = (cand > 0) & (dist < 0)
    return (new.astype(jnp.int8), jnp.where(new, step, dist),
            jnp.where(new, cand, sigma))
