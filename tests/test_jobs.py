"""Resumable-job layer (core/jobs.py): preemption-safe checkpoint/resume
bit-identical to uninterrupted runs, fault injection (kill between /
within checkpoint intervals, corrupt shards, dead hosts), and the
elastic restore-onto-a-smaller-mesh walk.

Kills are injected through the job's ``on_chunk`` seam (raising
simulates preemption after that chunk's checkpoint was submitted; with
``checkpoint_interval > 1`` the newest chunks are not yet checkpointed,
which simulates dying inside an interval).  Mesh tests run in a
subprocess so jax initializes with 8 virtual devices.
"""
import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

from oracles import adversarial_families, bfs_dists

import repro as dawn
from repro.core import SweepOptions
from repro.core.autotune import build_plan
from repro.core.engine import EngineConfig, apsp_engine
from repro.core.centrality import CentralityConfig, counting_apsp
from repro.core.jobs import JobMismatchError, JobResult, run_sweep_job
from repro.graph.csr import CSRGraph
from repro.train import checkpoint as C


class _Preempt(RuntimeError):
    """Injected kill."""


def _kill_after(chunk_idx):
    def on_chunk(k):
        if k == chunk_idx:
            raise _Preempt(f"killed after chunk {k}")
    return on_chunk


def _graphs():
    keep = ("star_in", "path", "two_components", "random_ragged")
    return {name: CSRGraph.from_edges(src, dst, n)
            for name, src, dst, n in adversarial_families(seed=0)
            if name in keep}


# mode="auto" used to need a pinned form here: the reference (CPU) path
# picked the direction by wall-clock calibration, so direction_counts
# were not reproducible across invocations.  A TuningPlan replaces the
# calibration with an analytic roofline argmin (core/autotune.py), which
# makes auto deterministic — the very property these resume tests
# compare.  One static plan serves every family: the direction pin uses
# per-call (s, n_pad, m_pad) and tiles clamp per graph.
_PLAN = build_plan(_graphs()["random_ragged"], use_hlo=False)
OPTS = SweepOptions(source_batch=8, mode="auto", tuning=_PLAN)


def _assert_results_equal(a: JobResult, b: JobResult):
    np.testing.assert_array_equal(a.dist, b.dist)
    if a.sigma is not None or b.sigma is not None:
        np.testing.assert_array_equal(a.sigma, b.sigma)
    assert a.sweeps == b.sweeps
    np.testing.assert_array_equal(a.direction_counts, b.direction_counts)
    assert a.edges_touched == b.edges_touched
    assert a.chunks_total == b.chunks_total


def test_job_matches_engine_boolean_and_counting():
    """Chunked job aggregation == one engine call (dist, sigma, sweeps,
    direction_counts, edges_touched) when the chunking matches the
    engine's internal tiling."""
    g = _graphs()["random_ragged"]
    srcs = np.arange(24, dtype=np.int32)
    job = run_sweep_job(g, srcs, workload="boolean", options=OPTS,
                        chunk_size=8)
    eng = apsp_engine(g, srcs, config=OPTS.to(EngineConfig, lenient=True))
    np.testing.assert_array_equal(job.dist, np.asarray(eng.dist))
    np.testing.assert_array_equal(job.dist, bfs_dists(g, srcs))
    assert job.sweeps == int(eng.sweeps)
    np.testing.assert_array_equal(job.direction_counts,
                                  np.asarray(eng.direction_counts))
    assert job.edges_touched == float(eng.edges_touched)
    assert (job.chunks_total, job.chunks_computed,
            job.chunks_restored) == (3, 3, 0)

    jc = run_sweep_job(g, srcs, workload="counting", options=OPTS,
                       chunk_size=8)
    ec = counting_apsp(g, srcs, config=OPTS.to(CentralityConfig,
                                               lenient=True))
    np.testing.assert_array_equal(jc.dist, np.asarray(ec.dist))
    np.testing.assert_array_equal(jc.sigma, np.asarray(ec.sigma))
    assert jc.sweeps == int(ec.sweeps)


@pytest.mark.parametrize("workload", ["boolean", "tropical", "counting"])
def test_resume_bit_identical_across_families(workload):
    """Kill after the first chunk, resume in a fresh invocation: every
    result field is bit-identical to the uninterrupted run, on every
    adversarial family."""
    rng = np.random.default_rng(3)
    for name, g in _graphs().items():
        w = rng.uniform(0.5, 4.0, g.m_pad).astype(np.float32) \
            if workload == "tropical" else None
        srcs = np.arange(min(24, g.n_nodes), dtype=np.int32)
        full = run_sweep_job(g, srcs, workload=workload, weights=w,
                             options=OPTS, chunk_size=8)
        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(_Preempt):
                run_sweep_job(g, srcs, workload=workload, weights=w,
                              options=OPTS, chunk_size=8,
                              checkpoint_dir=d, on_chunk=_kill_after(0))
            res = run_sweep_job(g, srcs, workload=workload, weights=w,
                                options=OPTS, chunk_size=8,
                                checkpoint_dir=d)
        _assert_results_equal(res, full)
        assert res.chunks_restored >= 1, name
        assert res.chunks_computed == res.chunks_total - \
            res.chunks_restored
        assert res.restored_step == res.chunks_restored
        assert res.corrupt_skipped == 0


def test_kill_inside_checkpoint_interval_recomputes_tail():
    """checkpoint_interval=2 and a kill after chunk 2 (0-indexed):
    chunks 0-1 are checkpointed, chunk 2's work is lost and must be
    recomputed — the resumed result is still bit-identical."""
    g = _graphs()["random_ragged"]
    srcs = np.arange(32, dtype=np.int32)          # 4 chunks of 8
    full = run_sweep_job(g, srcs, workload="boolean", options=OPTS,
                         chunk_size=8)
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(_Preempt):
            run_sweep_job(g, srcs, workload="boolean", options=OPTS,
                          chunk_size=8, checkpoint_dir=d,
                          checkpoint_interval=2, on_chunk=_kill_after(2))
        assert C.latest_step(d) == 2              # chunk 2 never landed
        res = run_sweep_job(g, srcs, workload="boolean", options=OPTS,
                            chunk_size=8, checkpoint_dir=d,
                            checkpoint_interval=2)
    _assert_results_equal(res, full)
    assert res.chunks_restored == 2
    assert res.chunks_computed == 2


def test_corrupt_checkpoint_falls_back_to_older():
    """Flip bytes in the newest checkpoint's shard: resume counts it as
    corrupt, falls back to the next-older intact checkpoint, and still
    reproduces the uninterrupted result."""
    g = _graphs()["random_ragged"]
    srcs = np.arange(32, dtype=np.int32)
    full = run_sweep_job(g, srcs, workload="boolean", options=OPTS,
                         chunk_size=8)
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(_Preempt):
            run_sweep_job(g, srcs, workload="boolean", options=OPTS,
                          chunk_size=8, checkpoint_dir=d,
                          on_chunk=_kill_after(2))
        assert C.latest_step(d) == 3
        with open(os.path.join(d, "step_000000003", "0000.bin"),
                  "r+b") as f:
            f.write(b"\xde\xad\xbe\xef")
        res = run_sweep_job(g, srcs, workload="boolean", options=OPTS,
                            chunk_size=8, checkpoint_dir=d)
    _assert_results_equal(res, full)
    assert res.corrupt_skipped == 1
    assert res.restored_step == 2
    assert res.chunks_restored == 2


def test_mismatched_job_refuses_to_resume():
    """A checkpoint_dir written by a different job (other sources, other
    graph content) raises JobMismatchError instead of silently resuming
    or overwriting."""
    gs = _graphs()
    g = gs["random_ragged"]
    with tempfile.TemporaryDirectory() as d:
        run_sweep_job(g, np.arange(16), workload="boolean", options=OPTS,
                      chunk_size=8, checkpoint_dir=d)
        with pytest.raises(JobMismatchError):
            run_sweep_job(g, np.arange(24), workload="boolean",
                          options=OPTS, chunk_size=8, checkpoint_dir=d)
        with pytest.raises(JobMismatchError):
            run_sweep_job(gs["path"], np.arange(16), workload="boolean",
                          options=OPTS, chunk_size=8, checkpoint_dir=d)


def test_finished_job_restores_without_compute():
    """Re-running a completed checkpointed job restores everything and
    sweeps nothing."""
    g = _graphs()["path"]
    srcs = np.arange(16, dtype=np.int32)
    with tempfile.TemporaryDirectory() as d:
        first = run_sweep_job(g, srcs, workload="boolean", options=OPTS,
                              chunk_size=8, checkpoint_dir=d)
        again = run_sweep_job(g, srcs, workload="boolean", options=OPTS,
                              chunk_size=8, checkpoint_dir=d)
    _assert_results_equal(again, first)
    assert again.chunks_computed == 0
    assert again.chunks_restored == again.chunks_total
    assert again.checkpoints_written == 0
    # resume=False recomputes from scratch instead
    with tempfile.TemporaryDirectory() as d:
        run_sweep_job(g, srcs, workload="boolean", options=OPTS,
                      chunk_size=8, checkpoint_dir=d)
        redo = run_sweep_job(g, srcs, workload="boolean", options=OPTS,
                             chunk_size=8, checkpoint_dir=d,
                             resume=False)
    assert redo.chunks_computed == redo.chunks_total
    _assert_results_equal(redo, first)


def test_facade_checkpointed_apsp():
    """dawn.prepare(g).apsp(checkpoint_dir=...) routes through the job
    layer, survives a kill, and carries the resume counters."""
    g = _graphs()["two_components"]
    h = dawn.prepare(g, source_batch=8)
    srcs = np.arange(24, dtype=np.int32)
    plain = h.apsp(srcs)
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(_Preempt):
            h.apsp(srcs, checkpoint_dir=d, chunk_size=8,
                   on_chunk=_kill_after(0))
        res = h.apsp(srcs, checkpoint_dir=d, chunk_size=8)
    assert isinstance(res, JobResult)
    np.testing.assert_array_equal(res.dist, np.asarray(plain.dist))
    assert res.sweeps == int(plain.sweeps)
    assert res.chunks_restored == 1 and res.restored_step == 1


def test_mutated_dynamic_graph_invalidates_checkpoints():
    """The job fingerprint pins the dynamic graph's content epoch: a
    mutation between runs must raise, not resume stale distances."""
    from repro.graph.dynamic import DynamicCSRGraph
    _, src, dst, n = [f for f in adversarial_families(0)
                      if f[0] == "path"][0]
    dg = DynamicCSRGraph.from_edges(src, dst, n)
    srcs = np.arange(8, dtype=np.int32)
    with tempfile.TemporaryDirectory() as d:
        run_sweep_job(dg, srcs, workload="boolean", options=OPTS,
                      chunk_size=4, checkpoint_dir=d)
        dg.insert_edges([0], [n - 1])
        with pytest.raises(JobMismatchError):
            run_sweep_job(dg, srcs, workload="boolean", options=OPTS,
                          chunk_size=4, checkpoint_dir=d)


# -------------------------------------------------------------------------
# sharded + elastic: subprocess with 8 virtual devices
# -------------------------------------------------------------------------

def _run(body: str, devices: int = 8):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \\
            "--xla_force_host_platform_device_count={devices}"
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]
    return res.stdout


@pytest.mark.slow
def test_sharded_job_elastic_resume_onto_smaller_mesh():
    """Acceptance: a sharded counting (betweenness-grade) job killed
    mid-run, whose host loss is detected by HeartbeatMonitor on an
    injected clock, resumes via plan_remesh + mesh_from_plan +
    restore(shardings=) onto a SMALLER mesh — and is bit-identical
    (dist, sigma, sweeps, counters) to the uninterrupted large-mesh run
    and the single-device engine."""
    out = _run("""
        import sys, tempfile; sys.path.insert(0, "tests")
        import numpy as np, jax
        from oracles import bfs_sigmas
        from repro.graph import generators as gen
        from repro.core import SweepOptions
        from repro.core.centrality import CentralityConfig, counting_apsp
        from repro.core.jobs import run_sweep_job
        from repro.launch.mesh import make_mesh, mesh_from_plan
        from repro.train import fault_tolerance as FT

        g = gen.rmat(8, 6, directed=False, seed=5)       # n = 256
        srcs = np.arange(32, dtype=np.int32)
        # direction_counts must survive the mesh change bit-for-bit, so
        # pin the form (the auto cost model's pmean'd stats are not
        # mesh-shape invariant; dist/sigma/sweeps are under any mode)
        opts = SweepOptions(source_batch=8, mode="dense")
        big = make_mesh((4, 2), ("data", "model"))

        full = run_sweep_job(g, srcs, workload="counting", mesh=big,
                             options=opts, chunk_size=8)
        single = counting_apsp(g, srcs, config=opts.to(
            CentralityConfig, lenient=True))
        np.testing.assert_array_equal(full.dist, np.asarray(single.dist))
        np.testing.assert_array_equal(full.sigma,
                                      np.asarray(single.sigma))
        np.testing.assert_allclose(full.sigma, bfs_sigmas(g, srcs))
        assert full.sweeps == int(single.sweeps)
        assert full.edges_touched > 0

        class Boom(RuntimeError): pass
        def kill(k):
            if k == 1:
                raise Boom()

        d = tempfile.mkdtemp()
        try:
            run_sweep_job(g, srcs, workload="counting", mesh=big,
                          options=opts, chunk_size=8, checkpoint_dir=d,
                          on_chunk=kill)
        except Boom:
            pass

        # virtual 2-host world: host 1 stops beating -> dead -> replan
        t = [0.0]
        mon = FT.HeartbeatMonitor(2, interval_s=10.0, dead_after=3,
                                  clock=lambda: t[0])
        assert mon.sweep() == []          # construction-time last_beat
        for step in range(1, 10):
            t[0] = 10.0 * step
            mon.beat(0)
            if step < 2:
                mon.beat(1)
        dead = mon.sweep()
        assert dead == [1], dead
        alive_chips = len(mon.alive_hosts) * 4
        plan = FT.plan_remesh(alive_chips, model_parallel=2,
                              restore_step=None, dropped_hosts=(1,))
        assert plan.mesh_shape == (2, 2)
        small = mesh_from_plan(plan)

        res = run_sweep_job(g, srcs, workload="counting", mesh=small,
                            options=opts, chunk_size=8, checkpoint_dir=d)
        assert res.chunks_restored == 2 and res.chunks_computed == 2
        np.testing.assert_array_equal(res.dist, full.dist)
        np.testing.assert_array_equal(res.sigma, full.sigma)
        assert res.sweeps == full.sweeps
        np.testing.assert_array_equal(res.direction_counts,
                                      full.direction_counts)
        assert res.edges_touched == full.edges_touched
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_boolean_job_resume_and_edge_counter_parity():
    """Boolean sharded job: kill + resume onto a source-only mesh is
    bit-identical, and the new sharded edges_touched counter is
    mesh-shape invariant (exact integer partial sums)."""
    out = _run("""
        import sys, tempfile; sys.path.insert(0, "tests")
        import numpy as np, jax
        from oracles import bfs_dists
        from repro.graph import generators as gen
        from repro.core import SweepOptions, ShardedConfig
        from repro.core.distributed import sharded_apsp
        from repro.core.jobs import run_sweep_job
        from repro.launch.mesh import make_mesh

        g = gen.erdos_renyi(237, 3.0, seed=9)
        srcs = np.arange(24, dtype=np.int32)
        opts = SweepOptions(source_batch=8, mode="dense")
        big = make_mesh((2, 4), ("data", "model"))
        small = make_mesh((2,), ("data",))

        a = sharded_apsp(g, srcs, mesh=big,
                         config=ShardedConfig(mode="dense"))
        b = sharded_apsp(g, srcs, mesh=small,
                         config=ShardedConfig(mode="dense"))
        assert float(a.edges_touched) == float(b.edges_touched) > 0

        full = run_sweep_job(g, srcs, workload="boolean", mesh=big,
                             options=opts, chunk_size=8)
        np.testing.assert_array_equal(full.dist, bfs_dists(g, srcs))

        class Boom(RuntimeError): pass
        def kill(k):
            if k == 0:
                raise Boom()
        d = tempfile.mkdtemp()
        try:
            run_sweep_job(g, srcs, workload="boolean", mesh=big,
                          options=opts, chunk_size=8, checkpoint_dir=d,
                          on_chunk=kill)
        except Boom:
            pass
        res = run_sweep_job(g, srcs, workload="boolean", mesh=small,
                            options=opts, chunk_size=8, checkpoint_dir=d)
        assert res.chunks_restored == 1
        np.testing.assert_array_equal(res.dist, full.dist)
        assert res.sweeps == full.sweeps
        np.testing.assert_array_equal(res.direction_counts,
                                      full.direction_counts)
        assert res.edges_touched == full.edges_touched
        print("OK")
    """)
    assert "OK" in out
