"""arctic-480b — MoE LM: 128 experts top-2 + dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]  35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128e top-2."""
from ..models.layers import MoEConfig
from ..models.transformer import LMConfig

CONFIG = LMConfig(
    name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv=8,
    d_head=128, d_ff=4864, vocab=32000, act="swiglu",
    moe=MoEConfig(n_experts=128, top_k=2, d_model=7168, d_ff=4864,
                  dense_residual_ff=4864, act="swiglu"),
    n_dense_layers=0)
