"""Pallas TPU kernel for the counting-semiring sweep (Brandes stage 1 —
shortest-path counting on the BOVM substrate).

``fused_counting_sweep`` — push direction on the shared skeleton from
``kernels/common.py``: grid (Si, Nj, Kk), K innermost, each (i, j) output
tile accumulating ``fsigma_block @ adj_block`` f32 MXU products in a VMEM
scratch, then fusing the counting epilogue on the last K step:

    new    = (acc > 0) & (dist < 0)        (the boolean discovery test)
    dist'  = new ? step : dist
    sigma' = new ? acc  : sigma            (⊕ = add, gated on dist ties)

The input frontier operand is ``fsigma = where(frontier, sigma, 0)`` —
the frontier-masked path counts — so the very matmul that detects
discovery (acc > 0 is exactly "any frontier in-neighbour") also sums the
shortest-path counts over all of them: one MXU pass produces both halves
of the (dist, sigma) state.

Tile skipping: ``f_occ[i, k]`` gates on any nonzero fsigma lane (counts
are strictly positive on the frontier); the boolean ``o_occ[i, j]`` "any
unreached target" table is SOUND for this semiring even though ⊕ = add
is not idempotent — sigma only ever changes where dist improves, and
dist only improves on unreached targets, so a tile with no unreached
target can change neither array.  (Contrast the tropical kernel, which
needs the settled-bound generalization.)

Like the boolean/tropical push kernels the operand may be a rectangular
(k = n/C) K-row block under the sharded executor; partial candidates are
then psum-combined across shards *before* the gate (masked-add ⊕ — see
core/distributed.py), because add-of-epilogue-outputs would double-gate.

VMEM (defaults bs=bn=bk=128): f32 fsigma + i8 adj + i32 dist + f32
sigma/acc + (i8, i32, f32) outputs ≈ 0.4 MB — see the table in
docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import common


def _counting_sweep_kernel(f_occ_ref, o_occ_ref, step_ref,   # scalar prefetch
                           fs_ref, a_ref, dist_ref, sig_ref,  # VMEM in
                           new_ref, dist_out_ref, sig_out_ref,  # VMEM out
                           acc_ref):                          # VMEM scratch
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = (f_occ_ref[i, k] > 0) & (o_occ_ref[i, j] > 0)

    @pl.when(live)
    def _accumulate():
        acc_ref[...] += jnp.dot(
            fs_ref[...], a_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        dist = dist_ref[...]
        cand = acc_ref[...]
        new = (cand > 0) & (dist < 0)
        new_ref[...] = new.astype(jnp.int8)
        dist_out_ref[...] = jnp.where(new, step_ref[0], dist)
        sig_out_ref[...] = jnp.where(new, cand, sig_ref[...])


@functools.partial(jax.jit, static_argnames=("bs", "bn", "bk", "interpret"))
def fused_counting_sweep(fsigma: jax.Array, adj: jax.Array, dist: jax.Array,
                         sigma: jax.Array, step: jax.Array, *, bs: int = 128,
                         bn: int = 128, bk: int = 128,
                         interpret: bool = False):
    """One fused counting sweep.  Shapes: fsigma (S, k) f32 — the
    frontier-masked path counts (``where(frontier, sigma, 0)``), adj
    (k, n) int8 (square k == n single-device; a K-row block k = n/C under
    the sharded executor — partials are masked-add-combined across
    shards), dist (S, n) int32, sigma (S, n) f32.  S % bs == 0,
    n % bn == 0, k % bk == 0.  Returns (new int8, dist int32, sigma f32)
    — bit-identical to the reference form (f32 sums commute per tile in
    the same K order; the skips are provably inert)."""
    s, k = fsigma.shape
    ka, n = adj.shape
    assert ka == k and dist.shape == (s, n) and sigma.shape == (s, n), \
        (fsigma.shape, adj.shape, dist.shape, sigma.shape)
    common.check_push_tiles(s, n, bs, bn, bk, k=k)
    gi, gj, gk = s // bs, n // bn, k // bk

    f_occ = common.block_any(fsigma > 0, gi, bs, gk, bk)
    o_occ = common.block_any(dist < 0, gi, bs, gj, bn)
    step_arr = jnp.asarray(step, jnp.int32).reshape(1)

    grid_spec = common.push_grid_spec(gi, gj, gk, bs=bs, bn=bn, bk=bk,
                                      num_scalar_prefetch=3,
                                      acc_dtype=jnp.float32, n_state=2)
    new, dist_out, sig_out = pl.pallas_call(
        _counting_sweep_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((s, n), jnp.int8),
                   jax.ShapeDtypeStruct((s, n), jnp.int32),
                   jax.ShapeDtypeStruct((s, n), jnp.float32)],
        compiler_params=common.sweep_compiler_params(),
        interpret=interpret,
    )(f_occ.astype(jnp.int32), o_occ.astype(jnp.int32), step_arr,
      fsigma, adj, dist, sigma)
    return new, dist_out, sig_out


# --------------------------------------------------------------------------
# fused multi-sweep persistent kernel (counting): the (dist, sigma) pair
# stays resident across sweeps — same skeleton, two state arrays
# --------------------------------------------------------------------------

def _fused_counting_kernel(meta_ref,                       # scalar prefetch
                           f_ref, a_ref, dist_ref, sig_ref,  # VMEM in
                           new_ref, dist_out_ref, sig_out_ref,  # VMEM out
                           prod_ref, stop_ref,             # VMEM out (1, 1)
                           *, max_sweeps: int):
    step0 = meta_ref[0]
    n_run = meta_ref[1]
    a = a_ref[...].astype(jnp.float32)   # (n, n), resident throughout
    d0 = dist_ref[...]                   # (bs, n) int32
    sg0 = sig_ref[...]                   # (bs, n) f32

    def sweep(t, carry):
        done, prod, f8, d, sg, new8 = carry
        live = (done == 0) & (t < n_run)
        fs = jnp.where(f8 != 0, sg, 0.0)
        cand = jnp.dot(fs, a, preferred_element_type=jnp.float32)
        new = (cand > 0) & (d < 0)
        any_new = jnp.any(new)
        upd = new & live
        d = jnp.where(upd, step0 + 1 + t, d)
        sg = jnp.where(upd, cand, sg)
        new8 = jnp.where(live, new.astype(jnp.int8), new8)
        f8 = jnp.where(live, new.astype(jnp.int8), f8)
        prod = prod + (live & any_new).astype(jnp.int32)
        done = done | (live & ~any_new).astype(jnp.int32)
        return done, prod, f8, d, sg, new8

    done, prod, _, d, sg, new8 = jax.lax.fori_loop(
        0, max_sweeps, sweep,
        (jnp.int32(0), jnp.int32(0), f_ref[...], d0, sg0,
         jnp.zeros(d0.shape, jnp.int8)))
    new_ref[...] = new8
    dist_out_ref[...] = d
    sig_out_ref[...] = sg
    prod_ref[0, 0] = prod
    stop_ref[0, 0] = done


@functools.partial(jax.jit,
                   static_argnames=("bs", "max_sweeps", "interpret"))
def fused_counting_multisweep(frontier: jax.Array, adj: jax.Array,
                              state, step: jax.Array, n_run: jax.Array, *,
                              bs: int = 128, max_sweeps: int = 1,
                              interpret: bool = False):
    """Run up to ``n_run`` counting sweeps in one invocation — the
    counting instantiation of the fused multi-sweep skeleton (see the
    boolean ``fused_boolean_multisweep`` for the accounting contract).
    frontier (S, n) int8, adj (n, n) int8 resident, ``state`` the
    (dist int32, sigma f32) pair.  Path counts are integer-valued f32 —
    exact below 2^24 — so the single whole-row MXU matmul per sweep is
    bit-identical to the per-sweep kernel's K-tiled accumulation.
    Returns (new int8, (dist, sigma), prod int32, stopped bool)."""
    dist, sigma = state
    s, n = frontier.shape
    assert adj.shape == (n, n) and dist.shape == (s, n) \
        and sigma.shape == (s, n), (frontier.shape, adj.shape, dist.shape)
    assert s % bs == 0 and n % 128 == 0, (s, n, bs)
    gi = s // bs
    meta = jnp.stack([jnp.asarray(step, jnp.int32),
                      jnp.asarray(n_run, jnp.int32)])

    grid_spec = common.fused_grid_spec(gi, bs=bs, n=n, f_block=(bs, n),
                                       op_block=(n, n), n_state=2)
    new, dist_out, sig_out, prod, stop = pl.pallas_call(
        functools.partial(_fused_counting_kernel, max_sweeps=max_sweeps),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((s, n), jnp.int8),
                   jax.ShapeDtypeStruct((s, n), jnp.int32),
                   jax.ShapeDtypeStruct((s, n), jnp.float32),
                   jax.ShapeDtypeStruct((gi, 1), jnp.int32),
                   jax.ShapeDtypeStruct((gi, 1), jnp.int32)],
        compiler_params=common.fused_compiler_params(),
        interpret=interpret,
    )(meta, frontier, adj, dist, sigma)
    return new, (dist_out, sig_out), jnp.max(prod), jnp.min(stop) > 0
