"""Pure-jnp oracles for the tropical (min,+) sweep kernels."""
from __future__ import annotations

import jax.numpy as jnp


def minplus_sweep_ref(fdist: jnp.ndarray, wdense: jnp.ndarray,
                      dist: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference fused min-plus sweep.

    fdist  : (S, n) f32 — frontier-masked distances (+inf off-frontier)
    wdense : (n, n) f32 — weight matrix, +inf non-edges
    dist   : (S, n) f32 — current distances, +inf unreached

    cand[s, j] = min_k fdist[s, k] + W[k, j]; returns
    (new int8 — entries improved, dist f32 — min(dist, cand)).
    """
    cand = jnp.min(fdist[:, :, None] + wdense[None, :, :], axis=1)
    new = cand < dist
    return new.astype(jnp.int8), jnp.where(new, cand, dist)


def sparse_relax_ref(frontier: jnp.ndarray, dist: jnp.ndarray,
                     src_idx: jnp.ndarray, dst_idx: jnp.ndarray,
                     w_edges: jnp.ndarray
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference edge-parallel relax: gather dist[:, src] + w over CSR
    lanes, frontier-gated, scatter-min into dst columns."""
    cand = jnp.where(frontier[:, src_idx] != 0,
                     dist[:, src_idx] + w_edges[None, :], jnp.inf)
    nd = dist.at[:, dst_idx].min(cand)
    new = nd < dist
    return new.astype(jnp.int8), nd
