"""Jitted wrappers + full DAWN drivers built on the Pallas sweep kernels.

On CPU (this container) the kernels execute under ``interpret=True``; on a
real TPU set ``interpret=False`` (the default flips on backend detection).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...core.frontier import UNREACHED, one_hot_frontier, pack_bits
from .. import common
from . import kernel as K
from . import ref as R

_default_interpret = common.default_interpret


class KernelDawnResult(NamedTuple):
    dist: jax.Array
    sweeps: jax.Array


def sweep(frontier, adj, dist, step, *, use_kernel: bool = True,
          interpret: bool | None = None, **tiles):
    """Single fused sweep — kernel when shapes allow, oracle otherwise."""
    if interpret is None:
        interpret = _default_interpret()
    s, n = frontier.shape
    bs = tiles.get("bs", 128)
    bn = tiles.get("bn", 128)
    bk = tiles.get("bk", 512)
    if (not use_kernel or s % bs or n % bn or n % bk):
        return R.sweep_ref(frontier, adj, dist, step)
    return K.fused_sweep(frontier, adj, dist, step, interpret=interpret,
                         **tiles)


@functools.partial(jax.jit,
                   static_argnames=("max_steps", "interpret", "bs", "bn", "bk"))
def msbfs_kernel(adj: jax.Array, sources: jax.Array, *, max_steps: int,
                 interpret: bool = True, bs: int = 128, bn: int = 128,
                 bk: int = 512) -> KernelDawnResult:
    """Full multi-source DAWN with the fused Pallas sweep in the loop body."""
    n = adj.shape[0]
    s = sources.shape[0]
    f0 = one_hot_frontier(sources, n, dtype=jnp.int8)
    dist0 = jnp.where(f0 > 0, 0, jnp.full((s, n), UNREACHED))

    def cond(c):
        _, _, step, done = c
        return (~done) & (step < max_steps)

    def body(c):
        f, dist, step, _ = c
        new, dist = K.fused_sweep(f, adj, dist, step + 1, bs=bs, bn=bn,
                                  bk=bk, interpret=interpret)
        return new, dist, step + 1, ~jnp.any(new > 0)

    _, dist, step, _ = jax.lax.while_loop(
        cond, body, (f0, dist0, jnp.int32(0), jnp.bool_(False)))
    return KernelDawnResult(dist, step)


@functools.partial(jax.jit,
                   static_argnames=("n", "max_steps", "interpret",
                                    "bs", "bn", "wk"))
def msbfs_packed(adj_in_packed: jax.Array, sources: jax.Array, n: int, *,
                 max_steps: int, interpret: bool = True, bs: int = 8,
                 bn: int = 128, wk: int = 128) -> KernelDawnResult:
    """Pull-direction DAWN over the bit-packed in-neighbour matrix."""
    s = sources.shape[0]
    f0 = one_hot_frontier(sources, n, dtype=jnp.bool_)
    dist0 = jnp.where(f0, 0, jnp.full((s, n), UNREACHED))

    def cond(c):
        _, _, step, done = c
        return (~done) & (step < max_steps)

    def body(c):
        fp, dist, step, _ = c
        new, dist = K.packed_pull_sweep(fp, adj_in_packed, dist, step + 1,
                                        bs=bs, bn=bn, wk=wk,
                                        interpret=interpret)
        return pack_bits(new > 0), dist, step + 1, ~jnp.any(new > 0)

    _, dist, step, _ = jax.lax.while_loop(
        cond, body, (pack_bits(f0), dist0, jnp.int32(0), jnp.bool_(False)))
    return KernelDawnResult(dist, step)


def pack_adjacency_pull(adj: jax.Array) -> jax.Array:
    """(n, n) dense adjacency -> (n, W) uint32 packed in-neighbour rows."""
    return pack_bits(adj.T != 0)
