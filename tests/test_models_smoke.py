"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + finiteness.
The FULL configs are exercised via the dry-run (ShapeDtypeStruct only)."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro._attic.configs import get_arch, list_archs, shapes_for, all_cells
from repro._attic.models import gnn as G
from repro._attic.models import recsys as R
from repro._attic.models import transformer as T
from repro._attic.launch.train import reduced_lm

LM_ARCHS = [a for a in list_archs() if get_arch(a)[0] == "lm"]
GNN_ARCHS = [a for a in list_archs() if get_arch(a)[0] == "gnn"]


def _finite(x):
    return bool(jnp.isfinite(x.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    _, cfg = get_arch(arch)
    cfg = reduced_lm(cfg)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits = T.forward(params, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert _finite(logits)
    loss = T.loss_fn(params, {"tokens": toks, "labels": toks}, cfg)
    assert _finite(loss) and float(loss) > 0
    grads = jax.grad(lambda p: T.loss_fn(p, {"tokens": toks,
                                             "labels": toks}, cfg))(params)
    assert all(_finite(g) for g in jax.tree_util.tree_leaves(grads))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_decode_smoke(arch):
    _, cfg = get_arch(arch)
    cfg = reduced_lm(cfg)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.make_cache(cfg, 2, 8)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 1), 0, cfg.vocab)
    logits, cache = T.decode_step(params, cache, toks, cfg)
    assert logits.shape == (2, 1, cfg.vocab)
    assert _finite(logits)
    assert int(cache["pos"][0]) == 1


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_matches_forward(arch):
    _, cfg = get_arch(arch)
    cfg = reduced_lm(cfg)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
    logits, cache = T.prefill_step(params, toks, cfg, q_block=4)
    ref = T.forward(params, toks, cfg)[:, -1]
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=0.05, atol=0.05)


def _gnn_batch(rng, n=48, e=160, d_feat=12):
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    dst = np.where(dst == src, (dst + 1) % n, dst)  # no self loops:
    # a zero edge vector has no defined local frame (geometric graphs
    # never contain self edges; CSRGraph strips them too)
    return {
        "feat": jnp.asarray(rng.normal(size=(n, d_feat)), jnp.float32),
        "pos": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
        "species": jnp.asarray(rng.integers(0, 10, n)),
        "src": jnp.asarray(src),
        "dst": jnp.asarray(dst),
        "labels": jnp.asarray(rng.integers(0, 5, n)),
        "targets": jnp.asarray(rng.normal(size=(n, 2)), jnp.float32),
        "node_mask": jnp.ones((n,), bool),
        "graph_id": jnp.asarray(rng.integers(0, 4, n)),
        "energy": jnp.asarray(rng.normal(size=(4,)), jnp.float32),
    }


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke(arch):
    rng = np.random.default_rng(0)
    batch = _gnn_batch(rng)
    key = jax.random.PRNGKey(0)
    _, cfg = get_arch(arch)
    if arch == "graphsage-reddit":
        cfg = dataclasses.replace(cfg, d_in=12, n_classes=5)
        params = G.sage_init(key, cfg)
        loss = G.sage_loss(params, batch, cfg)
        out = G.sage_forward(params, batch, cfg)
        assert out.shape == (48, 5)
    elif arch == "meshgraphnet":
        cfg = dataclasses.replace(cfg, n_layers=3, d_node_in=12)
        params = G.mgn_init(key, cfg)
        loss = G.mgn_loss(params, batch, cfg)
        out = G.mgn_forward(params, batch, cfg)
        assert out.shape == (48, 2)
    elif arch == "schnet":
        cfg = dataclasses.replace(cfg, n_rbf=16)
        params = G.schnet_init(key, cfg)
        loss = G.schnet_loss(params, batch, cfg, 4)
        out = G.schnet_forward(params, batch, cfg, 4)
        assert out.shape == (4,)
    else:  # equiformer-v2 — reduced width, full eSCN machinery
        cfg = dataclasses.replace(cfg, n_layers=2, d_hidden=16, l_max=3)
        params = G.eqv2_init(key, cfg)
        loss = G.eqv2_loss(params, batch, cfg, 4)
        out = G.eqv2_forward(params, batch, cfg, 4)
        assert out.shape == (4,)
    assert _finite(loss)
    assert _finite(out)


def test_equiformer_rotation_invariance():
    rng = np.random.default_rng(3)
    batch = _gnn_batch(rng)
    _, cfg = get_arch("equiformer-v2")
    cfg = dataclasses.replace(cfg, n_layers=2, d_hidden=16, l_max=3)
    params = G.eqv2_init(jax.random.PRNGKey(0), cfg)
    e0 = G.eqv2_forward(params, batch, cfg, 4)
    th = 0.9
    q = np.array([[np.cos(th), -np.sin(th), 0],
                  [np.sin(th), np.cos(th), 0], [0, 0, 1]], np.float32)
    b2 = dict(batch)
    b2["pos"] = batch["pos"] @ jnp.asarray(q).T
    e1 = G.eqv2_forward(params, b2, cfg, 4)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1),
                               rtol=1e-3, atol=1e-3)


def test_dien_smoke():
    _, cfg = get_arch("dien")
    cfg = dataclasses.replace(cfg, n_items=500, n_cats=20, n_profile=100,
                              seq_len=12)
    rng = np.random.default_rng(0)
    b, t = 4, 12
    batch = {
        "hist_items": jnp.asarray(rng.integers(0, 500, (b, t))),
        "hist_cats": jnp.asarray(rng.integers(0, 20, (b, t))),
        "hist_mask": jnp.ones((b, t), jnp.float32),
        "target_item": jnp.asarray(rng.integers(0, 500, b)),
        "target_cat": jnp.asarray(rng.integers(0, 20, b)),
        "profile": jnp.asarray(rng.integers(0, 100, (b, 4, 8))),
        "neg_items": jnp.asarray(rng.integers(0, 500, (b, t))),
        "neg_cats": jnp.asarray(rng.integers(0, 20, (b, t))),
        "label": jnp.asarray(rng.integers(0, 2, b)),
    }
    params = R.dien_init(jax.random.PRNGKey(0), cfg)
    logits, _ = R.dien_forward(params, batch, cfg)
    assert logits.shape == (b,)
    loss = R.dien_loss(params, batch, cfg)
    assert _finite(loss)
    uv = R.dien_user_vector(params, batch, cfg)
    scores = R.retrieval_scores(params, uv, jnp.arange(100))
    assert scores.shape == (b, 100)
    assert _finite(scores)


def test_registry_covers_assignment():
    assert len(list_archs()) == 10
    assert len(all_cells()) == 40
    for a in list_archs():
        assert len(shapes_for(a)) == 4


def test_param_counts_match_published_scale():
    for arch, lo, hi in [("qwen2-72b", 60e9, 85e9),
                         ("granite-34b", 25e9, 40e9),
                         ("nemotron-4-15b", 12e9, 20e9),
                         ("arctic-480b", 400e9, 560e9),
                         ("deepseek-v3-671b", 580e9, 760e9)]:
        _, cfg = get_arch(arch)
        n = cfg.n_params()
        assert lo < n < hi, (arch, n)
    _, ds = get_arch("deepseek-v3-671b")
    assert ds.n_active_params() < 50e9  # ~37B active
