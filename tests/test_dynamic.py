"""Dynamic graphs: delta-CSR mutation, incremental repair, epoch guards.

Three layers under test, matching the write-path subsystem's stack:

1. :class:`repro.graph.dynamic.DynamicCSRGraph` — every mutation
   sequence must leave ``view()`` bit-identical (indptr/indices, both
   directions) to a ``CSRGraph`` rebuilt from the surviving edge set;
   compaction must be content-neutral and epoch-neutral.
2. :mod:`repro.core.incremental` — frontier-seeded repair must be
   bit-identical (dist AND parent forest) to a from-scratch sweep on
   every adversarial family, including deletes that disconnect whole
   components (the Yamane–Kobayashi taint case).
3. The serving tier — once the graph mutates, no cached artifact (LRU
   row, landmark oracle label, betweenness vector) may answer: the
   fake-clock test proves a stale certified answer is impossible.
"""
import zlib

import numpy as np
import pytest

import repro
from repro.core.engine import apsp_engine
from repro.core.incremental import repair, sssp_state
from repro.core.sweep import UNREACHED, derive_parents
from repro.core.weighted import weighted_apsp
from repro.graph.csr import CSRGraph
from repro.graph.dynamic import DynamicCSRGraph
from repro.serve.engine import GraphQuery, GraphService

from oracles import adversarial_families, bfs_dists


def _families():
    return list(adversarial_families(seed=7))


def _edge_list(dg):
    e = dg.edges()
    return list(zip(e[0].tolist(), e[1].tolist()))


# --------------------------------------------------------------------------
# 1. DynamicCSRGraph round-trips
# --------------------------------------------------------------------------

def test_insert_delete_roundtrip_matches_rebuilt_csr():
    rng = np.random.default_rng(0)
    n = 37
    dg = DynamicCSRGraph.from_edges(np.array([0]), np.array([1]), n_nodes=n)
    live = {(0, 1)}
    for it in range(25):
        ins = rng.integers(0, n, (rng.integers(1, 9), 2))
        dg.insert_edges(ins[:, 0], ins[:, 1])
        live |= {(int(u), int(v)) for u, v in ins if u != v}
        if live and it % 3 == 2:
            kill = [list(live)[i] for i in
                    rng.choice(len(live), min(4, len(live)), replace=False)]
            dg.delete_edges(np.array([u for u, _ in kill]),
                            np.array([v for _, v in kill]))
            live -= set(map(tuple, kill))
        view = dg.view()
        ref = CSRGraph.from_edges(
            np.array([u for u, _ in sorted(live)], np.int64),
            np.array([v for _, v in sorted(live)], np.int64), n,
            pad_to=view.m_pad)
        np.testing.assert_array_equal(view.indptr, ref.indptr)
        np.testing.assert_array_equal(view.indices, ref.indices)
        np.testing.assert_array_equal(view.indptr_t, ref.indptr_t)
        np.testing.assert_array_equal(view.indices_t, ref.indices_t)
        assert dg.n_edges == len(live)
    assert dg.epoch > 0


def test_compact_is_content_and_epoch_neutral():
    rng = np.random.default_rng(1)
    n = 50
    e = rng.integers(0, n, (200, 2))
    dg = DynamicCSRGraph.from_edges(e[:, 0], e[:, 1], n_nodes=n)
    dg.delete_edges(e[:20, 0], e[:20, 1])
    before = _edge_list(dg)
    epoch = dg.epoch
    layout = dg.layout_version
    dg.compact()
    assert _edge_list(dg) == before
    assert dg.epoch == epoch            # content unchanged
    assert dg.layout_version > layout   # layout repacked
    assert len(dg._dead_slots) == 0


def test_auto_compaction_triggers_on_tombstone_ratio():
    n = 32
    src = np.repeat(np.arange(n), 2)
    dst = np.concatenate([(np.arange(n) + k) % n for k in (1, 2)])
    dg = DynamicCSRGraph.from_edges(src, dst, n_nodes=n,
                                    compact_threshold=0.25)
    base = dg.compactions
    dg.delete_edges(src[: n], dst[: n])  # kill half the edges
    assert dg.compactions > base
    ref = CSRGraph.from_edges(src[n:], dst[n:], n, pad_to=dg.view().m_pad)
    np.testing.assert_array_equal(dg.view().indices, ref.indices)


def test_weighted_roundtrip_and_decrease_only_insert():
    n = 16
    dg = DynamicCSRGraph.from_edges(
        np.array([0, 1]), np.array([1, 2]), n_nodes=n,
        weights=np.array([2.0, 3.0], np.float32))
    assert dg.weighted
    # re-insert with a HIGHER weight: no-op (min semantics, no epoch bump)
    e0 = dg.epoch
    dg.insert_edges(np.array([0]), np.array([1]),
                    weights=np.array([9.0], np.float32))
    assert dg.epoch == e0
    # lower weight: decrease-key, epoch bumps
    dg.insert_edges(np.array([0]), np.array([1]),
                    weights=np.array([0.5], np.float32))
    assert dg.epoch == e0 + 1
    view, w = dg.view(), dg.view_weights()
    lane = {(int(s), int(d)): float(x)
            for s, d, x in zip(view.src, view.dst, w) if s < n}
    assert lane[(0, 1)] == 0.5 and lane[(1, 2)] == 3.0


def test_journal_delta_and_trim():
    n = 8
    dg = DynamicCSRGraph.from_edges(np.array([0]), np.array([1]), n_nodes=n)
    e0 = dg.epoch
    dg.insert_edges(np.array([1, 2]), np.array([2, 3]))
    dg.delete_edges(np.array([0]), np.array([1]))
    ins_src, ins_dst, _, del_src, del_dst = dg.delta_since(e0)
    assert set(zip(ins_src.tolist(), ins_dst.tolist())) == {(1, 2), (2, 3)}
    assert set(zip(del_src.tolist(), del_dst.tolist())) == {(0, 1)}
    # net delta: an edge inserted then deleted cancels out
    e1 = dg.epoch
    dg.insert_edges(np.array([4]), np.array([5]))
    dg.delete_edges(np.array([4]), np.array([5]))
    ins_src, ins_dst, _, del_src, del_dst = dg.delta_since(e1)
    assert ins_src.size == 0 and del_src.size == 0
    assert dg.delta_since(-10_000) is None  # beyond the journal floor


def test_delete_reinsert_higher_weight_surfaces_delete():
    """REGRESSION: delta_since must not net a delete + re-insert of a
    pre-existing edge into a bare insert.  The revived weight can exceed
    the old one, so the repair has to taint the subtree built on the
    cheaper edge first — otherwise IncrementalSSSP serves stale,
    too-small distances."""
    n = 4
    dg = DynamicCSRGraph.from_edges(
        np.array([0, 1]), np.array([1, 2]), n_nodes=n,
        weights=np.array([1.0, 1.0], np.float32))
    inc = repro.IncrementalSSSP(dg, [0])
    assert float(inc.dist[0, 2]) == 2.0
    e0 = dg.epoch
    dg.delete_edges(np.array([1]), np.array([2]))
    dg.insert_edges(np.array([1]), np.array([2]),
                    weights=np.array([5.0], np.float32))
    # the round-trip must appear in BOTH lists: delete (taints the old
    # subtree) and insert (at the current, higher weight)
    ins_src, ins_dst, ins_w, del_src, del_dst = dg.delta_since(e0)
    assert list(zip(ins_src.tolist(), ins_dst.tolist())) == [(1, 2)]
    assert ins_w.tolist() == [5.0]
    assert list(zip(del_src.tolist(), del_dst.tolist())) == [(1, 2)]
    res = inc.update()
    assert res is not None and res.tainted > 0
    ref = weighted_apsp(dg.view(), dg.view_weights(), inc.state.sources)
    np.testing.assert_array_equal(inc.dist, np.asarray(ref.dist))
    assert float(inc.dist[0, 2]) == 6.0     # not the stale 2.0
    # an edge CREATED inside the window still nets out on round-trips:
    # deleting it again needs no taint (the synced state never saw it)
    e1 = dg.epoch
    dg.insert_edges(np.array([2]), np.array([3]),
                    weights=np.array([1.0], np.float32))
    dg.delete_edges(np.array([2]), np.array([3]))
    ins_src, _, _, del_src, _ = dg.delta_since(e1)
    assert ins_src.size == 0 and del_src.size == 0


# --------------------------------------------------------------------------
# 2. Incremental repair bit-identity
# --------------------------------------------------------------------------

def _dynamic_from_family(src, dst, n):
    if len(src) == 0:
        src, dst = np.array([0]), np.array([min(1, n - 1)])
    return DynamicCSRGraph.from_edges(np.asarray(src, np.int64),
                                      np.asarray(dst, np.int64), n_nodes=n)


def _assert_repair_matches_scratch(dg, state, sources, name):
    scratch = apsp_engine(dg.view(), sources)
    dist_ref = np.asarray(scratch.dist)
    par_ref = np.asarray(derive_parents(dg.view(), scratch.dist))
    np.testing.assert_array_equal(state.dist_int(), dist_ref,
                                  err_msg=f"{name}: dist")
    np.testing.assert_array_equal(state.parent, par_ref,
                                  err_msg=f"{name}: parent")
    np.testing.assert_array_equal(
        dist_ref, bfs_dists(dg.view(), sources), err_msg=f"{name}: oracle")


@pytest.mark.parametrize("name,src,dst,n",
                         _families(), ids=[f[0] for f in _families()])
def test_repair_bit_identity_adversarial(name, src, dst, n):
    rng = np.random.default_rng(zlib.crc32(name.encode()))
    dg = _dynamic_from_family(src, dst, n)
    sources = np.unique(rng.integers(0, n, min(4, n))).astype(np.int32)
    state, _ = sssp_state(dg, sources)
    for it in range(4):
        ins = rng.integers(0, n, (rng.integers(1, 4), 2))
        ins = ins[ins[:, 0] != ins[:, 1]]
        dg.insert_edges(ins[:, 0], ins[:, 1])
        res = repair(dg, state, inserts=(ins[:, 0], ins[:, 1]))
        state = res.state
        _assert_repair_matches_scratch(dg, state, sources, name)
        es = _edge_list(dg)
        if es and it % 2 == 1:
            u, v = es[rng.integers(0, len(es))]
            dg.delete_edges(np.array([u]), np.array([v]))
            state = repair(dg, state,
                           deletes=(np.array([u]), np.array([v]))).state
            _assert_repair_matches_scratch(dg, state, sources, name)


def test_repair_delete_disconnects_component():
    # path 0->1->2->3->4 plus a bridge: deleting the bridge edge must
    # taint (and re-unreach) everything downstream
    src = np.array([0, 1, 2, 3])
    dst = np.array([1, 2, 3, 4])
    dg = _dynamic_from_family(src, dst, 5)
    state, _ = sssp_state(dg, [0])
    dg.delete_edges(np.array([1]), np.array([2]))
    res = repair(dg, state, deletes=(np.array([1]), np.array([2])))
    state = res.state
    assert res.sweeps == 0          # tainted subtree is unreachable: free
    d = state.dist_int()[0]
    np.testing.assert_array_equal(d, [0, 1, UNREACHED, UNREACHED, UNREACHED])
    _assert_repair_matches_scratch(dg, state, np.array([0], np.int32),
                                   "disconnect")


def test_repair_insert_reconnects_component():
    src = np.array([0, 2, 3])
    dst = np.array([1, 3, 4])
    dg = _dynamic_from_family(src, dst, 5)
    state, scratch_sweeps = sssp_state(dg, [0])
    dg.insert_edges(np.array([1]), np.array([2]))
    res = repair(dg, state, inserts=(np.array([1]), np.array([2])))
    np.testing.assert_array_equal(res.state.dist_int()[0], [0, 1, 2, 3, 4])
    assert res.sweeps > 0
    _assert_repair_matches_scratch(dg, res.state, np.array([0], np.int32),
                                   "reconnect")


def test_weighted_repair_bit_identity():
    rng = np.random.default_rng(11)
    n = 24
    e = rng.integers(0, n, (60, 2))
    w = rng.uniform(0.5, 4.0, 60).astype(np.float32)
    dg = DynamicCSRGraph.from_edges(e[:, 0], e[:, 1], n_nodes=n, weights=w)
    sources = np.array([0, 5], np.int32)
    state, _ = sssp_state(dg, sources)
    for it in range(4):
        u, v = int(rng.integers(0, n)), int(rng.integers(0, n))
        wt = float(rng.uniform(0.1, 2.0))
        if dg.insert_edges(np.array([u]), np.array([v]),
                           weights=np.array([wt], np.float32)):
            res = repair(dg, state,
                         inserts=(np.array([u]), np.array([v]),
                                  np.array([wt], np.float32)))
            state = res.state
        es = _edge_list(dg)
        du, dv = es[rng.integers(0, len(es))]
        dg.delete_edges(np.array([du]), np.array([dv]))
        res = repair(dg, state, deletes=(np.array([du]), np.array([dv])))
        state = res.state
        ref = weighted_apsp(dg.view(), dg.view_weights(), sources)
        np.testing.assert_array_equal(state.dist, np.asarray(ref.dist))


def test_incremental_sssp_streaming_and_rebuild_fallback():
    rng = np.random.default_rng(3)
    n = 64
    e = rng.integers(0, n, (220, 2))
    dg = DynamicCSRGraph.from_edges(e[:, 0], e[:, 1], n_nodes=n)
    inc = repro.IncrementalSSSP(dg, [0, 1, 2])
    for _ in range(6):
        ins = rng.integers(0, n, (3, 2))
        dg.insert_edges(ins[:, 0], ins[:, 1])
        es = _edge_list(dg)
        u, v = es[rng.integers(0, len(es))]
        dg.delete_edges(np.array([u]), np.array([v]))
        inc.update()
        ref = apsp_engine(dg.view(), inc.state.sources)
        np.testing.assert_array_equal(inc.dist_int(), np.asarray(ref.dist))
    assert inc.repairs > 0
    # trim the journal past the sync point: update() must full-rebuild
    inc2 = repro.IncrementalSSSP(dg, [0])
    for _ in range(600):   # overflow the bounded journal
        dg.insert_edges(np.array([rng.integers(0, n)]),
                        np.array([rng.integers(0, n)]))
    inc2.update()
    assert inc2.rebuilds > 0
    ref = apsp_engine(dg.view(), inc2.state.sources)
    np.testing.assert_array_equal(inc2.dist_int(), np.asarray(ref.dist))


# --------------------------------------------------------------------------
# 3. Serving-tier epoch invalidation
# --------------------------------------------------------------------------

def _ring_dynamic(n=48):
    src = np.arange(n)
    return DynamicCSRGraph.from_edges(src, (src + 1) % n, n_nodes=n)


def test_stale_oracle_answer_impossible_fake_clock():
    """After a mutation, neither the row cache nor the landmark oracle
    may certify an answer computed against the old graph — even with
    zero wall-clock time elapsing between mutation and query."""
    t = [0.0]
    dg = _ring_dynamic(48)
    svc = GraphService(dg, max_batch=8, n_landmarks=6, row_cache_size=64,
                       clock=lambda: t[0])
    q0 = GraphQuery(qid=0, source=0, target=24)
    svc.submit(q0)
    svc.flush()
    assert q0.hops == 24
    # warm both tiers: second identical query must come from a cache
    q1 = GraphQuery(qid=1, source=0, target=24)
    svc.submit(q1)
    assert q1.certified and q1.served_by in ("cache", "oracle")
    # mutate: shortcut straight to the antipode; the virtual clock does
    # not advance, so any staleness check keyed on time would pass here
    dg.insert_edges(np.array([0]), np.array([24]))
    q2 = GraphQuery(qid=2, source=0, target=24)
    svc.submit(q2)
    svc.flush()
    assert q2.hops == 1, (q2.hops, q2.served_by)
    assert svc.epoch_invalidations == 1
    # the oracle rebuilt against the fresh epoch, lazily
    assert svc.oracle.prepared.epoch == dg.epoch
    # betweenness cache: analytics answer reflects the new edge
    qa = GraphQuery(qid=3, source=0, analytics=("betweenness",))
    svc.submit(qa)
    svc.flush()
    assert qa.analytics_result is not None


def test_tick_entry_point_also_invalidates():
    t = [0.0]
    dg = _ring_dynamic(32)
    svc = GraphService(dg, max_batch=4, clock=lambda: t[0])
    q0 = GraphQuery(qid=0, source=0, deadline=0.5)
    svc.submit(q0)
    dg.insert_edges(np.array([0]), np.array([16]))
    t[0] = 10.0   # deadline long gone -> tick must surface, not serve
    out = svc.tick()
    assert svc.epoch_invalidations == 1
    assert q0 in out and q0.expired and q0.served_by == "expired"
    # fill one bucket to max_batch so the next tick serves it whole
    qs = [GraphQuery(qid=1 + i, source=i, target=(i + 16) % 32,
                     deadline=99.0) for i in range(4)]
    for q in qs:
        svc.submit(q)
    svc.tick()
    assert qs[0].hops == 1          # sees the inserted shortcut
    assert all(q.served_by == "sweep" for q in qs)


def test_facade_serve_is_epoch_guarded():
    dg = _ring_dynamic(32)
    h = repro.prepare(dg)
    svc = h.serve(max_batch=8, clock=lambda: 0.0)
    q = GraphQuery(qid=0, source=0)
    svc.submit(q)
    svc.flush()
    d_before = np.array(q.dist)
    h.insert_edges([0], [16])
    q2 = GraphQuery(qid=1, source=0)
    svc.submit(q2)
    svc.flush()
    assert q2.dist[16] == 1 and d_before[16] == 16
