"""Distribution tests on virtual devices (subprocess: jax must initialize
with --xla_force_host_platform_device_count before first use)."""
import subprocess
import sys
import textwrap

import pytest


def _run(body: str, devices: int = 8):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \\
            "--xla_force_host_platform_device_count={devices}"
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]
    return res.stdout


@pytest.mark.slow
def test_sharded_dawn_all_schedules():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.graph import generators as gen
        from repro.core import make_sharded_msbfs, shard_inputs, \\
            bfs_queue_numpy
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        g = gen.rmat(9, 6, directed=False, seed=5)
        adj = np.asarray(g.to_dense_padded(512))
        sources = np.arange(8, dtype=np.int32)
        refs = np.stack([bfs_queue_numpy(g, int(x)) for x in sources])
        for schedule, bitpack in [("allgather", True),
                                  ("allgather", False), ("psum", False)]:
            fn = make_sharded_msbfs(mesh, schedule=schedule, bitpack=bitpack)
            a, s = shard_inputs(mesh, jnp.asarray(adj, jnp.int8),
                                jnp.asarray(sources), schedule)
            out = fn(a, s)
            dist = np.asarray(out.dist)[:, :g.n_nodes]
            assert (dist == refs).all(), schedule
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_lm_train_step_matches_single_device():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.models import transformer as T
        from repro.train import optimizer as O
        from repro.train.train_loop import make_train_step
        from repro.launch.cells import shardings

        cfg = T.LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                         n_kv=2, d_head=16, d_ff=128, vocab=256,
                         dtype=jnp.float32)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        opt = O.sgd(lr=0.1)
        state = opt.init(params)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 256)
        batch = {"tokens": toks, "labels": toks}
        step = make_train_step(lambda p, b: T.loss_fn(p, b, cfg), opt)

        p1, _, m1 = jax.jit(step)(params, state, batch)

        pspec = T.param_specs(cfg)
        sspec = opt.state_specs(pspec)
        bspec = {"tokens": P("data", None), "labels": P("data", None)}
        from repro import compat
        with compat.set_mesh(mesh):
            jstep = jax.jit(step,
                            in_shardings=shardings(mesh, (pspec, sspec,
                                                          bspec)),
                            out_shardings=shardings(mesh, (pspec, sspec,
                                                           None)))
            p2, _, m2 = jstep(params, state, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_embed_lookup_sharded_equals_local():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.models.layers import embed_lookup
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        table = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0, 64)
        ref = table[toks]
        from repro import compat
        with compat.set_mesh(mesh):
            t = jax.device_put(table, NamedSharding(mesh, P(None, "model")))
            k = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
            got = jax.jit(lambda a, b: embed_lookup(a, b, jnp.float32))(t, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_compressed_cross_pod_psum():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.train.compression import make_cross_pod_psum
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("pod",))
        psum_c = make_cross_pod_psum("int8")
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 0.1

        def f(v):
            return psum_c(v)

        from repro import compat
        got = compat.shard_map(f, mesh=mesh,
                            in_specs=jax.sharding.PartitionSpec("pod"),
                            out_specs=jax.sharding.PartitionSpec("pod"))(x)
        ref = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 0.01, err
        print("OK")
    """, devices=4)
    assert "OK" in out
