"""The benchmark regression gate (benchmarks/regression.py): hard gates
on deterministic fields, generous median timing gates, advisory warnings
for timing-derived booleans."""
import copy

import pytest

from benchmarks.regression import MIN_GATE_SECONDS, compare


def _aggregate(sweeps=9, median=0.05, flag=True):
    return {
        "schema": 2,
        "gate": {"time_tol": 4.0, "min_gate_seconds": MIN_GATE_SECONDS},
        "rows": [{"name": "apsp_grid_road", "us_per_call": 1.0,
                  "derived": "x"}],
        "bench_apsp": {"families": {"grid_road": {
            "n_nodes": 1024, "n_edges": 3968, "n_sources": 64,
            "sweeps": sweeps,
            "t_auto": median * 0.9, "t_auto_median": median,
            "auto_no_slower_than_best": flag,
        }}},
        "bench_weighted": {"families": {}},
    }


def test_identical_aggregates_pass():
    base = _aggregate()
    failures, warnings = compare(copy.deepcopy(base), base)
    assert failures == [] and warnings == []


def test_sweep_count_change_is_a_hard_failure():
    base = _aggregate(sweeps=9)
    cur = _aggregate(sweeps=11)
    failures, _ = compare(cur, base)
    assert any("sweeps" in f for f in failures)


def test_fused_sweep_count_gates_hard():
    """The fused multi-sweep kernel path reports its own sweep count
    (bit-identity with the per-sweep loop is asserted in-bench); a drift
    means the fused accounting broke, and must fail hard.  The timing
    columns and the fused_equals_per_sweep boolean stay advisory."""
    def agg(sf=9, flag=True, median=0.05):
        out = _aggregate()
        fam = out["bench_apsp"]["families"]["grid_road"]
        fam["sweeps_fused"] = sf
        fam["fused_equals_per_sweep"] = flag
        fam["t_kernel_fused_median"] = median
        return out
    failures, _ = compare(agg(sf=10), agg(sf=9))
    assert any("sweeps_fused" in f for f in failures)
    failures, warnings = compare(agg(flag=False), agg(flag=True))
    assert failures == []
    assert any("fused_equals_per_sweep" in w for w in warnings)
    failures, _ = compare(agg(median=0.05 * 2), agg())
    assert failures == []
    failures, _ = compare(agg(), agg())
    assert failures == []


def test_median_regression_beyond_tolerance_fails():
    base = _aggregate(median=0.05)
    cur = _aggregate(median=0.05 * 5)        # 5x > 4x tolerance
    failures, _ = compare(cur, base)
    assert any("t_auto_median" in f and "regressed" in f for f in failures)


def test_median_within_tolerance_passes():
    base = _aggregate(median=0.05)
    cur = _aggregate(median=0.05 * 2)        # 2x < 4x tolerance
    failures, _ = compare(cur, base)
    assert failures == []


def test_sub_threshold_timings_never_gate():
    base = _aggregate(median=MIN_GATE_SECONDS / 10)
    cur = _aggregate(median=MIN_GATE_SECONDS / 2)   # 5x but micro-timing
    failures, _ = compare(cur, base)
    assert failures == []


def test_tiny_baseline_cannot_hide_a_large_regression():
    """A sub-threshold baseline must not disable the gate when the
    current timing is real: the baseline is floored, not skipped."""
    base = _aggregate(median=MIN_GATE_SECONDS / 2)
    cur = _aggregate(median=1.0)
    failures, _ = compare(cur, base)
    assert any("t_auto_median" in f and "regressed" in f for f in failures)


def test_missing_family_and_row_fail():
    base = _aggregate()
    cur = copy.deepcopy(base)
    cur["bench_apsp"]["families"] = {}
    cur["rows"] = []
    failures, _ = compare(cur, base)
    assert any("family missing" in f for f in failures)
    assert any("missing from this run" in f for f in failures)


def test_acceptance_boolean_flip_warns_not_fails():
    base = _aggregate(flag=True)
    cur = _aggregate(flag=False)
    failures, warnings = compare(cur, base)
    assert failures == []
    assert any("auto_no_slower_than_best" in w for w in warnings)


def test_centrality_sigma_checksum_gates_hard():
    """bench_centrality's path-count checksum is a deterministic-by-seed
    field: a drifted checksum (the counting engine counted different
    paths) must fail hard, and a timing wobble must not."""
    def agg(checksum=62910.0, median=0.05):
        out = _aggregate()
        out["bench_centrality"] = {"families": {"ws_small": {
            "n_nodes": 256, "n_edges": 1536, "n_sources": 32,
            "sweeps": 12, "sigma_checksum": checksum,
            "t_batched_median": median,
        }}}
        return out
    failures, _ = compare(agg(checksum=62911.0), agg())
    assert any("bench_centrality" in f and "sigma_checksum" in f
               for f in failures)
    failures, _ = compare(agg(median=0.05 * 2), agg())
    assert failures == []
    failures, _ = compare(agg(), agg())
    assert failures == []


def test_serving_determinism_fields_gate_hard():
    """bench_serving's hit-rate / certified-fraction / labels checksum
    are pure functions of the seeds (virtual-clock load loop): any drift
    fails hard, while the latency fields stay ungated and the
    oracle_p50_beats_exact boolean only warns."""
    def agg(hit=0.9904, cert=6171, checksum=8238884, beats=True,
            p50=120.0):
        out = _aggregate()
        out["bench_serving"] = {"families": {"grid_road": {
            "n_nodes": 1024, "n_edges": 3968, "n_queries": 20000,
            "n_landmarks": 16, "labels_checksum": checksum,
            "certified_count": cert, "certified_fraction": cert / 20000,
            "hit_rate": hit, "cache_hits": 19000, "oracle_hits": 808,
            "sweep_served": 192,
            "p50_latency_us": p50, "p99_latency_us": p50 * 40,
            "qps": 5000.0, "oracle_p50_beats_exact": beats,
        }}}
        return out
    for kwargs, field in ((dict(hit=0.5), "hit_rate"),
                          (dict(cert=6000), "certified_count"),
                          (dict(checksum=1), "labels_checksum")):
        failures, _ = compare(agg(**kwargs), agg())
        assert any("bench_serving" in f and field in f
                   for f in failures), field
    # latency drift never fails; the advisory boolean warns
    failures, warnings = compare(agg(p50=5000.0, beats=False), agg())
    assert failures == []
    assert any("oracle_p50_beats_exact" in w for w in warnings)
    failures, _ = compare(agg(), agg())
    assert failures == []


def test_batching_tile_skip_fraction_gates_hard():
    """bench_batching's tile-skip fraction depends only on the seeded
    graph and sweep schedule — drift means the occupancy accounting (or
    the fixpoint) changed."""
    def agg(frac=0.428, median=0.2):
        out = _aggregate()
        out["bench_batching"] = {"families": {"rmat_64src": {
            "n_nodes": 1024, "n_edges": 7628, "n_sources": 64,
            "tile_skip_fraction": frac, "t_batched_median": median,
        }}}
        return out
    failures, _ = compare(agg(frac=0.3), agg())
    assert any("bench_batching" in f and "tile_skip_fraction" in f
               for f in failures)
    failures, _ = compare(agg(median=0.3), agg())
    assert failures == []
    failures, _ = compare(agg(), agg())
    assert failures == []


def test_sharded_bench_sweeps_gate_hard():
    """bench_sharded rides the same hard gates: a tropical sweep-count
    change (sharded and single device are pinned to agree) fails."""
    def agg(st=8):
        out = _aggregate()
        out["bench_sharded"] = {"families": {"grid_road": {
            "n_nodes": 1024, "n_edges": 3968, "n_sources": 32,
            "sweeps": 63, "sweeps_tropical": st,
            "t_sharded_boolean_median": 0.4,
        }}}
        return out
    failures, _ = compare(agg(st=9), agg(st=8))
    assert any("bench_sharded" in f and "sweeps_tropical" in f
               for f in failures)
    failures, _ = compare(agg(), agg())
    assert failures == []


def test_dynamic_repair_fields_gate_hard():
    """bench_dynamic's repair/scratch sweep totals, bit-identity flag,
    epoch counters and interleaved-query checksum are exact given the
    seeded update stream: any drift fails hard, while the replay timings
    ride the ordinary generous median gate."""
    def agg(repair=20, scratch=77, identical=True, epochs=10,
            compactions=4, checksum=157, t=1.5):
        out = _aggregate()
        out["bench_dynamic"] = {"families": {"ws_locality": {
            "n_nodes": 2048, "n_edges": 16382, "n_sources": 4,
            "n_rounds": 6, "repair_sweeps": repair,
            "scratch_sweeps": scratch,
            "repair_equals_scratch": identical,
            "n_epochs": epochs, "n_compactions": compactions,
            "query_checksum": checksum,
            "t_repair": t * 0.9, "t_repair_median": t,
            "t_scratch": t * 6, "t_scratch_median": t * 7,
        }}}
        return out
    for kwargs, field in ((dict(repair=25), "repair_sweeps"),
                          (dict(scratch=80), "scratch_sweeps"),
                          (dict(identical=False), "repair_equals_scratch"),
                          (dict(epochs=11), "n_epochs"),
                          (dict(compactions=0), "n_compactions"),
                          (dict(checksum=0), "query_checksum")):
        failures, _ = compare(agg(**kwargs), agg())
        assert any("bench_dynamic" in f and field in f
                   for f in failures), field
    # timing drift inside tolerance passes; identical aggregates pass
    failures, _ = compare(agg(t=2.0), agg())
    assert failures == []
    failures, _ = compare(agg(), agg())
    assert failures == []


def test_resume_job_fields_gate_hard():
    """bench_resume's full-run checksums and resumed-chunk accounting are
    exact given the seeds (bit-identity of the resumed job is asserted
    in-bench): any drift — different distances, different path counts, a
    lost checkpoint, or a resume that recomputed the wrong tail — fails
    hard, while the full/resume timings ride the generous median gate."""
    def agg(dist=48000, sigma=62910.0, written=4, restored=2,
            recomputed=2, equal=True, t=0.3):
        out = _aggregate()
        out["bench_resume"] = {"families": {"grid_road": {
            "n_nodes": 1024, "n_edges": 3968, "n_sources": 32,
            "chunks_total": 4, "sweeps": 63,
            "dist_checksum": dist, "sigma_checksum": sigma,
            "checkpoints_written": written,
            "resumed_chunks": restored, "recomputed_chunks": recomputed,
            "resume_equals_full": equal,
            "t_full": t * 0.9, "t_full_median": t,
            "t_resume": t * 0.5, "t_resume_median": t * 0.6,
        }}}
        return out
    for kwargs, field in ((dict(dist=47999), "dist_checksum"),
                          (dict(sigma=1.0), "sigma_checksum"),
                          (dict(written=3), "checkpoints_written"),
                          (dict(restored=3), "resumed_chunks"),
                          (dict(recomputed=1), "recomputed_chunks"),
                          (dict(equal=False), "resume_equals_full")):
        failures, _ = compare(agg(**kwargs), agg())
        assert any("bench_resume" in f and field in f
                   for f in failures), field
    # timing drift inside tolerance passes; identical aggregates pass
    failures, _ = compare(agg(t=0.5), agg())
    assert failures == []
    failures, _ = compare(agg(), agg())
    assert failures == []
