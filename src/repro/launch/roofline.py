"""Roofline analysis from the dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs / 197 TFLOP/s          (per chip)
    memory     = HLO_bytes / 819 GB/s             (per chip)
    collective = wire_bytes / 50 GB/s per link    (per chip)

All three are derived from the post-SPMD HLO dumped by the dry-run, via
``hlo_analysis``:  ``compiled.cost_analysis()`` counts while-loop bodies
once (verified: a scan of 8 matmuls reports 1 matmul), so FLOPs/bytes are
rebuilt instruction-by-instruction with call-graph multiplicities (loop
trip counts recovered from scan condition constants; flops validated exact
on scan/nested-scan/grad-of-scan fixtures).  Collective wire bytes use
ring conversions (AG/RS (n-1)/n, AR 2(n-1)/n, A2A (n-1)/n).

MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference);
useful-flops ratio = MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste);
roofline fraction = (MODEL_FLOPS/peak) / max(term) — the score.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List

from .hlo_analysis import analyze_file
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def roofline_terms(flops: float, bytes_accessed: float,
                   wire_bytes: float = 0.0, *,
                   peak_flops: float = PEAK_FLOPS_BF16,
                   hbm_bw: float = HBM_BW, ici_bw: float = ICI_BW) -> dict:
    """The three roofline terms in seconds plus the dominant one — the
    reusable core of :func:`analyze_cell` (the autotuner prices sweep
    forms with it, core/autotune.py)."""
    t_comp = flops / peak_flops
    t_mem = bytes_accessed / hbm_bw
    t_coll = wire_bytes / ici_bw
    dominant = max((("compute", t_comp), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "dominant": dominant}


_REQUIRED_CELL_KEYS = ("arch", "shape", "mesh", "kind", "n_devices",
                       "meta", "memory")


def analyze_cell(json_path: str) -> dict:
    with open(json_path) as f:
        rec = json.load(f)
    missing = [k for k in _REQUIRED_CELL_KEYS if k not in rec]
    if missing:
        raise ValueError(
            f"{json_path}: dry-run record missing keys {missing}")
    if "peak_bytes" not in rec["memory"]:
        raise ValueError(f"{json_path}: memory record has no peak_bytes")
    hlo_path = json_path.replace(".json", ".hlo.gz")
    st = analyze_file(hlo_path)

    chips = rec["n_devices"]
    meta = rec["meta"]
    terms = roofline_terms(st.flops, st.bytes_accessed, st.wire_bytes)
    t_comp, t_mem, t_coll = (terms["t_compute_s"], terms["t_memory_s"],
                             terms["t_collective_s"])
    dominant = terms["dominant"]
    model_flops_dev = meta.get("model_flops", 0.0) / chips
    bound = max(t_comp, t_mem, t_coll, 1e-30)
    t_model = model_flops_dev / PEAK_FLOPS_BF16
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"], "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "hlo_flops_dev": st.flops, "hlo_bytes_dev": st.bytes_accessed,
        "wire_bytes_dev": st.wire_bytes,
        "n_collective_sites": len(st.collective_ops),
        "model_flops_total": meta.get("model_flops", 0.0),
        "useful_flops_ratio": (model_flops_dev / st.flops) if st.flops else 0,
        "roofline_fraction": t_model / bound,
        "peak_bytes_dev": rec["memory"]["peak_bytes"],
        "bf16_promo_bytes": rec["memory"].get("bf16_promotion_bytes", 0),
        "compile_s": rec.get("compile_s"),
    }


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | mesh | dominant | t_comp ms | t_mem ms | "
           "t_coll ms | useful | roofline | peak GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['dominant']} "
            f"| {r['t_compute_s']*1e3:.3f} | {r['t_memory_s']*1e3:.3f} "
            f"| {r['t_collective_s']*1e3:.3f} "
            f"| {r['useful_flops_ratio']*100:.0f}% "
            f"| {r['roofline_fraction']*100:.1f}% "
            f"| {r['peak_bytes_dev']/2**30:.1f} |")
    return hdr + "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--glob", default="experiments/dryrun/*.json")
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(args.glob)):
        try:
            rows.append(analyze_cell(path))
        except Exception as e:  # noqa: BLE001
            print(f"skip {path}: {e!r}")
    table = markdown_table(rows)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write("# Roofline (per device, TPU v5e: 197 TF/s bf16, "
                "819 GB/s HBM, 50 GB/s ICI)\n\n" + table + "\n")
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(table)


if __name__ == "__main__":
    main()
