"""Batched serving engine: prefill/decode split + continuous batching.

A single-host simulation of the production serving loop: requests arrive
with prompts; the engine prefills them into free KV-cache slots, then runs
batched decode steps over all active slots, retiring finished sequences and
immediately admitting queued ones (continuous batching).  The decode step
is the same jitted ``transformer.decode_step`` the dry-run lowers at the
32k/500k shapes.

The engine also serves ``shortest_path`` graph queries: a
:class:`GraphService` micro-batches pending :class:`GraphQuery` requests
into one direction-optimized multi-source sweep (core/engine.py) per
engine tick, so graph analytics ride the same continuous-batching loop as
decode steps instead of needing a separate deployment.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.centrality import (MEASURES, CentralityConfig, betweenness,
                               centrality)
from ..core.distributed import (ShardedConfig, ShardedOperands,
                                prepare_sharded, sharded_apsp)
from ..core.engine import EngineConfig, PreparedGraph, apsp_engine_blocks, \
    prepare_graph
from ..core.weighted import (PreparedWeightedGraph, WeightedConfig,
                             prepare_weighted, weighted_apsp)
from ..graph.csr import CSRGraph
from ..models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (L,) int32
    max_new: int = 16
    out: Optional[List[int]] = None
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass
class GraphQuery:
    """A ``shortest_path`` request served by the batching loop.

    ``target=None`` returns the full distance vector from ``source``;
    otherwise ``hops`` is the shortest unweighted path length (or -1 when
    unreachable).  ``weighted=True`` routes through the tropical-semiring
    engine instead: ``dist`` becomes float32 (inf = unreachable) and a
    target query fills ``cost`` (the weighted distance) rather than
    ``hops``.

    ``analytics`` turns the query into a centrality request: a tuple of
    measure names from :data:`repro.core.centrality.MEASURES`
    ("closeness" / "harmonic" / "eccentricity" / "betweenness").  The
    per-source measures of every analytics query in a flush batch into
    ONE jit-batched multi-source run (core/centrality.py); betweenness —
    a whole-graph analytic — is computed once per service (through the
    sharded executor when a mesh is configured), cached, and answered
    from the cache.  Results land in ``analytics_result`` keyed by
    measure, all for node ``source``.
    """
    qid: int
    source: int
    target: Optional[int] = None
    weighted: bool = False
    analytics: Optional[tuple] = None
    dist: Optional[np.ndarray] = None
    hops: Optional[int] = None
    cost: Optional[float] = None
    analytics_result: Optional[Dict[str, float]] = None
    t_submit: float = 0.0
    t_done: float = 0.0


class GraphService:
    """Micro-batched shortest-path queries over one prepared graph.

    Pending query sources are packed into a single source tile and run
    through the direction-optimizing engine — one jitted multi-source
    sweep per flush, amortized across every query in the batch exactly
    like decode steps amortize across KV slots.  Pass edge ``weights`` to
    additionally serve weighted queries: each flush runs at most one
    boolean and one tropical micro-batch, both through the shared semiring
    sweep layer.  ``GraphQuery(analytics=...)`` requests join the same
    loop: per-source centrality measures micro-batch into one
    counting/boolean run per flush, and the whole-graph betweenness
    vector is built once (through the sharded executor when a mesh is
    configured) and served from cache.

    Pass ``mesh`` to scale flushes past one device: micro-batches of at
    least ``sharded_threshold`` queries route through the semiring-generic
    sharded executor (``core/distributed.py::sharded_apsp`` — sources
    sharded over the mesh's data axes, the operand optionally over
    ``model``), whose results are bit-identical to the single-device
    engines; smaller flushes stay on the single-device path where the
    collective overhead isn't worth it.
    """

    def __init__(self, graph: CSRGraph, *,
                 config: Optional[EngineConfig] = None,
                 weights=None,
                 weighted_config: Optional[WeightedConfig] = None,
                 max_batch: int = 32,
                 mesh=None,
                 sharded_threshold: int = 16,
                 sharded_config: Optional[ShardedConfig] = None,
                 sharded_weighted_config: Optional[ShardedConfig] = None,
                 centrality_config: Optional[CentralityConfig] = None):
        batch = max(8, ((max_batch + 7) // 8) * 8)
        if batch > 128:  # EngineConfig: above one push tile, multiple of 128
            batch = ((batch + 127) // 128) * 128
        self.config = config or EngineConfig(source_batch=batch)
        # per-flush latency cap: honored even with an explicit config (the
        # source tile stays config.source_batch wide; short flushes pad)
        self.max_batch = min(max_batch, self.config.source_batch)
        self.prepared: PreparedGraph = prepare_graph(graph)
        self.prepared_weighted: Optional[PreparedWeightedGraph] = \
            None if weights is None else prepare_weighted(graph, weights)
        # weighted queries ride the same kernel-path resolution as the
        # boolean engine: both semirings dispatch Pallas kernels through
        # the registry when the config (or TPU detection) says so
        self.weighted_config = weighted_config or \
            WeightedConfig(source_batch=min(self.config.source_batch, 128),
                           use_kernel=self.config.use_kernel)
        self.mesh = mesh
        self.sharded_threshold = max(1, sharded_threshold)
        self._sharded_cfg = {
            "boolean": sharded_config or
            ShardedConfig(semiring="boolean", mode="dense",
                          use_kernel=self.config.use_kernel),
            "tropical": sharded_weighted_config or
            ShardedConfig(semiring="tropical", mode="dense",
                          use_kernel=self.config.use_kernel),
        }
        self._weights = weights
        self._sharded_ops: Dict[str, ShardedOperands] = {}
        self.sharded_flushes = 0
        self.centrality_config = centrality_config or CentralityConfig(
            source_batch=min(self.config.source_batch, 128),
            use_kernel=self.config.use_kernel)
        # betweenness is a whole-graph analytic: computed once (sharded
        # when a mesh is configured), then served from this cache
        self._betweenness: Optional[np.ndarray] = None
        self.queue: deque[GraphQuery] = deque()
        self.completed: List[GraphQuery] = []

    def _sharded_operands(self, semiring: str) -> ShardedOperands:
        """Lazy per-semiring ShardedOperands (dense/partitioned operands
        built and device_put once, reused every sharded flush).  On a
        mesh without vertex sharding the padded size matches the
        single-device operands, so those are handed over instead of
        materializing a second O(n_pad^2) dense copy."""
        if semiring not in self._sharded_ops:
            cfg = self._sharded_cfg[semiring]
            dense_op = None
            if "model" not in self.mesh.axis_names or \
                    dict(self.mesh.shape).get("model", 1) == 1:
                if semiring == "boolean" and cfg.need_dense:
                    dense_op = self.prepared.adj
                elif semiring == "tropical" and cfg.need_dense:
                    dense_op = self.prepared_weighted.wdense
            self._sharded_ops[semiring] = prepare_sharded(
                self.prepared.graph, self.mesh,
                weights=self._weights if semiring == "tropical" else None,
                config=cfg, dense_op=dense_op)
        return self._sharded_ops[semiring]

    def _route_sharded(self, n_queries: int) -> bool:
        return self.mesh is not None and \
            n_queries >= self.sharded_threshold

    def submit(self, query: GraphQuery):
        n = self.prepared.graph.n_nodes
        if not 0 <= query.source < n:
            raise ValueError(f"source {query.source} not in [0, {n})")
        if query.target is not None and not 0 <= query.target < n:
            raise ValueError(f"target {query.target} not in [0, {n})")
        if query.analytics is not None:
            if query.weighted:
                raise ValueError("analytics queries are unweighted "
                                 "(counting/boolean semiring)")
            unknown = set(query.analytics) - set(MEASURES)
            if unknown:
                raise ValueError(f"unknown analytics {sorted(unknown)}; "
                                 f"available: {MEASURES}")
        if query.weighted and self.prepared_weighted is None:
            raise ValueError(
                "weighted query on a GraphService built without weights=")
        query.t_submit = time.monotonic()
        self.queue.append(query)

    def pending(self) -> int:
        return len(self.queue)

    def flush(self) -> List[GraphQuery]:
        """Serve up to one source tile of pending queries; returns them."""
        if not self.queue:
            return []
        batch = [self.queue.popleft()
                 for _ in range(min(len(self.queue), self.max_batch))]
        now = time.monotonic()
        analytics = [q for q in batch if q.analytics is not None]
        unweighted = [q for q in batch
                      if not q.weighted and q.analytics is None]
        weighted = [q for q in batch if q.weighted]
        if unweighted:
            sources = np.asarray([q.source for q in unweighted], np.int32)
            if self._route_sharded(len(unweighted)):
                dist = np.asarray(
                    sharded_apsp(self._sharded_operands("boolean"),
                                 sources).dist)
                self.sharded_flushes += 1
            else:
                (_, dist, _), = apsp_engine_blocks(self.prepared, sources,
                                                   config=self.config)
                dist = np.asarray(dist)
            now = time.monotonic()
            for row, q in zip(dist, unweighted):
                if q.target is None:
                    q.dist = row
                else:
                    q.hops = int(row[q.target])
        if weighted:
            sources = np.asarray([q.source for q in weighted], np.int32)
            if self._route_sharded(len(weighted)):
                dist = np.asarray(
                    sharded_apsp(self._sharded_operands("tropical"),
                                 sources).dist)
                self.sharded_flushes += 1
            else:
                res = weighted_apsp(self.prepared_weighted, sources=sources,
                                    config=self.weighted_config)
                dist = np.asarray(res.dist)
            now = time.monotonic()
            for row, q in zip(dist, weighted):
                if q.target is None:
                    q.dist = row
                else:
                    q.cost = float(row[q.target])
        if analytics:
            self._flush_analytics(analytics)
            now = time.monotonic()
        for q in batch:
            q.t_done = now
            self.completed.append(q)
        return batch

    def _flush_analytics(self, queries: List[GraphQuery]) -> None:
        """Serve one micro-batch of centrality queries: all per-source
        measures ride ONE batched multi-source run (the analytics
        analogue of the distance micro-batch); betweenness comes from
        the per-service cache, built on first demand — through the
        sharded executor when the service has a mesh."""
        per_source = set()
        want_bc = False
        for q in queries:
            for m in q.analytics:
                if m == "betweenness":
                    want_bc = True
                else:
                    per_source.add(m)
        results: Dict[int, Dict[str, float]] = {
            id(q): {} for q in queries}
        # one batched run over only the queries that need per-source
        # measures (betweenness-only queries are served from the cache),
        # reusing the service's prepared operands and calibration cache
        ps_queries = [q for q in queries
                      if set(q.analytics) - {"betweenness"}]
        if ps_queries:
            sources = np.asarray([q.source for q in ps_queries], np.int32)
            res = centrality(self.prepared, sources,
                             measures=tuple(sorted(per_source)),
                             config=self.centrality_config)
            if res.closeness is not None:
                for i, q in enumerate(ps_queries):
                    results[id(q)]["closeness"] = float(res.closeness[i])
            if res.harmonic is not None:
                for i, q in enumerate(ps_queries):
                    results[id(q)]["harmonic"] = float(res.harmonic[i])
            if res.eccentricity is not None:
                for i, q in enumerate(ps_queries):
                    results[id(q)]["eccentricity"] = \
                        int(res.eccentricity[i])
        if want_bc:
            if self._betweenness is None:
                n = self.prepared.graph.n_nodes
                self._betweenness = betweenness(
                    self.prepared, config=self.centrality_config,
                    mesh=self.mesh if (self.mesh is not None and
                                       n >= self.sharded_threshold)
                    else None)
                if self.mesh is not None and \
                        n >= self.sharded_threshold:
                    self.sharded_flushes += 1
            for q in queries:
                if "betweenness" in q.analytics:
                    results[id(q)]["betweenness"] = \
                        float(self._betweenness[q.source])
        for q in queries:
            q.analytics_result = {m: results[id(q)][m]
                                  for m in q.analytics}


class ServingEngine:
    """Fixed-slot continuous batching over a shared KV cache.

    Optionally co-serves graph ``shortest_path`` queries: pass a
    :class:`GraphService` and submit :class:`GraphQuery` objects via
    :meth:`submit_graph`; each engine tick flushes one micro-batch of
    graph queries alongside the decode step.
    """

    def __init__(self, params, cfg: T.LMConfig, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 graph_service: Optional[GraphService] = None):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}
        self.free = list(range(slots))
        self.remaining = np.zeros(slots, np.int32)
        self.cache = T.make_cache(cfg, slots, max_len)
        self.cur_tok = np.zeros((slots, 1), np.int32)
        self._decode = jax.jit(
            lambda p, c, t, a: T.decode_step(p, c, t, cfg, active=a))
        self.completed: List[Request] = []
        self.graph_service = graph_service

    def submit_graph(self, query: GraphQuery):
        if self.graph_service is None:
            raise RuntimeError(
                "construct ServingEngine with graph_service= to serve graphs")
        self.graph_service.submit(query)

    def submit(self, req: Request):
        req.t_submit = time.monotonic()
        req.out = []
        self.queue.append(req)

    def _admit(self):
        while self.queue and self.free:
            req = self.queue.popleft()
            slot = self.free.pop()
            self.active[req.rid] = req
            self.slot_of[req.rid] = slot
            # reset the slot's cache position, then prefill its prompt
            # token-by-token with only this slot active (the production
            # prefill_step lowers the full-sequence path — launch/serve.py)
            self.cache["pos"] = self.cache["pos"].at[slot].set(0)
            mask = np.zeros(self.slots, bool)
            mask[slot] = True
            for tok in req.prompt:
                self.cur_tok[slot, 0] = tok
                self._decode_tick(mask)
            # first generated token comes from the last prefill logits
            first = int(np.argmax(self._last_logits[slot]))
            req.out.append(first)
            req.t_first = time.monotonic()
            self.cur_tok[slot, 0] = first
            self.remaining[slot] = req.max_new - 1
            if self.remaining[slot] == 0:
                req.t_done = req.t_first
                self.completed.append(self.active.pop(req.rid))
                self.free.append(self.slot_of.pop(req.rid))

    def _decode_tick(self, active_mask: np.ndarray):
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.cur_tok),
            jnp.asarray(active_mask))
        self._last_logits = np.asarray(logits[:, 0], np.float32)

    def step(self) -> int:
        """One engine tick: admit, serve one graph micro-batch, decode one
        token for all active slots, retire finished requests.  Returns the
        number of live requests (LM and graph)."""
        graph_live = 0
        if self.graph_service is not None:
            self.graph_service.flush()
            graph_live = self.graph_service.pending()
        self._admit()
        if not self.active:
            return graph_live
        mask = np.zeros(self.slots, bool)
        for rid in self.active:
            mask[self.slot_of[rid]] = True
        self._decode_tick(mask)
        nxt = np.argmax(self._last_logits, axis=-1).astype(np.int32)
        done_rids = []
        for rid, req in self.active.items():
            s = self.slot_of[rid]
            if self.remaining[s] <= 0:
                continue
            req.out.append(int(nxt[s]))
            self.cur_tok[s, 0] = nxt[s]
            self.remaining[s] -= 1
            if self.remaining[s] == 0:
                done_rids.append(rid)
        for rid in done_rids:
            req = self.active.pop(rid)
            req.t_done = time.monotonic()
            self.completed.append(req)
            self.free.append(self.slot_of.pop(rid))
        return len(self.active) + len(self.queue) + graph_live

    def run_to_completion(self, max_ticks: int = 10_000):
        for _ in range(max_ticks):
            if self.step() == 0 and not self.queue:
                break
        return self.completed
