from .ops import (sweep, msbfs_kernel, msbfs_packed, pack_adjacency_pull,
                  KernelDawnResult)
from .kernel import fused_sweep, packed_pull_sweep
from .ref import sweep_ref, packed_pull_ref
