"""Unified ``dawn`` facade: one handle, every semiring, static or mutable.

The caller-visible surface of the reproduction used to be four parallel
config dataclasses and one entry point per semiring (``apsp_engine`` /
``weighted_apsp`` / ``counting_apsp`` / ``sharded_apsp``).  This module
replaces that spread with a single verb:

    import repro as dawn

    h = dawn.prepare(graph)                     # static CSRGraph
    d = h.sssp(0)                               # one dist row
    res = h.apsp(semiring="boolean")            # batched engine result
    cen = h.centrality(measures=("closeness",))
    svc = h.serve(n_landmarks=16)               # tiered GraphService

    h = dawn.prepare(dyn)                       # DynamicCSRGraph
    h.insert_edges([u], [v])                    # mutation passthrough
    d = h.sssp(0)                               # fresh epoch, same call

Every query method takes ``semiring=`` ("boolean" / "tropical" /
"counting") and ``mesh=`` (route through the sharded executor) keywords;
tuning knobs come from one :class:`repro.core.options.SweepOptions`
passed to :func:`prepare` (or plain keywords forwarded to it).  The old
config dataclasses survive as thin subclasses — the handle projects the
shared options onto whichever engine a call dispatches to via
``SweepOptions.to``.

The handle is epoch-aware: prepared operands are built lazily per
semiring and rebuilt automatically whenever the underlying
:class:`repro.graph.dynamic.DynamicCSRGraph` has mutated since they
were prepared, so "same query, now on a mutable graph" is exactly the
same call.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Sequence, Union

import numpy as np

from .core.autotune import TuningPlan, build_plan
from .core.centrality import (MEASURES, CentralityConfig, CentralityResult,
                              centrality as _centrality)
from .core.centrality import counting_apsp as _counting_apsp
from .core.distributed import ShardedConfig, prepare_sharded
from .core.distributed import sharded_apsp as _sharded_apsp
from .core.engine import EngineConfig, prepare_graph
from .core.engine import apsp_engine as _apsp_engine
from .core.incremental import IncrementalSSSP
from .core.options import SweepOptions
from .core.weighted import WeightedConfig, prepare_weighted
from .core.weighted import weighted_apsp as _weighted_apsp
from .graph.csr import CSRGraph
from .graph.dynamic import DynamicCSRGraph

SEMIRING_NAMES = ("boolean", "tropical", "counting")


class DawnGraph:
    """Prepared-graph handle returned by :func:`prepare`.

    Query methods (``sssp`` / ``apsp`` / ``centrality``) lazily build
    and cache the per-semiring prepared operands; on a mutable graph
    every call first checks the content epoch and re-prepares when the
    graph has changed.  ``serve`` hands the *source* graph to
    :class:`repro.serve.GraphService`, whose own epoch guard covers the
    serving-tier caches.
    """

    def __init__(self, graph: Union[CSRGraph, DynamicCSRGraph], *,
                 weights=None, options: Optional[SweepOptions] = None):
        if isinstance(graph, DynamicCSRGraph) and weights is not None:
            raise ValueError(
                "weights= with a DynamicCSRGraph is ambiguous — build the "
                "dynamic graph with weights instead")
        self.graph = graph
        self.options = options or SweepOptions()
        self._weights = weights
        self._pg = None          # PreparedGraph (boolean/counting)
        self._pw = None          # PreparedWeightedGraph (tropical)
        self._sharded = {}       # semiring -> ShardedOperands
        self._sharded_mesh = None
        self._sharded_epoch = -1

    # -- epoch-aware operand cache ----------------------------------------

    @property
    def epoch(self) -> int:
        return int(getattr(self.graph, "epoch", 0))

    @property
    def mutable(self) -> bool:
        return isinstance(self.graph, DynamicCSRGraph)

    def _lane_weights(self):
        if self._weights is not None:
            return self._weights
        if self.mutable and self.graph.weighted:
            return self.graph.view_weights()
        return None

    def prepared(self):
        """Current-epoch :class:`PreparedGraph` (boolean operands)."""
        if self._pg is None or self._pg.epoch != self.epoch:
            self._pg = prepare_graph(self.graph)
        return self._pg

    def prepared_weighted(self):
        """Current-epoch :class:`PreparedWeightedGraph` (tropical)."""
        w = self._lane_weights()
        if w is None:
            raise ValueError(
                "tropical semiring needs weights: prepare(graph, weights=...) "
                "or a weighted DynamicCSRGraph")
        if self._pw is None or self._pw.epoch != self.epoch:
            self._pw = prepare_weighted(self.graph) if self.mutable \
                else prepare_weighted(self.graph, w)
        return self._pw

    def _sharded_operands(self, semiring: str, mesh):
        if mesh is not self._sharded_mesh or self._sharded_epoch != \
                self.epoch:
            self._sharded = {}
            self._sharded_mesh = mesh
            self._sharded_epoch = self.epoch
        if semiring not in self._sharded:
            cfg = self.options.to(
                ShardedConfig, lenient=True, semiring=semiring, mode="dense")
            g = self.graph.view() if self.mutable else self.graph
            self._sharded[semiring] = prepare_sharded(
                g, mesh, weights=self._lane_weights()
                if semiring == "tropical" else None, config=cfg)
        return self._sharded[semiring]

    # -- mutation passthrough (DynamicCSRGraph only) -----------------------

    def _dynamic(self) -> DynamicCSRGraph:
        if not self.mutable:
            raise TypeError(
                "graph is a static CSRGraph; prepare(DynamicCSRGraph...) "
                "for mutation support")
        return self.graph

    def insert_edges(self, src, dst, weights=None) -> int:
        return self._dynamic().insert_edges(src, dst, weights)

    def delete_edges(self, src, dst) -> int:
        return self._dynamic().delete_edges(src, dst)

    def compact(self) -> None:
        self._dynamic().compact()

    # -- queries -----------------------------------------------------------

    def _check_semiring(self, semiring: str) -> None:
        if semiring not in SEMIRING_NAMES:
            raise ValueError(
                f"unknown semiring {semiring!r}; one of {SEMIRING_NAMES}")

    def apsp(self, sources: Optional[Sequence[int]] = None, *,
             semiring: str = "boolean", mesh=None,
             checkpoint_dir: Optional[str] = None,
             checkpoint_interval: int = 1,
             chunk_size: Optional[int] = None, resume: bool = True,
             on_chunk=None):
        """Batched multi-source shortest paths (default: all sources).

        Returns the dispatched engine's native result — ``ApspResult``
        (boolean), ``WeightedApspResult`` (tropical), ``CountingResult``
        (counting) or ``ShardedApspResult`` (any semiring + ``mesh=``) —
        all carrying ``.dist`` plus sweep counters.

        ``checkpoint_dir=`` routes through the resumable-job layer
        (:func:`repro.core.jobs.run_sweep_job`): the run is chunked into
        ``chunk_size`` source tiles, checkpointed every
        ``checkpoint_interval`` chunks, and a rerun of the same call
        resumes from the newest intact checkpoint (``resume=False``
        starts over).  Returns a :class:`repro.core.jobs.JobResult`
        carrying the resume counters (``chunks_restored``,
        ``restored_step``, ``corrupt_skipped``, ...) alongside the
        distances.
        """
        self._check_semiring(semiring)
        if checkpoint_dir is not None or on_chunk is not None:
            from .core.jobs import run_sweep_job
            return run_sweep_job(
                self.graph, sources, workload=semiring,
                weights=self._lane_weights()
                if semiring == "tropical" else None,
                mesh=mesh, options=self.options, chunk_size=chunk_size,
                checkpoint_dir=checkpoint_dir,
                checkpoint_interval=checkpoint_interval, resume=resume,
                on_chunk=on_chunk)
        if mesh is not None:
            # config is baked into the prepared operands (_sharded_operands)
            return _sharded_apsp(self._sharded_operands(semiring, mesh),
                                 sources)
        if semiring == "boolean":
            return _apsp_engine(self.prepared(), sources,
                                config=self.options.to(EngineConfig,
                                                       lenient=True))
        if semiring == "tropical":
            return _weighted_apsp(self.prepared_weighted(), sources=sources,
                                  config=self.options.to(WeightedConfig,
                                                         lenient=True))
        return _counting_apsp(self.prepared(), sources,
                              config=self.options.to(CentralityConfig,
                                                     lenient=True))

    def sssp(self, source: int, *, semiring: str = "boolean",
             mesh=None) -> np.ndarray:
        """One distance row from ``source`` — int32 hops with -1 for
        unreachable (boolean/counting), float32 with +inf (tropical)."""
        res = self.apsp([int(source)], semiring=semiring, mesh=mesh)
        return np.asarray(res.dist[0])

    def centrality(self, sources: Optional[Sequence[int]] = None, *,
                   measures: Sequence[str] = MEASURES,
                   mesh=None) -> CentralityResult:
        """Batched centrality analytics over the counting semiring."""
        return _centrality(self.prepared(), sources, measures=measures,
                           config=self.options.to(CentralityConfig,
                                                  lenient=True),
                           mesh=mesh)

    def incremental(self, sources, *, config=None) -> IncrementalSSSP:
        """Streaming repair driver bound to this handle's dynamic graph
        (frontier-seeded incremental BFS/SSSP — core/incremental.py)."""
        g = self._dynamic()
        if config is None:
            config = self.options.to(
                WeightedConfig if g.weighted else EngineConfig,
                lenient=True)
        return IncrementalSSSP(g, sources, config=config)

    # -- autotuning --------------------------------------------------------

    @property
    def tuning(self) -> Optional[TuningPlan]:
        """The TuningPlan cached on this handle (None = untuned)."""
        return self.options.tuning

    def tune(self, *, use_hlo: bool = True, save=None,
             profile=None) -> TuningPlan:
        """Build a roofline :class:`TuningPlan` for this graph, cache it
        on the handle (every later query consults it — tile sizes, the
        fused gate, and deterministic ``mode="auto"`` direction pins),
        and optionally ``save`` it for reproducible reruns
        (``prepare(g, tuning="plan.json")``)."""
        plan = build_plan(self.prepared(), weights=self._lane_weights(),
                          profile=profile, use_hlo=use_hlo)
        if save is not None:
            plan.save(save)
        self.options = dataclasses.replace(self.options, tuning=plan)
        self._sharded = {}       # baked configs must pick the plan up
        return plan

    def serve(self, *, mesh=None, **kwargs):
        """Construct a tiered :class:`repro.serve.GraphService` over the
        source graph (epoch-guarded when the graph is dynamic).  Keyword
        arguments pass through (``n_landmarks=``, ``max_batch=``,
        ``clock=``, ...)."""
        from .serve.engine import GraphService
        kwargs.setdefault("config",
                          self.options.to(EngineConfig, lenient=True))
        if self._weights is not None:
            kwargs.setdefault("weights", self._weights)
        return GraphService(self.graph, mesh=mesh, **kwargs)


def prepare(graph: Union[CSRGraph, DynamicCSRGraph], *, weights=None,
            options: Optional[SweepOptions] = None, **opts) -> DawnGraph:
    """Entry point of the facade: wrap a graph in a :class:`DawnGraph`.

    ``options=`` takes a ready :class:`SweepOptions`; any extra keywords
    construct one (``prepare(g, source_batch=64, use_kernel=False)``).
    ``weights=`` attaches static edge weights for the tropical semiring
    (a weighted :class:`DynamicCSRGraph` carries its own).
    ``tuning=`` accepts a :class:`TuningPlan` or the path of a saved one
    (loaded with the backend-fingerprint check) — the reproducibility
    lock for ``mode="auto"`` runs; build one with :meth:`DawnGraph.tune`.
    """
    if options is not None and opts:
        raise ValueError("pass options= or plain keywords, not both")
    if isinstance(opts.get("tuning"), (str, os.PathLike)):
        opts["tuning"] = TuningPlan.load(opts["tuning"])
    return DawnGraph(graph, weights=weights,
                     options=options or SweepOptions(**opts))
