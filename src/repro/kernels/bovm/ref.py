"""Pure-jnp oracle for the fused DAWN sweep kernel."""
from __future__ import annotations

import jax.numpy as jnp


def sweep_ref(frontier: jnp.ndarray, adj: jnp.ndarray, dist: jnp.ndarray,
              step) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference fused sweep.

    frontier : (S, n) int8/bool — current frontier
    adj      : (n, n) int8 dense adjacency
    dist     : (S, n) int32, -1 = unreached
    step     : int32 — path length being assigned this sweep

    returns (new_frontier int8 (S, n), dist int32 (S, n))
    """
    counts = frontier.astype(jnp.float32) @ adj.astype(jnp.float32)
    visited = dist >= 0
    new = (counts > 0) & ~visited
    return new.astype(jnp.int8), jnp.where(new, jnp.int32(step), dist)


def packed_pull_ref(frontier_packed: jnp.ndarray, adj_in_packed: jnp.ndarray,
                    dist: jnp.ndarray, step) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference for the bit-packed pull sweep.

    frontier_packed : (S, W) uint32 — packed frontier rows (W = ceil(n/32))
    adj_in_packed   : (n, W) uint32 — row j = packed in-neighbour set of j
    dist            : (S, n) int32

    hits[s, j] = any_w(frontier_packed[s, w] & adj_in_packed[j, w])
    """
    inter = frontier_packed[:, None, :] & adj_in_packed[None, :, :]
    hits = jnp.any(inter != 0, axis=-1)
    visited = dist >= 0
    new = hits & ~visited
    return new.astype(jnp.int8), jnp.where(new, jnp.int32(step), dist)


def packed_push_ref(frontier_packed: jnp.ndarray, adj_in_packed: jnp.ndarray,
                    dist: jnp.ndarray, step) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference for the bit-packed push sweep.  Once the frontier is
    packed over the contraction axis, push computes the identical
    word-AND/OR product as pull — the reference is shared; only the
    kernels differ (tile shape + occupancy gating)."""
    return packed_pull_ref(frontier_packed, adj_in_packed, dist, step)
