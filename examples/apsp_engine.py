"""Direction-optimizing batched APSP, and graph queries in the serving loop.

    PYTHONPATH=src python examples/apsp_engine.py

Part 1 runs tiled all-pairs shortest paths over a road-network-like graph
and prints which sweep forms the engine chose.  Part 2 stands up the
continuous-batching ServingEngine with a GraphService attached and serves
shortest-path queries alongside LM decode steps.
"""
import numpy as np
import jax

from repro.core import EngineConfig, apsp_engine, prepare_graph
from repro.graph import generators as gen
from repro.models import transformer as T
from repro.serve import GraphQuery, GraphService, Request, ServingEngine


def part1_batched_apsp():
    g = gen.grid2d(32, 32)                       # 1024-node road grid
    stats = g.degree_stats()
    print(f"graph: n={stats.n_nodes} m={stats.n_edges} "
          f"avg_deg={stats.avg_degree:.1f} density={stats.density:.2%}")

    pg = prepare_graph(g)                        # dense + packed operands
    res = apsp_engine(pg, config=EngineConfig(source_batch=128))
    dirs = dict(zip(("push", "pull", "sparse"),
                    np.asarray(res.direction_counts).tolist()))
    print(f"APSP over all {stats.n_nodes} sources: dist {res.dist.shape}, "
          f"{int(res.sweeps)} sweeps/tile max, directions {dirs}")
    ecc = int(res.dist.max())
    print(f"graph diameter (max eccentricity): {ecc}")


def part2_serving():
    cfg = T.LMConfig(name="demo", n_layers=2, d_model=64, n_heads=4,
                     n_kv=2, d_head=16, d_ff=128, vocab=96)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    g = gen.watts_strogatz(512, 8, 0.05, seed=1)
    eng = ServingEngine(params, cfg, slots=2, max_len=64,
                        graph_service=GraphService(g, max_batch=16))

    eng.submit(Request(rid=0, prompt=np.array([3, 1, 4], np.int32),
                       max_new=4))
    for i in range(20):
        eng.submit_graph(GraphQuery(qid=i, source=i * 7 % 512, target=200))
    eng.run_to_completion()

    lm = eng.completed[0]
    print(f"LM request: generated {lm.out}")
    hops = [q.hops for q in eng.graph_service.completed]
    print(f"graph queries: {len(hops)} served, hops to node 200: {hops}")


if __name__ == "__main__":
    part1_batched_apsp()
    part2_serving()
