"""Pure-NumPy / SciPy shortest-path oracles shared by the test suite.

Deliberately independent of the library under test: queue BFS (the
paper's Alg. 3 semantics) is reimplemented here straight off the CSR
arrays — it does NOT call ``repro.core.bfs_queue_numpy``, so a bug in
the library's own baseline cannot mask an engine bug — and Dijkstra
comes from ``scipy.sparse.csgraph``.  Dtypes match what the engines
emit (int32 with -1 unreachable for BFS, float64/inf for Dijkstra) so
tests compare with ``assert_array_equal`` / ``assert_allclose``
directly.  Subprocess tests (``tests/test_distributed.py``) import this
module after ``sys.path.insert(0, "tests")``.
"""
from __future__ import annotations

from collections import deque

import numpy as np


def bfs_dist(g, source: int) -> np.ndarray:
    """Textbook queue BFS over the CSR arrays -> (n,) int32, -1 = unreachable."""
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    n = g.n_nodes
    dist = np.full(n, -1, dtype=np.int32)
    dist[source] = 0
    q = deque([source])
    while q:
        u = q.popleft()
        for e in range(indptr[u], indptr[u + 1]):
            v = indices[e]
            if v < n and dist[v] < 0:
                dist[v] = dist[u] + 1
                q.append(v)
    return dist


def bfs_dists(g, sources) -> np.ndarray:
    """Stacked queue-BFS distances -> (S, n) int32."""
    return np.stack([bfs_dist(g, int(s)) for s in np.asarray(sources)])


def bfs_sigma(g, source: int):
    """Queue BFS with shortest-path counting -> (dist int32, sigma
    float64, predecessor lists, stack order) — the textbook forward
    stage of Brandes, straight off the CSR arrays."""
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    n = g.n_nodes
    dist = np.full(n, -1, dtype=np.int32)
    sigma = np.zeros(n, dtype=np.float64)
    pred = [[] for _ in range(n)]
    dist[source] = 0
    sigma[source] = 1.0
    order = []
    q = deque([source])
    while q:
        u = q.popleft()
        order.append(u)
        for e in range(indptr[u], indptr[u + 1]):
            v = indices[e]
            if v >= n:
                continue
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                q.append(v)
            if dist[v] == dist[u] + 1:
                sigma[v] += sigma[u]
                pred[v].append(u)
    return dist, sigma, pred, order


def bfs_sigmas(g, sources) -> np.ndarray:
    """Stacked shortest-path counts -> (S, n) float64 (0 unreachable)."""
    return np.stack([bfs_sigma(g, int(s))[1] for s in np.asarray(sources)])


def brandes_betweenness(g, sources=None) -> np.ndarray:
    """Textbook Brandes betweenness (directed, unnormalized, endpoints
    excluded) -> (n,) float64.  ``sources`` restricts the dependency
    sums (the source-sampled estimator); default: all nodes (exact).
    Deliberately independent of the library's batched level-parallel
    accumulation: per-source predecessor lists and an explicit
    reverse-BFS-order stack."""
    n = g.n_nodes
    sources = range(n) if sources is None else np.asarray(sources)
    bc = np.zeros(n, dtype=np.float64)
    for s in sources:
        s = int(s)
        _, sigma, pred, order = bfs_sigma(g, s)
        delta = np.zeros(n, dtype=np.float64)
        for w in reversed(order):
            for v in pred[w]:
                delta[v] += sigma[v] / sigma[w] * (1.0 + delta[w])
            if w != s:
                bc[w] += delta[w]
    return bc


def closeness_centrality(g, sources=None) -> np.ndarray:
    """Wasserman-Faust closeness over OUTGOING distances -> float64."""
    n = g.n_nodes
    sources = np.arange(n) if sources is None else np.asarray(sources)
    out = np.zeros(len(sources), np.float64)
    for i, s in enumerate(sources):
        dist = bfs_dist(g, int(s))
        reach = dist > 0
        r = int(reach.sum())
        tot = int(dist[reach].sum())
        out[i] = (r / max(n - 1, 1)) * (r / tot) if tot > 0 else 0.0
    return out


def harmonic_centrality(g, sources=None) -> np.ndarray:
    """Harmonic centrality H(u) = Σ_{v≠u} 1/d(u,v) -> float64."""
    sources = np.arange(g.n_nodes) if sources is None else \
        np.asarray(sources)
    out = np.zeros(len(sources), np.float64)
    for i, s in enumerate(sources):
        dist = bfs_dist(g, int(s))
        out[i] = (1.0 / dist[dist > 0]).sum()
    return out


def eccentricities(g, sources=None) -> np.ndarray:
    """Per-source eccentricity over reachable targets -> int32 (0 when
    nothing is reachable)."""
    sources = np.arange(g.n_nodes) if sources is None else \
        np.asarray(sources)
    out = np.zeros(len(sources), np.int32)
    for i, s in enumerate(sources):
        out[i] = int(bfs_dist(g, int(s)).max(initial=0))
    return out


def dijkstra_dist(g, weights, source: int) -> np.ndarray:
    """scipy Dijkstra -> (n,) float64, +inf = unreachable.  ``weights``
    may cover the padded edge lanes; only the first ``n_edges`` are read."""
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csgraph
    src, dst = g.edge_arrays_np()
    mat = sp.csr_matrix((np.asarray(weights[: g.n_edges], np.float64),
                         (src, dst)), shape=(g.n_nodes, g.n_nodes))
    return csgraph.dijkstra(mat, indices=source, directed=True)


def dijkstra_dists(g, weights, sources) -> np.ndarray:
    """Stacked Dijkstra distances -> (S, n) float64."""
    return np.stack([dijkstra_dist(g, weights, int(s))
                     for s in np.asarray(sources)])


# --------------------------------------------------------------------------
# adversarial graph families for the cross-form differential harness
# --------------------------------------------------------------------------

def adversarial_families(seed: int = 0):
    """Edge lists that historically break sweep implementations.

    Yields ``(name, src, dst, n_nodes)`` tuples — raw numpy edge arrays,
    deliberately NOT CSRGraph objects so callers control dedup/padding.
    One seeded random member keeps the family list honest against shapes
    nobody thought to enumerate.  Families cover: hub fan-out/fan-in
    (stars), deep frontiers (path), 2-cycles of discovery (cycle), dense
    one-sweep closure (clique), unreachable components, self-loops,
    duplicate/parallel edges, isolated vertices, and vertex counts not
    divisible by any tile size (ragged n; tiny n).
    """
    rng = np.random.default_rng(seed)
    fams = []

    def fam(name, src, dst, n):
        fams.append((name, np.asarray(src, np.int32),
                     np.asarray(dst, np.int32), n))

    n = 37                                   # ragged on purpose
    hub = np.zeros(n - 1, np.int64)
    spokes = np.arange(1, n)
    fam("star_out", hub, spokes, n)          # hub -> all: 1-sweep BFS
    fam("star_in", spokes, hub, n)           # all -> hub: most rows stall
    fam("path", np.arange(n - 1), np.arange(1, n), n)   # diameter n-1
    fam("cycle", np.arange(n), np.r_[np.arange(1, n), 0], n)
    k = 13
    cq = np.arange(k)
    fam("clique", np.repeat(cq, k), np.tile(cq, k), k)  # incl. self-loops
    # two components + isolated vertices 20..36 (never discovered)
    fam("two_components",
        np.r_[np.arange(0, 9), np.arange(10, 19)],
        np.r_[np.arange(1, 10), np.arange(11, 20)], n)
    fam("self_loops", np.r_[np.arange(12), np.arange(12)],
        np.r_[np.arange(12), np.r_[np.arange(1, 12), 0]], 12)
    fam("duplicate_edges", np.r_[[0] * 5, [1] * 5, np.arange(2, 9)],
        np.r_[[1] * 5, [2] * 5, np.arange(3, 10)], 10)
    fam("tiny", [0, 1], [1, 0], 2)
    n2 = 137                                 # ragged vs 8/32/128 tiles
    m2 = 600
    fam("random_ragged", rng.integers(0, n2, m2), rng.integers(0, n2, m2),
        n2)
    return fams
