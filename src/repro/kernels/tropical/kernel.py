"""Pallas TPU kernels for the tropical (min,+) sweep — the weighted
engine's hot path (paper §5 grown onto the same substrate as BOVM).

``fused_minplus_sweep`` — dense direction (min-plus "GEMM" push).
  Grid (Si, Nj, Kk), K innermost, exactly the boolean ``fused_sweep``
  skeleton from ``kernels/common.py``: each (i, j) output tile
  accumulates ``min_k(fdist_block[s, k] + W_block[k, j])`` in a VMEM
  scratch (⊕ = min replaces the MXU add-accumulate; the inner min-plus
  runs one k lane per VPU step, the same per-lane schedule as the packed
  pull kernel's word loop), then fuses the DAWN epilogue: improved-mask
  test, distance write.  Two scalar-prefetched occupancy tables gate
  every grid step:

    * f_occ[i, k] — frontier block (i, k) has any active source
                    (``isfinite`` of the frontier-masked distances);
    * o_occ[i, j] — output tile (i, j) has any *improvable* target.

  The boolean o_occ ("any unreached") is unsound for (min,+) — finite
  distances can still improve — so the tropical table generalizes
  Thm 3.2 through Dijkstra's settled criterion at tile rank:

    skip (i, j)  iff  dist[s, j'] <= min_k fdist[s, k] + w_min
                      for every (s, j') in the tile,

  where ``w_min`` is the graph's minimum edge weight.  Every candidate
  this sweep can produce for row s is >= min_fd[s] + w_min, so a tile of
  settled targets cannot improve: the skip is exact, not heuristic, and
  with unit weights it degenerates to the boolean "any unreached" table.

``sparse_relax_sweep`` — edge-parallel relaxation over CSR lanes.
  Grid (m_pad / eb,), sequential: each step gathers ``dist[:, src]``,
  adds the lane weights, masks to the frontier, and scatter-mins an
  (S, n_pad) VMEM accumulator (``eb`` edges relax in parallel per step);
  the last step fuses the epilogue.  Padded lanes carry the CSR sentinel
  (src = dst = n, w = +inf) and are inert.  Gather/scatter by edge index
  is validated under ``interpret=True`` (the CPU path this repo tests);
  on real TPU hardware prefer the dense kernel or the XLA sparse form —
  the registry notes record this caveat.

VMEM budgets (defaults): dense tiles (128×128 f32 fdist + 128×128 f32 W
+ 128×128 f32 dist/acc + i8+f32 out) ≈ 0.4 MB.  The sparse kernel keeps
whole (S, n_pad) state blocks resident (~14 B/entry: i8 frontier, f32
dist/acc/out, i8 out), so its footprint scales with S × n_pad — (64,
1152) ≈ 1.0 MB, but a 131k-node graph at S=64 would need ~117 MB: on
large graphs keep S small or prefer the dense kernel / XLA sparse form.
All dense dims are multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import common


# --------------------------------------------------------------------------
# dense direction: fused min-plus "GEMM" sweep
# --------------------------------------------------------------------------

def _minplus_sweep_kernel(f_occ_ref, o_occ_ref,        # scalar prefetch
                          fd_ref, w_ref, dist_ref,     # VMEM in
                          new_ref, dist_out_ref,       # VMEM out
                          acc_ref):                    # VMEM scratch f32
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, jnp.inf)

    live = (f_occ_ref[i, k] > 0) & (o_occ_ref[i, j] > 0)

    @pl.when(live)
    def _accumulate():
        fd = fd_ref[...]                       # (bs, bk) f32, +inf off-front
        w = w_ref[...]                         # (bk, bn) f32, +inf non-edge

        def lane(kk, acc):
            col = jax.lax.dynamic_slice_in_dim(fd, kk, 1, 1)   # (bs, 1)
            row = jax.lax.dynamic_slice_in_dim(w, kk, 1, 0)    # (1, bn)
            return jnp.minimum(acc, col + row)

        acc_ref[...] = jax.lax.fori_loop(0, fd.shape[1], lane, acc_ref[...])

    @pl.when(k == nk - 1)
    def _epilogue():
        dist = dist_ref[...]
        cand = acc_ref[...]
        new = cand < dist
        new_ref[...] = new.astype(jnp.int8)
        dist_out_ref[...] = jnp.where(new, cand, dist)


@functools.partial(jax.jit, static_argnames=("bs", "bn", "bk", "interpret"))
def fused_minplus_sweep(fdist: jax.Array, wdense: jax.Array,
                        dist: jax.Array, w_min: jax.Array, *, bs: int = 128,
                        bn: int = 128, bk: int = 128,
                        interpret: bool = False):
    """One fused (min,+) sweep.  Shapes: fdist (S, k) f32 — the
    frontier-masked distances (``where(frontier, dist, +inf)``), wdense
    (k, n) f32 with +inf non-edges (square, k == n, on the single-device
    path; a K-row block, k = n/C, under the sharded executor — partials
    are min-combined across shards), dist (S, n) f32; ``w_min`` the
    scalar minimum finite edge weight (traced; drives the settled-skip
    table).  S % bs == 0, n % bn == 0, k % bk == 0.  Returns
    (new int8 (S, n), dist f32 (S, n)) — bit-identical to the dense
    reference form (f32 min is exact, the skips are provably inert)."""
    s, k = fdist.shape
    ka, n = wdense.shape
    assert ka == k and dist.shape == (s, n), \
        (fdist.shape, wdense.shape, dist.shape)
    common.check_push_tiles(s, n, bs, bn, bk, k=k)
    gi, gj, gk = s // bs, n // bn, k // bk

    f_occ = common.block_any(jnp.isfinite(fdist), gi, bs, gk, bk)
    # Dijkstra-style settled bound: row s cannot improve any target whose
    # distance is already <= min_k fdist[s, k] + w_min
    bound = jnp.min(fdist, axis=1, keepdims=True) + w_min    # (S, 1)
    o_occ = common.block_any(dist > bound, gi, bs, gj, bn)

    grid_spec = common.push_grid_spec(gi, gj, gk, bs=bs, bn=bn, bk=bk,
                                      num_scalar_prefetch=2,
                                      acc_dtype=jnp.float32)
    new, dist_out = pl.pallas_call(
        _minplus_sweep_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((s, n), jnp.int8),
                   jax.ShapeDtypeStruct((s, n), jnp.float32)],
        compiler_params=common.sweep_compiler_params(),
        interpret=interpret,
    )(f_occ.astype(jnp.int32), o_occ.astype(jnp.int32), fdist, wdense, dist)
    return new, dist_out


# --------------------------------------------------------------------------
# fused multi-sweep persistent kernel (tropical): same skeleton as the
# boolean fused kernel — whole weight matrix resident, Fact 1 in-kernel
# --------------------------------------------------------------------------

def _fused_minplus_kernel(meta_ref,                        # scalar prefetch
                          f_ref, w_ref, dist_ref,          # VMEM in
                          new_ref, dist_out_ref,           # VMEM out
                          prod_ref, stop_ref,              # VMEM out (1, 1)
                          *, max_sweeps: int):
    n_run = meta_ref[1]                  # meta[0] (step) unused: dist is ⊕
    w = w_ref[...]                       # (n, n) f32, resident throughout
    d0 = dist_ref[...]                   # (bs, n) f32

    def sweep(t, carry):
        done, prod, f8, d, new8 = carry
        live = (done == 0) & (t < n_run)
        fd = jnp.where(f8 != 0, d, jnp.inf)

        def lane(kk, acc):
            col = jax.lax.dynamic_slice_in_dim(fd, kk, 1, 1)   # (bs, 1)
            row = jax.lax.dynamic_slice_in_dim(w, kk, 1, 0)    # (1, n)
            return jnp.minimum(acc, col + row)

        cand = jax.lax.fori_loop(0, w.shape[0], lane,
                                 jnp.full(d.shape, jnp.inf))
        new = cand < d
        any_new = jnp.any(new)
        d = jnp.where(new & live, cand, d)
        new8 = jnp.where(live, new.astype(jnp.int8), new8)
        f8 = jnp.where(live, new.astype(jnp.int8), f8)
        prod = prod + (live & any_new).astype(jnp.int32)
        done = done | (live & ~any_new).astype(jnp.int32)
        return done, prod, f8, d, new8

    done, prod, _, d, new8 = jax.lax.fori_loop(
        0, max_sweeps, sweep,
        (jnp.int32(0), jnp.int32(0), f_ref[...], d0,
         jnp.zeros(d0.shape, jnp.int8)))
    new_ref[...] = new8
    dist_out_ref[...] = d
    prod_ref[0, 0] = prod
    stop_ref[0, 0] = done


@functools.partial(jax.jit,
                   static_argnames=("bs", "max_sweeps", "interpret"))
def fused_minplus_multisweep(frontier: jax.Array, wdense: jax.Array,
                             dist: jax.Array, step: jax.Array,
                             n_run: jax.Array, *, bs: int = 128,
                             max_sweeps: int = 1, interpret: bool = False):
    """Run up to ``n_run`` (min,+) sweeps in one invocation — the
    tropical instantiation of the fused multi-sweep skeleton (see the
    boolean ``fused_boolean_multisweep`` for the accounting contract).
    frontier (S, n) int8 improved-mask, wdense (n, n) f32 resident,
    dist (S, n) f32; ``step`` is accepted for signature uniformity but
    unused (tropical distances are the candidates themselves).  The
    per-lane min order matches the per-sweep kernel and reference forms,
    and f32 min is exact, so the fused block is bit-identical to
    ``n_run`` per-sweep dispatches.  Returns (new int8, dist f32,
    prod int32, stopped bool)."""
    del step
    s, n = frontier.shape
    assert wdense.shape == (n, n) and dist.shape == (s, n), \
        (frontier.shape, wdense.shape, dist.shape)
    assert s % bs == 0 and n % 128 == 0, (s, n, bs)
    gi = s // bs
    meta = jnp.stack([jnp.int32(0), jnp.asarray(n_run, jnp.int32)])

    grid_spec = common.fused_grid_spec(gi, bs=bs, n=n, f_block=(bs, n),
                                       op_block=(n, n))
    new, dist_out, prod, stop = pl.pallas_call(
        functools.partial(_fused_minplus_kernel, max_sweeps=max_sweeps),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((s, n), jnp.int8),
                   jax.ShapeDtypeStruct((s, n), jnp.float32),
                   jax.ShapeDtypeStruct((gi, 1), jnp.int32),
                   jax.ShapeDtypeStruct((gi, 1), jnp.int32)],
        compiler_params=common.fused_compiler_params(),
        interpret=interpret,
    )(meta, frontier, wdense, dist)
    return new, dist_out, jnp.max(prod), jnp.min(stop) > 0


# --------------------------------------------------------------------------
# sparse direction: edge-parallel relax over CSR lanes
# --------------------------------------------------------------------------

def _sparse_relax_kernel(f_ref, d_ref, src_ref, dst_ref, w_ref,  # VMEM in
                         new_ref, dist_out_ref,                  # VMEM out
                         acc_ref):                               # scratch f32
    k = pl.program_id(0)
    nk = pl.num_programs(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.full_like(acc_ref, jnp.inf)

    src = src_ref[0, :]                       # (eb,) int32 lanes
    dst = dst_ref[0, :]
    w = w_ref[0, :]
    d = d_ref[...]                            # (S, n_pad) f32
    active = f_ref[...][:, src] != 0          # frontier gate per lane
    cand = jnp.where(active, d[:, src] + w[None, :], jnp.inf)
    acc_ref[...] = acc_ref[...].at[:, dst].min(cand)

    @pl.when(k == nk - 1)
    def _epilogue():
        new = acc_ref[...] < d
        new_ref[...] = new.astype(jnp.int8)
        dist_out_ref[...] = jnp.where(new, acc_ref[...], d)


@functools.partial(jax.jit, static_argnames=("eb", "interpret"))
def sparse_relax_sweep(frontier: jax.Array, dist: jax.Array,
                       src_idx: jax.Array, dst_idx: jax.Array,
                       w_edges: jax.Array, *, eb: int = 128,
                       interpret: bool = True):
    """One edge-parallel (min,+) relax sweep.  frontier (S, n_pad) int8,
    dist (S, n_pad) f32, src/dst (m_pad,) int32 CSR lanes (sentinel-
    padded), w_edges (m_pad,) f32 (+inf padded lanes).  m_pad % eb == 0
    (CSRGraph pads edges to multiples of 128).

    Interpret-only: the per-lane gathers/scatters are validated op-by-op,
    not under Mosaic compilation, and the whole-(S, n_pad)-state VMEM
    footprint is unbounded in n_pad — the registry marks the form
    ``interpret_only`` and ``sweep.tropical_forms`` dispatches the XLA
    scatter-min form instead on compiled backends.  This guard makes the
    contract a hard error rather than a registry convention."""
    if not interpret:
        raise RuntimeError(
            "sparse_relax_sweep is interpret-only (see the tropical "
            "KernelSet's interpret_only marker): compiled TPU dispatch "
            "must fall back to the XLA sparse form — "
            "sweep.tropical_forms does this automatically")
    s, n_pad = frontier.shape
    m_pad = src_idx.shape[0]
    assert dist.shape == (s, n_pad)
    assert dst_idx.shape == (m_pad,) and w_edges.shape == (m_pad,)
    assert m_pad % eb == 0, (m_pad, eb)
    gk = m_pad // eb
    # 2D (gk, eb) lane blocks: TPU block loads want >= 2D operands
    src2 = src_idx.reshape(gk, eb)
    dst2 = dst_idx.reshape(gk, eb)
    w2 = w_edges.reshape(gk, eb)

    full = lambda i: (0, 0)        # noqa: E731 — whole-state block per step
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(gk,),
        in_specs=[
            pl.BlockSpec((s, n_pad), full),
            pl.BlockSpec((s, n_pad), full),
            pl.BlockSpec((1, eb), lambda i: (i, 0)),
            pl.BlockSpec((1, eb), lambda i: (i, 0)),
            pl.BlockSpec((1, eb), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((s, n_pad), full),
            pl.BlockSpec((s, n_pad), full),
        ],
        scratch_shapes=[pltpu.VMEM((s, n_pad), jnp.float32)],
    )
    new, dist_out = pl.pallas_call(
        _sparse_relax_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((s, n_pad), jnp.int8),
                   jax.ShapeDtypeStruct((s, n_pad), jnp.float32)],
        compiler_params=common.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(frontier, dist, src2, dst2, w2)
    return new, dist_out
