from .ops import (sweep, msbfs_kernel, msbfs_packed, pack_adjacency_pull,
                  KernelDawnResult)
from .kernel import fused_sweep, packed_pull_sweep
from .ref import sweep_ref, packed_pull_ref

from .. import common, registry


def vmem_bytes(*, form: str = "push", bs: int | None = None, bn: int = 128,
               bk: int = 512, wk: int = 128) -> int:
    """Resident VMEM of one grid step (docs/ARCHITECTURE.md table).

    ``bs`` defaults to the tile the engine actually dispatches: 128 for
    the push form, 8 for the bit-packed pull form (``sweep.boolean_forms``
    caps the pull source tile at ``min(s, 8)``).
    """
    if form == "push":   # int8 frontier + int8 adj + i32 dist/acc, i8+i32 out
        return common.push_vmem_bytes(128 if bs is None else bs, bn, bk,
                                      f_itemsize=1, a_itemsize=1,
                                      d_itemsize=4, acc_itemsize=4,
                                      out_itemsizes=(1, 4))
    assert form == "pull", form    # uint32 words + i32 dist/acc, i8+i32 out
    return common.pull_vmem_bytes(8 if bs is None else bs, bn, wk,
                                  word_itemsize=4, d_itemsize=4,
                                  acc_itemsize=4, out_itemsizes=(1, 4))


registry.register(registry.KernelSet(
    semiring="boolean",
    forms={"push": fused_sweep, "pull": packed_pull_sweep},
    vmem_bytes=vmem_bytes,
    notes="fused boolean GEMM sweep (MXU) + bit-packed pull sweep (VPU)",
))
