"""DAWN's own workloads (the paper's experiment families, §4.1)."""
from ..graph import generators

GRAPH_SUITE = generators.SUITE
SOURCE_SET_SIZE = 500      # paper: 500-node random source set
REPEATS = 64               # paper: 64 repetitions per source
