"""graphsage-reddit — sampled GNN, mean aggregator.
[arXiv:1706.02216; paper]  2L d_hidden=128 sample 25-10."""
from ..models.gnn import SAGEConfig

CONFIG = SAGEConfig(
    name="graphsage-reddit", n_layers=2, d_hidden=128, fanouts=(25, 10),
    d_in=602, n_classes=41)
