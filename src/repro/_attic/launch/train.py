"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a *reduced* config of the selected architecture end-to-end on the
local devices (CPU here; the same code path drives the production mesh on
real hardware), with checkpointing, fault-tolerance hooks, and metrics.
The full-size configs are exercised by the dry-run (launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from repro.data import graphs as DG
from ..data import recsys as DR
from repro.data import tokens as DTok
from ..models import gnn as G
from ..models import recsys as R
from ..models import transformer as T
from repro.train import optimizer as O
from repro.train.checkpoint import CheckpointHook, latest_step, restore
from repro.train.train_loop import make_train_step, train


def reduced_lm(cfg: T.LMConfig) -> T.LMConfig:
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, n_experts=8, top_k=2,
                                  d_model=128, d_ff=128, n_groups=1,
                                  shared_expert_ff=min(
                                      cfg.moe.shared_expert_ff, 128),
                                  dense_residual_ff=min(
                                      cfg.moe.dense_residual_ff, 128))
    mla = None
    if cfg.mla is not None:
        mla = dataclasses.replace(cfg.mla, d_model=128, n_heads=4,
                                  q_lora_rank=64, kv_lora_rank=32,
                                  d_nope=16, d_rope=16, d_v=16)
    return dataclasses.replace(
        cfg, n_layers=2, d_model=128, n_heads=4, n_kv=min(cfg.n_kv, 2),
        d_head=32, d_ff=256, vocab=512, moe=moe,
        n_dense_layers=min(cfg.n_dense_layers, 1), mla=mla)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    family, cfg = get_arch(args.arch)
    key = jax.random.PRNGKey(0)
    hooks = []
    if args.ckpt_dir:
        hooks.append(CheckpointHook(args.ckpt_dir, args.ckpt_every))

    if family == "lm":
        cfg = reduced_lm(cfg)
        params = T.init_params(key, cfg)
        opt = O.adamw(peak_lr=args.lr,
                      schedule=O.cosine_schedule(args.lr, warmup=10,
                                                 total=args.steps))
        step = jax.jit(make_train_step(
            lambda p, b: T.loss_fn(p, b, cfg), opt))
        it = ({"tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"])}
              for b in DTok.lm_iterator(global_batch=args.batch,
                                        seq_len=args.seq, vocab=cfg.vocab))
    elif family == "gnn":
        g = DG.demo_graph("small")
        batch_np = DG.full_graph_batch(g, d_feat=64, seed=0)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if args.arch == "graphsage-reddit":
            cfg = dataclasses.replace(cfg, d_in=64)
            params = G.sage_init(key, cfg)
            loss = lambda p, b: G.sage_loss(p, b, cfg)
        elif args.arch == "meshgraphnet":
            cfg = dataclasses.replace(cfg, n_layers=3, d_node_in=64)
            params = G.mgn_init(key, cfg)
            loss = lambda p, b: G.mgn_loss(p, b, cfg)
        elif args.arch == "schnet":
            cfg = dataclasses.replace(cfg, n_rbf=32)
            params = G.schnet_init(key, cfg)
            loss = lambda p, b: G.schnet_loss(p, b, cfg, 1)
        else:
            cfg = dataclasses.replace(cfg, n_layers=2, d_hidden=16, l_max=2)
            params = G.eqv2_init(key, cfg)
            loss = lambda p, b: G.eqv2_loss(p, b, cfg, 1)
        opt = O.adamw(peak_lr=args.lr)
        step = jax.jit(make_train_step(loss, opt))
        it = iter(lambda: batch, None)  # same full-graph batch each step
        it = (batch for _ in range(10**9))
    else:
        cfg = dataclasses.replace(cfg, n_items=5000, n_cats=100,
                                  n_profile=1000, seq_len=20)
        params = R.dien_init(key, cfg)
        opt = O.adamw(peak_lr=args.lr)
        step = jax.jit(make_train_step(
            lambda p, b: R.dien_loss(p, b, cfg), opt))
        it = ({k: jnp.asarray(v) for k, v in
               DR.click_batch(i, cfg, batch=args.batch).items()}
              for i in range(10**9))

    opt_state = opt.init(params)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir):
        s = latest_step(args.ckpt_dir)
        (restored, _) = restore(args.ckpt_dir, s,
                                {"params": params, "opt": opt_state})[0], s
        params, opt_state = restored["params"], restored["opt"]
        start = s
        print(f"resumed from step {s}")

    t0 = time.time()
    params, opt_state, metrics = train(
        params, opt_state, step, it, n_steps=args.steps, hooks=hooks,
        start_step=start)
    for h in hooks:
        if hasattr(h, "flush"):
            h.flush()
    dt = time.time() - t0
    print(f"[{args.arch}] {args.steps - start} steps in {dt:.1f}s; "
          f"final metrics: "
          f"{ {k: float(np.asarray(v)) for k, v in metrics.items()} }")


if __name__ == "__main__":
    main()
