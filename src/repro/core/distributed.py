"""Semiring-generic sharded sweep executor — DAWN's multi-device path.

The paper's APSP regime O(S_wcc · E_wcc) is embarrassingly parallel over
sources, and the algebraic formulation (Burkhardt 2019's algebraic BFS;
the paper's Eq. 9 union-as-matrix-op) makes the per-sweep relaxation
itself shardable over vertices.  This module scales BOTH axes, for any
semiring the sweep layer knows:

  * **sources** shard over the mesh's data-parallel axes (every axis not
    named ``model``): each shard runs the unified driver
    (:func:`repro.core.sweep.sweep_loop`) on its ``(S/D, n_pad)`` state
    rows with zero per-sweep communication; only the Fact-1 convergence
    predicate is psum'd across the whole mesh so every shard executes the
    same trip count.
  * **vertices** (optional, mesh axis ``model``) shard the sweep operand:
    the dense adjacency / weight matrix splits into K-row blocks (the
    contraction dim), the CSR lanes into per-shard dst-block partitions
    (:func:`repro.graph.partition.edge_partition_global`).  Each sweep
    computes a *partial* candidate set from its local block and
    cross-shard combines with the semiring's ⊕ — OR (``lax.pmax``) for
    boolean, min (``lax.pmin``) for tropical, masked ADD (``lax.psum``
    of gated partial path counts) for the counting semiring — before
    the epilogue.  The idempotent ⊕'s (OR, min) may fold epilogue
    outputs; the non-idempotent counting ⊕ must sum *gated partials*
    instead so every shortest path is counted exactly once.  All are
    associative, commutative and exact (f32 min does not round; f32
    adds of path counts are exact under 2^24), so sharded distances
    and sweep counts are **bit-identical** to the single-device engines.

Forms dispatch through :mod:`repro.kernels.registry` exactly as the
single-device engines do (``use_kernel`` / ``interpret`` resolve the same
way; the rectangular Pallas push / min-plus kernels take the K-row
blocks directly), and this module carries no loop of its own — the ONE
``lax.while_loop`` stays in ``core/sweep.py``; the old boolean-only
msbfs builder and its private loop plumbing are gone.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import compat
from ..graph.csr import CSRGraph, _round_up
from ..graph.partition import edge_partition_global
from ..kernels import registry as kernel_registry
from . import autotune
from . import sweep as S
from .engine import _resolve_kernel, frontier_stats
from .frontier import (UNREACHED, one_hot_frontier, pack_bits,
                       unpack_bits)
from .options import SweepOptions

INF = jnp.float32(jnp.inf)

MODEL_AXIS = "model"

DENSE, SPARSE = 0, 1
SHARDED_FORM_NAMES = ("dense", "sparse")


@dataclasses.dataclass(frozen=True)
class ShardedConfig(SweepOptions):
    """Static sharded-executor parameters (a :class:`SweepOptions`
    subclass, hashable jit static arg).

    ``semiring`` picks the algebra ("boolean" unweighted BFS, "tropical"
    (min,+) APSP — weights required, "counting" shortest-path counting
    with (dist, sigma) state for the centrality subsystem).  ``mode``
    pins the sweep form —
    dense (the GEMM-analogue push; the collective-friendly matrix form)
    or sparse (edge-partitioned scatter) — or lets ``auto`` switch per
    sweep on the same occupancy cost model the single-device engines use
    (stats pmean'd over the data axes so every shard picks the same
    branch).  ``use_kernel=None`` resolves to "Pallas kernels iff on
    TPU", exactly like ``EngineConfig``/``WeightedConfig``.
    """
    mode: str = "dense"                # dense | sparse | auto
    semiring: str = "boolean"          # boolean | tropical | counting
    max_sweeps: Optional[int] = None   # alias of max_steps (hop bound)
    # kernel / reference tiling knobs (mirror the single-device configs)
    eb: int = 128
    chunk: int = 128
    # auto-mode cost constants (same units as the single-device engines)
    c_dense: float = 1.0
    c_sparse: float = 8.0
    # fused multi-sweep blocks (boolean, mode="dense", kernel path,
    # C == 1 only): 0 = off, K > 0 = K sweeps per launch, -1 = whole
    # fixpoint.  Vertex sharding (C > 1) needs a cross-shard ⊕ between
    # sweeps, so it always falls back to the per-sweep loop; with C == 1
    # only the Fact-1 predicate crosses shards and the fused block's
    # (prod, stopped) scalars psum/pmax-combine instead (fused_combine).

    _mode_names = SHARDED_FORM_NAMES   # dense | sparse

    def __post_init__(self):
        assert self.semiring in ("boolean", "tropical", "counting"), \
            self.semiring
        bound = self.max_sweeps if self.max_sweeps is not None \
            else self.max_steps
        object.__setattr__(self, "max_sweeps", bound)
        object.__setattr__(self, "max_steps", bound)
        super().__post_init__()

    @property
    def tropical(self) -> bool:
        return self.semiring == "tropical"

    @property
    def counting(self) -> bool:
        return self.semiring == "counting"

    @property
    def need_dense(self) -> bool:
        return self.mode in ("dense", "auto")

    @property
    def need_sparse(self) -> bool:
        return self.mode in ("sparse", "auto")


class ShardedApspResult(NamedTuple):
    dist: jax.Array              # (S, n) int32 boolean / float32 tropical
    sweeps: jax.Array            # scalar int32 — matches the 1-device count
    direction_counts: jax.Array  # (2,) int32 — dense/sparse sweeps run
    # (S, n) f32 shortest-path counts — counting semiring only, else None
    sigma: Optional[jax.Array] = None
    # f32 Eq. 10 useful-work counter, psum'd over the data shards (the
    # per-shard partials are exact integer sums, so the total is
    # independent of the mesh shape); 0 on the fused-kernel path, which
    # never materializes per-sweep frontiers to weigh against ``deg``
    edges_touched: Optional[jax.Array] = None


@dataclasses.dataclass
class ShardedOperands:
    """Device-resident sharded operands, built once per (graph, mesh,
    config) and reused across calls (the serving path caches one)."""
    graph: CSRGraph
    mesh: Mesh
    config: ShardedConfig
    n_pad: int
    n_shards: int            # model-axis extent C (1 = no vertex sharding)
    m_local: int             # padded CSR lanes per shard (cost model)
    dense_op: jax.Array      # (n_pad, n_pad) adj int8 / weights f32,
    #                          K-row-sharded over model; (1, 1) dummy
    src_l: jax.Array         # (C, e_pad) sharded / (m_pad,) replicated
    dst_l: jax.Array         #   global ids, CSR sentinel n
    w_l: jax.Array           # tropical lane weights (+inf pad); (1,) dummy
    w_min: jax.Array         # scalar f32 min finite edge weight (0 dummy)
    deg: jax.Array           # (n_pad,) f32 out-degrees, replicated (0 pad)


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


def dp_extent(mesh: Mesh) -> int:
    out = 1
    for a in _dp_axes(mesh):
        out *= mesh.shape[a]
    return out


def prepare_sharded(g: CSRGraph, mesh: Mesh, *, weights=None,
                    config: ShardedConfig = ShardedConfig(),
                    dense_op: Optional[jax.Array] = None
                    ) -> ShardedOperands:
    """Pad, partition and device_put the operands ``config`` can
    dispatch.  ``n_pad`` rounds to a multiple of 128·C so the K-row
    blocks stay MXU-tileable; arbitrary (non-divisible) n and source
    counts are handled by padding, exactly like the single-device
    engines.  Pass ``dense_op`` (an already-materialized (n_pad, n_pad)
    adjacency / weight matrix, e.g. ``PreparedGraph.adj`` /
    ``PreparedWeightedGraph.wdense``) to avoid holding a second dense
    copy when the padded size matches — the serving path does this on
    meshes without vertex sharding."""
    C = dict(mesh.shape).get(MODEL_AXIS, 1)
    n_pad = g.n_padded(128 * C)
    # TuningPlan overlay happens here, where the config is baked into the
    # prepared operands (sharded_apsp refuses config= on a ShardedOperands)
    config = autotune.apply(config, semiring=config.semiring, n_pad=n_pad)
    tropical = config.tropical

    lanes = None
    w_min = jnp.float32(0.0)
    if tropical:
        if weights is None:
            raise ValueError("tropical sharding needs edge weights")
        w = np.asarray(weights, np.float32)
        assert w.ndim == 1 and w.size >= g.n_edges, \
            f"need >= {g.n_edges} weights, got shape {w.shape}"
        assert (w[: g.n_edges] >= 0).all(), "weights must be non-negative"
        lanes = np.full(g.m_pad, np.inf, np.float32)
        lanes[: g.n_edges] = w[: g.n_edges]
        w_min = jnp.float32(lanes[: g.n_edges].min() if g.n_edges
                            else np.inf)

    if not config.need_dense:
        if dense_op is not None:
            raise ValueError(
                "prepare_sharded: dense_op= passed but config.mode="
                f"{config.mode!r} never dispatches the dense form — it "
                "would be silently dropped")
        dense_op = jnp.zeros((1, 1), jnp.float32 if tropical else jnp.int8)
    else:
        if dense_op is None:
            if tropical:
                dense_op = jnp.full((n_pad, n_pad), INF).at[
                    g.src, g.dst].min(jnp.asarray(lanes))
            else:
                dense_op = g.to_dense_padded(n_pad, dtype=jnp.int8)
        else:
            assert dense_op.shape == (n_pad, n_pad), \
                (dense_op.shape, n_pad)
        spec = P(MODEL_AXIS, None) if C > 1 else P()
        dense_op = jax.device_put(dense_op, NamedSharding(mesh, spec))

    src_l = dst_l = jnp.zeros((1,), jnp.int32)
    w_l = jnp.zeros((1,), jnp.float32)
    m_local = g.m_pad
    if config.need_sparse:
        if C > 1:
            parts = edge_partition_global(g, C, weights=lanes)
            lane_sharding = NamedSharding(mesh, P(MODEL_AXIS, None))
            src_l = jax.device_put(parts["src"], lane_sharding)
            dst_l = jax.device_put(parts["dst"], lane_sharding)
            if tropical:
                w_l = jax.device_put(parts["w"], lane_sharding)
            m_local = parts["e_pad"]
        else:
            src_l, dst_l = g.src, g.dst
            if tropical:
                w_l = jnp.asarray(lanes)
            m_local = g.m_pad

    deg = jnp.zeros(n_pad, jnp.float32).at[: g.n_nodes].set(
        jnp.asarray(g.out_degrees(), jnp.float32))
    deg = jax.device_put(deg, NamedSharding(mesh, P()))

    return ShardedOperands(graph=g, mesh=mesh, config=config, n_pad=n_pad,
                           n_shards=C, m_local=m_local, dense_op=dense_op,
                           src_l=src_l, dst_l=dst_l, w_l=w_l, w_min=w_min,
                           deg=deg)


# --------------------------------------------------------------------------
# the shard_map'd runner (built once per mesh/config/shape, lru-cached)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _make_runner(mesh: Mesh, cfg: ShardedConfig, n_pad: int, n_real: int,
                 m_local: int, use_kernel: bool, interpret: bool,
                 C: int):
    dp = _dp_axes(mesh)
    tropical = cfg.tropical
    counting = cfg.counting
    vertex_sharded = C > 1
    nk = n_pad // C
    all_axes = tuple(mesh.axis_names)

    def run_local(dense_l, src_e, dst_e, w_e, w_min, deg_l, f0_l, dist0_l,
                  sigma0_l, steps):
        if src_e.ndim == 2:              # (1, e_pad) model-axis block row
            src_e, dst_e = src_e[0], dst_e[0]
            w_e = w_e[0] if w_e.ndim == 2 else w_e
        s_l = f0_l.shape[0]
        fused = fused_combine = None
        fused_steps_l = 0

        def or_combine(new_p):
            """Cross-shard ⊕ = OR, bit-packed: all-gather uint32 words
            (S_l·n_pad/8 bytes/shard — 8x under an int8 pmax; OR of words
            is exactly the union of bits) and fold them locally."""
            packed = pack_bits(new_p != 0)                     # (S_l, W)
            gathered = jax.lax.all_gather(packed, MODEL_AXIS)  # (C, ...)
            words = functools.reduce(jnp.bitwise_or,
                                     [gathered[i] for i in range(C)])
            return unpack_bits(words, n_pad).astype(jnp.int8)

        def counting_epilogue(cand_p, d, sg, step):
            """⊕ = masked ADD, the non-idempotent cross-shard combine:
            each shard's candidate counts are gated to zero where they
            cannot contribute, then SUMMED (psum) so every shortest path
            is counted exactly once — folding epilogue *outputs* (the
            OR/min trick) would double-gate the counts."""
            if vertex_sharded:
                cand = jax.lax.psum(cand_p, MODEL_AXIS)
            else:
                cand = cand_p
            new = (cand > 0) & (d == UNREACHED)
            return (new.astype(jnp.int8),
                    (jnp.where(new, step, d), jnp.where(new, cand, sg)))

        # ---- dense form: the GEMM-analogue push over the local K block
        dense_form = None
        if cfg.need_dense:
            if counting:
                if use_kernel:
                    Kc = kernel_registry.get("counting").forms
                    bsc = min(s_l, 128)

                    def partial_cand(fs_k, d, sg, step):
                        # reconstruct the gated partial from the kernel's
                        # epilogue outputs: where new_p, nsg_p IS cand_p,
                        # and dropped zeros don't change the psum
                        new_p, _, nsg_p = Kc["push"](
                            fs_k, dense_l, d, sg, step, bs=bsc, bn=cfg.bn,
                            bk=cfg.bk, interpret=interpret)
                        return jnp.where(new_p != 0, nsg_p, 0.0)
                else:
                    def partial_cand(fs_k, d, sg, step):
                        cand = jax.lax.dot_general(
                            fs_k, dense_l.astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
                        return jnp.where(d == UNREACHED, cand, 0.0)

                if vertex_sharded:
                    def dense_form(f, ds, p, step):
                        d, sg = ds
                        k0 = jax.lax.axis_index(MODEL_AXIS) * nk
                        f_k = jax.lax.dynamic_slice_in_dim(f, k0, nk, 1)
                        sg_k = jax.lax.dynamic_slice_in_dim(sg, k0, nk, 1)
                        fs_k = jnp.where(f_k != 0, sg_k, 0.0)
                        cand_p = partial_cand(fs_k, d, sg, step)
                        new, ds2 = counting_epilogue(cand_p, d, sg, step)
                        return new, ds2, p
                else:
                    dense_form = S.counting_forms(
                        dense_l, jnp.zeros((1,), jnp.int32),
                        jnp.zeros((1,), jnp.int32), n_pad=n_pad, s=s_l,
                        bn=cfg.bn, bk=cfg.bk, use_kernel=use_kernel,
                        interpret=interpret)[0]
            elif tropical:
                if use_kernel:
                    K = kernel_registry.get("tropical").forms
                    bs = min(s_l, 128)

                    def partial_nd(fd_k, d):
                        _, nd = K["dense"](fd_k, dense_l, d, w_min, bs=bs,
                                           bn=cfg.bn, bk=cfg.bk,
                                           interpret=interpret)
                        return nd
                else:
                    def partial_nd(fd_k, d):
                        cand = S.minplus_candidates(fd_k, dense_l,
                                                    chunk=cfg.chunk)
                        return jnp.minimum(d, cand)

                if vertex_sharded:
                    def dense_form(f, d, p, step):
                        k0 = jax.lax.axis_index(MODEL_AXIS) * nk
                        f_k = jax.lax.dynamic_slice_in_dim(f, k0, nk, 1)
                        d_k = jax.lax.dynamic_slice_in_dim(d, k0, nk, 1)
                        fd_k = jnp.where(f_k != 0, d_k, INF)
                        # ⊕ = min: exact cross-shard combine of partials
                        nd = jax.lax.pmin(partial_nd(fd_k, d), MODEL_AXIS)
                        return (nd < d).astype(jnp.int8), nd, p
                else:
                    def dense_form(f, d, p, step):
                        fd = jnp.where(f != 0, d, INF)
                        nd = partial_nd(fd, d)
                        return (nd < d).astype(jnp.int8), nd, p
            else:
                # the kernel push is bit-packed: pack the transpose of
                # the local K-row block (C == 1: the full pull operand)
                # once per trace — word-exact vs graph.to_pull_packed
                adj_pull_l = pack_bits(jnp.transpose(dense_l) != 0) \
                    if use_kernel else jnp.zeros((1, 1), jnp.uint32)
                push = S.boolean_forms(
                    dense_l, adj_pull_l,
                    jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32),
                    n_pad=n_pad, s=s_l, bn=cfg.bn, bk=cfg.bk,
                    use_kernel=use_kernel, interpret=interpret)[S.PUSH]
                if vertex_sharded:
                    def dense_form(f, d, p, step):
                        k0 = jax.lax.axis_index(MODEL_AXIS) * nk
                        f_k = jax.lax.dynamic_slice_in_dim(f, k0, nk, 1)
                        new_p, _, _ = push(f_k, d, p, step)
                        # ⊕ = OR: any shard's partial discovery counts
                        new = or_combine(new_p)
                        return new, jnp.where(new != 0, step, d), p
                else:
                    dense_form = push
                    if cfg.fused_steps and use_kernel \
                            and cfg.mode == "dense":
                        fused_steps_l = S.resolve_fused_steps(
                            "boolean", "push",
                            fused_steps=cfg.fused_steps,
                            max_steps=cfg.max_sweeps or n_real,
                            use_kernel=True, n_pad=n_pad,
                            bs=min(s_l, 128),
                            budget=None if cfg.tuning is None
                            else cfg.tuning.vmem_budget) or 0
                    if fused_steps_l:
                        fused = S.fused_form(
                            "boolean", adj_pull_l, "push",
                            bs=min(s_l, 128), max_sweeps=fused_steps_l,
                            interpret=interpret)

                        def fused_combine(prod, stopped):
                            # like `converged`: the fused block's scalars
                            # must agree on every shard so each shard's
                            # while_loop takes the same trip count
                            prod = jax.lax.pmax(prod, all_axes)
                            alive = jax.lax.psum(
                                (~stopped).astype(jnp.int32), all_axes)
                            return prod, alive == 0

        # ---- sparse form: scatter-⊕ over the shard's CSR lanes --------
        sparse_form = None
        if cfg.need_sparse:
            if counting:
                if vertex_sharded:
                    def sparse_form(f, ds, p, step):
                        # each edge lives in exactly one shard partition,
                        # so the local scatter-adds psum to the exact
                        # per-node path count
                        d, sg = ds
                        active = f[..., src_e] != 0
                        contrib = jnp.where(active, sg[..., src_e], 0.0)
                        cand_p = jnp.zeros(d.shape, jnp.float32).at[
                            ..., dst_e].add(contrib)
                        new, ds2 = counting_epilogue(cand_p, d, sg, step)
                        return new, ds2, p
                else:
                    sparse_form = S.counting_forms(
                        jnp.zeros((1, 1), jnp.int8), src_e, dst_e,
                        n_pad=n_pad, s=s_l, use_kernel=False,
                        interpret=interpret)[1]
            elif tropical:
                _, sparse_c = S.tropical_forms(
                    None, src_e, dst_e, w_e, n_pad=n_pad, chunk=cfg.chunk,
                    use_kernel=use_kernel, interpret=interpret, eb=cfg.eb)
                if vertex_sharded:
                    def sparse_form(f, d, p, step):
                        _, nd_p, _ = sparse_c(f, d, p, step)
                        nd = jax.lax.pmin(nd_p, MODEL_AXIS)
                        return (nd < d).astype(jnp.int8), nd, p
                else:
                    sparse_form = sparse_c
            else:
                sparse_c = S.boolean_forms(
                    jnp.zeros((1, 1), jnp.int8),
                    jnp.zeros((1, 1), jnp.uint32), src_e, dst_e,
                    n_pad=n_pad, s=s_l, use_kernel=False,
                    interpret=interpret)[S.SPARSE]
                if vertex_sharded:
                    def sparse_form(f, d, p, step):
                        new_p, _, _ = sparse_c(f, d, p, step)
                        new = or_combine(new_p)
                        return new, jnp.where(new != 0, step, d), p
                else:
                    sparse_form = sparse_c

        forms = (dense_form or sparse_form, sparse_form or dense_form)

        choose = None
        if cfg.mode == "auto":
            bs = min(s_l, 128)

            def choose(st: S.SweepState):
                d = st.dist[0] if counting else st.dist
                stats = frontier_stats(
                    st.frontier, d, bs=bs, bn=128, bk=128,
                    unreached=jnp.isinf(d) if tropical else None)
                live = stats.live_tile_frac
                if dp:
                    # the lax.switch predicate must agree on every shard
                    # or the collectives inside the forms deadlock
                    live = jax.lax.pmean(live, dp)
                dense_c = cfg.c_dense * s_l * nk * n_pad * live
                sparse_c_ = jnp.float32(cfg.c_sparse * s_l * m_local)
                return (dense_c > sparse_c_).astype(jnp.int32)

        def converged(new):
            # Fact 1 must fire everywhere at once: reduce over the whole
            # mesh so every shard's while_loop predicate agrees
            return jax.lax.psum(jnp.any(new != 0).astype(jnp.int32),
                                all_axes) == 0

        state0 = (dist0_l, sigma0_l) if counting else dist0_l
        st = S.sweep_loop(forms, S.make_state(f0_l, state0, n_forms=2),
                          max_steps=steps, choose=choose, deg=deg_l,
                          forced_dir=0 if cfg.mode in ("auto", "dense")
                          else 1,
                          converged=converged,
                          fused=fused, fused_steps=fused_steps_l,
                          fused_combine=fused_combine)
        if counting:
            dist_out, sigma_out = st.dist
        else:
            dist_out, sigma_out = st.dist, sigma0_l
        # per-shard partials are exact integer sums in f32, so the
        # psum'd Eq. 10 counter matches any row partition bit-for-bit;
        # the frontier rows are replicated over MODEL, so the dp-psum
        # already agrees on every model shard
        edges = jax.lax.psum(st.edges_touched, dp) if dp \
            else st.edges_touched
        return dist_out, sigma_out, st.step, st.dir_counts, edges

    row_spec = P(dp, None) if dp else P(None, None)
    dense_spec = P(MODEL_AXIS, None) \
        if (vertex_sharded and cfg.need_dense) else P()
    lane_spec = P(MODEL_AXIS, None) \
        if (vertex_sharded and cfg.need_sparse) else P()
    w_spec = lane_spec if tropical else P()   # boolean w_l is a 1-D dummy

    sharded = compat.shard_map(
        run_local, mesh=mesh,
        in_specs=(dense_spec, lane_spec, lane_spec, w_spec, P(), P(),
                  row_spec, row_spec, row_spec, P()),
        out_specs=(row_spec, row_spec, P(), P(), P()),
        check_vma=False)

    @jax.jit
    def runner(dense_op, src_l, dst_l, w_l, w_min, deg, sources, n_valid,
               steps):
        s_pad = sources.shape[0]
        f0 = one_hot_frontier(sources, n_pad, dtype=jnp.int8)
        row_ok = (jnp.arange(s_pad) < n_valid)[:, None]
        f0 = jnp.where(row_ok, f0, 0)
        if tropical:
            # pad rows/cols stay +inf with empty frontiers: inert
            dist0 = jnp.where(f0 != 0, 0.0, jnp.full((s_pad, n_pad), INF))
        else:
            dist0 = jnp.where(f0 != 0, 0,
                              jnp.full((s_pad, n_pad), UNREACHED))
            # pad rows/cols are born "visited" — same trick as the engine
            dist0 = jnp.where(
                row_ok & (jnp.arange(n_pad)[None, :] < n_real), dist0, 0)
        if counting:
            sigma0 = jnp.where(f0 != 0, 1.0, 0.0).astype(jnp.float32)
        else:
            # inert row-sharded dummy so the shard_map arity stays fixed
            sigma0 = jnp.zeros((s_pad, 1), jnp.float32)
        return sharded(dense_op, src_l, dst_l, w_l, w_min, deg, f0, dist0,
                       sigma0, steps)

    return runner


# --------------------------------------------------------------------------
# public entry point
# --------------------------------------------------------------------------

def sharded_apsp(g: Union[CSRGraph, ShardedOperands],
                 sources: Optional[Sequence[int]] = None, *,
                 mesh: Optional[Mesh] = None, weights=None,
                 config: Optional[ShardedConfig] = None
                 ) -> ShardedApspResult:
    """Multi-device batched APSP through the semiring sweep layer.

    Pass a :class:`ShardedOperands` (from :func:`prepare_sharded`) to
    reuse device-resident operands across calls; otherwise a
    :class:`CSRGraph` plus ``mesh`` (and ``weights`` for the tropical
    semiring).  Sources are padded up to the data-parallel extent and
    distances/sweep counts come back bit-identical to the single-device
    ``apsp_engine`` / ``weighted_apsp``.
    """
    if isinstance(g, ShardedOperands):
        if mesh is not None or weights is not None or config is not None:
            raise ValueError(
                "sharded_apsp: mesh=/weights=/config= are baked into the "
                "prepared ShardedOperands — passing them alongside would "
                "be silently ignored; call prepare_sharded again instead")
        ops = g
    else:
        if mesh is None:
            raise ValueError("sharded_apsp needs mesh= (or prepared "
                             "ShardedOperands)")
        ops = prepare_sharded(g, mesh, weights=weights,
                              config=config or ShardedConfig())
    graph, cfg = ops.graph, ops.config
    n = graph.n_nodes
    srcs = np.arange(n, dtype=np.int32) if sources is None else \
        np.asarray(sources, np.int32)
    if srcs.size == 0:
        raise ValueError("sharded_apsp: empty source list")
    if srcs.min() < 0 or srcs.max() >= n:
        raise ValueError(
            f"sharded_apsp: sources must be in [0, {n}), got "
            f"[{srcs.min()}, {srcs.max()}]")
    D = dp_extent(ops.mesh)
    # every dp shard gets the same multiple-of-8 (kernel-tileable) row
    # count; above one source tile the local rows must tile by 128
    s_pad = _round_up(len(srcs), D * 8)
    if s_pad // D > 128:
        s_pad = _round_up(s_pad, D * 128)
    padded = np.zeros(s_pad, np.int32)
    padded[: len(srcs)] = srcs

    use_kernel, interpret = _resolve_kernel(cfg)
    runner = _make_runner(ops.mesh, cfg, ops.n_pad, n, ops.m_local,
                          use_kernel, interpret, ops.n_shards)
    dist, sigma, step, dir_counts, edges = runner(
        ops.dense_op, ops.src_l, ops.dst_l, ops.w_l, ops.w_min, ops.deg,
        jnp.asarray(padded), jnp.int32(len(srcs)),
        jnp.int32(cfg.max_sweeps or n))
    return ShardedApspResult(dist=dist[: len(srcs), :n], sweeps=step,
                             direction_counts=dir_counts,
                             sigma=sigma[: len(srcs), :n]
                             if cfg.counting else None,
                             edges_touched=edges)
