"""nemotron-4-15b — dense LM, GQA kv=8, squared-ReLU MLP.
[arXiv:2402.16819; unverified]  32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000."""
from ..models.transformer import LMConfig

CONFIG = LMConfig(
    name="nemotron-4-15b", n_layers=32, d_model=6144, n_heads=48, n_kv=8,
    d_head=128, d_ff=24576, vocab=256000, act="relu2")
