"""DAWN core — matrix-operation shortest paths (the paper's contribution)."""
from .frontier import (UNREACHED, pack_bits, unpack_bits, popcount,
                       one_hot_frontier, packed_width)
from .sweep import (Semiring, BOOLEAN, TROPICAL, MIN_LABEL, COUNTING,
                    SEMIRINGS, SweepState, make_state, sweep_loop,
                    boolean_forms, tropical_forms, minlabel_form,
                    counting_forms, minplus_candidates,
                    derive_parents, time_sweep_forms, PUSH, PULL, SPARSE,
                    DIRECTION_NAMES)
from .bovm import bovm_sweep, bovm_msbfs, bovm_sssp, DawnState
from .sovm import sovm_sweep, sovm_sssp, sovm_msbfs, SovmState, reconstruct_path
from .bfs import bfs_queue_numpy, bfs_scipy, bfs_level_sync_jax
from .sssp import sssp, multi_source, apsp, apsp_dense, SsspResult
from .wcc import wcc, wcc_stats, WccResult
from .distributed import (ShardedConfig, ShardedOperands, ShardedApspResult,
                          prepare_sharded, sharded_apsp,
                          SHARDED_FORM_NAMES)
from .weighted import (minplus_sssp, bucketed_sssp, expand_integer_weights,
                       dijkstra_oracle, WeightedResult, weighted_apsp,
                       WeightedApspResult, WeightedConfig,
                       PreparedWeightedGraph, prepare_weighted,
                       measure_weighted_costs, WEIGHTED_FORM_NAMES)
from .centrality import (CentralityConfig, CentralityResult, CountingResult,
                         COUNTING_FORM_NAMES, MEASURES, betweenness,
                         brandes_dependencies, centrality, closeness,
                         counting_apsp, counting_apsp_blocks, eccentricity,
                         eccentricity_sample, harmonic,
                         measure_counting_costs)
from .engine import (EngineConfig, SweepStats, ApspResult, PreparedGraph,
                     prepare_graph, frontier_stats, sweep_costs,
                     choose_direction, measure_sweep_costs, apsp_engine,
                     apsp_engine_blocks)
from .jobs import (JobMismatchError, JobResult, WORKLOADS, run_sweep_job)
from .autotune import (BackendProfile, GraphStats, TuningPlan,
                       backend_profile, build_plan, device_fingerprint,
                       tune_tiles)

__all__ = [
    "UNREACHED", "pack_bits", "unpack_bits", "popcount", "one_hot_frontier",
    "packed_width",
    "Semiring", "BOOLEAN", "TROPICAL", "MIN_LABEL", "COUNTING", "SEMIRINGS",
    "SweepState", "make_state", "sweep_loop", "boolean_forms",
    "tropical_forms", "minlabel_form", "counting_forms", "derive_parents",
    "time_sweep_forms",
    "bovm_sweep", "bovm_msbfs", "bovm_sssp", "DawnState",
    "sovm_sweep", "sovm_sssp", "sovm_msbfs", "SovmState", "reconstruct_path",
    "bfs_queue_numpy", "bfs_scipy", "bfs_level_sync_jax",
    "sssp", "multi_source", "apsp", "apsp_dense", "SsspResult",
    "wcc", "wcc_stats", "WccResult",
    "ShardedConfig", "ShardedOperands", "ShardedApspResult",
    "prepare_sharded", "sharded_apsp", "SHARDED_FORM_NAMES",
    "minplus_candidates",
    "minplus_sssp", "bucketed_sssp", "expand_integer_weights",
    "dijkstra_oracle", "WeightedResult", "weighted_apsp",
    "WeightedApspResult", "WeightedConfig", "PreparedWeightedGraph",
    "prepare_weighted", "measure_weighted_costs", "WEIGHTED_FORM_NAMES",
    "CentralityConfig", "CentralityResult", "CountingResult",
    "COUNTING_FORM_NAMES", "MEASURES", "betweenness", "brandes_dependencies",
    "centrality", "counting_apsp", "counting_apsp_blocks", "eccentricity",
    "measure_counting_costs",
    "closeness", "harmonic", "eccentricity_sample",
    "PUSH", "PULL", "SPARSE", "DIRECTION_NAMES", "EngineConfig",
    "SweepStats", "ApspResult", "PreparedGraph", "prepare_graph",
    "frontier_stats", "sweep_costs", "choose_direction",
    "measure_sweep_costs", "apsp_engine", "apsp_engine_blocks",
    "JobMismatchError", "JobResult", "WORKLOADS", "run_sweep_job",
    "BackendProfile", "GraphStats", "TuningPlan", "backend_profile",
    "build_plan", "device_fingerprint", "tune_tiles",
]

# --- deprecated caller-facing entry points --------------------------------
# The per-semiring functions remain the internal engines (submodule imports
# are unwrapped), but external callers should go through the unified facade
# (repro.prepare).  Each wrapper warns exactly once per process.
import functools as _functools
import warnings as _warnings

from .options import SweepOptions  # noqa: F401  (facade config base)

__all__.append("SweepOptions")


def _deprecated_entry_point(fn, replacement):
    warned = []

    @_functools.wraps(fn)
    def wrapper(*args, **kwargs):
        if not warned:
            warned.append(True)
            _warnings.warn(
                f"repro.core.{fn.__name__} is deprecated as a public entry "
                f"point; use {replacement} (the unified dawn facade)",
                DeprecationWarning, stacklevel=2)
        return fn(*args, **kwargs)

    wrapper.__wrapped__ = fn
    return wrapper


apsp_engine = _deprecated_entry_point(
    apsp_engine, "repro.prepare(g).apsp()")
weighted_apsp = _deprecated_entry_point(
    weighted_apsp, "repro.prepare(g, weights=...).apsp(semiring='tropical')")
counting_apsp = _deprecated_entry_point(
    counting_apsp, "repro.prepare(g).apsp(semiring='counting')")
sharded_apsp = _deprecated_entry_point(
    sharded_apsp, "repro.prepare(g).apsp(mesh=...)")
