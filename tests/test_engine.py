"""Direction-optimizing batched APSP engine: correctness of all sweep
forms, the switch heuristic, graph stats, and the serving integration."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (EngineConfig, apsp_engine, bfs_queue_numpy,
                        choose_direction, frontier_stats,
                        measure_sweep_costs, prepare_graph, sweep_costs,
                        PUSH, PULL, SPARSE, UNREACHED)
from repro.graph import generators as gen


def _ref_dists(g, sources):
    return np.stack([bfs_queue_numpy(g, int(s)) for s in sources])


GRAPHS = {
    "er": lambda seed: gen.erdos_renyi(200, 4.0, directed=False, seed=seed),
    "er_directed": lambda seed: gen.erdos_renyi(160, 3.0, seed=seed),
    "ws": lambda seed: gen.watts_strogatz(150, 6, 0.1, seed=seed),
    "grid": lambda seed: gen.grid2d(12, 12),
    "mycielskian": lambda seed: gen.mycielskian(7),
    "disconnected": lambda seed: gen.disconnected(6, 20, 3.0, seed=seed),
}


@pytest.mark.parametrize("family", sorted(GRAPHS))
@pytest.mark.parametrize("seed", [0, 1])
def test_auto_apsp_matches_queue_bfs(family, seed):
    """Property: auto-switch APSP distances equal queue-BFS on random
    graphs across every generator family (the sweep_ref/packed_pull_ref
    oracles are themselves validated against these in test_kernels)."""
    g = GRAPHS[family](seed)
    sources = np.arange(min(24, g.n_nodes), dtype=np.int32)
    res = apsp_engine(g, sources, config=EngineConfig(source_batch=24))
    np.testing.assert_array_equal(np.asarray(res.dist),
                                  _ref_dists(g, sources))
    # counts sum over all source tiles; sweeps is the per-tile max
    assert int(res.direction_counts.sum()) >= int(res.sweeps) > 0


@pytest.mark.parametrize("mode", ["push", "pull", "sparse"])
def test_fixed_modes_agree(mode):
    g = gen.erdos_renyi(180, 5.0, directed=False, seed=7)
    sources = np.arange(16, dtype=np.int32)
    res = apsp_engine(g, sources,
                      config=EngineConfig(mode=mode, source_batch=16))
    np.testing.assert_array_equal(np.asarray(res.dist),
                                  _ref_dists(g, sources))
    # the pinned direction is the only one that ran
    counts = np.asarray(res.direction_counts)
    idx = ["push", "pull", "sparse"].index(mode)
    assert counts[idx] == counts.sum() > 0


def test_dynamic_per_sweep_switching_is_exact():
    """The lax.switch path (per-sweep heuristic, kernel regime) must give
    identical distances to the calibrated-static path."""
    g = gen.watts_strogatz(140, 6, 0.08, seed=5)
    sources = np.arange(16, dtype=np.int32)
    dyn = apsp_engine(g, sources, config=EngineConfig(source_batch=16,
                                                      dynamic=True))
    np.testing.assert_array_equal(np.asarray(dyn.dist),
                                  _ref_dists(g, sources))


def test_kernel_path_matches_ref():
    """Engine driving the Pallas kernels (interpret=True on CPU)."""
    g = gen.erdos_renyi(100, 4.0, directed=False, seed=3)
    sources = np.arange(8, dtype=np.int32)
    ref = _ref_dists(g, sources)
    for mode in ("push", "pull"):
        res = apsp_engine(g, sources,
                          config=EngineConfig(mode=mode, source_batch=8,
                                              use_kernel=True))
        np.testing.assert_array_equal(np.asarray(res.dist), ref)


def test_source_tiling_and_padding():
    """Sources that don't fill a tile, and more sources than one tile."""
    g = gen.erdos_renyi(150, 4.0, directed=False, seed=11)
    sources = np.arange(37, dtype=np.int32)          # 37 = 2 tiles of 24
    res = apsp_engine(g, sources, config=EngineConfig(source_batch=24))
    assert res.dist.shape == (37, g.n_nodes)
    np.testing.assert_array_equal(np.asarray(res.dist),
                                  _ref_dists(g, sources))


# -- the direction heuristic ------------------------------------------------

def _stats_for(frontier, dist):
    return frontier_stats(jnp.asarray(frontier), jnp.asarray(dist),
                          bs=64, bn=128, bk=128)


def test_heuristic_pull_on_dense_late_frontier():
    """Late-stage dense frontier: every push tile is live, so the packed
    pull sweep (32 nodes/word) is modelled ~4x cheaper."""
    s, n_pad, m_pad = 64, 1024, 65536
    cfg = EngineConfig()
    frontier = np.ones((s, n_pad), np.int8)
    dist = np.full((s, n_pad), int(UNREACHED), np.int32)
    stats = _stats_for(frontier, dist)
    assert float(stats.live_tile_frac) == 1.0
    assert int(choose_direction(stats, n_pad=n_pad, s=s, m_pad=m_pad,
                                cfg=cfg)) == PULL


def test_heuristic_push_on_sparse_early_frontier():
    """Early one-hot frontier: 1/8 of push tiles live -> push is cheapest
    on a dense graph (sparse is priced out by the big edge count)."""
    s, n_pad, m_pad = 64, 1024, 65536
    cfg = EngineConfig()
    frontier = np.zeros((s, n_pad), np.int8)
    frontier[np.arange(s), np.arange(s)] = 1      # all in k-block 0
    dist = np.full((s, n_pad), int(UNREACHED), np.int32)
    stats = _stats_for(frontier, dist)
    assert float(stats.live_tile_frac) == pytest.approx(1 / 8)
    assert int(choose_direction(stats, n_pad=n_pad, s=s, m_pad=m_pad,
                                cfg=cfg)) == PUSH


def test_heuristic_sparse_on_sparse_graph():
    """Few edges: the edge-parallel SOVM sweep undercuts both dense forms
    regardless of occupancy."""
    s, n_pad, m_pad = 64, 1024, 4096
    cfg = EngineConfig()
    frontier = np.ones((s, n_pad), np.int8)
    dist = np.full((s, n_pad), int(UNREACHED), np.int32)
    stats = _stats_for(frontier, dist)
    costs = np.asarray(sweep_costs(stats, n_pad=n_pad, s=s, m_pad=m_pad,
                                   cfg=cfg))
    assert costs.shape == (3,)
    assert int(np.argmin(costs)) == SPARSE


def test_calibration_measures_and_caches():
    g = gen.erdos_renyi(150, 4.0, directed=False, seed=2)
    pg = prepare_graph(g)
    cfg = EngineConfig(source_batch=16)
    costs = measure_sweep_costs(pg, 16, cfg)
    assert len(costs) == 3 and all(c > 0 for c in costs)
    assert measure_sweep_costs(pg, 16, cfg) is costs  # cached


# -- graph stats feeding the engine -----------------------------------------

def test_degree_stats_and_padding():
    g = gen.grid2d(8, 8)                       # n = 64
    st = g.degree_stats()
    assert st.n_nodes == 64
    assert st.max_out_degree == 4
    assert 0 < st.density < 1
    # sentinel must index a dead column: n_padded > n_nodes always
    assert g.n_padded() >= g.n_nodes + 1
    assert g.n_padded() % 128 == 0


def test_to_pull_packed_roundtrip():
    from repro.core import unpack_bits
    g = gen.erdos_renyi(100, 3.0, seed=4)
    n_pad = g.n_padded()
    packed = g.to_pull_packed(n_pad)
    assert packed.shape == (n_pad, n_pad // 32)
    dense = np.asarray(g.to_dense_padded(n_pad))
    got = np.asarray(unpack_bits(packed, n_pad))
    np.testing.assert_array_equal(got, dense.T != 0)


# -- serving integration ----------------------------------------------------

def test_graph_queries_served_alongside_decode():
    import jax
    from repro._attic.models import transformer as T
    from repro._attic.lm_serving import Request, ServingEngine
    from repro.serve import GraphQuery, GraphService
    cfg = T.LMConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                     d_head=16, d_ff=64, vocab=64)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    g = gen.watts_strogatz(128, 6, 0.1, seed=1)
    eng = ServingEngine(params, cfg, slots=1, max_len=32,
                        graph_service=GraphService(g, max_batch=8))
    eng.submit(Request(rid=0, prompt=np.array([1, 2], np.int32), max_new=2))
    for i in range(11):   # 11 queries > one 8-wide micro-batch
        eng.submit_graph(GraphQuery(qid=i, source=i,
                                    target=None if i % 2 else 100))
    eng.run_to_completion()
    done = eng.graph_service.completed
    assert len(done) == 11 and len(eng.completed) == 1
    for q in done:
        ref = bfs_queue_numpy(g, q.source)
        if q.target is None:
            np.testing.assert_array_equal(q.dist, ref)
        else:
            assert q.hops == int(ref[q.target])
        assert q.t_done >= q.t_submit


def test_graph_service_standalone_flush():
    from repro.serve import GraphQuery, GraphService
    g = gen.grid2d(10, 10)
    svc = GraphService(g, max_batch=8)
    for i in range(5):
        svc.submit(GraphQuery(qid=i, source=i * 3, target=99))
    served = svc.flush()
    assert len(served) == 5 and svc.pending() == 0
    for q in served:
        assert q.hops == int(bfs_queue_numpy(g, q.source)[99])
