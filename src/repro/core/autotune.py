"""HLO/roofline-driven kernel autotuner (ROADMAP item 2b).

Two knobs dominate a sweep's cost and were, until this module, pinned by
hand: the kernel tile shapes validated against the ~16 MB/core VMEM table
in ``kernels/common.py``, and the per-sweep push/pull/sparse switch —
dynamic occupancy cost model on the kernel path, *wall-clock calibration*
(``sweep.time_sweep_forms``) on the reference path.  The calibration is
the one non-deterministic decision in the engine: two identical
``mode="auto"`` runs could race to different pinned directions and
therefore different ``direction_counts``.

:func:`build_plan` replaces both with a static roofline model:

  * a :class:`BackendProfile` supplies peak FLOP/s, HBM bandwidth and the
    per-core VMEM budget (a table keyed on ``jax.default_backend()``,
    seeded from ``launch/mesh.py``'s TPU v5e constants);
  * per-(semiring, form) *unit costs* — seconds per modelled work unit —
    come from either the jitted sweep HLO (``launch/hlo_analysis.analyze``
    counts exact FLOPs/bytes, ``launch/roofline.roofline_terms`` converts
    them to a roofline-bound time; deterministic, unlike a timer) or, when
    lowering is unavailable, a static fallback that reproduces the
    engines' historical cost-constant ratios;
  * :func:`tune_tiles` picks the largest MXU-aligned ``bn``/``bk`` that
    every registered KernelSet fits inside the budget, and gates
    ``fused_steps`` on whole-operand residency.

The result is a frozen, hashable, JSON-serializable :class:`TuningPlan`.
Threading: ``SweepOptions.tuning`` carries the plan into every engine
config; each engine calls :func:`apply` (tile/constant overlay, clamped
to the current graph's padding) and consults
:meth:`TuningPlan.pinned_direction` where it used to wall-clock-calibrate
— ``mode="auto"`` becomes a pure function of (plan, graph shape, batch),
so ``direction_counts`` are finally assertable under auto.  Precedence:
an explicit ``mode=`` pin beats the plan, the plan beats calibration.

Import discipline: this module sits *below* the engines (imports
options/sweep/kernels/launch only); ``engine``/``weighted``/
``centrality``/``distributed`` import it, passing their semiring by name.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import common as kernel_common
from ..kernels import registry as kernel_registry
from ..launch.hlo_analysis import analyze_jitted
from ..launch.mesh import HBM_BW, PEAK_FLOPS_BF16
from ..launch.roofline import roofline_terms
from .frontier import UNREACHED
from . import sweep as S
from .options import SweepOptions

__all__ = ["BackendProfile", "GraphStats", "TuningPlan", "FORM_VOCAB",
           "backend_profile", "device_fingerprint", "graph_stats",
           "form_units", "tune_tiles", "build_plan", "apply"]

PLAN_VERSION = 1

# the forms each semiring's engine dispatches, in that engine's direction
# indexing (boolean == sweep.DIRECTION_NAMES, tropical ==
# weighted.WEIGHTED_FORM_NAMES, counting == centrality.COUNTING_FORM_NAMES)
FORM_VOCAB: Dict[str, Tuple[str, ...]] = {
    "boolean": ("push", "pull", "sparse"),
    "tropical": ("dense", "sparse"),
    "counting": ("push", "sparse"),
}

# engine-config cost-constant field per form name
_COST_FIELDS = {"push": "c_push", "pull": "c_pull", "sparse": "c_sparse",
                "dense": "c_dense"}

# static fallback ratio of each form's per-unit cost to the GEMM form's
# (the engines' historical c_* defaults: dense MAC 1, word/lane 8)
_STATIC_RATIO = {"push": 1.0, "dense": 1.0, "pull": 8.0, "sparse": 8.0}


# --------------------------------------------------------------------------
# backend profiles
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendProfile:
    """Roofline constants for one device class.

    ``name`` is the device fingerprint the plan is locked to;
    ``vmem_budget`` is the per-core fast-memory budget tile plans must
    fit (VMEM on TPU; reused as a residency bound elsewhere).
    """
    name: str
    peak_flops: float
    hbm_bw: float
    vmem_budget: int


# Static table keyed on jax.default_backend().  The TPU row is the
# launch/mesh.py v5e roofline; cpu/gpu rows are order-of-magnitude
# placeholders — they only need to *rank* forms sanely, and the VMEM
# budget still bounds interpret-mode tile choices.
STATIC_PROFILES: Dict[str, BackendProfile] = {
    "tpu": BackendProfile("tpu", PEAK_FLOPS_BF16, HBM_BW,
                          kernel_common.VMEM_BUDGET_BYTES),
    "gpu": BackendProfile("gpu", 1.0e14, 1.0e12,
                          kernel_common.VMEM_BUDGET_BYTES),
    "cpu": BackendProfile("cpu", 2.0e11, 5.0e10,
                          kernel_common.VMEM_BUDGET_BYTES),
}


def device_fingerprint() -> str:
    """``backend:device_kind`` of the default device — the identity a
    saved plan refuses to load across (tile/threshold choices do not
    transfer between device classes)."""
    dev = jax.devices()[0]
    return f"{jax.default_backend()}:{getattr(dev, 'device_kind', '?')}"


def backend_profile(fingerprint: Optional[str] = None) -> BackendProfile:
    """Profile for ``fingerprint`` (default: the current device), from
    the static table keyed on its backend prefix."""
    fp = fingerprint or device_fingerprint()
    base = STATIC_PROFILES.get(fp.split(":", 1)[0], STATIC_PROFILES["cpu"])
    return dataclasses.replace(base, name=fp)


# --------------------------------------------------------------------------
# graph statistics (the tuner's view of a graph)
# --------------------------------------------------------------------------

class GraphStats(NamedTuple):
    """Shape/occupancy summary a plan records as provenance."""
    n_nodes: int
    n_edges: int
    n_pad: int
    m_pad: int
    avg_degree: float
    max_degree: int


def graph_stats(g) -> GraphStats:
    """Stats for a ``CSRGraph`` / ``DynamicCSRGraph`` / prepared handle
    (anything with ``.graph`` or the CSR surface itself)."""
    pg_n_pad = getattr(g, "n_pad", None)
    graph = getattr(g, "graph", g)
    if hasattr(graph, "view"):               # DynamicCSRGraph duck-type
        graph = graph.view()
    n_pad = pg_n_pad if pg_n_pad is not None else graph.n_padded(128)
    deg = np.asarray(graph.out_degrees())
    return GraphStats(
        n_nodes=int(graph.n_nodes), n_edges=int(graph.n_edges),
        n_pad=int(n_pad), m_pad=int(graph.m_pad),
        avg_degree=float(graph.n_edges / max(graph.n_nodes, 1)),
        max_degree=int(deg.max()) if deg.size else 0)


def form_units(form: str, *, s: int, n_pad: int, m_pad: int) -> float:
    """Modelled work units of one sweep in ``form`` — the same counts the
    engines' dynamic cost model uses (engine.sweep_costs), evaluated at
    full occupancy: dense GEMM elements for push/dense, uint32 words for
    pull, padded CSR lanes for sparse."""
    if form in ("push", "dense"):
        return float(s) * n_pad * n_pad
    if form == "pull":
        return float(s) * n_pad * max(n_pad // 32, 1)
    if form == "sparse":
        return float(s) * m_pad
    raise ValueError(f"unknown form {form!r}")


# --------------------------------------------------------------------------
# the plan
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TuningPlan:
    """Serializable tuner output: tile sizes, the fused-steps gate, and
    per-(semiring, form) switch costs.  Frozen and hashable — it rides
    inside the engines' jit-static configs.

    ``unit_costs`` is ``((semiring, form, seconds_per_unit), ...)``;
    :meth:`pinned_direction` turns it into the deterministic replacement
    for wall-clock calibration.  ``source`` records whether the costs
    came from HLO analysis ("hlo") or the static fallback ("static").
    """
    backend: str                  # device fingerprint the plan is locked to
    vmem_budget: int              # bytes; budget the tiles were fit against
    peak_flops: float
    hbm_bw: float
    bs: int                       # source tile (informational; engines cap
                                  # at min(batch, 128) as always)
    bn: int                       # output-column tile
    bk: int                       # contraction tile
    fused_steps: int              # -1 = fuse whole fixpoint, 0 = leave off
    unit_costs: Tuple[Tuple[str, str, float], ...]
    graph: GraphStats             # provenance: the graph it was built on
    source: str = "static"        # "hlo" | "static"
    version: int = PLAN_VERSION

    # -- cost queries ------------------------------------------------------

    def unit_cost(self, semiring: str, form: str) -> Optional[float]:
        for sr, f, c in self.unit_costs:
            if sr == semiring and f == form:
                return c
        return None

    def covers(self, semiring: str) -> bool:
        """True when every form the semiring dispatches has a cost."""
        return all(self.unit_cost(semiring, f) is not None
                   for f in FORM_VOCAB.get(semiring, ()))

    def pinned_direction(self, semiring: str, *, s: int, n_pad: int,
                         m_pad: int) -> Optional[int]:
        """argmin form index for a whole batch — the deterministic
        replacement for the calibrated (wall-clock) regime.  Index is in
        the semiring engine's own direction order (FORM_VOCAB).  Returns
        None when the plan lacks a cost for some form."""
        vocab = FORM_VOCAB.get(semiring)
        if not vocab or not self.covers(semiring):
            return None
        costs = [self.unit_cost(semiring, f)
                 * form_units(f, s=s, n_pad=n_pad, m_pad=m_pad)
                 for f in vocab]
        return int(np.argmin(costs))

    # -- budget validation -------------------------------------------------

    def validate(self, n_pad: Optional[int] = None) -> None:
        """Assert every registered KernelSet fits the plan's tiles inside
        ``vmem_budget`` at ``n_pad`` (default: the build graph's).
        Raises ValueError on the first oversized (semiring, form)."""
        n = self.graph.n_pad if n_pad is None else n_pad
        bn = self.bn if n % self.bn == 0 else kernel_common.MXU_ALIGN
        bk = self.bk if n % self.bk == 0 else kernel_common.MXU_ALIGN
        for semiring in sorted(kernel_registry.available()):
            ks = kernel_registry.get(semiring)
            forms = list(ks.forms)
            if self.fused_steps:
                forms += [f"fused:{f}" for f in ks.fused_forms]
            for name in forms:
                form = name.split(":")[-1] if ":" in name else name
                kind = "fused" if name.startswith("fused:") else form
                need = ks.vmem_bytes(form=kind, bs=self.bs, bn=bn, bk=bk,
                                     n=n, n_pad=n)
                if need > self.vmem_budget:
                    raise ValueError(
                        f"TuningPlan tiles (bs={self.bs}, bn={bn}, "
                        f"bk={bk}) blow the VMEM budget for "
                        f"{semiring}/{name} at n_pad={n}: {need} > "
                        f"{self.vmem_budget} bytes")

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["graph"] = list(self.graph)
        d["unit_costs"] = [list(uc) for uc in self.unit_costs]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TuningPlan":
        d = dict(d)
        version = int(d.get("version", 0))
        if version != PLAN_VERSION:
            raise ValueError(
                f"TuningPlan version {version} != {PLAN_VERSION}")
        d["graph"] = GraphStats(*d["graph"])
        d["unit_costs"] = tuple(
            (str(sr), str(f), float(c)) for sr, f, c in d["unit_costs"])
        return cls(**d)

    def checksum(self) -> str:
        """Stable content hash (the bench gate's hard field)."""
        payload = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]

    def save(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path, *, allow_mismatch: bool = False) -> "TuningPlan":
        """Load a saved plan; refuses a plan built for a different device
        fingerprint unless ``allow_mismatch=True`` (tile and threshold
        choices do not transfer across device classes)."""
        with open(path) as f:
            plan = cls.from_dict(json.load(f))
        here = device_fingerprint()
        if not allow_mismatch and plan.backend != here:
            raise ValueError(
                f"TuningPlan backend fingerprint {plan.backend!r} does "
                f"not match this device ({here!r}); pass "
                f"allow_mismatch=True to override")
        return plan


# --------------------------------------------------------------------------
# tile tuning (the VMEM-budget fit replacing the hard-coded table)
# --------------------------------------------------------------------------

def _tiles_fit(bs: int, bn: int, bk: int, n_pad: int, budget: int) -> bool:
    for semiring in kernel_registry.available():
        ks = kernel_registry.get(semiring)
        for form in ks.forms:
            if ks.vmem_bytes(form=form, bs=bs, bn=bn, bk=bk, n=n_pad,
                             n_pad=n_pad) > budget:
                return False
    return True


def _fused_fits(bs: int, n_pad: int, budget: int) -> bool:
    for semiring in kernel_registry.available():
        ks = kernel_registry.get(semiring)
        if ks.fused_forms and ks.vmem_bytes(
                form="fused", bs=bs, n=n_pad, n_pad=n_pad) > budget:
            return False
    return True


def tune_tiles(profile: BackendProfile, *, n_pad: int
               ) -> Tuple[int, int, int, int]:
    """(bs, bn, bk, fused_steps) for ``n_pad`` under the profile's
    budget: the largest MXU-aligned divisor tiles every registered
    KernelSet fits, and ``fused_steps=-1`` iff every fused form's
    whole-operand residency fits too (else 0 — the per-sweep grids)."""
    bs = kernel_common.MXU_ALIGN
    best = (kernel_common.MXU_ALIGN, kernel_common.MXU_ALIGN)
    cands = kernel_common.tile_candidates(n_pad)
    for bn in cands:
        for bk in cands:
            if bn * bk > best[0] * best[1] and \
                    _tiles_fit(bs, bn, bk, n_pad, profile.vmem_budget):
                best = (bn, bk)
    fused = -1 if _fused_fits(bs, n_pad, profile.vmem_budget) else 0
    return bs, best[0], best[1], fused


# --------------------------------------------------------------------------
# unit-cost extraction
# --------------------------------------------------------------------------

def _static_unit_costs(profile: BackendProfile
                       ) -> Tuple[Tuple[str, str, float], ...]:
    """Fallback costs: the engines' historical cost-constant ratios
    converted to seconds-per-unit on this profile (2 flops per MAC) —
    deterministic and rank-preserving with the old defaults."""
    mac = 2.0 / profile.peak_flops
    return tuple((sr, f, _STATIC_RATIO[f] * mac)
                 for sr in sorted(FORM_VOCAB)
                 for f in FORM_VOCAB[sr])


def _representative_state(s: int, n_pad: int, dtype, unreached, visited_val):
    """The same mid-sweep occupancy measure_sweep_costs uses: ~6%
    frontier, ~25% visited."""
    f = np.zeros((s, n_pad), np.int8)
    f[:, ::17] = 1
    dist = np.full((s, n_pad), unreached, dtype)
    dist[:, ::4] = visited_val
    return jnp.asarray(f), jnp.asarray(dist)


def _form_seconds(form, frontier, state, profile: BackendProfile
                  ) -> Optional[float]:
    """Roofline-bound seconds of one jitted sweep of ``form``, from exact
    HLO flop/byte counts — None when lowering/analysis fails (the caller
    keeps the static cost)."""
    parent = jnp.zeros((1,), jnp.int32)
    try:
        stats = analyze_jitted(
            lambda fr, st, p: form(fr, st, p, jnp.int32(1)),
            frontier, state, parent)
    except Exception:
        return None
    if stats.flops <= 0 and stats.bytes_accessed <= 0:
        return None
    terms = roofline_terms(stats.flops, stats.bytes_accessed,
                           peak_flops=profile.peak_flops,
                           hbm_bw=profile.hbm_bw)
    return max(terms["t_compute_s"], terms["t_memory_s"], 1e-12)


def _hlo_unit_costs(pg, profile: BackendProfile, *, weights, s: int
                    ) -> Dict[Tuple[str, str], float]:
    """Per-(semiring, form) seconds-per-unit from the lowered XLA
    reference sweeps at a representative state.  Tropical forms are
    priced only when ``weights`` are given."""
    g = pg.graph
    n_pad = pg.n_pad
    units = {f: form_units(f, s=s, n_pad=n_pad, m_pad=g.m_pad)
             for forms in FORM_VOCAB.values() for f in forms}
    out: Dict[Tuple[str, str], float] = {}

    f0, dist = _representative_state(s, n_pad, np.int32, int(UNREACHED), 1)
    bool_forms = S.boolean_forms(pg.adj, pg.adj_pull, g.src, g.dst,
                                 n_pad=n_pad, s=s)
    for name, form in zip(FORM_VOCAB["boolean"], bool_forms):
        t = _form_seconds(form, f0, dist, profile)
        if t is not None:
            out[("boolean", name)] = t / units[name]

    sigma = (np.asarray(dist) >= 0).astype(np.float32)
    cnt_forms = S.counting_forms(pg.adj, g.src, g.dst, n_pad=n_pad, s=s)
    for name, form in zip(FORM_VOCAB["counting"], cnt_forms):
        t = _form_seconds(form, f0, (dist, jnp.asarray(sigma)), profile)
        if t is not None:
            out[("counting", name)] = t / units[name]

    if weights is not None:
        w = np.asarray(weights, np.float32)
        lanes = np.full(g.m_pad, np.inf, np.float32)
        lanes[: g.n_edges] = w[: g.n_edges]
        wdense = jnp.full((n_pad, n_pad), jnp.inf,
                          jnp.float32).at[g.src, g.dst].min(
                              jnp.asarray(lanes))
        fw, dw = _representative_state(s, n_pad, np.float32, np.inf, 1.0)
        trop_forms = S.tropical_forms(wdense, g.src, g.dst,
                                      jnp.asarray(lanes), n_pad=n_pad)
        for name, form in zip(FORM_VOCAB["tropical"], trop_forms):
            t = _form_seconds(form, fw, dw, profile)
            if t is not None:
                out[("tropical", name)] = t / units[name]
    return out


# --------------------------------------------------------------------------
# plan construction + config overlay
# --------------------------------------------------------------------------

def build_plan(g, *, weights=None, profile: Optional[BackendProfile] = None,
               source_batch: int = 8, use_hlo: bool = True) -> TuningPlan:
    """Build a :class:`TuningPlan` for graph ``g`` (CSRGraph /
    DynamicCSRGraph / PreparedGraph).

    ``use_hlo=True`` prices each reference sweep form from its compiled
    HLO (exact flop/byte counts → roofline time; deterministic), falling
    back per-form to the static table when lowering fails; ``False``
    skips lowering entirely — cheapest, fully static, still deterministic
    (the differential suite and the bench gate use this).  ``weights``
    enables tropical-form pricing on the HLO path.
    """
    prof = profile or backend_profile()
    stats = graph_stats(g)
    bs, bn, bk, fused = tune_tiles(prof, n_pad=stats.n_pad)
    costs = {(sr, f): c for sr, f, c in _static_unit_costs(prof)}
    source = "static"
    if use_hlo:
        from .engine import PreparedGraph, prepare_graph
        pg = g if isinstance(g, PreparedGraph) else prepare_graph(g)
        measured = _hlo_unit_costs(pg, prof, weights=weights,
                                   s=source_batch)
        if measured:
            costs.update(measured)
            source = "hlo"
    plan = TuningPlan(
        backend=prof.name, vmem_budget=prof.vmem_budget,
        peak_flops=prof.peak_flops, hbm_bw=prof.hbm_bw,
        bs=bs, bn=bn, bk=bk, fused_steps=fused,
        unit_costs=tuple((sr, f, costs[(sr, f)])
                         for sr in sorted(FORM_VOCAB)
                         for f in FORM_VOCAB[sr]),
        graph=stats, source=source)
    plan.validate()
    return plan


def _cost_overrides(plan: TuningPlan, semiring: str, fields) -> dict:
    """Normalized cost-constant overlays for an engine config: each
    form's per-unit cost relative to the GEMM form's (so the overlay has
    the same scale as the hand-set defaults).  The sharded executor names
    its GEMM form ``c_dense`` for every semiring — map push onto it when
    the target has no ``c_push``."""
    vocab = FORM_VOCAB[semiring]
    base = plan.unit_cost(semiring, vocab[0])
    if not base:
        return {}
    out = {}
    for form in vocab:
        c = plan.unit_cost(semiring, form)
        if c is None:
            continue
        fld = _COST_FIELDS[form]
        if fld not in fields and form == "push" and "c_dense" in fields:
            fld = "c_dense"
        if fld in fields:
            out[fld] = float(c / base)
    return out


def apply(cfg: SweepOptions, *, semiring: str,
          n_pad: Optional[int] = None) -> SweepOptions:
    """Overlay ``cfg.tuning`` onto an engine config: tile sizes (clamped
    back to MXU_ALIGN when they don't divide this graph's ``n_pad``),
    the fused-steps gate (only when the caller left ``fused_steps`` at
    its 0 default — an explicit request wins), and the dynamic cost
    model's constants.  A config with no plan passes through unchanged.
    """
    plan = cfg.tuning
    if plan is None or semiring not in FORM_VOCAB:
        return cfg
    fields = {f.name for f in dataclasses.fields(type(cfg))}
    kw = {}
    bn, bk = plan.bn, plan.bk
    if n_pad is not None:
        if n_pad % bn:
            bn = kernel_common.MXU_ALIGN
        if n_pad % bk:
            bk = kernel_common.MXU_ALIGN
    if "bn" in fields:
        kw["bn"] = bn
    if "bk" in fields:
        kw["bk"] = bk
    if "fused_steps" in fields and cfg.fused_steps == 0 and plan.fused_steps:
        kw["fused_steps"] = plan.fused_steps
    kw.update(_cost_overrides(plan, semiring, fields))
    return dataclasses.replace(cfg, **kw) if kw else cfg
