"""repro — DAWN (matrix-operation shortest paths) as a production JAX framework."""
__version__ = "1.0.0"
