"""Graph substrate: CSR invariants, generators, partitioners, sampler, IO."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # the seeded variant below always runs
    HAVE_HYPOTHESIS = False

from repro.graph.csr import CSRGraph, symmetrize
from repro.graph import generators as gen
from repro.graph.partition import block_dense, edge_partition
from repro.graph.sampler import sample_hop, sample_subgraph
from repro.graph.io import (load_edgelist, load_mtx, save_edgelist,
                            save_mtx)

from oracles import bfs_dist, dijkstra_dist


def _check_csr_roundtrip(n, m, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    g = CSRGraph.from_edges(src, dst, n)
    sp = g.to_scipy().toarray()
    ref = np.zeros((n, n), np.int8)
    ref[src, dst] = 1
    np.fill_diagonal(ref, 0)  # self-loops removed
    np.testing.assert_array_equal(sp != 0, ref != 0)
    # dense view agrees
    np.testing.assert_array_equal(np.asarray(g.to_dense()) != 0, ref != 0)
    # transpose view
    np.testing.assert_array_equal(
        np.asarray(g.reverse().to_dense()) != 0, ref.T != 0)
    # degrees
    np.testing.assert_array_equal(np.asarray(g.out_degrees()),
                                  (ref != 0).sum(1))
    np.testing.assert_array_equal(np.asarray(g.in_degrees()),
                                  (ref != 0).sum(0))


@pytest.mark.parametrize("seed", range(10))
def test_csr_roundtrip(seed):
    rng = np.random.default_rng(seed * 5003 + 3)
    _check_csr_roundtrip(int(rng.integers(2, 65)),
                         int(rng.integers(1, 257)),
                         int(rng.integers(0, 10**6)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(2, 64), m=st.integers(1, 256),
           seed=st.integers(0, 10**6))
    def test_csr_roundtrip_hypothesis(n, m, seed):
        _check_csr_roundtrip(n, m, seed)


def test_generators_basic_invariants():
    for name, make in gen.SUITE.items():
        g = make()
        assert g.n_nodes > 0 and g.n_edges > 0, name
        src, dst = g.edge_arrays_np()
        assert (src < g.n_nodes).all() and (dst < g.n_nodes).all(), name
        assert (src != dst).all(), name  # no self loops


def test_block_dense_reassembles():
    g = gen.rmat(7, 4, seed=3)
    tiles, nb = block_dense(g, 2, 2)
    n_pad = nb * 2
    dense = np.zeros((n_pad, n_pad), np.int8)
    t = np.asarray(tiles)
    for r in range(2):
        for c in range(2):
            dense[r * nb:(r + 1) * nb, c * nb:(c + 1) * nb] = t[r, c]
    ref = np.asarray(g.to_dense_padded(n_pad))
    np.testing.assert_array_equal(dense, ref)


def test_edge_partition_covers_all_edges():
    g = gen.erdos_renyi(100, 4.0, seed=5)
    parts = edge_partition(g, 4)
    n_local = parts["n_local"]
    got = set()
    src = np.asarray(parts["src"])
    dst = np.asarray(parts["dst"])
    for p in range(4):
        for s, d in zip(src[p], dst[p]):
            if s < g.n_nodes:
                got.add((int(s), int(d) + p * n_local))
    want = set(zip(*[x.tolist() for x in g.edge_arrays_np()]))
    assert got == want


def test_sampler_returns_true_neighbors():
    g = gen.watts_strogatz(128, 6, 0.1, seed=7)
    adj = np.asarray(g.to_dense()) != 0
    nodes = jnp.arange(16, dtype=jnp.int32)
    nbrs = np.asarray(sample_hop(g, nodes, jax.random.PRNGKey(0), 5))
    deg = np.asarray(g.out_degrees())
    for i, v in enumerate(np.asarray(nodes)):
        for u in nbrs[i]:
            if deg[v] > 0:
                assert adj[v, u], (v, u)
            else:
                assert u == v


def test_sample_subgraph_shapes():
    g = gen.rmat(8, 6, seed=9)
    seeds = jnp.arange(8, dtype=jnp.int32)
    layers = sample_subgraph(g, seeds, jax.random.PRNGKey(1), (4, 3))
    assert layers[0].shape == (8,)
    assert layers[1].shape == (32,)
    assert layers[2].shape == (96,)


def test_edgelist_io_roundtrip():
    g = gen.erdos_renyi(50, 3.0, seed=11)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "g.txt")
        save_edgelist(g, path)
        g2 = load_edgelist(path)
        assert g2.n_edges == g.n_edges
        np.testing.assert_array_equal(np.asarray(g2.to_dense()),
                                      np.asarray(g.to_dense()))


# -- vectorized loaders: round trips checked against the oracles -----------

def _check_weighted_io_roundtrip(n, avg_deg, seed):
    rng = np.random.default_rng(seed)
    m = max(1, int(n * avg_deg))
    g, w = CSRGraph.from_weighted_edges(rng.integers(0, n, m),
                                        rng.integers(0, n, m),
                                        rng.uniform(0.1, 5.0, m), n)
    with tempfile.TemporaryDirectory() as d:
        pe = os.path.join(d, "g.txt")
        save_edgelist(g, pe, weights=w)
        g2, w2 = load_edgelist(pe, weighted=True)
        assert g2.n_edges == g.n_edges
        np.testing.assert_allclose(dijkstra_dist(g2, w2, 0),
                                   dijkstra_dist(g, w, 0), rtol=1e-6)
        pm = os.path.join(d, "g.mtx")
        save_mtx(g, pm, weights=w)
        g3, w3 = load_mtx(pm, return_weights=True)
        np.testing.assert_array_equal(bfs_dist(g3, 0), bfs_dist(g, 0))
        np.testing.assert_allclose(dijkstra_dist(g3, w3, 0),
                                   dijkstra_dist(g, w, 0), rtol=1e-6)
        # values must be ignorable: the unweighted view of a real mtx
        g4 = load_mtx(pm)
        np.testing.assert_array_equal(np.asarray(g4.to_dense()),
                                      np.asarray(g.to_dense()))


@pytest.mark.parametrize("seed", range(6))
def test_weighted_io_roundtrip(seed):
    rng = np.random.default_rng(seed * 6007 + 5)
    _check_weighted_io_roundtrip(int(rng.integers(3, 61)), 3.0,
                                 int(rng.integers(0, 10**6)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(3, 60), seed=st.integers(0, 10**6))
    def test_weighted_io_roundtrip_hypothesis(n, seed):
        _check_weighted_io_roundtrip(n, 3.0, seed)


def test_mtx_pattern_roundtrip_and_symmetric_real():
    g = gen.erdos_renyi(40, 3.0, seed=13)
    with tempfile.TemporaryDirectory() as d:
        pm = os.path.join(d, "p.mtx")
        save_mtx(g, pm)
        g2 = load_mtx(pm)
        np.testing.assert_array_equal(np.asarray(g2.to_dense()),
                                      np.asarray(g.to_dense()))
        # symmetric real: one stored triangle expands to both directions
        ps = os.path.join(d, "s.mtx")
        with open(ps, "w") as f:
            f.write("%%MatrixMarket matrix coordinate real symmetric\n")
            f.write("4 4 3\n")
            f.write("2 1 0.5\n3 1 1.5\n4 2 2.5\n")
        gs, ws = load_mtx(ps, return_weights=True)
        assert gs.n_nodes == 4 and gs.n_edges == 6
        ref = dijkstra_dist(gs, ws, 0)
        np.testing.assert_allclose(ref[:3], [0.0, 0.5, 1.5])
        # pattern view of the same file: weights dropped, still symmetric
        gp = load_mtx(ps)
        assert gp.n_edges == 6


def test_from_weighted_edges_min_reduces_duplicates():
    src = np.array([0, 0, 1, 0])
    dst = np.array([1, 1, 2, 1])
    w = np.array([3.0, 1.0, 2.0, 5.0])
    g, lanes = CSRGraph.from_weighted_edges(src, dst, w, 3)
    assert g.n_edges == 2
    s, d = g.edge_arrays_np()
    lane_w = {(int(a), int(b)): float(x)
              for a, b, x in zip(s, d, lanes[: g.n_edges])}
    assert lane_w == {(0, 1): 1.0, (1, 2): 2.0}
    assert np.isinf(lanes[g.n_edges:]).all()
