"""The §Perf optimizations must be semantics-preserving — A/B tests."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro._attic.models import layers as L
from repro._attic.models import transformer as T


def test_mla_absorption_equivalence():
    """Weight-absorbed MLA decode == naive latent-reconstruction decode."""
    mla = L.MLAConfig(d_model=64, n_heads=4, q_lora_rank=32,
                      kv_lora_rank=16, d_nope=16, d_rope=8, d_v=16)
    p = L.mla_init(jax.random.PRNGKey(0), mla, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 1, 64), jnp.float32)
    cache = {"latent": jax.random.normal(jax.random.PRNGKey(2), (3, 6, 16)),
             "k_rope": jax.random.normal(jax.random.PRNGKey(3),
                                         (3, 6, 1, 8)),
             "pos": jnp.asarray([2, 0, 4], jnp.int32)}
    o_naive, c_naive = L.mla_decode(p, x, dict(cache), mla, absorb=False)
    o_abs, c_abs = L.mla_decode(p, x, dict(cache), mla, absorb=True)
    # identical math, different contraction association: (qW)·lat vs
    # q·(latW) — f32 reassociation noise through softmax is ~1e-3
    np.testing.assert_allclose(np.asarray(o_naive), np.asarray(o_abs),
                               rtol=5e-2, atol=5e-3)
    for k in ("latent", "k_rope", "pos"):
        np.testing.assert_allclose(np.asarray(c_naive[k]),
                                   np.asarray(c_abs[k]), rtol=1e-6)


def test_repeat_kv_attention_matches_reference():
    """repeat-KV head-local attention == grouped-score reference."""
    cfg = L.AttnConfig(d_model=64, n_heads=8, n_kv=2, d_head=16)
    p = L.attn_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 64), jnp.float32)
    out = L.attn_forward(p, x, cfg)

    # reference: grouped-score formulation (the pre-iteration-1 math)
    b, l, _ = x.shape
    inv_freq = L.rope_freqs(cfg.d_head)
    pos = jnp.arange(l)[None, :]
    q = L.linear(p["q"], x).reshape(b, l, cfg.n_heads, cfg.d_head)
    k = L.linear(p["k"], x).reshape(b, l, cfg.n_kv, cfg.d_head)
    v = L.linear(p["v"], x).reshape(b, l, cfg.n_kv, cfg.d_head)
    q = L.apply_rope(q, pos, inv_freq)
    k = L.apply_rope(k, pos, inv_freq)
    s = L._gqa_scores(q, k, cfg)
    mask = pos[:, :, None] >= pos[:, None, :]
    s = jnp.where(mask[:, :, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bqkgl,blkd->bqkgd", w, v)
    ref = L.linear(p["o"], ref.reshape(b, l, -1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_qblock_attention_matches_unblocked():
    cfg = L.AttnConfig(d_model=32, n_heads=4, n_kv=4, d_head=8)
    p = L.attn_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    full = L.attn_forward(p, x, cfg)
    blocked = L.attn_forward(p, x, cfg, q_block=4)
    np.testing.assert_allclose(np.asarray(full), np.asarray(blocked),
                               rtol=1e-5, atol=1e-6)


def test_moe_segment_add_combine_matches_dense_oracle():
    cfg = L.MoEConfig(n_experts=8, top_k=2, d_model=32, d_ff=48,
                      n_groups=2, capacity_factor=8.0)  # no drops
    p = L.moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32)
    out = L.moe_forward(p, x, cfg)
    logits = x @ p["router"]
    gate, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(8):
        h = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        y = h @ p["w_down"][e]
        ref += y * ((idx == e) * gate).sum(-1)[:, None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.0 and adversarial routing, dropped tokens
    lose their expert contribution but the layer stays finite."""
    cfg = L.MoEConfig(n_experts=4, top_k=1, d_model=16, d_ff=16,
                      n_groups=1, capacity_factor=1.0)
    p = L.moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jnp.ones((32, 16), jnp.float32)  # all tokens route identically
    out = L.moe_forward(p, x, cfg)
    assert bool(jnp.isfinite(out).all())
