"""Streaming edge mutation over the fixed-shape CSR pytree.

Production graphs mutate.  :class:`CSRGraph` is deliberately frozen — a
fixed-shape device pytree the jitted sweeps treat as immutable — so this
module adds the write path *around* it instead of inside it:

  * :class:`DynamicCSRGraph` owns host-side COO lane buffers with free
    headroom (a "COO side-buffer" over the packed CSR lanes).
    ``insert_edges`` fills free slots, ``delete_edges`` tombstones live
    slots to the CSR sentinel ``n_nodes`` — the exact inert-lane
    convention every sweep form already honours, which is what makes the
    merged operand cheap: a tombstoned lane *is* a padded lane.
  * ``view()`` materializes the merged (base + delta) operand as a
    plain :class:`CSRGraph` whose ``m_pad`` equals the buffer capacity.
    Capacity only changes when the buffer is grown, so the jitted sweep
    shapes — and their compiled executables — survive arbitrarily many
    mutations.  Views are immutable snapshots: a reader holding one is
    never invalidated by later writes or by compaction.
  * ``compact()`` re-packs the lanes (dropping tombstones, restoring
    CSR sort order) when the tombstone fraction passes a threshold or
    the buffer runs out of slots.  Compaction changes layout, never
    content: the ``epoch`` counter is untouched.

Staleness is tracked by two counters:

  ``epoch``    — bumps once per mutation batch that changed the edge
                 *content*.  Everything downstream (``PreparedGraph``,
                 the serving tier's row cache / betweenness vector /
                 landmark tables, `repro.api` handles) keys its cached
                 artifacts on this.
  ``layout_version`` — bumps on compaction too; only the cached
                 ``view()`` keys on it.

A bounded journal of net content deltas (``delta_since``) lets callers
patch O(n^2) dense operands in O(Δ) instead of rebuilding them; when the
journal has been trimmed past the requested epoch it returns ``None``
and the caller falls back to a rebuild.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from .csr import CSRGraph, _round_up

__all__ = ["DynamicCSRGraph"]

# keep at most this many mutation batches of journal; older deltas fall
# back to a full operand rebuild
_JOURNAL_LIMIT = 256


class DynamicCSRGraph:
    """A mutable graph: packed CSR lanes + free headroom + tombstones.

    ``weights=None`` builds an unweighted (boolean/counting) graph;
    passing lane weights (any array covering the first ``n_edges``
    lanes, e.g. the ``from_weighted_edges`` lane vector) makes it a
    tropical graph whose ``view_weights()`` stays aligned with
    ``view()``'s lanes.
    """

    def __init__(self, base: CSRGraph, *,
                 weights: Optional[np.ndarray] = None,
                 slack: float = 0.5,
                 compact_threshold: float = 0.25):
        assert slack >= 0.0 and compact_threshold > 0.0
        self.n_nodes = int(base.n_nodes)
        self._slack = float(slack)
        self._compact_threshold = float(compact_threshold)

        src, dst = base.edge_arrays_np()
        m = len(src)
        w = None
        if weights is not None:
            w = np.asarray(weights, np.float32).ravel()
            assert w.size >= m, f"need >= {m} weights, got {w.size}"
            assert (w[:m] >= 0).all(), "weights must be non-negative"
            w = w[:m]

        cap = max(_round_up(int((m + 1) * (1.0 + self._slack)), 128),
                  int(base.m_pad), 128)
        self._cap = cap
        self._src = np.full(cap, self.n_nodes, np.int64)
        self._dst = np.full(cap, self.n_nodes, np.int64)
        self._src[:m] = src
        self._dst[:m] = dst
        self._w = None
        if w is not None:
            self._w = np.full(cap, np.inf, np.float32)
            self._w[:m] = w
        self._slots = {(int(u), int(v)): i
                       for i, (u, v) in enumerate(zip(src, dst))}
        assert len(self._slots) == m, "base graph has duplicate edges"
        self._free = list(range(cap - 1, m - 1, -1))  # pop() -> low slots
        self._dead_slots = set()      # tombstoned (once-live) free slots
        self._n_live = m

        self.epoch = 0
        self.layout_version = 0
        self.compactions = 0
        self._journal = []   # [(epoch, kind, [(u, v, w, created), ...])]
        self._journal_floor = 0       # deltas valid for since >= floor
        self._view = None
        self._view_w = None
        self._view_key = None

    # -- constructors ------------------------------------------------------

    @classmethod
    def from_edges(cls, src, dst, n_nodes: int, *,
                   weights: Optional[np.ndarray] = None,
                   **kw) -> "DynamicCSRGraph":
        if weights is None:
            return cls(CSRGraph.from_edges(src, dst, n_nodes), **kw)
        g, lanes = CSRGraph.from_weighted_edges(src, dst, weights, n_nodes)
        return cls(g, weights=lanes, **kw)

    # -- introspection -----------------------------------------------------

    @property
    def n_edges(self) -> int:
        return self._n_live

    @property
    def m_pad(self) -> int:
        """Merged-operand lane capacity (the ``view()``'s ``m_pad``)."""
        return self._cap

    @property
    def weighted(self) -> bool:
        return self._w is not None

    def edges(self) -> Tuple[np.ndarray, ...]:
        """Live edges in CSR (src, dst) order — (src, dst[, w])."""
        live = self._src < self.n_nodes
        s, d = self._src[live], self._dst[live]
        order = np.lexsort((d, s))
        out = (s[order].astype(np.int64), d[order].astype(np.int64))
        if self._w is not None:
            out = out + (self._w[live][order].copy(),)
        return out

    def has_edge(self, u: int, v: int) -> bool:
        return (int(u), int(v)) in self._slots

    # -- mutation ----------------------------------------------------------

    def _normalize(self, src, dst, weights):
        src = np.asarray(src, np.int64).ravel()
        dst = np.asarray(dst, np.int64).ravel()
        assert src.shape == dst.shape, (src.shape, dst.shape)
        if src.size:
            assert src.min() >= 0 and dst.min() >= 0 and \
                src.max() < self.n_nodes and dst.max() < self.n_nodes, \
                "edge endpoints out of range"
        if weights is None:
            w = np.ones(src.size, np.float32)
        else:
            w = np.asarray(weights, np.float32).ravel()
            assert w.shape == src.shape, (w.shape, src.shape)
            assert (w >= 0).all(), "weights must be non-negative"
        return src, dst, w

    def insert_edges(self, src, dst, weights=None) -> int:
        """Insert a batch of edges; returns the number of *effective*
        changes.  Self-loops, in-batch duplicates (weighted: min-reduced,
        matching ``from_weighted_edges``) and edges already live at an
        equal-or-lower weight are no-ops.  On a weighted graph an insert
        of a live edge with a strictly lower weight is a weight decrease
        — journalled, epoch-bumped."""
        src, dst, w = self._normalize(src, dst, weights)
        effective = []
        for u, v, wt in zip(src, dst, w):
            u, v, wt = int(u), int(v), float(wt)
            if u == v:
                continue
            slot = self._slots.get((u, v))
            if slot is not None:
                if self._w is not None and wt < float(self._w[slot]):
                    self._w[slot] = wt
                    effective.append((u, v, wt, False))  # decrease-key
                continue
            if not self._free:
                self._compact(grow=True)
            slot = self._free.pop()
            self._dead_slots.discard(slot)
            self._src[slot] = u
            self._dst[slot] = v
            if self._w is not None:
                self._w[slot] = wt
            self._slots[(u, v)] = slot
            self._n_live += 1
            effective.append((u, v, wt, True))   # created (was absent)
        self._commit("insert", effective)
        return len(effective)

    def delete_edges(self, src, dst) -> int:
        """Delete a batch of edges; absent edges are no-ops.  Returns the
        number of effective deletions.  Deleted slots tombstone to the
        CSR sentinel (an inert padded lane) and are reusable."""
        src, dst, _ = self._normalize(src, dst, None)
        effective = []
        for u, v in zip(src, dst):
            u, v = int(u), int(v)
            slot = self._slots.pop((u, v), None)
            if slot is None:
                continue
            self._src[slot] = self.n_nodes
            self._dst[slot] = self.n_nodes
            if self._w is not None:
                self._w[slot] = np.inf
            self._free.append(slot)
            self._dead_slots.add(slot)
            self._n_live -= 1
            effective.append((u, v, np.inf, False))
        self._commit("delete", effective)
        if len(self._dead_slots) > \
                self._compact_threshold * max(self._n_live, 1):
            self._compact()
        return len(effective)

    def _commit(self, kind: str, effective) -> None:
        if not effective:
            return
        self.epoch += 1
        self._journal.append((self.epoch, kind, effective))
        if len(self._journal) > _JOURNAL_LIMIT:
            dropped = self._journal.pop(0)
            self._journal_floor = dropped[0]

    # -- compaction --------------------------------------------------------

    def compact(self) -> None:
        """Re-pack live lanes into CSR (src, dst) order, dropping
        tombstones.  Content (and ``epoch``) unchanged; layout version
        bumps.  Capacity is preserved so downstream jitted shapes — and
        any outstanding ``view()`` snapshot — stay valid."""
        self._compact(grow=False)

    def _compact(self, grow: bool = False) -> None:
        live = self._src < self.n_nodes
        s, d = self._src[live], self._dst[live]
        w = self._w[live] if self._w is not None else None
        order = np.lexsort((d, s))
        s, d = s[order], d[order]
        m = len(s)
        cap = self._cap
        if grow:
            cap = max(_round_up(int((m + 1) * (1.0 + self._slack)) + 128,
                                128), cap + 128)
        self._cap = cap
        self._src = np.full(cap, self.n_nodes, np.int64)
        self._dst = np.full(cap, self.n_nodes, np.int64)
        self._src[:m] = s
        self._dst[:m] = d
        if self._w is not None:
            ww = np.full(cap, np.inf, np.float32)
            ww[:m] = w[order]
            self._w = ww
        self._slots = {(int(u), int(v)): i
                       for i, (u, v) in enumerate(zip(s, d))}
        self._free = list(range(cap - 1, m - 1, -1))
        self._dead_slots = set()
        self._n_live = m
        self.layout_version += 1
        self.compactions += 1

    # -- merged read view --------------------------------------------------

    def view(self) -> CSRGraph:
        """The merged (base + delta) operand as an immutable
        :class:`CSRGraph` snapshot, ``m_pad`` = buffer capacity.  Cached
        per (epoch, layout); safe to hold across later mutations."""
        key = (self.epoch, self.layout_version)
        if self._view_key != key:
            live = self._src < self.n_nodes
            s, d = self._src[live], self._dst[live]
            g = CSRGraph.from_edges(s, d, self.n_nodes, dedup=False,
                                    remove_self_loops=False,
                                    pad_to=self._cap)
            if self._w is not None:
                # from_edges lexsorts by (src, dst); mirror it so lane
                # weights line up with the view's padded CSR lanes
                order = np.lexsort((d, s))
                lanes = np.full(self._cap, np.inf, np.float32)
                lanes[:len(s)] = self._w[live][order]
                self._view_w = lanes
            self._view = g
            self._view_key = key
        return self._view

    def view_weights(self) -> Optional[np.ndarray]:
        """(m_pad,) f32 lane weights aligned with ``view()`` (+inf pad);
        ``None`` for unweighted graphs."""
        if self._w is None:
            return None
        self.view()
        return self._view_w

    # -- delta journal -----------------------------------------------------

    def delta_since(self, since_epoch: int):
        """Net content delta from ``since_epoch`` to now, or ``None`` if
        the journal no longer reaches back that far (caller rebuilds).

        Returns ``(ins_src, ins_dst, ins_w, del_src, del_dst)`` numpy
        arrays: the edges now live that were inserted/updated after
        ``since_epoch``, and the edges deleted after it.  An edge
        *created* after ``since_epoch`` and deleted again cancels out
        entirely (its first journal entry records whether the insert
        created the edge or merely decreased a live weight).  An edge
        that existed at ``since_epoch`` and was deleted at any point
        appears in the delete list even when a later insert revived it
        (then also in the insert list, at its current weight): the
        revived weight may exceed the old one, so consumers must taint
        the state built on the old edge before applying the insert —
        netting the round-trip to a bare insert would leave distances
        that relied on the cheaper edge stale."""
        if since_epoch < self._journal_floor:
            return None
        # (u, v) -> [first_op_created_edge, last_kind, last_w, saw_delete]
        net = {}
        for ep, kind, edges in self._journal:
            if ep <= since_epoch:
                continue
            for (u, v, w, created) in edges:
                cur = net.get((u, v))
                if cur is None:
                    net[(u, v)] = [kind == "insert" and created, kind, w,
                                   kind == "delete"]
                else:
                    cur[1], cur[2] = kind, w
                    cur[3] = cur[3] or kind == "delete"
        ins = [(u, v, w) for (u, v), (_, k, w, _) in net.items()
               if k == "insert"]
        dels = [(u, v) for (u, v), (fc, _, _, sd) in net.items()
                if sd and not fc]
        ins_src = np.array([e[0] for e in ins], np.int64)
        ins_dst = np.array([e[1] for e in ins], np.int64)
        ins_w = np.array([e[2] for e in ins], np.float32)
        del_src = np.array([e[0] for e in dels], np.int64)
        del_dst = np.array([e[1] for e in dels], np.int64)
        return ins_src, ins_dst, ins_w, del_src, del_dst

    def __repr__(self) -> str:
        return (f"DynamicCSRGraph(n={self.n_nodes}, live={self._n_live}, "
                f"dead={len(self._dead_slots)}, cap={self._cap}, "
                f"epoch={self.epoch}, layout={self.layout_version}, "
                f"weighted={self._w is not None})")
