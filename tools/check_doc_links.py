#!/usr/bin/env python
"""Fail on dead intra-repo references in the documentation.

Scans README.md and docs/*.md for

  * markdown links whose target is a relative path — resolved against
    the linking file's directory (anchors stripped); and
  * backticked repo paths (tokens containing ``/`` that end in a known
    source extension, with an optional ``::symbol`` suffix) — resolved
    against the repo root or any of the package shorthand roots the
    docs conventionally use (``src/``, ``src/repro/``,
    ``src/repro/kernels/`` — so ``core/sweep.py`` means
    ``src/repro/core/sweep.py``),

and exits non-zero listing every target that does not exist.  This is
what keeps docs/REPRODUCTION.md honest: every module/test path a claim
row cites must resolve.  External (http/mailto) and pure-anchor links
are ignored.

    python tools/check_doc_links.py            # from the repo root
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List

DOC_GLOBS = ("README.md", "docs/*.md")
# shorthand roots for backticked code paths, tried in order
PATH_ROOTS = ("", "src/", "src/repro/", "src/repro/kernels/")
_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE_PATH = re.compile(
    r"`([A-Za-z0-9_./-]+/[A-Za-z0-9_.-]+\.(?:py|md|json|yml|yaml|toml))"
    r"(?:::[A-Za-z0-9_.]+)?`")


def check(root: Path) -> List[str]:
    """Return 'file: dead target' strings for every unresolvable
    reference under ``root``."""
    failures: List[str] = []
    docs: List[Path] = []
    for pattern in DOC_GLOBS:
        docs.extend(sorted(root.glob(pattern)))
    for doc in docs:
        text = doc.read_text()
        rel = doc.relative_to(root)
        seen = set()
        for m in _MD_LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path or path in seen:
                continue
            seen.add(path)
            if not (doc.parent / path).exists():
                failures.append(f"{rel}: dead link ({target})")
        for m in _CODE_PATH.finditer(text):
            path = m.group(1)
            if path in seen:
                continue
            seen.add(path)
            if not any((root / pre / path).exists()
                       for pre in PATH_ROOTS):
                failures.append(f"{rel}: dead code path (`{path}`)")
    return failures


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    failures = check(root)
    for f in failures:
        print(f"[doc-links] FAIL {f}")
    if failures:
        print(f"[doc-links] {len(failures)} dead reference(s)")
        return 1
    n_docs = sum(len(list(root.glob(p))) for p in DOC_GLOBS)
    print(f"[doc-links] OK — {n_docs} docs, no dead intra-repo links")
    return 0


if __name__ == "__main__":
    sys.exit(main())
