"""schnet — continuous-filter convolutions.
[arXiv:1706.08566; paper]  3 interactions d_hidden=64 rbf=300 cutoff=10."""
from ..models.gnn import SchNetConfig

CONFIG = SchNetConfig(
    name="schnet", n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0)
