"""Public SSSP/APSP drivers — the paper's user-facing API.

``sssp(graph, source, method="auto")`` picks the execution path:

  * ``sovm``  — edge-parallel sparse sweep (paper Alg. 2), best for sparse
                graphs / single sources (default for density < 1%).
  * ``bovm``  — dense boolean matmul sweeps (paper Alg. 1 / MXU path),
                best for dense graphs or batched sources.
  * ``auto``  — density- and batch-driven dispatch (the paper's own BOVM vs
                SOVM guidance, §3.3).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph
from .bovm import bovm_msbfs
from .sovm import sovm_msbfs, sovm_sssp


class SsspResult(NamedTuple):
    dist: jax.Array          # (n,) or (S, n) int32; -1 unreachable
    eccentricity: jax.Array  # sweeps executed that discovered something
    edges_touched: jax.Array


def _density(g: CSRGraph) -> float:
    return g.n_edges / max(g.n_nodes * g.n_nodes, 1)


def _pick(g: CSRGraph, n_sources: int, method: str) -> str:
    if method != "auto":
        return method
    # dense matmul path pays off when the adjacency fits comfortably and
    # either the graph is dense or many sources amortize the O(n^2) sweeps.
    if g.n_nodes <= 4096 and (_density(g) > 0.01 or n_sources >= 32):
        return "bovm"
    return "sovm"


def sssp(g: CSRGraph, source: int, *, method: str = "auto") -> SsspResult:
    m = _pick(g, 1, method)
    if m == "bovm":
        st = bovm_msbfs(g.to_dense(), jnp.asarray([source], jnp.int32))
        return SsspResult(st.dist[0], st.step - 1, st.edges_touched)
    st = sovm_sssp(g, source)
    return SsspResult(st.dist, st.sweeps, st.edges_touched)


def multi_source(g: CSRGraph, sources: Sequence[int] | jax.Array, *,
                 method: str = "auto") -> SsspResult:
    sources = jnp.asarray(sources, jnp.int32)
    m = _pick(g, int(sources.shape[0]), method)
    if m == "bovm":
        st = bovm_msbfs(g.to_dense(), sources)
        return SsspResult(st.dist, st.step - 1, st.edges_touched)
    st = sovm_msbfs(g, sources)
    return SsspResult(st.dist, jnp.max(st.sweeps), jnp.sum(st.edges_touched))


def apsp(g: CSRGraph, *, block: int = 128, method: str = "auto"):
    """All-pairs via blocked multi-source sweeps.  Yields (sources, dist)
    blocks to avoid materializing the full (n, n) matrix for large n."""
    n = g.n_nodes
    for lo in range(0, n, block):
        srcs = jnp.arange(lo, min(lo + block, n), dtype=jnp.int32)
        yield srcs, multi_source(g, srcs, method=method).dist


def apsp_dense(g: CSRGraph, *, block: int = 128, method: str = "auto"):
    """Materialized APSP (small graphs / tests)."""
    rows = [np.asarray(d) for _, d in apsp(g, block=block, method=method)]
    return np.concatenate(rows, axis=0)
