from .csr import CSRGraph, DegreeStats, symmetrize
from .landmarks import (STRATEGIES, degree_landmarks, farthest_point_fill,
                        select_landmarks)
from . import generators, landmarks, partition, sampler, io

__all__ = ["CSRGraph", "DegreeStats", "symmetrize", "generators",
           "landmarks", "partition", "sampler", "io",
           "STRATEGIES", "degree_landmarks", "farthest_point_fill",
           "select_landmarks"]
