"""Continuous-batching serving demo (prefill + decode + slot reuse).

    PYTHONPATH=src python examples/serve_lm.py --requests 8
"""
import argparse
import time

import jax
import numpy as np

from repro._attic.models import transformer as T
from repro._attic.lm_serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = T.LMConfig(name="serve-demo", n_layers=4, d_model=256, n_heads=4,
                     n_kv=2, d_head=64, d_ff=1024, vocab=8192)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, slots=args.slots, max_len=512)

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    for r in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=int(rng.integers(4, 32)))
        eng.submit(Request(rid=r, prompt=prompt.astype(np.int32),
                           max_new=args.max_new))
    done = eng.run_to_completion()
    wall = time.monotonic() - t0
    toks = sum(len(d.out) for d in done)
    ttft = sorted(d.t_first - d.t_submit for d in done)
    print(f"{len(done)} requests / {toks} tokens in {wall:.1f}s "
          f"→ {toks / wall:.1f} tok/s")
    print(f"TTFT p50={ttft[len(ttft) // 2] * 1e3:.0f}ms "
          f"p99={ttft[-1] * 1e3:.0f}ms")
    print("sample output:", done[0].out)


if __name__ == "__main__":
    main()
