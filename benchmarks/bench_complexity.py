"""Paper Eqs. 5/6/10/12: complexity-claim verification.

  * SOVM useful work == E_wcc(i)                (Eq. 10)
  * BOVM work ≤ (1+p)/2 · ε(i) · m              (Eq. 6)
  * sweeps executed == ε(i)                     (Thm 3.2 / Fact 1)
  * APSP total work  ≤ 2 · S_wcc · E_wcc        (Eq. 12)
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.configs.dawn import GRAPH_SUITE
from repro.core import bovm_sssp, sovm_sssp, sovm_msbfs, wcc_stats


def run(csv: List[str] | None = None, n_sources: int = 8):
    rng = np.random.default_rng(1)
    results = {}
    for name, make in GRAPH_SUITE.items():
        g = make()
        stats = wcc_stats(g)
        sources = rng.integers(0, g.n_nodes, n_sources)
        ok_eq10, ok_eccen, ratios = True, True, []
        for s in sources:
            st = sovm_sssp(g, int(s))
            dist = np.asarray(st.dist)
            reach = dist >= 0
            ecc = dist[reach].max() if reach.any() else 0
            if int(st.sweeps) != int(ecc):
                ok_eccen = False
            # Eq. 10 on undirected graphs: touched == E_cc(i)
            e_cc = stats["E_wcc_of"](int(s))
            ratios.append(float(st.edges_touched) / max(e_cc, 1))
        # BOVM bound (Eq. 6)
        st_b = bovm_sssp(g.to_dense(), int(sources[0]))
        p_conn = g.n_edges / (g.n_nodes ** 2)
        dist0 = np.asarray(sovm_sssp(g, int(sources[0])).dist)
        ecc0 = dist0[dist0 >= 0].max()
        bound = (1 + p_conn) / 2 * max(int(ecc0), 1) * g.n_edges
        bovm_ok = float(st_b.edges_touched) <= bound + 1
        results[name] = {"eq10_ratio": float(np.mean(ratios)),
                         "sweeps==ecc": ok_eccen, "eq6_bound_ok": bovm_ok}
        if csv is not None:
            csv.append(
                f"complexity_{name},,eq10_work/E_wcc={np.mean(ratios):.3f}"
                f";sweeps_eq_ecc={ok_eccen};eq6_bound_ok={bovm_ok}")
    return results


if __name__ == "__main__":
    out: List[str] = []
    run(csv=out)
    print("\n".join(out))
