"""Host→device pipeline: sharded placement + background prefetch.

``shard_batch`` places a host batch according to a PartitionSpec pytree
(each host would materialize only its addressable shard in a multi-host
deployment — here single-host, full placement).  ``Prefetcher`` overlaps
host batch synthesis with device compute via a worker thread and a small
queue (depth 2 keeps one batch in flight without unbounded memory)."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def shard_batch(mesh: Mesh, batch: Dict[str, np.ndarray],
                specs: Dict[str, PartitionSpec]):
    return {
        k: jax.device_put(v, NamedSharding(mesh, specs.get(
            k, PartitionSpec())))
        for k, v in batch.items()}


class Prefetcher:
    def __init__(self, it: Iterator, *, depth: int = 2,
                 place: Optional[Callable] = None):
        self.it = it
        self.place = place or (lambda x: x)
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.done = False
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        try:
            for item in self.it:
                self.q.put(self.place(item))
        finally:
            self.q.put(StopIteration)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is StopIteration:
            raise StopIteration
        return item
