"""Attic launchers (LM/GNN/recsys train + dry-run + LM serve).

The live ``repro.launch`` keeps only mesh/HLO/roofline tooling.
"""
