from .engine import (GraphQuery, GraphService, Request, ServingEngine)

__all__ = ["GraphQuery", "GraphService", "Request", "ServingEngine"]
