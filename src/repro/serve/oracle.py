"""Landmark distance oracle: O(|landmarks|) point-to-point answers with
an exactness certificate, backed by label tables the batched APSP engine
builds offline.

The serving tier's hot path is repeated point-to-point queries, and a
full direction-optimized sweep run per micro-batch is O(sweeps · n)
work when the answer is often determined by |landmarks| table lookups.
This module implements the classic landmark (ALT-style) oracle on top of
the engine's own machinery:

  * **offline** — :func:`build_landmark_labels` selects landmarks
    (``graph/landmarks.py``: degree/farthest-point mix) and computes one
    BFS row per landmark with ``core/engine.py::apsp_engine`` — the
    batched engine *is* the preprocessing pass (Burkhardt's algebraic-BFS
    bound covers its cost: one O(ε·m) sweep run per landmark tile).
    Directed graphs get a second table from the reversed graph; symmetric
    graphs share one.  Tables live on the :class:`PreparedGraph` so every
    oracle over the same prepared graph reuses one build.

  * **online** — for a query (s, t) the triangle inequality gives, per
    landmark L with forward rows F[L, v] = d(L, v) and reverse rows
    R[L, v] = d(v, L):

        upper:  d(s,t) ≤ R[L, s] + F[L, t]             (route via L)
        lower:  d(s,t) ≥ F[L, t] − F[L, s]             (F[L, s] finite)
        lower:  d(s,t) ≥ R[L, s] − R[L, t]             (R[L, t] finite)

    Unreachability propagates soundly through the lower bounds: if L
    reaches s but not t (or s reaches L but t does not... reversed), the
    bound is +inf — a *certificate* that t is unreachable from s.  The
    answer is **certified exact** when the query hits a landmark's own
    shortest-path tree root (s or t is a landmark — the Yamane &
    Kobayashi SPT case: the landmark's BFS row is the exact answer) or
    when upper == lower.  Everything else is a miss the serving tier
    falls back to an exact batched sweep for — oracle answers are
    therefore bit-identical to the engine by construction, never
    approximate.

All online math is host-side numpy over the (L, n) tables: queries are
O(L), full-row bounds are O(L·n).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

import numpy as np

from ..core.engine import (EngineConfig, PreparedGraph, apsp_engine,
                           prepare_graph)
from ..graph.csr import CSRGraph
from ..graph.landmarks import STRATEGIES, select_landmarks

_INF = np.inf


def _is_symmetric(g: CSRGraph) -> bool:
    """Edge-set symmetry check: for a symmetric graph the CSC arrays
    equal the CSR arrays (same lexsorted layout), so the reverse label
    table would be identical and need not be built."""
    return bool(
        np.array_equal(np.asarray(g.indptr), np.asarray(g.indptr_t))
        and np.array_equal(np.asarray(g.indices), np.asarray(g.indices_t)))


def _label_config(n_landmarks: int,
                  config: Optional[EngineConfig]) -> EngineConfig:
    if config is not None:
        return config
    batch = max(8, ((n_landmarks + 7) // 8) * 8)
    if batch > 128:
        batch = ((batch + 127) // 128) * 128
    return EngineConfig(source_batch=min(batch, 128))


def build_landmark_labels(pg: PreparedGraph, *, n_landmarks: int = 16,
                          strategy: str = "mixed",
                          config: Optional[EngineConfig] = None
                          ) -> np.ndarray:
    """Select landmarks and attach the (L, n) label tables to ``pg``.

    Idempotent per (n_landmarks, strategy): a matching ``landmark_key``
    reuses the cached tables, anything else rebuilds.  Returns the
    landmark id array.
    """
    key = (int(n_landmarks), strategy)
    if pg.landmark_key == key and pg.landmark_dist is not None:
        return pg.landmarks
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown landmark strategy {strategy!r}; "
                         f"available: {STRATEGIES}")
    if n_landmarks < 1:
        raise ValueError(f"n_landmarks must be >= 1, got {n_landmarks}")
    cfg = _label_config(n_landmarks, config)

    def bfs_row(v: int) -> np.ndarray:
        return np.asarray(
            apsp_engine(pg, np.asarray([v], np.int32), config=cfg).dist[0])

    marks = select_landmarks(pg.graph, n_landmarks, strategy=strategy,
                             dist_fn=bfs_row)
    fwd = np.asarray(apsp_engine(pg, marks, config=cfg).dist)
    if _is_symmetric(pg.graph):
        rev = fwd
    else:
        rev_pg = prepare_graph(pg.graph.reverse())
        rev = np.asarray(apsp_engine(rev_pg, marks, config=cfg).dist)
    pg.landmarks = marks
    pg.landmark_dist = fwd
    pg.landmark_dist_rev = rev
    pg.landmark_key = key
    return marks


def select_top_k(dist_row: np.ndarray, source: int, k: int
                 ) -> List[Tuple[int, int]]:
    """Deterministic top-k-nearest from an exact distance row: reachable
    targets (excluding the source itself) sorted by (distance, vertex
    id), first ``k``.  The oracle's certified top-k answer and the exact
    sweep fallback both use this rule, so they are bit-identical."""
    dist = np.asarray(dist_row)
    nodes = np.arange(len(dist))
    mask = (dist >= 0) & np.isfinite(dist.astype(np.float64)) & \
        (nodes != source)
    nodes = nodes[mask]
    d = dist[mask]
    order = np.lexsort((nodes, d))[:k]
    return [(int(nodes[i]), int(d[i])) for i in order]


@dataclasses.dataclass
class OracleAnswer:
    """One point-to-point oracle result.  ``exact`` means the bounds (or
    a landmark hit) *prove* ``hops`` — certified answers are bit-identical
    to an exact sweep.  Uncertified answers carry only the bound
    interval; ``hops`` is None and the caller must fall back."""
    source: int
    target: int
    lower: float              # sound lower bound (may be +inf: proof of
    upper: float              # unreachability); upper may be +inf too
    exact: bool
    hops: Optional[int] = None        # set iff exact; -1 = unreachable
    certificate: str = ""     # "trivial" | "landmark-source" |
    #                           "landmark-target" | "bounds" | ""


class DistanceOracle:
    """Query-time wrapper over the landmark label tables.

    Construct from a :class:`CSRGraph` or an already-shared
    :class:`PreparedGraph`; the label build goes through
    :func:`build_landmark_labels` (cached on the prepared graph).
    """

    def __init__(self, g: Union[CSRGraph, PreparedGraph], *,
                 n_landmarks: int = 16, strategy: str = "mixed",
                 config: Optional[EngineConfig] = None):
        pg = g if isinstance(g, PreparedGraph) else prepare_graph(g)
        self.prepared = pg
        build_landmark_labels(pg, n_landmarks=n_landmarks,
                              strategy=strategy, config=config)
        self.landmarks: np.ndarray = pg.landmarks
        self._pos = {int(v): i for i, v in enumerate(self.landmarks)}
        # float views with +inf for unreachable — the bound arithmetic's
        # native encoding (int -1 sentinels don't min/max soundly)
        self._F = np.where(pg.landmark_dist < 0, _INF,
                           pg.landmark_dist.astype(np.float64))
        self._R = self._F if pg.landmark_dist_rev is pg.landmark_dist \
            else np.where(pg.landmark_dist_rev < 0, _INF,
                          pg.landmark_dist_rev.astype(np.float64))
        # per-landmark forward eccentricity over reachable targets —
        # feeds the serving tier's predicted-sweep-count buckets
        finite = np.where(np.isfinite(self._F), self._F, 0.0)
        self._ecc_fwd = finite.max(axis=1)
        self.n_queries = 0
        self.n_certified = 0

    @property
    def n_landmarks(self) -> int:
        return len(self.landmarks)

    def landmark_row(self, source: int) -> Optional[np.ndarray]:
        """The exact (n,) int32 forward row when ``source`` is a
        landmark (its SPT is the label), else None."""
        i = self._pos.get(int(source))
        if i is None:
            return None
        return self.prepared.landmark_dist[i]

    # -- point-to-point ----------------------------------------------------

    def query(self, source: int, target: int) -> OracleAnswer:
        """O(L) bounds + certificate for one (source, target) pair."""
        self.n_queries += 1
        s, t = int(source), int(target)
        if s == t:
            self.n_certified += 1
            return OracleAnswer(s, t, 0.0, 0.0, True, hops=0,
                                certificate="trivial")
        i = self._pos.get(s)
        if i is not None:
            d = float(self._F[i, t])
            self.n_certified += 1
            return OracleAnswer(s, t, d, d, True,
                                hops=-1 if np.isinf(d) else int(d),
                                certificate="landmark-source")
        j = self._pos.get(t)
        if j is not None:
            d = float(self._R[j, s])
            self.n_certified += 1
            return OracleAnswer(s, t, d, d, True,
                                hops=-1 if np.isinf(d) else int(d),
                                certificate="landmark-target")
        Fs, Ft = self._F[:, s], self._F[:, t]
        Rs, Rt = self._R[:, s], self._R[:, t]
        upper = float(np.min(Rs + Ft, initial=_INF))
        with np.errstate(invalid="ignore"):   # inf-inf in masked branches
            lb_f = np.where(np.isfinite(Fs), Ft - Fs, -_INF)
            lb_r = np.where(np.isfinite(Rt), Rs - Rt, -_INF)
        lower = max(float(np.max(lb_f, initial=1.0)),
                    float(np.max(lb_r, initial=1.0)), 1.0)
        if upper == lower:
            self.n_certified += 1
            return OracleAnswer(s, t, lower, upper, True,
                                hops=-1 if np.isinf(upper) else int(upper),
                                certificate="bounds")
        return OracleAnswer(s, t, lower, upper, False)

    # -- full-row bounds / top-k ------------------------------------------

    def bounds(self, source: int) -> Tuple[np.ndarray, np.ndarray]:
        """(lower, upper) float64 rows over ALL targets — O(L·n)."""
        s = int(source)
        i = self._pos.get(s)
        if i is not None:
            row = self._F[i]
            return row.copy(), row.copy()
        Fs = self._F[:, s][:, None]
        Rs = self._R[:, s][:, None]
        upper = np.min(Rs + self._F, axis=0, initial=_INF)
        with np.errstate(invalid="ignore"):   # inf-inf in masked branches
            lb_f = np.max(np.where(np.isfinite(Fs), self._F - Fs, -_INF),
                          axis=0, initial=1.0)
            lb_r = np.max(np.where(np.isfinite(self._R), Rs - self._R,
                                   -_INF), axis=0, initial=1.0)
        lower = np.maximum(np.maximum(lb_f, lb_r), 1.0)
        lower[s] = 0.0
        upper[s] = 0.0
        return lower, upper

    def top_k(self, source: int, k: int
              ) -> Optional[List[Tuple[int, int]]]:
        """Certified top-k-nearest, or None when the bounds cannot prove
        the full answer.

        The selected set is the k lexicographically-(distance, id)-
        smallest certified-reachable targets; the whole answer certifies
        only if no *uncertified* target could still beat the k-th
        selected distance (every uncertified lower bound is strictly
        larger).  Certified-but-excluded targets are safe by
        construction of the selection order."""
        self.n_queries += 1
        lower, upper = self.bounds(source)
        s = int(source)
        nodes = np.arange(len(lower))
        certified = (lower == upper) & (nodes != s)
        reach = certified & np.isfinite(upper)
        cand_nodes = nodes[reach]
        cand_d = upper[reach]
        order = np.lexsort((cand_nodes, cand_d))[:k]
        sel = [(int(cand_nodes[i]), int(cand_d[i])) for i in order]
        d_k = sel[-1][1] if len(sel) == k else _INF
        uncert = ~certified & (nodes != s)
        if np.any(lower[uncert] <= d_k):
            return None
        self.n_certified += 1
        return sel

    # -- serving-tier helpers ---------------------------------------------

    def predicted_sweeps(self, source: int) -> int:
        """Upper estimate of the sweep count a fresh BFS from ``source``
        would run: ecc(s) ≤ min_L d(s, L) + ecc_fwd(L).  Falls back to n
        when s reaches no landmark (nothing is known).  Drives the
        serving tier's pad-waste-avoiding buckets — an estimate only,
        never correctness-relevant."""
        s = int(source)
        i = self._pos.get(s)
        if i is not None:
            return int(self._ecc_fwd[i])
        bound = float(np.min(self._R[:, s] + self._ecc_fwd, initial=_INF))
        if np.isinf(bound):
            return self.prepared.graph.n_nodes
        return int(bound)

    def labels_checksum(self) -> int:
        """Deterministic fingerprint of (landmarks, tables) — a hard
        regression-gate field: any drift means selection or the label
        build did different work."""
        return int(self.landmarks.astype(np.int64).sum()
                   + np.int64(7) * self.prepared.landmark_dist.astype(
                       np.int64).sum()
                   + np.int64(13) * self.prepared.landmark_dist_rev.astype(
                       np.int64).sum())
