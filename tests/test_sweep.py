"""The semiring sweep layer: cross-form / cross-semiring equivalence,
parent reconstruction on the batched paths, the weighted engine vs
Dijkstra, and the one-driver structural invariant."""
import re
from pathlib import Path

import numpy as np
import pytest
import jax.numpy as jnp

import repro.core as core
from repro.core import (EngineConfig, WeightedConfig, apsp_engine,
                        derive_parents, minplus_sssp, multi_source,
                        prepare_weighted, reconstruct_path, sovm_sssp,
                        sssp, weighted_apsp)
from repro.graph import generators as gen

from oracles import bfs_dist, bfs_dists, dijkstra_dists


# -- structural invariant: ONE sweep driver ---------------------------------

def test_exactly_one_while_loop_under_core():
    """The refactor's contract: every core path flows through
    sweep.sweep_loop — no module re-grows its own loop."""
    core_dir = Path(core.__file__).parent
    hits = {}
    for path in sorted(core_dir.glob("*.py")):
        count = len(re.findall(r"lax\.while_loop\(", path.read_text()))
        if count:
            hits[path.name] = count
    assert hits == {"sweep.py": 1}, hits


def test_every_layer_imports_the_sweep_layer():
    core_dir = Path(core.__file__).parent
    for name in ("bovm", "sovm", "bfs", "weighted", "wcc", "distributed",
                 "engine", "centrality"):
        text = (core_dir / f"{name}.py").read_text()
        assert re.search(r"from \. import sweep as S|from \.sweep import",
                         text), name


def test_core_reaches_kernels_only_through_the_registry():
    """The kernel-layer contract: no core module imports a semiring
    kernel package directly — the registry is the single seam, so adding
    a semiring's hardware path never touches core."""
    core_dir = Path(core.__file__).parent
    for path in sorted(core_dir.glob("*.py")):
        for line in path.read_text().splitlines():
            if line.strip().startswith(("import", "from")):
                assert "kernels.bovm" not in line, (path.name, line)
                assert "kernels.tropical" not in line, (path.name, line)
                assert "kernels.counting" not in line, (path.name, line)


def test_weighted_kernel_and_reference_share_the_one_driver(random_weighted):
    """Kernel-backed tropical forms run through the same sweep_loop: the
    sweep counters agree with the reference path on the same graph."""
    g, w = random_weighted(80, 3.0, 37)
    sources = np.arange(8, dtype=np.int32)
    kern = weighted_apsp(g, w, sources,
                         config=WeightedConfig(mode="sparse", source_batch=8,
                                               use_kernel=True))
    ref = weighted_apsp(g, w, sources,
                        config=WeightedConfig(mode="sparse", source_batch=8,
                                              use_kernel=False))
    assert int(kern.sweeps) == int(ref.sweeps)
    np.testing.assert_array_equal(np.asarray(kern.direction_counts),
                                  np.asarray(ref.direction_counts))
    np.testing.assert_array_equal(np.asarray(kern.dist), np.asarray(ref.dist))
    np.testing.assert_allclose(float(kern.edges_touched),
                               float(ref.edges_touched))


# -- cross-form equivalence (boolean semiring) ------------------------------

FAMILIES = {
    "grid": lambda: gen.grid2d(11, 11),
    "rmat": lambda: gen.rmat(8, 4, directed=False, seed=2),
    "er_directed": lambda: gen.erdos_renyi(150, 3.0, seed=9),
    "disconnected": lambda: gen.disconnected(5, 25, 3.0, seed=5),
}


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_push_pull_sparse_agree_with_queue_oracle(family):
    """push ≡ pull ≡ sparse ≡ the queue-BFS oracle on every family."""
    g = FAMILIES[family]()
    sources = np.arange(min(16, g.n_nodes), dtype=np.int32)
    ref = bfs_dists(g, sources)
    for mode in ("push", "pull", "sparse"):
        res = apsp_engine(g, sources,
                          config=EngineConfig(mode=mode, source_batch=16))
        np.testing.assert_array_equal(np.asarray(res.dist), ref,
                                      err_msg=f"{family}/{mode}")


# -- cross-semiring equivalence ---------------------------------------------

@pytest.mark.parametrize("family", ["grid", "rmat", "disconnected"])
def test_minplus_unit_weights_equals_unweighted_sovm(family):
    """Tropical semiring with all-ones weights ≡ boolean SOVM distances."""
    g = FAMILIES[family]()
    w = jnp.ones((g.m_pad,), jnp.float32)
    for src in (0, g.n_nodes // 2):
        sovm_dist = np.asarray(sovm_sssp(g, src).dist).astype(np.float64)
        sovm_dist = np.where(sovm_dist < 0, np.inf, sovm_dist)
        trop = np.asarray(minplus_sssp(g, w, src).dist)
        np.testing.assert_allclose(trop, sovm_dist, err_msg=family)


def test_weighted_apsp_unit_weights_equals_boolean_engine():
    g = gen.watts_strogatz(180, 6, 0.1, seed=7)
    sources = np.arange(16, dtype=np.int32)
    boolean = apsp_engine(g, sources, config=EngineConfig(source_batch=16))
    bdist = np.asarray(boolean.dist).astype(np.float64)
    bdist = np.where(bdist < 0, np.inf, bdist)
    trop = weighted_apsp(g, np.ones(g.m_pad, np.float32), sources,
                         config=WeightedConfig(source_batch=16))
    np.testing.assert_allclose(np.asarray(trop.dist), bdist)


# -- the weighted engine vs Dijkstra ----------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_weighted_apsp_auto_matches_dijkstra(seed, random_weighted):
    """Acceptance: weighted_apsp auto mode == scipy Dijkstra on random
    non-negative graphs."""
    g, w = random_weighted(80 + 30 * seed, 3.0, seed)
    sources = np.arange(min(12, g.n_nodes), dtype=np.int32)
    ref = dijkstra_dists(g, w, sources)
    res = weighted_apsp(g, w, sources,
                        config=WeightedConfig(source_batch=8))
    np.testing.assert_allclose(np.asarray(res.dist), ref, rtol=1e-5)
    assert int(res.direction_counts.sum()) >= int(res.sweeps) > 0


@pytest.mark.parametrize("mode", ["dense", "sparse"])
def test_weighted_fixed_forms_agree(mode, random_weighted):
    g, w = random_weighted(120, 3.0, 11)
    sources = np.arange(10, dtype=np.int32)
    ref = dijkstra_dists(g, w, sources)
    res = weighted_apsp(g, w, sources,
                        config=WeightedConfig(mode=mode, source_batch=8))
    np.testing.assert_allclose(np.asarray(res.dist), ref, rtol=1e-5)
    counts = np.asarray(res.direction_counts)
    idx = ["dense", "sparse"].index(mode)
    assert counts[idx] == counts.sum() > 0


def test_weighted_dynamic_switch_is_exact(random_weighted):
    g, w = random_weighted(100, 4.0, 13)
    sources = np.arange(8, dtype=np.int32)
    ref = dijkstra_dists(g, w, sources)
    res = weighted_apsp(g, w, sources,
                        config=WeightedConfig(source_batch=8, dynamic=True))
    np.testing.assert_allclose(np.asarray(res.dist), ref, rtol=1e-5)


def test_weighted_apsp_tiling_and_prepared_reuse(random_weighted):
    g, w = random_weighted(90, 3.0, 17)
    pw = prepare_weighted(g, w)
    sources = np.arange(21, dtype=np.int32)       # 3 tiles of 8
    res = weighted_apsp(pw, sources=sources,
                        config=WeightedConfig(source_batch=8))
    assert res.dist.shape == (21, g.n_nodes)
    ref = dijkstra_dists(g, w, sources)
    np.testing.assert_allclose(np.asarray(res.dist), ref, rtol=1e-5)
    assert pw.cost_cache                           # calibration cached


# -- parent derivation / path round-trips -----------------------------------

def _check_paths(g, dist_row, parent_row, source):
    adj = g.to_scipy().tocsr()
    dist_row = np.asarray(dist_row)
    reachable = np.flatnonzero(dist_row > 0)
    targets = reachable[:: max(1, len(reachable) // 8)]
    for t in targets:
        path = reconstruct_path(parent_row, source, int(t), g.n_nodes)
        assert path is not None and path[0] == source and path[-1] == t
        assert len(path) - 1 == dist_row[t]
        for a, b in zip(path[:-1], path[1:]):
            assert adj[a, b] != 0


@pytest.mark.parametrize("method", ["auto", "bovm", "sovm"])
def test_sssp_parent_roundtrip_all_methods(method):
    g = gen.watts_strogatz(150, 6, 0.1, seed=21)
    res = sssp(g, 3, method=method)
    np.testing.assert_array_equal(np.asarray(res.dist),
                                  bfs_dist(g, 3))
    _check_paths(g, res.dist, res.parent, 3)


def test_multi_source_auto_parent_roundtrip():
    g = gen.grid2d(9, 9)
    sources = np.arange(6, dtype=np.int32)
    res = multi_source(g, sources, method="auto")
    ref = bfs_dists(g, sources)
    np.testing.assert_array_equal(np.asarray(res.dist), ref)
    parent = np.asarray(res.parent)
    for i, s in enumerate(sources):
        _check_paths(g, res.dist[i], parent[i], int(s))


def test_derive_parents_matches_inloop_sovm():
    """Post-pass parents == in-loop sparse tracking (same tie-break)."""
    g = gen.erdos_renyi(120, 4.0, directed=False, seed=23)
    st = sovm_sssp(g, 0)
    post = np.asarray(derive_parents(g, st.dist[None, :]))[0]
    np.testing.assert_array_equal(post, np.asarray(st.parent))


def test_derive_parents_weighted(random_weighted):
    g, w = random_weighted(70, 3.0, 29)
    res = weighted_apsp(g, w, np.arange(8),
                        config=WeightedConfig(source_batch=8))
    parent = np.asarray(derive_parents(g, res.dist,
                                       weights=jnp.asarray(
                                           np.where(np.isfinite(w), w,
                                                    np.inf))))
    dist = np.asarray(res.dist)
    src_np, dst_np = g.edge_arrays_np()
    w_np = w[: g.n_edges]
    for i in range(8):
        for v in range(g.n_nodes):
            p = parent[i, v]
            if v == i or not np.isfinite(dist[i, v]):
                continue
            assert p >= 0
            lanes = (src_np == p) & (dst_np == v)
            assert lanes.any()
            assert np.isclose(dist[i, p] + w_np[lanes].min(), dist[i, v],
                              rtol=1e-5)


# -- engine auto == public API auto (satellite: _pick deleted) --------------

def test_public_auto_is_engine_dispatch():
    import repro.core.sssp as sssp_mod
    assert not hasattr(sssp_mod, "_pick")
    g = gen.disconnected(4, 30, 3.0, seed=31)
    res = multi_source(g, np.arange(12), method="auto")
    np.testing.assert_array_equal(np.asarray(res.dist),
                                  bfs_dists(g, np.arange(12)))
    assert np.asarray(res.parent).shape == res.dist.shape
    # eccentricity is the max productive sweep count over sources
    dm = np.asarray(res.dist)
    assert int(res.eccentricity) == int(dm.max())


# -- serving: weighted queries in the batching loop -------------------------

def test_graph_service_weighted_and_unweighted_flush():
    from repro.serve import GraphQuery, GraphService
    g = gen.watts_strogatz(128, 6, 0.1, seed=1)
    rng = np.random.default_rng(0)
    w = rng.uniform(0.5, 3.0, g.m_pad).astype(np.float32)
    svc = GraphService(g, weights=w, max_batch=16)
    for i in range(6):
        svc.submit(GraphQuery(qid=i, source=i,
                              target=None if i % 2 else 100))
    for i in range(6, 12):
        svc.submit(GraphQuery(qid=i, source=i, weighted=True,
                              target=None if i % 2 else 100))
    served = svc.flush()
    assert len(served) == 12 and svc.pending() == 0
    from oracles import dijkstra_dist
    for q in served:
        if q.weighted:
            ref = dijkstra_dist(g, w, q.source)
            if q.target is None:
                np.testing.assert_allclose(q.dist, ref, rtol=1e-5)
            else:
                np.testing.assert_allclose(q.cost, ref[q.target], rtol=1e-5)
        else:
            ref = bfs_dist(g, q.source)
            if q.target is None:
                np.testing.assert_array_equal(q.dist, ref)
            else:
                assert q.hops == int(ref[q.target])


def test_graph_service_rejects_weighted_without_weights():
    from repro.serve import GraphQuery, GraphService
    g = gen.grid2d(8, 8)
    svc = GraphService(g, max_batch=8)
    with pytest.raises(ValueError):
        svc.submit(GraphQuery(qid=0, source=0, weighted=True))
