"""Dynamic graphs: interleaved update/query stream, repair vs scratch.

The tentpole claim for the streaming tier: on a locality-heavy update
stream, frontier-seeded incremental repair (core/incremental.py) answers
every post-update query **bit-identically** to a from-scratch recompute
while executing strictly fewer sweeps.  Both halves are asserted
in-bench before the JSON row is written:

  * after every update batch, the repaired ``(dist, parent)`` must equal
    the scratch ``sssp_state`` of the mutated graph exactly;
  * over the whole stream, ``repair_sweeps < scratch_sweeps``.

Emitted hard-gate fields (deterministic given the seeds — any change
means the algorithm did different work): ``repair_sweeps``,
``scratch_sweeps``, ``repair_equals_scratch``, the epoch counters
``n_epochs`` / ``n_compactions``, and ``query_checksum`` (the summed
hop answers of the interleaved point queries).  Wall-clock replays of
the same recorded stream (repair-driver vs scratch-per-batch) ride the
usual advisory ``_median`` timing gate.

    PYTHONPATH=src python -m benchmarks.bench_dynamic [--out f.json]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.incremental import IncrementalSSSP, sssp_state
from repro.graph import generators as gen
from repro.graph.dynamic import DynamicCSRGraph

from ._timing import time_interleaved_stats

Batch = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]

_SOURCES = np.array([0, 1, 2, 3], np.int32)
_QUERIES_PER_ROUND = 4


def _record_stream(g, n_rounds: int, per_round: int,
                   seed: int) -> List[Batch]:
    """Seeded locality-heavy stream: every batch touches one small index
    window (a 32-node working set), mixing shortcut inserts with deletes
    of the shortcuts added two rounds earlier — the shape that keeps the
    taint/reseed frontier small relative to the graph."""
    rng = np.random.default_rng(seed)
    n = g.n_nodes
    batches: List[Batch] = []
    history: List[np.ndarray] = []
    for _ in range(n_rounds):
        center = int(rng.integers(0, n))
        lo, hi = max(0, center - 16), min(n, center + 16)
        u = rng.integers(lo, hi, size=per_round)
        v = rng.integers(lo, hi, size=per_round)
        keep = u != v
        u, v = u[keep], v[keep]
        ins_src = np.concatenate([u, v]).astype(np.int64)   # undirected
        ins_dst = np.concatenate([v, u]).astype(np.int64)
        if len(history) >= 2:
            old = history.pop(0)
            del_src, del_dst = old[0], old[1]
        else:
            del_src = del_dst = np.zeros(0, np.int64)
        history.append(np.stack([ins_src, ins_dst]))
        batches.append((ins_src, ins_dst, del_src, del_dst))
    return batches


def _apply(dg: DynamicCSRGraph, batch: Batch) -> None:
    ins_src, ins_dst, del_src, del_dst = batch
    dg.insert_edges(ins_src, ins_dst)
    if del_src.size:
        dg.delete_edges(del_src, del_dst)


def _run_family(name: str, g, stream: List[Batch], seed: int,
                repeats: int) -> Dict:
    rng = np.random.default_rng(seed + 1)

    # -- accounting pass: repair with scratch shadow, bit-identity gated --
    dg = DynamicCSRGraph(g, compact_threshold=0.001)
    inc = IncrementalSSSP(dg, _SOURCES)
    scratch_sweeps = inc.scratch_sweeps     # both paths pay the initial run
    query_checksum = 0
    identical = True
    for batch in stream:
        _apply(dg, batch)
        inc.update()
        shadow, sweeps = sssp_state(dg, _SOURCES)
        scratch_sweeps += sweeps
        identical &= bool(
            np.array_equal(inc.dist_int(), shadow.dist_int())
            and np.array_equal(inc.parent, shadow.parent))
        targets = rng.integers(0, g.n_nodes, size=_QUERIES_PER_ROUND)
        query_checksum += int(inc.dist_int()[0, targets].sum())
    assert identical, f"{name}: repair diverged from scratch"
    assert inc.repair_sweeps < scratch_sweeps, (
        f"{name}: repair did not beat scratch "
        f"({inc.repair_sweeps} vs {scratch_sweeps} sweeps)")

    # -- timing pass: replay the same recorded stream both ways -----------
    def replay_repair():
        d = DynamicCSRGraph(g, compact_threshold=0.001)
        drv = IncrementalSSSP(d, _SOURCES)
        for b in stream:
            _apply(d, b)
            drv.update()
        np.asarray(drv.dist)

    def replay_scratch():
        d = DynamicCSRGraph(g, compact_threshold=0.001)
        sssp_state(d, _SOURCES)
        for b in stream:
            _apply(d, b)
            sssp_state(d, _SOURCES)

    stats = time_interleaved_stats(
        {"repair": replay_repair, "scratch": replay_scratch},
        max(2, repeats))

    row: Dict = {"n_nodes": g.n_nodes, "n_edges": g.n_edges,
                 "n_sources": int(_SOURCES.size),
                 "n_rounds": len(stream),
                 "repair_sweeps": inc.repair_sweeps,
                 "scratch_sweeps": scratch_sweeps,
                 "repair_equals_scratch": identical,
                 "n_epochs": int(dg.epoch),
                 "n_compactions": int(dg.compactions),
                 "rebuilds": inc.rebuilds,
                 "query_checksum": query_checksum}
    for mode, st in stats.items():
        row[f"t_{mode}"] = st["best"]
        row[f"t_{mode}_median"] = st["median"]
    row["sweep_ratio"] = round(scratch_sweeps /
                               max(inc.repair_sweeps, 1), 2)
    row["repair_speedup"] = row["t_scratch"] / row["t_repair"]
    return row


def run(quick: bool = False, repeats: int = 3,
        csv: Optional[List[str]] = None) -> Dict:
    n_rounds = 6 if quick else 12
    fams = {
        "ws_locality": gen.watts_strogatz(2048, 8, 0.05, seed=3),
        "grid_locality": gen.grid2d(40, 40),
    }
    families: Dict[str, Dict] = {}
    for name, g in fams.items():
        stream = _record_stream(g, n_rounds, per_round=6, seed=11)
        families[name] = _run_family(name, g, stream, seed=11,
                                     repeats=repeats)

    if csv is not None:
        for name, row in families.items():
            csv.append(
                f"dynamic_{name},{row['t_repair'] * 1e6:.0f},"
                f"repair_vs_scratch_sweeps={row['repair_sweeps']}/"
                f"{row['scratch_sweeps']} "
                f"speedup={row['repair_speedup']:.2f}")
    return {"benchmark": "bench_dynamic", "families": families}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    result = run(quick=args.quick, repeats=args.repeats)
    text = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
