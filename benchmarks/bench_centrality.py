"""Batched centrality analytics: NumPy per-source loop vs the jit-batched
counting engine vs the Pallas kernel path.

For each family, one source set runs the full analytics bundle
(closeness + harmonic + eccentricity + exact betweenness) three ways:

  * ``loop``    — the pre-subsystem style: textbook per-source queue-BFS
                  Brandes in NumPy (reimplemented here; the shape of the
                  old per-block host loop taken to its sequential limit);
  * ``batched`` — ``repro.core.centrality.centrality`` through the
                  counting-semiring sweep engine (XLA reference forms);
  * ``kernel``  — the same with the fused counting Pallas kernel
                  (interpret mode off-TPU: op-by-op exactness check, not
                  a speed claim — the relative loop-vs-batched ordering
                  is what CI watches).

The JSON carries the hard-gate fields (``n_nodes``/``n_edges``/
``n_sources``/``sweeps``) plus ``sigma_checksum`` — the sum of
shortest-path counts over reachable pairs, an exact integer-in-f32
fingerprint of the counting work that the regression gate pins hard: a
changed checksum means the algorithm counted different paths, not that
the machine was slow.  Betweenness results are asserted equal across all
three paths before any timing.

    PYTHONPATH=src python -m benchmarks.bench_centrality [--quick] [--out f.json]
"""
from __future__ import annotations

import argparse
import json
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import CentralityConfig, centrality, prepare_graph
from repro.graph import generators as gen

from ._timing import time_interleaved_stats

FAMILIES: Dict[str, Callable] = {
    "ws_small": lambda: gen.watts_strogatz(256, 6, 0.05, seed=3),
    "grid_road": lambda: gen.grid2d(16, 16),
}

QUICK_FAMILIES = ("ws_small",)

MEASURES = ("closeness", "harmonic", "eccentricity", "betweenness")


def _numpy_loop_centrality(g, sources) -> np.ndarray:
    """The sequential baseline: per-source queue BFS + Brandes stack,
    pure NumPy/Python — returns the betweenness vector (the other
    measures fall out of the same per-source pass and are folded into
    the same loop so the comparison is bundle-vs-bundle)."""
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    n = g.n_nodes
    bc = np.zeros(n)
    clo = np.zeros(len(sources))
    har = np.zeros(len(sources))
    ecc = np.zeros(len(sources), np.int32)
    for i, s in enumerate(np.asarray(sources)):
        s = int(s)
        dist = np.full(n, -1, np.int32)
        sigma = np.zeros(n)
        pred: List[List[int]] = [[] for _ in range(n)]
        dist[s] = 0
        sigma[s] = 1.0
        order = []
        q = deque([s])
        while q:
            u = q.popleft()
            order.append(u)
            for e in range(indptr[u], indptr[u + 1]):
                v = indices[e]
                if v >= n:
                    continue
                if dist[v] < 0:
                    dist[v] = dist[u] + 1
                    q.append(v)
                if dist[v] == dist[u] + 1:
                    sigma[v] += sigma[u]
                    pred[v].append(u)
        reach = dist > 0
        r, tot = int(reach.sum()), int(dist[reach].sum())
        clo[i] = (r / max(n - 1, 1)) * (r / tot) if tot else 0.0
        har[i] = (1.0 / dist[reach]).sum()
        ecc[i] = dist.max(initial=0)
        delta = np.zeros(n)
        for w in reversed(order):
            for v in pred[w]:
                delta[v] += sigma[v] / sigma[w] * (1.0 + delta[w])
            if w != s:
                bc[w] += delta[w]
    return bc


def run(quick: bool = False, n_sources: int = 32, repeats: int = 3,
        csv: Optional[List[str]] = None) -> Dict:
    names = QUICK_FAMILIES if quick else tuple(FAMILIES)
    families = {}
    for name in names:
        g = FAMILIES[name]()
        pg = prepare_graph(g)
        sources = np.arange(min(n_sources, g.n_nodes), dtype=np.int32)
        row: Dict = {"n_nodes": g.n_nodes, "n_edges": g.n_edges,
                     "n_sources": int(len(sources))}
        cfg = CentralityConfig(source_batch=32, use_kernel=False)
        cfg_k = CentralityConfig(source_batch=32, use_kernel=True)

        # exactness across all three paths before any timing
        res_b = centrality(pg, sources, measures=MEASURES, config=cfg)
        res_k = centrality(pg, sources, measures=MEASURES, config=cfg_k)
        bc_loop = _numpy_loop_centrality(g, sources)
        np.testing.assert_allclose(res_b.betweenness, bc_loop,
                                   rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(res_k.betweenness, res_b.betweenness,
                                   rtol=1e-6, atol=1e-9)
        assert res_k.sigma_checksum == res_b.sigma_checksum
        row["sweeps"] = int(res_b.sweeps)
        row["sigma_checksum"] = float(res_b.sigma_checksum)

        def go_loop():
            _numpy_loop_centrality(g, sources)

        def go_batched():
            centrality(pg, sources, measures=MEASURES, config=cfg)

        def go_kernel():
            centrality(pg, sources, measures=MEASURES, config=cfg_k)

        stats = time_interleaved_stats(
            {"loop": go_loop, "batched": go_batched,
             "kernel": go_kernel}, repeats)
        for mode, st in stats.items():
            row[f"t_{mode}"] = st["best"]
            row[f"t_{mode}_median"] = st["median"]
        row["batched_speedup_vs_loop"] = row["t_loop"] / row["t_batched"]
        families[name] = row
        if csv is not None:
            csv.append(
                f"centrality_{name},{row['t_batched'] * 1e6:.1f},"
                f"batched_vs_loop={row['batched_speedup_vs_loop']:.2f}x")
    return {
        "benchmark": "bench_centrality",
        "measures": list(MEASURES),
        "families": families,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--sources", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    result = run(quick=args.quick, n_sources=args.sources,
                 repeats=args.repeats)
    text = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
