"""Checkpointing: manifest + per-leaf raw-bytes shards, async writes,
integrity hashes, resume, and re-mesh on restore (elastic restart).

Layout:
    <dir>/step_000000123/
        MANIFEST.json     {step, meta?, leaves: {path: {file, shape,
                           dtype, sha256}}}
        0000.bin ...      raw leaf bytes (bf16-safe; dtype+shape come
                           from the manifest, not a container format)
A checkpoint directory is atomic: written to ``.tmp`` then renamed — and
any stale ``.tmp`` left by a crashed earlier write is purged first, never
merged — so a crash mid-write never corrupts the latest-pointer.
``latest_step``/``all_steps`` scan complete checkpoints only.  ``meta``
is an optional JSON-serializable job-identity blob embedded in the
manifest; the resumable-job layer (:mod:`repro.core.jobs`) uses it to
refuse resuming a checkpoint written by a different job.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> list[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True,
         keep: int = 3, meta: Optional[dict] = None
         ) -> threading.Thread | None:
    """Save pytree. ``blocking=False`` hands a host *snapshot* to a writer
    thread (device->host transfer AND a defensive copy happen before
    returning, so the caller may donate or mutate its buffers
    immediately).  ``meta`` (JSON-serializable) is embedded in the
    manifest — job identity for resume checks."""
    # np.array(copy=True), not np.asarray: for a leaf that is already a
    # host ndarray, asarray is a no-copy view — the async writer would
    # read a buffer the caller keeps mutating (a torn checkpoint).
    host_tree = jax.tree.map(
        lambda x: np.array(jax.device_get(x), copy=True), tree)

    def write():
        final = os.path.join(ckpt_dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        # a stale .tmp from a crashed earlier write would silently merge
        # its leftover leaf files into this checkpoint: purge, never merge
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        if meta is not None:
            manifest["meta"] = meta
        for i, (path, leaf) in enumerate(_leaf_paths(host_tree)):
            fname = f"{i:04d}.bin"
            fpath = os.path.join(tmp, fname)
            arr = np.asarray(leaf)
            raw = arr.tobytes()          # raw bytes: bf16-safe
            with open(fpath, "wb") as f:
                f.write(raw)
            digest = hashlib.sha256(raw).hexdigest()
            manifest["leaves"][path] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": arr.dtype.name, "sha256": digest}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") \
                and os.path.exists(os.path.join(ckpt_dir, d, "MANIFEST.json")):
            out.append(int(d[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """The checkpoint's MANIFEST.json: step, optional ``meta`` job
    identity, and the per-leaf {file, shape, dtype, sha256} table."""
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        return json.load(f)


def restore(ckpt_dir: str, step: int, like, *, verify: bool = True,
            shardings=None):
    """Restore into the structure of ``like``.  ``shardings`` (optional
    pytree of NamedSharding matching ``like``) re-shards onto the *current*
    mesh — this is the elastic-restart path: a checkpoint written on a
    512-chip mesh restores onto whatever mesh is alive now."""
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    manifest = read_manifest(ckpt_dir, step)

    import ml_dtypes  # jax dependency; provides bfloat16 etc.
    paths = [p for p, _ in _leaf_paths(like)]
    leaves = []
    for path in paths:
        ent = manifest["leaves"][path]
        fpath = os.path.join(d, ent["file"])
        with open(fpath, "rb") as f:
            raw = f.read()
        if verify:
            digest = hashlib.sha256(raw).hexdigest()
            if digest != ent["sha256"]:
                raise IOError(f"checkpoint corruption in {path}: "
                              f"{digest} != {ent['sha256']}")
        try:
            dtype = np.dtype(ent["dtype"])
        except TypeError:
            dtype = np.dtype(getattr(ml_dtypes, ent["dtype"]))
        leaves.append(np.frombuffer(raw, dtype=dtype
                                    ).reshape(ent["shape"]).copy())

    treedef = jax.tree_util.tree_structure(like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s),
                            tree, shardings)
    else:
        tree = jax.tree.map(jax.device_put, tree)
    return tree, manifest["step"]


class CheckpointHook:
    """Async checkpoint writer with single-writer discipline.

    ``__call__`` is the training-loop hook (save every ``interval``
    steps); ``submit`` saves unconditionally — the resumable-job layer
    (:mod:`repro.core.jobs`) drives it at chunk boundaries.  At most one
    writer thread is ever in flight: ``policy="join"`` blocks until the
    previous write lands, ``policy="skip"`` drops the new snapshot
    instead (counted in ``skipped``) so a slow filesystem never stalls
    the sweep loop.  ``pending`` exposes the in-flight thread; call
    ``flush()`` before shutdown so the last write is durable.
    """

    def __init__(self, ckpt_dir: str, interval: int = 100, keep: int = 3,
                 policy: str = "join"):
        if policy not in ("join", "skip"):
            raise ValueError(f"policy must be 'join' or 'skip': {policy!r}")
        self.dir = ckpt_dir
        self.interval = interval
        self.keep = keep
        self.policy = policy
        self.written = 0
        self.skipped = 0
        self._pending: threading.Thread | None = None

    @property
    def pending(self) -> threading.Thread | None:
        """The in-flight writer thread (None when idle)."""
        return self._pending

    def submit(self, step: int, tree, *, meta: Optional[dict] = None
               ) -> bool:
        """Start an async save of ``tree`` at ``step``.  Returns False iff
        ``policy="skip"`` dropped it because a write is still in flight."""
        if self._pending is not None:
            if self.policy == "skip" and self._pending.is_alive():
                self.skipped += 1
                return False
            self._pending.join()        # one in-flight write at a time
        self._pending = save(self.dir, step, tree, blocking=False,
                             keep=self.keep, meta=meta)
        self.written += 1
        return True

    def __call__(self, step, params, opt_state, metrics):
        if (step + 1) % self.interval:
            return
        self.submit(step + 1, {"params": params, "opt": opt_state})

    def flush(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
