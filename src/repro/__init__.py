"""repro — DAWN (matrix-operation shortest paths) as a production JAX framework.

The caller-facing surface is the unified ``dawn`` facade (``repro.api``):

    import repro as dawn

    h = dawn.prepare(graph)          # CSRGraph or DynamicCSRGraph
    row = h.sssp(0)
    res = h.apsp(semiring="tropical")
    svc = h.serve(n_landmarks=16)

Per-semiring entry points (``repro.core.apsp_engine`` & co.) still work
but are deprecated for external callers; ``tests/test_api_surface.py``
pins this module's ``__all__`` so the surface cannot grow silently.
"""
from .api import DawnGraph, SEMIRING_NAMES, prepare
from .core.incremental import (IncrementalSSSP, IncrementalState,
                               RepairResult, repair, sssp_state)
from .core.options import SweepOptions
from .graph.csr import CSRGraph
from .graph.dynamic import DynamicCSRGraph

__version__ = "1.1.0"

__all__ = [
    "CSRGraph",
    "DawnGraph",
    "DynamicCSRGraph",
    "IncrementalSSSP",
    "IncrementalState",
    "RepairResult",
    "SEMIRING_NAMES",
    "SweepOptions",
    "prepare",
    "repair",
    "sssp_state",
]
