"""Public-API surface guard + deprecation-shim behavior.

The checked-in snapshot below IS the caller-facing surface of the
package: the unified ``dawn`` facade plus the dynamic-graph types it
fronts.  Growing it is an API decision — update the snapshot in the
same PR and say why — not a side effect of an import added somewhere.
"""
import subprocess
import sys
import warnings

import repro

# the snapshot: repro.__all__, frozen
PUBLIC_SURFACE = [
    "CSRGraph",
    "DawnGraph",
    "DynamicCSRGraph",
    "IncrementalSSSP",
    "IncrementalState",
    "RepairResult",
    "SEMIRING_NAMES",
    "SweepOptions",
    "prepare",
    "repair",
    "sssp_state",
]


def test_public_surface_matches_snapshot():
    assert sorted(repro.__all__) == sorted(PUBLIC_SURFACE)
    for name in repro.__all__:
        assert hasattr(repro, name), f"__all__ exports missing {name}"


def test_importing_repro_does_not_touch_attic():
    code = ("import sys, repro, repro.core, repro.serve, repro.graph; "
            "bad = [m for m in sys.modules if m.startswith('repro._attic')]; "
            "assert not bad, bad; print('clean')")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert "clean" in out.stdout


def test_old_entry_points_warn_exactly_once():
    """Each deprecated per-semiring entry point warns on first call only.

    Runs in a subprocess: the warn-once latch is per-process state, and
    other tests in this session may already have tripped it.
    """
    code = """
import warnings
import numpy as np
from repro.core import apsp_engine, counting_apsp, weighted_apsp
from repro.graph import generators as gen

g = gen.watts_strogatz(32, 4, 0.1, seed=0)
w = np.ones(g.m_pad, np.float32)
for fn, args in ((apsp_engine, (g, [0])),
                 (counting_apsp, (g, [0])),
                 (weighted_apsp, (g, w, [0]))):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        fn(*args)
        fn(*args)
    dep = [x for x in rec if issubclass(x.category, DeprecationWarning)
           and "deprecated" in str(x.message)]
    assert len(dep) == 1, (fn.__name__, [str(x.message) for x in dep])
print('once-each')
"""
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert "once-each" in out.stdout


def test_attic_serving_engine_shim_warns():
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        from repro.serve import ServingEngine  # noqa: F401
    # warn-once latch: a warning fires only if this is the first touch
    # in the process, so just check nothing *else* leaked and the name
    # resolves to the attic module
    import repro._attic.lm_serving as lm
    from repro import serve
    assert serve.ServingEngine is lm.ServingEngine
    assert all(issubclass(x.category, DeprecationWarning) for x in rec)


def test_deprecated_wrappers_preserve_identity():
    from repro.core import apsp_engine, sharded_apsp
    from repro.core.engine import apsp_engine as raw_engine
    from repro.core.distributed import sharded_apsp as raw_sharded
    assert apsp_engine.__wrapped__ is raw_engine
    assert sharded_apsp.__wrapped__ is raw_sharded
    assert apsp_engine.__name__ == "apsp_engine"
