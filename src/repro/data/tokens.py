"""Deterministic synthetic LM token pipeline.

Batches are a pure function of (seed, step) — byte-identical across hosts
and across elastic restarts (each host materializes only its shard of the
global batch; determinism is what makes skip-and-catchup straggler recovery
sound)."""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


def lm_batch(step: int, *, global_batch: int, seq_len: int, vocab: int,
             seed: int = 0) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic tokens (not uniform noise: loss can decrease)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    base = rng.integers(0, vocab, size=(global_batch, 1))
    steps = rng.integers(1, 17, size=(global_batch, seq_len))
    toks = (base + np.cumsum(steps, axis=1)) % vocab
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    pad = seq_len - tokens.shape[1]
    if pad:
        tokens = np.pad(tokens, ((0, 0), (0, pad)))
        labels = np.pad(labels, ((0, 0), (0, pad)))
    return {"tokens": tokens[:, :seq_len], "labels": labels[:, :seq_len]}


def lm_iterator(*, global_batch: int, seq_len: int, vocab: int,
                seed: int = 0, start_step: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield lm_batch(step, global_batch=global_batch, seq_len=seq_len,
                       vocab=vocab, seed=seed)
        step += 1
