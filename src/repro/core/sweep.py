"""The semiring sweep-operator layer — one loop under every DAWN path.

Every bound in the paper (Eqs. 5/10) falls out of a single mechanism: a
*sweep* operator that extends all known shortest paths by one relaxation,
skips already-settled targets (Thm 3.2), and stops at the first sweep that
settles nothing (Fact 1).  Algebraic BFS (Burkhardt 2019) and the paper's
own §5 weighted outlook say the same thing: the machinery is a *semiring*
iteration

    dist' = dist (+)  frontier-restricted ( dist (x) A )

with (+, x) = (∨, ∧) for unweighted BFS, (min, +) for non-negative
weights, (min, id) for label propagation, and (add-on-dist-ties, ·) for
shortest-path counting (the Brandes/betweenness substrate — the one
non-idempotent ⊕ in the set).  This module owns:

  * :class:`Semiring`    — the algebra spec (boolean / tropical / min-label);
  * the three sweep *forms* over identical padded state — dense push GEMM
    (:func:`boolean_forms`/:func:`tropical_forms` ``[PUSH]``), bit-packed
    pull (boolean only), and edge-parallel sparse scatter;
  * :class:`SweepState`  — the unified loop state (``frontier``, ``dist``,
    ``parent``, ``step``, ``sweeps``, ``edges_touched``, ``dir_counts``);
  * :func:`sweep_loop`   — the ONE ``lax.while_loop`` driver in the repo's
    core: every layer (bovm/sovm/bfs/weighted/wcc/distributed/engine)
    instantiates it with a semiring's forms instead of carrying its own
    loop;
  * :func:`derive_parents` — shortest-path-tree post-pass shared by the
    batched paths that do not track parents in-loop;
  * :func:`time_sweep_forms` — the wall-clock calibration primitive behind
    the CPU-path direction choice (see core/engine.py).

A *form* is a callable ``(frontier, dist, parent, step) -> (new_frontier,
dist, parent)``.  ``new_frontier`` is the set of entries improved by the
sweep (int8/bool); Fact-1 convergence is ``~any(new_frontier)`` — for the
boolean semiring "nothing newly discovered", for the tropical semiring
"no distance improved", for min-label "no label lowered".  Forms are
shape-polymorphic over the leading axes: the batched engine runs (S, n)
state, the single-source paths run (n+1,) sentinel-padded state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels import common as kernel_common
from ..kernels import registry as kernel_registry
from .frontier import UNREACHED, pack_bits

PUSH, PULL, SPARSE = 0, 1, 2
DIRECTION_NAMES = ("push", "pull", "sparse")

INF = jnp.float32(jnp.inf)


# --------------------------------------------------------------------------
# semiring specs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Semiring:
    """Algebra spec for a sweep: which (⊕, ⊗) the forms implement.

    ``unreached`` is the ⊕-identity stored for "no path yet"; ``source_dist``
    the ⊗-identity stored at the sources.  The cost-model ``unit`` names
    what one modelled cost count means for this semiring (the engine's
    cost constants are per-unit, see docs/ARCHITECTURE.md).
    """
    name: str
    dist_dtype: Any
    unreached: Any
    source_dist: Any
    unit: str

    def unreached_mask(self, dist: jax.Array) -> jax.Array:
        """Boolean mask of not-yet-settled entries (the Thm 3.2 skip set
        and the pull/push occupancy signal)."""
        if self.name == "tropical":
            return jnp.isinf(dist)
        return dist == jnp.asarray(self.unreached, dist.dtype)


BOOLEAN = Semiring("boolean", jnp.int32, -1, 0,
                   unit="MXU MAC / uint32 word / CSR lane")
TROPICAL = Semiring("tropical", jnp.float32, float("inf"), 0.0,
                    unit="f32 add+min lane / CSR relax lane")
MIN_LABEL = Semiring("min_label", jnp.int32, None, None,
                     unit="CSR min-scatter lane")
# Path counting (Burkhardt's algebraic-BFS companion semiring): the state
# is the PAIR (dist int32, sigma f32) and ⊕ is elementwise ADD of path
# counts, gated on dist-improvement ties — the first non-idempotent ⊕ in
# the repo (OR∘OR = OR and min∘min = min, but add∘add ≠ add), which is
# why the sharded reduction must mask partials before summing (see
# core/distributed.py) instead of just folding epilogue outputs.
COUNTING = Semiring("counting", jnp.int32, -1, 0,
                    unit="f32 MAC / CSR add lane")

SEMIRINGS = {s.name: s for s in (BOOLEAN, TROPICAL, MIN_LABEL, COUNTING)}


# --------------------------------------------------------------------------
# unified loop state + the single while_loop driver
# --------------------------------------------------------------------------

class SweepState(NamedTuple):
    """Loop state shared by every semiring / form / execution path."""
    frontier: jax.Array       # entries improved by the last sweep (int8/bool)
    dist: jax.Array           # distances / labels (semiring dist_dtype)
    parent: jax.Array         # shortest-path tree (int32; (1,) dummy if off)
    step: jax.Array           # scalar int32 — sweeps executed
    done: jax.Array           # scalar bool — Fact 1 fired
    sweeps: jax.Array         # scalar int32 — last *productive* step (= ε)
    edges_touched: jax.Array  # scalar float32 — Eq. 10 useful-work counter
    dir_counts: jax.Array     # (n_forms,) int32 — sweeps run per form


SweepForm = Callable[[jax.Array, jax.Array, jax.Array, jax.Array],
                     Tuple[jax.Array, jax.Array, jax.Array]]


def make_state(frontier: jax.Array, dist: jax.Array,
               parent: Optional[jax.Array] = None, *,
               n_forms: int = 3) -> SweepState:
    """Initial SweepState around caller-built frontier/dist buffers."""
    if parent is None:
        parent = jnp.zeros((1,), jnp.int32)
    return SweepState(frontier=frontier, dist=dist, parent=parent,
                      step=jnp.int32(0), done=jnp.bool_(False),
                      sweeps=jnp.int32(0),
                      edges_touched=jnp.float32(0.0),
                      dir_counts=jnp.zeros(n_forms, jnp.int32))


def sweep_loop(forms: Sequence[SweepForm], state: SweepState, *,
               max_steps, deg: Optional[jax.Array] = None,
               choose: Optional[Callable[[SweepState], jax.Array]] = None,
               forced_dir: int = 0,
               converged: Optional[Callable[[jax.Array], jax.Array]] = None,
               fused: Optional[Callable] = None, fused_steps: int = 0,
               fused_combine: Optional[Callable] = None,
               ) -> SweepState:
    """THE sweep driver — the only ``lax.while_loop`` under repro/core.

    forms      : candidate sweep forms; one runs per iteration.
    max_steps  : static or traced sweep bound (diameter / hop bound).
    deg        : optional out-degree vector; when given, each sweep adds
                 sum(deg[frontier]) to ``edges_touched`` (Eq. 10).
    choose     : traced ``SweepState -> int32`` form index (the per-sweep
                 direction optimizer, dispatched through ``lax.switch``);
                 ``None`` pins ``forms[forced_dir]`` at trace time.
    converged  : Fact-1 test over the new frontier; default
                 ``~any(new)``.  The distributed path overrides it with a
                 psum so all shards agree on termination.
    fused      : optional fused multi-sweep block ``(frontier, dist, step,
                 n_run) -> (new, dist, prod, stopped)`` built by
                 :func:`fused_form` — each loop iteration then executes up
                 to ``fused_steps`` sweeps inside ONE persistent kernel
                 (Fact 1 in-kernel), and the body reconstructs the exact
                 per-sweep accounting from the kernel's (productive-count,
                 converged) pair: a tile's productivity is prefix-
                 contiguous, so the block executed ``prod + 1`` sweeps if
                 it converged and ``n_run`` otherwise.  ``step``,
                 ``sweeps``, ``done``, ``dir_counts`` and the final
                 frontier/dist are bit-identical to the per-sweep path;
                 only ``edges_touched`` is not tracked (stays at its prior
                 value — the fused kernel never materializes per-sweep
                 frontiers to weigh against ``deg``).  ``choose`` must be
                 None (fusion pins one direction).
    fused_combine : optional cross-shard reduction of the block's
                 ``(prod, stopped)`` pair (pmax / psum-all) so every
                 shard of the distributed executor agrees on the loop
                 accounting — the fused analogue of ``converged``.
    """
    forms = tuple(forms)

    def cond(st: SweepState):
        return (~st.done) & (st.step < max_steps)

    if fused is not None:
        assert choose is None, "fused blocks pin one direction"

        def body(st: SweepState):
            n_run = jnp.minimum(jnp.asarray(fused_steps, jnp.int32),
                                jnp.asarray(max_steps, jnp.int32) - st.step)
            new, dist, prod, stopped = fused(st.frontier, st.dist,
                                             st.step, n_run)
            if fused_combine is not None:
                prod, stopped = fused_combine(prod, stopped)
            executed = jnp.where(stopped, prod + 1, n_run)
            return SweepState(
                frontier=new, dist=dist, parent=st.parent,
                step=st.step + executed, done=stopped,
                sweeps=jnp.where(prod > 0, st.step + prod, st.sweeps),
                edges_touched=st.edges_touched,
                dir_counts=st.dir_counts.at[jnp.int32(forced_dir)]
                                        .add(executed))
    else:
        def body(st: SweepState):
            step = st.step + 1
            if choose is None:
                idx = jnp.int32(forced_dir)
                new, dist, parent = forms[forced_dir](st.frontier, st.dist,
                                                      st.parent, step)
            else:
                idx = choose(st)
                new, dist, parent = jax.lax.switch(idx, forms, st.frontier,
                                                   st.dist, st.parent, step)
            if converged is None:
                stop = ~jnp.any(new != 0)
            else:
                stop = converged(new)
            touched = st.edges_touched
            if deg is not None:
                touched = touched + jnp.sum(
                    (st.frontier != 0).astype(jnp.float32) * deg)
            return SweepState(
                frontier=new, dist=dist, parent=parent, step=step, done=stop,
                sweeps=jnp.where(stop, st.sweeps, step),
                edges_touched=touched,
                dir_counts=st.dir_counts.at[idx].add(1))

    return jax.lax.while_loop(cond, body, state)


# --------------------------------------------------------------------------
# fused multi-sweep dispatch (the persistent-kernel capability seam)
# --------------------------------------------------------------------------

def resolve_fused_steps(semiring, form: str, *, fused_steps: int,
                        max_steps: int, use_kernel: bool, n_pad: int,
                        bs: int, budget: Optional[int] = None
                        ) -> Optional[int]:
    """Static fused-block length for an engine run, or ``None`` for the
    per-sweep path.  ``fused_steps`` is the engine config's request: 0 =
    off, -1 = whole fixpoint per invocation, K > 0 = K-sweep blocks.
    Fusion engages only on the kernel path, only when the semiring
    registers a fused form for ``form``, and only when the fused kernel's
    whole-operand VMEM residency (``vmem_bytes(form="fused")``) fits the
    per-core budget — oversized graphs silently fall back to per-sweep
    dispatch rather than blowing VMEM.  ``budget`` overrides the static
    default (engines pass their TuningPlan's per-device figure)."""
    if not fused_steps or not use_kernel or not kernel_registry.has(semiring):
        return None
    ks = kernel_registry.get(semiring)
    if form not in ks.fused_forms:
        return None
    if ks.vmem_bytes(form="fused", bs=bs, n=n_pad) > \
            kernel_common.vmem_limit(budget):
        return None
    return max_steps if fused_steps < 0 else min(fused_steps, max_steps)


def fused_form(semiring, operand, form: str, *, bs: int, max_sweeps: int,
               interpret: bool = True) -> Callable:
    """Close a registered fused multi-sweep kernel over its operand —
    the fused analogue of the per-sweep form closures.  The result has
    the ``sweep_loop(fused=...)`` contract: ``(frontier, dist, step,
    n_run) -> (new, dist, prod, stopped)``, where ``dist`` is the loop
    state's dist slot (the (dist, sigma) pair for counting)."""
    kern = kernel_registry.get(semiring).fused_forms[form]

    def fused(f, state, step, n_run):
        return kern(f, operand, state, step, n_run, bs=bs,
                    max_sweeps=max_sweeps, interpret=interpret)

    return fused


# --------------------------------------------------------------------------
# boolean semiring forms (unweighted BFS — paper Algs. 1/2)
# --------------------------------------------------------------------------

def _pull_chunk_size(n_pad: int, preferred: int) -> int:
    for c in (preferred, 512, 256, 128):
        if c <= n_pad and n_pad % c == 0:
            return c
    return n_pad


def _pull_kernel_wk(words: int) -> int:
    for wk in (128, 64, 32, 16, 8, 4):
        if words % wk == 0:
            return wk
    return words


def boolean_forms(adj, adj_pull, src_idx, dst_idx, *, n_pad: int, s: int,
                  bn: int = 128, bk: int = 128, pull_chunk: int = 512,
                  use_kernel: bool = False, interpret: bool = True,
                  track_parent: bool = False,
                  accum_dtype=jnp.float32) -> Tuple[SweepForm, ...]:
    """(push, pull, sparse) boolean sweep forms over identical state —
    the single source of truth for what each direction dispatches, shared
    by the batch driver, the single-source paths, and the calibration
    measurement.

    ``adj``/``adj_pull``/``src_idx``/``dst_idx`` may be dummies when the
    caller has resolved a form that never dispatches the others (a pinned
    ``forced_dir`` traces only its own operands); ``n_pad`` is therefore
    passed explicitly rather than read off ``adj``.  ``track_parent``
    maintains the shortest-path tree in-loop on the sparse form (any
    active in-neighbor, max src id wins — the same tie-break
    :func:`derive_parents` applies as a post-pass).

    ``use_kernel`` swaps the push/pull closures for the boolean Pallas
    kernels looked up in :mod:`repro.kernels.registry`.  BOTH kernel
    directions read the bit-packed ``adj_pull`` operand (the kernel push
    is the packed word-AND/OR sweep — no f32 GEMM on the boolean kernel
    path); ``adj`` feeds only the XLA reference push.
    """
    bs = min(s, 128)
    chunk = _pull_chunk_size(n_pad, pull_chunk)
    wk = _pull_kernel_wk(max(n_pad // 32, 1))

    if use_kernel:
        K = kernel_registry.get(BOOLEAN).forms
        # The kernel push is bit-packed (paper Eq. 13): it drives the SAME
        # word-AND/OR math as pull over ``adj_pull`` — whose word width may
        # be rectangular (a sharded K-row block packs n/C contraction rows)
        # — so its word tile comes off the operand, not n_pad.  The f32
        # GEMM push survives as the registry's "push_f32" form.
        wk_push = _pull_kernel_wk(adj_pull.shape[1])

        def push(f, d, p, step):
            new, dist = K["push"](pack_bits(f != 0), adj_pull, d, step,
                                  bs=bs, bn=bn, wk=wk_push,
                                  interpret=interpret)
            return new, dist, p

        def pull(f, d, p, step):
            new, dist = K["pull"](pack_bits(f != 0), adj_pull, d,
                                  step, bs=min(s, 8), bn=bn, wk=wk,
                                  interpret=interpret)
            return new, dist, p
    else:
        def push(f, d, p, step):
            counts = jax.lax.dot_general(
                f.astype(accum_dtype), adj.astype(accum_dtype),
                (((f.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=accum_dtype)
            new = (counts > 0) & (d == UNREACHED)
            return new.astype(jnp.int8), jnp.where(new, step, d), p

        def pull(f, d, p, step):
            # chunked oracle for the packed pull sweep — bounds the
            # (S, C, W) broadcast intermediate to ~chunk * S * W words
            fp = pack_bits(f != 0)                       # (S, W)
            blocks = adj_pull.reshape(n_pad // chunk, chunk, -1)

            def one(block):                              # (C, W) uint32
                return jnp.any(fp[:, None, :] & block[None], axis=-1)

            hits = jnp.moveaxis(jax.lax.map(one, blocks), 0, 1)
            hits = hits.reshape(f.shape)
            new = hits & (d == UNREACHED)
            return new.astype(jnp.int8), jnp.where(new, step, d), p

    def sparse(f, d, p, step):
        # batched SOVM sweep (paper Alg. 2 / Eq. 9 union as scatter-OR)
        active = f[..., src_idx] != 0
        hits = jnp.zeros(d.shape, jnp.bool_).at[..., dst_idx].max(active)
        new = hits & (d == UNREACHED)
        if track_parent:
            pcand = jnp.full(d.shape, -1, jnp.int32).at[..., dst_idx].max(
                jnp.where(active, src_idx, -1))
            p = jnp.where(new, pcand, p)
        return new.astype(jnp.int8), jnp.where(new, step, d), p

    return push, pull, sparse


# --------------------------------------------------------------------------
# tropical semiring forms (weighted SSSP — paper §5 extension)
# --------------------------------------------------------------------------

def tropical_forms(wdense, src_idx, dst_idx, w_edges, *,
                   n_pad: int = 0, chunk: int = 128,
                   use_frontier: bool = True,
                   use_kernel: bool = False, interpret: bool = True,
                   bn: int = 128, bk: int = 128,
                   eb: int = 128) -> Tuple[SweepForm, ...]:
    """(dense, sparse) (min,+) sweep forms.

    dense  — the f32 min-plus GEMM-analogue of the boolean push sweep:
             ``cand[s, j] = min_k (dist[s, k] + W[k, j])`` over frontier
             rows.  ``wdense`` is (n_pad, n_pad) f32 with +inf non-edges
             (pass ``None`` when only the sparse form runs).  Reference
             path: ``chunk`` destination columns per ``lax.map`` step so
             the (S, chunk, n) broadcast stays bounded.  Kernel path
             (``use_kernel=True``): the fused Pallas min-plus sweep with
             settled-bound tile skipping, looked up in
             :mod:`repro.kernels.registry` exactly as
             :func:`boolean_forms` does.
    sparse — edge-parallel relaxation: ``cand = dist[src] + w`` scattered
             with min into ``dst`` — Bellman-Ford restricted to the
             improved frontier (sound for non-negative weights:
             un-improved sources cannot produce new improvements).
             ``use_frontier=False`` relaxes every edge every sweep (the
             level-synchronous baseline semantics; reference path only).
             Kernel path: the edge-parallel Pallas relax over CSR lane
             blocks (batched 2D state only) — *interpret mode only*: its
             dynamic gathers/scatters are interpret-validated and its
             whole-(S, n_pad)-state VMEM footprint is unbounded in
             ``n_pad``, so a compiled (real-TPU) kernel path dispatches
             the XLA sparse form instead, per the registry notes.

    Fact 1 generalizes: the new frontier is the improved set, and a sweep
    that improves nothing terminates.  Sweep count is bounded by the
    longest shortest path's hop count (Bellman-Ford depth).
    """
    def sparse_ref(f, d, p, step):
        cand = d[..., src_idx] + w_edges
        if use_frontier:
            cand = jnp.where(f[..., src_idx] != 0, cand, INF)
        nd = d.at[..., dst_idx].min(cand)
        new = nd < d
        return new.astype(jnp.int8), nd, p

    if use_kernel:
        assert use_frontier, "kernel path is frontier-gated by construction"
        ks = kernel_registry.get(TROPICAL)
        K = ks.forms
        # min finite edge weight — drives the kernel's settled-skip table
        # (padded lanes are +inf and fall out of the min)
        w_min = jnp.min(w_edges)

        dense = None
        if wdense is not None:
            def dense(f, d, p, step):
                fd = jnp.where(f != 0, d, INF)           # frontier rows only
                new, nd = K["dense"](fd, wdense, d, w_min,
                                     bs=min(f.shape[0], 128), bn=bn,
                                     bk=bk, interpret=interpret)
                return new, nd, p

        if not ks.dispatchable("sparse", interpret=interpret):
            sparse = sparse_ref    # compiled path: XLA scatter-min relax
        else:
            def sparse(f, d, p, step):
                new, nd = K["sparse"](f, d, src_idx, dst_idx, w_edges,
                                      eb=eb, interpret=interpret)
                return new, nd, p

        return dense, sparse

    dense = None
    if wdense is not None:
        def dense(f, d, p, step):
            fd = jnp.where(f != 0, d, INF)               # frontier rows only
            cand = minplus_candidates(fd, wdense, chunk=chunk)
            nd = jnp.minimum(d, cand)
            new = nd < d
            return new.astype(jnp.int8), nd, p

    return dense, sparse_ref


def minplus_candidates(fd: jax.Array, wdense: jax.Array, *,
                       chunk: int = 128) -> jax.Array:
    """The (min,+) matrix product ``cand[s, j] = min_k fd[s, k] + W[k, j]``
    — the GEMM-analogue behind the dense tropical form, factored out so
    the sharded executor can run it on a rectangular (K, N) row-block of
    the weight matrix (its k-partial sweeps).  ``chunk`` destination
    columns per ``lax.map`` step bound the (S, chunk, K) broadcast
    intermediate."""
    kdim, ndim = wdense.shape
    c = _pull_chunk_size(ndim, chunk)
    blocks = wdense.T.reshape(ndim // c, c, kdim)        # (nb, C, K) in-wts

    def one(block):                                      # (C, K)
        return jnp.min(fd[:, None, :] + block[None], axis=-1)

    cand = jnp.moveaxis(jax.lax.map(one, blocks), 0, 1)
    return cand.reshape(fd.shape[:-1] + (ndim,))


# --------------------------------------------------------------------------
# min-label semiring form (connected components)
# --------------------------------------------------------------------------

def minlabel_form(src_idx, dst_idx) -> SweepForm:
    """Min-label propagation sweep: ``labels[dst] ⊕= labels[src]`` with
    ⊕ = min.  Pass symmetrized edge arrays for *weakly* connected
    components.  The frontier is the changed-label set; Fact 1 is "no
    label lowered"."""
    def sweep(f, labels, p, step):
        nl = labels.at[..., dst_idx].min(labels[..., src_idx])
        changed = nl < labels
        return changed.astype(jnp.int8), nl, p
    return sweep


# --------------------------------------------------------------------------
# counting semiring forms (shortest-path counting — Brandes stage 1)
# --------------------------------------------------------------------------

def counting_forms(adj, src_idx, dst_idx, *, n_pad: int = 0, s: int = 0,
                   bn: int = 128, bk: int = 128,
                   use_kernel: bool = False,
                   interpret: bool = True) -> Tuple[SweepForm, SweepForm]:
    """(push, sparse) counting sweep forms.

    The loop state's ``dist`` slot is the PAIR ``(dist int32, sigma
    f32)``: ``dist`` is exactly the boolean semiring's level array and
    ``sigma[s, v]`` counts shortest s→v paths.  Because unweighted BFS is
    level-synchronous, *every* shortest path to a node first reached at
    this sweep enters through the current frontier, so one f32 matmul of
    frontier-masked sigma against the adjacency produces the complete
    count:

        cand[s, j] = Σ_k (frontier ? sigma : 0)[s, k] · A[k, j]
        new        = (cand > 0) & (dist == UNREACHED)
        dist'      = new ? step : dist          (the boolean update)
        sigma'     = new ? cand : sigma         (⊕ = add, gated on ties)

    ⊕ is elementwise ADD — non-idempotent, unlike OR/min — so partial
    candidates (sharded K-blocks, sparse scatter lanes) must be SUMMED
    exactly once per edge before the gate; the scatter-add form below and
    the sharded executor's masked-add reduction both preserve that.
    Counts are f32: exact up to 2^24 paths per (source, node) pair —
    beyond that the add rounds (documented in docs/ARCHITECTURE.md).

    ``adj`` is the dense int8 operand (a (1, 1) dummy when only sparse
    dispatches); ``use_kernel`` swaps the push closure for the fused
    counting Pallas kernel looked up in :mod:`repro.kernels.registry`.
    Settledness makes the boolean o_occ table sound here: sigma only
    changes where dist improves, so a tile with no unreached target
    cannot change either half of the state.
    """
    if use_kernel:
        K = kernel_registry.get(COUNTING).forms
        bs = min(s, 128) if s else 128

        def push(f, ds, p, step):
            d, sg = ds
            fs = jnp.where(f != 0, sg, 0.0)
            new, nd, nsg = K["push"](fs, adj, d, sg, step, bs=bs, bn=bn,
                                     bk=bk, interpret=interpret)
            return new, (nd, nsg), p
    else:
        def push(f, d_pair, p, step):
            d, sg = d_pair
            fs = jnp.where(f != 0, sg, 0.0)
            cand = jax.lax.dot_general(
                fs, adj.astype(jnp.float32),
                (((fs.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            new = (cand > 0) & (d == UNREACHED)
            return (new.astype(jnp.int8),
                    (jnp.where(new, step, d), jnp.where(new, cand, sg)), p)

    def sparse(f, d_pair, p, step):
        # edge-parallel scatter-ADD: each CSR lane contributes its source's
        # sigma once (lanes are deduped), so the sum over in-lanes is the
        # exact path count — the non-idempotent analogue of SOVM's
        # scatter-OR
        d, sg = d_pair
        contrib = jnp.where(f[..., src_idx] != 0, sg[..., src_idx], 0.0)
        cand = jnp.zeros(d.shape, jnp.float32).at[..., dst_idx].add(contrib)
        new = (cand > 0) & (d == UNREACHED)
        return (new.astype(jnp.int8),
                (jnp.where(new, step, d), jnp.where(new, cand, sg)), p)

    return push, sparse


# --------------------------------------------------------------------------
# shortest-path tree post-pass
# --------------------------------------------------------------------------

def derive_parents(g, dist: jax.Array, *, weights=None) -> jax.Array:
    """Parent of v = any in-neighbor u on a shortest path (max u id wins —
    the same deterministic tie-break as the in-loop sparse tracking).

    Unweighted: ``dist[u] + 1 == dist[v]``.  Weighted (pass ``weights``):
    ``dist[u] + w(u, v) == dist[v]`` — exact because the sweeps computed
    dist[v] as that very f32 sum for at least one in-neighbor.

    dist is (..., n) over real nodes; one sparse pass over the padded CSR
    lanes, vmappable / jittable.
    """
    n = g.n_nodes
    pad = jnp.zeros(dist.shape[:-1] + (1,), dist.dtype)
    d = jnp.concatenate([dist, pad], axis=-1)           # sentinel column
    du, dv = d[..., g.src], d[..., g.dst]
    if weights is None:
        ok = (du != UNREACHED) & (dv == du + 1)
    else:
        w = jnp.where(g.src < n, weights, INF)
        ok = jnp.isfinite(du) & (dv == du + w)
    cand = jnp.where(ok, g.src, -1)
    par = jnp.full(d.shape, -1, jnp.int32).at[..., g.dst].max(cand)
    return par[..., :n]


# --------------------------------------------------------------------------
# wall-clock form calibration (the CPU-path direction signal)
# --------------------------------------------------------------------------

_CALIBRATION_SWEEPS = 8
_CALIBRATION_REPS = 5


def time_sweep_forms(forms: Sequence[SweepForm], frontier, dist,
                     parent: Optional[jax.Array] = None, *,
                     n_sweeps: int = _CALIBRATION_SWEEPS,
                     reps: int = _CALIBRATION_REPS) -> Tuple[float, ...]:
    """Median wall-clock seconds per sweep for each form on the given
    mid-BFS state.  Times a jitted block of ``n_sweeps`` chained sweeps so
    per-dispatch timer noise is drowned; the frontier must evolve or XLA
    hoists the loop-invariant sweep out of the fori_loop, so ``dist`` is
    refreshed every other sweep to keep the frontier alive.  Fixed-shape
    XLA sweeps cost the same at any occupancy, so one measurement
    characterizes every sweep of a run (see core/engine.py calibration).
    """
    if parent is None:
        parent = jnp.zeros((1,), jnp.int32)

    def chained(form):
        def go(fr, d, p):
            def body(i, c):
                new, dd, pp = form(c[0], c[1], c[2], i + 1)
                # dist may be a pytree (the counting semiring carries a
                # (dist, sigma) pair) — refresh every leaf
                refreshed = jax.tree_util.tree_map(
                    lambda orig, upd: jnp.where(i % 2 == 1, orig, upd),
                    d, dd)
                return (new, refreshed, pp)
            return jax.lax.fori_loop(0, n_sweeps, body, (fr, d, p))
        return jax.jit(go)

    costs = []
    for form in forms:
        fn = chained(form)
        jax.block_until_ready(fn(frontier, dist, parent))  # compile + warm
        samples = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(frontier, dist, parent))
            samples.append(time.perf_counter() - t0)
        costs.append(sorted(samples)[reps // 2] / n_sweeps)
    return tuple(costs)
