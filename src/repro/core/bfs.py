"""Baseline BFS implementations (the paper's comparison targets).

The paper benchmarks against GAP (direction-optimizing CPU BFS, C++) and
Gunrock (GPU).  Offline we provide three baselines:

  * ``bfs_queue_numpy``   — textbook queue BFS (paper Alg. 3) in Python/numpy;
                            the priority-queue-bound reference semantics.
  * ``bfs_scipy``         — scipy.sparse.csgraph C implementation; our
                            "GAP stand-in": a compiled, cache-tuned CPU BFS.
  * ``bfs_level_sync_jax``— level-synchronous BFS on the *same JAX substrate*
                            as DAWN, but WITHOUT the Thm 3.2 skip: every
                            sweep re-relaxes every edge and writes via
                            min-reduction.  DAWN vs this isolates the
                            algorithmic contribution on equal footing.
                            Expressed through the shared sweep layer as
                            the tropical semiring with unit weights and
                            ``use_frontier=False`` — min-plus relaxation
                            over all edges IS level-synchronous BFS.
"""
from __future__ import annotations

from collections import deque
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph
from . import sweep as S
from .frontier import UNREACHED


def bfs_queue_numpy(g: CSRGraph, source: int) -> np.ndarray:
    """Paper Alg. 3 — the oracle for all correctness tests."""
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    n = g.n_nodes
    dist = np.full(n, -1, dtype=np.int32)
    dist[source] = 0
    q = deque([source])
    while q:
        u = q.popleft()
        for e in range(indptr[u], indptr[u + 1]):
            v = indices[e]
            if v < n and dist[v] < 0:
                dist[v] = dist[u] + 1
                q.append(v)
    return dist


def bfs_scipy(g: CSRGraph, source: int) -> np.ndarray:
    """Compiled-C BFS via scipy.sparse.csgraph (GAP stand-in)."""
    import scipy.sparse.csgraph as csgraph
    d = csgraph.shortest_path(g.to_scipy(), method="D", unweighted=True,
                              indices=source, directed=True)
    d = np.where(np.isinf(d), -1, d).astype(np.int32)
    return d


class BfsState(NamedTuple):
    dist: jax.Array
    step: jax.Array
    done: jax.Array


@partial(jax.jit, static_argnames=("max_steps",))
def bfs_level_sync_jax(g: CSRGraph, source, *, max_steps=None) -> BfsState:
    """Level-synchronous BFS without DAWN's skip: each sweep relaxes every
    edge (dist[dst] = min(dist[dst], dist[src]+1)) — the matrix-substrate
    baseline DAWN is measured against.  Tropical semiring, unit weights,
    frontier gating off."""
    n = g.n_nodes
    max_steps = n if max_steps is None else max_steps
    src = jnp.asarray(source, jnp.int32)
    dist0 = jnp.full(n + 1, S.INF).at[src].set(0.0)
    w = jnp.where(g.src < n, jnp.float32(1.0), S.INF)

    _, sparse = S.tropical_forms(None, g.src, g.dst, w, use_frontier=False)
    st = S.sweep_loop((sparse,),
                      S.make_state(jnp.ones(n + 1, jnp.int8), dist0,
                                   n_forms=1),
                      max_steps=max_steps)
    dist = jnp.where(jnp.isinf(st.dist), UNREACHED,
                     st.dist.astype(jnp.int32))[:n]
    return BfsState(dist, st.step, st.done)
