"""Randomized property tests on system invariants.

Every property has a seeded ``pytest.mark.parametrize`` variant that
ALWAYS runs — parameters are derived from the seed through
``np.random.default_rng``, so the sampled space matches the hypothesis
strategies without depending on hypothesis being installed.  When
hypothesis IS available (CI installs it via ``pip install -e .[test]``),
the adaptive ``*_hypothesis`` variants run on top; when it isn't, they
simply don't exist — no environment-dependent skips either way.
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.graph.csr import CSRGraph
from repro.core import (sovm_sssp, bovm_sssp, pack_bits, unpack_bits,
                        popcount)
from repro.models.recsys import embedding_bag, embedding_bag_ragged

from oracles import bfs_dist


# -- DAWN == queue BFS on random graphs --------------------------------------

def _check_dawn_equals_bfs(n, avg_deg, seed, directed, source):
    rng = np.random.default_rng(seed)
    m = max(1, int(n * avg_deg))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    if not directed:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    g = CSRGraph.from_edges(src, dst, n)
    s = source % n
    ref = bfs_dist(g, s)
    np.testing.assert_array_equal(np.asarray(sovm_sssp(g, s).dist), ref)
    np.testing.assert_array_equal(
        np.asarray(bovm_sssp(g.to_dense(), s).dist), ref)


@pytest.mark.parametrize("seed", range(12))
def test_dawn_equals_bfs_on_random_graphs(seed):
    rng = np.random.default_rng(seed * 7919 + 1)
    _check_dawn_equals_bfs(int(rng.integers(2, 121)),
                           float(rng.uniform(0.5, 6.0)),
                           int(rng.integers(0, 10**6)),
                           bool(rng.integers(0, 2)),
                           int(rng.integers(0, 10**6)))


# -- bit-packing round-trips -------------------------------------------------

def _check_pack_unpack(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.random((3, n)) < 0.5
    packed = pack_bits(jnp.asarray(x))
    back = np.asarray(unpack_bits(packed, n))
    np.testing.assert_array_equal(back, x)
    np.testing.assert_array_equal(np.asarray(popcount(packed)),
                                  x.sum(axis=1))


@pytest.mark.parametrize("seed", range(10))
def test_pack_unpack_roundtrip(seed):
    rng = np.random.default_rng(seed * 6007 + 5)
    _check_pack_unpack(int(rng.integers(1, 201)),
                       int(rng.integers(0, 10**6)))


# -- ragged == fixed embedding bags ------------------------------------------

def _check_embedding_bag(v, d, bags, maxlen, seed, mode):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    lens = rng.integers(0, maxlen + 1, bags)
    idx_fixed = np.full((bags, maxlen), -1, np.int64)
    flat, seg = [], []
    for b in range(bags):
        ids = rng.integers(0, v, lens[b])
        idx_fixed[b, :lens[b]] = ids
        flat.extend(ids)
        seg.extend([b] * lens[b])
    fixed = embedding_bag(table, jnp.asarray(idx_fixed), mode=mode)
    if flat:
        ragged = embedding_bag_ragged(
            table, jnp.asarray(np.array(flat)),
            jnp.asarray(np.array(seg)), bags, mode=mode)
        np.testing.assert_allclose(np.asarray(fixed), np.asarray(ragged),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("seed", range(5))
def test_embedding_bag_ragged_equals_fixed(seed, mode):
    rng = np.random.default_rng(seed * 4001 + 9)
    _check_embedding_bag(int(rng.integers(2, 51)),
                         int(rng.integers(1, 17)),
                         int(rng.integers(1, 9)),
                         int(rng.integers(1, 7)),
                         int(rng.integers(0, 10**6)), mode)


# -- triangle inequality -----------------------------------------------------

def _check_triangle_inequality(seed):
    """Shortest-path distances satisfy d(s,v) <= d(s,u) + 1 per edge."""
    rng = np.random.default_rng(seed)
    n = 80
    m = 240
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    g = CSRGraph.from_edges(src, dst, n)
    dist = np.asarray(sovm_sssp(g, 0).dist)
    s_np, d_np = g.edge_arrays_np()
    for a, b in zip(s_np, d_np):
        if dist[a] >= 0:
            assert dist[b] >= 0 and dist[b] <= dist[a] + 1


@pytest.mark.parametrize("seed", range(8))
def test_triangle_inequality(seed):
    _check_triangle_inequality(seed * 2003 + 17)


# -- hypothesis variants (adaptive search on top of the seeded slices) -------

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(2, 120), avg_deg=st.floats(0.5, 6.0),
           seed=st.integers(0, 10**6), directed=st.booleans(),
           source=st.integers(0, 10**6))
    def test_dawn_equals_bfs_hypothesis(n, avg_deg, seed, directed,
                                        source):
        _check_dawn_equals_bfs(n, avg_deg, seed, directed, source)

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 200), seed=st.integers(0, 10**6))
    def test_pack_unpack_roundtrip_hypothesis(n, seed):
        _check_pack_unpack(n, seed)

    @settings(max_examples=20, deadline=None)
    @given(v=st.integers(2, 50), d=st.integers(1, 16),
           bags=st.integers(1, 8), maxlen=st.integers(1, 6),
           seed=st.integers(0, 10**6),
           mode=st.sampled_from(["sum", "mean"]))
    def test_embedding_bag_ragged_equals_fixed_hypothesis(
            v, d, bags, maxlen, seed, mode):
        _check_embedding_bag(v, d, bags, maxlen, seed, mode)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10**6))
    def test_triangle_inequality_hypothesis(seed):
        _check_triangle_inequality(seed)
