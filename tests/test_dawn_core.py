"""DAWN core correctness: BOVM/SOVM vs queue-BFS and scipy oracles,
complexity-claim verification (Eqs. 5/10/13), WCC, path reconstruction."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.graph import generators as gen
from repro.core import (bovm_msbfs, bovm_sssp, bfs_queue_numpy, bfs_scipy,
                        bfs_level_sync_jax, multi_source, sssp, sovm_sssp,
                        sovm_msbfs, wcc_stats, reconstruct_path, UNREACHED)
from oracles import bfs_dist, bfs_dists

GRAPHS = {
    "grid": lambda: gen.grid2d(10, 13),
    "rmat_undir": lambda: gen.rmat(8, 4, directed=False, seed=1),
    "rmat_dir": lambda: gen.rmat(8, 4, directed=True, seed=2),
    "ws": lambda: gen.watts_strogatz(300, 6, 0.1, seed=3),
    "disconnected": lambda: gen.disconnected(6, 40, 3.0, seed=4),
    "er_dir": lambda: gen.erdos_renyi(257, 2.5, seed=5),
    "mycielskian": lambda: gen.mycielskian(7),
}


@pytest.fixture(params=list(GRAPHS), scope="module")
def graph(request):
    return GRAPHS[request.param]()


@pytest.mark.parametrize("source", [0, 3, 17])
def test_sovm_matches_bfs(graph, source):
    source = source % graph.n_nodes
    ref = bfs_dist(graph, source)
    got = np.asarray(sovm_sssp(graph, source).dist)
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("source", [0, 5])
def test_bovm_matches_bfs(graph, source):
    source = source % graph.n_nodes
    ref = bfs_dist(graph, source)
    got = np.asarray(bovm_sssp(graph.to_dense(), source).dist)
    np.testing.assert_array_equal(got, ref)


def test_scipy_oracle_agrees(graph):
    """The library's own baselines agree with each other AND with the
    test suite's independent oracle (tests/oracles.py)."""
    ref = bfs_dist(graph, 1)
    np.testing.assert_array_equal(bfs_queue_numpy(graph, 1), ref)
    np.testing.assert_array_equal(bfs_scipy(graph, 1), ref)


def test_level_sync_baseline(graph):
    ref = bfs_dist(graph, 2)
    got = np.asarray(bfs_level_sync_jax(graph, 2).dist)
    np.testing.assert_array_equal(got, ref)


def test_multi_source_both_methods(graph):
    srcs = np.array([0, 1, 7, 11]) % graph.n_nodes
    refs = bfs_dists(graph, srcs)
    for method in ("sovm", "bovm"):
        got = np.asarray(multi_source(graph, srcs, method=method).dist)
        np.testing.assert_array_equal(got, refs, err_msg=method)


def test_auto_dispatch(graph):
    res = sssp(graph, 0, method="auto")
    np.testing.assert_array_equal(np.asarray(res.dist),
                                  bfs_dist(graph, 0))


def test_sweep_count_equals_eccentricity():
    """DAWN executes exactly ε(i) productive sweeps (Thm 3.2 / Fact 1)."""
    g = gen.grid2d(9, 9)  # diameter 16 from corner
    st = sovm_sssp(g, 0)
    dist = np.asarray(st.dist)
    ecc = dist[dist >= 0].max()
    assert int(st.sweeps) == int(ecc)


def test_sovm_work_is_component_local():
    """Eq. 10: SOVM useful work == E_wcc(i) — edges of the component
    reachable from i (undirected graph), NOT global m."""
    g = gen.disconnected(6, 40, 3.0, seed=7)
    stats = wcc_stats(g)
    src, dst = g.edge_arrays_np()
    labels = stats["labels"]
    st = sovm_sssp(g, 0)
    comp_edges = int((labels[src] == labels[0]).sum())
    assert int(st.edges_touched) == comp_edges
    assert comp_edges < g.n_edges  # strictly component-local


def test_memory_model_eq13():
    """η = (4D+3)/(4D+8) — DAWN vs BFS memory (paper Eq. 13)."""
    g = gen.rmat(8, 8, directed=False, seed=9)
    dawn_b = g.memory_bytes(boolean_frontier=True)
    bfs_b = g.memory_bytes(boolean_frontier=False)
    d_avg = g.n_edges / g.n_nodes
    eta = (4 * d_avg + 3) / (4 * d_avg + 8)
    assert abs(dawn_b / bfs_b - eta) < 1e-9


def test_unreachable_marked():
    g = gen.disconnected(4, 30, 3.0, seed=11)
    dist = np.asarray(sovm_sssp(g, 0).dist)
    assert (dist == UNREACHED).any()
    ref = bfs_dist(g, 0)
    np.testing.assert_array_equal(dist, ref)


def test_parent_reconstruction():
    g = gen.grid2d(8, 8)
    st = sovm_sssp(g, 0)
    dist = np.asarray(st.dist)
    target = 63
    path = reconstruct_path(st.parent, 0, target, g.n_nodes)
    assert path[0] == 0 and path[-1] == target
    assert len(path) - 1 == dist[target]
    # every hop is a real edge
    import scipy.sparse as sp
    adj = g.to_scipy().tocsr()
    for a, b in zip(path[:-1], path[1:]):
        assert adj[a, b] != 0


def test_wcc_matches_scipy(graph):
    import scipy.sparse.csgraph as csgraph
    stats = wcc_stats(graph)
    n_ref, labels_ref = csgraph.connected_components(
        graph.to_scipy(), directed=True, connection="weak")
    assert stats["n_components"] == n_ref
    # same partition (up to relabeling)
    ours = stats["labels"]
    mapping = {}
    for a, b in zip(ours, labels_ref):
        assert mapping.setdefault(a, b) == b


def test_vmapped_msbfs_consistent():
    g = gen.watts_strogatz(200, 6, 0.1, seed=13)
    srcs = jnp.arange(8, dtype=jnp.int32)
    st = sovm_msbfs(g, srcs)
    for i in range(8):
        np.testing.assert_array_equal(np.asarray(st.dist[i]),
                                      bfs_dist(g, i))
