"""DIEN (Deep Interest Evolution Network) — arXiv:1809.03672.

Structure: huge sparse embedding tables → interest extractor (GRU over the
behaviour sequence) → interest evolution (AUGRU gated by target-item
attention) → MLP(200-80) CTR head.

JAX has no native EmbeddingBag: we build it from ``jnp.take`` +
``jax.ops.segment_sum`` (fixed-bag and ragged/offsets variants) — this is a
first-class substrate op, shared with the retrieval scorer.

Sharding: tables row-sharded over ``model`` (canonical recsys layout);
the scorer is data-parallel.  ``retrieval_scores`` scores one query against
10⁶ candidates as a single batched matmul (no loop).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import linear, linear_init, _normal

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp: Tuple[int, ...] = (200, 80)
    n_items: int = 8_000_000
    n_cats: int = 100_000
    n_profile: int = 1_000_000   # user-profile multi-hot vocab
    profile_bags: int = 4
    bag_len: int = 8
    use_aux_loss: bool = True


# -- EmbeddingBag (jnp.take + segment_sum) ------------------------------------

def embedding_bag(table: jax.Array, idx: jax.Array, *,
                  weights: Optional[jax.Array] = None,
                  mode: str = "sum") -> jax.Array:
    """Fixed-shape bags: idx (..., L) -> (..., d).  Padding id = table rows-1
    contributes via explicit mask (idx < 0 → masked)."""
    mask = (idx >= 0)
    safe = jnp.where(mask, idx, 0)
    emb = jnp.take(table, safe, axis=0)               # (..., L, d)
    w = mask.astype(table.dtype)[..., None]
    if weights is not None:
        w = w * weights[..., None]
    out = jnp.sum(emb * w, axis=-2)
    if mode == "mean":
        out = out / jnp.maximum(jnp.sum(w, axis=-2), 1)
    return out


def embedding_bag_ragged(table: jax.Array, flat_idx: jax.Array,
                         segment_ids: jax.Array, n_bags: int, *,
                         mode: str = "sum") -> jax.Array:
    """Ragged bags: flat indices + segment ids -> (n_bags, d) via
    take + segment_sum (the torch EmbeddingBag(offsets=...) equivalent)."""
    emb = jnp.take(table, jnp.maximum(flat_idx, 0), axis=0)
    emb = jnp.where((flat_idx >= 0)[:, None], emb, 0)
    out = jnp.zeros((n_bags + 1, table.shape[1]), table.dtype
                    ).at[segment_ids].add(emb)[:n_bags]
    if mode == "mean":
        cnt = jnp.zeros((n_bags + 1,), table.dtype).at[segment_ids].add(
            (flat_idx >= 0).astype(table.dtype))[:n_bags]
        out = out / jnp.maximum(cnt, 1)[:, None]
    return out


# -- GRU / AUGRU ---------------------------------------------------------------

def gru_init(key, d_in: int, d_h: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s = (d_in + d_h) ** -0.5
    return {"wz": _normal(k1, (d_in + d_h, d_h), s, jnp.float32),
            "wr": _normal(k2, (d_in + d_h, d_h), s, jnp.float32),
            "wh": _normal(k3, (d_in + d_h, d_h), s, jnp.float32),
            "bz": jnp.zeros((d_h,), jnp.float32),
            "br": jnp.zeros((d_h,), jnp.float32),
            "bh": jnp.zeros((d_h,), jnp.float32)}


def _gru_cell(p, h, x, att: Optional[jax.Array] = None):
    xh = jnp.concatenate([x, h], axis=-1)
    z = jax.nn.sigmoid(xh @ p["wz"] + p["bz"])
    r = jax.nn.sigmoid(xh @ p["wr"] + p["br"])
    hh = jnp.tanh(jnp.concatenate([x, r * h], -1) @ p["wh"] + p["bh"])
    if att is not None:                 # AUGRU: attention scales update gate
        z = z * att[:, None]
    return (1 - z) * h + z * hh


def gru_scan(p, xs: jax.Array, att: Optional[jax.Array] = None) -> jax.Array:
    """xs (B, T, d) -> all hidden states (B, T, d_h)."""
    b = xs.shape[0]
    d_h = p["bz"].shape[0]
    h0 = jnp.zeros((b, d_h), jnp.float32)

    def step(h, inp):
        if att is None:
            x = inp
            h = _gru_cell(p, h, x)
        else:
            x, a = inp
            h = _gru_cell(p, h, x, a)
        return h, h

    xs_t = jnp.swapaxes(xs, 0, 1)
    inputs = xs_t if att is None else (xs_t, jnp.swapaxes(att, 0, 1))
    _, hs = jax.lax.scan(step, h0, inputs)
    return jnp.swapaxes(hs, 0, 1)


# -- DIEN ----------------------------------------------------------------------

def dien_init(key, cfg: DIENConfig) -> Params:
    ks = jax.random.split(key, 10)
    d = cfg.embed_dim
    d_beh = 2 * d                   # item ‖ category
    feat_dim = cfg.profile_bags * d + d_beh + cfg.gru_dim + d_beh
    mlp_dims = [feat_dim, *cfg.mlp, 1]
    mlp = [linear_init(k, a, b, bias=True, dtype=jnp.float32)
           for k, a, b in zip(jax.random.split(ks[6], len(mlp_dims) - 1),
                              mlp_dims[:-1], mlp_dims[1:])]
    return {
        "item_table": _normal(ks[0], (cfg.n_items, d), 0.01, jnp.float32),
        "cat_table": _normal(ks[1], (cfg.n_cats, d), 0.01, jnp.float32),
        "profile_table": _normal(ks[2], (cfg.n_profile, d), 0.01,
                                 jnp.float32),
        "gru1": gru_init(ks[3], d_beh, cfg.gru_dim),
        "augru": gru_init(ks[4], cfg.gru_dim, cfg.gru_dim),
        "att_w": _normal(ks[5], (cfg.gru_dim, d_beh), cfg.gru_dim ** -0.5,
                         jnp.float32),
        "mlp": mlp,
        "aux_w": _normal(ks[7], (cfg.gru_dim, d_beh), cfg.gru_dim ** -0.5,
                         jnp.float32),
        "retrieval_proj": _normal(ks[8], (cfg.gru_dim, d), cfg.gru_dim ** -0.5,
                                  jnp.float32),
    }


def _behavior_emb(params, item_ids, cat_ids):
    return jnp.concatenate([jnp.take(params["item_table"], item_ids, axis=0),
                            jnp.take(params["cat_table"], cat_ids, axis=0)],
                           axis=-1)


def dien_forward(params: Params, batch: Dict[str, jax.Array],
                 cfg: DIENConfig):
    """batch: hist_items/hist_cats (B,T), hist_mask (B,T), target_item (B,),
    target_cat (B,), profile (B, bags, bag_len).  Returns (logits, aux)."""
    e_hist = _behavior_emb(params, batch["hist_items"], batch["hist_cats"])
    e_hist = e_hist * batch["hist_mask"][..., None]
    e_tgt = _behavior_emb(params, batch["target_item"], batch["target_cat"])

    h1 = gru_scan(params["gru1"], e_hist)                    # (B,T,gru)
    # target attention over interest states
    scores = jnp.einsum("btd,de,be->bt", h1, params["att_w"], e_tgt)
    scores = jnp.where(batch["hist_mask"] > 0, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    h2 = gru_scan(params["augru"], h1, att=att)[:, -1]       # (B,gru)

    profile = embedding_bag(params["profile_table"], batch["profile"]
                            ).reshape(batch["profile"].shape[0], -1)
    feats = jnp.concatenate(
        [profile, e_tgt, h2, jnp.sum(e_hist, axis=1)], axis=-1)
    h = feats
    for i, lp in enumerate(params["mlp"]):
        h = linear(lp, h)
        if i < len(params["mlp"]) - 1:
            h = jax.nn.relu(h)
    return h[:, 0], h1


def dien_loss(params: Params, batch: Dict[str, jax.Array],
              cfg: DIENConfig) -> jax.Array:
    logits, h1 = dien_forward(params, batch, cfg)
    y = batch["label"].astype(jnp.float32)
    bce = jnp.mean(jnp.maximum(logits, 0) - logits * y
                   + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    if cfg.use_aux_loss and "neg_items" in batch:
        # auxiliary loss: h1_t should score next positive > sampled negative
        e_pos = _behavior_emb(params, batch["hist_items"],
                              batch["hist_cats"])[:, 1:]
        e_neg = _behavior_emb(params, batch["neg_items"],
                              batch["neg_cats"])[:, 1:]
        hs = h1[:, :-1]
        sp = jnp.einsum("btd,de,bte->bt", hs, params["aux_w"], e_pos)
        sn = jnp.einsum("btd,de,bte->bt", hs, params["aux_w"], e_neg)
        m = batch["hist_mask"][:, 1:]
        aux = -(jax.nn.log_sigmoid(sp) + jax.nn.log_sigmoid(-sn)) * m
        bce = bce + jnp.sum(aux) / jnp.maximum(jnp.sum(m), 1)
    return bce


def dien_user_vector(params: Params, batch: Dict[str, jax.Array],
                     cfg: DIENConfig) -> jax.Array:
    """User vector for retrieval: final AUGRU state projected to item space."""
    _, h1 = dien_forward(params, batch, cfg)
    scores = jnp.einsum("btd,de,be->bt", h1, params["att_w"],
                        _behavior_emb(params, batch["target_item"],
                                      batch["target_cat"]))
    att = jax.nn.softmax(jnp.where(batch["hist_mask"] > 0, scores, -1e30), -1)
    h2 = gru_scan(params["augru"], h1, att=att)[:, -1]
    return h2 @ params["retrieval_proj"]                      # (B, d)


def retrieval_scores(params: Params, user_vec: jax.Array,
                     candidate_ids: jax.Array) -> jax.Array:
    """Score users against candidates: one batched matmul.
    user_vec (B, d); candidate_ids (C,) -> (B, C)."""
    cand = jnp.take(params["item_table"], candidate_ids, axis=0)  # (C, d)
    return user_vec @ cand.T


def dien_param_specs(cfg: DIENConfig) -> Params:
    """Tables row-sharded over model; dense scorer replicated."""
    from jax.sharding import PartitionSpec as P

    def walk(tree, path=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}.{k}") for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, path) for v in tree]
        if "table" in path:
            return P("model", None)
        return P(*([None] * tree.ndim))

    shapes = jax.eval_shape(lambda k: dien_init(k, cfg), jax.random.PRNGKey(0))
    return walk(shapes)
