"""Public SSSP/APSP drivers — the paper's user-facing API.

``sssp(graph, source, method="auto")`` picks the execution path:

  * ``auto``  — THE direction-optimizing engine dispatcher
                (core/engine.py): sources tile into batches and every
                sweep runs in the cheapest form (push / pull / sparse)
                chosen by the engine cost model.  There is no separate
                density heuristic here — auto *is* the engine, so the
                public API can never drift from the dispatcher.
  * ``sovm``  — pin the edge-parallel sparse sweep (paper Alg. 2),
                single-source state, in-loop parent tracking.
  * ``bovm``  — pin the dense boolean matmul sweeps (paper Alg. 1 /
                MXU path).

Every result carries a shortest-path-tree ``parent`` array (any
in-neighbor at dist-1; max node id as the deterministic tie-break)
usable with :func:`repro.core.sovm.reconstruct_path`.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph
from .bovm import bovm_msbfs
from .engine import EngineConfig, PreparedGraph, apsp_engine_blocks, \
    prepare_graph
from .sovm import sovm_msbfs, sovm_sssp
from .sweep import derive_parents


class SsspResult(NamedTuple):
    dist: jax.Array          # (n,) or (S, n) int32; -1 unreachable
    eccentricity: jax.Array  # sweeps executed that discovered something
    edges_touched: jax.Array
    # (n,) or (S, n) int32; -1 at sources/unreached.  None when the caller
    # opted out (parents=False — bulk distance consumers skip the
    # O(S · m_pad) derive_parents post-pass)
    parent: Optional[jax.Array]


def _auto_config(n_sources: int) -> EngineConfig:
    b = min(128, max(8, ((n_sources + 7) // 8) * 8))
    return EngineConfig(source_batch=b)


def _engine_sssp(g: Union[CSRGraph, PreparedGraph], sources: np.ndarray,
                 config: Optional[EngineConfig],
                 parents: bool) -> SsspResult:
    """Run sources through the engine dispatcher, attach parents."""
    pg = g if isinstance(g, PreparedGraph) else prepare_graph(g)
    config = config or _auto_config(len(sources))
    rows, ecc, touched = [], jnp.int32(0), jnp.float32(0.0)
    for _, dist, st in apsp_engine_blocks(pg, sources, config=config):
        rows.append(dist)
        ecc = jnp.maximum(ecc, st.sweeps)
        touched = touched + st.edges_touched
    dist = jnp.concatenate(rows, axis=0)
    return SsspResult(dist, ecc, touched,
                      derive_parents(pg.graph, dist) if parents else None)


def sssp(g: Union[CSRGraph, PreparedGraph], source: int, *,
         method: str = "auto", parents: bool = True,
         config: Optional[EngineConfig] = None) -> SsspResult:
    if method == "auto":
        r = _engine_sssp(g, np.asarray([source], np.int32), config, parents)
        return SsspResult(r.dist[0], r.eccentricity, r.edges_touched,
                          r.parent[0] if parents else None)
    graph = g.graph if isinstance(g, PreparedGraph) else g
    if method == "bovm":
        st = bovm_msbfs(graph.to_dense(), jnp.asarray([source], jnp.int32))
        return SsspResult(st.dist[0], st.step - 1, st.edges_touched,
                          derive_parents(graph, st.dist)[0] if parents
                          else None)
    assert method == "sovm", method
    st = sovm_sssp(graph, source)   # parent tracked in-loop (free)
    return SsspResult(st.dist, st.sweeps, st.edges_touched, st.parent)


def multi_source(g: Union[CSRGraph, PreparedGraph],
                 sources: Sequence[int] | jax.Array, *,
                 method: str = "auto", parents: bool = True,
                 config: Optional[EngineConfig] = None) -> SsspResult:
    srcs = np.asarray(sources, np.int32)
    if method == "auto":
        return _engine_sssp(g, srcs, config, parents)
    graph = g.graph if isinstance(g, PreparedGraph) else g
    if method == "bovm":
        st = bovm_msbfs(graph.to_dense(), jnp.asarray(srcs))
        return SsspResult(st.dist, st.step - 1, st.edges_touched,
                          derive_parents(graph, st.dist) if parents
                          else None)
    assert method == "sovm", method
    st = sovm_msbfs(graph, jnp.asarray(srcs))   # parent tracked in-loop
    return SsspResult(st.dist, jnp.max(st.sweeps),
                      jnp.sum(st.edges_touched), st.parent)


def apsp(g: Union[CSRGraph, PreparedGraph], *, block: int = 128,
         method: str = "auto"):
    """All-pairs via blocked multi-source sweeps.  Yields (sources, dist)
    blocks to avoid materializing the full (n, n) matrix for large n.

    method='auto' prepares the graph once so engine operands and the
    calibration cache are shared across every block."""
    if method == "auto" and not isinstance(g, PreparedGraph):
        g = prepare_graph(g)
    n = (g.graph if isinstance(g, PreparedGraph) else g).n_nodes
    for lo in range(0, n, block):
        srcs = jnp.arange(lo, min(lo + block, n), dtype=jnp.int32)
        yield srcs, multi_source(g, srcs, method=method, parents=False).dist


def apsp_dense(g: Union[CSRGraph, PreparedGraph], *, block: int = 128,
               method: str = "auto"):
    """Materialized APSP (small graphs / tests)."""
    rows = [np.asarray(d) for _, d in apsp(g, block=block, method=method)]
    return np.concatenate(rows, axis=0)
