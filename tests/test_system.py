"""End-to-end behaviour: train→checkpoint→crash→restore→resume parity,
and the DAWN public API on a realistic analytics flow."""
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.data import tokens as DT
from repro._attic.models import transformer as T
from repro.train import checkpoint as C
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step, train

CFG = T.LMConfig(name="e2e", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                 d_head=16, d_ff=128, vocab=128)


def _data(start=0):
    return ({"tokens": jnp.asarray(b["tokens"]),
             "labels": jnp.asarray(b["labels"])}
            for b in DT.lm_iterator(global_batch=8, seq_len=32, vocab=128,
                                    start_step=start))


def test_train_loss_decreases_and_resume_is_exact():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    opt = O.adamw(peak_lr=5e-3,
                  schedule=O.cosine_schedule(5e-3, warmup=5, total=60))
    state = opt.init(params)
    step = jax.jit(make_train_step(lambda p, b: T.loss_fn(p, b, CFG), opt))

    losses = []
    hook = lambda i, p, s, m: losses.append(float(m["loss"]))
    with tempfile.TemporaryDirectory() as d:
        ck = C.CheckpointHook(d, interval=10)
        params1, state1, _ = train(params, state, step, _data(),
                                   n_steps=20, hooks=[hook, ck])
        ck.flush()
        assert losses[-1] < losses[0]

        # "crash" and restore from step 20, continue to 30
        like = {"params": params, "opt": state}
        restored, s0 = C.restore(d, C.latest_step(d), like)
        assert s0 == 20
        params2, state2, _ = train(restored["params"], restored["opt"],
                                   step, _data(start=20), n_steps=30,
                                   start_step=20)
        # no-crash reference run to step 30
        params3, state3, _ = train(params1, state1, step, _data(start=20),
                                   n_steps=30, start_step=20)
        for a, b in zip(jax.tree_util.tree_leaves(params2),
                        jax.tree_util.tree_leaves(params3)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_graph_analytics_flow():
    """WCC → per-component APSP blocks → eccentricity — the DAWN public
    API composed the way the examples use it."""
    from repro.core import wcc_stats, multi_source
    from repro.graph import generators as gen
    g = gen.disconnected(4, 50, 3.5, seed=3)
    stats = wcc_stats(g)
    assert stats["n_components"] > 1
    srcs = np.arange(16)
    res = multi_source(g, srcs, method="sovm")
    dist = np.asarray(res.dist)
    # distances within a component are finite, across components -1
    labels = stats["labels"]
    for i, s in enumerate(srcs):
        same = labels == labels[s]
        assert (dist[i][same] >= 0).all()
        assert (dist[i][~same] == -1).all()
