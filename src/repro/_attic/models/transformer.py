"""Decoder-only LM supporting all five assigned transformer archs.

Layers are *stacked* (leading layer axis) and executed with ``lax.scan`` +
``jax.checkpoint`` so 60-90-layer models lower to a single-layer HLO body —
essential for dry-run compile times and for remat memory control.

Supports: GQA/MQA (+ optional QKV bias), MLA, dense MLP (swiglu / relu² /
gelu), MoE blocks (with shared expert and dense residual variants), MTP
(DeepSeek multi-token prediction) and KV-cache decode (GQA cache or
compressed MLA latent cache).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    act: str = "swiglu"
    qkv_bias: bool = False
    rope_theta: float = 1e4
    # MoE (None → dense)
    moe: Optional[L.MoEConfig] = None
    n_dense_layers: int = 0          # leading dense layers before MoE stack
    # MLA (None → GQA)
    mla: Optional[L.MLAConfig] = None
    mtp: bool = False                # DeepSeek multi-token prediction head
    dtype: Any = jnp.bfloat16

    @property
    def attn_cfg(self) -> L.AttnConfig:
        return L.AttnConfig(self.d_model, self.n_heads, self.n_kv,
                            self.d_head, self.qkv_bias, self.rope_theta)

    def n_params(self) -> int:
        """Total parameter count (for MODEL_FLOPS = 6·N·D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora_rank + m.q_lora_rank * self.n_heads
                    * (m.d_nope + m.d_rope) + d * (m.kv_lora_rank + m.d_rope)
                    + m.kv_lora_rank * self.n_heads * (m.d_nope + m.d_v)
                    + self.n_heads * m.d_v * d)
        else:
            attn = d * self.n_heads * self.d_head \
                + 2 * d * self.n_kv * self.d_head + self.n_heads * self.d_head * d
        gate = f if self.act == "swiglu" else 0
        dense_mlp = d * (2 * f + gate) if self.act == "swiglu" else 2 * d * f
        total = 2 * v * d  # embed + head
        if self.moe is None:
            total += self.n_layers * (attn + dense_mlp)
        else:
            mo = self.moe
            expert = 3 * d * mo.d_ff if mo.act == "swiglu" else 2 * d * mo.d_ff
            moe_mlp = mo.n_experts * expert + d * mo.n_experts
            if mo.shared_expert_ff:
                moe_mlp += 3 * d * mo.shared_expert_ff
            if mo.dense_residual_ff:
                moe_mlp += 3 * d * mo.dense_residual_ff
            total += self.n_dense_layers * (attn + dense_mlp)
            total += (self.n_layers - self.n_dense_layers) * (attn + moe_mlp)
        return int(total)

    def n_active_params(self) -> int:
        """Activated params per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.n_params()
        mo = self.moe
        full = self.n_params()
        expert = 3 * self.d_model * mo.d_ff if mo.act == "swiglu" \
            else 2 * self.d_model * mo.d_ff
        n_moe = self.n_layers - self.n_dense_layers
        return int(full - n_moe * (mo.n_experts - mo.top_k) * expert)


# -- init ---------------------------------------------------------------------

def _layer_init(key, cfg: LMConfig, moe: bool) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"ln1": L.norm_init(cfg.d_model, cfg.dtype),
                 "ln2": L.norm_init(cfg.d_model, cfg.dtype)}
    if cfg.mla is not None:
        p["attn"] = L.mla_init(k1, cfg.mla, cfg.dtype)
    else:
        p["attn"] = L.attn_init(k1, cfg.attn_cfg, cfg.dtype)
    if moe:
        p["moe"] = L.moe_init(k2, cfg.moe, cfg.dtype)
    else:
        p["mlp"] = L.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act, cfg.dtype)
    return p


def init_params(key, cfg: LMConfig) -> Params:
    ke, kh, kl, km = jax.random.split(key, 4)
    n_moe = 0 if cfg.moe is None else cfg.n_layers - cfg.n_dense_layers
    n_dense = cfg.n_layers - n_moe
    p: Params = {
        "embed": L._normal(ke, (cfg.vocab, cfg.d_model), 0.02, cfg.dtype),
        "head": L.linear_init(kh, cfg.d_model, cfg.vocab, dtype=cfg.dtype),
        "ln_f": L.norm_init(cfg.d_model, cfg.dtype),
    }
    if n_dense:
        keys = jax.random.split(kl, n_dense)
        p["dense_layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, moe=False))(keys)
    if n_moe:
        keys = jax.random.split(km, n_moe)
        p["moe_layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, moe=True))(keys)
    if cfg.mtp:
        km1, km2 = jax.random.split(jax.random.fold_in(key, 7))
        p["mtp_layer"] = _layer_init(km1, cfg, moe=False)
        p["mtp_proj"] = L.linear_init(km2, 2 * cfg.d_model, cfg.d_model,
                                      dtype=cfg.dtype)
    return p


# -- forward ------------------------------------------------------------------

def _block(p: Params, x: jax.Array, cfg: LMConfig, moe: bool,
           q_block: int | None = None) -> jax.Array:
    """Pre-norm block with a *d-sharded residual stream* (sequence-
    parallel analogue): the carry lives sharded over `model` (remat saves
    1/16th of the activations), each sub-block all-gathers once on entry
    and reduce-scatters on exit (GSPMD converts the o/down psum + sharded
    consumer into a reduce-scatter).  §Perf iterations 2→3."""
    h = L.rmsnorm(p["ln1"], L.hint_replicated(x))
    if cfg.mla is not None:
        a = L.mla_forward(p["attn"], h, cfg.mla, q_block=q_block)
    else:
        a = L.attn_forward(p["attn"], h, cfg.attn_cfg, q_block=q_block)
    x = x + L.hint_activation(a)
    h = L.rmsnorm(p["ln2"], L.hint_replicated(x))
    if moe:
        b, s, d = h.shape
        y = L.moe_forward(p["moe"], h.reshape(b * s, d), cfg.moe)
        x = x + L.hint_activation(y.reshape(b, s, d))
    else:
        x = x + L.hint_activation(L.mlp_forward(p["mlp"], h, cfg.act))
    return x


def _scan_stack(stacked: Params, x: jax.Array, cfg: LMConfig,
                moe: bool, q_block: int | None = None) -> jax.Array:
    def body(h, lp):
        h = L.hint_activation(h)   # carry pinned d-sharded (§Perf iter 3)
        return _block(lp, h, cfg, moe, q_block), None
    body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, L.hint_activation(x), stacked)
    return x


def forward(params: Params, tokens: jax.Array, cfg: LMConfig) -> jax.Array:
    """tokens (B, S) int32 -> logits (B, S, V)."""
    x = L.embed_lookup(params["embed"], tokens, cfg.dtype)
    if "dense_layers" in params:
        x = _scan_stack(params["dense_layers"], x, cfg, moe=False)
    if "moe_layers" in params:
        x = _scan_stack(params["moe_layers"], x, cfg, moe=True)
    x = L.rmsnorm(params["ln_f"], x)
    return L.linear(params["head"], x)


def hidden_forward(params: Params, tokens: jax.Array, cfg: LMConfig,
                   q_block: int | None = None):
    x = L.embed_lookup(params["embed"], tokens, cfg.dtype)
    if "dense_layers" in params:
        x = _scan_stack(params["dense_layers"], x, cfg, moe=False,
                        q_block=q_block)
    if "moe_layers" in params:
        x = _scan_stack(params["moe_layers"], x, cfg, moe=True,
                        q_block=q_block)
    return L.rmsnorm(params["ln_f"], x)


def xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Cross entropy with the gold score taken via one-hot contraction —
    unlike take_along_axis this partitions cleanly when the vocab dim is
    sharded (no logits rematerialization)."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    gold = jnp.sum(jnp.where(iota == labels[..., None], lg, 0.0), axis=-1)
    return jnp.mean(lse - gold)


def loss_fn(params: Params, batch: Dict[str, jax.Array],
            cfg: LMConfig, q_block: int | None = None) -> jax.Array:
    h = hidden_forward(params, batch["tokens"], cfg, q_block=q_block)
    logits = L.linear(params["head"], h)
    loss = xent(logits, batch["labels"])
    if cfg.mtp and "mtp_layer" in params:
        # DeepSeek MTP: one extra block over [h_t ; emb(label_t)] predicts t+2
        emb_next = L.embed_lookup(params["embed"], batch["labels"],
                                  cfg.dtype)
        hm = L.linear(params["mtp_proj"],
                      jnp.concatenate([h, emb_next], axis=-1))
        hm = _block(params["mtp_layer"], hm, cfg, moe=False,
                    q_block=q_block)
        logits2 = L.linear(params["head"], hm[:, :-1])
        labels2 = batch["labels"][:, 1:]
        loss = loss + 0.1 * xent(logits2, labels2)
    return loss


# -- decode -------------------------------------------------------------------

def prefill_step(params: Params, tokens: jax.Array, cfg: LMConfig,
                 *, q_block: int = 2048) -> Tuple[jax.Array, Params]:
    """Full-sequence prefill: query-blocked attention + KV-cache capture.

    tokens (B, L) -> (next-token logits (B, V), cache with pos = L).
    The returned cache is the stacked-layer layout decode_step consumes."""
    b, l = tokens.shape
    x = L.embed_lookup(params["embed"], tokens, cfg.dtype)
    qb = q_block if l % q_block == 0 else None

    caches = {}
    pos = jnp.full((b,), l, jnp.int32)

    def run_stack(name, x, moe):
        stacked = params[name]

        def body(h, lp):
            hn = L.rmsnorm(lp["ln1"], h)
            if cfg.mla is not None:
                out, (latent, kr) = L.mla_forward(lp["attn"], hn, cfg.mla,
                                                  q_block=qb, return_kv=True)
                kv = {"latent": latent, "k_rope": kr}
            else:
                out, (k, v) = L.attn_forward(lp["attn"], hn, cfg.attn_cfg,
                                             q_block=qb, return_kv=True)
                kv = {"k": k, "v": v}
            h = h + out
            hn = L.rmsnorm(lp["ln2"], h)
            if moe:
                bb, ss, dd = hn.shape
                h = h + L.moe_forward(lp["moe"], hn.reshape(bb * ss, dd),
                                      cfg.moe).reshape(bb, ss, dd)
            else:
                h = h + L.mlp_forward(lp["mlp"], hn, cfg.act)
            return h, kv

        return jax.lax.scan(body, x, stacked)

    if "dense_layers" in params:
        x, kv = run_stack("dense_layers", x, moe=False)
        for key, val in kv.items():
            caches.setdefault(key, []).append(val)
    if "moe_layers" in params:
        x, kv = run_stack("moe_layers", x, moe=True)
        for key, val in kv.items():
            caches.setdefault(key, []).append(val)

    cache = {k: jnp.concatenate(v, axis=0) if len(v) > 1 else v[0]
             for k, v in caches.items()}
    cache["pos"] = pos
    x = L.rmsnorm(params["ln_f"], x)
    logits = L.linear(params["head"], x[:, -1])
    return logits, cache


def make_cache(cfg: LMConfig, batch: int, max_len: int) -> Params:
    nl = cfg.n_layers
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "latent": jnp.zeros((nl, batch, max_len, m.kv_lora_rank), cfg.dtype),
            "k_rope": jnp.zeros((nl, batch, max_len, 1, m.d_rope), cfg.dtype),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((nl, batch, max_len, cfg.n_kv, cfg.d_head), cfg.dtype),
        "v": jnp.zeros((nl, batch, max_len, cfg.n_kv, cfg.d_head), cfg.dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params: Params, cache: Params, tokens: jax.Array,
                cfg: LMConfig, active: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Params]:
    """One token of autoregressive decode. tokens (B, 1) int32; ``active``
    (B,) bool freezes inactive rows (continuous batching).

    The stacked (L, ...) cache rides the scan CARRY and is updated with
    dynamic-update-slice — in-place under XLA buffer donation.  (Emitting
    the updated cache as scan ys instead costs a full extra cache copy in
    temp memory — measured +10 GB/device on qwen2 decode_32k, §Perf.)"""
    x = params["embed"][tokens].astype(cfg.dtype)
    pos = cache["pos"]
    n_dense = 0
    stacks = []
    if "dense_layers" in params:
        n_dense = jax.tree_util.tree_leaves(
            params["dense_layers"])[0].shape[0]
        stacks.append(("dense_layers", False, 0, n_dense))
    if "moe_layers" in params:
        n_moe = jax.tree_util.tree_leaves(params["moe_layers"])[0].shape[0]
        stacks.append(("moe_layers", True, n_dense, n_dense + n_moe))

    cache_arrs = {k: v for k, v in cache.items() if k != "pos"}
    for name, is_moe, lo, hi in stacks:
        stacked = params[name]

        def body(carry, xs):
            h, ca = carry
            lp, li = xs
            cl = {k: jax.lax.dynamic_index_in_dim(v, li, 0, keepdims=False)
                  for k, v in ca.items()}
            hn = L.rmsnorm(lp["ln1"], h)
            if cfg.mla is not None:
                out, cu = L.mla_decode(lp["attn"], hn,
                                       {**cl, "pos": pos}, cfg.mla, active)
            else:
                out, cu = L.attn_decode(lp["attn"], hn,
                                        {**cl, "pos": pos}, cfg.attn_cfg,
                                        active)
            h = h + out
            hn = L.rmsnorm(lp["ln2"], h)
            if is_moe:
                b, s_, d = hn.shape
                h = h + L.moe_forward(lp["moe"], hn.reshape(b * s_, d),
                                      cfg.moe).reshape(b, s_, d)
            else:
                h = h + L.mlp_forward(lp["mlp"], hn, cfg.act)
            ca = {k: jax.lax.dynamic_update_index_in_dim(
                      v, cu[k].astype(v.dtype), li, 0)
                  for k, v in ca.items()}
            return (h, ca), None

        (x, cache_arrs), _ = jax.lax.scan(
            body, (x, cache_arrs),
            (stacked, jnp.arange(lo, hi, dtype=jnp.int32)))

    adv = jnp.ones_like(pos) if active is None else active.astype(jnp.int32)
    new_cache = dict(cache_arrs)
    new_cache["pos"] = pos + adv
    x = L.rmsnorm(params["ln_f"], x)
    logits = L.linear(params["head"], x)
    return logits, new_cache


# -- sharding rules -------------------------------------------------------------

def param_specs(cfg: LMConfig) -> Params:
    """PartitionSpec pytree: Megatron TP over ``model`` + FSDP over ``data``.
    Stacked layer params get a leading None axis."""

    def spec_for(path: str, ndim: int) -> P:
        stacked = ".dense_layers." in path or ".moe_layers." in path \
            or path.startswith(("dense_layers.", "moe_layers."))
        lead = (None,) if stacked else ()
        eff = ndim - len(lead)
        if path.endswith((".g", ".b")) or eff == 1:
            return P(*lead, None)
        if "embed" in path:
            # d_model over model, vocab unsharded → token gather is local
            # (vocab-sharding the table turns every lookup into a full
            # rematerialization under SPMD — measured in §Perf)
            return P(None, "model")
        if "head" in path:
            # vocab over model → logits sharded on vocab; replicated over
            # data (all-gather-free head matmul)
            return P(None, "model")
        if ".attn." in path or path.startswith("attn."):
            if ".k." in path or ".v." in path:
                # KV projections: FSDP over data, REPLICATED over model →
                # repeat_kv attention stays head-local (§Perf iteration 1)
                return P(*lead, "data", None) if eff == 2 else P(*lead, None)
            if any(s in path for s in (".q.", ".q_b.", ".kv_b.")):
                return P(*lead, None, "model") if eff == 2 else P(*lead, None)
            if ".o." in path:
                return P(*lead, "model", "data")
            # MLA down-projections (q_a / kv_a): FSDP only
            return P(*lead, "data", None)
        if ".moe." in path:
            if "router" in path:
                return P(*lead, "data", None)
            if eff == 3:  # (E, d, f) expert stacks — EP over model
                return P(*lead, "model", "data", None)
            if ".shared." in path or ".residual." in path:
                if ".down." in path:
                    return P(*lead, "model", "data")
                return P(*lead, "data", "model")
            return P(*lead, None)
        if ".mlp." in path or "mtp" in path:
            if ".down." in path:
                return P(*lead, "model", "data")
            return P(*lead, "data", "model")
        return P(*lead, *([None] * eff))

    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.random.PRNGKey(0))

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}.{k}" if prefix else k)
                    for k, v in tree.items()}
        return spec_for(prefix, tree.ndim)

    return walk(shapes)


def cache_specs(cfg: LMConfig, batch_ax="data",
                model_size: int = 16) -> Params:
    """KV cache sharding: batch over the data axes; heads (or head-dim,
    when n_kv < model size) over ``model``.

    Head/dh sharding keeps the per-token dynamic cache write *local*
    (sharding the sequence dim turns every decode write into a full cache
    all-gather under SPMD — measured 15.4 GB temp on qwen2 decode_32k,
    EXPERIMENTS.md §Perf); attention pays one small score psum instead."""
    if cfg.mla is not None:
        return {"latent": P(None, batch_ax, None, "model"),
                "k_rope": P(None, batch_ax, None, None, "model"),
                "pos": P(batch_ax)}
    if cfg.n_kv % model_size == 0:
        kv_spec = ("model", None)         # shard kv heads
    else:
        kv_spec = (None, "model")         # shard d_head
    return {"k": P(None, batch_ax, None, *kv_spec),
            "v": P(None, batch_ax, None, *kv_spec),
            "pos": P(batch_ax)}
