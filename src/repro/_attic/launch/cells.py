"""Cell builder: (arch × shape × mesh) → a lowerable, shard-annotated step.

This is the hub the dry-run, the roofline pass, and the real launchers all
share.  ``build_cell`` returns the jit-able function, abstract input
ShapeDtypeStructs, and in/out PartitionSpecs for the given mesh — 40 cells
total across the 10 assigned architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_arch, shapes_for
from ..configs.shapes import (GNNShape, LMShape, RecsysShape, pad_to,
                              sampled_sizes)
from ..models import gnn as G
from ..models import recsys as R
from ..models import transformer as T
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step
from repro.launch.mesh import dp_axes, dp_size


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_id: str
    kind: str
    fn: Callable
    args: Tuple[Any, ...]          # ShapeDtypeStruct pytrees
    in_specs: Tuple[Any, ...]      # PartitionSpec pytrees (same structure)
    out_specs: Any
    meta: Dict[str, Any]
    donate: Tuple[int, ...] = ()   # arg indices aliased into outputs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _replicated_like(tree):
    return jax.tree.map(lambda _: P(), tree)


def shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# -- LM cells -------------------------------------------------------------------

def _lm_cfg_for(cfg: T.LMConfig, n_groups: int) -> T.LMConfig:
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_groups=n_groups))


def _lm_train_cell(arch_id, cfg: T.LMConfig, shape: LMShape, mesh) -> Cell:
    dp = dp_axes(mesh)
    dpn = dp_size(mesh)
    b, s = shape.global_batch, shape.seq_len
    per_chip = max(1, b // dpn)
    # §Perf iteration 3: the d-sharded residual stream shrinks remat
    # carries 16×, so larger microbatches fit — fewer FSDP weight
    # re-gathers (collective term scales with accum)
    accum = max(1, per_chip // 4)
    cfg = _lm_cfg_for(cfg, dpn)

    opt = O.adafactor(peak_lr=1e-4) if cfg.moe is not None \
        else O.adamw(peak_lr=3e-4)
    param_shapes = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    opt_shapes = jax.eval_shape(opt.init, param_shapes)
    batch = {"tokens": _sds((b, s), jnp.int32),
             "labels": _sds((b, s), jnp.int32)}
    batch_specs = {"tokens": P(dp, None), "labels": P(dp, None)}

    param_specs = T.param_specs(cfg)
    state_specs = opt.state_specs(param_specs)
    q_block = 1024 if s >= 4096 else None
    # giant-MoE grads don't fit as f32 scan carry → accumulate in bf16
    accum_dtype = jnp.bfloat16 if (cfg.moe is not None
                                   and cfg.n_params() > 1e11) else jnp.float32
    step = make_train_step(
        lambda p, mb: T.loss_fn(p, mb, cfg, q_block=q_block), opt,
        accum=accum, accum_dtype=accum_dtype)
    tokens = b * s
    return Cell(
        arch_id, shape.shape_id, "train", step,
        (param_shapes, opt_shapes, batch),
        (param_specs, state_specs, batch_specs),
        (param_specs, state_specs, None),
        {"family": "lm", "tokens": tokens, "accum": accum,
         "n_params": cfg.n_params(), "n_active": cfg.n_active_params(),
         "model_flops": 6.0 * cfg.n_active_params() * tokens},
        donate=(0, 1))


def _lm_prefill_cell(arch_id, cfg: T.LMConfig, shape: LMShape, mesh) -> Cell:
    dp = dp_axes(mesh)
    cfg = _lm_cfg_for(cfg, dp_size(mesh))
    b, s = shape.global_batch, shape.seq_len
    param_shapes = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    param_specs = T.param_specs(cfg)
    tokens = _sds((b, s), jnp.int32)

    def fn(params, toks):
        return T.prefill_step(params, toks, cfg, q_block=2048)

    cache_out = T.cache_specs(cfg, batch_ax=dp,
                              model_size=mesh.shape["model"])
    return Cell(
        arch_id, shape.shape_id, "prefill", fn,
        (param_shapes, tokens),
        (param_specs, P(dp, None)),
        (P(dp, "model"), cache_out),
        {"family": "lm", "tokens": b * s, "n_params": cfg.n_params(),
         "n_active": cfg.n_active_params(),
         "model_flops": 2.0 * cfg.n_active_params() * b * s})


def _lm_decode_cell(arch_id, cfg: T.LMConfig, shape: LMShape, mesh) -> Cell:
    dp = dp_axes(mesh)
    b, s = shape.global_batch, shape.seq_len
    long_ctx = b == 1
    # decode token counts are tiny: one routing group, d sharded over data
    # in the dispatch buffer (§Perf deepseek decode iteration 2)
    cfg = _lm_cfg_for(cfg, 1)
    param_shapes = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    param_specs = T.param_specs(cfg)
    cache_shapes = jax.eval_shape(lambda: T.make_cache(cfg, b, s))
    msize = mesh.shape["model"]
    cache_specs = T.cache_specs(cfg, batch_ax=None if long_ctx else dp,
                                model_size=msize)
    toks = _sds((b, 1), jnp.int32)
    tok_spec = P(None, None) if long_ctx else P(dp, None)

    def fn(params, cache, t):
        return T.decode_step(params, cache, t, cfg)

    return Cell(
        arch_id, shape.shape_id, "decode", fn,
        (param_shapes, cache_shapes, toks),
        (param_specs, cache_specs, tok_spec),
        (None, cache_specs),
        {"family": "lm", "tokens": b, "kv_len": s,
         "n_params": cfg.n_params(), "n_active": cfg.n_active_params(),
         "model_flops": 2.0 * cfg.n_active_params() * b},
        donate=(1,))


# -- GNN cells -------------------------------------------------------------------

_GNN_LOSS = {
    "graphsage-reddit": lambda p, b, cfg, ng: G.sage_loss(p, b, cfg),
    "meshgraphnet": lambda p, b, cfg, ng: G.mgn_loss(p, b, cfg),
    "schnet": lambda p, b, cfg, ng: G.schnet_loss(p, b, cfg, ng),
    "equiformer-v2": lambda p, b, cfg, ng: G.eqv2_loss(p, b, cfg, ng),
}

_GNN_INIT = {
    "graphsage-reddit": G.sage_init,
    "meshgraphnet": G.mgn_init,
    "schnet": G.schnet_init,
    "equiformer-v2": G.eqv2_init,
}


def _gnn_batch_shapes(arch_id, n_pad, e_pad, d_feat, n_graphs):
    base = {"src": _sds((e_pad,), jnp.int32),
            "dst": _sds((e_pad,), jnp.int32),
            "node_mask": _sds((n_pad,), jnp.bool_)}
    if arch_id == "graphsage-reddit":
        base |= {"feat": _sds((n_pad, d_feat), jnp.float32),
                 "labels": _sds((n_pad,), jnp.int32)}
    elif arch_id == "meshgraphnet":
        base |= {"feat": _sds((n_pad, d_feat), jnp.float32),
                 "pos": _sds((n_pad, 3), jnp.float32),
                 "targets": _sds((n_pad, 2), jnp.float32)}
    else:  # schnet / equiformer: geometric, species-driven
        base |= {"species": _sds((n_pad,), jnp.int32),
                 "pos": _sds((n_pad, 3), jnp.float32),
                 "graph_id": _sds((n_pad,), jnp.int32),
                 "energy": _sds((n_graphs,), jnp.float32)}
    return base


def _gnn_cell(arch_id, cfg, shape: GNNShape, mesh,
              local_sampled: bool = True) -> Cell:
    all_ax = tuple(mesh.axis_names)
    if shape.kind == "sampled":
        n, e = sampled_sizes(shape)
        n_graphs = 1
    elif shape.kind == "batched":
        n, e = shape.n_nodes * shape.n_graphs, shape.n_edges * shape.n_graphs
        n_graphs = shape.n_graphs
    else:
        n, e = shape.n_nodes, shape.n_edges
        n_graphs = 1
    n_pad, e_pad = pad_to(n, 1024), pad_to(e, 1024)

    if arch_id == "graphsage-reddit":
        cfg = dataclasses.replace(cfg, d_in=shape.d_feat)
    elif arch_id == "meshgraphnet":
        cfg = dataclasses.replace(cfg, d_node_in=shape.d_feat)
    # NOTE equiformer-v2 × ogb_products: the edge-chunked two-pass layer
    # (EqV2Config.edge_chunk, exactness-tested) bounds *forward* edge
    # buffers, but reverse-mode through the chunk scan stores the (n, M, C)
    # carry per chunk — full-batch TRAINING at 61.8M edges needs a
    # flash-attention-style custom VJP (two extra edge passes from the
    # softmax statistics). Documented in EXPERIMENTS.md §F; the cell lowers
    # unchunked (compiles; does not fit 16 GiB).

    init = _GNN_INIT[arch_id]
    loss = _GNN_LOSS[arch_id]
    param_shapes = jax.eval_shape(
        lambda: init(jax.random.PRNGKey(0), cfg))
    param_specs = _replicated_like(param_shapes)
    opt = O.adamw(peak_lr=1e-3)
    opt_shapes = jax.eval_shape(opt.init, param_shapes)
    state_specs = opt.state_specs(param_specs)

    batch = _gnn_batch_shapes(arch_id, n_pad, e_pad, shape.d_feat, n_graphs)
    batch_specs = {k: P(all_ax, *([None] * (v.ndim - 1)))
                   if v.shape and v.shape[0] in (n_pad, e_pad) else P()
                   for k, v in batch.items()}

    if shape.kind == "sampled" and local_sampled:
        # §Perf iteration 2: sampled-subgraph training is data-parallel
        # over seed minibatches.  Each device holds self-contained
        # subgraphs with LOCAL node ids (data/graphs.sampled_batch emits
        # per-shard-local blocks), so the whole GNN step runs inside
        # shard_map with zero cross-device traffic except the (tiny)
        # parameter-gradient psum.  Baseline (GSPMD over one flat graph)
        # paid an all-gather of node states per message-passing layer.
        def loss_fn(params, mb):
            def local(params, mbl):
                return jax.lax.pmean(loss(params, mbl, cfg, n_graphs),
                                     all_ax)
            in_specs = (_replicated_like(param_shapes),
                        {k: P(all_ax, *([None] * (v.ndim - 1)))
                         if v.shape and v.shape[0] in (n_pad, e_pad)
                         else P() for k, v in batch.items()})
            return compat.shard_map(local, mesh=mesh, in_specs=in_specs,
                                 out_specs=P())(params, mb)
    else:
        loss_fn = lambda p, mb: loss(p, mb, cfg, n_graphs)
    step = make_train_step(loss_fn, opt)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(param_shapes))
    return Cell(
        arch_id, shape.shape_id, "train", step,
        (param_shapes, opt_shapes, batch),
        (param_specs, state_specs, batch_specs),
        (param_specs, state_specs, None),
        {"family": "gnn", "tokens": n, "edges": e, "n_params": n_params,
         "n_active": n_params,
         "model_flops": 6.0 * n_params * n},
        donate=(0, 1))


# -- recsys cells -----------------------------------------------------------------

def _dien_batch_shapes(cfg: R.DIENConfig, b: int, train: bool):
    t = cfg.seq_len
    base = {
        "hist_items": _sds((b, t), jnp.int32),
        "hist_cats": _sds((b, t), jnp.int32),
        "hist_mask": _sds((b, t), jnp.float32),
        "target_item": _sds((b,), jnp.int32),
        "target_cat": _sds((b,), jnp.int32),
        "profile": _sds((b, cfg.profile_bags, cfg.bag_len), jnp.int32),
    }
    if train:
        base |= {"neg_items": _sds((b, t), jnp.int32),
                 "neg_cats": _sds((b, t), jnp.int32),
                 "label": _sds((b,), jnp.int32)}
    return base


def _recsys_cell(arch_id, cfg: R.DIENConfig, shape: RecsysShape,
                 mesh) -> Cell:
    dp = dp_axes(mesh)
    param_shapes = jax.eval_shape(
        lambda: R.dien_init(jax.random.PRNGKey(0), cfg))
    param_specs = R.dien_param_specs(cfg)
    b = shape.batch
    flat = jax.tree_util.tree_flatten_with_path(param_shapes)[0]
    n_params = sum(x.size for _, x in flat)
    table_params = sum(x.size for kp, x in flat
                       if "table" in jax.tree_util.keystr(kp))
    # active params per example: dense scorer + touched embedding rows
    touched_rows = 2 * cfg.seq_len + 2 + cfg.profile_bags * cfg.bag_len
    n_active = (n_params - table_params) + touched_rows * cfg.embed_dim
    meta = {"family": "recsys", "tokens": b, "n_params": n_params,
            "n_active": n_active, "model_flops": 6.0 * n_active * b}

    if shape.kind == "train":
        opt = O.adamw(peak_lr=1e-3)
        opt_shapes = jax.eval_shape(opt.init, param_shapes)
        state_specs = opt.state_specs(param_specs)
        batch = _dien_batch_shapes(cfg, b, train=True)
        batch_specs = {k: P(dp, *([None] * (v.ndim - 1)))
                       for k, v in batch.items()}
        step = make_train_step(lambda p, mb: R.dien_loss(p, mb, cfg), opt)
        return Cell(arch_id, shape.shape_id, "train", step,
                    (param_shapes, opt_shapes, batch),
                    (param_specs, state_specs, batch_specs),
                    (param_specs, state_specs, None), meta, donate=(0, 1))

    batch = _dien_batch_shapes(cfg, b, train=False)
    if shape.kind == "serve":
        batch_specs = {k: P(dp, *([None] * (v.ndim - 1)))
                       for k, v in batch.items()}

        def fn(params, mb):
            return R.dien_forward(params, mb, cfg)[0]

        return Cell(arch_id, shape.shape_id, "serve", fn,
                    (param_shapes, batch),
                    (param_specs, batch_specs), P(dp),
                    dict(meta, model_flops=2.0 * n_active * b))

    # retrieval: one user vs 1e6 candidates — single batched matmul
    cands = _sds((shape.n_candidates,), jnp.int32)

    def fn(params, mb, cand_ids):
        uv = R.dien_user_vector(params, mb, cfg)
        return R.retrieval_scores(params, uv, cand_ids)

    batch_specs = {k: P(*([None] * v.ndim)) for k, v in batch.items()}
    meta = dict(meta, model_flops=2.0 * shape.n_candidates * cfg.embed_dim
                + 2.0 * n_active)
    return Cell(arch_id, shape.shape_id, "retrieval", fn,
                (param_shapes, batch, cands),
                (param_specs, batch_specs, P(dp)),
                P(None, dp), meta)


# -- entry point -------------------------------------------------------------------

def build_cell(arch_id: str, shape_id: str, mesh) -> Cell:
    family, cfg = get_arch(arch_id)
    shape = shapes_for(arch_id)[shape_id]
    if family == "lm":
        if shape.kind == "train":
            return _lm_train_cell(arch_id, cfg, shape, mesh)
        if shape.kind == "prefill":
            return _lm_prefill_cell(arch_id, cfg, shape, mesh)
        return _lm_decode_cell(arch_id, cfg, shape, mesh)
    if family == "gnn":
        return _gnn_cell(arch_id, cfg, shape, mesh)
    return _recsys_cell(arch_id, cfg, shape, mesh)


def jit_cell(cell: Cell, mesh):
    """jit with explicit shardings, ready for .lower(*args)."""
    return jax.jit(
        cell.fn,
        in_shardings=shardings(mesh, cell.in_specs),
        out_shardings=shardings(mesh, cell.out_specs),
        donate_argnums=cell.donate)
