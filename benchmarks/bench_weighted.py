"""Paper §5 extension: weighted DAWN vs scipy Dijkstra (C implementation)."""
from __future__ import annotations

import time
from typing import List

import numpy as np
import jax.numpy as jnp

from repro.core import dijkstra_oracle, minplus_sssp
from repro.graph import generators as gen


def run(csv: List[str] | None = None, n_sources: int = 8):
    rng = np.random.default_rng(0)
    for name, make in [("grid_road_sm", lambda: gen.grid2d(64, 64)),
                       ("rmat_social_sm",
                        lambda: gen.rmat(10, 8, directed=False, seed=1))]:
        g = make()
        w = rng.uniform(0.5, 4.0, g.m_pad).astype(np.float32)
        wj = jnp.asarray(w)
        srcs = rng.integers(0, g.n_nodes, n_sources)

        minplus_sssp(g, wj, int(srcs[0])).dist.block_until_ready()  # jit
        t0 = time.perf_counter()
        for s in srcs:
            minplus_sssp(g, wj, int(s)).dist.block_until_ready()
        t_dawn = (time.perf_counter() - t0) / n_sources

        t0 = time.perf_counter()
        for s in srcs:
            dijkstra_oracle(g, w, int(s))
        t_dij = (time.perf_counter() - t0) / n_sources
        if csv is not None:
            csv.append(f"weighted_{name},{t_dawn*1e6:.0f},"
                       f"speedup_vs_scipy_dijkstra={t_dij/t_dawn:.2f}")


if __name__ == "__main__":
    out: List[str] = []
    run(csv=out)
    print("\n".join(out))
