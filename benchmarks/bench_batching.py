"""Beyond-paper: multi-source blocked GEMM vs per-source sweeps (DESIGN §9.1)
and the kernel-path work-skipping ratio (tile-skip effectiveness)."""
from __future__ import annotations

import time
from typing import List

import numpy as np
import jax.numpy as jnp

from repro.core import bovm_msbfs, sovm_sssp
from repro.graph import generators as gen


def _time(fn, repeats=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def run(csv: List[str] | None = None):
    g = gen.rmat(10, 8, directed=False, seed=5)
    adj = g.to_dense()
    srcs = jnp.arange(64, dtype=jnp.int32)

    t_batched = _time(lambda: bovm_msbfs(adj, srcs).dist.block_until_ready())

    def seq():
        for s in range(64):
            sovm_sssp(g, s).dist.block_until_ready()

    t_seq = _time(seq)
    sp = t_seq / t_batched
    if csv is not None:
        csv.append(f"batching_bovm64,{t_batched*1e6:.0f},"
                   f"speedup_vs_64xSOVM={sp:.2f}")

    # tile-skip effectiveness: fraction of (i,j,k) tiles skippable per sweep
    from repro.core import one_hot_frontier, UNREACHED
    f = one_hot_frontier(srcs, adj.shape[0], dtype=jnp.int8)
    dist = jnp.where(f > 0, 0, jnp.full(f.shape, UNREACHED))
    total, skipped = 0, 0
    step = 0
    while step < adj.shape[0]:
        step += 1
        gi, gk, gj = 64 // 64, adj.shape[0] // 128, adj.shape[0] // 128
        f_occ = np.asarray(jnp.any(
            f.reshape(gi, 64, gk, 128) != 0, axis=(1, 3)))
        o_occ = np.asarray(jnp.any(
            dist.reshape(gi, 64, gj, 128) < 0, axis=(1, 3)))
        live = f_occ[:, None, :] & o_occ[:, :, None]     # (gi, gj, gk)
        total += live.size
        skipped += live.size - int(live.sum())
        counts = f.astype(jnp.float32) @ adj.astype(jnp.float32)
        new = (counts > 0) & (dist == UNREACHED)
        dist = jnp.where(new, step, dist)
        f = new.astype(jnp.int8)
        if not bool(jnp.any(new)):
            break
    frac = skipped / max(total, 1)
    if csv is not None:
        csv.append(f"tile_skip_fraction,,skipped={frac:.3f}")
    return {"batch_speedup": sp, "tile_skip": frac}


if __name__ == "__main__":
    out: List[str] = []
    print(run(csv=out))
    print("\n".join(out))
