"""The roofline autotuner (core/autotune.py): plan construction, the
determinism lock, serialization, VMEM-budget validation, and the
differential guarantee that tuning may change speed but never results.

Three claims under test (ISSUE 10 acceptance criteria):

  1. Autotuned configs are bit-identical (dist/parent/sigma) to default
     configs on every adversarial family × boolean/tropical/counting ×
     ref/kernel path.
  2. A pinned TuningPlan makes two ``mode="auto"`` runs agree on
     ``direction_counts`` — the plan's analytic argmin replaces the
     wall-clock calibration race (the PR 9 non-determinism).
  3. ``save`` → ``load`` round-trips exactly, refuses a foreign backend
     fingerprint, and every emitted tile shape fits the
     push/pull/fused VMEM budgets of every registered KernelSet.
"""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph.csr import CSRGraph
from repro.core import autotune
from repro.core.autotune import (FORM_VOCAB, TuningPlan, backend_profile,
                                 build_plan, form_units, graph_stats,
                                 tune_tiles)
from repro.core.engine import EngineConfig, apsp_engine, prepare_graph
from repro.core.weighted import WeightedConfig, weighted_apsp
from repro.core.centrality import CentralityConfig, counting_apsp
from repro.kernels import common as kernel_common
from repro.kernels import registry as kernel_registry

from oracles import adversarial_families

_FAMILIES = {name: (src, dst, n)
             for name, src, dst, n in adversarial_families(seed=0)}


def _graph(family):
    src, dst, n = _FAMILIES[family]
    return CSRGraph.from_edges(src, dst, n)


def _sources(n):
    return np.unique(np.clip([0, 1, n // 2, n - 1], 0, n - 1)).astype(
        np.int32)


def _family_weights(g):
    gs, gd = g.edge_arrays_np()
    return ((gs * 7 + gd * 3) % 9 + 1).astype(np.float32)


@pytest.fixture(scope="module")
def plan_cache():
    """One static plan per family (build_plan is deterministic, so
    sharing across tests in the module is sound)."""
    cache = {}

    def get(family):
        if family not in cache:
            cache[family] = build_plan(_graph(family), use_hlo=False)
        return cache[family]

    return get


# --------------------------------------------------------------------------
# differential suite: tuning may change speed, never results
# --------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["ref", "kernel"])
@pytest.mark.parametrize("family", sorted(_FAMILIES))
def test_autotuned_bit_identical_boolean(family, use_kernel, plan_cache):
    g = _graph(family)
    sources = _sources(g.n_nodes)
    base_cfg = EngineConfig(source_batch=8, use_kernel=use_kernel)
    tuned_cfg = dataclasses.replace(base_cfg, tuning=plan_cache(family))
    base = apsp_engine(g, sources, config=base_cfg)
    tuned = apsp_engine(g, sources, config=tuned_cfg)
    np.testing.assert_array_equal(np.asarray(base.dist),
                                  np.asarray(tuned.dist), err_msg=family)
    assert int(base.sweeps) == int(tuned.sweeps), family
    from repro.core import sweep as S
    np.testing.assert_array_equal(
        np.asarray(S.derive_parents(g, base.dist)),
        np.asarray(S.derive_parents(g, tuned.dist)), err_msg=family)


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["ref", "kernel"])
@pytest.mark.parametrize("family", sorted(_FAMILIES))
def test_autotuned_bit_identical_tropical(family, use_kernel, plan_cache):
    g = _graph(family)
    w = _family_weights(g)
    sources = _sources(g.n_nodes)
    base_cfg = WeightedConfig(source_batch=8, use_kernel=use_kernel)
    tuned_cfg = dataclasses.replace(base_cfg, tuning=plan_cache(family))
    base = weighted_apsp(g, w, sources, config=base_cfg)
    tuned = weighted_apsp(g, w, sources, config=tuned_cfg)
    np.testing.assert_array_equal(np.asarray(base.dist),
                                  np.asarray(tuned.dist), err_msg=family)
    assert int(base.sweeps) == int(tuned.sweeps), family


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["ref", "kernel"])
@pytest.mark.parametrize("family", sorted(_FAMILIES))
def test_autotuned_bit_identical_counting(family, use_kernel, plan_cache):
    g = _graph(family)
    sources = _sources(g.n_nodes)
    base_cfg = CentralityConfig(source_batch=8, use_kernel=use_kernel)
    tuned_cfg = dataclasses.replace(base_cfg, tuning=plan_cache(family))
    base = counting_apsp(g, sources, config=base_cfg)
    tuned = counting_apsp(g, sources, config=tuned_cfg)
    np.testing.assert_array_equal(np.asarray(base.dist),
                                  np.asarray(tuned.dist), err_msg=family)
    np.testing.assert_array_equal(np.asarray(base.sigma),
                                  np.asarray(tuned.sigma), err_msg=family)


# --------------------------------------------------------------------------
# the determinism lock (the PR 9 mode="auto" regression)
# --------------------------------------------------------------------------

def test_auto_direction_counts_deterministic_with_plan():
    """Two identical mode="auto" runs with the same pinned plan must
    report identical direction_counts — and the pinned direction is
    exactly the plan's analytic argmin, not a timing race."""
    g = _graph("random_ragged")
    plan = build_plan(g, use_hlo=False)
    cfg = EngineConfig(source_batch=16, mode="auto", use_kernel=False,
                       tuning=plan)
    r1 = apsp_engine(g, config=cfg)
    r2 = apsp_engine(g, config=cfg)
    np.testing.assert_array_equal(np.asarray(r1.direction_counts),
                                  np.asarray(r2.direction_counts))
    pg = prepare_graph(g)
    want = plan.pinned_direction("boolean", s=16, n_pad=pg.n_pad,
                                 m_pad=g.m_pad)
    counts = np.asarray(r1.direction_counts)
    assert counts.sum() > 0
    # every sweep ran in the plan-pinned form
    assert counts[want] == counts.sum(), (counts, want)


def test_auto_deterministic_through_jobs_layer():
    """The same lock holds through the resumable-job layer (chunked
    runs resolve the direction per chunk from the same plan)."""
    from repro.core.jobs import run_sweep_job
    from repro.core.options import SweepOptions
    g = _graph("two_components")
    plan = build_plan(g, use_hlo=False)
    opts = SweepOptions(source_batch=8, mode="auto", use_kernel=False,
                        tuning=plan)
    j1 = run_sweep_job(g, list(range(16)), workload="boolean",
                       options=opts)
    j2 = run_sweep_job(g, list(range(16)), workload="boolean",
                       options=opts)
    np.testing.assert_array_equal(np.asarray(j1.dist), np.asarray(j2.dist))
    np.testing.assert_array_equal(np.asarray(j1.direction_counts),
                                  np.asarray(j2.direction_counts))


@pytest.mark.parametrize("semiring", sorted(FORM_VOCAB))
def test_pinned_direction_is_analytic_argmin(semiring):
    plan = build_plan(_graph("path"), use_hlo=False)
    stats = graph_stats(_graph("path"))
    idx = plan.pinned_direction(semiring, s=8, n_pad=stats.n_pad,
                                m_pad=stats.m_pad)
    vocab = FORM_VOCAB[semiring]
    costs = [plan.unit_cost(semiring, f)
             * form_units(f, s=8, n_pad=stats.n_pad, m_pad=stats.m_pad)
             for f in vocab]
    assert idx == int(np.argmin(costs))
    assert 0 <= idx < len(vocab)


def test_hlo_plan_build_is_deterministic():
    """The HLO-extraction path (exact flop/byte counts off the compiled
    sweep HLO) yields the same plan twice in a process — the property
    wall-clock calibration lacked."""
    g = _graph("two_components")
    w = _family_weights(g)
    p1 = build_plan(g, weights=w, use_hlo=True)
    p2 = build_plan(g, weights=w, use_hlo=True)
    assert p1 == p2
    assert p1.checksum() == p2.checksum()
    assert p1.source == "hlo"
    assert all(c > 0 and np.isfinite(c) for _, _, c in p1.unit_costs)
    # every semiring's full form vocabulary is priced
    for semiring in FORM_VOCAB:
        assert p1.covers(semiring), semiring


# --------------------------------------------------------------------------
# serialization properties
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_plan_save_load_roundtrip(tmp_path, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 200))
    m = int(rng.integers(1, 4 * n))
    g = CSRGraph.from_edges(rng.integers(0, n, m), rng.integers(0, n, m), n)
    plan = build_plan(g, use_hlo=False)
    path = tmp_path / "plan.json"
    plan.save(path)
    loaded = TuningPlan.load(path)
    assert loaded == plan
    assert loaded.checksum() == plan.checksum()
    # the on-disk form is plain sorted JSON (inspectable, diffable)
    with open(path) as f:
        raw = json.load(f)
    assert raw["version"] == autotune.PLAN_VERSION
    assert TuningPlan.from_dict(raw) == plan


def test_plan_load_refuses_foreign_fingerprint(tmp_path):
    plan = build_plan(_graph("tiny"), use_hlo=False)
    alien = dataclasses.replace(plan, backend="tpu:v9000-imaginary")
    path = tmp_path / "alien.json"
    alien.save(path)
    with pytest.raises(ValueError, match="fingerprint"):
        TuningPlan.load(path)
    assert TuningPlan.load(path, allow_mismatch=True) == alien


def test_plan_load_refuses_wrong_version(tmp_path):
    plan = build_plan(_graph("tiny"), use_hlo=False)
    d = plan.to_dict()
    d["version"] = 999
    path = tmp_path / "future.json"
    with open(path, "w") as f:
        json.dump(d, f)
    with pytest.raises(ValueError, match="version"):
        TuningPlan.load(path)


# --------------------------------------------------------------------------
# VMEM-budget validation of emitted tiles
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n_pad", [128, 256, 512, 1024, 4096])
def test_emitted_tiles_fit_every_vmem_budget(n_pad):
    """Every tile shape the tuner emits fits the per-grid-step budgets
    of every registered KernelSet (push/pull/fused estimators) at the
    n_pad it was tuned for — checked through both plan.validate and the
    raw kernels/common.py budget math."""
    prof = backend_profile()
    bs, bn, bk, fused = tune_tiles(prof, n_pad=n_pad)
    assert n_pad % bn == 0 and n_pad % bk == 0
    assert bn in kernel_common.TILE_CANDIDATES
    assert bk in kernel_common.TILE_CANDIDATES
    for semiring in sorted(kernel_registry.available()):
        ks = kernel_registry.get(semiring)
        for form in ks.forms:
            assert ks.vmem_bytes(form=form, bs=bs, bn=bn, bk=bk,
                                 n=n_pad, n_pad=n_pad) \
                <= prof.vmem_budget, (semiring, form)
        if fused:
            for form in ks.fused_forms:
                assert ks.vmem_bytes(form="fused", bs=bs, n=n_pad,
                                     n_pad=n_pad) <= prof.vmem_budget, \
                    (semiring, form)
    # the same invariant through the raw budget math the estimators wrap
    assert kernel_common.push_vmem_bytes(
        bs, bn, bk, f_itemsize=1, a_itemsize=1, d_itemsize=4,
        acc_itemsize=4, out_itemsizes=(1, 4)) <= prof.vmem_budget
    assert kernel_common.pull_vmem_bytes(
        8, bn, max(n_pad // 32, 1), word_itemsize=4, d_itemsize=4,
        acc_itemsize=4, out_itemsizes=(1, 4)) <= prof.vmem_budget


def test_plan_validate_rejects_oversized_tiles():
    plan = build_plan(_graph("random_ragged"), use_hlo=False)
    plan.validate()                      # the emitted plan passes
    bloated = dataclasses.replace(plan, vmem_budget=1024)
    with pytest.raises(ValueError, match="VMEM budget"):
        bloated.validate()


def test_apply_clamps_foreign_tiles_to_divisors():
    """A plan built for a large padding overlays onto a smaller graph
    with its tiles clamped back to MXU_ALIGN when they don't divide —
    shared options objects stay usable across graphs."""
    big = CSRGraph.from_edges([0], [1], 500)         # n_pad = 512
    plan = build_plan(big, use_hlo=False)
    assert (plan.bn, plan.bk) == (512, 512)
    cfg = EngineConfig(tuning=plan)
    small = autotune.apply(cfg, semiring="boolean", n_pad=256)
    assert (small.bn, small.bk) == (128, 128)
    same = autotune.apply(cfg, semiring="boolean", n_pad=512)
    assert (same.bn, same.bk) == (512, 512)
    # an explicit fused_steps request survives the overlay
    explicit = autotune.apply(
        EngineConfig(tuning=plan, fused_steps=3), semiring="boolean",
        n_pad=512)
    assert explicit.fused_steps == 3
    assert same.fused_steps == plan.fused_steps


def test_apply_without_plan_is_identity():
    cfg = EngineConfig(source_batch=32)
    assert autotune.apply(cfg, semiring="boolean", n_pad=256) is cfg


def test_plan_is_hashable_static_arg():
    """Plans ride inside jit-static engine configs — they must hash."""
    plan = build_plan(_graph("tiny"), use_hlo=False)
    cfg = EngineConfig(tuning=plan)
    assert hash(cfg) == hash(dataclasses.replace(cfg))
    assert cfg == dataclasses.replace(cfg)


# --------------------------------------------------------------------------
# facade integration
# --------------------------------------------------------------------------

def test_facade_tune_and_reload(tmp_path):
    import repro as dawn
    g = _graph("two_components")
    h = dawn.prepare(g, source_batch=8, mode="auto", use_kernel=False)
    path = tmp_path / "plan.json"
    plan = h.tune(use_hlo=False, save=path)
    assert h.tuning is plan
    r1 = h.apsp()
    h2 = dawn.prepare(g, source_batch=8, mode="auto", use_kernel=False,
                      tuning=str(path))
    assert h2.tuning == plan
    r2 = h2.apsp()
    np.testing.assert_array_equal(np.asarray(r1.dist), np.asarray(r2.dist))
    np.testing.assert_array_equal(np.asarray(r1.direction_counts),
                                  np.asarray(r2.direction_counts))
