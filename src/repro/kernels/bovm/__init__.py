from .ops import (sweep, msbfs_kernel, msbfs_packed, pack_adjacency_pull,
                  KernelDawnResult)
from .kernel import (fused_sweep, packed_pull_sweep, packed_push_sweep,
                     fused_boolean_multisweep)
from .ref import sweep_ref, packed_pull_ref, packed_push_ref

from .. import common, registry


def vmem_bytes(*, form: str = "push", bs: int | None = None, bn: int = 128,
               bk: int = 512, wk: int = 128, n: int = 1152, **_) -> int:
    """Resident VMEM of one grid step (docs/ARCHITECTURE.md table).

    ``bs`` defaults to the tile the engine actually dispatches: 128 for
    the push forms, 8 for the bit-packed pull form (``sweep.boolean_forms``
    caps the pull source tile at ``min(s, 8)``).  ``form="fused"`` prices
    the multi-sweep persistent kernel, whose whole packed operand stays
    resident — pass the padded node count ``n``.  Extra keywords are
    ignored so the autotuner can price every KernelSet with one uniform
    call (core/autotune.py).
    """
    if form == "push":   # packed words + i32 dist/acc, i8+i32 out
        return common.pull_vmem_bytes(128 if bs is None else bs, bn, wk,
                                      word_itemsize=4, d_itemsize=4,
                                      acc_itemsize=4, out_itemsizes=(1, 4))
    if form == "push_f32":  # int8 frontier/adj + i32 dist/acc, i8+i32 out
        return common.push_vmem_bytes(128 if bs is None else bs, bn, bk,
                                      f_itemsize=1, a_itemsize=1,
                                      d_itemsize=4, acc_itemsize=4,
                                      out_itemsizes=(1, 4))
    if form == "fused":  # whole (n, W) uint32 operand + resident tile state
        b = 128 if bs is None else bs
        words = max(n // 32, 1)
        return common.fused_vmem_bytes(
            bs=b, n=n, operand_bytes=n * words * 4,
            frontier_bytes=b * words * 4,
            state_itemsizes=(4,),          # dist i32 (carried in-register)
            out_itemsizes=(1, 4))          # new i8 + dist i32 out
    assert form == "pull", form    # uint32 words + i32 dist/acc, i8+i32 out
    return common.pull_vmem_bytes(8 if bs is None else bs, bn, wk,
                                  word_itemsize=4, d_itemsize=4,
                                  acc_itemsize=4, out_itemsizes=(1, 4))


registry.register(registry.KernelSet(
    semiring="boolean",
    forms={"push": packed_push_sweep, "push_f32": fused_sweep,
           "pull": packed_pull_sweep},
    vmem_bytes=vmem_bytes,
    notes="bit-packed push AND pull word-AND/OR sweeps (VPU, Eq. 13: no "
          "f32 GEMM on the boolean kernel path; the f32 MXU push survives "
          "as push_f32) + the fused multi-sweep persistent kernel",
    fused_forms={"push": fused_boolean_multisweep},
))
