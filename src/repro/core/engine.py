"""Direction-optimizing batched APSP engine over the semiring sweep layer.

The paper's all-pairs bound O(S_wcc * E_wcc) is only reachable when every
sweep runs in its cheapest *form*.  The boolean semiring has three
equivalent forms (core/sweep.py::boolean_forms) with very different cost
profiles:

  PUSH   — dense boolean GEMM (paper Alg. 1 / BOVM).  On TPU this is the
           MXU ``fused_sweep`` kernel whose tile-skip tables make its cost
           proportional to the *live* (frontier x unreached) tile fraction.
  PULL   — bit-packed AND/OR over in-neighbour words (paper's CSC BOVM,
           §3.2).  Reads 32 nodes per uint32 lane; cost proportional to the
           unreached tile fraction but independent of frontier size.
  SPARSE — edge-parallel gather/scatter over CSR lanes (paper Alg. 2 /
           SOVM).  Cost proportional to the padded edge count, independent
           of both occupancies.

This module tiles sources into MXU-aligned batches, runs each tile through
the shared :func:`repro.core.sweep.sweep_loop` driver, and picks the
cheapest form per sweep (direction-optimizing BFS in the style of Beamer's
push/pull switch, generalized to three forms).  Two selection regimes:

  dynamic (kernel path / TPU) — at every sweep, a ``lax.switch`` driven by
    the occupancy cost model in :func:`sweep_costs`.  The signals are
    exactly the scalar-prefetch tables the Pallas push kernel computes per
    sweep, so the heuristic is free; tile skipping makes push cost truly
    occupancy-proportional.

  calibrated (reference path / CPU) — XLA's fixed-shape reference sweeps
    cost the same regardless of occupancy, so per-sweep switching cannot
    win.  Instead one sweep of each form is *measured* on the prepared
    graph (sweep.time_sweep_forms) and the argmin direction is fixed for
    the whole batch (zero per-sweep overhead; the measurement is cached
    per graph).

All three sweeps operate on identical padded state (frontier (S, n_pad)
int8, dist (S, n_pad) int32), so switching costs nothing but the branch.
The weighted analogue of this driver lives in core/weighted.py
(``weighted_apsp``) and reuses the same cost model / calibration over the
tropical forms.

Thresholds and cost constants are documented in docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph
from . import autotune
from . import sweep as S
from .frontier import UNREACHED, one_hot_frontier
from .options import SweepOptions
from .sweep import DIRECTION_NAMES, PULL, PUSH, SPARSE, SweepState


@dataclasses.dataclass(frozen=True)
class EngineConfig(SweepOptions):
    """Static boolean-engine parameters (a :class:`SweepOptions`
    subclass, hashable: used as a jit static arg).

    Cost-model units (see docs/ARCHITECTURE.md for the calibration):
      c_push   — per dense element in a live (i, j, k) push tile (MXU MAC)
      c_pull   — per uint32 word scanned by the pull sweep (VPU bitwise op;
                 one word covers 32 nodes, so the per-element cost is
                 c_pull / 32)
      c_sparse — per padded CSR edge lane (gather + random scatter)
    """
    # cost model
    c_push: float = 1.0
    c_pull: float = 8.0
    c_sparse: float = 8.0
    pull_chunk: int = 512            # ref pull: nodes per lax.map chunk

    _mode_names = DIRECTION_NAMES    # push | pull | sparse


class SweepStats(NamedTuple):
    """Per-sweep occupancy signals (traced scalars, computed in-loop)."""
    live_tile_frac: jax.Array   # fraction of (i,j,k) push tiles doing work
    o_occ_frac: jax.Array       # fraction of output tiles with unreached


class ApspResult(NamedTuple):
    dist: jax.Array              # (S, n) int32, -1 unreachable
    sweeps: jax.Array            # int32 — max sweeps over batches
    direction_counts: jax.Array  # (3,) int32 — push/pull/sparse sweeps run
    edges_touched: jax.Array     # float32 — Eq. 10 useful-work counter


@dataclasses.dataclass
class PreparedGraph:
    """Device-resident operands shared by all three sweep forms.

    The dense push operand and the bit-packed pull operand are O(n_pad^2)
    and built lazily on first use: a run whose resolved direction never
    dispatches them (e.g. ``mode='sparse'`` on a large road network) only
    ever touches the O(m) CSR lanes and scales to graphs the dense forms
    can't hold.
    """
    graph: CSRGraph
    deg: jax.Array        # (n_pad,) float32 out-degrees (0 on pad)
    n_pad: int
    # content epoch of the source graph at prepare time (0 for a static
    # CSRGraph) — staleness checks in serve/ and repro.api key on it
    epoch: int = 0
    # per-graph sweep-cost measurements, keyed (s, bn, bk, pull_chunk, path)
    cost_cache: dict = dataclasses.field(default_factory=dict, repr=False)
    # landmark label tables for the distance-oracle serving tier
    # (serve/oracle.py builds them with apsp_engine — the batched engine
    # IS the preprocessing pass — and caches them here so every oracle
    # over the same prepared graph shares one build):
    #   landmarks          (L,) int32 sorted vertex ids
    #   landmark_dist      (L, n) int32 forward rows d(landmark -> v)
    #   landmark_dist_rev  (L, n) int32 reverse rows d(v -> landmark)
    #                      (same array object as landmark_dist when the
    #                      graph is symmetric)
    #   landmark_key       build fingerprint (k, strategy) — a different
    #                      request rebuilds and overwrites
    landmarks: Optional[np.ndarray] = dataclasses.field(default=None,
                                                        repr=False)
    landmark_dist: Optional[np.ndarray] = dataclasses.field(default=None,
                                                            repr=False)
    landmark_dist_rev: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False)
    landmark_key: Optional[tuple] = dataclasses.field(default=None,
                                                      repr=False)
    _adj: Optional[jax.Array] = dataclasses.field(default=None, repr=False)
    _adj_pull: Optional[jax.Array] = dataclasses.field(default=None,
                                                       repr=False)

    @property
    def adj(self) -> jax.Array:
        """(n_pad, n_pad) int8 dense adjacency (push operand)."""
        if self._adj is None:
            self._adj = self.graph.to_dense_padded(self.n_pad,
                                                   dtype=jnp.int8)
        return self._adj

    @property
    def adj_pull(self) -> jax.Array:
        """(n_pad, n_pad/32) uint32 packed in-neighbours (pull operand)."""
        if self._adj_pull is None:
            self._adj_pull = self.graph.to_pull_packed(self.n_pad,
                                                       adj=self._adj)
        return self._adj_pull


def prepare_graph(g, *, align: int = 128) -> PreparedGraph:
    """Pad-size the graph and build the O(n) degree operand; the dense
    push/pull operands materialize lazily when a sweep form needs them.

    Accepts a plain :class:`CSRGraph` or a
    :class:`repro.graph.dynamic.DynamicCSRGraph` — the latter prepares
    its merged ``view()`` snapshot and records the content ``epoch`` so
    downstream caches can staleness-check against the live graph."""
    epoch = 0
    if hasattr(g, "view"):            # DynamicCSRGraph duck-type
        epoch = int(g.epoch)
        g = g.view()
    n_pad = g.n_padded(align)
    deg = jnp.zeros(n_pad, jnp.float32).at[: g.n_nodes].set(
        g.out_degrees().astype(jnp.float32))
    return PreparedGraph(graph=g, deg=deg, n_pad=n_pad, epoch=epoch)


# --------------------------------------------------------------------------
# heuristic: occupancy stats -> modelled sweep costs -> direction
# --------------------------------------------------------------------------

def frontier_stats(frontier: jax.Array, dist: jax.Array, *, bs: int,
                   bn: int, bk: int,
                   unreached: Optional[jax.Array] = None) -> SweepStats:
    """Tile-occupancy fractions — the same tables the push kernel prefetches.

    live(i, j, k) = f_occ[i, k] & o_occ[i, j]; its mean factorizes as
    E_i[ mean_k f_occ[i, :] * mean_j o_occ[i, :] ].

    ``unreached`` is the semiring's not-yet-settled mask; default is the
    boolean semiring's ``dist < 0`` (tropical passes ``isinf(dist)``).
    """
    s, n = frontier.shape
    gi, gj, gk = s // bs, n // bn, n // bk
    unr = (dist < 0) if unreached is None else unreached
    f_occ = jnp.any(frontier.reshape(gi, bs, gk, bk) != 0, axis=(1, 3))
    o_occ = jnp.any(unr.reshape(gi, bs, gj, bn), axis=(1, 3))
    f_row = jnp.mean(f_occ.astype(jnp.float32), axis=1)   # (gi,)
    o_row = jnp.mean(o_occ.astype(jnp.float32), axis=1)   # (gi,)
    return SweepStats(
        live_tile_frac=jnp.mean(f_row * o_row),
        o_occ_frac=jnp.mean(o_row),
    )


def sweep_costs(stats: SweepStats, *, n_pad: int, s: int, m_pad: int,
                cfg: EngineConfig) -> jax.Array:
    """Modelled cost of one sweep in each form -> (3,) float32."""
    words = n_pad // 32
    push = cfg.c_push * s * n_pad * n_pad * stats.live_tile_frac
    pull = cfg.c_pull * s * n_pad * words * stats.o_occ_frac
    sparse = jnp.float32(cfg.c_sparse * s * m_pad)
    return jnp.stack([push, pull, jnp.broadcast_to(sparse, ())])


def choose_direction(stats: SweepStats, *, n_pad: int, s: int, m_pad: int,
                     cfg: EngineConfig) -> jax.Array:
    """argmin of the modelled costs -> PUSH | PULL | SPARSE (traced int32)."""
    return jnp.argmin(
        sweep_costs(stats, n_pad=n_pad, s=s, m_pad=m_pad, cfg=cfg)
    ).astype(jnp.int32)


# --------------------------------------------------------------------------
# jitted per-batch driver (state + loop live in core/sweep.py)
# --------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("cfg", "n_real", "n_pad", "max_steps",
                                    "use_kernel", "interpret",
                                    "forced_dir", "fused_steps"))
def _run_batch(adj, adj_pull, src_idx, dst_idx, deg, sources, n_valid, *,
               cfg: EngineConfig, n_real: int, n_pad: int, max_steps: int,
               use_kernel: bool, interpret: bool,
               forced_dir: Optional[int],
               fused_steps: int = 0) -> SweepState:
    # n_valid is traced (not static): the serving loop flushes micro-batches
    # of whatever size is pending, and each distinct count must not retrace
    s = sources.shape[0]
    m_pad = src_idx.shape[0]
    bs = min(s, 128)

    f0 = one_hot_frontier(sources, n_pad, dtype=jnp.int8)
    # padded source rows (>= n_valid) start with an empty frontier and a
    # fully-visited dist: they do no work, add nothing to the Eq. 10
    # counters, and never extend the while_loop past the real rows
    row_ok = (jnp.arange(s) < n_valid)[:, None]
    f0 = jnp.where(row_ok, f0, 0)
    dist0 = jnp.where(f0 != 0, 0, jnp.full((s, n_pad), UNREACHED))
    # pad columns are born "visited" so no sweep form ever discovers them
    dist0 = jnp.where(row_ok & (jnp.arange(n_pad)[None, :] < n_real),
                      dist0, 0)

    forms = S.boolean_forms(adj, adj_pull, src_idx, dst_idx, n_pad=n_pad,
                            s=s, bn=cfg.bn, bk=cfg.bk,
                            pull_chunk=cfg.pull_chunk,
                            use_kernel=use_kernel, interpret=interpret)

    if forced_dir is None:
        def choose(st: SweepState):
            stats = frontier_stats(st.frontier, st.dist, bs=bs, bn=cfg.bn,
                                   bk=cfg.bk)
            return choose_direction(stats, n_pad=n_pad, s=s, m_pad=m_pad,
                                    cfg=cfg)
    else:  # direction resolved at trace time: no stats, no switch
        choose = None

    fused = None
    if fused_steps:  # resolved upstream: kernel path, push pinned
        fused = S.fused_form("boolean", adj_pull, "push", bs=bs,
                             max_sweeps=fused_steps, interpret=interpret)

    st0 = S.make_state(f0, dist0, n_forms=3)
    return S.sweep_loop(forms, st0, max_steps=max_steps, deg=deg,
                        choose=choose,
                        forced_dir=0 if forced_dir is None else forced_dir,
                        fused=fused, fused_steps=fused_steps)


# --------------------------------------------------------------------------
# calibrated direction choice (reference path)
# --------------------------------------------------------------------------

def measure_sweep_costs(pg: "PreparedGraph", s: int, cfg: EngineConfig, *,
                        use_kernel: bool = False,
                        interpret: bool = True) -> Tuple[float, float, float]:
    """Wall-clock one mid-BFS sweep in each form on this graph.

    Times the *same* sweep forms ``_run_batch`` will dispatch (kernel or
    reference, per ``use_kernel``) via :func:`sweep.time_sweep_forms`, so
    the pinned argmin is the argmin of what actually runs.  Reference
    sweeps have occupancy-independent (fixed-shape) cost, so a single
    measurement per form characterizes every sweep of the run.  Cached on
    the PreparedGraph per (batch size, tiles, path) — calibration costs a
    few warm sweeps once per graph, then is free.
    """
    key = (s, cfg.bn, cfg.bk, cfg.pull_chunk, use_kernel, interpret)
    if key in pg.cost_cache:
        return pg.cost_cache[key]
    n_pad = pg.n_pad
    # representative mid-BFS state: ~6% frontier, ~25% visited
    f = np.zeros((s, n_pad), np.int8)
    f[:, ::17] = 1
    dist = np.full((s, n_pad), int(UNREACHED), np.int32)
    dist[:, ::4] = 1
    forms = S.boolean_forms(pg.adj, pg.adj_pull, pg.graph.src, pg.graph.dst,
                            n_pad=n_pad, s=s, bn=cfg.bn, bk=cfg.bk,
                            pull_chunk=cfg.pull_chunk, use_kernel=use_kernel,
                            interpret=interpret)
    result = S.time_sweep_forms(forms, jnp.asarray(f), jnp.asarray(dist))
    pg.cost_cache[key] = result
    return result


# --------------------------------------------------------------------------
# public drivers
# --------------------------------------------------------------------------

def _resolve_kernel(cfg: EngineConfig) -> Tuple[bool, bool]:
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = on_tpu if cfg.use_kernel is None else cfg.use_kernel
    return use_kernel, not on_tpu


def _resolve_direction(pg: "PreparedGraph", s: int, cfg: EngineConfig,
                       use_kernel: bool, interpret: bool) -> Optional[int]:
    """None -> per-sweep dynamic switch; int -> direction fixed per batch.

    Precedence on the pinned path: an explicit ``mode=`` wins, then a
    :class:`~repro.core.autotune.TuningPlan` (deterministic roofline
    argmin), then wall-clock calibration (the legacy fallback — the only
    non-deterministic regime, kept for plan-less runs)."""
    if cfg.mode != "auto":
        return DIRECTION_NAMES.index(cfg.mode)
    dynamic = use_kernel if cfg.dynamic is None else cfg.dynamic
    if dynamic:
        return None
    if cfg.tuning is not None:
        pinned = cfg.tuning.pinned_direction(
            "boolean", s=s, n_pad=pg.n_pad, m_pad=pg.graph.m_pad)
        if pinned is not None:
            return pinned
    costs = measure_sweep_costs(pg, s, cfg, use_kernel=use_kernel,
                                interpret=interpret)
    return int(np.argmin(costs))


def apsp_engine_blocks(
        g: Union[CSRGraph, PreparedGraph],
        sources: Optional[Sequence[int]] = None, *,
        config: EngineConfig = EngineConfig(),
) -> Iterator[Tuple[np.ndarray, jax.Array, SweepState]]:
    """Stream (source_ids, dist_rows, raw_sweep_state) one source tile at a
    time — the non-materializing form for large n."""
    pg = g if isinstance(g, PreparedGraph) else prepare_graph(g)
    # TuningPlan overlay (no-op without one): tiles clamped to this
    # graph's padding, fused gate, cost constants
    config = autotune.apply(config, semiring="boolean", n_pad=pg.n_pad)
    graph = pg.graph
    n = graph.n_nodes
    srcs = np.arange(n, dtype=np.int32) if sources is None else \
        np.asarray(sources, np.int32)
    if srcs.size == 0:
        raise ValueError("apsp_engine: empty source list")
    if srcs.min() < 0 or srcs.max() >= n:
        raise ValueError(
            f"apsp_engine: sources must be in [0, {n}), got "
            f"[{srcs.min()}, {srcs.max()}]")
    use_kernel, interpret = _resolve_kernel(config)
    max_steps = config.max_steps or n
    B = config.source_batch
    forced_dir = _resolve_direction(pg, B, config, use_kernel, interpret)
    # fused multi-sweep blocks only exist on the kernel push path; the
    # resolver returns None (-> per-sweep loop) whenever the capability is
    # missing or the whole-operand residency would blow the VMEM budget
    fused_steps = 0
    if config.fused_steps and forced_dir in (None, PUSH):
        fused_steps = S.resolve_fused_steps(
            "boolean", "push", fused_steps=config.fused_steps,
            max_steps=max_steps, use_kernel=use_kernel, n_pad=pg.n_pad,
            bs=min(B, 128),
            budget=None if config.tuning is None
            else config.tuning.vmem_budget) or 0
        if fused_steps:
            forced_dir = PUSH   # fused blocks pin one direction
    # only materialize the O(n_pad^2) operands the resolved direction can
    # dispatch; the other slot gets a (1, 1) dummy its closure never
    # traces.  The kernel path runs *both* dense directions (and the
    # fused block) off the bit-packed pull operand; the dense int8
    # adjacency only feeds the XLA reference push.
    adj = pg.adj if (forced_dir in (None, PUSH) and not use_kernel) else \
        jnp.zeros((1, 1), jnp.int8)
    adj_pull = pg.adj_pull if (
        forced_dir in (None, PULL)
        or (forced_dir in (None, PUSH) and use_kernel)) else \
        jnp.zeros((1, 1), jnp.uint32)
    for lo in range(0, len(srcs), B):
        block = srcs[lo: lo + B]
        valid = len(block)
        padded = np.zeros(B, np.int32)
        padded[:valid] = block
        st = _run_batch(adj, adj_pull, pg.graph.src, pg.graph.dst,
                        pg.deg, jnp.asarray(padded), jnp.int32(valid),
                        cfg=config, n_real=n, n_pad=pg.n_pad,
                        max_steps=max_steps,
                        use_kernel=use_kernel, interpret=interpret,
                        forced_dir=forced_dir, fused_steps=fused_steps)
        yield block, st.dist[:valid, :n], st


def apsp_engine(g: Union[CSRGraph, PreparedGraph],
                sources: Optional[Sequence[int]] = None, *,
                config: EngineConfig = EngineConfig()) -> ApspResult:
    """Materialized batched APSP with per-sweep direction optimization.

    Returns distances for every requested source (default: all nodes),
    plus sweep/direction/work counters aggregated over source tiles.
    """
    rows = []
    sweeps = jnp.int32(0)
    counts = jnp.zeros(3, jnp.int32)
    touched = jnp.float32(0.0)
    for _, dist, st in apsp_engine_blocks(g, sources, config=config):
        rows.append(dist)
        sweeps = jnp.maximum(sweeps, st.step)
        counts = counts + st.dir_counts
        touched = touched + st.edges_touched
    return ApspResult(dist=jnp.concatenate(rows, axis=0), sweeps=sweeps,
                      direction_counts=counts, edges_touched=touched)
