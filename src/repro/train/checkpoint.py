"""Checkpointing: manifest + per-leaf .npy shards, async writes, integrity
hashes, resume, and re-mesh on restore (elastic restart).

Layout:
    <dir>/step_000123/
        MANIFEST.json     {step, leaves: {path: {file, shape, dtype, sha256}}}
        0000.npy ...
A checkpoint directory is atomic: written to ``.tmp`` then renamed, so a
crash mid-write never corrupts the latest-pointer.  ``latest_step`` scans
complete checkpoints only.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> list[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True,
         keep: int = 3) -> threading.Thread | None:
    """Save pytree. ``blocking=False`` hands the host copy to a writer
    thread (device->host transfer happens before returning so training can
    donate buffers immediately)."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

    def write():
        final = os.path.join(ckpt_dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for i, (path, leaf) in enumerate(_leaf_paths(host_tree)):
            fname = f"{i:04d}.bin"
            fpath = os.path.join(tmp, fname)
            arr = np.asarray(leaf)
            raw = arr.tobytes()          # raw bytes: bf16-safe
            with open(fpath, "wb") as f:
                f.write(raw)
            digest = hashlib.sha256(raw).hexdigest()
            manifest["leaves"][path] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": arr.dtype.name, "sha256": digest}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") \
                and os.path.exists(os.path.join(ckpt_dir, d, "MANIFEST.json")):
            out.append(int(d[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like, *, verify: bool = True,
            shardings=None):
    """Restore into the structure of ``like``.  ``shardings`` (optional
    pytree of NamedSharding matching ``like``) re-shards onto the *current*
    mesh — this is the elastic-restart path: a checkpoint written on a
    512-chip mesh restores onto whatever mesh is alive now."""
    d = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)

    import ml_dtypes  # jax dependency; provides bfloat16 etc.
    paths = [p for p, _ in _leaf_paths(like)]
    leaves = []
    for path in paths:
        ent = manifest["leaves"][path]
        fpath = os.path.join(d, ent["file"])
        with open(fpath, "rb") as f:
            raw = f.read()
        if verify:
            digest = hashlib.sha256(raw).hexdigest()
            if digest != ent["sha256"]:
                raise IOError(f"checkpoint corruption in {path}: "
                              f"{digest} != {ent['sha256']}")
        try:
            dtype = np.dtype(ent["dtype"])
        except TypeError:
            dtype = np.dtype(getattr(ml_dtypes, ent["dtype"]))
        leaves.append(np.frombuffer(raw, dtype=dtype
                                    ).reshape(ent["shape"]).copy())

    treedef = jax.tree_util.tree_structure(like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s),
                            tree, shardings)
    else:
        tree = jax.tree.map(jax.device_put, tree)
    return tree, manifest["step"]


class CheckpointHook:
    """Training-loop hook: async save every ``interval`` steps."""

    def __init__(self, ckpt_dir: str, interval: int = 100, keep: int = 3):
        self.dir = ckpt_dir
        self.interval = interval
        self.keep = keep
        self._pending: threading.Thread | None = None

    def __call__(self, step, params, opt_state, metrics):
        if (step + 1) % self.interval:
            return
        if self._pending is not None:
            self._pending.join()        # one in-flight write at a time
        self._pending = save(self.dir, step + 1,
                             {"params": params, "opt": opt_state},
                             blocking=False, keep=self.keep)

    def flush(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
