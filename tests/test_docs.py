"""Documentation integrity: the intra-repo link checker (the same one
CI runs as its own step) must pass, and the paper-reproduction map must
exist and be reachable from the README."""
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_doc_links_resolve():
    res = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_doc_links.py")],
        capture_output=True, text=True)
    assert res.returncode == 0, res.stdout + res.stderr


def test_reproduction_doc_exists_and_is_linked():
    repro = ROOT / "docs" / "REPRODUCTION.md"
    assert repro.exists()
    readme = (ROOT / "README.md").read_text()
    assert "docs/REPRODUCTION.md" in readme
    arch = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    assert "REPRODUCTION.md" in arch
