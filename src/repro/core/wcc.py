"""Weakly connected components via min-label propagation.

Provides S_wcc / E_wcc(i) — the quantities in DAWN's complexity bounds
(Eqs. 10-12) — as the min-label semiring instantiation of the shared
sweep layer: one :func:`repro.core.sweep.minlabel_form` sweep over the
symmetrized edge lanes per iteration, Fact-1 ("no label lowered")
termination through the same ``sweep_loop`` driver as every other path.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph
from . import sweep as S


class WccResult(NamedTuple):
    labels: jax.Array      # (n,) int32 — component id = min node id in comp
    iters: jax.Array


@partial(jax.jit, static_argnames=("max_iters",))
def wcc(g: CSRGraph, *, max_iters=None) -> WccResult:
    n = g.n_nodes
    max_iters = n if max_iters is None else max_iters
    labels0 = jnp.concatenate([jnp.arange(n, dtype=jnp.int32),
                               jnp.full(1, n, jnp.int32)])
    # undirected propagation: min label flows along both edge directions
    src_sym = jnp.concatenate([g.src, g.dst])
    dst_sym = jnp.concatenate([g.dst, g.src])

    form = S.minlabel_form(src_sym, dst_sym)
    st = S.sweep_loop((form,),
                      S.make_state(jnp.ones(n + 1, jnp.int8), labels0,
                                   n_forms=1),
                      max_steps=max_iters)
    return WccResult(st.dist[:n], st.step)


def wcc_stats(g: CSRGraph):
    """Host-side S_wcc, E_wcc and per-node component sizes (numpy)."""
    labels = np.asarray(wcc(g).labels)
    src, dst = g.edge_arrays_np()
    comp_ids, counts = np.unique(labels, return_counts=True)
    edge_comp = labels[src]
    edge_counts = {int(c): int((edge_comp == c).sum()) for c in comp_ids}
    node_counts = {int(c): int(k) for c, k in zip(comp_ids, counts)}
    largest = max(node_counts, key=lambda c: node_counts[c])
    return {
        "labels": labels,
        "S_wcc": node_counts[largest],
        "E_wcc": edge_counts.get(largest, 0),
        "S_wcc_of": lambda i: node_counts[int(labels[i])],
        "E_wcc_of": lambda i: edge_counts.get(int(labels[i]), 0),
        "n_components": len(comp_ids),
    }
