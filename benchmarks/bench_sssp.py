"""Paper Tables 7/8 analogue: DAWN vs BFS baselines across the graph suite.

Offline substitutions (SuiteSparse unavailable): matched synthetic graph
families; 'GAP' stand-in = scipy.sparse.csgraph C BFS; 'queueBFS' = paper
Alg. 3 in numpy.  DAWN runs jitted on CPU — speedups are conservative for
the matrix formulation (no MXU here).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.dawn import GRAPH_SUITE, SOURCE_SET_SIZE
from repro.core import (bfs_queue_numpy, bfs_scipy, pack_bits,
                        prepare_graph, sovm_sssp, sssp)
from repro.core.sovm import sovm_msbfs
from repro.kernels.bovm import fused_sweep, packed_push_sweep


def _time(fn: Callable, repeats: int = 5) -> float:
    fn()  # warmup / jit
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def run(n_sources: int = 16, csv: List[str] | None = None) -> Dict:
    rng = np.random.default_rng(0)
    buckets = {"<1x": 0, "1-2x": 0, "2-4x": 0, "4-16x": 0, ">16x": 0}
    speedups = []
    for name, make in GRAPH_SUITE.items():
        g = make()
        sources = rng.integers(0, g.n_nodes, n_sources).astype(np.int32)

        def dawn_run():
            for s in sources:
                sovm_sssp(g, int(s)).dist.block_until_ready()

        def gap_run():
            for s in sources:
                bfs_scipy(g, int(s))

        t_dawn = _time(dawn_run, repeats=3)
        t_gap = _time(gap_run, repeats=3)
        sp = t_gap / t_dawn
        speedups.append(sp)
        if sp < 1:
            buckets["<1x"] += 1
        elif sp < 2:
            buckets["1-2x"] += 1
        elif sp < 4:
            buckets["2-4x"] += 1
        elif sp < 16:
            buckets["4-16x"] += 1
        else:
            buckets[">16x"] += 1
        if csv is not None:
            csv.append(f"sssp_{name},{t_dawn / n_sources * 1e6:.1f},"
                       f"speedup_vs_gap={sp:.2f}")
    geo = float(np.exp(np.mean(np.log(speedups))))

    # Eq. 13 in practice: the bit-packed uint32 push operand vs the f32
    # GEMM push it replaces — one first-hop sweep, batch of 64 sources,
    # on the first suite graph, bit-identity asserted before timing.
    # Interpret-mode Pallas on CPU, so the ratio tracks lowered-op count
    # (the 32x operand shrink), not MXU throughput.
    g0 = next(iter(GRAPH_SUITE.values()))()
    pg = prepare_graph(g0)
    srcs = rng.integers(0, g0.n_nodes, 64).astype(np.int32)
    f0 = np.zeros((64, pg.n_pad), np.int8)
    f0[np.arange(64), srcs] = 1
    d0 = np.full((64, pg.n_pad), -1, np.int32)
    d0[np.arange(64), srcs] = 0
    f0, d0 = jnp.asarray(f0), jnp.asarray(d0)
    fp = pack_bits(f0 > 0)
    pp = jax.jit(lambda: packed_push_sweep(fp, pg.adj_pull, d0, 0, bs=64,
                                           bn=128, wk=4, interpret=True)[1])
    pf = jax.jit(lambda: fused_sweep(f0, pg.adj, d0, 0, bs=64, bn=128,
                                     bk=128, interpret=True)[1])
    np.testing.assert_array_equal(np.asarray(pp()), np.asarray(pf()))
    t_packed = _time(lambda: pp().block_until_ready(), repeats=3)
    t_f32 = _time(lambda: pf().block_until_ready(), repeats=3)
    if csv is not None:
        csv.append(f"sssp_suite_geomean,,speedup={geo:.3f}")
        csv.append(f"sssp_speedup_buckets,,{buckets}")
        csv.append(f"sssp_push_packed,{t_packed * 1e6:.1f},"
                   f"packed_vs_f32={t_packed / t_f32:.2f}")
    return {"buckets": buckets, "geomean": geo, "speedups": speedups,
            "push_packed_seconds": t_packed, "push_f32_seconds": t_f32}


if __name__ == "__main__":
    rows: List[str] = []
    out = run(csv=rows)
    print("\n".join(rows))
