"""End-to-end graph analytics driver built on DAWN's batched subsystems.

Computes, for any generated or on-disk graph:
  connectivity (WCC sizes) → one batched centrality run over the counting
  semiring (closeness / harmonic / exact eccentricity + radius/diameter /
  exact Brandes betweenness) → sample shortest paths → weighted APSP
  through the tropical semiring.  All query dispatch goes through the
  unified ``dawn`` facade: one ``prepare`` handle serves every semiring.

    PYTHONPATH=src python examples/graph_analytics.py --graph rmat \
        --scale 10 --sources 128
"""
import argparse
import time

import numpy as np

import repro as dawn
from repro.core import reconstruct_path, sssp, wcc_stats
from repro.graph import generators as gen
from repro.graph.io import load_edgelist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat",
                    choices=["rmat", "grid", "ws", "disconnected", "file"])
    ap.add_argument("--path", help="edge list path for --graph file")
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--sources", type=int, default=128,
                    help="sources for the centrality run (restricting "
                         "them gives the standard source-sampled "
                         "betweenness estimator; pass 0 for all nodes "
                         "= exact)")
    args = ap.parse_args()

    if args.graph == "rmat":
        g = gen.rmat(args.scale, 8, directed=False, seed=1)
    elif args.graph == "grid":
        side = int(2 ** (args.scale / 2))
        g = gen.grid2d(side, side)
    elif args.graph == "ws":
        g = gen.watts_strogatz(2 ** args.scale, 8, 0.05, seed=1)
    elif args.graph == "disconnected":
        g = gen.disconnected(2 ** (args.scale - 7), 128, 4.0, seed=1)
    else:
        g = load_edgelist(args.path, undirected=True)
    print(f"graph: {g.n_nodes} nodes / {g.n_edges} edges")

    # one facade handle drives every semiring below; weights attach here
    rng = np.random.default_rng(0)
    w = rng.uniform(0.5, 4.0, g.m_pad).astype(np.float32)
    h = dawn.prepare(g, weights=w, source_batch=128)

    t0 = time.perf_counter()
    stats = wcc_stats(g)
    print(f"WCC: {stats['n_components']} components, "
          f"S_wcc={stats['S_wcc']} E_wcc={stats['E_wcc']} "
          f"({time.perf_counter() - t0:.2f}s)")

    # ONE batched run over the counting semiring produces every measure:
    # the forward sweeps carry (dist, sigma), the Brandes backward pass
    # accumulates dependencies over the recorded levels, and the
    # distance reductions fall out of the same dist rows.
    n_src = g.n_nodes if args.sources in (0, None) else \
        min(args.sources, g.n_nodes)
    sources = np.arange(n_src, dtype=np.int32)
    t0 = time.perf_counter()
    res = h.centrality(sources)
    dt = time.perf_counter() - t0
    exact = "exact" if n_src == g.n_nodes else f"{n_src}-source estimate"
    print(f"centrality ({exact}) in {dt:.2f}s "
          f"({dt / n_src * 1e3:.1f} ms/source, {res.sweeps} sweeps)")
    print(f"  eccentricity: radius={res.radius} diameter={res.diameter} "
          f"mean={res.eccentricity.mean():.1f}")
    top = np.argsort(res.betweenness)[-5:][::-1]
    print("  top betweenness:",
          [(int(v), round(float(res.betweenness[v]), 1)) for v in top])
    top_c = np.argsort(res.closeness)[-3:][::-1]
    print("  top closeness:  ",
          [(int(sources[v]), round(float(res.closeness[v]), 4))
           for v in top_c])
    print(f"  harmonic: mean={res.harmonic.mean():.2f} "
          f"max={res.harmonic.max():.2f}")

    # sample path reconstruction — every SsspResult carries a parent tree
    res0 = sssp(g, int(top[0]))
    d0 = np.asarray(res0.dist)
    far = int(np.argmax(d0))
    path = reconstruct_path(res0.parent, int(top[0]), far, g.n_nodes)
    print(f"sample shortest path {int(top[0])} → {far} "
          f"(len {d0[far]}): {path[:12]}{'...' if len(path) > 12 else ''}")

    # weighted analytics ride the same sweep core through the tropical
    # semiring — same handle, different semiring=
    t0 = time.perf_counter()
    wres = h.apsp(sources[: min(32, len(sources))], semiring="tropical")
    wd = np.asarray(wres.dist)
    forms = dict(zip(("dense", "sparse"),
                     np.asarray(wres.direction_counts).tolist()))
    print(f"weighted APSP ({wd.shape[0]} sources) in "
          f"{time.perf_counter() - t0:.2f}s — forms {forms}, "
          f"mean finite dist {wd[np.isfinite(wd)].mean():.2f}")


if __name__ == "__main__":
    main()
