"""Resumable sweep jobs — preemption-safe checkpoint/resume for long
batched workloads (ROADMAP item 2a).

DAWN's all-pairs regime is O(S_wcc · E_wcc): exact APSP / betweenness on
a large graph is hours of sweeps, and a preemption near the end would
restart from zero.  This layer runs any batched sweep workload —
boolean APSP, tropical (min,+) APSP, counting (dist, sigma) for
centrality; single-device or sharded — as a sequence of source-tile
*chunks* with periodic progress checkpoints through
:mod:`repro.train.checkpoint` (async writer, sha256-manifested raw-bytes
shards, atomic rename), and resumes bit-identically after a kill.

Why resume is bit-identical to an uninterrupted run:

  * each chunk is a pure function of (graph, chunk sources, config) —
    restored rows are byte-exact copies of what the interrupted run
    computed, and recomputed chunks see operands identical to the
    original run's;
  * the aggregation is partition-stable: ``sweeps`` is a running max
    (the per-tile trip count is the max per-row settle time, so the max
    over any chunking equals the single-run max), ``direction_counts``
    and ``edges_touched`` are running sums folded in fixed chunk order;
  * the sharded executor is bit-identical to the single-device engines
    *and across mesh shapes* (its cross-shard ⊕ is exact), so a job
    checkpointed on one mesh restores onto a smaller one — the elastic
    walk is ``plan_remesh`` → :func:`repro.launch.mesh.mesh_from_plan` →
    ``restore(..., shardings=)`` — and still reproduces the
    uninterrupted distances, counts and sweep totals.

The checkpoint state is a fixed-shape host pytree (full-size dist/sigma
buffers plus scalar counters), so every checkpoint of a job has the same
tree structure regardless of progress: ``restore(like=...)`` needs no
knowledge of how far the dead run got, and the ``shardings=`` re-shard
path applies cleanly.  The manifest embeds a job fingerprint (graph
content hash, sources, workload, chunking) and resume refuses — with
:class:`JobMismatchError` — to touch checkpoints written by a different
job.

One caveat: under ``mode="auto"`` on the reference (non-kernel) path
the per-chunk direction choice is wall-clock calibrated, so
``direction_counts`` — and only they — are not reproducible across
invocations; ``dist`` / ``sigma`` / ``sweeps`` / ``edges_touched`` are
form-invariant and stay bit-identical under any mode.  Pin a concrete
``mode`` when the direction tallies themselves must survive a resume.

Fault-injection seam: ``on_chunk(k)`` runs after chunk ``k``'s
checkpoint is submitted; tests raise from it to simulate a kill between
chunks (with ``checkpoint_interval > 1`` the newest chunks are then
*not* checkpointed, which simulates dying within an interval).
"""
from __future__ import annotations

import hashlib
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec as P

from ..train import checkpoint as ckpt
from .centrality import CentralityConfig, counting_apsp
from .distributed import ShardedConfig, prepare_sharded, sharded_apsp
from .engine import EngineConfig, apsp_engine, prepare_graph
from .options import SweepOptions
from .weighted import WeightedConfig, prepare_weighted, weighted_apsp

WORKLOADS = ("boolean", "tropical", "counting")


class JobMismatchError(RuntimeError):
    """``checkpoint_dir`` holds checkpoints of a *different* job (graph
    content, sources, workload or chunking changed) — refusing to resume
    from or garbage-collect them."""


class JobResult(NamedTuple):
    dist: np.ndarray             # (S, n) int32 hops / float32 tropical
    sigma: Optional[np.ndarray]  # (S, n) f32 path counts (counting only)
    sweeps: int                  # max per-tile trip count (== engine's)
    direction_counts: np.ndarray  # summed over chunks
    edges_touched: float         # Eq. 10 work counter summed over chunks
    chunks_total: int
    chunks_computed: int         # chunks swept by THIS invocation
    chunks_restored: int         # chunks recovered from the checkpoint
    checkpoints_written: int     # by this invocation
    restored_step: Optional[int]  # checkpoint step resumed from, or None
    corrupt_skipped: int         # damaged checkpoints skipped over


def _sha(arr) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(np.asarray(arr)).tobytes()).hexdigest()[:16]


def _job_meta(g, epoch: int, srcs, weights, workload: str,
              chunk_size: int, options: SweepOptions) -> dict:
    """JSON-serializable job fingerprint.  Everything that determines the
    chunk results and their aggregation order is pinned: graph content
    (edge lanes + epoch), sources, workload, weights, and the chunking /
    mode / tile knobs (``direction_counts`` depends on tile composition,
    so resuming under a different chunking would not be bit-identical)."""
    return {
        "job": "sweep-v1",
        "workload": workload,
        "n_nodes": int(g.n_nodes),
        "n_edges": int(g.n_edges),
        "epoch": int(epoch),
        "edges_sha": _sha(np.stack([np.asarray(g.src, np.int64),
                                    np.asarray(g.dst, np.int64)])),
        "sources_sha": _sha(np.asarray(srcs, np.int32)),
        "weights_sha": _sha(np.asarray(weights, np.float32))
        if weights is not None else None,
        "chunk_size": int(chunk_size),
        "mode": options.mode,
        "source_batch": int(options.source_batch),
        "max_steps": options.max_steps,
    }


def _chunk_runner(graph, workload: str, weights, mesh,
                  options: SweepOptions):
    """Build operands once; return (run, n_dirs) where ``run(chunk)`` →
    (dist, sigma | None, sweeps, dir_counts, edges_touched)."""
    if mesh is not None:
        cfg = options.to(ShardedConfig, lenient=True, semiring=workload)
        ops = prepare_sharded(
            graph, mesh,
            weights=weights if workload == "tropical" else None, config=cfg)

        def run(chunk):
            r = sharded_apsp(ops, chunk)
            return r.dist, r.sigma, r.sweeps, r.direction_counts, \
                r.edges_touched
        return run, 2
    if workload == "tropical":
        pw = prepare_weighted(graph, weights)
        wcfg = options.to(WeightedConfig, lenient=True)

        def run(chunk):
            r = weighted_apsp(pw, sources=chunk, config=wcfg)
            return r.dist, None, r.sweeps, r.direction_counts, \
                r.edges_touched
        return run, 2
    pg = prepare_graph(graph)
    if workload == "counting":
        ccfg = options.to(CentralityConfig, lenient=True)

        def run(chunk):
            r = counting_apsp(pg, chunk, config=ccfg)
            # the counting engine has no Eq. 10 counter — stays 0
            return r.dist, r.sigma, r.sweeps, r.direction_counts, 0.0
        return run, 2
    ecfg = options.to(EngineConfig, lenient=True)

    def run(chunk):
        r = apsp_engine(pg, chunk, config=ecfg)
        return r.dist, None, r.sweeps, r.direction_counts, r.edges_touched
    return run, 3


def _fresh_state(S: int, n: int, workload: str, n_dirs: int) -> dict:
    """Fixed-shape host checkpoint state: full-size result buffers plus
    scalar progress counters, identical tree structure at every step."""
    tropical = workload == "tropical"
    dist = np.full((S, n), np.inf, np.float32) if tropical \
        else np.full((S, n), -1, np.int32)
    sigma = np.zeros((S, n) if workload == "counting" else (1, 1),
                     np.float32)
    return {
        "dist": dist,
        "sigma": sigma,
        "sweeps": np.int32(0),
        "dir_counts": np.zeros(n_dirs, np.int32),
        "edges_touched": np.float32(0.0),
        "chunks_done": np.int32(0),
    }


def _try_restore(checkpoint_dir: str, like: dict, meta: dict,
                 verify: bool, shardings):
    """Newest-first scan: (state, restored_step, corrupt_skipped).
    Damaged checkpoints (bad sha256, unreadable manifest) are counted
    and skipped; a manifest from a DIFFERENT job raises."""
    corrupt = 0
    for step in sorted(ckpt.all_steps(checkpoint_dir), reverse=True):
        try:
            man = ckpt.read_manifest(checkpoint_dir, step)
        except (OSError, ValueError):
            corrupt += 1
            continue
        got = man.get("meta")
        if got != meta:
            raise JobMismatchError(
                f"{checkpoint_dir!r} step {step} was written by a "
                f"different job:\n  found    {got}\n  expected {meta}")
        try:
            tree, _ = ckpt.restore(checkpoint_dir, step, like,
                                   verify=verify, shardings=shardings)
        except (OSError, KeyError, ValueError):
            corrupt += 1
            continue
        # back to mutable host buffers (restore device_puts the leaves)
        return jax.tree.map(lambda x: np.array(x), tree), step, corrupt
    return None, None, corrupt


def run_sweep_job(graph, sources: Optional[Sequence[int]] = None, *,
                  workload: str = "boolean", weights=None, mesh=None,
                  options: Optional[SweepOptions] = None,
                  chunk_size: Optional[int] = None,
                  checkpoint_dir: Optional[str] = None,
                  checkpoint_interval: int = 1, keep: int = 3,
                  resume: bool = True, verify: bool = True,
                  on_chunk: Optional[Callable[[int], None]] = None
                  ) -> JobResult:
    """Run a batched sweep workload as resumable source-tile chunks.

    With ``checkpoint_dir=`` set, progress is checkpointed every
    ``checkpoint_interval`` chunks (async, atomic, sha256-manifested;
    newest ``keep`` retained) plus once after the final chunk, and a
    rerun of the same call resumes from the newest intact checkpoint —
    producing results bit-identical to an uninterrupted run, including
    on a different mesh than the one that wrote the checkpoint.
    ``mesh=`` routes chunks through the sharded executor and exercises
    the ``restore(shardings=)`` elastic re-shard path.
    """
    if workload not in WORKLOADS:
        raise ValueError(f"unknown workload {workload!r}; one of "
                         f"{WORKLOADS}")
    epoch = 0
    if hasattr(graph, "view"):        # DynamicCSRGraph duck-type
        epoch = int(graph.epoch)
        if weights is None and getattr(graph, "weighted", False):
            weights = graph.view_weights()
        graph = graph.view()
    options = options or SweepOptions()
    n = graph.n_nodes
    srcs = np.arange(n, dtype=np.int32) if sources is None else \
        np.asarray(sources, np.int32)
    if srcs.size == 0:
        raise ValueError("run_sweep_job: empty source list")
    if srcs.min() < 0 or srcs.max() >= n:
        raise ValueError(f"run_sweep_job: sources must be in [0, {n})")
    chunk_size = int(chunk_size or options.source_batch)
    if chunk_size < 1:
        raise ValueError(f"chunk_size must be >= 1: {chunk_size}")
    n_chunks = -(-len(srcs) // chunk_size)

    run, n_dirs = _chunk_runner(graph, workload, weights, mesh, options)
    state = _fresh_state(len(srcs), n, workload, n_dirs)
    meta = _job_meta(graph, epoch, srcs, weights, workload, chunk_size,
                     options)
    meta["chunks_total"] = n_chunks

    hook = None
    restored_step = None
    corrupt = 0
    start = 0
    if checkpoint_dir is not None:
        hook = ckpt.CheckpointHook(checkpoint_dir, keep=keep)
        if resume:
            # restoring THROUGH the current mesh's shardings is the
            # elastic path: the checkpoint may have been written by a
            # run on a different mesh shape
            shardings = jax.tree.map(
                lambda _: NamedSharding(mesh, P()), state) \
                if mesh is not None else None
            got, restored_step, corrupt = _try_restore(
                checkpoint_dir, state, meta, verify, shardings)
            if got is not None:
                state = got
                start = int(state["chunks_done"])

    computed = 0
    try:
        for k in range(start, n_chunks):
            lo = k * chunk_size
            hi = min(len(srcs), lo + chunk_size)
            dist, sigma, sweeps, dirs, edges = run(srcs[lo:hi])
            state["dist"][lo:hi] = np.asarray(dist)
            if workload == "counting":
                state["sigma"][lo:hi] = np.asarray(sigma)
            state["sweeps"] = np.int32(max(int(state["sweeps"]),
                                           int(sweeps)))
            state["dir_counts"] = (state["dir_counts"]
                                   + np.asarray(dirs, np.int32))
            state["edges_touched"] = np.float32(
                np.float32(state["edges_touched"]) + np.float32(edges))
            state["chunks_done"] = np.int32(k + 1)
            computed += 1
            if hook is not None and ((k + 1) % checkpoint_interval == 0
                                     or k + 1 == n_chunks):
                hook.submit(k + 1, state, meta=meta)
            if on_chunk is not None:
                on_chunk(k)
    finally:
        if hook is not None:
            hook.flush()    # clean shutdown: the last write is durable

    return JobResult(
        dist=state["dist"],
        sigma=state["sigma"] if workload == "counting" else None,
        sweeps=int(state["sweeps"]),
        direction_counts=np.asarray(state["dir_counts"]),
        edges_touched=float(state["edges_touched"]),
        chunks_total=n_chunks,
        chunks_computed=computed,
        chunks_restored=start,
        checkpoints_written=hook.written if hook is not None else 0,
        restored_step=restored_step,
        corrupt_skipped=corrupt)
