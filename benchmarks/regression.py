"""Benchmark regression gate: compare a fresh BENCH_RESULTS aggregate
against a committed baseline (``BENCH_BASELINE.json``) and fail CI when
something real regressed.

Two classes of checks, calibrated to what is and is not deterministic:

  * **hard gates** — fields that are exact given the seeds: the set of
    benchmark rows (nothing silently dropped), per-family graph shapes
    (``n_nodes``/``n_edges``), **sweep counts** (the Fact-1 iteration
    counts) and the counting semiring's **sigma checksum** (the sum of
    shortest-path counts — exact integers in f32; any change means the
    algorithm did different work, not that the machine was slow), and the
    serving tier's determinism fields (landmark ``labels_checksum``,
    oracle ``certified_count``/``certified_fraction``, load-loop
    ``hit_rate`` and tier hit counters — the gated load run flushes on
    size thresholds over a virtual clock, so these are pure functions of
    the seeds).  A mismatch always fails.
  * **timing gates** — per-family interleaved best-of-N *medians*
    (``t_<mode>_median`` from ``_timing.time_interleaved_stats``).  Wall
    clock is ±30% noisy on shared runners and the baseline may have been
    recorded on different hardware, so the threshold is generous
    (``time_tol``, stored in the baseline's ``gate`` block) and timings
    under ``min_gate_seconds`` are ignored entirely.

The acceptance booleans (``auto_no_slower_than_best`` etc.) are
themselves timing-derived, so they warn rather than fail.

    PYTHONPATH=src python -m benchmarks.run --quick \
        --check-against benchmarks/BENCH_BASELINE.json
"""
from __future__ import annotations

import json
from typing import Dict, List, Tuple

# The baseline may have been recorded on different hardware than the CI
# runner and --quick medians come from 2-3 samples, so both knobs are
# deliberately loose: the timing gate exists to catch order-of-magnitude
# regressions (an accidental O(n^2) hot path, a dropped jit), not single-
# digit-percent drift — that's what the hard sweep-count gates and the
# uploaded aggregates are for.
DEFAULT_TIME_TOL = 6.0        # median may grow this much before failing
MIN_GATE_SECONDS = 5e-3       # ignore timings too small to be stable

_HARD_FAMILY_FIELDS = ("n_nodes", "n_edges", "n_sources", "sweeps",
                       "sweeps_fused", "sweeps_tropical", "sigma_checksum",
                       # serving tier: all pure functions of graph +
                       # landmarks + seeded arrival order (the gated load
                       # loop runs on a virtual clock with size-threshold-
                       # only flushing, so no wall-clock dependence)
                       "n_queries", "n_landmarks", "labels_checksum",
                       "certified_count", "certified_fraction", "hit_rate",
                       "cache_hits", "oracle_hits", "sweep_served",
                       # kernel tile occupancy: graph + schedule only
                       "tile_skip_fraction",
                       # dynamic tier: the recorded update stream is
                       # seeded, so repair/scratch sweep totals, the
                       # bit-identity flag, the epoch/compaction
                       # counters and the interleaved-query checksum
                       # are exact
                       "repair_sweeps", "scratch_sweeps",
                       "repair_equals_scratch", "n_epochs",
                       "n_compactions", "query_checksum",
                       # resumable jobs: full-run checksums and the
                       # resumed-chunk accounting are exact given the
                       # seeds (bit-identity full-vs-resumed is asserted
                       # in-bench before the JSON is written)
                       "chunks_total", "dist_checksum",
                       "checkpoints_written", "resumed_chunks",
                       "recomputed_chunks", "resume_equals_full",
                       # autotuner: the static roofline plan is a pure
                       # function of graph shape + backend profile, so
                       # its checksum changing means the tuner decided
                       # differently (tiles / fused gate / direction
                       # pins), never that the machine was slow
                       "tuning_plan_checksum")
_BENCHES = ("bench_apsp", "bench_weighted", "bench_sharded",
            "bench_centrality", "bench_batching", "bench_serving",
            "bench_dynamic", "bench_resume")


def load(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def compare(current: Dict, baseline: Dict
            ) -> Tuple[List[str], List[str]]:
    """Returns (failures, warnings).  Empty failures == gate passes."""
    failures: List[str] = []
    warnings: List[str] = []
    gate = baseline.get("gate", {})
    time_tol = float(gate.get("time_tol", DEFAULT_TIME_TOL))
    min_gate = float(gate.get("min_gate_seconds", MIN_GATE_SECONDS))

    # -- structural: every baseline CSV row still exists -------------------
    cur_rows = {r["name"] for r in current.get("rows", [])}
    for r in baseline.get("rows", []):
        if r["name"] not in cur_rows:
            failures.append(f"row {r['name']!r} present in baseline but "
                            f"missing from this run")

    for bench in _BENCHES:
        base_b = baseline.get(bench) or {}
        cur_b = current.get(bench) or {}
        for fam, brow in base_b.get("families", {}).items():
            crow = cur_b.get("families", {}).get(fam)
            if crow is None:
                failures.append(f"{bench}/{fam}: family missing")
                continue
            # hard: deterministic-by-seed fields
            for field in _HARD_FAMILY_FIELDS:
                if field in brow and crow.get(field) != brow[field]:
                    failures.append(
                        f"{bench}/{fam}: {field} changed "
                        f"{brow[field]} -> {crow.get(field)} "
                        f"(deterministic field; the algorithm did "
                        f"different work)")
            # timing: interleaved medians, generous tolerance
            for key, bval in brow.items():
                if not key.endswith("_median"):
                    continue
                cval = crow.get(key)
                if cval is None:
                    failures.append(f"{bench}/{fam}: {key} missing")
                    continue
                if cval < min_gate:
                    continue
                # floor the baseline so a sub-millisecond baseline can't
                # hide an unbounded regression (tiny/tiny stays exempt
                # via the cval check above)
                ratio = cval / max(bval, min_gate)
                if ratio > time_tol:
                    failures.append(
                        f"{bench}/{fam}: {key} regressed {ratio:.2f}x "
                        f"({bval * 1e3:.2f} ms -> {cval * 1e3:.2f} ms, "
                        f"tol {time_tol}x)")
                elif ratio > 0.5 * time_tol + 0.5:
                    warnings.append(
                        f"{bench}/{fam}: {key} drifted {ratio:.2f}x "
                        f"(under the {time_tol}x gate)")
            # advisory: timing-derived acceptance booleans (the two
            # bit-identity flags are asserted in-bench before the JSON is
            # written; a flip here means a hand-edited aggregate)
            for flag in ("auto_no_slower_than_best", "auto_beats_worse",
                         "fused_equals_per_sweep",
                         "packed_push_matches_f32",
                         "oracle_p50_beats_exact",
                         "autotuned_beats_default"):
                if brow.get(flag) and not crow.get(flag, True):
                    warnings.append(f"{bench}/{fam}: {flag} flipped "
                                    f"True -> False (timing-derived; "
                                    f"not gated)")
    return failures, warnings


def check_against(current: Dict, baseline_path: str) -> int:
    """Print a report; return the number of hard failures."""
    baseline = load(baseline_path)
    failures, warnings = compare(current, baseline)
    for w in warnings:
        print(f"[bench-gate] WARN {w}")
    for f in failures:
        print(f"[bench-gate] FAIL {f}")
    if failures:
        print(f"[bench-gate] {len(failures)} regression(s) vs "
              f"{baseline_path}")
    else:
        print(f"[bench-gate] OK — no regressions vs {baseline_path} "
              f"({len(warnings)} warning(s))")
    return len(failures)
