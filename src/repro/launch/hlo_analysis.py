"""Static analysis of post-SPMD HLO text: exact per-device FLOPs, memory
traffic, and collective wire bytes with loop-trip multiplicities.

Why: ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified:
a scan of 8 matmuls reports 1 matmul of FLOPs), which under-counts scanned
layer stacks by ~100×.  This module rebuilds the numbers from the HLO text:

  * computations are parsed into instruction lists;
  * a call graph (while/fusion/call/conditional/to_apply) is walked from
    ENTRY with multiplicities — while bodies multiply by their trip count,
    recovered from the constant bound in the loop condition;
  * FLOPs: 2 · prod(result dims) · prod(contracting dims) per dot
    (counted inside fusions too);
  * bytes: operand + result bytes of every *scheduled* instruction (entry,
    while bodies, conditional branches) — fusion internals excluded, the
    fusion call-site I/O counted instead, matching what actually moves
    through HBM;
  * collectives: result bytes converted to per-device wire bytes with ring
    factors (AG/RS: (n-1)/n; AR: 2(n-1)/n; A2A: (n-1)/n).

Shapes in post-SPMD HLO are per-partition, so every number is per-device.
"""
from __future__ import annotations

import dataclasses
import gzip
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# result type is either a tuple "(... /*index=5*/ ...)" (lazy-matched up to
# the first ") opcode(") or a single shape token
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|\S+?)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->")
_ATTR_SINGLE_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")
_ATTR_LIST_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUP_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "iota", "partition-id",
                   "replica-id"}

_WIRE_FACTOR = {
    "all-gather": lambda n: (n - 1) / n,
    "all-gather-start": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-reduce-start": lambda n: 2 * (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
    "collective-permute-start": lambda n: 1.0,
}


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shape_dims(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]          # instr name -> result type text


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        # computation header: "%name (args...) -> type {" (args may nest)
        if stripped.endswith("{") and "->" in stripped and \
                (stripped.startswith("%") or stripped.startswith("ENTRY")):
            m = re.search(r"%([\w\.\-]+)", stripped)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry_name = cur.name
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, rtype, opcode = mi.groups()
            cur.instrs.append(Instr(name, opcode, rtype, line.strip()))
            cur.shapes[name] = rtype
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _operands(line: str) -> List[str]:
    """Operand names inside the first balanced paren group after the '='."""
    i = line.find("(")
    if i < 0:
        return []
    depth = 0
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                inner = line[i + 1:j]
                return re.findall(r"%([\w\.\-]+)", inner)
    return []


def _called_comps(line: str) -> List[str]:
    out = [m.group(1) for m in _ATTR_SINGLE_RE.finditer(line)]
    for m in _ATTR_LIST_RE.finditer(line):
        out.extend(re.findall(r"%?([\w\.\-]+)", m.group(1)))
    return out


def _trip_count(comps, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for ins in cond.instrs:
        m = re.search(r"constant\((\d+)\)", ins.line)
        if m:
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def multiplicities(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Execution count per computation, walking from ENTRY."""
    mult: Dict[str, float] = {}
    entry = comps.get("__entry__")
    if entry is None:
        return {}

    def visit(comp: Computation, m: float):
        mult[comp.name] = mult.get(comp.name, 0.0) + m
        for ins in comp.instrs:
            called = _called_comps(ins.line)
            if not called:
                continue
            if ins.opcode == "while":
                body_cond = re.search(
                    r"condition=%?([\w\.\-]+), body=%?([\w\.\-]+)", ins.line)
                if body_cond:
                    cond_n, body_n = body_cond.groups()
                    trip = _trip_count(comps, cond_n)
                    if body_n in comps:
                        visit(comps[body_n], m * trip)
                    if cond_n in comps:
                        visit(comps[cond_n], m * (trip + 1))
                continue
            for cn in called:
                if cn in comps:
                    visit(comps[cn], m)

    visit(entry, 1.0)
    return mult


def _dot_flops(comp: Computation, ins: Instr) -> float:
    shapes = _shape_dims(ins.result_type)
    if not shapes:
        return 0.0
    _, rdims = shapes[0]
    out = 1
    for d in rdims:
        out *= d
    ops = _operands(ins.line)
    if not ops:
        return 0.0
    lhs_type = comp.shapes.get(ops[0], "")
    lhs_shapes = _shape_dims(lhs_type)
    m = _CONTRACT_RE.search(ins.line)
    k = 1
    if lhs_shapes and m:
        _, ldims = lhs_shapes[0]
        for ds in m.group(1).split(","):
            if ds and int(ds) < len(ldims):
                k *= ldims[int(ds)]
    return 2.0 * out * k


@dataclasses.dataclass
class HloStats:
    flops: float
    bytes_accessed: float
    wire_bytes: float
    collective_ops: List[dict]
    trip_counts: Dict[str, float]


def analyze(hlo: str) -> HloStats:
    comps = parse_module(hlo)
    mult = multiplicities(comps)
    entry = comps.get("__entry__")
    entry_name = entry.name if entry else ""

    # which computations are "scheduled" (their instruction I/O is HBM
    # traffic): entry + while bodies/conds + conditional branches + call
    scheduled = {entry_name}
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.opcode in ("while", "conditional", "call"):
                scheduled.update(_called_comps(ins.line))

    def _instr_bytes(comp: Computation, ins: Instr) -> float:
        """HBM traffic of one scheduled instruction — slice-aware:
        slicing ops touch the slice, not the (possibly huge, stacked)
        operand; in-place updates touch the update region twice."""
        ops = _operands(ins.line)
        if ins.opcode in ("dynamic-slice", "slice", "gather"):
            return 2.0 * _shape_bytes(ins.result_type)
        if ins.opcode == "dynamic-update-slice":
            upd = comp.shapes.get(ops[1], "") if len(ops) > 1 else ""
            return 2.0 * _shape_bytes(upd)
        if ins.opcode == "scatter":
            upd = comp.shapes.get(ops[2], "") if len(ops) > 2 else ""
            return 3.0 * _shape_bytes(upd)
        if ins.opcode == "fusion":
            called = _called_comps(ins.line)
            body = comps.get(called[0]) if called else None
            if body is not None:
                return _fusion_bytes(body, comp, ops, ins)
        b = _shape_bytes(ins.result_type)
        for op in ops:
            b += _shape_bytes(comp.shapes.get(op, ""))
        return b

    def _fusion_bytes(body: Computation, caller: Computation,
                      call_operands: List[str], ins: Instr) -> float:
        """Fusion I/O with slice-awareness: a fusion parameter consumed
        only by slice/gather ops inside the body is charged at the slice
        size; a fusion whose root is a dynamic-update-slice is charged at
        the update size (in-place stacked-buffer update)."""
        # parameter index -> body param name
        param_names = {}
        for bi in body.instrs:
            if bi.opcode == "parameter":
                mnum = re.search(r"parameter\((\d+)\)", bi.line)
                if mnum:
                    param_names[int(mnum.group(1))] = bi.name
        total = 0.0
        for idx, opname in enumerate(call_operands):
            full = _shape_bytes(caller.shapes.get(opname, ""))
            pname = param_names.get(idx)
            if pname is None:
                total += full
                continue
            uses = [bi for bi in body.instrs
                    if pname in _operands(bi.line)]
            if uses and all(u.opcode in ("dynamic-slice", "slice", "gather")
                            for u in uses):
                total += sum(_shape_bytes(u.result_type) for u in uses)
            else:
                total += full
        # output side
        root = body.instrs[-1] if body.instrs else None
        if root is not None and root.opcode == "dynamic-update-slice":
            rops = _operands(root.line)
            upd = body.shapes.get(rops[1], "") if len(rops) > 1 else ""
            total += 2.0 * _shape_bytes(upd)
        else:
            total += _shape_bytes(ins.result_type)
        return total

    flops = 0.0
    nbytes = 0.0
    wire = 0.0
    coll_ops: List[dict] = []
    for key, comp in comps.items():
        m = mult.get(comp.name, 0.0)
        # skip the "__entry__" alias key: it holds the same object as the
        # entry's real name, and iterating both double-counts entry-level
        # instructions (dots outside any loop body)
        if m <= 0 or key == "__entry__":
            continue
        is_sched = comp.name in scheduled or comp.name == entry_name
        for ins in comp.instrs:
            if ins.opcode in ("dot", "convolution"):
                flops += _dot_flops(comp, ins) * m
            if is_sched and ins.opcode not in _SKIP_BYTES_OPS:
                nbytes += _instr_bytes(comp, ins) * m
            if ins.opcode in _WIRE_FACTOR or ins.opcode in _COLLECTIVES:
                g = _GROUP_RE.search(ins.line)
                if g:
                    group = len(g.group(1).split(","))
                else:
                    g2 = _GROUP_RE2.search(ins.line)
                    group = int(g2.group(2)) if g2 else 1
                rb = _shape_bytes(ins.result_type)
                factor = _WIRE_FACTOR.get(
                    ins.opcode, lambda n: 1.0)(max(group, 1))
                wire += rb * factor * m
                coll_ops.append({"op": ins.opcode, "bytes": rb,
                                 "group_size": group, "mult": m,
                                 "comp": comp.name})

    trips = {c: mult[c] for c in mult if mult[c] > 1}
    return HloStats(flops=flops, bytes_accessed=nbytes, wire_bytes=wire,
                    collective_ops=coll_ops, trip_counts=trips)


def analyze_file(path: str) -> HloStats:
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        return analyze(f.read())


def analyze_jitted(fn, *args, **kwargs) -> HloStats:
    """Lower + compile a callable and :func:`analyze` its optimized HLO —
    the convenience behind the autotuner's per-form pricing
    (core/autotune.py).  ``fn`` may already be jitted (anything with
    ``.lower``); a plain callable is wrapped in ``jax.jit`` first."""
    import jax
    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    return analyze(jitted.lower(*args, **kwargs).compile().as_text())
