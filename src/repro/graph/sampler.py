"""Fanout neighbor sampler (GraphSAGE) built on DAWN frontier machinery.

A fanout sample IS a randomized sub-frontier expansion: hop ``h`` draws
``fanout[h]`` neighbors per frontier node from the CSR row — exactly the
SOVM row-gather (paper Alg. 2 line 4-5) with a random subset instead of the
full row.  Fixed shapes throughout: each hop yields (batch · prod(fanouts))
node ids with repeats allowed (standard GraphSAGE semantics); zero-degree
nodes self-loop.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from .csr import CSRGraph


@partial(jax.jit, static_argnames=("fanout",))
def sample_hop(g: CSRGraph, nodes: jax.Array, key: jax.Array,
               fanout: int) -> jax.Array:
    """Sample ``fanout`` neighbors for each node. (B,) -> (B, fanout)."""
    start = g.indptr[jnp.minimum(nodes, g.n_nodes - 1)]
    deg = g.indptr[jnp.minimum(nodes, g.n_nodes - 1) + 1] - start
    r = jax.random.randint(key, (nodes.shape[0], fanout), 0, jnp.iinfo(jnp.int32).max)
    # r mod deg, guarding deg==0 → self-loop
    safe_deg = jnp.maximum(deg, 1)
    offs = r % safe_deg[:, None]
    eidx = start[:, None] + offs
    nbrs = g.indices[jnp.clip(eidx, 0, g.m_pad - 1)]
    return jnp.where(deg[:, None] > 0, nbrs, nodes[:, None])


def sample_subgraph(g: CSRGraph, seeds: jax.Array, key: jax.Array,
                    fanouts: Sequence[int]) -> Tuple[jax.Array, ...]:
    """Multi-hop fanout sample. Returns tuple of per-hop node-id arrays:
    layer 0 = seeds (B,), layer h = (B * prod(fanouts[:h]),)."""
    layers = [seeds]
    cur = seeds
    for h, f in enumerate(fanouts):
        key, sub = jax.random.split(key)
        nbrs = sample_hop(g, cur, sub, int(f))
        cur = nbrs.reshape(-1)
        layers.append(cur)
    return tuple(layers)
