"""The counting semiring + batched centrality subsystem vs independent
NumPy oracles: exact betweenness (Brandes), closeness/harmonic/
eccentricity, the counting kernel path, and cross-form equivalence —
per docs/TESTING.md conventions (seeded parametrize always runs; the
hypothesis variants ride along when hypothesis is installed)."""
import numpy as np
import pytest
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # the seeded variants below always run
    HAVE_HYPOTHESIS = False

from repro.core import (CentralityConfig, betweenness, brandes_dependencies,
                        centrality, closeness, counting_apsp, eccentricity,
                        eccentricity_sample, harmonic)
from repro.graph import generators as gen
from repro.graph.csr import CSRGraph

from oracles import (bfs_dists, bfs_sigmas, brandes_betweenness,
                     closeness_centrality, eccentricities,
                     harmonic_centrality)

FAMILIES = {
    "grid": lambda: gen.grid2d(9, 9),
    "rmat": lambda: gen.rmat(7, 4, directed=False, seed=2),
    "er_directed": lambda: gen.erdos_renyi(90, 3.0, seed=9),
    "ws": lambda: gen.watts_strogatz(96, 6, 0.1, seed=4),
    "disconnected": lambda: gen.disconnected(4, 24, 3.0, seed=5),
}


# -- the counting engine: dist == BFS, sigma == path counts -----------------

@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_counting_dist_and_sigma_match_oracle(family):
    """The forward counting sweeps produce the queue-BFS levels AND the
    textbook path counts, on every family including disconnected."""
    g = FAMILIES[family]()
    sources = np.arange(min(16, g.n_nodes), dtype=np.int32)
    res = counting_apsp(g, sources,
                        config=CentralityConfig(source_batch=16))
    np.testing.assert_array_equal(np.asarray(res.dist),
                                  bfs_dists(g, sources), err_msg=family)
    np.testing.assert_allclose(np.asarray(res.sigma),
                               bfs_sigmas(g, sources), err_msg=family)


@pytest.mark.parametrize("mode", ["push", "sparse"])
def test_counting_forms_agree(mode):
    """push ≡ sparse: the non-idempotent ⊕ gives the same (dist, sigma)
    through the dense f32 GEMM and the edge-parallel scatter-add."""
    g = gen.rmat(7, 4, directed=False, seed=2)
    sources = np.arange(16, dtype=np.int32)
    res = counting_apsp(g, sources,
                        config=CentralityConfig(mode=mode,
                                                source_batch=16))
    np.testing.assert_array_equal(np.asarray(res.dist),
                                  bfs_dists(g, sources))
    np.testing.assert_allclose(np.asarray(res.sigma),
                               bfs_sigmas(g, sources))
    counts = np.asarray(res.direction_counts)
    idx = ["push", "sparse"].index(mode)
    assert counts[idx] == counts.sum() > 0


def test_counting_kernel_path_bit_identical():
    """The fused counting Pallas kernel (interpret=True) and the XLA
    reference form are the same sweeps: identical dist AND sigma."""
    g = gen.rmat(7, 4, directed=False, seed=3)
    sources = np.arange(24, dtype=np.int32)
    ref = counting_apsp(g, sources,
                        config=CentralityConfig(mode="push",
                                                source_batch=24,
                                                use_kernel=False))
    kern = counting_apsp(g, sources,
                         config=CentralityConfig(mode="push",
                                                 source_batch=24,
                                                 use_kernel=True))
    np.testing.assert_array_equal(np.asarray(kern.dist),
                                  np.asarray(ref.dist))
    np.testing.assert_array_equal(np.asarray(kern.sigma),
                                  np.asarray(ref.sigma))
    assert int(kern.sweeps) == int(ref.sweeps)


# -- exact betweenness vs the independent Brandes oracle --------------------

def _check_betweenness(n, avg_deg, seed, *, config=None):
    rng = np.random.default_rng(seed)
    m = max(1, int(n * avg_deg))
    g = CSRGraph.from_edges(rng.integers(0, n, m),
                            rng.integers(0, n, m), n)
    ref = brandes_betweenness(g)
    got = betweenness(g, config=config)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("seed", range(8))
def test_betweenness_matches_brandes_oracle(seed):
    rng = np.random.default_rng(seed * 4001 + 17)
    _check_betweenness(int(rng.integers(4, 81)),
                       float(rng.uniform(1.0, 5.0)),
                       int(rng.integers(0, 10**6)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(4, 80), avg_deg=st.floats(1.0, 5.0),
           seed=st.integers(0, 10**6))
    def test_betweenness_matches_brandes_oracle_hypothesis(n, avg_deg,
                                                           seed):
        _check_betweenness(n, avg_deg, seed)


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_betweenness_families(family):
    """Acceptance: exact betweenness on every seeded family, including
    the disconnected one (unreachable pairs contribute nothing)."""
    g = FAMILIES[family]()
    np.testing.assert_allclose(betweenness(g), brandes_betweenness(g),
                               rtol=1e-4, atol=1e-6, err_msg=family)


@pytest.mark.parametrize("mode,use_kernel", [("push", False),
                                             ("sparse", False),
                                             ("auto", False),
                                             ("push", True),
                                             ("auto", True)])
def test_betweenness_every_execution_path(mode, use_kernel):
    """Acceptance: the Brandes pipeline is exact through every form and
    the Pallas kernel (interpret) path."""
    _check_betweenness(72, 3.0, 123,
                       config=CentralityConfig(mode=mode, source_batch=24,
                                               use_kernel=use_kernel))


def test_betweenness_source_subset_and_normalization():
    g = gen.watts_strogatz(64, 4, 0.2, seed=6)
    sources = np.asarray([0, 3, 7, 11, 40], np.int32)
    ref = brandes_betweenness(g, sources)
    got = betweenness(g, sources)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-6)
    n = g.n_nodes
    np.testing.assert_allclose(betweenness(g, sources, normalized=True),
                               ref / ((n - 1) * (n - 2)), rtol=1e-4,
                               atol=1e-9)


def test_brandes_dependencies_delta_shape_and_source_row():
    g = gen.grid2d(6, 6)
    sources = np.arange(4, dtype=np.int32)
    res = counting_apsp(g, sources,
                        config=CentralityConfig(source_batch=8))
    delta = np.asarray(brandes_dependencies(g, res.dist, res.sigma))
    assert delta.shape == (4, g.n_nodes)
    # δ_s(s) counts paths through the source as an interior node of its
    # own tree — Brandes drops it from bc; it must still be finite
    assert np.isfinite(delta).all()


# -- closeness / harmonic / eccentricity vs oracles -------------------------

@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_distance_measures_match_oracles(family):
    g = FAMILIES[family]()
    sources = np.arange(min(24, g.n_nodes), dtype=np.int32)
    res = centrality(g, sources,
                     measures=("closeness", "harmonic", "eccentricity"))
    np.testing.assert_allclose(res.closeness,
                               closeness_centrality(g, sources),
                               rtol=1e-9, err_msg=family)
    np.testing.assert_allclose(res.harmonic,
                               harmonic_centrality(g, sources),
                               rtol=1e-5, err_msg=family)
    np.testing.assert_array_equal(res.eccentricity,
                                  eccentricities(g, sources),
                                  err_msg=family)


def test_exact_eccentricity_radius_diameter():
    g = gen.grid2d(10, 10)   # diameter 18; radius 10 (even side: the
    est = eccentricity(g)    # four central cells sit at ecc 5+5)
    np.testing.assert_array_equal(est["ecc"], eccentricities(g))
    assert est["diameter"] == 18
    assert est["radius"] == 10
    # the sampled bounds bracket the exact values
    s = eccentricity_sample(g, n_samples=20, seed=1)
    assert s["radius_upper"] >= est["radius"]
    assert s["diameter_lower"] <= est["diameter"]


def test_disconnected_graph_conventions():
    """Unreachable pairs: closeness Wasserman-Faust-scales, harmonic and
    betweenness simply drop them, eccentricity is per-component."""
    g = gen.disconnected(3, 20, 3.0, seed=7)
    res = centrality(g)
    np.testing.assert_allclose(res.closeness, closeness_centrality(g),
                               rtol=1e-9)
    np.testing.assert_allclose(res.betweenness, brandes_betweenness(g),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_array_equal(res.eccentricity, eccentricities(g))
    ecc = np.asarray(res.eccentricity)
    assert res.diameter == int(ecc.max())
    assert res.radius == int(ecc[ecc > 0].min())


def test_centrality_rejects_unknown_measures():
    g = gen.grid2d(4, 4)
    with pytest.raises(ValueError, match="unknown measures"):
        centrality(g, measures=("pagerank",))


def test_centrality_rejects_empty_and_out_of_range_sources():
    g = gen.grid2d(4, 4)
    with pytest.raises(ValueError, match="empty source list"):
        centrality(g, sources=[], measures=("eccentricity",))
    with pytest.raises(ValueError, match="must be in"):
        centrality(g, sources=[99], measures=("closeness",))


def test_single_measure_wrappers_match_full_run():
    g = gen.watts_strogatz(80, 4, 0.1, seed=9)
    sources = np.arange(16)
    res = centrality(g, sources)
    np.testing.assert_allclose(closeness(g, sources), res.closeness)
    np.testing.assert_allclose(harmonic(g, sources), res.harmonic)
    full = betweenness(g)
    np.testing.assert_allclose(full, brandes_betweenness(g), rtol=1e-4,
                               atol=1e-6)


def test_sigma_checksum_is_deterministic():
    """The benchmark gate's hard field: two runs on the same seeded
    graph produce the identical path-count checksum, and it moves when
    the graph does."""
    g = gen.watts_strogatz(64, 4, 0.2, seed=3)
    a = centrality(g, measures=("betweenness",)).sigma_checksum
    b = centrality(g, measures=("betweenness",)).sigma_checksum
    assert a == b > 0
    g2 = gen.watts_strogatz(64, 4, 0.2, seed=4)
    assert centrality(g2, measures=("betweenness",)).sigma_checksum != a
