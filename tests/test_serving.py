"""Serving engine: continuous batching must match offline greedy decode."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.serve import Request, ServingEngine

CFG = T.LMConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                 d_head=16, d_ff=128, vocab=96)


def _offline(params, prompt, max_new):
    toks = list(prompt)
    for _ in range(max_new):
        lg = T.forward(params, jnp.asarray([toks]), CFG)
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_offline_greedy():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    eng = ServingEngine(params, CFG, slots=2, max_len=64)
    reqs = []
    for r in range(5):
        prompt = (np.arange(3 + 2 * r) * 7 + r) % CFG.vocab
        reqs.append(Request(rid=r, prompt=prompt.astype(np.int32),
                            max_new=3 + (r % 3)))
        eng.submit(reqs[-1])
    done = eng.run_to_completion()
    assert len(done) == 5
    for d in done:
        assert d.out == _offline(params, d.prompt, d.max_new)


def test_slot_reuse_and_latency_fields():
    params = T.init_params(jax.random.PRNGKey(1), CFG)
    eng = ServingEngine(params, CFG, slots=1, max_len=64)
    for r in range(3):
        eng.submit(Request(rid=r, prompt=np.array([1, 2, 3], np.int32),
                           max_new=2))
    done = eng.run_to_completion()
    assert len(done) == 3
    for d in done:
        assert d.t_done >= d.t_first >= d.t_submit


def test_decode_active_mask_freezes_rows():
    params = T.init_params(jax.random.PRNGKey(2), CFG)
    cache = T.make_cache(CFG, 2, 8)
    toks = jnp.asarray([[5], [9]])
    active = jnp.asarray([True, False])
    _, cache = T.decode_step(params, cache, toks, CFG, active=active)
    assert int(cache["pos"][0]) == 1
    assert int(cache["pos"][1]) == 0
    assert float(jnp.abs(cache["k"][:, 1].astype(jnp.float32)).sum()) == 0.0
