"""Pallas kernel validation (interpret=True): the semiring kernel registry,
shape/dtype sweeps + full BFS drivers vs the pure-jnp oracles for the
boolean kernels, and the tropical min-plus kernels vs their oracles, the
dense reference forms, and scipy Dijkstra.

This module runs without hypothesis (only the property-based test is
guarded) so CI can execute it as its own fast kernel-layer job step.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # seeded variants below always run regardless
    HAVE_HYPOTHESIS = False

from repro.graph import generators as gen
from repro.core import WeightedConfig, pack_bits, weighted_apsp
from oracles import bfs_dists, dijkstra_dists
from repro.kernels import common, registry
from repro.kernels.bovm import (fused_sweep, packed_pull_sweep,
                                packed_push_sweep, fused_boolean_multisweep,
                                sweep_ref, packed_pull_ref, packed_push_ref,
                                msbfs_kernel, msbfs_packed,
                                pack_adjacency_pull)
from repro.kernels.tropical import (fused_minplus_sweep,
                                    fused_minplus_multisweep,
                                    sparse_relax_sweep,
                                    minplus_sweep_ref, sparse_relax_ref)
from repro.kernels.counting import (fused_counting_sweep,
                                    fused_counting_multisweep,
                                    counting_sweep_ref)


def _random_state(rng, s, n, density=0.05, visited=0.2):
    f = (rng.random((s, n)) < density).astype(np.int8)
    dist = np.where(rng.random((s, n)) < visited, 1, -1).astype(np.int32)
    return jnp.asarray(f), jnp.asarray(dist)


# --------------------------------------------------------------------------
# the registry: one substrate, N semirings
# --------------------------------------------------------------------------

def test_registry_has_every_semiring():
    assert registry.available() == ("boolean", "counting", "tropical")
    assert registry.has("boolean") and registry.has("tropical")
    assert registry.has("counting")
    assert set(registry.get("boolean").forms) == {"push", "push_f32",
                                                  "pull"}
    assert set(registry.get("tropical").forms) == {"dense", "sparse"}
    assert set(registry.get("counting").forms) == {"push"}


def test_registry_has_fused_multisweep_capability():
    """Every semiring ships the fused multi-sweep persistent form under
    the same key its per-sweep kernel uses — the capability
    sweep.resolve_fused_steps consults."""
    assert set(registry.get("boolean").fused_forms) == {"push"}
    assert set(registry.get("tropical").fused_forms) == {"dense"}
    assert set(registry.get("counting").fused_forms) == {"push"}
    assert registry.get("boolean").fused_forms["push"] \
        is fused_boolean_multisweep
    assert registry.get("tropical").fused_forms["dense"] \
        is fused_minplus_multisweep
    assert registry.get("counting").fused_forms["push"] \
        is fused_counting_multisweep


def test_registry_accepts_semiring_objects():
    from repro.core import BOOLEAN, COUNTING, TROPICAL
    # the boolean kernel push is the bit-packed word sweep (no f32 GEMM);
    # the old MXU GEMM survives under the explicit "push_f32" key
    assert registry.get(BOOLEAN).forms["push"] is packed_push_sweep
    assert registry.get(BOOLEAN).forms["push_f32"] is fused_sweep
    assert registry.get(TROPICAL).forms["dense"] is fused_minplus_sweep
    assert registry.get(COUNTING).forms["push"] is fused_counting_sweep
    with pytest.raises(KeyError, match="min_label"):
        registry.get("min_label")    # no kernels for label propagation


def test_vmem_budgets_under_per_core_limit():
    """Every registered kernel's default tiles sit well under ~16 MB."""
    assert registry.get("boolean").vmem_bytes(form="push") \
        < common.VMEM_BUDGET_BYTES // 4
    assert registry.get("boolean").vmem_bytes(form="pull") \
        < common.VMEM_BUDGET_BYTES // 4
    assert registry.get("tropical").vmem_bytes(form="dense") \
        < common.VMEM_BUDGET_BYTES // 4
    assert registry.get("tropical").vmem_bytes(form="sparse", s=128,
                                               n_pad=2048) \
        < common.VMEM_BUDGET_BYTES // 4
    assert registry.get("counting").vmem_bytes(form="push") \
        < common.VMEM_BUDGET_BYTES // 4
    assert registry.get("boolean").vmem_bytes(form="push_f32") \
        < common.VMEM_BUDGET_BYTES // 4


def test_fused_vmem_scales_with_whole_operand():
    """The fused forms hold the WHOLE operand resident: their cost is a
    function of n, grows quadratically, and the default paddings still
    fit the 16 MB budget — exactly what resolve_fused_steps gates on."""
    for semi, mult in (("boolean", 1 / 8), ("tropical", 4),
                       ("counting", 1)):
        ks = registry.get(semi)
        small = ks.vmem_bytes(form="fused", bs=128, n=1152)
        big = ks.vmem_bytes(form="fused", bs=128, n=4 * 1152)
        assert small < common.VMEM_BUDGET_BYTES, (semi, small)
        # superlinear in n: the resident whole-operand term scales n^2
        # (the per-row state term alone would only scale linearly, x4)
        assert big > small * 4, (semi, small, big)
        assert small > 1152 * 1152 * mult, (semi, small)
    # the gate actually trips for an operand that cannot fit
    import repro.core.sweep as S
    assert S.resolve_fused_steps("tropical", "dense", fused_steps=-1,
                                 max_steps=64, use_kernel=True,
                                 n_pad=8192, bs=128) is None
    assert S.resolve_fused_steps("tropical", "dense", fused_steps=-1,
                                 max_steps=64, use_kernel=True,
                                 n_pad=1152, bs=128) == 64
    assert S.resolve_fused_steps("tropical", "dense", fused_steps=4,
                                 max_steps=64, use_kernel=True,
                                 n_pad=1152, bs=128) == 4
    # reference path and unregistered semirings never fuse
    assert S.resolve_fused_steps("tropical", "dense", fused_steps=-1,
                                 max_steps=64, use_kernel=False,
                                 n_pad=1152, bs=128) is None
    assert S.resolve_fused_steps("min_label", "push", fused_steps=-1,
                                 max_steps=64, use_kernel=True,
                                 n_pad=1152, bs=128) is None


# --------------------------------------------------------------------------
# boolean semiring kernels (paper Algs. 1/2)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("s,n,bs,bn,bk", [
    (64, 256, 64, 128, 128),
    (128, 512, 128, 128, 256),
    (8, 128, 8, 128, 128),
    (256, 384, 64, 128, 128),
])
def test_fused_sweep_shapes(s, n, bs, bn, bk):
    rng = np.random.default_rng(s * n)
    g = gen.erdos_renyi(n, 4.0, seed=n, directed=False)
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    f, dist = _random_state(rng, s, n)
    new_k, dist_k = fused_sweep(f, adj, dist, 5, bs=bs, bn=bn, bk=bk,
                                interpret=True)
    new_r, dist_r = sweep_ref(f, adj, dist, 5)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


@pytest.mark.parametrize("s,n,bs,bn,wk", [
    (8, 256, 8, 128, 8),
    (16, 512, 8, 128, 16),
    (32, 128, 16, 128, 4),
])
def test_packed_pull_shapes(s, n, bs, bn, wk):
    rng = np.random.default_rng(s + n)
    g = gen.erdos_renyi(n, 5.0, seed=n + 1, directed=True)
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    ap = pack_adjacency_pull(adj)
    f, dist = _random_state(rng, s, n)
    fp = pack_bits(f > 0)
    new_k, dist_k = packed_pull_sweep(fp, ap, dist, 3, bs=bs, bn=bn, wk=wk,
                                      interpret=True)
    new_r, dist_r = packed_pull_ref(fp, ap, dist, 3)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


@pytest.mark.parametrize("s,n,bs,bn,wk", [
    (128, 256, 128, 128, 8),
    (64, 512, 64, 128, 16),
    (8, 128, 8, 128, 4),
    (256, 384, 128, 128, 4),     # ragged: n not a multiple of bn*2
])
def test_packed_push_shapes(s, n, bs, bn, wk):
    """The bit-packed push drives the same word-AND/OR math as pull: the
    packed frontier rows hit the packed in-neighbour words, so the shared
    packed_pull_ref is its oracle too."""
    rng = np.random.default_rng(3 * s + n)
    g = gen.erdos_renyi(n, 5.0, seed=n + 2, directed=True)
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    ap = pack_adjacency_pull(adj)
    f, dist = _random_state(rng, s, n)
    fp = pack_bits(f > 0)
    new_k, dist_k = packed_push_sweep(fp, ap, dist, 3, bs=bs, bn=bn, wk=wk,
                                      interpret=True)
    new_r, dist_r = packed_push_ref(fp, ap, dist, 3)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


def test_packed_push_matches_f32_push():
    """Packed word push == the f32 GEMM push it replaces, bit for bit."""
    rng = np.random.default_rng(11)
    n, s = 256, 64
    adj = jnp.asarray((rng.random((n, n)) < 0.03).astype(np.int8))
    f, dist = _random_state(rng, s, n)
    new_p, dist_p = packed_push_sweep(pack_bits(f > 0),
                                      pack_adjacency_pull(adj), dist, 5,
                                      bs=64, bn=128, wk=8, interpret=True)
    new_g, dist_g = fused_sweep(f, adj, dist, 5, bs=64, bn=128, bk=128,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(new_p), np.asarray(new_g))
    np.testing.assert_array_equal(np.asarray(dist_p), np.asarray(dist_g))


def test_packed_push_tile_skip_preserves_semantics():
    """Adversarial occupancy: one frontier word block and one unreached
    block live — every skipped (i, j, k) tile must be provably inert."""
    n, s = 512, 128
    rng = np.random.default_rng(5)
    adj = jnp.asarray((rng.random((n, n)) < 0.02).astype(np.int8))
    f = np.zeros((s, n), np.int8)
    f[:, :32] = 1                       # frontier in the first word block
    dist = np.zeros((s, n), np.int32)   # almost everything settled…
    dist[:, 256:] = -1                  # …except the last j tiles
    fp = pack_bits(jnp.asarray(f) > 0)
    ap = pack_adjacency_pull(adj)
    new_k, dist_k = packed_push_sweep(fp, ap, jnp.asarray(dist), 4,
                                      bs=128, bn=128, wk=4, interpret=True)
    new_r, dist_r = packed_push_ref(fp, ap, jnp.asarray(dist), 4)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


# --------------------------------------------------------------------------
# fused multi-sweep persistent kernels (all semirings, one skeleton)
# --------------------------------------------------------------------------

def _per_sweep_boolean(f, ap, dist, step, n_run):
    """Oracle: n_run per-sweep packed pushes with the fused accounting
    contract (prod = productive sweeps, stopped = converged mid-block)."""
    prod, stopped = 0, False
    new = jnp.zeros_like(dist, dtype=jnp.int8)
    for t in range(n_run):
        if stopped:
            break
        new, dist = packed_push_ref(pack_bits(new != 0) if t else f,
                                    ap, dist, step + 1 + t)
        if bool(jnp.any(new != 0)):
            prod += 1
        else:
            stopped = True
    return new, dist, prod, stopped


@pytest.mark.parametrize("n_run", [1, 2, 3, 7])
def test_fused_boolean_multisweep_matches_per_sweep(n_run):
    rng = np.random.default_rng(n_run)
    n, s = 256, 128
    adj = jnp.asarray((rng.random((n, n)) < 0.02).astype(np.int8))
    ap = pack_adjacency_pull(adj)
    f = jnp.asarray((rng.random((s, n)) < 0.02).astype(np.int8))
    dist = jnp.where(f != 0, 3, -1).astype(jnp.int32)
    new_k, dist_k, prod_k, stop_k = fused_boolean_multisweep(
        f, ap, dist, 3, n_run, bs=128, max_sweeps=n_run, interpret=True)
    new_r, dist_r, prod_r, stop_r = _per_sweep_boolean(
        pack_bits(f != 0), ap, dist, 3, n_run)
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    assert int(prod_k) == prod_r and bool(stop_k) == stop_r


def test_fused_boolean_multisweep_converges_mid_block():
    """Fact 1 inside the block: a 3-hop path exhausts after 3 productive
    sweeps of an 8-sweep block — the kernel must report stopped with
    prod == 3 and leave dist at the fixpoint."""
    n, s = 128, 8
    src = np.array([0, 1, 2])
    dst = np.array([1, 2, 3])
    adj = np.zeros((n, n), np.int8)
    adj[src, dst] = 1
    ap = pack_adjacency_pull(jnp.asarray(adj))
    f = np.zeros((s, n), np.int8)
    f[:, 0] = 1
    dist = np.full((s, n), -1, np.int32)
    dist[:, 0] = 0
    new, dist_out, prod, stop = fused_boolean_multisweep(
        jnp.asarray(f), ap, jnp.asarray(dist), 0, 8, bs=8, max_sweeps=8,
        interpret=True)
    assert int(prod) == 3 and bool(stop)
    assert np.asarray(new).sum() == 0          # final frontier is empty
    expect = np.full(n, -1, np.int32)
    expect[:4] = [0, 1, 2, 3]
    np.testing.assert_array_equal(np.asarray(dist_out)[0], expect)


def test_fused_boolean_multisweep_not_converged_keeps_frontier():
    """A block that ends mid-BFS reports stopped=False, prod == n_run and
    a live packed frontier equal to the last sweep's discoveries."""
    n, s = 128, 8
    adj = np.zeros((n, n), np.int8)
    adj[np.arange(20), np.arange(1, 21)] = 1      # a 20-hop path
    ap = pack_adjacency_pull(jnp.asarray(adj))
    f = np.zeros((s, n), np.int8)
    f[:, 0] = 1
    dist = np.full((s, n), -1, np.int32)
    dist[:, 0] = 0
    new, dist_out, prod, stop = fused_boolean_multisweep(
        jnp.asarray(f), ap, jnp.asarray(dist), 0, 5, bs=8, max_sweeps=5,
        interpret=True)
    assert int(prod) == 5 and not bool(stop)
    assert np.asarray(new)[0, 5] == 1 and np.asarray(new)[0].sum() == 1
    assert np.asarray(dist_out)[0, 5] == 5


def test_fused_minplus_multisweep_matches_per_sweep():
    """Tropical fused block == iterated per-sweep min-plus reference."""
    rng = np.random.default_rng(17)
    n, s = 256, 64
    mask = rng.random((n, n)) < 0.03
    w = np.where(mask, rng.integers(1, 8, (n, n)).astype(np.float32),
                 np.inf)
    np.fill_diagonal(w, np.inf)
    dist = np.full((s, n), np.inf, np.float32)
    dist[np.arange(s), np.arange(s)] = 0.0
    f = (dist == 0).astype(np.int8)
    wj = jnp.asarray(w)
    d = jnp.asarray(dist)
    new_k, dist_k, prod_k, stop_k = fused_minplus_multisweep(
        jnp.asarray(f), wj, d, 0, 6, bs=64, max_sweeps=6, interpret=True)
    # reference: per-sweep dense min-plus with the same convergence rule
    fr, dr, prod_r, stop_r = jnp.asarray(f), d, 0, False
    for _ in range(6):
        if stop_r:
            break
        fd = jnp.where(fr != 0, dr, jnp.inf)
        nd = minplus_sweep_ref(fd, wj, dr)[1]
        fr = (nd < dr).astype(jnp.int8)
        dr = nd
        if bool(jnp.any(fr != 0)):
            prod_r += 1
        else:
            stop_r = True
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dr))
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(fr))
    assert int(prod_k) == prod_r and bool(stop_k) == stop_r


def test_fused_counting_multisweep_matches_per_sweep():
    """Counting fused block == iterated per-sweep counting kernel: the
    (dist, sigma) pair stays resident and path counts stay exact."""
    rng = np.random.default_rng(23)
    n, s = 256, 64
    adj = jnp.asarray((rng.random((n, n)) < 0.03).astype(np.int8))
    dist = np.full((s, n), -1, np.int32)
    dist[np.arange(s), np.arange(s)] = 0
    sigma = (dist == 0).astype(np.float32)
    f = (dist == 0).astype(np.int8)
    d, sg, fr = jnp.asarray(dist), jnp.asarray(sigma), jnp.asarray(f)
    new_k, (dist_k, sig_k), prod_k, stop_k = fused_counting_multisweep(
        fr, adj, (d, sg), 0, 6, bs=64, max_sweeps=6, interpret=True)
    prod_r, stop_r = 0, False
    new_r = jnp.zeros_like(fr)
    for t in range(6):
        if stop_r:
            break
        fs = jnp.where(fr != 0, sg, 0.0)
        new_r, d, sg = fused_counting_sweep(fs, adj, d, sg, t + 1, bs=64,
                                            interpret=True)
        fr = new_r
        if bool(jnp.any(new_r != 0)):
            prod_r += 1
        else:
            stop_r = True
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(d))
    np.testing.assert_array_equal(np.asarray(sig_k), np.asarray(sg))
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    assert int(prod_k) == prod_r and bool(stop_k) == stop_r


# --------------------------------------------------------------------------
# structural guard: the boolean kernel push must not lower an f32 GEMM
# --------------------------------------------------------------------------

def _boolean_push_jaxpr(n=256, s=64):
    import repro.core.sweep as S
    adj_pull = jnp.zeros((n, n // 32), jnp.uint32)
    push = S.boolean_forms(jnp.zeros((1, 1), jnp.int8), adj_pull,
                           jnp.zeros((1,), jnp.int32),
                           jnp.zeros((1,), jnp.int32), n_pad=n, s=s,
                           use_kernel=True, interpret=True)[S.PUSH]
    f = jnp.zeros((s, n), jnp.int8)
    d = jnp.zeros((s, n), jnp.int32)
    p = jnp.zeros((s, n), jnp.int32)
    return str(jax.make_jaxpr(push)(f, d, p, jnp.int32(1)))


def test_boolean_kernel_push_has_no_f32_dot():
    """Bit-packing is structural, not incidental: the boolean kernel
    push (and the fused boolean block) must trace to a jaxpr with NO
    dot_general anywhere — dense boolean push no longer pays f32 GEMM
    cost (paper Eq. 13: 32 adjacency lanes per uint32 word)."""
    assert "dot_general" not in _boolean_push_jaxpr()
    n, s = 256, 64
    fused_jaxpr = str(jax.make_jaxpr(
        lambda f, ap, d: fused_boolean_multisweep(
            f, ap, d, 0, 4, bs=64, max_sweeps=4, interpret=True))(
        jnp.zeros((s, n), jnp.int8), jnp.zeros((n, n // 32), jnp.uint32),
        jnp.zeros((s, n), jnp.int32)))
    assert "dot_general" not in fused_jaxpr


def test_no_f32_dot_guard_sees_nested_jaxprs():
    """Positive controls for the guard above: (a) the XLA reference push
    DOES contain dot_general, and (b) a dot inside a pallas_call kernel
    (the counting fused block, interpret mode) IS visible to the same
    str(make_jaxpr(...)) probe — so the boolean assertion cannot pass
    vacuously by the dot hiding below the traced surface."""
    import repro.core.sweep as S
    n, s = 256, 64
    adj = jnp.zeros((n, n), jnp.int8)
    ref_push = S.boolean_forms(adj, jnp.zeros((1, 1), jnp.uint32),
                               jnp.zeros((1,), jnp.int32),
                               jnp.zeros((1,), jnp.int32), n_pad=n, s=s,
                               use_kernel=False, interpret=True)[S.PUSH]
    f = jnp.zeros((s, n), jnp.int8)
    d = jnp.zeros((s, n), jnp.int32)
    p = jnp.zeros((s, n), jnp.int32)
    assert "dot_general" in str(jax.make_jaxpr(ref_push)(f, d, p,
                                                         jnp.int32(1)))
    counting_jaxpr = str(jax.make_jaxpr(
        lambda f8, a, dd, sgg: fused_counting_multisweep(
            f8, a, (dd, sgg), 0, 2, bs=64, max_sweeps=2, interpret=True))(
        jnp.zeros((s, n), jnp.int8), adj, d,
        jnp.zeros((s, n), jnp.float32)))
    assert "dot_general" in counting_jaxpr


def _fused_sweep_vs_ref(seed, density, visited):
    """kernel == oracle for arbitrary frontier/visited states."""
    rng = np.random.default_rng(seed)
    n, s = 256, 64
    adj = jnp.asarray((rng.random((n, n)) < 0.02).astype(np.int8))
    f = jnp.asarray((rng.random((s, n)) < density).astype(np.int8))
    dist = jnp.asarray(
        np.where(rng.random((s, n)) < visited, 2, -1).astype(np.int32))
    new_k, dist_k = fused_sweep(f, adj, dist, 7, bs=64, bn=128, bk=128,
                                interpret=True)
    new_r, dist_r = sweep_ref(f, adj, dist, 7)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


@pytest.mark.parametrize("seed", range(8))
def test_fused_sweep_randomized(seed):
    """Seeded always-run slice of the property space (the hypothesis
    variant below explores it adaptively when hypothesis is installed)."""
    rng = np.random.default_rng(seed * 7919 + 13)
    _fused_sweep_vs_ref(int(rng.integers(0, 10_000)),
                        float(rng.uniform(0.0, 0.3)),
                        float(rng.uniform(0.0, 1.0)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), density=st.floats(0.0, 0.3),
           visited=st.floats(0.0, 1.0))
    def test_fused_sweep_property(seed, density, visited):
        _fused_sweep_vs_ref(seed, density, visited)


def test_msbfs_kernel_end_to_end():
    g = gen.rmat(8, 5, directed=False, seed=21)
    n = 256
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    srcs = jnp.arange(64, dtype=jnp.int32)
    res = msbfs_kernel(adj, srcs, max_steps=n, interpret=True,
                       bs=64, bn=128, bk=128)
    refs = bfs_dists(g, np.asarray(srcs))
    np.testing.assert_array_equal(
        np.asarray(res.dist)[:, :g.n_nodes], refs)


def test_msbfs_packed_end_to_end():
    g = gen.rmat(8, 5, directed=True, seed=22)
    n = 256
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    ap = pack_adjacency_pull(adj)
    srcs = jnp.arange(16, dtype=jnp.int32)
    res = msbfs_packed(ap, srcs, n, max_steps=n, interpret=True,
                       bs=8, bn=128, wk=8)
    refs = bfs_dists(g, np.asarray(srcs))
    np.testing.assert_array_equal(
        np.asarray(res.dist)[:, :g.n_nodes], refs)


def test_tile_skip_preserves_semantics():
    """All-visited output tiles and empty frontier tiles must not change
    results (the Thm 3.2 tile-skip)."""
    rng = np.random.default_rng(0)
    n, s = 256, 64
    adj = jnp.asarray((rng.random((n, n)) < 0.05).astype(np.int8))
    f = np.zeros((s, n), np.int8)
    f[:, :128] = (rng.random((s, 128)) < 0.1)   # half the k-tiles empty
    dist = np.full((s, n), -1, np.int32)
    dist[:, 128:] = 3                            # half the out-tiles visited
    new_k, dist_k = fused_sweep(jnp.asarray(f), adj, jnp.asarray(dist), 4,
                                bs=64, bn=128, bk=128, interpret=True)
    new_r, dist_r = sweep_ref(jnp.asarray(f), adj, jnp.asarray(dist), 4)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


# --------------------------------------------------------------------------
# tropical semiring kernels (paper §5, min-plus)
# --------------------------------------------------------------------------

def _random_tropical_state(rng, s, n, *, density=0.03, wdensity=0.03):
    w = np.full((n, n), np.inf, np.float32)
    mask = rng.random((n, n)) < wdensity
    w[mask] = rng.uniform(0.5, 4.0, mask.sum())
    dist = np.where(rng.random((s, n)) < 0.3,
                    rng.uniform(0.0, 10.0, (s, n)), np.inf).astype(np.float32)
    f = (rng.random((s, n)) < density).astype(np.int8)
    fdist = np.where(f != 0, dist, np.inf).astype(np.float32)
    finite = w[np.isfinite(w)]
    w_min = np.float32(finite.min() if finite.size else np.inf)
    return (jnp.asarray(f), jnp.asarray(fdist), jnp.asarray(w),
            jnp.asarray(dist), w_min)


@pytest.mark.parametrize("s,n,bs,bn,bk", [
    (64, 256, 64, 128, 128),
    (8, 128, 8, 128, 128),
    (16, 384, 16, 128, 128),
])
def test_minplus_sweep_shapes(s, n, bs, bn, bk):
    rng = np.random.default_rng(s * n + 1)
    _, fdist, w, dist, w_min = _random_tropical_state(rng, s, n)
    new_k, dist_k = fused_minplus_sweep(fdist, w, dist, w_min, bs=bs, bn=bn,
                                        bk=bk, interpret=True)
    new_r, dist_r = minplus_sweep_ref(fdist, w, dist)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


def test_minplus_settled_skip_preserves_semantics():
    """The tropical o_occ table (Dijkstra settled bound at tile rank) must
    be exact: tiles whose distances all sit under min_frontier + w_min are
    skipped, and the result still matches the unskipped oracle."""
    rng = np.random.default_rng(7)
    s, n = 64, 256
    w = np.full((n, n), np.inf, np.float32)
    mask = rng.random((n, n)) < 0.05
    w[mask] = rng.uniform(1.0, 2.0, mask.sum())
    dist = np.full((s, n), np.inf, np.float32)
    dist[:, :128] = rng.uniform(0.0, 0.5, (s, 128))    # settled out-tile
    f = np.zeros((s, n), np.int8)
    f[:, :64] = (rng.random((s, 64)) < 0.2)            # half the k-tiles dead
    fdist = np.where(f != 0, dist, np.inf).astype(np.float32)
    w_min = np.float32(w[np.isfinite(w)].min())
    new_k, dist_k = fused_minplus_sweep(
        jnp.asarray(fdist), jnp.asarray(w), jnp.asarray(dist), w_min,
        bs=64, bn=128, bk=128, interpret=True)
    new_r, dist_r = minplus_sweep_ref(jnp.asarray(fdist), jnp.asarray(w),
                                      jnp.asarray(dist))
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


@pytest.mark.parametrize("s,n_pad,eb", [(8, 128, 128), (16, 256, 128),
                                        (32, 256, 256)])
def test_sparse_relax_shapes(s, n_pad, eb):
    rng = np.random.default_rng(s + n_pad)
    n = n_pad - 1                                     # room for the sentinel
    m = 4 * n
    m_pad = ((m + eb - 1) // eb) * eb
    src = np.full(m_pad, n, np.int32)
    dst = np.full(m_pad, n, np.int32)
    w = np.full(m_pad, np.inf, np.float32)
    src[:m] = rng.integers(0, n, m)
    dst[:m] = rng.integers(0, n, m)
    w[:m] = rng.uniform(0.5, 4.0, m)
    f = (rng.random((s, n_pad)) < 0.1).astype(np.int8)
    dist = np.where(rng.random((s, n_pad)) < 0.4,
                    rng.uniform(0.0, 8.0, (s, n_pad)),
                    np.inf).astype(np.float32)
    args = (jnp.asarray(f), jnp.asarray(dist), jnp.asarray(src),
            jnp.asarray(dst), jnp.asarray(w))
    new_k, dist_k = sparse_relax_sweep(*args, eb=eb, interpret=True)
    new_r, dist_r = sparse_relax_ref(*args)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


# --------------------------------------------------------------------------
# counting semiring kernel (Brandes stage 1 — path counting)
# --------------------------------------------------------------------------

def _random_counting_state(rng, s, n, *, density=0.05, visited=0.3):
    adj = (rng.random((n, n)) < 0.03).astype(np.int8)
    dist = np.where(rng.random((s, n)) < visited, 2, -1).astype(np.int32)
    sigma = np.where(dist >= 0, rng.integers(1, 9, (s, n)), 0
                     ).astype(np.float32)
    f = ((rng.random((s, n)) < density) & (dist >= 0)).astype(np.int8)
    fsigma = np.where(f != 0, sigma, 0.0).astype(np.float32)
    return (jnp.asarray(fsigma), jnp.asarray(adj), jnp.asarray(dist),
            jnp.asarray(sigma))


@pytest.mark.parametrize("s,n,bs,bn,bk", [
    (64, 256, 64, 128, 128),
    (8, 128, 8, 128, 128),
    (16, 384, 16, 128, 128),
])
def test_counting_sweep_shapes(s, n, bs, bn, bk):
    rng = np.random.default_rng(s * n + 3)
    fsigma, adj, dist, sigma = _random_counting_state(rng, s, n)
    k_out = fused_counting_sweep(fsigma, adj, dist, sigma, 5, bs=bs, bn=bn,
                                 bk=bk, interpret=True)
    r_out = counting_sweep_ref(fsigma, adj, dist, sigma, 5)
    for got, ref in zip(k_out, r_out):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def _counting_sweep_vs_ref(seed, density, visited):
    rng = np.random.default_rng(seed)
    fsigma, adj, dist, sigma = _random_counting_state(
        rng, 64, 256, density=density, visited=visited)
    k_out = fused_counting_sweep(fsigma, adj, dist, sigma, 7, bs=64,
                                 bn=128, bk=128, interpret=True)
    r_out = counting_sweep_ref(fsigma, adj, dist, sigma, 7)
    for got, ref in zip(k_out, r_out):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("seed", range(6))
def test_counting_sweep_randomized(seed):
    rng = np.random.default_rng(seed * 6199 + 29)
    _counting_sweep_vs_ref(int(rng.integers(0, 10_000)),
                           float(rng.uniform(0.0, 0.3)),
                           float(rng.uniform(0.0, 1.0)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), density=st.floats(0.0, 0.3),
           visited=st.floats(0.0, 1.0))
    def test_counting_sweep_property(seed, density, visited):
        _counting_sweep_vs_ref(seed, density, visited)


def test_counting_rectangular_partials_sum_to_square():
    """K-row block partials combine with the masked-add ⊕ (sum of gated
    candidates) to the square sweep — the sharded executor's reduction.
    Path counts are integers in f32, so the sum is exact."""
    rng = np.random.default_rng(19)
    s, n, k = 8, 256, 128
    fsigma, adj, dist, sigma = _random_counting_state(rng, s, n)
    new_sq, dist_sq, sig_sq = fused_counting_sweep(
        fsigma, adj, dist, sigma, 5, bs=8, bn=128, bk=128, interpret=True)
    cand = np.zeros((s, n), np.float32)
    for k0 in range(0, n, k):
        new_p, _, nsg_p = fused_counting_sweep(
            fsigma[:, k0: k0 + k], adj[k0: k0 + k], dist, sigma, 5,
            bs=8, bn=128, bk=128, interpret=True)
        cand += np.where(np.asarray(new_p) != 0, np.asarray(nsg_p), 0.0)
    new = (cand > 0) & (np.asarray(dist) < 0)
    np.testing.assert_array_equal(new.astype(np.int8), np.asarray(new_sq))
    np.testing.assert_array_equal(
        np.where(new, 5, np.asarray(dist)), np.asarray(dist_sq))
    np.testing.assert_array_equal(
        np.where(new, cand, np.asarray(sigma)), np.asarray(sig_sq))


def test_counting_tile_skip_preserves_semantics():
    """Dead frontier k-tiles and all-visited output tiles must not
    change either half of the (dist, sigma) state — the boolean o_occ
    is sound for the counting semiring (sigma only moves with dist)."""
    rng = np.random.default_rng(23)
    s, n = 64, 256
    adj = (rng.random((n, n)) < 0.05).astype(np.int8)
    dist = np.full((s, n), -1, np.int32)
    dist[:, 128:] = 3                            # half the out-tiles visited
    sigma = np.where(dist >= 0, 2.0, 0.0).astype(np.float32)
    f = np.zeros((s, n), np.int8)
    f[:, 128: 192] = (rng.random((s, 64)) < 0.2)  # half the k-tiles empty
    fsigma = np.where(f != 0, sigma, 0.0).astype(np.float32)
    args = (jnp.asarray(fsigma), jnp.asarray(adj), jnp.asarray(dist),
            jnp.asarray(sigma))
    k_out = fused_counting_sweep(*args, 4, bs=64, bn=128, bk=128,
                                 interpret=True)
    r_out = counting_sweep_ref(*args, 4)
    for got, ref in zip(k_out, r_out):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# --------------------------------------------------------------------------
# cross-semiring kernel equivalence (acceptance)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["dense", "sparse", "auto"])
def test_weighted_kernel_path_matches_dijkstra(mode, random_weighted):
    """weighted_apsp dispatching the tropical Pallas kernels under
    interpret=True == scipy Dijkstra (the PR's acceptance criterion)."""
    g, w = random_weighted(100, 3.0, 41)
    sources = np.arange(12, dtype=np.int32)
    ref = dijkstra_dists(g, w, sources)
    res = weighted_apsp(g, w, sources,
                        config=WeightedConfig(mode=mode, source_batch=16,
                                              use_kernel=True))
    np.testing.assert_allclose(np.asarray(res.dist), ref, rtol=1e-5)
    assert int(res.direction_counts.sum()) == int(res.sweeps) > 0


def test_weighted_kernel_matches_reference_forms(random_weighted):
    """Kernel forms and XLA reference forms are the same sweeps: identical
    distances AND identical sweep counts on the same graph."""
    g, w = random_weighted(90, 4.0, 43)
    sources = np.arange(8, dtype=np.int32)
    for mode in ("dense", "sparse"):
        kern = weighted_apsp(g, w, sources,
                             config=WeightedConfig(mode=mode, source_batch=8,
                                                   use_kernel=True))
        ref = weighted_apsp(g, w, sources,
                            config=WeightedConfig(mode=mode, source_batch=8,
                                                  use_kernel=False))
        np.testing.assert_array_equal(np.asarray(kern.dist),
                                      np.asarray(ref.dist))
        assert int(kern.sweeps) == int(ref.sweeps)


def test_unit_weight_tropical_kernel_equals_boolean_kernel():
    """(min,+) with unit weights through the tropical kernel == boolean
    BFS through the boolean kernel — the cross-semiring contract at the
    kernel layer."""
    g = gen.rmat(8, 5, directed=False, seed=51)
    n_pad = g.n_padded(128)
    w = jnp.ones((g.m_pad,), jnp.float32)
    sources = np.arange(16, dtype=np.int32)
    trop = weighted_apsp(g, np.asarray(w), sources,
                         config=WeightedConfig(mode="dense", source_batch=16,
                                               use_kernel=True))
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n_pad)), jnp.int8)
    boolean = msbfs_kernel(adj, jnp.asarray(sources), max_steps=n_pad,
                           interpret=True, bs=16, bn=128, bk=128)
    bdist = np.asarray(boolean.dist)[:, :g.n_nodes].astype(np.float64)
    bdist = np.where(bdist < 0, np.inf, bdist)
    np.testing.assert_allclose(np.asarray(trop.dist), bdist)


# --------------------------------------------------------------------------
# interpret-only policy: the registry seam must keep the tropical sparse
# kernel off compiled (real-TPU) backends
# --------------------------------------------------------------------------

def test_tropical_sparse_is_marked_interpret_only():
    ks = registry.get("tropical")
    assert "sparse" in ks.interpret_only
    assert ks.dispatchable("sparse", interpret=True)
    assert not ks.dispatchable("sparse", interpret=False)
    assert ks.dispatchable("dense", interpret=False)
    assert registry.get("boolean").dispatchable("push", interpret=False)


def test_sparse_relax_sweep_refuses_compiled_dispatch():
    """The kernel wrapper itself hard-errors on interpret=False — the
    contract is not just a registry convention."""
    f = jnp.zeros((8, 128), jnp.int8)
    d = jnp.full((8, 128), jnp.inf, jnp.float32)
    idx = jnp.full((128,), 127, jnp.int32)
    w = jnp.full((128,), jnp.inf, jnp.float32)
    with pytest.raises(RuntimeError, match="interpret-only"):
        sparse_relax_sweep(f, d, idx, idx, w, eb=128, interpret=False)


def test_compiled_tropical_dispatch_falls_back_to_xla_sparse():
    """sweep.tropical_forms(use_kernel=True, interpret=False) must route
    the sparse form to XLA: poison the registry's sparse kernel and check
    the returned closure never calls it yet still relaxes correctly."""
    import repro.core.sweep as S
    ks = registry.get("tropical")

    def boom(*a, **k):
        raise AssertionError("sparse kernel dispatched on compiled path")

    registry.register(registry.KernelSet(
        semiring="tropical", forms={**ks.forms, "sparse": boom},
        vmem_bytes=ks.vmem_bytes, notes=ks.notes,
        interpret_only=ks.interpret_only))
    try:
        g = gen.erdos_renyi(100, 3.0, seed=7)
        rng = np.random.default_rng(0)
        w = jnp.asarray(np.where(np.arange(g.m_pad) < g.n_edges,
                                 rng.uniform(0.5, 4.0, g.m_pad),
                                 np.inf).astype(np.float32))
        _, sparse = S.tropical_forms(None, g.src, g.dst, w,
                                     use_kernel=True, interpret=False)
        n_pad = g.n_padded(128)
        f = jnp.zeros((4, n_pad), jnp.int8).at[:, 0].set(1)
        d = jnp.full((4, n_pad), jnp.inf).at[:, 0].set(0.0)
        new, nd, _ = sparse(f, d, jnp.zeros((1,), jnp.int32), jnp.int32(1))
        _, ref_sparse = S.tropical_forms(None, g.src, g.dst, w,
                                         use_kernel=False)
        new_r, nd_r, _ = ref_sparse(f, d, jnp.zeros((1,), jnp.int32),
                                    jnp.int32(1))
        np.testing.assert_array_equal(np.asarray(new), np.asarray(new_r))
        np.testing.assert_array_equal(np.asarray(nd), np.asarray(nd_r))
    finally:
        registry.register(ks)    # restore the real kernel set


# --------------------------------------------------------------------------
# rectangular (K-row block) kernel dispatch — the sharded executor's
# vertex-sharded partial sweeps
# --------------------------------------------------------------------------

@pytest.mark.parametrize("s,k,n", [(64, 128, 256), (8, 128, 384)])
def test_fused_sweep_rectangular_matches_square_slice(s, k, n):
    """fused_sweep on a (k, n) K-row block == the k-rows' contribution:
    OR of the C block partials must equal the square sweep."""
    rng = np.random.default_rng(s + k + n)
    adj = jnp.asarray((rng.random((n, n)) < 0.04).astype(np.int8))
    f, dist = _random_state(rng, s, n)
    new_sq, dist_sq = fused_sweep(f, adj, dist, 5, bs=min(s, 64), bn=128,
                                  bk=128, interpret=True)
    parts = []
    for k0 in range(0, n, k):
        new_p, _ = fused_sweep(f[:, k0: k0 + k], adj[k0: k0 + k], dist, 5,
                               bs=min(s, 64), bn=128, bk=128,
                               interpret=True)
        parts.append(np.asarray(new_p))
    new_or = np.maximum.reduce(parts)
    np.testing.assert_array_equal(new_or, np.asarray(new_sq))
    dist_comb = np.where(new_or != 0, 5, np.asarray(dist))
    np.testing.assert_array_equal(dist_comb, np.asarray(dist_sq))


def test_minplus_rectangular_matches_square_slice():
    """fused_minplus_sweep K-row partials min-combine to the square
    result (⊕ = min is exact in f32)."""
    rng = np.random.default_rng(11)
    s, n, k = 8, 256, 128
    _, fdist, w, dist, w_min = _random_tropical_state(rng, s, n)
    _, dist_sq = fused_minplus_sweep(fdist, w, dist, w_min, bs=8, bn=128,
                                     bk=128, interpret=True)
    parts = []
    for k0 in range(0, n, k):
        _, nd_p = fused_minplus_sweep(fdist[:, k0: k0 + k],
                                      w[k0: k0 + k], dist, w_min, bs=8,
                                      bn=128, bk=128, interpret=True)
        parts.append(np.asarray(nd_p))
    np.testing.assert_array_equal(np.minimum.reduce(parts),
                                  np.asarray(dist_sq))
