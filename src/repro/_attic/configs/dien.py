"""dien — sequential-behaviour CTR model with AUGRU interest evolution.
[arXiv:1809.03672; unverified]  embed=18 seq=100 gru=108 mlp=200-80."""
from ..models.recsys import DIENConfig

CONFIG = DIENConfig(
    name="dien", embed_dim=18, seq_len=100, gru_dim=108, mlp=(200, 80),
    n_items=8_000_000, n_cats=100_000, n_profile=1_000_000)
