"""Quickstart: DAWN shortest paths through the ``dawn`` facade.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro as dawn
from repro.core import bfs_scipy
from repro.graph import generators as gen

# 1. build a graph (or CSRGraph.from_edges / repro.graph.io.load_edgelist)
g = gen.watts_strogatz(5000, 8, 0.05, seed=0)
print(f"graph: {g.n_nodes} nodes, {g.n_edges} edges")

# 2. wrap it in a handle — one verb for every semiring and topology
h = dawn.prepare(g)

# 3. single-source shortest paths (auto-dispatches BOVM/SOVM)
dist = h.sssp(0)
print(f"SSSP from 0: eccentricity={int(dist.max())}, "
      f"reachable={int((dist >= 0).sum())}")

# 4. verify against scipy's C BFS
assert (dist == bfs_scipy(g, 0)).all()
print("matches scipy.sparse.csgraph ✓")

# 5. batched multi-source (the MXU-friendly formulation)
batch = h.apsp(np.arange(64))
print(f"64-source batch: dist matrix {batch.dist.shape}, "
      f"{int(batch.sweeps)} sweeps, "
      f"edges touched={int(batch.edges_touched)}")

# 6. the same call works on a mutable graph — mutate, query, repeat
dg = dawn.DynamicCSRGraph(g)
hd = dawn.prepare(dg)
base = hd.sssp(0)
far = int(np.argmax(base))                     # most distant node
hd.insert_edges([0], [far])                    # add a shortcut edge
after = hd.sssp(0)                             # fresh epoch, same call
print(f"dynamic: dist[{far}] {int(base[far])} → {int(after[far])} "
      f"after inserting shortcut (epoch {hd.epoch})")
