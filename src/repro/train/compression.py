"""Gradient compression for the cross-pod (DCI) all-reduce.

Within a pod the ICI is fast; across pods the data-center interconnect is
the bottleneck for pure-DP gradient sync.  Two classic compressors, both
with error feedback (the residual is re-added next step so compression is
unbiased over time):

  * int8 quantization (per-tensor scale)          — 4× fewer bytes than f32
  * top-k sparsification (magnitude, per-tensor)  — k/n of the bytes

Usage: wrap the cross-pod psum — compress locally, reduce, decompress —
or (single-program form, used here) compress grads before the optimizer
applies them, carrying the error-feedback state in the train state.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_int8(grads, ef):
    """Returns (compressed_grads, new_error_feedback).  Compressed grads are
    the dequantized int8 values (what the wire would carry)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), g32 - deq
    pairs = jax.tree.map(one, grads, ef)
    leaves, treedef = jax.tree_util.tree_flatten(
        pairs, is_leaf=lambda x: isinstance(x, tuple))
    return (treedef.unflatten([l[0] for l in leaves]),
            treedef.unflatten([l[1] for l in leaves]))


def topk_mask(x: jax.Array, frac: float) -> jax.Array:
    k = max(1, int(x.size * frac))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def compress_topk(grads, ef, frac: float = 0.01):
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        m = topk_mask(g32, frac)
        sparse = g32 * m
        return sparse.astype(g.dtype), g32 - sparse
    pairs = jax.tree.map(one, grads, ef)
    leaves, treedef = jax.tree_util.tree_flatten(
        pairs, is_leaf=lambda x: isinstance(x, tuple))
    return (treedef.unflatten([l[0] for l in leaves]),
            treedef.unflatten([l[1] for l in leaves]))


def compressed_bytes(grads, method: str = "int8",
                     frac: float = 0.01) -> Tuple[int, int]:
    """(raw_bytes_f32, wire_bytes) for the §Perf collective accounting."""
    raw = sum(x.size * 4 for x in jax.tree_util.tree_leaves(grads))
    if method == "int8":
        wire = sum(x.size for x in jax.tree_util.tree_leaves(grads))
    elif method == "topk":
        # values (f32) + indices (int32) for k entries
        wire = sum(int(x.size * frac) * 8
                   for x in jax.tree_util.tree_leaves(grads))
    else:
        wire = raw
    return raw, wire


def make_cross_pod_psum(method: str = "int8", frac: float = 0.01):
    """shard_map-compatible compressed psum over the 'pod' axis: quantize →
    psum(int32 accum) → dequantize.  Exact for int8 (sum of ≤ n_pods
    int8 values fits int32)."""
    def psum_compressed(g):
        if method == "none":
            return jax.lax.psum(g, "pod")
        g32 = g.astype(jnp.float32)
        # agree on ONE scale across the pod axis BEFORE quantizing —
        # mixing per-pod scales under a single dequant is lossy
        scale = jax.lax.pmax(jnp.max(jnp.abs(g32)), "pod") / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        qsum = jax.lax.psum(q.astype(jnp.int32), "pod")
        return (qsum.astype(jnp.float32) * scale).astype(g.dtype)
    return psum_compressed
