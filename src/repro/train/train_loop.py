"""Generic training-step factory: grad accumulation + remat + pjit wiring.

``make_train_step`` turns any ``loss_fn(params, batch) -> scalar`` into a
jitted (params, opt_state, batch) -> (params, opt_state, metrics) step with:

  * microbatch gradient accumulation via ``lax.scan`` (static ``accum``) —
    live activation memory scales with the microbatch, not the global batch;
  * f32 gradient accumulation regardless of param dtype;
  * sharding-constrained outputs (params keep their specs across the update).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .optimizer import Optimizer


def _split_batch(batch: Dict[str, jax.Array], accum: int):
    """(B, ...) -> (accum, B/accum, ...) for every leaf."""
    def split(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape((accum, b // accum) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(loss_fn: Callable, optimizer: Optimizer, *,
                    accum: int = 1, accum_dtype=jnp.float32,
                    donate: bool = True) -> Callable:
    """Build the train step.  ``loss_fn(params, microbatch) -> scalar``.

    ``accum_dtype`` controls the gradient-accumulation carry.  f32 is the
    default; for models whose f32 grads alone exceed per-chip HBM (e.g.
    671B-param MoE at 256 chips: 10.5 GB/chip, double-buffered by the scan)
    pass bf16 — measured 42 GB → fits on deepseek-v3 train_4k (§Perf)."""

    def step(params, opt_state, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = _split_batch(batch, accum)

            def body(acc, mb):
                loss_acc, g_acc = acc
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g)
                return (loss_acc + l, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), g0), mbs)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        params, opt_state, stats = optimizer.update(params, grads, opt_state)
        metrics = {"loss": loss, **stats}
        return params, opt_state, metrics

    return step


def make_jitted_step(loss_fn, optimizer, mesh, param_specs, *,
                     batch_specs, accum: int = 1):
    """pjit-wrapped train step with explicit shardings for the dry-run and
    the real launcher."""
    from jax.sharding import NamedSharding

    step = make_train_step(loss_fn, optimizer, accum=accum)
    state_specs = optimizer.state_specs(param_specs)

    def shard(tree_specs):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree_specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    in_shardings = (shard(param_specs), shard(state_specs),
                    shard(batch_specs))
    out_shardings = (shard(param_specs), shard(state_specs), None)
    return jax.jit(step, in_shardings=in_shardings,
                   out_shardings=out_shardings,
                   donate_argnums=(0, 1)), state_specs


def make_eval_step(loss_fn) -> Callable:
    @jax.jit
    def step(params, batch):
        return loss_fn(params, batch)
    return step


def train(params, opt_state, step_fn, data_iter, *, n_steps: int,
          hooks: Optional[list] = None, start_step: int = 0):
    """Host-side loop with hook points (checkpoint / fault-tolerance /
    metrics).  Hooks: fn(step, params, opt_state, metrics) -> None."""
    hooks = hooks or []
    metrics = {}
    for i in range(start_step, n_steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        for h in hooks:
            h(i, params, opt_state, metrics)
    return params, opt_state, metrics
