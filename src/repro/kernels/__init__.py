"""Pallas TPU kernels for the paper's compute hot spots (validated with
interpret=True on CPU).

One tiling substrate (``common``), N semirings: each subpackage
registers its fused sweep kernels in ``registry`` keyed by semiring
name; the core sweep layer dispatches through the registry.
"""
from . import common, registry
from . import bovm       # registers "boolean"
from . import tropical   # registers "tropical"
from . import counting   # registers "counting"
