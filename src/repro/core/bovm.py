"""BOVM — Boolean Vector/Matrix Operation (paper Alg. 1), TPU form.

The paper walks CSC columns with a per-element early exit.  The TPU-native
equivalent is a {0,1}-valued matmul: a sweep computes

    counts = F @ A        (S sources batched; MXU-friendly)
    hits   = counts > 0
    new    = hits & ~visited          # Theorem 3.2 skip
    dist   = where(new, step, dist)   # first hit IS the shortest path

and the per-element early exit becomes tile-level skipping inside the
Pallas kernel (kernels/bovm).  Values are exact: counts ≤ n < 2^24 so f32
accumulation is lossless; int8 inputs with int32 accumulation are also
supported.

This module is a thin boolean-semiring instantiation of the shared sweep
layer: ``bovm_msbfs`` pins the dense PUSH form of
:func:`repro.core.sweep.boolean_forms` into :func:`repro.core.sweep.sweep_loop`
(Fact-1 convergence, Eq. 5 work counter and all).  The batched,
direction-optimizing production path is core/engine.py.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import sweep as S
from .frontier import UNREACHED, one_hot_frontier


class DawnState(NamedTuple):
    frontier: jax.Array   # (S, n) int8 — discovered in the previous sweep
    dist: jax.Array       # (S, n) int32, UNREACHED = -1
    step: jax.Array       # scalar int32, current path length
    done: jax.Array       # scalar bool — Fact 1 fired
    edges_touched: jax.Array  # scalar float — work counter (Eq. 5)


def bovm_sweep(adj: jax.Array, frontier: jax.Array, visited: jax.Array,
               *, accum_dtype=jnp.float32,
               matmul_fn=None) -> jax.Array:
    """One boolean sweep: new = (frontier @ adj > 0) & ~visited.

    adj      : (n, n) int8/bool dense adjacency (row = src, col = dst)
    frontier : (S, n) bool
    visited  : (S, n) bool
    matmul_fn: optional kernel override (e.g. the Pallas tile-skip kernel),
               signature (F_int, A_int) -> counts.
    """
    if matmul_fn is None:
        f = frontier.astype(accum_dtype)
        a = adj.astype(accum_dtype)
        counts = jax.lax.dot_general(
            f, a, (((1,), (0,)), ((), ())),
            preferred_element_type=accum_dtype)
    else:
        counts = matmul_fn(frontier, adj)
    hits = counts > 0
    return hits & ~visited


@partial(jax.jit, static_argnames=("max_steps", "accum_dtype"))
def bovm_msbfs(adj: jax.Array, sources: jax.Array, *,
               max_steps: Optional[int] = None,
               accum_dtype=jnp.float32) -> DawnState:
    """Multi-source DAWN over a dense adjacency.

    adj     : (n, n) int8 dense adjacency
    sources : (S,) int32
    returns : DawnState with dist (S, n); dist[s, sources[s]] = 0.
    """
    n = adj.shape[0]
    s = sources.shape[0]
    max_steps = n if max_steps is None else max_steps

    f0 = one_hot_frontier(sources, n, dtype=jnp.int8)
    dist0 = jnp.where(f0 != 0, 0, jnp.full((s, n), UNREACHED))
    deg = jnp.sum(adj.astype(jnp.float32), axis=1)  # out-degrees

    # dense boolean PUSH only: the pull/sparse slots get dummies that the
    # pinned forced_dir never traces
    push, _, _ = S.boolean_forms(
        adj, jnp.zeros((1, 1), jnp.uint32), jnp.zeros(1, jnp.int32),
        jnp.zeros(1, jnp.int32), n_pad=n, s=s, use_kernel=False,
        accum_dtype=accum_dtype)

    st = S.sweep_loop((push,), S.make_state(f0, dist0, n_forms=1),
                      max_steps=max_steps, deg=deg)
    return DawnState(frontier=st.frontier, dist=st.dist, step=st.step,
                     done=st.done, edges_touched=st.edges_touched)


def bovm_sssp(adj: jax.Array, source, **kw) -> DawnState:
    """Single-source convenience wrapper (S = 1)."""
    src = jnp.atleast_1d(jnp.asarray(source, jnp.int32))
    st = bovm_msbfs(adj, src, **kw)
    return DawnState(frontier=st.frontier[0], dist=st.dist[0],
                     step=st.step, done=st.done,
                     edges_touched=st.edges_touched)
