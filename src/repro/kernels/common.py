"""Shared tiling / occupancy / grid machinery for every semiring kernel.

One substrate, N semirings: the boolean push/pull kernels
(``kernels/bovm``), the tropical min-plus kernels
(``kernels/tropical``) and the counting-semiring kernel
(``kernels/counting`` — two state arrays through the same grid) are
instantiations of the same skeleton —

  * a ``(S/bs, n/bn, n/bk)`` grid with K innermost ("arbitrary") so each
    output tile accumulates operand-block products in a VMEM scratch and
    fuses the DAWN epilogue on the last K step;
  * scalar-prefetched occupancy tables (``f_occ`` input sparsity,
    ``o_occ`` output sparsity — Thm 3.2 at tile rank) that gate each grid
    step before any VMEM compute;
  * MXU-aligned tile sizes validated against the per-core VMEM budget.

This module owns the pieces the semirings share: the jax-version
compiler-params shim, interpret-mode backend detection, the blockwise
``any`` reduction behind both occupancy tables, the push/pull grid-spec
builders, and the VMEM budget math quoted in docs/ARCHITECTURE.md.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names the TPU compiler-params struct TPUCompilerParams
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

MXU_ALIGN = 128                      # matmul dims must be multiples of this
# default per-core budget when no TuningPlan overrides it (the historical
# hard-coded table value; core/autotune.py BackendProfile carries the
# per-device figure and threads it through vmem_limit())
VMEM_BUDGET_BYTES = 16 * 2 ** 20

# tile-edge candidates the autotuner searches, largest first (all
# MXU-aligned; 128 is always a candidate so every padded n divides one)
TILE_CANDIDATES = (512, 256, 128)


def vmem_limit(budget: int | None = None) -> int:
    """The per-core VMEM byte budget tile plans must fit: ``budget``
    when a BackendProfile/TuningPlan supplies one, else the static
    default."""
    return VMEM_BUDGET_BYTES if budget is None else int(budget)


def tile_candidates(n_pad: int) -> tuple[int, ...]:
    """MXU-aligned tile edges that divide ``n_pad``, largest first."""
    cands = tuple(c for c in TILE_CANDIDATES
                  if c <= n_pad and n_pad % c == 0)
    return cands or (MXU_ALIGN,)


def default_interpret() -> bool:
    """Pallas kernels execute op-by-op (interpret mode) off-TPU."""
    return jax.default_backend() != "tpu"


def sweep_compiler_params():
    """The shared grid semantics: (i, j) output tiles are parallel, the
    K reduction axis is sequential (scratch accumulator carries state)."""
    return CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"))


def fused_compiler_params():
    """Fused multi-sweep grids iterate source tiles only; each tile runs
    its whole sweep block to convergence, so the single axis is
    "arbitrary" (tiles are independent but internally stateful)."""
    return CompilerParams(dimension_semantics=("arbitrary",))


# --------------------------------------------------------------------------
# occupancy tables (the Thm 3.2 tile-skip signals, semiring-generic)
# --------------------------------------------------------------------------

def block_any(mask: jax.Array, gi: int, bi: int, gj: int, bj: int
              ) -> jax.Array:
    """(gi*bi, gj*bj) bool -> (gi, gj) bool: does block (i, j) contain any
    True?  This one reduction is both occupancy tables:

      f_occ = block_any(frontier-active mask, gi, bs, gk, bk)
      o_occ = block_any(semiring's improvable mask, gi, bs, gj, bn)

    where "improvable" is ``dist == UNREACHED`` for the boolean semiring
    (settled distances never change) and the settled-bound test
    ``dist > min_frontier_dist + w_min`` for the tropical semiring (see
    kernels/tropical/kernel.py for the soundness argument).
    """
    return jnp.any(mask.reshape(gi, bi, gj, bj), axis=(1, 3))


def check_push_tiles(s: int, n: int, bs: int, bn: int, bk: int,
                     k: int | None = None) -> None:
    """Tile divisibility contract shared by the push-style kernels.
    ``k`` is the contraction dim — it equals ``n`` for the square
    single-device operands and ``n/C`` for a sharded K-row block."""
    k = n if k is None else k
    assert s % bs == 0 and n % bn == 0 and k % bk == 0, (s, n, k, bs, bn, bk)


# --------------------------------------------------------------------------
# grid specs (one (i, j, k) skeleton, two operand layouts)
# --------------------------------------------------------------------------

def push_grid_spec(gi: int, gj: int, gk: int, *, bs: int, bn: int, bk: int,
                   num_scalar_prefetch: int, acc_dtype,
                   n_state: int = 1) -> "pltpu.PrefetchScalarGridSpec":
    """Grid spec for push-direction sweeps (boolean GEMM, tropical
    min-plus "GEMM", counting f32 GEMM): frontier-state block (i, k),
    operand block (k, j), ``n_state`` per-(i, j) state tiles in and
    ``n_state + 1`` tiles out (the improved-mask plus each updated state
    array), one (bs, bn) scratch accumulator.  The boolean/tropical
    kernels carry one state array (dist); the counting kernel carries two
    (dist + sigma, ``n_state=2``)."""
    state_spec = pl.BlockSpec((bs, bn), lambda i, j, k, *_: (i, j))
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar_prefetch,
        grid=(gi, gj, gk),
        in_specs=[
            pl.BlockSpec((bs, bk), lambda i, j, k, *_: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k, *_: (k, j)),
        ] + [state_spec] * n_state,
        out_specs=[state_spec] * (n_state + 1),
        scratch_shapes=[pltpu.VMEM((bs, bn), acc_dtype)],
    )


def pull_grid_spec(gi: int, gj: int, gk: int, *, bs: int, bn: int, wk: int,
                   num_scalar_prefetch: int, acc_dtype) -> "pltpu.PrefetchScalarGridSpec":
    """Grid spec for pull-direction sweeps (bit-packed boolean): packed
    frontier block (i, k), packed in-neighbour block (j, k)."""
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar_prefetch,
        grid=(gi, gj, gk),
        in_specs=[
            pl.BlockSpec((bs, wk), lambda i, j, k, *_: (i, k)),
            pl.BlockSpec((bn, wk), lambda i, j, k, *_: (j, k)),
            pl.BlockSpec((bs, bn), lambda i, j, k, *_: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bs, bn), lambda i, j, k, *_: (i, j)),
            pl.BlockSpec((bs, bn), lambda i, j, k, *_: (i, j)),
        ],
        scratch_shapes=[pltpu.VMEM((bs, bn), acc_dtype)],
    )


def fused_grid_spec(gi: int, *, bs: int, n: int, f_block, op_block,
                    num_scalar_prefetch: int = 1,
                    n_state: int = 1) -> "pltpu.PrefetchScalarGridSpec":
    """Grid spec for the fused multi-sweep (persistent) kernels: grid
    ``(gi,)`` over source tiles only — each grid step keeps its frontier
    block ``f_block`` at ``(i, 0)``, the *whole* operand ``op_block`` at
    ``(0, 0)``, and ``n_state`` per-row state tiles ``(bs, n)`` resident
    in VMEM while it runs up to ``max_sweeps`` sweeps internally (the
    Fact-1 check fires in-kernel).  Outputs: the last sweep's improved
    mask, the updated state arrays, and two ``(1, 1)`` per-tile scalars —
    the productive-sweep count and the converged flag — that the wrapper
    max/all-reduces into the loop driver's accounting."""
    state_spec = pl.BlockSpec((bs, n), lambda i, *_: (i, 0))
    flag_spec = pl.BlockSpec((1, 1), lambda i, *_: (i, 0))
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar_prefetch,
        grid=(gi,),
        in_specs=[
            pl.BlockSpec(f_block, lambda i, *_: (i, 0)),
            pl.BlockSpec(op_block, lambda i, *_: (0, 0)),
        ] + [state_spec] * n_state,
        out_specs=[state_spec] * (n_state + 1) + [flag_spec, flag_spec],
    )


# --------------------------------------------------------------------------
# VMEM budget math (the numbers in docs/ARCHITECTURE.md)
# --------------------------------------------------------------------------

def push_vmem_bytes(bs: int, bn: int, bk: int, *, f_itemsize: int,
                    a_itemsize: int, d_itemsize: int, acc_itemsize: int,
                    out_itemsizes: Sequence[int]) -> int:
    """Resident VMEM for one push-style grid step: frontier-state tile
    (bs, bk) + operand tile (bk, bn) + dist tile + scratch + outputs."""
    return (bs * bk * f_itemsize + bk * bn * a_itemsize
            + bs * bn * (d_itemsize + acc_itemsize + sum(out_itemsizes)))


def pull_vmem_bytes(bs: int, bn: int, wk: int, *, word_itemsize: int,
                    d_itemsize: int, acc_itemsize: int,
                    out_itemsizes: Sequence[int]) -> int:
    """Resident VMEM for one pull-style grid step."""
    return ((bs + bn) * wk * word_itemsize
            + bs * bn * (d_itemsize + acc_itemsize + sum(out_itemsizes)))


def fused_vmem_bytes(*, bs: int, n: int, operand_bytes: int,
                     frontier_bytes: int, state_itemsizes: Sequence[int],
                     out_itemsizes: Sequence[int]) -> int:
    """Resident VMEM for one fused multi-sweep grid step: the WHOLE
    operand plus the tile's frontier block, state arrays (in + carried)
    and outputs all live for the entire sweep block — the residency the
    fused path trades for its dispatch amortization (unlike the per-sweep
    grids, footprint scales with n² through ``operand_bytes``).  The two
    (1, 1) accounting scalars round up to 16 bytes."""
    return (operand_bytes + frontier_bytes
            + bs * n * (sum(state_itemsizes) + sum(out_itemsizes)) + 16)
