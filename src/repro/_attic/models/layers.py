"""Shared NN layers — pure-function JAX (no flax): params are nested dicts.

Covers everything the assigned LM architectures need:
  * RMSNorm / LayerNorm, RoPE
  * grouped-query attention (MQA/GQA, optional QKV bias) — train + KV-cache decode
  * MLA (DeepSeek multi-head latent attention) — compressed-latent KV cache
  * MLPs: SwiGLU, squared-ReLU (Nemotron), GELU
  * MoE: sort-based grouped dispatch (top-k, capacity factor, optional
    shared expert / dense residual) with EP sharding hooks
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]


# -- sharding hints -----------------------------------------------------------

def _ambient_dp_axes():
    """Data-parallel axis names of the ambient mesh (None outside one)."""
    try:
        m = jax.sharding.get_abstract_mesh()
        names = tuple(m.axis_names)
    except Exception:
        return None
    if "model" not in names:
        return None
    return tuple(a for a in names if a != "model")


def hint_activation(x: jax.Array) -> jax.Array:
    """Constrain (B, ..., d) activations to (dp, ..., 'model')."""
    dp = _ambient_dp_axes()
    if dp is None:
        return x
    spec = P(dp, *([None] * (x.ndim - 2)), "model")
    return jax.lax.with_sharding_constraint(x, spec)


def hint_replicated(x: jax.Array) -> jax.Array:
    """Constrain activations to (dp, None, ...) — replicated over model.

    This is the Megatron layer-boundary convention: column-parallel
    up-projections shard the INTERMEDIATE, row-parallel down-projections
    psum back to replicated.  Leaving the boundary activation d-sharded
    (as the embed shard_map emits it) makes every dot in the layer re-
    gather x: 11 × 268 MB all-gathers per layer-iteration on qwen2
    train_4k (§Perf iteration 2)."""
    dp = _ambient_dp_axes()
    if dp is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(dp, *([None] * (x.ndim - 1))))


def embed_lookup(table: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    """Sharded embedding lookup as an explicit shard_map.

    Table is (vocab, d) with d sharded over `model`, tokens sharded over
    the data axes: the gather is device-local (each chip reads its d-slice
    of its token rows) and the backward is a local scatter + psum over the
    data axes.  Leaving this to the SPMD partitioner instead materializes
    a full-vocab f32 table gradient per device (12.6 GB vs 0.8 GB on
    nemotron train_4k — EXPERIMENTS.md §Perf) or trips partitioner bugs
    under remat."""
    dp = _ambient_dp_axes()
    if dp is None:
        return table[tokens].astype(dtype)

    def local(tbl, tok):
        return tbl[tok]

    out = compat.shard_map(
        local,
        in_specs=(P(None, "model"), P(dp, *([None] * (tokens.ndim - 1)))),
        out_specs=P(dp, *([None] * (tokens.ndim - 1)), "model"),
    )(table, tokens)
    return out.astype(dtype)


# -- init helpers -----------------------------------------------------------

def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.bfloat16, scale: Optional[float] = None) -> Params:
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": _normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, dtype=jnp.bfloat16) -> Params:
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * p["g"].astype(jnp.float32)).astype(x.dtype)


# -- RoPE -------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float = 1e4) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, pos: jax.Array, inv_freq: jax.Array) -> jax.Array:
    """x (..., L, H, dh) with pos (..., L)."""
    ang = pos[..., :, None].astype(jnp.float32) * inv_freq  # (..., L, dh/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- grouped-query attention ------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    qkv_bias: bool = False
    rope_theta: float = 1e4


def attn_init(key, cfg: AttnConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "q": linear_init(ks[0], cfg.d_model, cfg.n_heads * cfg.d_head,
                         bias=cfg.qkv_bias, dtype=dtype),
        "k": linear_init(ks[1], cfg.d_model, cfg.n_kv * cfg.d_head,
                         bias=cfg.qkv_bias, dtype=dtype),
        "v": linear_init(ks[2], cfg.d_model, cfg.n_kv * cfg.d_head,
                         bias=cfg.qkv_bias, dtype=dtype),
        "o": linear_init(ks[3], cfg.n_heads * cfg.d_head, cfg.d_model,
                         dtype=dtype),
    }


def _gqa_scores(q, k, cfg: AttnConfig):
    """q (B,Lq,H,dh), k (B,Lk,Kv,dh) -> scores (B,Lq,Kv,G,Lk) in f32."""
    b, lq, h, dh = q.shape
    g = cfg.n_heads // cfg.n_kv
    qg = q.reshape(b, lq, cfg.n_kv, g, dh)
    return jnp.einsum("bqkgd,blkd->bqkgl", qg.astype(jnp.float32),
                      k.astype(jnp.float32)) / (dh ** 0.5)


def attn_forward(p: Params, x: jax.Array, cfg: AttnConfig,
                 pos: Optional[jax.Array] = None,
                 q_block: Optional[int] = None,
                 return_kv: bool = False):
    """Causal self-attention (training / prefill). x (B, L, d).

    ``q_block`` enables query-blocked attention (lax.scan over query
    chunks against the full K/V): live score memory drops from O(L²) to
    O(q_block · L) — required for the 32k prefill shapes."""
    b, l, _ = x.shape
    inv_freq = rope_freqs(cfg.d_head, cfg.rope_theta)
    if pos is None:
        pos = jnp.arange(l)[None, :]
    q = linear(p["q"], x).reshape(b, l, cfg.n_heads, cfg.d_head)
    k = linear(p["k"], x).reshape(b, l, cfg.n_kv, cfg.d_head)
    v = linear(p["v"], x).reshape(b, l, cfg.n_kv, cfg.d_head)
    q = apply_rope(q, pos, inv_freq)
    k = apply_rope(k, pos, inv_freq)

    # repeat KV to full heads ("repeat_kv"): with KV projections
    # replicated over the model axis and Q head-sharded, the whole
    # attention chain stays head-local — no per-layer activation
    # all-gathers (the bqkgd grouped form defeated SPMD head-sharding
    # propagation: measured 3 GB/layer of collectives on qwen2 train_4k,
    # EXPERIMENTS.md §Perf iteration 1).
    g_rep = cfg.n_heads // cfg.n_kv
    k_full = jnp.repeat(k, g_rep, axis=2)               # (B,L,H,dh)
    v_full = jnp.repeat(v, g_rep, axis=2)

    def attend(q_blk, pos_q):
        scores = jnp.einsum("bqhd,blhd->bhql",
                            q_blk.astype(jnp.float32),
                            k_full.astype(jnp.float32)) / (cfg.d_head ** 0.5)
        mask = pos_q[:, :, None] >= pos[:, None, :]     # (B, qb, Lk)
        scores = jnp.where(mask[:, None, :, :], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhql,blhd->bqhd", w, v_full.astype(jnp.float32))
        return out.reshape(b, q_blk.shape[1],
                           cfg.n_heads * cfg.d_head).astype(x.dtype)

    if q_block is None or l <= q_block:
        out = attend(q, pos)
        y = linear(p["o"], out)
        if return_kv:
            return y, (k, v)
        return y
    else:
        assert l % q_block == 0, (l, q_block)
        nb = l // q_block
        qs = q.reshape(b, nb, q_block, cfg.n_heads, cfg.d_head)
        ps = jnp.broadcast_to(pos, (b, l)).reshape(b, nb, q_block)

        def body(_, inp):
            qb, pb = inp
            return None, attend(qb, pb)

        # remat per q-block: backward recomputes scores/probs block-by-block
        # instead of saving O(L²) softmax intermediates (flash-style)
        _, outs = jax.lax.scan(
            jax.checkpoint(body, prevent_cse=False), None,
            (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(ps, 1, 0)))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, l, -1)
    y = linear(p["o"], out)
    if return_kv:
        return y, (k, v)
    return y


def _masked_cache_write(buf: jax.Array, new: jax.Array, pos: jax.Array,
                        active: jax.Array) -> jax.Array:
    """Write new (B, 1, ...) into buf (B, L, ...) at per-row pos where
    active; inactive rows keep their current contents."""
    b = buf.shape[0]
    rows = jnp.arange(b)
    old = buf[rows, pos]
    val = jnp.where(
        active.reshape((b,) + (1,) * (new.ndim - 2)),
        new[:, 0].astype(buf.dtype), old)
    return buf.at[rows, pos].set(val)


def attn_decode(p: Params, x: jax.Array, cache: Params, cfg: AttnConfig,
                active: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Params]:
    """One decode step. x (B, 1, d); cache {k,v: (B, Lmax, Kv, dh),
    pos: (B,) int32 per-row positions}.  ``active`` (B,) bool rows advance;
    inactive rows are frozen (continuous-batching support)."""
    b = x.shape[0]
    inv_freq = rope_freqs(cfg.d_head, cfg.rope_theta)
    cur = cache["pos"]                                  # (B,) int32
    if active is None:
        active = jnp.ones((b,), jnp.bool_)
    pos = cur[:, None]                                  # (B, 1)
    q = linear(p["q"], x).reshape(b, 1, cfg.n_heads, cfg.d_head)
    k = linear(p["k"], x).reshape(b, 1, cfg.n_kv, cfg.d_head)
    v = linear(p["v"], x).reshape(b, 1, cfg.n_kv, cfg.d_head)
    q = apply_rope(q, pos, inv_freq)
    k = apply_rope(k, pos, inv_freq)
    kc = _masked_cache_write(cache["k"], k, cur, active)
    vc = _masked_cache_write(cache["v"], v, cur, active)
    scores = _gqa_scores(q, kc, cfg)                    # (B,1,Kv,G,Lmax)
    lk = kc.shape[1]
    valid = jnp.arange(lk)[None, :] <= cur[:, None]     # (B, Lmax)
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqkgl,blkd->bqkgd", w, vc.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.n_heads * cfg.d_head).astype(x.dtype)
    return linear(p["o"], out), {"k": kc, "v": vc,
                                 "pos": cur + active.astype(jnp.int32)}


# -- MLA (DeepSeek-V3 multi-head latent attention) ---------------------------

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128
    rope_theta: float = 1e4


def mla_init(key, cfg: MLAConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 7)
    h = cfg.n_heads
    return {
        "q_a": linear_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype=dtype),
        "q_a_norm": norm_init(cfg.q_lora_rank, dtype),
        "q_b": linear_init(ks[1], cfg.q_lora_rank,
                           h * (cfg.d_nope + cfg.d_rope), dtype=dtype),
        "kv_a": linear_init(ks[2], cfg.d_model,
                            cfg.kv_lora_rank + cfg.d_rope, dtype=dtype),
        "kv_a_norm": norm_init(cfg.kv_lora_rank, dtype),
        "kv_b": linear_init(ks[3], cfg.kv_lora_rank,
                            h * (cfg.d_nope + cfg.d_v), dtype=dtype),
        "o": linear_init(ks[4], h * cfg.d_v, cfg.d_model, dtype=dtype),
    }


def _mla_qkv(p, x, cfg: MLAConfig, pos, inv_freq):
    b, l, _ = x.shape
    h = cfg.n_heads
    q = linear(p["q_b"], rmsnorm(p["q_a_norm"], linear(p["q_a"], x)))
    q = q.reshape(b, l, h, cfg.d_nope + cfg.d_rope)
    q_nope, q_rope = q[..., :cfg.d_nope], q[..., cfg.d_nope:]
    q_rope = apply_rope(q_rope, pos, inv_freq)
    kv = linear(p["kv_a"], x)                           # (B,L,rank+rope)
    latent = rmsnorm(p["kv_a_norm"], kv[..., :cfg.kv_lora_rank])
    k_rope = apply_rope(kv[..., None, cfg.kv_lora_rank:], pos, inv_freq)
    return q_nope, q_rope, latent, k_rope               # k_rope (B,L,1,dr)


def _mla_attend(p, q_nope, q_rope, latent, k_rope, cfg: MLAConfig, mask):
    b, lq = q_nope.shape[:2]
    h = cfg.n_heads
    kv = linear(p["kv_b"], latent).reshape(
        b, -1, h, cfg.d_nope + cfg.d_v)
    k_nope, v = kv[..., :cfg.d_nope], kv[..., cfg.d_nope:]
    scale = (cfg.d_nope + cfg.d_rope) ** -0.5
    s = (jnp.einsum("bqhd,blhd->bqhl", q_nope.astype(jnp.float32),
                    k_nope.astype(jnp.float32))
         + jnp.einsum("bqhd,bld->bqhl", q_rope.astype(jnp.float32),
                      k_rope[:, :, 0].astype(jnp.float32))) * scale
    s = jnp.where(mask[:, :, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhl,blhd->bqhd", w, v.astype(jnp.float32))
    return linear(p["o"], out.reshape(b, lq, h * cfg.d_v).astype(jnp.bfloat16))


def mla_forward(p: Params, x: jax.Array, cfg: MLAConfig,
                pos: Optional[jax.Array] = None,
                q_block: Optional[int] = None,
                return_kv: bool = False):
    b, l, _ = x.shape
    if pos is None:
        pos = jnp.arange(l)[None, :]
    inv_freq = rope_freqs(cfg.d_rope, cfg.rope_theta)
    qn, qr, latent, kr = _mla_qkv(p, x, cfg, pos, inv_freq)
    if q_block is None or l <= q_block:
        mask = pos[:, :, None] >= pos[:, None, :]
        y = _mla_attend(p, qn, qr, latent, kr, cfg, mask).astype(x.dtype)
    else:
        assert l % q_block == 0, (l, q_block)
        nb = l // q_block

        def body(_, inp):
            qn_b, qr_b, pos_b = inp
            mask = pos_b[:, :, None] >= pos[:, None, :]
            return None, _mla_attend(p, qn_b, qr_b, latent, kr, cfg, mask)

        body = jax.checkpoint(body, prevent_cse=False)
        split = lambda a: jnp.moveaxis(
            a.reshape((b, nb, q_block) + a.shape[2:]), 1, 0)
        pos_b = jnp.broadcast_to(pos, (b, l))
        _, outs = jax.lax.scan(body, None,
                               (split(qn), split(qr), split(pos_b)))
        y = jnp.moveaxis(outs, 0, 1).reshape(b, l, -1).astype(x.dtype)
    if return_kv:
        return y, (latent, kr)
    return y


def mla_decode(p: Params, x: jax.Array, cache: Params, cfg: MLAConfig,
               active: Optional[jax.Array] = None, *, absorb: bool = True
               ) -> Tuple[jax.Array, Params]:
    """Decode with compressed cache {latent: (B,Lmax,rank), k_rope:
    (B,Lmax,1,dr), pos: (B,)} — the MLA memory saving (rank+dr ≪ H·dh).

    ``absorb=True`` (default) applies DeepSeek's weight-absorption: W_kv_b
    is folded into the query/context sides so attention runs directly in
    the rank-512 latent space — O(B·H·L·rank) per token instead of
    reconstructing K/V: O(B·L·rank·H·(dn+dv)), a (dn+dv)/2 = 128× flop
    reduction at L=32k (EXPERIMENTS.md §Perf iteration 3)."""
    b = x.shape[0]
    cur = cache["pos"]                                  # (B,)
    if active is None:
        active = jnp.ones((b,), jnp.bool_)
    pos = cur[:, None]
    inv_freq = rope_freqs(cfg.d_rope, cfg.rope_theta)
    qn, qr, latent_t, kr_t = _mla_qkv(p, x, cfg, pos, inv_freq)
    lat = _masked_cache_write(cache["latent"], latent_t, cur, active)
    krc = _masked_cache_write(cache["k_rope"], kr_t, cur, active)
    lk = lat.shape[1]
    new_cache = {"latent": lat, "k_rope": krc,
                 "pos": cur + active.astype(jnp.int32)}
    if not absorb:
        mask = (jnp.arange(lk)[None, None, :] <= cur[:, None, None])
        out = _mla_attend(p, qn, qr, lat, krc, cfg, mask)
        return out.astype(x.dtype), new_cache

    h = cfg.n_heads
    wkv = p["kv_b"]["w"].reshape(cfg.kv_lora_rank, h, cfg.d_nope + cfg.d_v)
    wk = wkv[..., :cfg.d_nope].astype(jnp.float32)
    wv = wkv[..., cfg.d_nope:].astype(jnp.float32)
    lat32 = lat.astype(jnp.float32)
    scale = (cfg.d_nope + cfg.d_rope) ** -0.5
    q_lat = jnp.einsum("bqhd,rhd->bqhr", qn.astype(jnp.float32), wk)
    s = (jnp.einsum("bqhr,blr->bqhl", q_lat, lat32)
         + jnp.einsum("bqhd,bld->bqhl", qr.astype(jnp.float32),
                      krc[:, :, 0].astype(jnp.float32))) * scale
    mask = (jnp.arange(lk)[None, None, None, :] <= cur[:, None, None, None])
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bqhl,blr->bqhr", w, lat32)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, wv)
    out = linear(p["o"], out.reshape(b, 1, h * cfg.d_v).astype(x.dtype))
    return out.astype(x.dtype), new_cache


# -- MLPs --------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, act: str,
             dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    p = {"up": linear_init(ks[0], d_model, d_ff, dtype=dtype),
         "down": linear_init(ks[1], d_ff, d_model, dtype=dtype)}
    if act in ("swiglu",):
        p["gate"] = linear_init(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp_forward(p: Params, x: jax.Array, act: str) -> jax.Array:
    if act == "swiglu":
        h = jax.nn.silu(linear(p["gate"], x).astype(jnp.float32)) \
            * linear(p["up"], x).astype(jnp.float32)
    elif act == "relu2":  # Nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(linear(p["up"], x).astype(jnp.float32)))
    else:
        h = jax.nn.gelu(linear(p["up"], x).astype(jnp.float32))
    return linear(p["down"], h.astype(x.dtype))


# -- MoE ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    capacity_factor: float = 1.25
    n_groups: int = 1            # routing groups (== data-parallel shards)
    shared_expert_ff: int = 0    # DeepSeek shared expert (0 = none)
    dense_residual_ff: int = 0   # Arctic dense residual MLP (0 = none)
    act: str = "swiglu"


def moe_init(key, cfg: MoEConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": _normal(ks[0], (d, e), d ** -0.5, jnp.float32),
        "w_gate": _normal(ks[1], (e, d, f), d ** -0.5, dtype),
        "w_up": _normal(ks[2], (e, d, f), d ** -0.5, dtype),
        "w_down": _normal(ks[3], (e, f, d), f ** -0.5, dtype),
    }
    if cfg.shared_expert_ff:
        p["shared"] = mlp_init(ks[1], d, cfg.shared_expert_ff, cfg.act, dtype)
    if cfg.dense_residual_ff:
        p["residual"] = mlp_init(ks[2], d, cfg.dense_residual_ff, cfg.act, dtype)
    return p


def _capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor
            / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)


def moe_forward(p: Params, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """Sort-based grouped dispatch.  x (T, d) -> (T, d).

    Tokens are routed within ``n_groups`` groups (group dim sharded over the
    data axes → local sort; expert dim sharded over ``model`` → the
    reshard between token and expert layout is the EP all-to-all,
    inserted by GSPMD from the sharding constraint)."""
    t, d = x.shape
    g = cfg.n_groups
    assert t % g == 0, (t, g)
    tg = t // g
    cap = _capacity(tg, cfg)
    e, k = cfg.n_experts, cfg.top_k

    def route(xg):  # (Tg, d)
        logits = xg.astype(jnp.float32) @ p["router"]
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, k)              # (Tg, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        e_flat = idx.reshape(-1)                         # (Tg*k,)
        order = jnp.argsort(e_flat)
        e_sorted = e_flat[order]
        tok_sorted = order // k
        counts = jnp.bincount(e_flat, length=e)
        start = jnp.concatenate([jnp.zeros(1, counts.dtype),
                                 jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(tg * k) - start[e_sorted]
        valid = pos < cap
        slot = jnp.where(valid, e_sorted * cap + pos, e * cap)  # sentinel row
        # gate weight per sorted entry; zero for dropped (over-capacity)
        gate_sorted = jnp.where(
            valid, gate.reshape(-1)[order], 0).astype(x.dtype)
        buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].add(
            xg[tok_sorted])[:-1]
        return buf.reshape(e, cap, d), (tok_sorted, slot, gate_sorted)

    xg = x.reshape(g, tg, d)
    buf, aux = jax.vmap(route)(xg)                       # (G, E, C, d)
    dp = _ambient_dp_axes()
    if dp is not None:
        # EP reshard: groups over the data axes, experts over model.
        # Decode-sized token counts additionally shard d over data so the
        # expert contraction runs on local weight shards + a small psum —
        # otherwise GSPMD all-gathers 1.4 GB/layer of expert weights to
        # chase a handful of tokens (§Perf deepseek decode iteration 2).
        g_ax = dp if g > 1 else None
        d_ax = "data" if (t <= 4096 and g == 1) else None
        buf = jax.lax.with_sharding_constraint(
            buf, P(g_ax, "model", None, d_ax))

    h = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    if cfg.act == "swiglu":
        h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) \
            * jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    elif cfg.act == "relu2":
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"])  # (G,E,C,d)

    def combine(out_b, xg_i, aux_i):
        # direct weighted segment-add back to tokens: avoids materializing
        # the (Tg·k, d) unsort buffer + (Tg, k, d) reshape (4 full-token
        # copies → 1; measured on deepseek-v3 train_4k, §Perf)
        tok_sorted, slot, gate_sorted = aux_i
        flat = out_b.reshape(e * cap, d)
        contrib = flat[jnp.minimum(slot, e * cap - 1)] \
            * gate_sorted[:, None]
        return jnp.zeros((tg, d), x.dtype).at[tok_sorted].add(contrib)

    out = jax.vmap(combine)(out_buf, xg, aux).reshape(t, d)
    if "shared" in p:
        out = out + mlp_forward(p["shared"], x, cfg.act)
    if "residual" in p:
        out = out + mlp_forward(p["residual"], x, cfg.act)
    return out


def moe_aux_loss(p: Params, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """Switch-style load-balance loss (fraction·probability product)."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(idx, cfg.n_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * imp)
