"""Serving engine: continuous batching must match offline greedy decode."""
import numpy as np
import jax
import jax.numpy as jnp

from repro._attic.models import transformer as T
from repro._attic.lm_serving import Request, ServingEngine

CFG = T.LMConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                 d_head=16, d_ff=128, vocab=96)


def _offline(params, prompt, max_new):
    toks = list(prompt)
    for _ in range(max_new):
        lg = T.forward(params, jnp.asarray([toks]), CFG)
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_offline_greedy():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    eng = ServingEngine(params, CFG, slots=2, max_len=64)
    reqs = []
    for r in range(5):
        prompt = (np.arange(3 + 2 * r) * 7 + r) % CFG.vocab
        reqs.append(Request(rid=r, prompt=prompt.astype(np.int32),
                            max_new=3 + (r % 3)))
        eng.submit(reqs[-1])
    done = eng.run_to_completion()
    assert len(done) == 5
    for d in done:
        assert d.out == _offline(params, d.prompt, d.max_new)


def test_slot_reuse_and_latency_fields():
    params = T.init_params(jax.random.PRNGKey(1), CFG)
    eng = ServingEngine(params, CFG, slots=1, max_len=64)
    for r in range(3):
        eng.submit(Request(rid=r, prompt=np.array([1, 2, 3], np.int32),
                           max_new=2))
    done = eng.run_to_completion()
    assert len(done) == 3
    for d in done:
        assert d.t_done >= d.t_first >= d.t_submit


def test_decode_active_mask_freezes_rows():
    params = T.init_params(jax.random.PRNGKey(2), CFG)
    cache = T.make_cache(CFG, 2, 8)
    toks = jnp.asarray([[5], [9]])
    active = jnp.asarray([True, False])
    _, cache = T.decode_step(params, cache, toks, CFG, active=active)
    assert int(cache["pos"][0]) == 1
    assert int(cache["pos"][1]) == 0
    assert float(jnp.abs(cache["k"][:, 1].astype(jnp.float32)).sum()) == 0.0


def test_graph_service_routes_large_flushes_to_sharded_path():
    """With a mesh configured, micro-batches at/above the threshold run
    through the sharded executor; results stay oracle-exact and small
    flushes stay on the single-device path."""
    from oracles import bfs_dist, dijkstra_dist
    from repro.graph import generators as gen
    from repro.launch.mesh import make_mesh
    from repro.serve import GraphQuery, GraphService

    mesh = make_mesh((1, 1), ("data", "model"))
    g = gen.watts_strogatz(96, 6, 0.1, seed=3)
    w = np.random.default_rng(0).uniform(0.5, 3.0, g.m_pad).astype(
        np.float32)
    svc = GraphService(g, weights=w, max_batch=16, mesh=mesh,
                       sharded_threshold=4)
    for i in range(5):
        svc.submit(GraphQuery(qid=i, source=i,
                              target=None if i % 2 else 90))
    for i in range(5, 10):
        svc.submit(GraphQuery(qid=i, source=i, weighted=True,
                              target=None if i % 2 else 90))
    served = svc.flush()
    assert len(served) == 10 and svc.sharded_flushes == 2
    for q in served:
        ref = dijkstra_dist(g, w, q.source) if q.weighted \
            else bfs_dist(g, q.source)
        if q.target is not None:
            got = q.cost if q.weighted else q.hops
            np.testing.assert_allclose(got, ref[q.target], rtol=1e-6)
        elif q.weighted:
            np.testing.assert_allclose(q.dist, ref, rtol=1e-6)
        else:
            np.testing.assert_array_equal(q.dist, ref)

    # under the threshold the single-device path serves the flush
    svc2 = GraphService(g, max_batch=16, mesh=mesh, sharded_threshold=8)
    for i in range(3):
        svc2.submit(GraphQuery(qid=i, source=i))
    svc2.flush()
    assert svc2.sharded_flushes == 0


def test_graph_service_serves_analytics_queries():
    """GraphQuery(analytics=...) joins the continuous-batching loop:
    per-source measures micro-batch into one centrality run per flush;
    betweenness is computed once, cached, and matches the independent
    Brandes oracle."""
    from oracles import (bfs_dist, brandes_betweenness,
                         closeness_centrality, eccentricities,
                         harmonic_centrality)
    from repro.graph import generators as gen
    from repro.serve import GraphQuery, GraphService

    g = gen.watts_strogatz(96, 6, 0.1, seed=5)
    svc = GraphService(g, max_batch=16)
    for i in range(5):
        svc.submit(GraphQuery(qid=i, source=i,
                              analytics=("closeness", "harmonic",
                                         "eccentricity")))
    svc.submit(GraphQuery(qid=5, source=7, analytics=("betweenness",)))
    svc.submit(GraphQuery(qid=6, source=3))       # distance query rides along
    served = svc.flush()
    assert len(served) == 7 and svc.pending() == 0
    bc_ref = brandes_betweenness(g)
    for q in served:
        if q.analytics is None:
            np.testing.assert_array_equal(q.dist, bfs_dist(g, q.source))
            continue
        src = np.asarray([q.source])
        if "betweenness" in q.analytics:
            np.testing.assert_allclose(q.analytics_result["betweenness"],
                                       bc_ref[q.source], rtol=1e-4,
                                       atol=1e-6)
        else:
            np.testing.assert_allclose(
                q.analytics_result["closeness"],
                closeness_centrality(g, src)[0], rtol=1e-9)
            np.testing.assert_allclose(
                q.analytics_result["harmonic"],
                harmonic_centrality(g, src)[0], rtol=1e-5)
            assert q.analytics_result["eccentricity"] == \
                int(eccentricities(g, src)[0])
    # the whole-graph betweenness vector is cached across flushes
    assert svc._betweenness is not None
    svc.submit(GraphQuery(qid=9, source=11, analytics=("betweenness",)))
    (q,) = svc.flush()
    np.testing.assert_allclose(q.analytics_result["betweenness"],
                               bc_ref[11], rtol=1e-4, atol=1e-6)


def test_graph_service_rejects_bad_analytics():
    import pytest
    from repro.graph import generators as gen
    from repro.serve import GraphQuery, GraphService

    g = gen.grid2d(6, 6)
    svc = GraphService(g, max_batch=8)
    with pytest.raises(ValueError, match="unknown analytics"):
        svc.submit(GraphQuery(qid=0, source=0, analytics=("pagerank",)))
    with pytest.raises(ValueError, match="unweighted"):
        svc.submit(GraphQuery(qid=1, source=0, weighted=True,
                              analytics=("closeness",)))
    with pytest.raises(ValueError, match="k_nearest"):
        svc.submit(GraphQuery(qid=2, source=0, k_nearest=0))
    with pytest.raises(ValueError, match="k_nearest"):
        svc.submit(GraphQuery(qid=3, source=0, target=5, k_nearest=2))


# -- serving tier: cache / oracle / buckets / deadlines ---------------------

class _FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


def test_fifo_order_within_bucket_and_flush_global_order():
    """flush() serves strict global submit order; within one bucket the
    queue is FIFO."""
    from repro.graph import generators as gen
    from repro.serve import GraphQuery, GraphService

    g = gen.watts_strogatz(64, 4, 0.1, seed=0)
    svc = GraphService(g, max_batch=8)          # no oracle: one bucket
    for i in range(6):
        svc.submit(GraphQuery(qid=i, source=i))
    served = svc.flush()
    assert [q.qid for q in served] == list(range(6))

    # with an oracle, buckets may differ, but flush still drains in
    # global submit order
    svc2 = GraphService(g, max_batch=8, n_landmarks=4, row_cache_size=0)
    qs = [GraphQuery(qid=i, source=(i * 13) % 64, target=(i * 7 + 1) % 64)
          for i in range(10)]
    for q in qs:
        svc2.submit(q)
    order = []
    while svc2.pending():
        order += [q.qid for q in svc2.flush()]
    queued = [q.qid for q in qs if q.served_by not in ("cache", "oracle")]
    assert order == queued


def test_max_batch_cap_across_mixed_kinds():
    """One flush never serves more than max_batch queries even when the
    batch mixes unweighted / weighted / analytics kinds."""
    import numpy as np
    from repro.graph import generators as gen
    from repro.serve import GraphQuery, GraphService

    g = gen.watts_strogatz(64, 4, 0.1, seed=1)
    w = np.random.default_rng(0).uniform(0.5, 2.0, g.m_pad).astype(
        np.float32)
    svc = GraphService(g, weights=w, max_batch=8)
    for i in range(20):
        if i % 3 == 0:
            svc.submit(GraphQuery(qid=i, source=i, weighted=True))
        elif i % 3 == 1:
            svc.submit(GraphQuery(qid=i, source=i,
                                  analytics=("eccentricity",)))
        else:
            svc.submit(GraphQuery(qid=i, source=i))
    sizes = []
    while svc.pending():
        sizes.append(len(svc.flush()))
    assert sizes == [8, 8, 4]
    assert sum(sizes) == 20


def test_deadline_expired_queries_surfaced_not_dropped():
    from repro.graph import generators as gen
    from repro.serve import GraphQuery, GraphService

    clock = _FakeClock()
    g = gen.grid2d(8, 8)
    svc = GraphService(g, max_batch=4, clock=clock)
    svc.submit(GraphQuery(qid=0, source=0, target=63, deadline=0.5))
    svc.submit(GraphQuery(qid=1, source=1, target=63))   # no deadline
    clock.now = 10.0                       # blow the first deadline
    served = svc.flush()
    assert len(served) == 2
    by_qid = {q.qid: q for q in served}
    assert by_qid[0].expired and by_qid[0].served_by == "expired"
    assert by_qid[0].hops is None
    assert not by_qid[1].expired and by_qid[1].hops is not None
    assert svc.expired_count == 1
    assert len(svc.drain_completed()) == 2  # surfaced, not dropped


def test_tick_flushes_on_deadline_headroom_and_max_wait():
    from repro.graph import generators as gen
    from repro.serve import GraphQuery, GraphService

    clock = _FakeClock()
    g = gen.grid2d(8, 8)
    svc = GraphService(g, max_batch=8, clock=clock, deadline_safety=1.0,
                       max_wait=5.0)
    svc._flush_est = 0.1                   # deterministic headroom
    svc.submit(GraphQuery(qid=0, source=0, deadline=1.0))
    assert svc.tick() == []                # plenty of headroom
    clock.now = 0.95                       # 0.05s left < 0.1s estimate
    assert [q.qid for q in svc.tick()] == [0]

    svc.submit(GraphQuery(qid=1, source=1))   # no deadline
    clock.now = 4.0
    assert svc.tick() == []                # not full, no deadline
    clock.now = 6.1                        # head waited > max_wait
    assert [q.qid for q in svc.tick()] == [1]


def test_row_cache_serves_repeats_and_is_bounded():
    import numpy as np
    from oracles import bfs_dist
    from repro.graph import generators as gen
    from repro.serve import GraphQuery, GraphService

    g = gen.watts_strogatz(64, 4, 0.1, seed=2)
    svc = GraphService(g, max_batch=8, row_cache_size=2)
    svc.submit(GraphQuery(qid=0, source=5))
    svc.flush()
    q = GraphQuery(qid=1, source=5, target=40)
    svc.submit(q)                          # cache hit: done at submit
    assert q.served_by == "cache" and q.certified
    assert q.hops == int(bfs_dist(g, 5)[40])
    assert svc.cache_hits == 1 and svc.pending() == 0
    k = GraphQuery(qid=2, source=5, k_nearest=3)
    svc.submit(k)
    assert k.served_by == "cache" and len(k.nearest) == 3
    # LRU bound: two more sources evict source 5
    for i, s in enumerate((7, 9)):
        svc.submit(GraphQuery(qid=10 + i, source=s))
    svc.flush()
    assert len(svc._row_cache) == 2
    miss = GraphQuery(qid=20, source=5)
    svc.submit(miss)
    assert miss.served_by is None and svc.pending() == 1


def test_completed_retention_bounded_and_drain():
    from repro.graph import generators as gen
    from repro.serve import GraphQuery, GraphService

    g = gen.grid2d(8, 8)
    svc = GraphService(g, max_batch=8, completed_retention=5,
                       row_cache_size=0)
    for i in range(16):
        svc.submit(GraphQuery(qid=i, source=i))
    while svc.pending():
        svc.flush()
    assert len(svc.completed) == 5          # bounded
    assert [q.qid for q in svc.completed] == list(range(11, 16))
    assert svc.n_completed_total == 16      # nothing lost to the counter
    drained = svc.drain_completed()
    assert len(drained) == 5 and svc.completed == []


def test_oracle_tier_bit_identical_to_exact_sweeps():
    """Every query kind, served by any tier, matches the BFS oracle —
    including on the adversarial families."""
    import numpy as np
    from oracles import adversarial_families, bfs_dist
    from repro.graph.csr import CSRGraph
    from repro.serve import GraphQuery, GraphService, select_top_k

    for name, src, dst, n in adversarial_families(seed=7):
        g = CSRGraph.from_edges(src, dst, n)
        svc = GraphService(g, max_batch=8, n_landmarks=min(4, n),
                           row_cache_size=4)
        rng = np.random.default_rng(0)
        qs = []
        for i in range(12):
            s = int(rng.integers(0, n))
            kind = i % 3
            if kind == 0:
                q = GraphQuery(qid=i, source=s,
                               target=int(rng.integers(0, n)))
            elif kind == 1:
                q = GraphQuery(qid=i, source=s, k_nearest=3)
            else:
                q = GraphQuery(qid=i, source=s)
            qs.append(q)
            svc.submit(q)
        while svc.pending():
            svc.flush()
        for q in qs:
            ref = bfs_dist(g, q.source)
            if q.target is not None:
                assert q.hops == int(ref[q.target]), (name, q.qid,
                                                      q.served_by)
            elif q.k_nearest is not None:
                assert q.nearest == select_top_k(ref, q.source, 3), \
                    (name, q.qid, q.served_by)
            else:
                np.testing.assert_array_equal(q.dist, ref,
                                              err_msg=f"{name}/{q.qid}")
