from . import mesh
