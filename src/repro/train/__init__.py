from . import optimizer, train_loop, checkpoint, fault_tolerance, compression
