"""Graph centrality analytics on top of DAWN's multi-source sweeps —
the "graph analytics tool" framing of the paper's conclusion (GBBS-style
applications: closeness, harmonic centrality, radius/diameter estimates).

Everything here is a thin reduction over ``multi_source`` distance
blocks, so it inherits DAWN's parallelism (and the distributed path)."""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..graph.csr import CSRGraph
from .sssp import multi_source


def closeness(g: CSRGraph, sources: Optional[np.ndarray] = None, *,
              block: int = 128, method: str = "auto") -> np.ndarray:
    """Closeness centrality C(u) = (r-1) / Σ_v d(u,v) over reachable v
    (Wasserman-Faust normalized for disconnected graphs).

    Computed for ``sources`` (default: all nodes) via blocked MSBFS."""
    n = g.n_nodes
    sources = np.arange(n) if sources is None else np.asarray(sources)
    out = np.zeros(len(sources), np.float64)
    for lo in range(0, len(sources), block):
        chunk = sources[lo:lo + block]
        dist = np.asarray(multi_source(g, chunk, method=method, parents=False).dist)
        reach = dist > 0
        r = reach.sum(axis=1) + 1                       # incl. self
        tot = np.where(reach, dist, 0).sum(axis=1)
        frac = (r - 1) / max(n - 1, 1)
        out[lo:lo + len(chunk)] = np.where(
            tot > 0, frac * (r - 1) / np.maximum(tot, 1), 0.0)
    return out


def harmonic(g: CSRGraph, sources: Optional[np.ndarray] = None, *,
             block: int = 128, method: str = "auto") -> np.ndarray:
    """Harmonic centrality H(u) = Σ_{v≠u} 1/d(u,v)."""
    n = g.n_nodes
    sources = np.arange(n) if sources is None else np.asarray(sources)
    out = np.zeros(len(sources), np.float64)
    for lo in range(0, len(sources), block):
        chunk = sources[lo:lo + block]
        dist = np.asarray(multi_source(g, chunk, method=method, parents=False).dist)
        with np.errstate(divide="ignore"):
            inv = np.where(dist > 0, 1.0 / np.maximum(dist, 1), 0.0)
        out[lo:lo + len(chunk)] = inv.sum(axis=1)
    return out


def eccentricity_sample(g: CSRGraph, n_samples: int = 64, *,
                        seed: int = 0, method: str = "auto"):
    """Sampled eccentricities → (radius_upper, diameter_lower) estimates
    (Takes-Kosters-style bounds from a random source set — the paper's
    ε(i) ≈ log n observation is checkable with this)."""
    rng = np.random.default_rng(seed)
    sources = rng.integers(0, g.n_nodes, n_samples)
    dist = np.asarray(multi_source(g, sources, method=method, parents=False).dist)
    ecc = np.where((dist >= 0).any(1), dist.max(1, initial=0), 0)
    return {"radius_upper": int(ecc[ecc > 0].min()) if (ecc > 0).any() else 0,
            "diameter_lower": int(ecc.max()),
            "ecc_mean": float(ecc.mean())}
