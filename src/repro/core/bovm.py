"""BOVM — Boolean Vector/Matrix Operation (paper Alg. 1), TPU form.

The paper walks CSC columns with a per-element early exit.  The TPU-native
equivalent is a {0,1}-valued matmul: a sweep computes

    counts = F @ A        (S sources batched; MXU-friendly)
    hits   = counts > 0
    new    = hits & ~visited          # Theorem 3.2 skip
    dist   = where(new, step, dist)   # first hit IS the shortest path

and the per-element early exit becomes tile-level skipping inside the
Pallas kernel (kernels/bovm).  Values are exact: counts ≤ n < 2^24 so f32
accumulation is lossless; int8 inputs with int32 accumulation are also
supported.

Convergence is Fact 1: a sweep that discovers nothing terminates the loop —
expressed as a scalar reduction usable as a `lax.while_loop` predicate.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .frontier import UNREACHED, one_hot_frontier


class DawnState(NamedTuple):
    frontier: jax.Array   # (S, n) bool — discovered in the previous sweep
    dist: jax.Array       # (S, n) int32, UNREACHED = -1
    step: jax.Array       # scalar int32, current path length
    done: jax.Array       # scalar bool — Fact 1 fired
    edges_touched: jax.Array  # scalar int64-ish float — work counter (Eq. 5)


def bovm_sweep(adj: jax.Array, frontier: jax.Array, visited: jax.Array,
               *, accum_dtype=jnp.float32,
               matmul_fn=None) -> jax.Array:
    """One boolean sweep: new = (frontier @ adj > 0) & ~visited.

    adj      : (n, n) int8/bool dense adjacency (row = src, col = dst)
    frontier : (S, n) bool
    visited  : (S, n) bool
    matmul_fn: optional kernel override (e.g. the Pallas tile-skip kernel),
               signature (F_int, A_int) -> counts.
    """
    if matmul_fn is None:
        f = frontier.astype(accum_dtype)
        a = adj.astype(accum_dtype)
        counts = jax.lax.dot_general(
            f, a, (((1,), (0,)), ((), ())),
            preferred_element_type=accum_dtype)
    else:
        counts = matmul_fn(frontier, adj)
    hits = counts > 0
    return hits & ~visited


@partial(jax.jit, static_argnames=("max_steps", "accum_dtype"))
def bovm_msbfs(adj: jax.Array, sources: jax.Array, *,
               max_steps: Optional[int] = None,
               accum_dtype=jnp.float32) -> DawnState:
    """Multi-source DAWN over a dense adjacency.

    adj     : (n, n) int8 dense adjacency
    sources : (S,) int32
    returns : DawnState with dist (S, n); dist[s, sources[s]] = 0.
    """
    n = adj.shape[0]
    s = sources.shape[0]
    max_steps = n if max_steps is None else max_steps

    f0 = one_hot_frontier(sources, n)
    dist0 = jnp.where(f0, 0, jnp.full((s, n), UNREACHED))
    state = DawnState(frontier=f0, dist=dist0,
                      step=jnp.int32(0), done=jnp.bool_(False),
                      edges_touched=jnp.float32(0.0))

    deg = jnp.sum(adj.astype(jnp.float32), axis=1)  # out-degrees

    def cond(st: DawnState):
        return (~st.done) & (st.step < max_steps)

    def body(st: DawnState):
        step = st.step + 1
        visited = st.dist >= 0
        new = bovm_sweep(adj, st.frontier, visited, accum_dtype=accum_dtype)
        dist = jnp.where(new, step, st.dist)
        any_new = jnp.any(new)
        touched = st.edges_touched + jnp.sum(
            st.frontier.astype(jnp.float32) * deg[None, :])
        return DawnState(frontier=new, dist=dist, step=step,
                         done=~any_new, edges_touched=touched)

    return jax.lax.while_loop(cond, body, state)


def bovm_sssp(adj: jax.Array, source, **kw) -> DawnState:
    """Single-source convenience wrapper (S = 1)."""
    src = jnp.atleast_1d(jnp.asarray(source, jnp.int32))
    st = bovm_msbfs(adj, src, **kw)
    return DawnState(frontier=st.frontier[0], dist=st.dist[0],
                     step=st.step, done=st.done,
                     edges_touched=st.edges_touched)
