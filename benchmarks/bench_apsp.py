"""Direction-optimized batched APSP: fixed-push vs fixed-pull vs auto.

Runs one MXU-aligned source tile through the core/engine.py driver on each
generator family three times — with the sweep direction pinned to push,
pinned to pull, and chosen by the engine (calibrated per graph on the CPU
reference path; per-sweep occupancy switching on the TPU kernel path) —
and emits a JSON document with per-family timings plus the two acceptance
booleans:

  * ``auto_no_slower_than_best_everywhere`` — auto within TOLERANCE of
    min(push, pull) on every family;
  * ``auto_beats_worse_on`` — families where auto beats the *worse* fixed
    direction by a real margin (>= 1.25x).

Times are best-of-``repeats`` wall clock of the jitted driver (compile
excluded by a warmup run).  On CPU the engine uses the XLA reference
sweeps; the relative ordering of the three forms is what is under test,
not absolute throughput.

Two kernel-path comparisons ride along per family (interpret-mode
Pallas): ``t_kernel_fused*`` vs ``t_kernel_push*`` time the fused
multi-sweep blocks (``fused_steps=-1``, whole fixpoint per launch)
against the per-sweep kernel loop, with dist bit-identity and the
``sweeps_fused`` hard-gate field asserted first; ``t_push_packed*`` vs
``t_push_f32*`` time one first-hop sweep through the bit-packed uint32
push kernel against the f32 GEMM push it replaces (Eq. 13 operand
shrink), again bit-identity first.

    PYTHONPATH=src python -m benchmarks.bench_apsp [--quick] [--out f.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import EngineConfig, pack_bits, prepare_graph
from repro.core.autotune import build_plan
from repro.core.engine import apsp_engine
from repro.graph import generators as gen
from repro.kernels.bovm import fused_sweep, packed_push_sweep

from ._timing import (BEAT_MARGIN, TOLERANCE, auto_vs_fixed,
                      time_interleaved_stats)

FAMILIES: Dict[str, Callable] = {
    "grid_road": lambda: gen.grid2d(32, 32),
    "rmat_social": lambda: gen.rmat(10, 8, directed=False, seed=1),
    "ws_citation": lambda: gen.watts_strogatz(1024, 8, 0.05, seed=3),
    "er_uniform": lambda: gen.erdos_renyi(1024, 6.0, directed=False, seed=5),
    "ba_web": lambda: gen.barabasi_albert(1024, 4, seed=6),
    "mycielskian": lambda: gen.mycielskian(9),
}

QUICK_FAMILIES = ("grid_road", "ws_citation", "mycielskian")


def run(quick: bool = False, n_sources: int = 64, repeats: int = 10,
        csv: Optional[List[str]] = None) -> Dict:
    names = QUICK_FAMILIES if quick else tuple(FAMILIES)
    families = {}
    beats_worse = []
    auto_ok_everywhere = True
    for name in names:
        g = FAMILIES[name]()
        pg = prepare_graph(g)
        sources = np.arange(min(n_sources, g.n_nodes), dtype=np.int32)
        row: Dict = {"n_nodes": g.n_nodes, "n_edges": g.n_edges,
                     "n_sources": int(len(sources))}

        last_auto: List = []

        def make_go(mode):
            cfg = EngineConfig(mode=mode, source_batch=64)

            def go():
                res = apsp_engine(pg, sources, config=cfg)
                res.dist.block_until_ready()
                if mode == "auto":
                    last_auto[:] = [res]
            return go

        stats = time_interleaved_stats(
            {m: make_go(m) for m in ("push", "pull", "auto")}, repeats)
        for mode, st in stats.items():
            row[f"t_{mode}"] = st["best"]
            row[f"t_{mode}_median"] = st["median"]
        res = last_auto[0]
        row["sweeps"] = int(res.sweeps)
        row["auto_direction_counts"] = dict(
            zip(("push", "pull", "sparse"),
                np.asarray(res.direction_counts).tolist()))
        auto_vs_fixed(row, ("push", "pull"))
        auto_ok_everywhere &= row["auto_no_slower_than_best"]
        if row["auto_beats_worse"]:
            beats_worse.append(name)

        # --- fused multi-sweep blocks vs the per-sweep kernel loop.
        # Both run the interpret-mode Pallas push kernel; bit-identity of
        # dist and the sweep count is asserted before anything is timed,
        # and ``sweeps_fused`` rides the hard regression gate.  Interpret
        # mode re-traces the whole K-sweep block as XLA ops, so the fused
        # column measures launch structure, not MXU residency.
        cfg_kernel = EngineConfig(mode="push", source_batch=64,
                                  use_kernel=True)
        cfg_fused = EngineConfig(mode="push", source_batch=64,
                                 use_kernel=True, fused_steps=-1)
        res_k = apsp_engine(pg, sources, config=cfg_kernel)
        res_f = apsp_engine(pg, sources, config=cfg_fused)
        np.testing.assert_array_equal(np.asarray(res_f.dist),
                                      np.asarray(res_k.dist))
        assert int(res_f.sweeps) == int(res_k.sweeps)
        row["sweeps_fused"] = int(res_f.sweeps)
        row["fused_equals_per_sweep"] = True

        def make_kernel_go(cfg):
            def go():
                apsp_engine(pg, sources, config=cfg).dist.block_until_ready()
            return go

        for mode, st in time_interleaved_stats(
                {"kernel_push": make_kernel_go(cfg_kernel),
                 "kernel_fused": make_kernel_go(cfg_fused)},
                max(2, repeats // 3)).items():
            row[f"t_{mode}"] = st["best"]
            row[f"t_{mode}_median"] = st["median"]

        # --- packed uint32 push vs the f32 GEMM it replaces: one sweep
        # from the first-hop frontier, bit-identity asserted first.
        s_b = int(len(sources))
        f0 = np.zeros((s_b, pg.n_pad), np.int8)
        f0[np.arange(s_b), np.asarray(sources)] = 1
        d0 = np.full((s_b, pg.n_pad), -1, np.int32)
        d0[np.arange(s_b), np.asarray(sources)] = 0
        f0, d0 = jnp.asarray(f0), jnp.asarray(d0)
        fp, ap = pack_bits(f0 > 0), pg.adj_pull
        bs = 64 if s_b % 64 == 0 else s_b
        new_p, dist_p = packed_push_sweep(fp, ap, d0, 0, bs=bs, bn=128,
                                          wk=4, interpret=True)
        new_g, dist_g = fused_sweep(f0, pg.adj, d0, 0, bs=bs, bn=128,
                                    bk=128, interpret=True)
        np.testing.assert_array_equal(np.asarray(dist_p), np.asarray(dist_g))
        row["packed_push_matches_f32"] = True

        pp = jax.jit(lambda: packed_push_sweep(fp, ap, d0, 0, bs=bs,
                                               bn=128, wk=4,
                                               interpret=True)[1])
        pf = jax.jit(lambda: fused_sweep(f0, pg.adj, d0, 0, bs=bs, bn=128,
                                         bk=128, interpret=True)[1])
        for mode, st in time_interleaved_stats(
                {"push_packed": lambda: pp().block_until_ready(),
                 "push_f32": lambda: pf().block_until_ready()},
                repeats).items():
            row[f"t_{mode}"] = st["best"]
            row[f"t_{mode}_median"] = st["median"]

        # --- autotuned vs default config.  The roofline plan may change
        # tiles, the fused gate and the auto-direction pin, but never
        # results: dist bit-identity is asserted before anything is
        # timed.  ``tuning_plan_checksum`` rides the hard regression
        # gate — the static plan is a pure function of graph shape and
        # backend, so a checksum change means the tuner (or the VMEM
        # budget math behind it) decided differently, not that the
        # machine was slow.  ``autotuned_beats_default`` is advisory
        # (timing-derived).
        plan = build_plan(pg, use_hlo=False)
        row["tuning_plan_checksum"] = plan.checksum()
        cfg_default = EngineConfig(mode="auto", source_batch=64)
        cfg_tuned = dataclasses.replace(cfg_default, tuning=plan)
        res_t = apsp_engine(pg, sources, config=cfg_tuned)
        np.testing.assert_array_equal(np.asarray(res_t.dist),
                                      np.asarray(res.dist))
        row["autotuned_matches_default"] = True
        for mode, st in time_interleaved_stats(
                {"auto_default": make_go("auto"),
                 "auto_tuned": make_kernel_go(cfg_tuned)},
                max(2, repeats // 3)).items():
            row[f"t_{mode}"] = st["best"]
            row[f"t_{mode}_median"] = st["median"]
        row["autotuned_beats_default"] = (
            row["t_auto_tuned"] <= row["t_auto_default"] * TOLERANCE)

        families[name] = row
        if csv is not None:
            csv.append(f"apsp_{name},{row['t_auto'] * 1e6:.1f},"
                       f"auto_vs_best={row['auto_vs_best']:.2f}")
            csv.append(
                f"apsp_{name}_fused,{row['t_kernel_fused'] * 1e6:.1f},"
                f"fused_vs_per_sweep="
                f"{row['t_kernel_fused'] / row['t_kernel_push']:.2f}")
            csv.append(
                f"apsp_{name}_push_packed,"
                f"{row['t_push_packed'] * 1e6:.1f},"
                f"packed_vs_f32="
                f"{row['t_push_packed'] / row['t_push_f32']:.2f}")
    return {
        "benchmark": "bench_apsp",
        "tolerance": TOLERANCE,
        "beat_margin": BEAT_MARGIN,
        "families": families,
        "auto_no_slower_than_best_everywhere": auto_ok_everywhere,
        "auto_beats_worse_on": beats_worse,
        "auto_beats_worse_on_at_least_two": len(beats_worse) >= 2,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--sources", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=10)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    result = run(quick=args.quick, n_sources=args.sources,
                 repeats=args.repeats)
    text = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
