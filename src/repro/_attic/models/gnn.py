"""GNN architectures: GraphSAGE, MeshGraphNet, SchNet, EquiformerV2 (eSCN).

Message passing is built on ``jax.ops.segment_sum``-style scatter over edge
index arrays (JAX has no CSR SpMM) — the same primitive family DAWN's SOVM
uses (DESIGN.md §4).  All batches are fixed-shape dicts:

    feat (N, d) | pos (N, 3) | species (N,) | src/dst (E,) int32 (sentinel N)
    node_mask (N,) bool | labels / targets

Scatters go into N+1 rows; the sentinel row is dropped.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import spherical as sph
from .layers import linear, linear_init, _normal

Params = Dict[str, Any]


# -- segment primitives -------------------------------------------------------

def seg_sum(data: jax.Array, seg: jax.Array, n: int) -> jax.Array:
    """Scatter-add rows of data by segment id; returns (n, ...)."""
    out = jnp.zeros((n + 1,) + data.shape[1:], data.dtype).at[seg].add(data)
    return out[:n]


def seg_mean(data: jax.Array, seg: jax.Array, n: int) -> jax.Array:
    s = seg_sum(data, seg, n)
    cnt = seg_sum(jnp.ones((data.shape[0], 1), data.dtype), seg, n)
    return s / jnp.maximum(cnt, 1)


def seg_softmax(logits: jax.Array, seg: jax.Array, n: int) -> jax.Array:
    """Per-destination softmax over edges. logits (E, H) -> weights (E, H)."""
    mx = jnp.full((n + 1,) + logits.shape[1:], -jnp.inf, logits.dtype
                  ).at[seg].max(logits)
    ex = jnp.exp(logits - mx[seg])
    den = jnp.zeros((n + 1,) + logits.shape[1:], logits.dtype).at[seg].add(ex)
    return ex / jnp.maximum(den[seg], 1e-20)


def _mlp_init(key, dims, dtype=jnp.float32, layernorm=False):
    ks = jax.random.split(key, len(dims) - 1)
    p = {"layers": [linear_init(k, a, b, bias=True, dtype=dtype)
                    for k, a, b in zip(ks, dims[:-1], dims[1:])]}
    if layernorm:
        p["ln_g"] = jnp.ones((dims[-1],), dtype)
        p["ln_b"] = jnp.zeros((dims[-1],), dtype)
    return p


def _mlp(p, x, act=jax.nn.relu):
    h = x
    for i, lp in enumerate(p["layers"]):
        h = linear(lp, h)
        if i < len(p["layers"]) - 1:
            h = act(h)
    if "ln_g" in p:
        mu = h.mean(-1, keepdims=True)
        var = ((h - mu) ** 2).mean(-1, keepdims=True)
        h = (h - mu) * jax.lax.rsqrt(var + 1e-6) * p["ln_g"] + p["ln_b"]
    return h


# ==============================================================================
# GraphSAGE  (mean aggregator, 2 layers) — arXiv:1706.02216
# ==============================================================================

@dataclasses.dataclass(frozen=True)
class SAGEConfig:
    name: str = "graphsage-reddit"
    n_layers: int = 2
    d_in: int = 602
    d_hidden: int = 128
    n_classes: int = 41
    fanouts: tuple = (25, 10)


def sage_init(key, cfg: SAGEConfig) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    d = cfg.d_in
    for i in range(cfg.n_layers):
        out = cfg.d_hidden
        layers.append({
            "self": linear_init(ks[i], d, out, bias=True, dtype=jnp.float32),
            "neigh": linear_init(jax.random.fold_in(ks[i], 1), d, out,
                                 dtype=jnp.float32)})
        d = out
    return {"layers": layers,
            "head": linear_init(ks[-1], d, cfg.n_classes, bias=True,
                                dtype=jnp.float32)}


def sage_forward(params: Params, batch: Dict[str, jax.Array],
                 cfg: SAGEConfig) -> jax.Array:
    """Full-graph / subgraph forward. Returns logits (N, n_classes)."""
    h = batch["feat"]
    n = h.shape[0]
    src, dst = batch["src"], batch["dst"]
    for lp in params["layers"]:
        msg = h[jnp.minimum(src, n - 1)]
        msg = jnp.where((src < n)[:, None], msg, 0)
        agg = seg_mean(msg, dst, n)
        h = jax.nn.relu(linear(lp["self"], h) + linear(lp["neigh"], agg))
        h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    return linear(params["head"], h)


def sage_loss(params, batch, cfg: SAGEConfig) -> jax.Array:
    logits = sage_forward(params, batch, cfg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], -1)[:, 0]
    mask = batch["node_mask"].astype(jnp.float32)
    return jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1)


# ==============================================================================
# MeshGraphNet (encode-process-decode, 15 MP layers) — arXiv:2010.03409
# ==============================================================================

@dataclasses.dataclass(frozen=True)
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 8     # node type one-hot + velocity
    d_edge_in: int = 4     # relative pos (3) + norm (1)
    d_out: int = 2


def mgn_init(key, cfg: MGNConfig) -> Params:
    ks = jax.random.split(key, 2 * cfg.n_layers + 3)
    h = cfg.d_hidden
    hidden = [h] * cfg.mlp_layers
    proc = []
    for i in range(cfg.n_layers):
        proc.append({
            "edge": _mlp_init(ks[2 * i], [3 * h] + hidden + [h],
                              layernorm=True),
            "node": _mlp_init(ks[2 * i + 1], [2 * h] + hidden + [h],
                              layernorm=True)})
    return {
        "enc_node": _mlp_init(ks[-3], [cfg.d_node_in] + hidden + [h],
                              layernorm=True),
        "enc_edge": _mlp_init(ks[-2], [cfg.d_edge_in] + hidden + [h],
                              layernorm=True),
        "dec": _mlp_init(ks[-1], [h] + hidden + [cfg.d_out]),
        "proc": proc,
    }


def mgn_forward(params: Params, batch: Dict[str, jax.Array],
                cfg: MGNConfig) -> jax.Array:
    n = batch["feat"].shape[0]
    src, dst = batch["src"], batch["dst"]
    s_safe = jnp.minimum(src, n - 1)
    d_safe = jnp.minimum(dst, n - 1)
    rel = batch["pos"][d_safe] - batch["pos"][s_safe]
    e_in = jnp.concatenate(
        [rel, jnp.linalg.norm(rel, axis=-1, keepdims=True)], -1)
    h = _mlp(params["enc_node"], batch["feat"])
    e = _mlp(params["enc_edge"], e_in)
    e = jnp.where((src < n)[:, None], e, 0)
    for lp in params["proc"]:
        e = e + _mlp(lp["edge"],
                     jnp.concatenate([e, h[s_safe], h[d_safe]], -1))
        e = jnp.where((src < n)[:, None], e, 0)
        h = h + _mlp(lp["node"],
                     jnp.concatenate([h, seg_sum(e, dst, n)], -1))
    return _mlp(params["dec"], h)


def mgn_loss(params, batch, cfg: MGNConfig) -> jax.Array:
    pred = mgn_forward(params, batch, cfg)
    mask = batch["node_mask"][:, None].astype(jnp.float32)
    return jnp.sum(((pred - batch["targets"]) ** 2) * mask) \
        / jnp.maximum(mask.sum(), 1)


# ==============================================================================
# SchNet (3 interactions, cfconv with RBF filters) — arXiv:1706.08566
# ==============================================================================

@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 100


def _ssp(x):  # shifted softplus
    return jax.nn.softplus(x) - jnp.log(2.0)


def schnet_init(key, cfg: SchNetConfig) -> Params:
    ks = jax.random.split(key, cfg.n_interactions + 3)
    h = cfg.d_hidden
    inter = []
    for i in range(cfg.n_interactions):
        kk = jax.random.split(ks[i], 5)
        inter.append({
            "in": linear_init(kk[0], h, h, dtype=jnp.float32),
            "filt": _mlp_init(kk[1], [cfg.n_rbf, h, h]),
            "out1": linear_init(kk[2], h, h, bias=True, dtype=jnp.float32),
            "out2": linear_init(kk[3], h, h, bias=True, dtype=jnp.float32)})
    return {
        "embed": _normal(ks[-3], (cfg.n_species, h), 0.1, jnp.float32),
        "inter": inter,
        "head": _mlp_init(ks[-1], [h, h // 2, 1]),
    }


def schnet_forward(params: Params, batch: Dict[str, jax.Array],
                   cfg: SchNetConfig, n_graphs: int = 1) -> jax.Array:
    """Returns per-graph energies (n_graphs,) via graph_id segment sum.
    ``n_graphs`` is static (close over it in the step factory)."""
    n = batch["species"].shape[0]
    src, dst = batch["src"], batch["dst"]
    s_safe, d_safe = jnp.minimum(src, n - 1), jnp.minimum(dst, n - 1)
    d_ij = jnp.linalg.norm(batch["pos"][d_safe] - batch["pos"][s_safe] + 1e-9,
                           axis=-1)
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = 10.0
    rbf = jnp.exp(-gamma * (d_ij[:, None] - centers) ** 2)
    # cosine cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.minimum(d_ij / cfg.cutoff, 1.0)) + 1.0)
    x = params["embed"][jnp.minimum(batch["species"], cfg.n_species - 1)]
    for lp in params["inter"]:
        h = linear(lp["in"], x)
        w = _mlp(lp["filt"], rbf, act=_ssp) * env[:, None]
        msg = h[s_safe] * w
        msg = jnp.where((src < n)[:, None], msg, 0)
        agg = seg_sum(msg, dst, n)
        v = linear(lp["out2"], _ssp(linear(lp["out1"], agg)))
        x = x + v
    atom_e = _mlp(params["head"], x, act=_ssp)[:, 0]
    atom_e = jnp.where(batch["node_mask"], atom_e, 0)
    return seg_sum(atom_e[:, None], batch["graph_id"], n_graphs)[:, 0]


def schnet_loss(params, batch, cfg: SchNetConfig, n_graphs: int = 1) -> jax.Array:
    e = schnet_forward(params, batch, cfg, n_graphs)
    return jnp.mean((e - batch["energy"]) ** 2)


# ==============================================================================
# EquiformerV2 (eSCN SO(2) equivariant graph attention) — arXiv:2306.12059
# ==============================================================================

@dataclasses.dataclass(frozen=True)
class EqV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128          # sphere channels
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 64
    n_species: int = 100
    cutoff: float = 10.0
    edge_chunk: Optional[int] = None   # scan over edge chunks (memory bound)

    @property
    def n_coeffs(self) -> int:
        return sph.n_coeffs(self.l_max)


def _so2_init(key, cfg: EqV2Config, dtype=jnp.float32) -> Params:
    """SO(2) linear weights per |m| (the eSCN O(L³) parameterization)."""
    c = cfg.d_hidden
    p = {}
    for m in range(cfg.m_max + 1):
        nl = cfg.l_max + 1 - m
        k1, k2, key = jax.random.split(key, 3)
        scale = (nl * c) ** -0.5
        p[f"w{m}_r"] = _normal(k1, (nl * c, nl * c), scale, dtype)
        if m > 0:
            p[f"w{m}_i"] = _normal(k2, (nl * c, nl * c), scale, dtype)
    return p


def eqv2_init(key, cfg: EqV2Config) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 4)
    c = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        kk = jax.random.split(ks[i], 6)
        layers.append({
            "so2": _so2_init(kk[0], cfg),
            "attn_mlp": _mlp_init(kk[1], [c + cfg.n_rbf, c, cfg.n_heads]),
            "val_proj": linear_init(kk[2], c, c, dtype=jnp.float32),
            "ffn_gate": linear_init(kk[3], c, c, bias=True,
                                    dtype=jnp.float32),
            "ffn1": linear_init(kk[4], c, c, dtype=jnp.float32),
            "ffn2": linear_init(kk[5], c, c, dtype=jnp.float32)})
    return {
        "embed": _normal(ks[-3], (cfg.n_species, c), 0.2, jnp.float32),
        "rbf_mlp": _mlp_init(ks[-2], [cfg.n_rbf, c, c]),
        "head": _mlp_init(ks[-1], [c, c, 1]),
        "layers": layers,
    }


def _eq_layernorm(x: jax.Array, cfg: EqV2Config) -> jax.Array:
    """Equivariant RMS norm: per-l, per-channel norm over m."""
    outs = []
    for lo, hi in sph.irrep_slices(cfg.l_max):
        blk = x[:, lo:hi, :]
        rms = jnp.sqrt(jnp.mean(blk * blk, axis=(1,), keepdims=True) + 1e-6)
        outs.append(blk / rms)
    return jnp.concatenate(outs, axis=1)


def _so2_conv(p: Params, x_edge: jax.Array, cfg: EqV2Config) -> jax.Array:
    """SO(2) restricted linear in the edge frame.  x_edge (E, M, C)."""
    e, m_tot, c = x_edge.shape
    pos_idx, neg_idx = sph.m_indices(cfg.l_max)
    out = jnp.zeros_like(x_edge)
    # m = 0
    nl = cfg.l_max + 1
    x0 = x_edge[:, jnp.asarray(pos_idx[0]), :].reshape(e, nl * c)
    out = out.at[:, jnp.asarray(pos_idx[0]), :].set(
        (x0 @ p["w0_r"]).reshape(e, nl, c))
    for m in range(1, cfg.m_max + 1):
        nl = cfg.l_max + 1 - m
        ip = jnp.asarray(pos_idx[m])
        im = jnp.asarray(neg_idx[m])
        xp = x_edge[:, ip, :].reshape(e, nl * c)
        xm = x_edge[:, im, :].reshape(e, nl * c)
        yr = xp @ p[f"w{m}_r"] - xm @ p[f"w{m}_i"]
        yi = xp @ p[f"w{m}_i"] + xm @ p[f"w{m}_r"]
        out = out.at[:, ip, :].set(yr.reshape(e, nl, c))
        out = out.at[:, im, :].set(yi.reshape(e, nl, c))
    return out


def _eqv2_messages(lp, x, rbf, wig, wig_inv, s_safe, edge_valid, cfg):
    """Per-edge eSCN attention messages. Returns (E, M, C) and (E, H)."""
    x_src = x[s_safe]                                   # (E, M, C)
    x_rot = jnp.einsum("enm,emc->enc", wig, x_src)
    msg = _so2_conv(lp["so2"], x_rot, cfg)
    # invariant (l=0) part drives attention logits
    inv = msg[:, 0, :]                                  # (E, C)
    logits = _mlp(lp["attn_mlp"], jnp.concatenate([inv, rbf], -1))
    logits = jnp.where(edge_valid[:, None], logits, -1e30)
    msg = jnp.einsum("enm,emc->enc", wig_inv, msg)      # rotate back
    msg = linear(lp["val_proj"], msg)
    return msg, logits


def _eqv2_layer_chunked(lp, x, batch, cfg: EqV2Config, n: int,
                        chunk: int):
    """Edge-chunked two-pass segment-softmax layer (§Perf: bounds the
    per-edge Wigner/message buffers to one chunk; 61.8M-edge graphs drop
    from ~1.9 TiB of edge intermediates to chunk-sized transients).

    Sharding contract: node tensors ride replicated-over-data /
    channel-sharded-over-model; edge chunks shard over data."""
    e_cnt = batch["src"].shape[0]
    nc = e_cnt // chunk
    c = cfg.d_hidden
    m_tot = cfg.n_coeffs
    h_heads = cfg.n_heads
    ch = c // h_heads

    def chunk_arrays(i):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * chunk, chunk)
        src_c, dst_c = sl(batch["src"]), sl(batch["dst"])
        s_safe = jnp.minimum(src_c, n - 1)
        d_safe = jnp.minimum(dst_c, n - 1)
        valid = src_c < n
        vec = batch["pos"][d_safe] - batch["pos"][s_safe]
        dist = jnp.linalg.norm(vec + 1e-9, axis=-1)
        centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
        rbf = jnp.exp(-10.0 * (dist[:, None] - centers) ** 2)
        rot = sph.align_to_z(vec)
        wig = sph.wigner_d(rot, cfg.l_max)
        return s_safe, d_safe, valid, rbf, wig

    def messages(i):
        s_safe, d_safe, valid, rbf, wig = chunk_arrays(i)
        wig_inv = jnp.swapaxes(wig, -1, -2)
        msg, logits = _eqv2_messages(lp, x, rbf, wig, wig_inv, s_safe,
                                     valid, cfg)
        return msg, logits, d_safe, valid

    # pass 1: per-destination logit max
    def p1(carry, i):
        mx = carry
        _, logits, d_safe, valid = messages(i)
        mx = mx.at[d_safe].max(jnp.where(valid[:, None], logits, -jnp.inf))
        return mx, None

    mx0 = jnp.full((n, h_heads), -jnp.inf, jnp.float32)
    mx, _ = jax.lax.scan(p1, mx0, jnp.arange(nc))

    # pass 2: accumulate exp-weighted messages + denominators
    def p2(carry, i):
        num, den = carry
        msg, logits, d_safe, valid = messages(i)
        ex = jnp.where(valid[:, None],
                       jnp.exp(logits - mx[d_safe]), 0.0)     # (ck, H)
        den = den.at[d_safe].add(ex)
        wmsg = (msg.reshape(chunk, m_tot, h_heads, ch)
                * ex[:, None, :, None]).reshape(chunk, m_tot, c)
        num = num.at[d_safe].add(wmsg)
        return (num, den), None

    num0 = jnp.zeros((n, m_tot, c), jnp.float32)
    den0 = jnp.zeros((n, h_heads), jnp.float32)
    (num, den), _ = jax.lax.scan(p2, (num0, den0), jnp.arange(nc))
    den_c = jnp.repeat(jnp.maximum(den, 1e-20), ch, axis=1)   # (n, C)
    return num / den_c[:, None, :]


def eqv2_forward(params: Params, batch: Dict[str, jax.Array],
                 cfg: EqV2Config, n_graphs: int = 1) -> jax.Array:
    n = batch["species"].shape[0]
    src, dst = batch["src"], batch["dst"]
    e_cnt = src.shape[0]
    chunked = cfg.edge_chunk is not None and e_cnt > cfg.edge_chunk
    if not chunked:
        s_safe, d_safe = jnp.minimum(src, n - 1), jnp.minimum(dst, n - 1)
        edge_valid = src < n
        vec = batch["pos"][d_safe] - batch["pos"][s_safe]
        dist = jnp.linalg.norm(vec + 1e-9, axis=-1)
        centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
        rbf = jnp.exp(-10.0 * (dist[:, None] - centers) ** 2)
        rot = sph.align_to_z(vec)                        # (E, 3, 3)
        wig = sph.wigner_d(rot, cfg.l_max)               # (E, M, M)
        wig_inv = jnp.swapaxes(wig, -1, -2)              # orthogonal

    c = cfg.d_hidden
    m_tot = cfg.n_coeffs
    x = jnp.zeros((n, m_tot, c), jnp.float32)
    x = x.at[:, 0, :].set(
        params["embed"][jnp.minimum(batch["species"], cfg.n_species - 1)])

    h = cfg.n_heads
    ch = c // h
    for lp in params["layers"]:
        xn = _eq_layernorm(x, cfg)
        if chunked:
            agg = _eqv2_layer_chunked(lp, xn, batch, cfg, n,
                                      cfg.edge_chunk)
            x = x + agg
            xn2 = _eq_layernorm(x, cfg)
            scal = xn2[:, 0, :]
            gate = jax.nn.sigmoid(linear(lp["ffn_gate"], scal))
            y = linear(lp["ffn1"], xn2) * gate[:, None, :]
            y = y.at[:, 0, :].set(jax.nn.silu(y[:, 0, :]))
            x = x + linear(lp["ffn2"], y)
            continue
        msg, logits = _eqv2_messages(lp, xn, rbf, wig, wig_inv, s_safe,
                                     edge_valid, cfg)
        w = seg_softmax(logits, dst, n)                  # (E, H)
        wmsg = (msg.reshape(e_cnt, m_tot, h, ch)
                * w[:, None, :, None]).reshape(e_cnt, m_tot, c)
        wmsg = jnp.where(edge_valid[:, None, None], wmsg, 0)
        agg = seg_sum(wmsg, dst, n)
        x = x + agg
        # gated equivariant FFN
        xn = _eq_layernorm(x, cfg)
        scal = xn[:, 0, :]
        gate = jax.nn.sigmoid(linear(lp["ffn_gate"], scal))
        y = linear(lp["ffn1"], xn)
        y = y * gate[:, None, :]
        y = y.at[:, 0, :].set(jax.nn.silu(y[:, 0, :]))
        x = x + linear(lp["ffn2"], y)
    # invariant readout
    atom_e = _mlp(params["head"], x[:, 0, :], act=jax.nn.silu)[:, 0]
    atom_e = jnp.where(batch["node_mask"], atom_e, 0)
    return seg_sum(atom_e[:, None], batch["graph_id"], n_graphs)[:, 0]


def eqv2_loss(params, batch, cfg: EqV2Config, n_graphs: int = 1) -> jax.Array:
    e = eqv2_forward(params, batch, cfg, n_graphs)
    return jnp.mean((e - batch["energy"]) ** 2)
