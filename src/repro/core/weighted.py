"""Weighted-graph DAWN — the paper's §5 future-work direction.

The paper closes with "addressing the balance between optimizing matrix
operations and managing the consumption of (min,+) operations … to expand
the applicability of DAWN on weighted graphs".  We implement that
extension two ways, both keeping DAWN's matrix-operation character:

1. ``minplus_sssp``  — (min,+) edge-parallel relaxation sweeps (tropical
   semiring analogue of the boolean sweep): each sweep relaxes every edge
   with scatter-min; Fact 1 generalizes to "no distance improved".  Exact
   for arbitrary non-negative float weights; sweep count ≤ the longest
   shortest path's hop count (Bellman-Ford depth), so the work bound is
   O(hops·m) — the direct generalization of BOVM's O(ε·m).

2. ``bucketed_sssp`` — for small integer weights w ∈ {1..W} (the regime
   of Galil-Margalit-style algorithms the paper cites): expand each
   weight-w edge into w unit hops through (w-1) virtual nodes, then run
   the UNWEIGHTED SOVM sweep machinery unchanged.  This preserves DAWN's
   boolean-sweep inner loop (Thm 3.2 skipping included) at the cost of
   O(W·m) virtual edges — the matrix-op/(min,+) trade the paper
   anticipates, made explicit.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph
from .sovm import sovm_sssp

INF = jnp.float32(jnp.inf)


class WeightedResult(NamedTuple):
    dist: jax.Array          # (n,) float32; inf = unreachable
    sweeps: jax.Array


@partial(jax.jit, static_argnames=("max_sweeps",))
def minplus_sssp(g: CSRGraph, weights: jax.Array, source, *,
                 max_sweeps: Optional[int] = None) -> WeightedResult:
    """(min,+) sweep SSSP.  weights (m_pad,) float32 ≥ 0 (padded entries
    ignored via the sentinel row)."""
    n = g.n_nodes
    max_sweeps = n if max_sweeps is None else max_sweeps
    src_id = jnp.asarray(source, jnp.int32)
    dist0 = jnp.full(n + 1, INF).at[src_id].set(0.0)

    w = jnp.where(g.src < n, weights, INF)

    def cond(c):
        _, sweeps, done = c
        return (~done) & (sweeps < max_sweeps)

    def body(c):
        dist, sweeps, _ = c
        cand = dist[g.src] + w                     # (m_pad,)
        new = dist.at[g.dst].min(cand)
        improved = jnp.any(new < dist)
        return new, sweeps + 1, ~improved

    dist, sweeps, _ = jax.lax.while_loop(
        cond, body, (dist0, jnp.int32(0), jnp.bool_(False)))
    return WeightedResult(dist[:n], sweeps - 1)


def expand_integer_weights(g: CSRGraph, weights: np.ndarray) -> CSRGraph:
    """Unit-hop expansion: a weight-w edge (u→v) becomes a path
    u → x₁ → … → x_{w-1} → v of unit edges (host-side construction)."""
    src, dst = g.edge_arrays_np()
    weights = np.asarray(weights[: g.n_edges], dtype=np.int64)
    assert (weights >= 1).all(), "integer weights must be ≥ 1"
    n = g.n_nodes
    new_src, new_dst = [], []
    next_virtual = n
    for u, v, w in zip(src, dst, weights):
        if w == 1:
            new_src.append(u); new_dst.append(v)
            continue
        chain = [u] + list(range(next_virtual, next_virtual + w - 1)) + [v]
        next_virtual += w - 1
        for a, b in zip(chain[:-1], chain[1:]):
            new_src.append(a); new_dst.append(b)
    return CSRGraph.from_edges(np.asarray(new_src), np.asarray(new_dst),
                               next_virtual, dedup=False)


def bucketed_sssp(g: CSRGraph, weights: np.ndarray, source: int
                  ) -> WeightedResult:
    """Small-integer-weight SSSP through the unweighted SOVM machinery."""
    eg = expand_integer_weights(g, weights)
    st = sovm_sssp(eg, source)
    dist = jnp.where(st.dist[: g.n_nodes] < 0, INF,
                     st.dist[: g.n_nodes].astype(jnp.float32))
    return WeightedResult(dist, st.sweeps)


def dijkstra_oracle(g: CSRGraph, weights: np.ndarray,
                    source: int) -> np.ndarray:
    """scipy Dijkstra reference for tests."""
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csgraph
    src, dst = g.edge_arrays_np()
    mat = sp.csr_matrix((np.asarray(weights[: g.n_edges], np.float64),
                         (src, dst)), shape=(g.n_nodes, g.n_nodes))
    return csgraph.dijkstra(mat, indices=source, directed=True)
