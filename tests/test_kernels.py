"""Pallas kernel validation (interpret=True): shape/dtype sweeps + full
BFS drivers vs the pure-jnp oracle and the queue-BFS reference."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.graph import generators as gen
from repro.core import bfs_queue_numpy, pack_bits
from repro.kernels.bovm import (fused_sweep, packed_pull_sweep, sweep_ref,
                                packed_pull_ref, msbfs_kernel, msbfs_packed,
                                pack_adjacency_pull)


def _random_state(rng, s, n, density=0.05, visited=0.2):
    f = (rng.random((s, n)) < density).astype(np.int8)
    dist = np.where(rng.random((s, n)) < visited, 1, -1).astype(np.int32)
    return jnp.asarray(f), jnp.asarray(dist)


@pytest.mark.parametrize("s,n,bs,bn,bk", [
    (64, 256, 64, 128, 128),
    (128, 512, 128, 128, 256),
    (8, 128, 8, 128, 128),
    (256, 384, 64, 128, 128),
])
def test_fused_sweep_shapes(s, n, bs, bn, bk):
    rng = np.random.default_rng(s * n)
    g = gen.erdos_renyi(n, 4.0, seed=n, directed=False)
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    f, dist = _random_state(rng, s, n)
    new_k, dist_k = fused_sweep(f, adj, dist, 5, bs=bs, bn=bn, bk=bk,
                                interpret=True)
    new_r, dist_r = sweep_ref(f, adj, dist, 5)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


@pytest.mark.parametrize("s,n,bs,bn,wk", [
    (8, 256, 8, 128, 8),
    (16, 512, 8, 128, 16),
    (32, 128, 16, 128, 4),
])
def test_packed_pull_shapes(s, n, bs, bn, wk):
    rng = np.random.default_rng(s + n)
    g = gen.erdos_renyi(n, 5.0, seed=n + 1, directed=True)
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    ap = pack_adjacency_pull(adj)
    f, dist = _random_state(rng, s, n)
    fp = pack_bits(f > 0)
    new_k, dist_k = packed_pull_sweep(fp, ap, dist, 3, bs=bs, bn=bn, wk=wk,
                                      interpret=True)
    new_r, dist_r = packed_pull_ref(fp, ap, dist, 3)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), density=st.floats(0.0, 0.3),
       visited=st.floats(0.0, 1.0))
def test_fused_sweep_property(seed, density, visited):
    """Property: kernel == oracle for arbitrary frontier/visited states."""
    rng = np.random.default_rng(seed)
    n, s = 256, 64
    adj = jnp.asarray((rng.random((n, n)) < 0.02).astype(np.int8))
    f = jnp.asarray((rng.random((s, n)) < density).astype(np.int8))
    dist = jnp.asarray(
        np.where(rng.random((s, n)) < visited, 2, -1).astype(np.int32))
    new_k, dist_k = fused_sweep(f, adj, dist, 7, bs=64, bn=128, bk=128,
                                interpret=True)
    new_r, dist_r = sweep_ref(f, adj, dist, 7)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


def test_msbfs_kernel_end_to_end():
    g = gen.rmat(8, 5, directed=False, seed=21)
    n = 256
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    srcs = jnp.arange(64, dtype=jnp.int32)
    res = msbfs_kernel(adj, srcs, max_steps=n, interpret=True,
                       bs=64, bn=128, bk=128)
    refs = np.stack([bfs_queue_numpy(g, int(x)) for x in np.asarray(srcs)])
    np.testing.assert_array_equal(
        np.asarray(res.dist)[:, :g.n_nodes], refs)


def test_msbfs_packed_end_to_end():
    g = gen.rmat(8, 5, directed=True, seed=22)
    n = 256
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    ap = pack_adjacency_pull(adj)
    srcs = jnp.arange(16, dtype=jnp.int32)
    res = msbfs_packed(ap, srcs, n, max_steps=n, interpret=True,
                       bs=8, bn=128, wk=8)
    refs = np.stack([bfs_queue_numpy(g, int(x)) for x in np.asarray(srcs)])
    np.testing.assert_array_equal(
        np.asarray(res.dist)[:, :g.n_nodes], refs)


def test_tile_skip_preserves_semantics():
    """All-visited output tiles and empty frontier tiles must not change
    results (the Thm 3.2 tile-skip)."""
    rng = np.random.default_rng(0)
    n, s = 256, 64
    adj = jnp.asarray((rng.random((n, n)) < 0.05).astype(np.int8))
    f = np.zeros((s, n), np.int8)
    f[:, :128] = (rng.random((s, 128)) < 0.1)   # half the k-tiles empty
    dist = np.full((s, n), -1, np.int32)
    dist[:, 128:] = 3                            # half the out-tiles visited
    new_k, dist_k = fused_sweep(jnp.asarray(f), adj, jnp.asarray(dist), 4,
                                bs=64, bn=128, bk=128, interpret=True)
    new_r, dist_r = sweep_ref(jnp.asarray(f), adj, jnp.asarray(dist), 4)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))
