"""Landmark distance oracle: selection determinism, bound soundness,
certificates, top-k certification, and bit-identity of every certified
answer against the queue-BFS oracle — including the adversarial edge-list
families."""
import numpy as np
import pytest

from oracles import adversarial_families, bfs_dist

from repro.core.engine import prepare_graph
from repro.graph import generators as gen
from repro.graph.csr import CSRGraph
from repro.graph.landmarks import (degree_landmarks, farthest_point_fill,
                                   select_landmarks)
from repro.serve import DistanceOracle, build_landmark_labels, select_top_k


def _bfs_fn(g):
    return lambda v: bfs_dist(g, int(v))


# -- landmark selection ----------------------------------------------------

def test_degree_landmarks_deterministic_and_sorted():
    g = gen.barabasi_albert(200, 3, seed=7)
    a = degree_landmarks(g, 8)
    b = degree_landmarks(g, 8)
    np.testing.assert_array_equal(a, b)
    assert len(np.unique(a)) == 8
    # top-degree vertices really are the highest-degree ones
    deg = np.diff(np.asarray(g.indptr)[:g.n_nodes + 1]) + \
        np.diff(np.asarray(g.indptr_t)[:g.n_nodes + 1])
    cutoff = np.sort(deg)[::-1][7]
    assert all(deg[v] >= cutoff for v in a)


def test_farthest_point_fill_spreads_over_components():
    # two disjoint paths: greedy k-center must pick from both components
    src = np.r_[np.arange(9), 10 + np.arange(9)]
    dst = np.r_[np.arange(1, 10), 11 + np.arange(9)]
    g = CSRGraph.from_edges(np.r_[src, dst], np.r_[dst, src], 20)
    marks = farthest_point_fill(g, [0], 3, _bfs_fn(g))
    comp = {int(v) // 10 for v in marks}
    assert comp == {0, 1}


def test_select_landmarks_strategies():
    g = gen.watts_strogatz(128, 6, 0.1, seed=1)
    for strategy in ("degree", "farthest", "mixed"):
        marks = select_landmarks(g, 8, strategy=strategy,
                                 dist_fn=_bfs_fn(g))
        assert len(marks) == 8 == len(np.unique(marks))
        np.testing.assert_array_equal(marks, np.sort(marks))
    with pytest.raises(ValueError, match="strategy"):
        select_landmarks(g, 8, strategy="random", dist_fn=_bfs_fn(g))


# -- label build / caching -------------------------------------------------

def test_build_landmark_labels_cached_on_prepared_graph():
    pg = prepare_graph(gen.grid2d(8, 8))
    m1 = build_landmark_labels(pg, n_landmarks=4)
    t1 = pg.landmark_dist
    m2 = build_landmark_labels(pg, n_landmarks=4)
    assert m2 is m1 and pg.landmark_dist is t1     # reused, not rebuilt
    build_landmark_labels(pg, n_landmarks=6)       # new key -> rebuild
    assert pg.landmark_dist is not t1
    assert pg.landmark_key == (6, "mixed")


def test_labels_match_bfs_and_symmetric_graph_shares_reverse_table():
    pg = prepare_graph(gen.watts_strogatz(96, 4, 0.2, seed=9))
    build_landmark_labels(pg, n_landmarks=4)
    assert pg.landmark_dist_rev is pg.landmark_dist    # symmetric: shared
    for i, L in enumerate(pg.landmarks):
        np.testing.assert_array_equal(pg.landmark_dist[i],
                                      bfs_dist(pg.graph, int(L)))


def test_directed_graph_builds_reverse_table():
    g = gen.rmat(7, 8, directed=True, seed=4)
    oc = DistanceOracle(g, n_landmarks=4)
    pg = oc.prepared
    assert pg.landmark_dist_rev is not pg.landmark_dist
    grev = g.reverse()
    for i, L in enumerate(pg.landmarks):
        np.testing.assert_array_equal(pg.landmark_dist_rev[i],
                                      bfs_dist(grev, int(L)))


def test_labels_checksum_deterministic():
    a = DistanceOracle(gen.grid2d(8, 8), n_landmarks=4).labels_checksum()
    b = DistanceOracle(gen.grid2d(8, 8), n_landmarks=4).labels_checksum()
    assert a == b and isinstance(a, int)


# -- point-to-point bounds -------------------------------------------------

def _check_pairs(g, oracle, pairs):
    """Soundness on every pair; exactness wherever certified."""
    certified = 0
    rows = {}
    for s, t in pairs:
        if s not in rows:
            rows[s] = bfs_dist(g, s)
        d = int(rows[s][t])
        ans = oracle.query(s, t)
        true = np.inf if d < 0 else float(d)
        assert ans.lower <= true <= ans.upper, \
            (s, t, ans.lower, true, ans.upper)
        if ans.exact:
            certified += 1
            assert ans.hops == d, (s, t, ans.certificate)
            assert ans.certificate in ("trivial", "landmark-source",
                                       "landmark-target", "bounds")
    return certified


@pytest.mark.parametrize("make", [
    lambda: gen.grid2d(12, 12),
    lambda: gen.watts_strogatz(144, 6, 0.1, seed=2),
    lambda: gen.rmat(7, 8, directed=True, seed=3),
], ids=["grid", "ws", "rmat_directed"])
def test_bounds_sound_and_certified_answers_exact(make):
    g = make()
    oracle = DistanceOracle(g, n_landmarks=8)
    rng = np.random.default_rng(0)
    pairs = [(int(s), int(t)) for s, t in
             rng.integers(0, g.n_nodes, size=(120, 2))]
    # landmark hits and the trivial certificate, explicitly
    pairs += [(int(oracle.landmarks[0]), 5), (5, int(oracle.landmarks[1])),
              (7, 7)]
    certified = _check_pairs(g, oracle, pairs)
    assert certified >= 3               # at least the explicit hits
    assert oracle.n_certified >= certified


def test_unreachability_certified_via_inf_bounds():
    # two components: landmark in component A proves B unreachable
    src = np.r_[np.arange(4), 6 + np.arange(3)]
    dst = np.r_[np.arange(1, 5), 7 + np.arange(3)]
    g = CSRGraph.from_edges(np.r_[src, dst], np.r_[dst, src], 10)
    oracle = DistanceOracle(g, n_landmarks=4)
    ans = oracle.query(0, 9)
    if ans.exact:                       # certified unreachable
        assert ans.hops == -1 and np.isinf(ans.upper)
    assert bfs_dist(g, 0)[9] == -1      # the ground truth it must match


def test_adversarial_families_certified_bit_identity():
    for name, src, dst, n in adversarial_families(seed=123):
        g = CSRGraph.from_edges(src, dst, n)
        k = min(4, n)
        oracle = DistanceOracle(g, n_landmarks=k)
        rng = np.random.default_rng(1)
        pairs = [(int(s), int(t)) for s, t in
                 rng.integers(0, n, size=(40, 2))]
        pairs += [(int(L), (int(L) + 1) % n) for L in oracle.landmarks]
        _check_pairs(g, oracle, pairs)


# -- top-k ------------------------------------------------------------------

def test_select_top_k_rule():
    row = np.asarray([0, 2, 1, 2, -1, 1], np.int32)
    assert select_top_k(row, 0, 3) == [(2, 1), (5, 1), (1, 2)]
    assert select_top_k(row, 0, 10) == [(2, 1), (5, 1), (1, 2), (3, 2)]
    # source itself excluded; other zero-distance entries still rank first
    assert select_top_k(row, 2, 1) == [(0, 0)]


def test_top_k_certified_matches_exact_selection():
    g = gen.watts_strogatz(128, 6, 0.1, seed=8)
    oracle = DistanceOracle(g, n_landmarks=8)
    hits = 0
    for s in range(0, 128, 7):
        got = oracle.top_k(s, 5)
        if got is None:
            continue
        hits += 1
        assert got == select_top_k(bfs_dist(g, s), s, 5)
    # every landmark source must certify (its row is exact)
    for L in oracle.landmarks:
        got = oracle.top_k(int(L), 5)
        assert got == select_top_k(bfs_dist(g, int(L)), int(L), 5)


def test_predicted_sweeps_upper_bounds_true_eccentricity():
    g = gen.grid2d(10, 10)
    oracle = DistanceOracle(g, n_landmarks=4)
    for s in range(0, 100, 11):
        true_ecc = int(bfs_dist(g, s).max())
        assert oracle.predicted_sweeps(s) >= true_ecc
