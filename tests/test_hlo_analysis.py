"""HLO analyzer fixtures: exact flop counting through scans + grads."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze


def _scan_fn(length):
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=length)
        return h
    return f


X = jax.ShapeDtypeStruct((128, 128), jnp.float32)
W = jax.ShapeDtypeStruct((128, 128), jnp.float32)
DOT = 2 * 128 ** 3


def test_scan_flops_exact():
    st = analyze(jax.jit(_scan_fn(8)).lower(X, W).compile().as_text())
    assert st.flops == DOT * 8
    assert 8 in [int(v) for v in st.trip_counts.values()]


def test_nested_scan_flops_exact():
    def f(x, w):
        def outer(h, _):
            def inner(h2, _):
                return h2 @ w, None
            h2, _ = jax.lax.scan(inner, h, None, length=4)
            return h2, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h
    st = analyze(jax.jit(f).lower(X, W).compile().as_text())
    assert st.flops == DOT * 12


def test_grad_of_scan_flops():
    def loss(x, w):
        return jnp.sum(_scan_fn(8)(x, w))
    st = analyze(jax.jit(jax.grad(loss, argnums=(0, 1))
                         ).lower(X, W).compile().as_text())
    assert st.flops == DOT * 8 * 3  # fwd + dx + dw


def test_bytes_scale_with_trip_count():
    st8 = analyze(jax.jit(_scan_fn(8)).lower(X, W).compile().as_text())
    st2 = analyze(jax.jit(_scan_fn(2)).lower(X, W).compile().as_text())
    assert st8.bytes_accessed > 2.5 * st2.bytes_accessed


def test_cost_analysis_undercounts_loops():
    """Documents WHY the analyzer exists: XLA cost_analysis counts scan
    bodies once."""
    co = jax.jit(_scan_fn(8)).lower(X, W).compile()
    ca = co.cost_analysis()
    if isinstance(ca, list):  # jax < 0.5 returns one dict per computation
        ca = ca[0]
    # one body (± a few scalar ops), not 8×:
    assert ca["flops"] < DOT * 1.01
    assert analyze(co.as_text()).flops == DOT * 8
