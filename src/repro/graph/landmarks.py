"""Landmark selection for the distance-oracle serving tier.

A landmark set is the offline half of a triangle-inequality distance
oracle (serve/oracle.py): the serving tier precomputes one BFS distance
row per landmark with the batched APSP engine — the engine *is* the
preprocessing pass — and answers point-to-point queries from the
``(n_landmarks, n)`` tables in O(|landmarks|).

Selection quality decides how often the bounds close (upper == lower, an
exactness certificate), so the default ``mixed`` strategy combines the
two classic heuristics:

  * **degree** — the highest-degree vertices.  On scale-free graphs most
    shortest paths route through hubs, so hub landmarks sit *on* many
    shortest paths (the bound is tight exactly when a landmark lies on a
    shortest s→t path).
  * **farthest-point** — greedy 2-approximate k-center: repeatedly pick
    the vertex maximizing its distance to the already-chosen set.  This
    spreads landmarks across the graph (and across disconnected
    components — unreached vertices have infinite distance and are
    picked first), covering the periphery hubs miss.

``mixed`` seeds the set with the top ``k // 2`` hubs and fills the rest
by farthest-point.  Everything here is host-side numpy and deterministic
(ties break on vertex id); the distance rows the greedy needs are
injected via ``dist_fn`` so this module stays engine-agnostic (the
serving tier passes a batched-engine-backed callable).
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from .csr import CSRGraph

STRATEGIES = ("degree", "farthest", "mixed")


def degree_landmarks(g: CSRGraph, k: int) -> np.ndarray:
    """Top-k vertices by total (out + in) degree, ties on vertex id."""
    deg = np.asarray(g.out_degrees()) + np.asarray(g.in_degrees())
    # stable sort on (-degree, id): argsort of -deg is id-stable
    order = np.argsort(-deg, kind="stable")
    return order[:k].astype(np.int32)


def farthest_point_fill(g: CSRGraph, chosen: np.ndarray, k: int,
                        dist_fn: Callable[[int], np.ndarray]) -> np.ndarray:
    """Grow ``chosen`` to ``k`` landmarks by greedy farthest-point.

    ``dist_fn(v)`` returns the (n,) int32 BFS row from ``v`` (-1 =
    unreachable).  Unreachable counts as infinitely far, so new weakly
    connected components are covered before refining known ones.  Starts
    from the highest-degree vertex when ``chosen`` is empty.
    """
    n = g.n_nodes
    chosen = list(np.asarray(chosen, np.int64))
    if not chosen:
        chosen.append(int(degree_landmarks(g, 1)[0]))
    mindist = np.full(n, np.inf)
    for c in chosen:
        row = np.asarray(dist_fn(int(c)), np.float64)
        row[row < 0] = np.inf
        np.minimum(mindist, row, out=mindist)
    taken = np.zeros(n, bool)
    taken[np.asarray(chosen, np.int64)] = True
    while len(chosen) < min(k, n):
        cand = np.where(taken, -np.inf, mindist)
        # argmax breaks ties on the lowest vertex id (deterministic)
        v = int(np.argmax(cand))
        chosen.append(v)
        taken[v] = True
        row = np.asarray(dist_fn(v), np.float64)
        row[row < 0] = np.inf
        np.minimum(mindist, row, out=mindist)
    return np.asarray(chosen, np.int32)


def select_landmarks(g: CSRGraph, k: int, *, strategy: str = "mixed",
                     dist_fn: Optional[Callable[[int], np.ndarray]] = None
                     ) -> np.ndarray:
    """Pick ``min(k, n)`` landmark vertex ids (sorted, unique).

    ``dist_fn`` (BFS row provider) is required for the ``farthest`` and
    ``mixed`` strategies; ``degree`` needs none.  The returned ids are
    sorted so the label-table layout is canonical regardless of the
    greedy's pick order.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown landmark strategy {strategy!r}; "
                         f"available: {STRATEGIES}")
    k = min(k, g.n_nodes)
    if k <= 0:
        return np.zeros(0, np.int32)
    if strategy == "degree":
        marks = degree_landmarks(g, k)
    else:
        if dist_fn is None:
            raise ValueError(f"strategy {strategy!r} needs dist_fn= "
                             f"(a BFS-row provider)")
        seed = degree_landmarks(g, k // 2) if strategy == "mixed" else \
            np.zeros(0, np.int32)
        marks = farthest_point_fill(g, seed, k, dist_fn)
    return np.sort(np.unique(marks)).astype(np.int32)
