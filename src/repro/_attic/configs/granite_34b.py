"""granite-34b — dense LM, llama-arch code model, MQA (kv=1).
[arXiv:2405.04324; hf]  88L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152."""
from ..models.transformer import LMConfig

CONFIG = LMConfig(
    name="granite-34b", n_layers=88, d_model=6144, n_heads=48, n_kv=1,
    d_head=128, d_ff=24576, vocab=49152, act="gelu")
