"""Vectorized graph IO: whitespace edge lists and MatrixMarket files.

Loaders parse in chunks of ~1M lines through ``np.loadtxt`` (C tokenizer,
no per-line Python ``int()`` loop) so multi-GB edge lists stream without
holding a Python object per edge.  Weighted formats map straight onto the
tropical engine's lane layout: ``load_edgelist(..., weighted=True)`` and
MatrixMarket ``real``/``integer`` coordinate files return
``(CSRGraph, lane_weights)`` where ``lane_weights`` is (m_pad,) float32
(+inf padded slots) — exactly what ``prepare_weighted`` /
``prepare_sharded`` consume (duplicate edges min-reduce, matching the
dense operand).
"""
from __future__ import annotations

import itertools
from typing import Optional, Tuple, Union

import numpy as np

from .csr import CSRGraph, symmetrize

_CHUNK_LINES = 1 << 20


def _loadtxt_chunked(f, *, usecols, chunk_lines: int = _CHUNK_LINES
                     ) -> np.ndarray:
    """np.loadtxt over an open text file in bounded-size line chunks
    (comment lines beginning '#'/'%' are skipped by the C tokenizer)."""
    blocks = []
    while True:
        lines = list(itertools.islice(f, chunk_lines))
        if not lines:
            break
        arr = np.loadtxt(lines, comments=("#", "%"), usecols=usecols,
                         dtype=np.float64, ndmin=2)
        if arr.size:
            blocks.append(arr)
    if not blocks:
        return np.zeros((0, len(usecols)), np.float64)
    return np.concatenate(blocks, axis=0)


def load_edgelist(path: str, *, undirected: bool = False,
                  zero_indexed: bool = True, weighted: bool = False
                  ) -> Union[CSRGraph, Tuple[CSRGraph, np.ndarray]]:
    """Whitespace edge list -> CSRGraph (or (CSRGraph, lane_weights)
    with ``weighted=True``, reading the third column).  Lines starting
    with '#' or '%' are comments; extra columns are ignored."""
    usecols = (0, 1, 2) if weighted else (0, 1)
    with open(path) as f:
        data = _loadtxt_chunked(f, usecols=usecols)
    src = data[:, 0].astype(np.int64)
    dst = data[:, 1].astype(np.int64)
    w = data[:, 2] if weighted else None
    if not zero_indexed:
        src -= 1
        dst -= 1
    n = int(max(src.max(), dst.max())) + 1 if len(src) else 1
    if undirected:
        src, dst = symmetrize(src, dst)
        if weighted:
            w = np.concatenate([w, w])
    if weighted:
        return CSRGraph.from_weighted_edges(src, dst, w, n)
    return CSRGraph.from_edges(src, dst, n)


def load_mtx(path: str, *, return_weights: bool = False
             ) -> Union[CSRGraph, Tuple[CSRGraph, np.ndarray]]:
    """MatrixMarket coordinate pattern/real/integer square matrices as
    graphs.  ``return_weights=True`` additionally returns the (m_pad,)
    float32 lane weights — the matrix values for ``real``/``integer``
    fields, all-ones for ``pattern`` — aligned with the graph's padded
    CSR lanes."""
    with open(path) as f:
        header = f.readline().lower()
        symmetric = "symmetric" in header
        has_values = ("real" in header) or ("integer" in header)
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        n_rows, n_cols, _ = (int(x) for x in line.split()[:3])
        usecols = (0, 1, 2) if (has_values and return_weights) else (0, 1)
        data = _loadtxt_chunked(f, usecols=usecols)
    src = data[:, 0].astype(np.int64) - 1
    dst = data[:, 1].astype(np.int64) - 1
    n = max(n_rows, n_cols)
    if return_weights:
        w = data[:, 2] if has_values else np.ones(len(src), np.float64)
        if symmetric:
            src, dst = symmetrize(src, dst)
            w = np.concatenate([w, w])
        return CSRGraph.from_weighted_edges(src, dst, w, n)
    if symmetric:
        src, dst = symmetrize(src, dst)
    return CSRGraph.from_edges(src, dst, n)


def save_edgelist(g: CSRGraph, path: str, *,
                  weights: Optional[np.ndarray] = None) -> None:
    """Vectorized writer (np.savetxt).  ``weights`` may cover the padded
    lanes (only the first ``n_edges`` are written, as a third column)."""
    src, dst = g.edge_arrays_np()
    header = f"nodes={g.n_nodes} edges={g.n_edges}"
    if weights is None:
        np.savetxt(path, np.stack([src, dst], axis=1), fmt="%d",
                   header=header)
    else:
        w = np.asarray(weights, np.float64)[: g.n_edges]
        np.savetxt(path, np.stack([src, dst, w], axis=1),
                   fmt=("%d", "%d", "%.9g"), header=header)


def save_mtx(g: CSRGraph, path: str, *,
             weights: Optional[np.ndarray] = None) -> None:
    """MatrixMarket coordinate writer (general symmetry; ``weights``
    switches the field from ``pattern`` to ``real``)."""
    src, dst = g.edge_arrays_np()
    field = "pattern" if weights is None else "real"
    with open(path, "w") as f:
        f.write(f"%%MatrixMarket matrix coordinate {field} general\n")
        f.write(f"{g.n_nodes} {g.n_nodes} {g.n_edges}\n")
        if weights is None:
            np.savetxt(f, np.stack([src + 1, dst + 1], axis=1), fmt="%d")
        else:
            w = np.asarray(weights, np.float64)[: g.n_edges]
            np.savetxt(f, np.stack([src + 1, dst + 1, w], axis=1),
                       fmt=("%d", "%d", "%.9g"))
