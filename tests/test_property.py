"""Hypothesis property tests on system invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.graph.csr import CSRGraph
from repro.core import (sovm_sssp, bovm_sssp, bfs_queue_numpy, pack_bits,
                        unpack_bits, popcount)
from repro.models.recsys import embedding_bag, embedding_bag_ragged


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 120), avg_deg=st.floats(0.5, 6.0),
       seed=st.integers(0, 10**6), directed=st.booleans(),
       source=st.integers(0, 10**6))
def test_dawn_equals_bfs_on_random_graphs(n, avg_deg, seed, directed,
                                          source):
    rng = np.random.default_rng(seed)
    m = max(1, int(n * avg_deg))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    if not directed:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    g = CSRGraph.from_edges(src, dst, n)
    s = source % n
    ref = bfs_queue_numpy(g, s)
    np.testing.assert_array_equal(np.asarray(sovm_sssp(g, s).dist), ref)
    np.testing.assert_array_equal(
        np.asarray(bovm_sssp(g.to_dense(), s).dist), ref)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 200), seed=st.integers(0, 10**6))
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.random((3, n)) < 0.5
    packed = pack_bits(jnp.asarray(x))
    back = np.asarray(unpack_bits(packed, n))
    np.testing.assert_array_equal(back, x)
    np.testing.assert_array_equal(np.asarray(popcount(packed)),
                                  x.sum(axis=1))


@settings(max_examples=20, deadline=None)
@given(v=st.integers(2, 50), d=st.integers(1, 16),
       bags=st.integers(1, 8), maxlen=st.integers(1, 6),
       seed=st.integers(0, 10**6), mode=st.sampled_from(["sum", "mean"]))
def test_embedding_bag_ragged_equals_fixed(v, d, bags, maxlen, seed, mode):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    lens = rng.integers(0, maxlen + 1, bags)
    idx_fixed = np.full((bags, maxlen), -1, np.int64)
    flat, seg = [], []
    for b in range(bags):
        ids = rng.integers(0, v, lens[b])
        idx_fixed[b, :lens[b]] = ids
        flat.extend(ids)
        seg.extend([b] * lens[b])
    fixed = embedding_bag(table, jnp.asarray(idx_fixed), mode=mode)
    if flat:
        ragged = embedding_bag_ragged(
            table, jnp.asarray(np.array(flat)),
            jnp.asarray(np.array(seg)), bags, mode=mode)
        np.testing.assert_allclose(np.asarray(fixed), np.asarray(ragged),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_triangle_inequality(seed):
    """Shortest-path distances satisfy d(s,v) <= d(s,u) + 1 per edge."""
    rng = np.random.default_rng(seed)
    n = 80
    m = 240
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    g = CSRGraph.from_edges(src, dst, n)
    dist = np.asarray(sovm_sssp(g, 0).dist)
    s_np, d_np = g.edge_arrays_np()
    for a, b in zip(s_np, d_np):
        if dist[a] >= 0:
            assert dist[b] >= 0 and dist[b] <= dist[a] + 1
