"""SOVM — Sparse Optimized boolean Vector-Matrix operation (paper Alg. 2).

The paper merges CSR rows of the frontier nodes (Eq. 9: the sweep result is
the union of the frontier rows), skipping targets already in the result
vector.  The TPU-native fixed-shape equivalent is edge-parallel masked
propagation with scatter-max:

    active[e] = frontier[src[e]]                       # gather
    hits      = scatter_or(active -> dst)              # Eq. 9 union
    new       = hits & (dist == UNREACHED)             # Thm 3.2 skip
    dist      = where(new, step, dist)

Padded edges carry src = dst = n (sentinel): ``frontier[n]`` is pinned
False and ``dist[n]`` is pinned 0 (visited), so padding is inert without
masks.

This module is the boolean-semiring SPARSE instantiation of the shared
sweep layer (core/sweep.py): ``sovm_sssp`` pins the sparse form — with
in-loop parent tracking — into the one ``sweep_loop`` driver.  Work
accounting: the true SOVM work per sweep is sum(out_degree[frontier])
(Eq. 10 → total = E_wcc(i)); the driver tracks it exactly in
``edges_touched`` so the complexity claims are empirically checkable even
though the fixed-shape scatter touches all m lanes.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..graph.csr import CSRGraph
from . import sweep as S
from .frontier import UNREACHED


class SovmState(NamedTuple):
    frontier: jax.Array        # (n,) int8
    dist: jax.Array            # (n,) int32
    parent: jax.Array          # (n,) int32 — path reconstruction
    step: jax.Array
    done: jax.Array
    edges_touched: jax.Array   # float32 scalar — Eq. 10 counter
    sweeps: jax.Array          # int32 — equals ε(i) at exit


def sovm_sweep(g: CSRGraph, frontier: jax.Array, dist: jax.Array):
    """One frontier expansion. Returns (new_frontier, parent_candidates)."""
    n = g.n_nodes
    active = frontier[g.src] != 0                             # (m_pad,)
    hits = jnp.zeros(n + 1, jnp.bool_).at[g.dst].max(active)  # scatter-OR
    new = hits & (dist == UNREACHED)
    # parent: any active in-neighbor (max src id wins — deterministic)
    pcand = jnp.full(n + 1, -1, jnp.int32).at[g.dst].max(
        jnp.where(active, g.src, -1))
    return new, pcand


@partial(jax.jit, static_argnames=("max_steps",))
def sovm_sssp(g: CSRGraph, source, *,
              max_steps: Optional[int] = None) -> SovmState:
    """DAWN-SOVM single-source shortest paths.  O(E_wcc(i)) useful work."""
    n = g.n_nodes
    max_steps = n if max_steps is None else max_steps
    src = jnp.asarray(source, jnp.int32)

    frontier0 = jnp.zeros(n + 1, jnp.int8).at[src].set(1)
    dist0 = jnp.full(n + 1, UNREACHED).at[src].set(0).at[n].set(0)
    parent0 = jnp.full(n + 1, -1, jnp.int32)
    deg = jnp.concatenate([g.out_degrees().astype(jnp.float32),
                           jnp.zeros(1, jnp.float32)])

    _, _, sparse = S.boolean_forms(
        jnp.zeros((1, 1), jnp.int8), jnp.zeros((1, 1), jnp.uint32),
        g.src, g.dst, n_pad=n + 1, s=1, track_parent=True)

    st = S.sweep_loop((sparse,), S.make_state(frontier0, dist0, parent0,
                                              n_forms=1),
                      max_steps=max_steps, deg=deg, forced_dir=0)
    # drop sentinel row
    return SovmState(st.frontier[:n], st.dist[:n], st.parent[:n],
                     st.step, st.done, st.edges_touched, st.sweeps)


@partial(jax.jit, static_argnames=("max_steps",))
def sovm_msbfs(g: CSRGraph, sources: jax.Array, *,
               max_steps: Optional[int] = None) -> SovmState:
    """Multi-source SOVM via vmap over sources (S small) — the sparse-graph
    analogue of bovm_msbfs.  For large S on dense graphs prefer the BOVM
    matmul path."""
    run = jax.vmap(lambda s: sovm_sssp(g, s, max_steps=max_steps))
    return run(jnp.asarray(sources, jnp.int32))


def reconstruct_path(parent, source: int, target: int, max_len: int):
    """Host-side path reconstruction from the parent array."""
    import numpy as np
    parent = np.asarray(parent)
    path = [target]
    cur = target
    for _ in range(max_len):
        if cur == source:
            break
        cur = int(parent[cur])
        if cur < 0:
            return None
        path.append(cur)
    return path[::-1] if path[-1] is not None and path[0] == source else path[::-1]
