"""Distribution tests on virtual devices (subprocess: jax must initialize
with --xla_force_host_platform_device_count before first use)."""
import subprocess
import sys
import textwrap

import pytest


def _run(body: str, devices: int = 8):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \\
            "--xla_force_host_platform_device_count={devices}"
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]
    return res.stdout


@pytest.mark.slow
def test_sharded_apsp_boolean_bit_identical_to_single_device():
    """Acceptance: sharded boolean APSP on an 8-virtual-device CPU mesh
    returns bit-identical distances AND sweep counts vs the single-device
    engine, across source-only and source×vertex meshes and all three
    sweep modes — and matches the independent queue-BFS oracle."""
    out = _run("""
        import sys; sys.path.insert(0, "tests")
        import numpy as np, jax
        from oracles import bfs_dists
        from repro.graph import generators as gen
        from repro.core import (EngineConfig, ShardedConfig, apsp_engine,
                                sharded_apsp)
        from repro.launch.mesh import make_mesh
        g = gen.rmat(9, 6, directed=False, seed=5)       # n = 512
        sources = np.arange(24, dtype=np.int32)
        single = apsp_engine(g, sources,
                             config=EngineConfig(mode="push",
                                                 source_batch=24))
        np.testing.assert_array_equal(np.asarray(single.dist),
                                      bfs_dists(g, sources))
        for shape, axes in [((8,), ("data",)),
                            ((2, 4), ("data", "model")),
                            ((4, 2), ("data", "model"))]:
            mesh = make_mesh(shape, axes)
            for mode in ("dense", "sparse", "auto"):
                res = sharded_apsp(g, sources, mesh=mesh,
                                   config=ShardedConfig(mode=mode))
                np.testing.assert_array_equal(np.asarray(res.dist),
                                              np.asarray(single.dist))
                assert int(res.sweeps) == int(single.sweeps), (shape, mode)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_apsp_tropical_bit_identical_to_single_device():
    """Same acceptance for the tropical semiring: (min,+) APSP sharded
    over sources and vertices is bit-identical (f32 min is exact) to
    weighted_apsp and allclose to scipy Dijkstra."""
    out = _run("""
        import sys; sys.path.insert(0, "tests")
        import numpy as np, jax
        from oracles import dijkstra_dists
        from repro.graph import generators as gen
        from repro.core import (ShardedConfig, WeightedConfig,
                                sharded_apsp, weighted_apsp)
        from repro.launch.mesh import make_mesh
        g = gen.rmat(9, 6, directed=False, seed=5)
        w = np.random.default_rng(0).uniform(0.5, 4.0, g.m_pad).astype(
            np.float32)
        sources = np.arange(24, dtype=np.int32)
        single = weighted_apsp(g, w, sources,
                               config=WeightedConfig(mode="dense",
                                                     source_batch=24))
        np.testing.assert_allclose(np.asarray(single.dist),
                                   dijkstra_dists(g, w, sources),
                                   rtol=1e-5)
        for shape, axes in [((8,), ("data",)),
                            ((2, 4), ("data", "model"))]:
            mesh = make_mesh(shape, axes)
            for mode in ("dense", "sparse", "auto"):
                res = sharded_apsp(g, sources, mesh=mesh, weights=w,
                                   config=ShardedConfig(
                                       semiring="tropical", mode=mode))
                np.testing.assert_array_equal(np.asarray(res.dist),
                                              np.asarray(single.dist))
                assert int(res.sweeps) == int(single.sweeps), (shape, mode)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_apsp_non_divisible_padding():
    """n=237 doesn't divide the 4-way vertex shard and S=13 doesn't
    divide the 2-way source shard: the executor's padding must keep both
    semirings bit-identical to the single-device engines."""
    out = _run("""
        import sys; sys.path.insert(0, "tests")
        import numpy as np, jax
        from repro.graph import generators as gen
        from repro.core import (EngineConfig, ShardedConfig,
                                WeightedConfig, apsp_engine, sharded_apsp,
                                weighted_apsp)
        from repro.launch.mesh import make_mesh
        g = gen.erdos_renyi(237, 3.0, seed=9)
        sources = np.arange(13, dtype=np.int32)
        mesh = make_mesh((2, 4), ("data", "model"))
        single = apsp_engine(g, sources,
                             config=EngineConfig(mode="sparse",
                                                 source_batch=16))
        for mode in ("dense", "sparse"):
            res = sharded_apsp(g, sources, mesh=mesh,
                               config=ShardedConfig(mode=mode))
            np.testing.assert_array_equal(np.asarray(res.dist),
                                          np.asarray(single.dist))
            assert int(res.sweeps) == int(single.sweeps), mode
        w = np.random.default_rng(1).uniform(0.1, 5.0, g.m_pad).astype(
            np.float32)
        wsingle = weighted_apsp(g, w, sources,
                                config=WeightedConfig(mode="sparse",
                                                      source_batch=16))
        for mode in ("dense", "sparse"):
            res = sharded_apsp(g, sources, mesh=mesh, weights=w,
                               config=ShardedConfig(semiring="tropical",
                                                    mode=mode))
            np.testing.assert_array_equal(np.asarray(res.dist),
                                          np.asarray(wsingle.dist))
            assert int(res.sweeps) == int(wsingle.sweeps), mode
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_counting_bit_identical_and_betweenness_matches_oracle():
    """Acceptance: the counting semiring (non-idempotent ⊕ — sigma
    partials combine with the masked-add psum) is bit-identical to the
    single-device counting engine on an 8-virtual-device mesh across
    source-only and source×vertex shardings and all modes, including
    the rectangular kernel path — and the mesh-routed betweenness
    matches the independent NumPy Brandes oracle."""
    out = _run("""
        import sys; sys.path.insert(0, "tests")
        import numpy as np, jax
        from oracles import bfs_sigmas, brandes_betweenness
        from repro.graph import generators as gen
        from repro.core import (CentralityConfig, ShardedConfig,
                                betweenness, counting_apsp, sharded_apsp)
        from repro.launch.mesh import make_mesh
        g = gen.rmat(8, 5, directed=False, seed=5)       # n = 256
        sources = np.arange(24, dtype=np.int32)
        single = counting_apsp(g, sources,
                               config=CentralityConfig(mode="push",
                                                       source_batch=24))
        np.testing.assert_allclose(np.asarray(single.sigma),
                                   bfs_sigmas(g, sources))
        for shape, axes in [((8,), ("data",)),
                            ((2, 4), ("data", "model")),
                            ((4, 2), ("data", "model"))]:
            mesh = make_mesh(shape, axes)
            for mode in ("dense", "sparse", "auto"):
                res = sharded_apsp(g, sources, mesh=mesh,
                                   config=ShardedConfig(
                                       semiring="counting", mode=mode))
                np.testing.assert_array_equal(np.asarray(res.dist),
                                              np.asarray(single.dist))
                np.testing.assert_array_equal(np.asarray(res.sigma),
                                              np.asarray(single.sigma))
                assert int(res.sweeps) == int(single.sweeps), (shape, mode)
        # rectangular counting kernel through the registry (interpret)
        mesh = make_mesh((2, 4), ("data", "model"))
        res = sharded_apsp(g, sources, mesh=mesh,
                           config=ShardedConfig(semiring="counting",
                                                mode="dense",
                                                use_kernel=True))
        np.testing.assert_array_equal(np.asarray(res.sigma),
                                      np.asarray(single.sigma))
        # end-to-end: betweenness through the sharded forward pass
        bc = betweenness(g, mesh=make_mesh((8,), ("data",)))
        np.testing.assert_allclose(bc, brandes_betweenness(g),
                                   rtol=1e-4, atol=1e-6)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_kernel_path_rides_the_executor():
    """use_kernel=True dispatches the rectangular Pallas kernels through
    the registry inside the sharded executor (interpret mode on CPU)."""
    out = _run("""
        import numpy as np, jax
        from repro.graph import generators as gen
        from repro.core import (EngineConfig, ShardedConfig,
                                WeightedConfig, apsp_engine, sharded_apsp,
                                weighted_apsp)
        from repro.launch.mesh import make_mesh
        g = gen.rmat(7, 4, directed=False, seed=3)       # n = 128
        sources = np.arange(8, dtype=np.int32)
        mesh = make_mesh((2, 2), ("data", "model"))
        single = apsp_engine(g, sources,
                             config=EngineConfig(mode="push",
                                                 source_batch=8))
        res = sharded_apsp(g, sources, mesh=mesh,
                           config=ShardedConfig(mode="dense",
                                                use_kernel=True))
        np.testing.assert_array_equal(np.asarray(res.dist),
                                      np.asarray(single.dist))
        assert int(res.sweeps) == int(single.sweeps)
        w = np.random.default_rng(0).uniform(0.5, 4.0, g.m_pad).astype(
            np.float32)
        wsingle = weighted_apsp(g, w, sources,
                                config=WeightedConfig(mode="dense",
                                                      source_batch=8))
        res = sharded_apsp(g, sources, mesh=mesh, weights=w,
                           config=ShardedConfig(semiring="tropical",
                                                mode="dense",
                                                use_kernel=True))
        np.testing.assert_array_equal(np.asarray(res.dist),
                                      np.asarray(wsingle.dist))
        assert int(res.sweeps) == int(wsingle.sweeps)
        print("OK")
    """, devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_lm_train_step_matches_single_device():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro._attic.models import transformer as T
        from repro.train import optimizer as O
        from repro.train.train_loop import make_train_step
        from repro._attic.launch.cells import shardings

        cfg = T.LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                         n_kv=2, d_head=16, d_ff=128, vocab=256,
                         dtype=jnp.float32)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        opt = O.sgd(lr=0.1)
        state = opt.init(params)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 256)
        batch = {"tokens": toks, "labels": toks}
        step = make_train_step(lambda p, b: T.loss_fn(p, b, cfg), opt)

        p1, _, m1 = jax.jit(step)(params, state, batch)

        pspec = T.param_specs(cfg)
        sspec = opt.state_specs(pspec)
        bspec = {"tokens": P("data", None), "labels": P("data", None)}
        from repro import compat
        with compat.set_mesh(mesh):
            jstep = jax.jit(step,
                            in_shardings=shardings(mesh, (pspec, sspec,
                                                          bspec)),
                            out_shardings=shardings(mesh, (pspec, sspec,
                                                           None)))
            p2, _, m2 = jstep(params, state, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_embed_lookup_sharded_equals_local():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro._attic.models.layers import embed_lookup
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        table = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0, 64)
        ref = table[toks]
        from repro import compat
        with compat.set_mesh(mesh):
            t = jax.device_put(table, NamedSharding(mesh, P(None, "model")))
            k = jax.device_put(toks, NamedSharding(mesh, P("data", None)))
            got = jax.jit(lambda a, b: embed_lookup(a, b, jnp.float32))(t, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_compressed_cross_pod_psum():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.train.compression import make_cross_pod_psum
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("pod",))
        psum_c = make_cross_pod_psum("int8")
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 0.1

        def f(v):
            return psum_c(v)

        from repro import compat
        got = compat.shard_map(f, mesh=mesh,
                            in_specs=jax.sharding.PartitionSpec("pod"),
                            out_specs=jax.sharding.PartitionSpec("pod"))(x)
        ref = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 0.01, err
        print("OK")
    """, devices=4)
    assert "OK" in out
