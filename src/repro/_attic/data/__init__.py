from . import recsys
