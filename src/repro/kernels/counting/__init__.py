from .kernel import fused_counting_sweep, fused_counting_multisweep
from .ref import counting_sweep_ref

from .. import common, registry


def vmem_bytes(*, form: str = "push", bs: int = 128, bn: int = 128,
               bk: int = 128, n: int = 1152, **_) -> int:
    """Resident VMEM of one grid step (docs/ARCHITECTURE.md table):
    f32 fsigma tile + int8 adj tile + the (dist i32, sigma f32) state
    pair + f32 acc + (i8, i32, f32) outputs.  ``form="fused"`` prices the
    multi-sweep persistent kernel (whole int8 adjacency resident plus the
    carried pair).  Extra keywords are ignored (uniform autotuner call)."""
    if form == "fused":
        return common.fused_vmem_bytes(
            bs=bs, n=n, operand_bytes=n * n * 1,
            frontier_bytes=bs * n * 1,
            state_itemsizes=(4, 4),        # dist i32 + sigma f32
            out_itemsizes=(1, 4, 4))       # new i8 + dist i32 + sigma f32
    assert form == "push", form
    return common.push_vmem_bytes(bs, bn, bk, f_itemsize=4, a_itemsize=1,
                                  d_itemsize=4 + 4,   # dist i32 + sigma f32
                                  acc_itemsize=4,
                                  out_itemsizes=(1, 4, 4))


registry.register(registry.KernelSet(
    semiring="counting",
    forms={"push": fused_counting_sweep},
    vmem_bytes=vmem_bytes,
    notes="fused f32 counting GEMM sweep (MXU): one matmul of "
          "frontier-masked sigma produces discovery AND exact path "
          "counts; sparse scatter-add stays on the XLA form; the fused "
          "multi-sweep kernel keeps the (dist, sigma) pair resident",
    fused_forms={"push": fused_counting_multisweep},
))
