"""Shared timing/acceptance machinery for the fixed-vs-auto JSON
benchmarks (bench_apsp boolean engine, bench_weighted tropical engine)."""
from __future__ import annotations

import statistics
import time
from typing import Callable, Dict

TOLERANCE = 1.25       # auto vs best fixed: timing-noise allowance (when
                       # auto pins the best direction it runs the *same*
                       # sweeps, so any gap is wall-clock jitter — observed
                       # up to ~20% on shared CI boxes even best-of-10)
BEAT_MARGIN = 1.25     # auto vs worse fixed: require a real win


def time_interleaved_stats(fns: Dict[str, Callable], repeats: int
                           ) -> Dict[str, Dict[str, float]]:
    """Per-mode ``{"best": min, "median": median}`` over ``repeats``
    rounds, modes interleaved within each round so machine-load drift
    hits all modes equally.  ``best`` drives the fixed-vs-auto acceptance
    booleans (least-noise estimator); ``median`` is what the CI
    regression gate compares run-over-run (robust to a single slow
    round)."""
    for fn in fns.values():
        fn()  # warmup: jit compile + calibration cache + device transfer
    samples: Dict[str, list] = {k: [] for k in fns}
    for _ in range(repeats):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            samples[k].append(time.perf_counter() - t0)
    return {k: {"best": min(v), "median": statistics.median(v)}
            for k, v in samples.items()}


def auto_vs_fixed(row: Dict, fixed_modes) -> None:
    """Fill the acceptance fields of one family row in place, given
    ``t_auto`` and ``t_<mode>`` timings already present."""
    best = min(row[f"t_{m}"] for m in fixed_modes)
    worse = max(row[f"t_{m}"] for m in fixed_modes)
    row["auto_vs_best"] = row["t_auto"] / best
    row["auto_vs_worse"] = row["t_auto"] / worse
    row["auto_no_slower_than_best"] = row["auto_vs_best"] <= TOLERANCE
    row["auto_beats_worse"] = worse / row["t_auto"] >= BEAT_MARGIN
