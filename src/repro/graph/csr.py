"""Fixed-shape CSR/CSC graph containers for JAX.

JAX has no CSR/CSC sparse support (BCOO only), and DAWN's SOVM operates on
CSR adjacency while BOVM operates on CSC.  We therefore carry *both* layouts
as padded, fixed-shape integer arrays registered as a pytree, so graphs can
flow through jit/shard_map/scan without retracing on content changes.

Padding convention: edge arrays are padded to ``m_pad`` entries; padded slots
hold ``src = dst = n_nodes`` (a sentinel row).  All frontier / distance
buffers are sized ``n_nodes + 1`` internally so the sentinel scatters into a
dead row that is dropped on exit.
"""
from __future__ import annotations

import dataclasses
import typing
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


class DegreeStats(typing.NamedTuple):
    """Static graph statistics feeding the engine's sweep cost model."""
    n_nodes: int
    n_edges: int
    avg_degree: float
    max_out_degree: int
    max_in_degree: int
    density: float


@partial(jax.tree_util.register_dataclass,
         data_fields=["indptr", "indices", "src", "dst",
                      "indptr_t", "indices_t"],
         meta_fields=["n_nodes", "n_edges", "m_pad"])
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Padded CSR (+ COO + transpose/CSC) adjacency.

    Attributes
    ----------
    indptr    : (n+1,) int32       row pointers (CSR, out-edges)
    indices   : (m_pad,) int32     column ids (dst), padded with ``n_nodes``
    src       : (m_pad,) int32     COO source per edge, padded with ``n_nodes``
    dst       : (m_pad,) int32     alias of indices (kept explicit for segment ops)
    indptr_t  : (n+1,) int32       CSC column pointers (in-edges)
    indices_t : (m_pad,) int32     CSC row ids, padded with ``n_nodes``
    n_nodes   : int (static)
    n_edges   : int (static)       true edge count (directed)
    m_pad     : int (static)       padded edge-array length
    """

    indptr: jax.Array
    indices: jax.Array
    src: jax.Array
    dst: jax.Array
    indptr_t: jax.Array
    indices_t: jax.Array
    n_nodes: int
    n_edges: int
    m_pad: int

    # -- constructors ------------------------------------------------------

    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int,
                   *, dedup: bool = True, remove_self_loops: bool = True,
                   pad_to: int | None = None) -> "CSRGraph":
        """Build from host-side COO edge arrays (numpy)."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if remove_self_loops:
            keep = src != dst
            src, dst = src[keep], dst[keep]
        if dedup and len(src):
            key = src * n_nodes + dst
            _, uniq = np.unique(key, return_index=True)
            src, dst = src[uniq], dst[uniq]
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        m = len(src)
        m_pad = pad_to if pad_to is not None else max(_round_up(max(m, 1), 128), 128)
        assert m_pad >= m, f"pad_to={m_pad} < m={m}"

        indptr = np.zeros(n_nodes + 1, dtype=np.int32)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr).astype(np.int32)

        # transpose (CSC) — in-edges sorted by dst
        order_t = np.lexsort((src, dst))
        src_t, dst_t = src[order_t], dst[order_t]
        indptr_t = np.zeros(n_nodes + 1, dtype=np.int32)
        np.add.at(indptr_t, dst_t + 1, 1)
        indptr_t = np.cumsum(indptr_t).astype(np.int32)

        def pad(a):
            out = np.full(m_pad, n_nodes, dtype=np.int32)
            out[:m] = a
            return out

        return CSRGraph(
            indptr=jnp.asarray(indptr),
            indices=jnp.asarray(pad(dst)),
            src=jnp.asarray(pad(src)),
            dst=jnp.asarray(pad(dst)),
            indptr_t=jnp.asarray(indptr_t),
            indices_t=jnp.asarray(pad(src_t)),
            n_nodes=int(n_nodes),
            n_edges=int(m),
            m_pad=int(m_pad),
        )

    @staticmethod
    def from_weighted_edges(src: np.ndarray, dst: np.ndarray,
                            weights: np.ndarray, n_nodes: int,
                            *, remove_self_loops: bool = True,
                            pad_to: int | None = None
                            ) -> Tuple["CSRGraph", np.ndarray]:
        """Build from weighted COO edges -> (graph, lane_weights).

        ``lane_weights`` is (m_pad,) float32 aligned with the graph's
        padded CSR lanes (+inf on padded slots) — exactly the layout
        ``prepare_weighted`` / ``prepare_sharded`` consume.  Duplicate
        edges reduce to their MIN weight, matching how the dense
        tropical operand resolves parallel edges.
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        w = np.asarray(weights, dtype=np.float64)
        assert w.shape == src.shape == dst.shape, \
            (src.shape, dst.shape, w.shape)
        if remove_self_loops:
            keep = src != dst
            src, dst, w = src[keep], dst[keep], w[keep]
        # sort by (src, dst) — the same primary/secondary order
        # from_edges' lexsort produces — and min-reduce duplicates so the
        # surviving lane order matches the graph's lane order exactly
        key = src * n_nodes + dst
        order = np.argsort(key, kind="stable")
        src, dst, w, key = src[order], dst[order], w[order], key[order]
        first = np.ones(len(key), bool)
        first[1:] = key[1:] != key[:-1]
        grp = np.cumsum(first) - 1
        w_min = np.full(int(first.sum()), np.inf)
        np.minimum.at(w_min, grp, w)
        src, dst = src[first], dst[first]
        g = CSRGraph.from_edges(src, dst, n_nodes, dedup=False,
                                remove_self_loops=False, pad_to=pad_to)
        lanes = np.full(g.m_pad, np.inf, np.float32)
        lanes[: g.n_edges] = w_min
        return g, lanes

    @staticmethod
    def from_scipy(mat, **kw) -> "CSRGraph":
        coo = mat.tocoo()
        return CSRGraph.from_edges(coo.row, coo.col, mat.shape[0], **kw)

    # -- views -------------------------------------------------------------

    def to_dense(self, dtype=jnp.int8) -> jax.Array:
        """Dense (n, n) adjacency — BOVM / MXU path.  Padded edges drop out."""
        n = self.n_nodes
        a = jnp.zeros((n + 1, n + 1), dtype=dtype)
        a = a.at[self.src, self.dst].set(1)
        return a[:n, :n]

    def to_dense_padded(self, n_pad: int, dtype=jnp.int8) -> jax.Array:
        """Dense adjacency zero-padded to (n_pad, n_pad) (tile-aligned)."""
        n = self.n_nodes
        assert n_pad >= n
        a = jnp.zeros((max(n_pad, n + 1), max(n_pad, n + 1)), dtype=dtype)
        a = a.at[self.src, self.dst].set(
            jnp.where(self.src < n, jnp.ones_like(self.src, dtype=dtype), 0))
        return a[:n_pad, :n_pad]

    def out_degrees(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    def in_degrees(self) -> jax.Array:
        return self.indptr_t[1:] - self.indptr_t[:-1]

    def n_padded(self, align: int = 128) -> int:
        """Tile-aligned node count with room for the sentinel row.

        ``>= n_nodes + 1`` so the padded-edge sentinel (``src = dst =
        n_nodes``) indexes a dead column instead of clipping onto a real
        node inside jit (JAX clamps out-of-range gather indices).
        """
        return _round_up(self.n_nodes + 1, align)

    def degree_stats(self) -> "DegreeStats":
        """Host-side degree/density summary — the static half of the
        direction-switch signal (the dynamic half is frontier occupancy,
        see core/engine.py)."""
        out_deg = np.asarray(self.out_degrees())
        in_deg = np.asarray(self.in_degrees())
        n = max(self.n_nodes, 1)
        return DegreeStats(
            n_nodes=self.n_nodes,
            n_edges=self.n_edges,
            avg_degree=self.n_edges / n,
            max_out_degree=int(out_deg.max(initial=0)),
            max_in_degree=int(in_deg.max(initial=0)),
            density=self.n_edges / (n * n),
        )

    def to_pull_packed(self, n_pad: int | None = None, dtype=jnp.int8,
                       *, adj: jax.Array | None = None) -> jax.Array:
        """(n_pad, n_pad/32) uint32 bit-packed in-neighbour rows — the
        operand of the pull-direction sweep (kernels/bovm packed_pull).

        Pass ``adj`` (a ``to_dense_padded`` result) to reuse an already
        built dense operand instead of materializing a second one."""
        from ..core.frontier import pack_bits
        if adj is None:
            n_pad = self.n_padded() if n_pad is None else n_pad
            adj = self.to_dense_padded(n_pad, dtype=dtype)
        return pack_bits(adj.T != 0)

    def reverse(self) -> "CSRGraph":
        """Transpose view as a first-class CSRGraph (shares buffers)."""
        return CSRGraph(
            indptr=self.indptr_t, indices=self.indices_t,
            src=self.dst, dst=self.src,
            indptr_t=self.indptr, indices_t=self.indices,
            n_nodes=self.n_nodes, n_edges=self.n_edges, m_pad=self.m_pad)

    # -- host helpers ------------------------------------------------------

    def edge_arrays_np(self) -> Tuple[np.ndarray, np.ndarray]:
        src = np.asarray(self.src)[: self.n_edges]
        dst = np.asarray(self.dst)[: self.n_edges]
        return src, dst

    def to_scipy(self):
        import scipy.sparse as sp
        src, dst = self.edge_arrays_np()
        return sp.csr_matrix(
            (np.ones(len(src), dtype=np.int8), (src, dst)),
            shape=(self.n_nodes, self.n_nodes))

    def memory_bytes(self, *, boolean_frontier: bool = True) -> int:
        """DAWN's memory model (paper §3.4): CSR + distance + 2 bool arrays."""
        n, m = self.n_nodes, self.n_edges
        csr = 4 * m  # 4m for column indices (indptr amortized into n terms)
        if boolean_frontier:
            return csr + 3 * n          # distance-as-byte + two bool arrays
        return csr + 8 * n              # BFS: 4n distance + 4n queue


def symmetrize(src: np.ndarray, dst: np.ndarray):
    return (np.concatenate([src, dst]), np.concatenate([dst, src]))
