"""Pallas TPU kernels for the DAWN sweep (the paper's compute hot spot).

Four kernels: both paper directions, each bit-packed, plus the f32 GEMM
push and the fused multi-sweep persistent kernel:

``packed_push_kernel`` — push direction, bit-packed (the engine default).
  The boolean push and pull sweeps are the SAME computation once the
  frontier is packed over the contraction axis:
  hits[s, j] = OR_w(frontier[s, w] & in_nbrs[j, w]) — so the push form
  drives the identical word-AND/OR math over ``adj_pull`` with a 128-row
  source tile and the push kernel's occupancy gating (f_occ frontier
  blocks, o_occ unreached tiles — Thm 3.2 at tile rank).  This is the
  paper's Eq. 13 BOVM memory model made compute: 32 frontier lanes per
  uint32 op, no f32 GEMM anywhere on the boolean kernel path.

``fused_boolean_kernel`` — the fused multi-sweep persistent kernel.
  Grid (S/bs,) over source tiles only; each invocation runs up to
  ``max_sweeps`` sweeps with the packed frontier, distances and the whole
  packed operand resident in VMEM, evaluating the Fact-1 convergence
  check in-kernel.  Source tiles evolve independently (the operand is
  read-only), and a tile's productivity is prefix-contiguous (an empty
  frontier stays empty), so per-tile (productive-count, converged) pairs
  max/all-reduce to exactly the per-sweep loop's global accounting — the
  wrapper returns them and ``core/sweep.py::sweep_loop`` advances its
  step/sweeps counters as if each sweep had been dispatched separately.

``fused_sweep_kernel`` — push direction (paper Alg. 1 as batched GEMM).
  Grid (Si, Nj, Kk), K innermost.  Each (i, j) output tile accumulates
  frontier-block × adjacency-block products on the MXU, then fuses the
  DAWN epilogue (hit test + Thm 3.2 visited-skip + distance write).
  The paper's per-element early exit becomes tile skipping driven by two
  scalar-prefetched occupancy tables:
    * f_occ[i, k]  — frontier block (i, k) has any active source
                     (input sparsity: late sweeps have tiny frontiers);
    * o_occ[i, j]  — output tile (i, j) has any unreached target
                     (output sparsity: early tiles retire as distances fill —
                     exactly Thm 3.2 "skip discovered targets" at tile rank).
  A skipped (i, j, k) step performs no MXU work and no VMEM traffic beyond
  the (already scheduled) block fetches.

``packed_pull_kernel`` — pull direction (paper's CSC BOVM, §3.2), bit-packed.
  hits[s, j] = OR_w(frontier[s, w] & in_nbrs[j, w]) over uint32 words:
  32 nodes/byte-lane, pure VPU bitwise ops — the TPU analogue of the
  boolean-compression argument in Eq. 3/4.

VMEM budgets (defaults): push tiles (128×512 f + 512×128 a + 128×128 acc/out)
≈ 0.6 MB;  pull tiles (128×W_blk + 128×W_blk uint32 + 128×128 acc) ≲ 1 MB.
All matmul dims are multiples of 128 (MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.frontier import pack_bits as frontier_pack_bits
from .. import common


# --------------------------------------------------------------------------
# push direction: fused masked GEMM sweep
# --------------------------------------------------------------------------

def _fused_sweep_kernel(f_occ_ref, o_occ_ref, step_ref,        # scalar prefetch
                        f_ref, a_ref, dist_ref,                # VMEM in
                        new_ref, dist_out_ref,                 # VMEM out
                        acc_ref):                              # VMEM scratch
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = (f_occ_ref[i, k] > 0) & (o_occ_ref[i, j] > 0)

    @pl.when(live)
    def _accumulate():
        acc_ref[...] += jnp.dot(
            f_ref[...].astype(jnp.float32), a_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        dist = dist_ref[...]
        new = (acc_ref[...] > 0) & (dist < 0)
        new_ref[...] = new.astype(jnp.int8)
        dist_out_ref[...] = jnp.where(new, step_ref[0], dist)


@functools.partial(jax.jit, static_argnames=("bs", "bn", "bk", "interpret"))
def fused_sweep(frontier: jax.Array, adj: jax.Array, dist: jax.Array,
                step: jax.Array, *, bs: int = 128, bn: int = 128,
                bk: int = 512, interpret: bool = False):
    """One fused DAWN sweep. Shapes: frontier (S,k) int8, adj (k,n) int8,
    dist (S,n) int32; S % bs == 0, n % bn == 0, k % bk == 0.  The square
    single-device operand has k == n; the sharded executor dispatches a
    K-row block (k = n/C) and OR-combines the partial across shards."""
    s, k = frontier.shape
    ka, n = adj.shape
    assert ka == k and dist.shape == (s, n), \
        (frontier.shape, adj.shape, dist.shape)
    common.check_push_tiles(s, n, bs, bn, bk, k=k)
    gi, gj, gk = s // bs, n // bn, k // bk

    # occupancy tables (computed by XLA; cheap VPU reproductions per sweep)
    f_occ = common.block_any(frontier != 0, gi, bs, gk, bk)
    o_occ = common.block_any(dist < 0, gi, bs, gj, bn)
    step_arr = jnp.asarray(step, jnp.int32).reshape(1)

    grid_spec = common.push_grid_spec(gi, gj, gk, bs=bs, bn=bn, bk=bk,
                                      num_scalar_prefetch=3,
                                      acc_dtype=jnp.float32)
    new, dist_out = pl.pallas_call(
        _fused_sweep_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((s, n), jnp.int8),
                   jax.ShapeDtypeStruct((s, n), jnp.int32)],
        compiler_params=common.sweep_compiler_params(),
        interpret=interpret,
    )(f_occ.astype(jnp.int32), o_occ.astype(jnp.int32), step_arr,
      frontier, adj, dist)
    return new, dist_out


# --------------------------------------------------------------------------
# pull direction: bit-packed AND/OR sweep (VPU)
# --------------------------------------------------------------------------

def _packed_pull_kernel(step_ref,                 # scalar prefetch
                        f_ref, at_ref, dist_ref,  # VMEM in
                        new_ref, dist_out_ref,    # VMEM out
                        acc_ref):                 # VMEM scratch (bs, bn) int32
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] = _word_hits(f_ref[...], at_ref[...], acc_ref[...])

    @pl.when(k == nk - 1)
    def _epilogue():
        dist = dist_ref[...]
        new = (acc_ref[...] > 0) & (dist < 0)
        new_ref[...] = new.astype(jnp.int8)
        dist_out_ref[...] = jnp.where(new, step_ref[0], dist)


def _word_hits(f: jax.Array, at: jax.Array, acc: jax.Array) -> jax.Array:
    """OR over packed words: acc[s, j] |= any_w(f[s, w] & at[j, w]).
    ``f`` (bs, wk) uint32, ``at`` (bn, wk) uint32, ``acc`` (bs, bn) int32
    — the single VPU inner loop shared by the packed pull AND packed push
    kernels (one word of 32 contraction lanes per step)."""
    def word(w, acc):
        fw = jax.lax.dynamic_slice_in_dim(f, w, 1, 1)    # (bs, 1)
        aw = jax.lax.dynamic_slice_in_dim(at, w, 1, 1)   # (bn, 1)
        pair = fw & aw.reshape(1, -1)                    # (bs, bn) uint32
        return acc | (pair != 0).astype(jnp.int32)

    return jax.lax.fori_loop(0, f.shape[1], word, acc)


@functools.partial(jax.jit, static_argnames=("bs", "bn", "wk", "interpret"))
def packed_pull_sweep(frontier_packed: jax.Array, adj_in_packed: jax.Array,
                      dist: jax.Array, step: jax.Array, *, bs: int = 8,
                      bn: int = 128, wk: int = 128, interpret: bool = False):
    """Bit-packed pull sweep.  frontier_packed (S, W) uint32,
    adj_in_packed (n, W) uint32 (row j = packed in-neighbours of j),
    dist (S, n) int32.  S % bs == 0, n % bn == 0, W % wk == 0."""
    s, w = frontier_packed.shape
    n = adj_in_packed.shape[0]
    assert adj_in_packed.shape == (n, w) and dist.shape == (s, n)
    assert s % bs == 0 and n % bn == 0 and w % wk == 0, (s, n, w, bs, bn, wk)
    gi, gj, gk = s // bs, n // bn, w // wk
    step_arr = jnp.asarray(step, jnp.int32).reshape(1)

    grid_spec = common.pull_grid_spec(gi, gj, gk, bs=bs, bn=bn, wk=wk,
                                      num_scalar_prefetch=1,
                                      acc_dtype=jnp.int32)
    new, dist_out = pl.pallas_call(
        _packed_pull_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((s, n), jnp.int8),
                   jax.ShapeDtypeStruct((s, n), jnp.int32)],
        compiler_params=common.sweep_compiler_params(),
        interpret=interpret,
    )(step_arr, frontier_packed, adj_in_packed, dist)
    return new, dist_out


# --------------------------------------------------------------------------
# push direction, bit-packed: the same word math as pull, with the push
# kernel's occupancy gating — the engine's boolean kernel default
# --------------------------------------------------------------------------

def _packed_push_kernel(f_occ_ref, o_occ_ref, step_ref,   # scalar prefetch
                        f_ref, at_ref, dist_ref,          # VMEM in
                        new_ref, dist_out_ref,            # VMEM out
                        acc_ref):                         # VMEM scratch i32
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = (f_occ_ref[i, k] > 0) & (o_occ_ref[i, j] > 0)

    @pl.when(live)
    def _accumulate():
        acc_ref[...] = _word_hits(f_ref[...], at_ref[...], acc_ref[...])

    @pl.when(k == nk - 1)
    def _epilogue():
        dist = dist_ref[...]
        new = (acc_ref[...] > 0) & (dist < 0)
        new_ref[...] = new.astype(jnp.int8)
        dist_out_ref[...] = jnp.where(new, step_ref[0], dist)


@functools.partial(jax.jit, static_argnames=("bs", "bn", "wk", "interpret"))
def packed_push_sweep(frontier_packed: jax.Array, adj_in_packed: jax.Array,
                      dist: jax.Array, step: jax.Array, *, bs: int = 128,
                      bn: int = 128, wk: int = 128, interpret: bool = False):
    """Bit-packed push sweep.  frontier_packed (S, W) uint32 — the packed
    frontier over the contraction axis — adj_in_packed (n, W) uint32 (the
    same operand the pull kernel reads; for a sharded K-row block the W
    words cover the block's k rows), dist (S, n) int32.  S % bs == 0,
    n % bn == 0, W % wk == 0.  Emits NO f32 GEMM: the (∨, ∧) product is
    pure uint32 word AND/OR on the VPU (paper Eq. 13: 32 lanes/word),
    gated by the push kernel's f_occ/o_occ occupancy tables."""
    s, w = frontier_packed.shape
    n = adj_in_packed.shape[0]
    assert adj_in_packed.shape == (n, w) and dist.shape == (s, n)
    assert s % bs == 0 and n % bn == 0 and w % wk == 0, (s, n, w, bs, bn, wk)
    gi, gj, gk = s // bs, n // bn, w // wk

    f_occ = common.block_any(frontier_packed != 0, gi, bs, gk, wk)
    o_occ = common.block_any(dist < 0, gi, bs, gj, bn)
    step_arr = jnp.asarray(step, jnp.int32).reshape(1)

    grid_spec = common.pull_grid_spec(gi, gj, gk, bs=bs, bn=bn, wk=wk,
                                      num_scalar_prefetch=3,
                                      acc_dtype=jnp.int32)
    new, dist_out = pl.pallas_call(
        _packed_push_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((s, n), jnp.int8),
                   jax.ShapeDtypeStruct((s, n), jnp.int32)],
        compiler_params=common.sweep_compiler_params(),
        interpret=interpret,
    )(f_occ.astype(jnp.int32), o_occ.astype(jnp.int32), step_arr,
      frontier_packed, adj_in_packed, dist)
    return new, dist_out


# --------------------------------------------------------------------------
# fused multi-sweep persistent kernel (boolean): K sweeps — or the whole
# fixpoint — per invocation, Fact 1 evaluated in-kernel
# --------------------------------------------------------------------------

def _pack_words(mask: jax.Array) -> jax.Array:
    """(bs, n) bool -> (bs, n/32) uint32 — in-kernel re-pack of the new
    frontier between fused sweeps.  Bit-for-bit the same little-endian
    layout as ``core.frontier.pack_bits`` (n is 128-aligned, no padding)."""
    bs, n = mask.shape
    bits = mask.reshape(bs, n // 32, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def _fused_boolean_kernel(meta_ref,                        # scalar prefetch
                          f_ref, at_ref, dist_ref,         # VMEM in
                          new_ref, dist_out_ref,           # VMEM out
                          prod_ref, stop_ref,              # VMEM out (1, 1)
                          *, max_sweeps: int):
    step0 = meta_ref[0]
    n_run = meta_ref[1]
    at = at_ref[...]                     # (n, W) uint32, resident throughout
    d0 = dist_ref[...]                   # (bs, n) int32

    def sweep(t, carry):
        done, prod, f, d, new8 = carry
        live = (done == 0) & (t < n_run)
        hits = _word_hits(f, at, jnp.zeros(d.shape, jnp.int32))
        new = (hits > 0) & (d < 0)
        any_new = jnp.any(new)
        d = jnp.where(new & live, step0 + 1 + t, d)
        new8 = jnp.where(live, new.astype(jnp.int8), new8)
        f = jnp.where(live, _pack_words(new), f)
        prod = prod + (live & any_new).astype(jnp.int32)
        done = done | (live & ~any_new).astype(jnp.int32)
        return done, prod, f, d, new8

    done, prod, _, d, new8 = jax.lax.fori_loop(
        0, max_sweeps, sweep,
        (jnp.int32(0), jnp.int32(0), f_ref[...], d0,
         jnp.zeros(d0.shape, jnp.int8)))
    new_ref[...] = new8
    dist_out_ref[...] = d
    prod_ref[0, 0] = prod
    stop_ref[0, 0] = done


@functools.partial(jax.jit,
                   static_argnames=("bs", "max_sweeps", "interpret"))
def fused_boolean_multisweep(frontier: jax.Array, adj_in_packed: jax.Array,
                             dist: jax.Array, step: jax.Array,
                             n_run: jax.Array, *, bs: int = 128,
                             max_sweeps: int = 1, interpret: bool = False):
    """Run up to ``n_run`` boolean sweeps (``n_run <= max_sweeps``, the
    static unroll bound) in ONE kernel invocation.  frontier (S, n) int8
    (packed on entry; re-packed in-VMEM between sweeps), adj_in_packed
    (n, W) uint32 fully resident, dist (S, n) int32, ``step`` the sweeps
    already executed (sweep t writes distance step + 1 + t).

    Each source tile runs its own Fact-1 check in-kernel: a tile whose
    sweep settles nothing zeroes its frontier and holds state for the
    rest of the block.  Returns (new int8, dist int32, prod int32 scalar,
    stopped bool scalar) where ``prod = max over tiles`` of productive
    sweeps and ``stopped = all tiles converged`` — because per-tile
    productivity is prefix-contiguous, the per-sweep driver's global
    accounting is ``executed = stopped ? prod + 1 : n_run`` exactly (see
    ``sweep_loop``'s fused body).  Bit-identical to ``n_run`` dispatches
    of the per-sweep path."""
    s, n = frontier.shape
    w = adj_in_packed.shape[1]
    assert adj_in_packed.shape == (n, w), (adj_in_packed.shape, n)
    assert dist.shape == (s, n) and w * 32 == n, (frontier.shape, w)
    assert s % bs == 0 and n % 128 == 0, (s, n, bs)
    gi = s // bs

    fp = frontier_pack_bits(frontier != 0)                # (S, W)
    meta = jnp.stack([jnp.asarray(step, jnp.int32),
                      jnp.asarray(n_run, jnp.int32)])

    grid_spec = common.fused_grid_spec(gi, bs=bs, n=n, f_block=(bs, w),
                                       op_block=(n, w))
    new, dist_out, prod, stop = pl.pallas_call(
        functools.partial(_fused_boolean_kernel, max_sweeps=max_sweeps),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((s, n), jnp.int8),
                   jax.ShapeDtypeStruct((s, n), jnp.int32),
                   jax.ShapeDtypeStruct((gi, 1), jnp.int32),
                   jax.ShapeDtypeStruct((gi, 1), jnp.int32)],
        compiler_params=common.fused_compiler_params(),
        interpret=interpret,
    )(meta, fp, adj_in_packed, dist)
    return new, dist_out, jnp.max(prod), jnp.min(stop) > 0
