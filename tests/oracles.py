"""Pure-NumPy / SciPy shortest-path oracles shared by the test suite.

Deliberately independent of the library under test: queue BFS (the
paper's Alg. 3 semantics) is reimplemented here straight off the CSR
arrays — it does NOT call ``repro.core.bfs_queue_numpy``, so a bug in
the library's own baseline cannot mask an engine bug — and Dijkstra
comes from ``scipy.sparse.csgraph``.  Dtypes match what the engines
emit (int32 with -1 unreachable for BFS, float64/inf for Dijkstra) so
tests compare with ``assert_array_equal`` / ``assert_allclose``
directly.  Subprocess tests (``tests/test_distributed.py``) import this
module after ``sys.path.insert(0, "tests")``.
"""
from __future__ import annotations

from collections import deque

import numpy as np


def bfs_dist(g, source: int) -> np.ndarray:
    """Textbook queue BFS over the CSR arrays -> (n,) int32, -1 = unreachable."""
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    n = g.n_nodes
    dist = np.full(n, -1, dtype=np.int32)
    dist[source] = 0
    q = deque([source])
    while q:
        u = q.popleft()
        for e in range(indptr[u], indptr[u + 1]):
            v = indices[e]
            if v < n and dist[v] < 0:
                dist[v] = dist[u] + 1
                q.append(v)
    return dist


def bfs_dists(g, sources) -> np.ndarray:
    """Stacked queue-BFS distances -> (S, n) int32."""
    return np.stack([bfs_dist(g, int(s)) for s in np.asarray(sources)])


def dijkstra_dist(g, weights, source: int) -> np.ndarray:
    """scipy Dijkstra -> (n,) float64, +inf = unreachable.  ``weights``
    may cover the padded edge lanes; only the first ``n_edges`` are read."""
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csgraph
    src, dst = g.edge_arrays_np()
    mat = sp.csr_matrix((np.asarray(weights[: g.n_edges], np.float64),
                         (src, dst)), shape=(g.n_nodes, g.n_nodes))
    return csgraph.dijkstra(mat, indices=source, directed=True)


def dijkstra_dists(g, weights, sources) -> np.ndarray:
    """Stacked Dijkstra distances -> (S, n) float64."""
    return np.stack([dijkstra_dist(g, weights, int(s))
                     for s in np.asarray(sources)])
