from .csr import CSRGraph, symmetrize
from . import generators, partition, sampler, io

__all__ = ["CSRGraph", "symmetrize", "generators", "partition", "sampler", "io"]
