"""Pallas TPU kernels for the paper's compute hot spots (validated with
interpret=True on CPU)."""
from . import bovm
