"""meshgraphnet — encode-process-decode mesh GNN.
[arXiv:2010.03409; unverified]  15L d_hidden=128 sum-agg 2-layer MLPs."""
from ..models.gnn import MGNConfig

CONFIG = MGNConfig(
    name="meshgraphnet", n_layers=15, d_hidden=128, mlp_layers=2)
