"""Minimal graph IO: whitespace edge lists and MatrixMarket pattern files."""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, symmetrize


def load_edgelist(path: str, *, undirected: bool = False,
                  zero_indexed: bool = True) -> CSRGraph:
    src, dst = [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(("#", "%")):
                continue
            a, b = line.split()[:2]
            src.append(int(a)); dst.append(int(b))
    src = np.asarray(src); dst = np.asarray(dst)
    if not zero_indexed:
        src -= 1; dst -= 1
    n = int(max(src.max(), dst.max())) + 1 if len(src) else 1
    if undirected:
        src, dst = symmetrize(src, dst)
    return CSRGraph.from_edges(src, dst, n)


def load_mtx(path: str) -> CSRGraph:
    """MatrixMarket coordinate pattern/real square matrices as graphs."""
    with open(path) as f:
        header = f.readline()
        symmetric = "symmetric" in header
        line = f.readline()
        while line.startswith("%"):
            line = f.readline()
        n_rows, n_cols, _ = (int(x) for x in line.split()[:3])
        src, dst = [], []
        for line in f:
            parts = line.split()
            if len(parts) < 2:
                continue
            src.append(int(parts[0]) - 1); dst.append(int(parts[1]) - 1)
    src = np.asarray(src); dst = np.asarray(dst)
    if symmetric:
        src, dst = symmetrize(src, dst)
    return CSRGraph.from_edges(src, dst, max(n_rows, n_cols))


def save_edgelist(g: CSRGraph, path: str) -> None:
    src, dst = g.edge_arrays_np()
    with open(path, "w") as f:
        f.write(f"# nodes={g.n_nodes} edges={g.n_edges}\n")
        for s, d in zip(src, dst):
            f.write(f"{s} {d}\n")
