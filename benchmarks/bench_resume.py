"""Resumable-job layer (core/jobs.py): checkpointed sweep jobs and
preemption-safe resume — overhead and bit-identity, as JSON.

For each family, a counting-semiring APSP job (dist + sigma — the
betweenness front half) runs three ways:

  (a) **full** — all chunks in one go, checkpointing every chunk into a
      fresh directory (the steady-state production configuration);
  (b) **killed** — the same job preempted via the ``on_chunk`` seam
      after half the chunks (the checkpoint directory it leaves behind
      is the resume fixture);
  (c) **resumed** — the same call pointed at a copy of the killed run's
      directory, restoring the newest checkpoint and sweeping only the
      missing tail.

Resume is asserted bit-identical to the full run (dist, sigma, sweeps,
direction counts) before any timing — a resumed job that drifts is a
bug, not a data point.  The JSON rides the hard regression gate with
the determinism fields: ``chunks_total`` / ``sweeps`` /
``dist_checksum`` / ``sigma_checksum`` (exact integer sums),
``checkpoints_written``, and the resumed-sweep accounting
(``resumed_chunks`` / ``recomputed_chunks`` / ``resume_equals_full``).
Timings (``t_full`` vs ``t_resume``, checkpoint I/O included) are
advisory medians: resuming half a job should cost roughly half a run
plus one restore.

Single-device by construction — mesh-routed jobs are exercised by the
subprocess tests (tests/test_jobs.py); their ``direction_counts`` are
mesh-shape dependent, which would make the baseline machine-specific.

    PYTHONPATH=src python -m benchmarks.bench_resume [--quick] [--out f.json]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
from typing import Callable, Dict, List, Optional

import numpy as np

from ._timing import time_interleaved_stats


def _families() -> Dict[str, Callable]:
    # lazy: main() may need to set XLA_FLAGS before anything imports jax
    from repro.graph import generators as gen
    return {
        "grid_road": lambda: gen.grid2d(32, 32),
        "ws_citation": lambda: gen.watts_strogatz(1024, 8, 0.05, seed=3),
    }


QUICK_FAMILIES = ("grid_road",)


class _Preempt(RuntimeError):
    pass


def _kill_after(chunk_idx: int):
    def on_chunk(k: int) -> None:
        if k == chunk_idx:
            raise _Preempt(f"injected preemption after chunk {k}")
    return on_chunk


def run(quick: bool = False, n_sources: int = 32, repeats: int = 3,
        csv: Optional[List[str]] = None) -> Dict:
    from repro.core.jobs import run_sweep_job
    from repro.core.options import SweepOptions

    chunk_size = 8
    # pinned form: auto's wall-clock calibration makes direction_counts
    # non-reproducible across invocations, and the in-bench full-vs-resume
    # assertion covers them; dist/sigma/sweeps are form-invariant
    opts = SweepOptions(source_batch=chunk_size, mode="sparse")
    names = QUICK_FAMILIES if quick else tuple(_families())
    families = {}
    for name in names:
        g = _families()[name]()
        sources = np.arange(min(n_sources, g.n_nodes), dtype=np.int32)

        def job(ckpt_dir, on_chunk=None):
            return run_sweep_job(
                g, sources, workload="counting", options=opts,
                chunk_size=chunk_size, checkpoint_dir=ckpt_dir,
                checkpoint_interval=1, on_chunk=on_chunk)

        with tempfile.TemporaryDirectory() as td:
            full = job(os.path.join(td, "full"))
            kill_at = full.chunks_total // 2 - 1   # die after half
            fixture = os.path.join(td, "killed")
            try:
                job(fixture, on_chunk=_kill_after(kill_at))
            except _Preempt:
                pass
            resume_dir = os.path.join(td, "resume0")
            shutil.copytree(fixture, resume_dir)
            resumed = job(resume_dir)

            # bit-identical before any timing
            np.testing.assert_array_equal(resumed.dist, full.dist)
            np.testing.assert_array_equal(resumed.sigma, full.sigma)
            assert resumed.sweeps == full.sweeps
            np.testing.assert_array_equal(resumed.direction_counts,
                                          full.direction_counts)
            assert resumed.chunks_restored == kill_at + 1
            assert resumed.chunks_restored + resumed.chunks_computed \
                == full.chunks_total

            row: Dict = {
                "n_nodes": g.n_nodes, "n_edges": g.n_edges,
                "n_sources": int(len(sources)),
                "chunks_total": full.chunks_total,
                "sweeps": full.sweeps,
                # exact integer sums in int64/f32 — any drift means the
                # resumed job computed different shortest paths
                "dist_checksum": int(
                    np.asarray(full.dist, np.int64).sum()),
                "sigma_checksum": float(np.asarray(full.sigma).sum()),
                "checkpoints_written": full.checkpoints_written,
                "resumed_chunks": resumed.chunks_restored,
                "recomputed_chunks": resumed.chunks_computed,
                "resume_equals_full": True,   # asserted above
            }

            counter = [0]

            def go_full():
                counter[0] += 1
                job(os.path.join(td, f"tf{counter[0]}"))

            def go_resume():
                counter[0] += 1
                d = os.path.join(td, f"tr{counter[0]}")
                shutil.copytree(fixture, d)
                job(d)

            stats = time_interleaved_stats(
                {"full": go_full, "resume": go_resume}, repeats)
            for mode, st in stats.items():
                row[f"t_{mode}"] = st["best"]
                row[f"t_{mode}_median"] = st["median"]
            row["resume_speedup"] = row["t_full"] / row["t_resume"]
        families[name] = row
        if csv is not None:
            csv.append(
                f"resume_{name},{row['t_resume'] * 1e6:.1f},"
                f"resume_speedup={row['resume_speedup']:.2f}x")
    return {
        "benchmark": "bench_resume",
        "chunk_size": chunk_size,
        "families": families,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--sources", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    result = run(quick=args.quick, n_sources=args.sources,
                 repeats=args.repeats)
    text = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
