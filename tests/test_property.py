"""Randomized property tests on system invariants.

Every property has a seeded ``pytest.mark.parametrize`` variant that
ALWAYS runs — parameters are derived from the seed through
``np.random.default_rng``, so the sampled space matches the hypothesis
strategies without depending on hypothesis being installed.  When
hypothesis IS available (CI installs it via ``pip install -e .[test]``),
the adaptive ``*_hypothesis`` variants run on top; when it isn't, they
simply don't exist — no environment-dependent skips either way.
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.graph.csr import CSRGraph
from repro.core import (sovm_sssp, bovm_sssp, pack_bits, unpack_bits,
                        popcount)
from repro._attic.models.recsys import embedding_bag, embedding_bag_ragged

from oracles import bfs_dist


# -- DAWN == queue BFS on random graphs --------------------------------------

def _check_dawn_equals_bfs(n, avg_deg, seed, directed, source):
    rng = np.random.default_rng(seed)
    m = max(1, int(n * avg_deg))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    if not directed:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    g = CSRGraph.from_edges(src, dst, n)
    s = source % n
    ref = bfs_dist(g, s)
    np.testing.assert_array_equal(np.asarray(sovm_sssp(g, s).dist), ref)
    np.testing.assert_array_equal(
        np.asarray(bovm_sssp(g.to_dense(), s).dist), ref)


@pytest.mark.parametrize("seed", range(12))
def test_dawn_equals_bfs_on_random_graphs(seed):
    rng = np.random.default_rng(seed * 7919 + 1)
    _check_dawn_equals_bfs(int(rng.integers(2, 121)),
                           float(rng.uniform(0.5, 6.0)),
                           int(rng.integers(0, 10**6)),
                           bool(rng.integers(0, 2)),
                           int(rng.integers(0, 10**6)))


# -- bit-packing round-trips -------------------------------------------------

def _check_pack_unpack(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.random((3, n)) < 0.5
    packed = pack_bits(jnp.asarray(x))
    back = np.asarray(unpack_bits(packed, n))
    np.testing.assert_array_equal(back, x)
    np.testing.assert_array_equal(np.asarray(popcount(packed)),
                                  x.sum(axis=1))


@pytest.mark.parametrize("seed", range(10))
def test_pack_unpack_roundtrip(seed):
    rng = np.random.default_rng(seed * 6007 + 5)
    _check_pack_unpack(int(rng.integers(1, 201)),
                       int(rng.integers(0, 10**6)))


# -- ragged == fixed embedding bags ------------------------------------------

def _check_embedding_bag(v, d, bags, maxlen, seed, mode):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    lens = rng.integers(0, maxlen + 1, bags)
    idx_fixed = np.full((bags, maxlen), -1, np.int64)
    flat, seg = [], []
    for b in range(bags):
        ids = rng.integers(0, v, lens[b])
        idx_fixed[b, :lens[b]] = ids
        flat.extend(ids)
        seg.extend([b] * lens[b])
    fixed = embedding_bag(table, jnp.asarray(idx_fixed), mode=mode)
    if flat:
        ragged = embedding_bag_ragged(
            table, jnp.asarray(np.array(flat)),
            jnp.asarray(np.array(seg)), bags, mode=mode)
        np.testing.assert_allclose(np.asarray(fixed), np.asarray(ragged),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("seed", range(5))
def test_embedding_bag_ragged_equals_fixed(seed, mode):
    rng = np.random.default_rng(seed * 4001 + 9)
    _check_embedding_bag(int(rng.integers(2, 51)),
                         int(rng.integers(1, 17)),
                         int(rng.integers(1, 9)),
                         int(rng.integers(1, 7)),
                         int(rng.integers(0, 10**6)), mode)


# -- triangle inequality -----------------------------------------------------

def _check_triangle_inequality(seed):
    """Shortest-path distances satisfy d(s,v) <= d(s,u) + 1 per edge."""
    rng = np.random.default_rng(seed)
    n = 80
    m = 240
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    g = CSRGraph.from_edges(src, dst, n)
    dist = np.asarray(sovm_sssp(g, 0).dist)
    s_np, d_np = g.edge_arrays_np()
    for a, b in zip(s_np, d_np):
        if dist[a] >= 0:
            assert dist[b] >= 0 and dist[b] <= dist[a] + 1


@pytest.mark.parametrize("seed", range(8))
def test_triangle_inequality(seed):
    _check_triangle_inequality(seed * 2003 + 17)


# -- hypothesis variants (adaptive search on top of the seeded slices) -------

if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(2, 120), avg_deg=st.floats(0.5, 6.0),
           seed=st.integers(0, 10**6), directed=st.booleans(),
           source=st.integers(0, 10**6))
    def test_dawn_equals_bfs_hypothesis(n, avg_deg, seed, directed,
                                        source):
        _check_dawn_equals_bfs(n, avg_deg, seed, directed, source)

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 200), seed=st.integers(0, 10**6))
    def test_pack_unpack_roundtrip_hypothesis(n, seed):
        _check_pack_unpack(n, seed)

    @settings(max_examples=20, deadline=None)
    @given(v=st.integers(2, 50), d=st.integers(1, 16),
           bags=st.integers(1, 8), maxlen=st.integers(1, 6),
           seed=st.integers(0, 10**6),
           mode=st.sampled_from(["sum", "mean"]))
    def test_embedding_bag_ragged_equals_fixed_hypothesis(
            v, d, bags, maxlen, seed, mode):
        _check_embedding_bag(v, d, bags, maxlen, seed, mode)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10**6))
    def test_triangle_inequality_hypothesis(seed):
        _check_triangle_inequality(seed)


# -- cross-form differential harness over adversarial graph families --------
#
# ONE table drives every cross-check the engines promise: for each
# adversarial family, every registered semiring's every execution variant
# (reference vs Pallas-kernel forms, per-sweep vs fused multi-sweep
# blocks, dynamic vs pinned direction) must return BIT-identical
# dist/parent/sigma — and the external NumPy/SciPy oracles anchor the
# whole equivalence class to ground truth.

from oracles import (adversarial_families, bfs_dists, bfs_sigmas,
                     dijkstra_dists)

_FAMILIES = {name: (src, dst, n)
             for name, src, dst, n in adversarial_families(seed=0)}

# (variant name, config) tables — every row must agree bit-for-bit
def _boolean_variants():
    from repro.core.engine import EngineConfig
    B = dict(source_batch=8, max_steps=None)
    return [
        ("ref-auto", EngineConfig(mode="auto", use_kernel=False, **B)),
        ("ref-push", EngineConfig(mode="push", use_kernel=False, **B)),
        ("ref-pull", EngineConfig(mode="pull", use_kernel=False, **B)),
        ("ref-sparse", EngineConfig(mode="sparse", use_kernel=False, **B)),
        ("kernel-dynamic", EngineConfig(mode="auto", use_kernel=True, **B)),
        ("kernel-push", EngineConfig(mode="push", use_kernel=True, **B)),
        ("kernel-fused2", EngineConfig(mode="push", use_kernel=True,
                                       fused_steps=2, **B)),
        ("kernel-fused-all", EngineConfig(mode="push", use_kernel=True,
                                          fused_steps=-1, **B)),
    ]


def _tropical_variants():
    from repro.core.weighted import WeightedConfig
    B = dict(source_batch=8)
    return [
        ("ref-dense", WeightedConfig(mode="dense", use_kernel=False, **B)),
        ("ref-sparse", WeightedConfig(mode="sparse", use_kernel=False,
                                      **B)),
        ("kernel-dense", WeightedConfig(mode="dense", use_kernel=True,
                                        **B)),
        ("kernel-fused2", WeightedConfig(mode="dense", use_kernel=True,
                                         fused_steps=2, **B)),
        ("kernel-fused-all", WeightedConfig(mode="dense", use_kernel=True,
                                            fused_steps=-1, **B)),
    ]


def _counting_variants():
    from repro.core.centrality import CentralityConfig
    B = dict(source_batch=8)
    return [
        ("ref-push", CentralityConfig(mode="push", use_kernel=False, **B)),
        ("ref-sparse", CentralityConfig(mode="sparse", use_kernel=False,
                                        **B)),
        ("kernel-push", CentralityConfig(mode="push", use_kernel=True,
                                         **B)),
        ("kernel-fused2", CentralityConfig(mode="push", use_kernel=True,
                                           fused_steps=2, **B)),
        ("kernel-fused-all", CentralityConfig(mode="push", use_kernel=True,
                                              fused_steps=-1, **B)),
    ]


def _family_sources(n):
    return np.unique(np.clip([0, 1, n // 2, n - 1], 0, n - 1)).astype(
        np.int32)


@pytest.mark.parametrize("family", sorted(_FAMILIES))
def test_differential_boolean_all_forms(family):
    from repro.core import sweep as S
    from repro.core.engine import apsp_engine
    src, dst, n = _FAMILIES[family]
    g = CSRGraph.from_edges(src, dst, n)
    sources = _family_sources(n)
    oracle = bfs_dists(g, sources)
    results = {}
    for name, cfg in _boolean_variants():
        res = apsp_engine(g, sources, config=cfg)
        results[name] = (np.asarray(res.dist), int(res.sweeps))
    base_name, (base, base_sweeps) = next(iter(results.items()))
    np.testing.assert_array_equal(base, oracle, err_msg=f"{family} oracle")
    base_parents = np.asarray(S.derive_parents(g, jnp.asarray(base)))
    for name, (dist, sweeps) in results.items():
        np.testing.assert_array_equal(
            dist, base, err_msg=f"{family}: {name} != {base_name}")
        assert sweeps == base_sweeps, (family, name, sweeps, base_sweeps)
        parents = np.asarray(S.derive_parents(g, jnp.asarray(dist)))
        np.testing.assert_array_equal(
            parents, base_parents, err_msg=f"{family}: parents {name}")
    # parent rows are internally consistent with the oracle distances
    rows, cols = np.nonzero(base_parents >= 0)
    assert (oracle[rows, base_parents[rows, cols]] + 1
            == oracle[rows, cols]).all(), family


@pytest.mark.parametrize("family", sorted(_FAMILIES))
def test_differential_tropical_all_forms(family):
    from repro.core import sweep as S
    from repro.core.weighted import weighted_apsp
    src, dst, n = _FAMILIES[family]
    g = CSRGraph.from_edges(src, dst, n)
    gs, gd = g.edge_arrays_np()
    # small integer weights: every path sum is f32-exact, so Dijkstra's
    # float64 distances must match the sweeps bit-for-bit
    w = ((gs * 7 + gd * 3) % 9 + 1).astype(np.float32)
    w_lanes = np.full(g.m_pad, np.inf, np.float32)   # padded CSR lanes
    w_lanes[: g.n_edges] = w
    sources = _family_sources(n)
    oracle = dijkstra_dists(g, w, sources)
    results = {}
    for name, cfg in _tropical_variants():
        res = weighted_apsp(g, w, sources, config=cfg)
        results[name] = (np.asarray(res.dist), int(res.sweeps))
    base_name, (base, base_sweeps) = next(iter(results.items()))
    np.testing.assert_array_equal(base.astype(np.float64), oracle,
                                  err_msg=f"{family} oracle")
    base_parents = np.asarray(S.derive_parents(
        g, jnp.asarray(base), weights=jnp.asarray(w_lanes)))
    for name, (dist, sweeps) in results.items():
        np.testing.assert_array_equal(
            dist, base, err_msg=f"{family}: {name} != {base_name}")
        assert sweeps == base_sweeps, (family, name, sweeps, base_sweeps)
        parents = np.asarray(S.derive_parents(
            g, jnp.asarray(dist), weights=jnp.asarray(w_lanes)))
        np.testing.assert_array_equal(
            parents, base_parents, err_msg=f"{family}: parents {name}")


@pytest.mark.parametrize("family", sorted(_FAMILIES))
def test_differential_counting_all_forms(family):
    from repro.core.centrality import counting_apsp
    src, dst, n = _FAMILIES[family]
    g = CSRGraph.from_edges(src, dst, n)
    sources = _family_sources(n)
    d_oracle = bfs_dists(g, sources)
    s_oracle = bfs_sigmas(g, sources)
    results = {}
    for name, cfg in _counting_variants():
        res = counting_apsp(g, sources, config=cfg)
        results[name] = (np.asarray(res.dist), np.asarray(res.sigma),
                         int(res.sweeps))
    base_name, (base_d, base_s, base_sweeps) = next(iter(results.items()))
    np.testing.assert_array_equal(base_d, d_oracle,
                                  err_msg=f"{family} dist oracle")
    np.testing.assert_array_equal(base_s.astype(np.float64), s_oracle,
                                  err_msg=f"{family} sigma oracle")
    for name, (dist, sigma, sweeps) in results.items():
        np.testing.assert_array_equal(
            dist, base_d, err_msg=f"{family}: dist {name} != {base_name}")
        np.testing.assert_array_equal(
            sigma, base_s, err_msg=f"{family}: sigma {name}")
        assert sweeps == base_sweeps, (family, name, sweeps, base_sweeps)
