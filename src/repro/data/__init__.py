from . import tokens, graphs, recsys, pipeline
