"""Fault tolerance for 1000+-node runs: heartbeats, straggler mitigation,
elastic re-meshing.

This container has one host, so the multi-host control plane is implemented
against an abstract ``HostState`` feed and *simulated* in tests/examples —
the policies (what to do on a dead host, how to shrink the mesh, when to
declare a straggler) are the deliverable; the transport (GRPC/etcd in a real
deployment) is a thin injection point.

Policies implemented:

  * Heartbeat monitor — a host missing ``dead_after`` consecutive beats is
    declared dead; the run moves to DRAINING and triggers an elastic plan.
  * Straggler detection — per-step durations are tracked per host with a
    robust (median + MAD) outlier rule; persistent stragglers trigger either
    a warning or eviction (they cost a full collective barrier each step).
  * Elastic re-mesh — on host loss, choose the largest data-parallel extent
    that keeps every model-parallel group intact (TP groups must be whole:
    losing one chip of a TP group kills the whole group), emit the new mesh
    shape + the checkpoint step to restore from.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class HostInfo:
    host_id: int
    chips: int = 4                   # chips per host (v5e host = 4)
    last_beat: float = 0.0
    missed: int = 0
    alive: bool = True


class HeartbeatMonitor:
    """``clock`` is the injectable time source (default
    ``time.monotonic``); virtual-clock tests must pass their own so beat
    and sweep timestamps never mix time scales."""

    def __init__(self, n_hosts: int, interval_s: float = 10.0,
                 dead_after: int = 3,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        # last_beat starts at the construction-time clock reading, NOT
        # the HostInfo default of 0.0: against a monotonic clock,
        # now - 0.0 is the machine uptime, so a fresh monitor's first
        # sweep() would declare every host dead before any beat arrived.
        now = clock()
        self.hosts = {i: HostInfo(i, last_beat=now) for i in range(n_hosts)}
        self.interval = interval_s
        self.dead_after = dead_after

    def beat(self, host_id: int, t: Optional[float] = None) -> None:
        h = self.hosts[host_id]
        h.last_beat = self.clock() if t is None else t
        h.missed = 0
        h.alive = True

    def sweep(self, now: Optional[float] = None) -> List[int]:
        """Returns newly-dead host ids."""
        now = self.clock() if now is None else now
        newly_dead = []
        for h in self.hosts.values():
            if not h.alive:
                continue
            if now - h.last_beat > self.interval:
                h.missed = int((now - h.last_beat) // self.interval)
                if h.missed >= self.dead_after:
                    h.alive = False
                    newly_dead.append(h.host_id)
        return newly_dead

    @property
    def alive_hosts(self) -> List[int]:
        return [h.host_id for h in self.hosts.values() if h.alive]


class StragglerDetector:
    """Median + MAD outlier rule over a sliding window of step times.

    ``clock`` is the injectable time source (same convention as
    :class:`HeartbeatMonitor`).  ``stale_after`` (seconds, optional)
    drops hosts whose last sample is older than that from ``classify``:
    a dead host otherwise keeps its final step time in the window
    forever, polluting the median every call."""

    def __init__(self, window: int = 32, threshold: float = 4.0,
                 evict_after: int = 16,
                 clock: Callable[[], float] = time.monotonic,
                 stale_after: Optional[float] = None):
        self.window = window
        self.threshold = threshold
        self.evict_after = evict_after
        self.clock = clock
        self.stale_after = stale_after
        self.times: Dict[int, deque] = defaultdict(
            lambda: deque(maxlen=window))
        self.last_seen: Dict[int, float] = {}
        self.strikes: Dict[int, int] = defaultdict(int)

    def record(self, host_id: int, step_time_s: float,
               t: Optional[float] = None) -> None:
        self.times[host_id].append(step_time_s)
        self.last_seen[host_id] = self.clock() if t is None else t

    def classify(self, now: Optional[float] = None
                 ) -> Tuple[List[int], List[int]]:
        """Returns (stragglers, evictions)."""
        import statistics
        latest = {h: t[-1] for h, t in self.times.items() if t}
        if self.stale_after is not None:
            now = self.clock() if now is None else now
            latest = {h: v for h, v in latest.items()
                      if now - self.last_seen.get(h, now) <= self.stale_after}
        if len(latest) < 3:
            return [], []
        med = statistics.median(latest.values())
        mad = statistics.median(abs(v - med) for v in latest.values()) or 1e-9
        stragglers = [h for h, v in latest.items()
                      if (v - med) / mad > self.threshold]
        evictions = []
        for h in self.times:
            if h in stragglers:
                self.strikes[h] += 1
                if self.strikes[h] >= self.evict_after:
                    evictions.append(h)
            else:
                self.strikes[h] = max(0, self.strikes[h] - 1)
        return stragglers, evictions


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    n_chips: int
    restore_step: Optional[int]
    dropped_hosts: Tuple[int, ...]


def plan_remesh(alive_chips: int, *, model_parallel: int,
                pods: int = 1, chips_per_pod: Optional[int] = None,
                restore_step: Optional[int] = None,
                dropped_hosts: Tuple[int, ...] = ()) -> ElasticPlan:
    """Largest mesh that keeps TP groups whole.

    data' = floor(alive_chips / (pods · model_parallel)); requires ≥ 1.
    The batch is re-split over data'; per-chip memory is unchanged because
    params are sharded over (data, model) and FSDP shards just regrow."""
    per_pod = alive_chips // max(pods, 1)
    data = per_pod // model_parallel
    if data < 1:
        raise RuntimeError(
            f"cannot keep TP groups of {model_parallel} with "
            f"{alive_chips} chips")
    if pods > 1:
        return ElasticPlan((pods, data, model_parallel),
                           ("pod", "data", "model"),
                           pods * data * model_parallel,
                           restore_step, dropped_hosts)
    return ElasticPlan((data, model_parallel), ("data", "model"),
                       data * model_parallel, restore_step, dropped_hosts)


class FaultTolerantRunner:
    """Glue: monitor + detector + checkpoint hook → elastic restart loop.

    Usage (see examples/fault_tolerance_demo.py): call ``on_step`` every
    step with per-host timings; it raises ``ElasticRestart`` carrying the
    new plan when the world must change."""

    class ElasticRestart(Exception):
        def __init__(self, plan: ElasticPlan):
            super().__init__(f"elastic restart -> {plan}")
            self.plan = plan

    def __init__(self, n_hosts: int, model_parallel: int, pods: int = 1,
                 chips_per_host: int = 4, ckpt_dir: str = "",
                 clock: Callable[[], float] = time.monotonic):
        self.monitor = HeartbeatMonitor(n_hosts, clock=clock)
        self.detector = StragglerDetector(clock=clock)
        self.model_parallel = model_parallel
        self.pods = pods
        self.chips_per_host = chips_per_host
        self.ckpt_dir = ckpt_dir

    def on_step(self, step: int, host_times: Dict[int, float],
                now: Optional[float] = None) -> None:
        for h, t in host_times.items():
            self.monitor.beat(h, now)
            self.detector.record(h, t, now)
        dead = self.monitor.sweep(now)
        _, evict = self.detector.classify()
        if dead or evict:
            dropped = tuple(sorted(set(dead) | set(evict)))
            for h in dropped:
                self.monitor.hosts[h].alive = False
            alive = len(self.monitor.alive_hosts) * self.chips_per_host
            from .checkpoint import latest_step
            plan = plan_remesh(
                alive, model_parallel=self.model_parallel, pods=self.pods,
                restore_step=latest_step(self.ckpt_dir) if self.ckpt_dir
                else None,
                dropped_hosts=dropped)
            raise FaultTolerantRunner.ElasticRestart(plan)
