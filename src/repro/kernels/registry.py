"""Kernel registry: one tiling substrate, N semirings.

Each kernel package (``kernels/bovm``, ``kernels/tropical``) registers a
:class:`KernelSet` — its fused Pallas sweep entry points plus a VMEM
budget estimator — keyed by the semiring name used by
``repro.core.sweep.Semiring``.  The core sweep layer
(``core/sweep.py::boolean_forms`` / ``tropical_forms``) looks its kernels
up here instead of importing a kernel module directly, so adding a
semiring's hardware path is: write the kernels, register them, and the
direction-optimizing engines dispatch them with zero core changes.

Keys are plain strings so this module has no dependency on the core
layer (``get`` also accepts any object with a ``.name``, e.g. a
``Semiring`` instance).  Registration happens on import of
``repro.kernels`` (each subpackage registers itself at the bottom of its
``__init__``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Tuple


@dataclasses.dataclass(frozen=True)
class KernelSet:
    """The Pallas entry points one semiring contributes.

    ``forms`` maps a form name (the same vocabulary the core layer uses:
    "push"/"pull" for boolean, "dense"/"sparse" for tropical) to the
    jitted kernel wrapper.  ``fused_forms`` maps the same form names to
    *multi-sweep* persistent-kernel wrappers — one invocation runs up to
    ``max_sweeps`` sweeps with the Fact-1 convergence check evaluated
    in-kernel, state tiles staying resident across sweeps (uniform
    signature ``(frontier, operand, state, step, n_run, *, bs,
    max_sweeps, interpret)``); ``core/sweep.py::resolve_fused_steps``
    consults it to decide whether an engine may fuse.  ``vmem_bytes``
    estimates the resident VMEM of one grid step at the given tile sizes
    (used by tests to enforce the budget and by docs/ARCHITECTURE.md's
    table; ``form="fused"`` prices the whole-operand residency of the
    fused path).  ``interpret_only`` names forms validated only under
    ``interpret=True`` — the core layer must not dispatch them compiled
    (it falls back to the XLA form); registering the capability here
    keeps that policy out of core.
    """
    semiring: str
    forms: Mapping[str, Callable]
    vmem_bytes: Callable[..., int]
    notes: str = ""
    interpret_only: frozenset = frozenset()
    fused_forms: Mapping[str, Callable] = \
        dataclasses.field(default_factory=dict)

    def dispatchable(self, form: str, *, interpret: bool) -> bool:
        """May ``form`` run at this execution mode?  Interpret-only forms
        (gather/scatter kernels validated op-by-op only) must never be
        compiled on a real TPU backend; callers dispatch the XLA/ref form
        instead when this returns False — the single policy seam the core
        sweep layer consults (``sweep.tropical_forms``)."""
        return interpret or form not in self.interpret_only


_REGISTRY: dict = {}


def _key(semiring) -> str:
    return semiring if isinstance(semiring, str) else semiring.name


def register(kernel_set: KernelSet) -> KernelSet:
    """Idempotent per name: re-registering the same semiring replaces it
    (supports module reloads in tests)."""
    _REGISTRY[kernel_set.semiring] = kernel_set
    return kernel_set


def has(semiring) -> bool:
    return _key(semiring) in _REGISTRY


def get(semiring) -> KernelSet:
    """Look up the kernel set for a semiring (str or Semiring)."""
    key = _key(semiring)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise KeyError(
            f"no Pallas kernels registered for semiring {key!r}; "
            f"available: {sorted(_REGISTRY)}") from None


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
