"""Weighted-graph DAWN — the paper's §5 future-work direction, grown into
a first-class tropical-semiring engine.

The paper closes with "addressing the balance between optimizing matrix
operations and managing the consumption of (min,+) operations … to expand
the applicability of DAWN on weighted graphs".  With the semiring sweep
layer (core/sweep.py) that balance is literal: the same
direction-optimizing batch driver that picks boolean sweep forms now
picks between the tropical forms —

  DENSE  — f32 min-plus GEMM-analogue of the boolean push sweep
           (``cand[s, j] = min_k dist[s, k] + W[k, j]`` over frontier
           rows; cost proportional to the live tile fraction); on the
           kernel path this is the fused Pallas min-plus sweep with
           settled-bound tile skipping (kernels/tropical);
  SPARSE — edge-parallel scatter-min relaxation over CSR lanes (cost
           O(S · m_pad) regardless of occupancy); kernel path: the
           Pallas edge-block relax

— chosen per sweep by the occupancy cost model (dynamic regime) or pinned
per graph by wall-clock calibration of both forms (CPU regime), exactly
mirroring core/engine.py.  Public entry points:

  * ``minplus_sssp``   — single-source (min,+) sweeps through the shared
                         driver (frontier-gated Bellman-Ford; sweep count
                         ≤ hop count of the longest shortest path, work
                         O(hops·m) — the direct generalization of BOVM's
                         O(ε·m));
  * ``weighted_apsp``  — batched multi-source tropical APSP with the
                         direction optimizer;
  * ``bucketed_sssp``  — small integer weights via unit-hop expansion
                         through the UNWEIGHTED sweep machinery (the
                         matrix-op/(min,+) trade the paper anticipates,
                         made explicit).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph
from . import autotune
from . import sweep as S
from .engine import _resolve_kernel, frontier_stats
from .frontier import one_hot_frontier
from .options import SweepOptions
from .sovm import sovm_sssp

INF = jnp.float32(jnp.inf)

DENSE, SPARSE = 0, 1
WEIGHTED_FORM_NAMES = ("dense", "sparse")


class WeightedResult(NamedTuple):
    dist: jax.Array          # (n,) float32; inf = unreachable
    sweeps: jax.Array


class WeightedApspResult(NamedTuple):
    dist: jax.Array              # (S, n) float32; inf = unreachable
    sweeps: jax.Array            # int32 — max sweeps over batches
    direction_counts: jax.Array  # (2,) int32 — dense/sparse sweeps run
    edges_touched: jax.Array     # float32 — relaxed-edge work counter


@dataclasses.dataclass(frozen=True)
class WeightedConfig(SweepOptions):
    """Static tropical-engine parameters (a :class:`SweepOptions`
    subclass, hashable jit static arg).

    Cost-model units: ``c_dense`` per f32 add+min lane in a live dense
    tile, ``c_sparse`` per CSR relax lane — same shape as the boolean
    engine's model with the pull form removed (bit-packing does not apply
    to f32 distances).

    ``use_kernel=None`` resolves to "Pallas kernels iff on TPU", exactly
    like ``EngineConfig``; the kernel closures come from the semiring
    kernel registry via ``sweep.tropical_forms``.  ``dynamic=None``
    mirrors the boolean engine too: per-sweep occupancy switching on the
    kernel path, per-graph wall-clock calibration on the reference path.

    ``max_sweeps`` is this engine's historical spelling of the base
    ``max_steps`` hop bound; setting either sets both.
    """
    source_batch: int = 64           # sources per tile (multiple of 8)
    max_sweeps: Optional[int] = None  # alias of max_steps (hop bound)
    chunk: int = 128                 # dense min-plus dst cols per map step
    eb: int = 128                    # sparse relax kernel edges per step
    c_dense: float = 1.0
    c_sparse: float = 8.0

    _mode_names = WEIGHTED_FORM_NAMES  # dense | sparse

    def __post_init__(self):
        # fold the two spellings of the hop bound into one value
        bound = self.max_sweeps if self.max_sweeps is not None \
            else self.max_steps
        object.__setattr__(self, "max_sweeps", bound)
        object.__setattr__(self, "max_steps", bound)
        super().__post_init__()


@dataclasses.dataclass
class PreparedWeightedGraph:
    """Device-resident tropical operands (dense O(n_pad^2) form lazy)."""
    graph: CSRGraph
    w_edges: jax.Array    # (m_pad,) float32; +inf on padded lanes
    deg: jax.Array        # (n_pad,) float32 out-degrees (0 on pad)
    n_pad: int
    # content epoch of the source graph at prepare time (0 = static)
    epoch: int = 0
    cost_cache: dict = dataclasses.field(default_factory=dict, repr=False)
    _wdense: Optional[jax.Array] = dataclasses.field(default=None,
                                                     repr=False)

    @property
    def wdense(self) -> jax.Array:
        """(n_pad, n_pad) f32 weight matrix, +inf non-edges (dense
        operand); parallel edges resolve to the min weight."""
        if self._wdense is None:
            g = self.graph
            self._wdense = jnp.full((self.n_pad, self.n_pad), INF).at[
                g.src, g.dst].min(self.w_edges)
        return self._wdense


def prepare_weighted(g, weights=None, *,
                     align: int = 128) -> PreparedWeightedGraph:
    """Normalize weights to the padded edge lanes and build the O(n)
    operands; the dense weight matrix materializes lazily.

    Accepts a plain :class:`CSRGraph` (``weights`` required) or a
    weighted :class:`repro.graph.dynamic.DynamicCSRGraph` (lane weights
    come from its merged view; the content ``epoch`` is recorded for
    downstream staleness checks)."""
    epoch = 0
    if hasattr(g, "view"):            # DynamicCSRGraph duck-type
        epoch = int(g.epoch)
        if weights is None:
            weights = g.view_weights()
        g = g.view()
    assert weights is not None, "prepare_weighted needs edge weights"
    w = np.asarray(weights, np.float32)
    assert w.ndim == 1 and w.size >= g.n_edges, \
        f"need >= {g.n_edges} weights, got shape {w.shape}"
    assert (w[: g.n_edges] >= 0).all(), "weights must be non-negative"
    lanes = np.full(g.m_pad, np.inf, np.float32)
    lanes[: g.n_edges] = w[: g.n_edges]
    n_pad = g.n_padded(align)
    deg = jnp.zeros(n_pad, jnp.float32).at[: g.n_nodes].set(
        g.out_degrees().astype(jnp.float32))
    return PreparedWeightedGraph(graph=g, w_edges=jnp.asarray(lanes),
                                 deg=deg, n_pad=n_pad, epoch=epoch)


# --------------------------------------------------------------------------
# single-source (min,+) sweeps
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_sweeps",))
def minplus_sssp(g: CSRGraph, weights: jax.Array, source, *,
                 max_sweeps: Optional[int] = None) -> WeightedResult:
    """(min,+) sweep SSSP through the shared driver.  weights (m_pad,)
    float32 ≥ 0 (padded entries ignored via the +inf mask)."""
    n = g.n_nodes
    max_sweeps = n if max_sweeps is None else max_sweeps
    src_id = jnp.asarray(source, jnp.int32)
    dist0 = jnp.full(n + 1, INF).at[src_id].set(0.0)
    f0 = jnp.zeros(n + 1, jnp.int8).at[src_id].set(1)
    w = jnp.where(g.src < n, weights, INF)

    _, sparse = S.tropical_forms(None, g.src, g.dst, w)
    st = S.sweep_loop((sparse,), S.make_state(f0, dist0, n_forms=1),
                      max_steps=max_sweeps)
    return WeightedResult(st.dist[:n], st.sweeps)


# --------------------------------------------------------------------------
# batched direction-optimizing tropical APSP
# --------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("cfg", "n_real", "n_pad", "max_sweeps",
                                    "use_kernel", "interpret", "forced_dir",
                                    "fused_steps"))
def _run_weighted_batch(wdense, src_idx, dst_idx, w_edges, deg, sources,
                        n_valid, *, cfg: WeightedConfig, n_real: int,
                        n_pad: int, max_sweeps: int, use_kernel: bool,
                        interpret: bool,
                        forced_dir: Optional[int],
                        fused_steps: int = 0) -> S.SweepState:
    s = sources.shape[0]
    m_pad = src_idx.shape[0]
    bs = min(s, 128)

    f0 = one_hot_frontier(sources, n_pad, dtype=jnp.int8)
    row_ok = (jnp.arange(s) < n_valid)[:, None]
    f0 = jnp.where(row_ok, f0, 0)
    # pad rows/cols stay +inf with empty frontiers: no candidate ever
    # improves them, so they are inert without masks
    dist0 = jnp.where(f0 != 0, 0.0, jnp.full((s, n_pad), INF))

    forms = S.tropical_forms(wdense, src_idx, dst_idx, w_edges,
                             n_pad=n_pad, chunk=cfg.chunk,
                             use_kernel=use_kernel, interpret=interpret,
                             bn=cfg.bn, bk=cfg.bk, eb=cfg.eb)
    if forms[0] is None:
        forms = (forms[1], forms[1])  # sparse pinned; keep switch arity 2

    if forced_dir is None:
        def choose(st: S.SweepState):
            stats = frontier_stats(st.frontier, st.dist, bs=bs, bn=128,
                                   bk=128, unreached=jnp.isinf(st.dist))
            dense_c = cfg.c_dense * s * n_pad * n_pad * stats.live_tile_frac
            sparse_c = jnp.float32(cfg.c_sparse * s * m_pad)
            return (dense_c > sparse_c).astype(jnp.int32)
    else:
        choose = None

    fused = None
    if fused_steps:  # resolved upstream: kernel path, dense pinned
        fused = S.fused_form("tropical", wdense, "dense", bs=bs,
                             max_sweeps=fused_steps, interpret=interpret)

    st0 = S.make_state(f0, dist0, n_forms=2)
    return S.sweep_loop(forms, st0, max_steps=max_sweeps, deg=deg,
                        choose=choose,
                        forced_dir=0 if forced_dir is None else forced_dir,
                        fused=fused, fused_steps=fused_steps)


def measure_weighted_costs(pw: PreparedWeightedGraph, s: int,
                           cfg: WeightedConfig, *,
                           use_kernel: bool = False,
                           interpret: bool = True) -> Tuple[float, float]:
    """Wall-clock one mid-run sweep of each tropical form on this graph
    (mirror of engine.measure_sweep_costs; cached on the prepared graph).
    Times the same closures ``_run_weighted_batch`` will dispatch (kernel
    or reference, per ``use_kernel``)."""
    key = (s, cfg.chunk, cfg.bn, cfg.bk, cfg.eb, use_kernel, interpret)
    if key in pw.cost_cache:
        return pw.cost_cache[key]
    n_pad = pw.n_pad
    f = np.zeros((s, n_pad), np.int8)
    f[:, ::17] = 1
    dist = np.full((s, n_pad), np.inf, np.float32)
    dist[:, ::4] = 1.0
    forms = S.tropical_forms(pw.wdense, pw.graph.src, pw.graph.dst,
                             pw.w_edges, n_pad=n_pad, chunk=cfg.chunk,
                             use_kernel=use_kernel, interpret=interpret,
                             bn=cfg.bn, bk=cfg.bk, eb=cfg.eb)
    result = S.time_sweep_forms(forms, jnp.asarray(f), jnp.asarray(dist))
    pw.cost_cache[key] = result
    return result


def _resolve_weighted_direction(pw: PreparedWeightedGraph, s: int,
                                cfg: WeightedConfig, use_kernel: bool,
                                interpret: bool) -> Optional[int]:
    """None -> per-sweep dynamic switch; int -> form fixed per batch.
    Pin precedence: explicit mode > TuningPlan argmin > wall-clock
    calibration (see engine._resolve_direction)."""
    if cfg.mode != "auto":
        return WEIGHTED_FORM_NAMES.index(cfg.mode)
    dynamic = use_kernel if cfg.dynamic is None else cfg.dynamic
    if dynamic:
        return None
    if cfg.tuning is not None:
        pinned = cfg.tuning.pinned_direction(
            "tropical", s=s, n_pad=pw.n_pad, m_pad=pw.graph.m_pad)
        if pinned is not None:
            return pinned
    return int(np.argmin(measure_weighted_costs(
        pw, s, cfg, use_kernel=use_kernel, interpret=interpret)))


def weighted_apsp(g: Union[CSRGraph, PreparedWeightedGraph],
                  weights=None,
                  sources: Optional[Sequence[int]] = None, *,
                  config: WeightedConfig = WeightedConfig()
                  ) -> WeightedApspResult:
    """Batched multi-source tropical APSP with direction optimization.

    Pass a :class:`PreparedWeightedGraph` (weights=None) to reuse
    operands and the calibration cache across calls (the serving path
    does).  Distances are float32 with +inf for unreachable targets.
    """
    pw = g if isinstance(g, PreparedWeightedGraph) else \
        prepare_weighted(g, weights)
    config = autotune.apply(config, semiring="tropical", n_pad=pw.n_pad)
    graph = pw.graph
    n = graph.n_nodes
    srcs = np.arange(n, dtype=np.int32) if sources is None else \
        np.asarray(sources, np.int32)
    if srcs.size == 0:
        raise ValueError("weighted_apsp: empty source list")
    if srcs.min() < 0 or srcs.max() >= n:
        raise ValueError(
            f"weighted_apsp: sources must be in [0, {n}), got "
            f"[{srcs.min()}, {srcs.max()}]")
    max_sweeps = config.max_sweeps or n
    B = config.source_batch
    # one resolution policy for both semirings: _resolve_kernel only
    # reads cfg.use_kernel, which WeightedConfig shares with EngineConfig
    use_kernel, interpret = _resolve_kernel(config)
    forced = _resolve_weighted_direction(pw, B, config, use_kernel,
                                         interpret)
    fused_steps = 0
    if config.fused_steps and forced in (None, DENSE):
        fused_steps = S.resolve_fused_steps(
            "tropical", "dense", fused_steps=config.fused_steps,
            max_steps=max_sweeps, use_kernel=use_kernel, n_pad=pw.n_pad,
            bs=min(B, 128),
            budget=None if config.tuning is None
            else config.tuning.vmem_budget) or 0
        if fused_steps:
            forced = DENSE      # fused blocks pin the dense form
    # only materialize the O(n_pad^2) dense operand when it can dispatch
    wdense = pw.wdense if forced in (None, DENSE) else None

    rows = []
    sweeps = jnp.int32(0)
    counts = jnp.zeros(2, jnp.int32)
    touched = jnp.float32(0.0)
    for lo in range(0, len(srcs), B):
        block = srcs[lo: lo + B]
        valid = len(block)
        padded = np.zeros(B, np.int32)
        padded[:valid] = block
        st = _run_weighted_batch(wdense, graph.src, graph.dst, pw.w_edges,
                                 pw.deg, jnp.asarray(padded),
                                 jnp.int32(valid), cfg=config, n_real=n,
                                 n_pad=pw.n_pad, max_sweeps=max_sweeps,
                                 use_kernel=use_kernel, interpret=interpret,
                                 forced_dir=forced, fused_steps=fused_steps)
        rows.append(st.dist[:valid, :n])
        sweeps = jnp.maximum(sweeps, st.step)
        counts = counts + st.dir_counts
        touched = touched + st.edges_touched
    return WeightedApspResult(dist=jnp.concatenate(rows, axis=0),
                              sweeps=sweeps, direction_counts=counts,
                              edges_touched=touched)


# --------------------------------------------------------------------------
# small-integer weights through the unweighted machinery
# --------------------------------------------------------------------------

def expand_integer_weights(g: CSRGraph, weights: np.ndarray) -> CSRGraph:
    """Unit-hop expansion: a weight-w edge (u→v) becomes a path
    u → x₁ → … → x_{w-1} → v of unit edges (host-side construction)."""
    src, dst = g.edge_arrays_np()
    weights = np.asarray(weights[: g.n_edges], dtype=np.int64)
    assert (weights >= 1).all(), "integer weights must be ≥ 1"
    n = g.n_nodes
    new_src, new_dst = [], []
    next_virtual = n
    for u, v, w in zip(src, dst, weights):
        if w == 1:
            new_src.append(u); new_dst.append(v)
            continue
        chain = [u] + list(range(next_virtual, next_virtual + w - 1)) + [v]
        next_virtual += w - 1
        for a, b in zip(chain[:-1], chain[1:]):
            new_src.append(a); new_dst.append(b)
    return CSRGraph.from_edges(np.asarray(new_src), np.asarray(new_dst),
                               next_virtual, dedup=False)


def bucketed_sssp(g: CSRGraph, weights: np.ndarray, source: int
                  ) -> WeightedResult:
    """Small-integer-weight SSSP through the unweighted SOVM machinery."""
    eg = expand_integer_weights(g, weights)
    st = sovm_sssp(eg, source)
    dist = jnp.where(st.dist[: g.n_nodes] < 0, INF,
                     st.dist[: g.n_nodes].astype(jnp.float32))
    return WeightedResult(dist, st.sweeps)


def dijkstra_oracle(g: CSRGraph, weights: np.ndarray,
                    source: int) -> np.ndarray:
    """scipy Dijkstra reference for tests."""
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csgraph
    src, dst = g.edge_arrays_np()
    mat = sp.csr_matrix((np.asarray(weights[: g.n_edges], np.float64),
                         (src, dst)), shape=(g.n_nodes, g.n_nodes))
    return csgraph.dijkstra(mat, indices=source, directed=True)
