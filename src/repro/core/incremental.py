"""Incremental BFS/SSSP repair — resume the sweep from the affected
frontier instead of re-running from scratch.

DAWN's per-source bound O(E_wcc(i)) comes from touching only reachable
structure; the same argument localizes *updates*: an edge mutation whose
affected region is small should cost a correspondingly small resumed
sweep.  This module classifies a batch of edge updates against a stored
``(dist, parent)`` state and re-converges it through the existing
one-``lax.while_loop`` driver (:func:`repro.core.sweep.sweep_loop`) —
no new loop, no new sweep semantics.

Classification (Yamane & Kobayashi, arXiv:1908.06806):

  * **Inserts can only lower distances.**  For each inserted (or
    weight-decreased) edge (u, v, w), if ``d[u] + w < d[v]`` the head v
    improves immediately and seeds the resume frontier; otherwise the
    insert is provably inert.
  * **Deletes taint the shortest-path subtree.**  A vertex's stored
    distance survives a delete iff its recorded shortest path avoids the
    deleted edges.  v is tainted iff its parent edge was deleted or its
    parent chain passes through a tainted vertex — computed by
    propagating taint down the parent forest.  Tainted distances reset
    to +inf (their parents to -1); untainted distances are still
    achievable (deletes never shorten paths), hence still optimal.

Seeding: the resume frontier F0 is the set of insert-improved heads plus
every *untainted* vertex with an out-edge into the tainted set (the
taint boundary).  Completeness: walk any true shortest path to a
tainted vertex backwards — it leaves the untainted region (where stored
distances are exact) through some boundary edge whose tail is in F0, so
the resumed relaxation rebuilds the path level by level; interior
tainted vertices join the frontier as they improve, exactly the sparse
form's Bellman–Ford frontier dynamics.  If F0 is empty the tainted set
is unreachable and +inf is already correct (the resume is skipped — 0
sweeps).

The resume always runs the **tropical** sparse form (unit lane weights
for unweighted graphs): the boolean forms gate on ``dist == UNREACHED``
and write the global step counter, so they cannot lower an existing
finite distance — value-based (min,+) relaxation is the one sweep
algebra that is resumable from any partial state.  Unit-weight f32
distances are integer-exact far past any reachable hop count, so the
final ``int32`` conversion is lossless and the repaired state is
**bit-identical** to a from-scratch boolean sweep (dist and the
``derive_parents`` max-id tie-break both depend only on the dist
fixpoint).  Weighted repair requires strictly positive weights: a
zero-weight cycle can make the recorded parent forest cyclic, which
breaks the subtree-taint argument.

Counting-semiring state (sigma) is NOT incrementally repaired — path
counts have no local taint bound — so the serving tier invalidates and
rebuilds its betweenness vector on epoch change instead (trivially
bit-identical).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph
from ..graph.dynamic import DynamicCSRGraph
from . import sweep as S
from .engine import EngineConfig, apsp_engine
from .frontier import UNREACHED
from .weighted import WeightedConfig, weighted_apsp

__all__ = ["IncrementalState", "RepairResult", "IncrementalSSSP",
           "sssp_state", "repair"]


@dataclasses.dataclass
class IncrementalState:
    """Resumable multi-source shortest-path state.

    ``dist`` is stored in the tropical domain for both algebras:
    (S, n) float32, +inf = unreached (integer-valued for unweighted
    graphs).  ``parent`` is the ``derive_parents`` forest (max-id
    tie-break, -1 = root/unreached) — the taint classifier walks it.
    """
    sources: np.ndarray          # (S,) int32
    dist: np.ndarray             # (S, n) float32, +inf unreached
    parent: np.ndarray           # (S, n) int32, -1 none
    weighted: bool
    epoch: int = 0               # graph epoch this state reflects

    def dist_int(self) -> np.ndarray:
        """Boolean-engine view: (S, n) int32 hops, -1 unreachable."""
        return np.where(np.isinf(self.dist), UNREACHED,
                        self.dist).astype(np.int32)


class RepairResult(NamedTuple):
    state: IncrementalState
    sweeps: int                  # productive resumed sweeps (0 if inert)
    tainted: int                 # vertices whose subtree a delete cut
    seeded: int                  # |F0| — resume frontier size
    rebuilt: bool                # True when repair fell back to scratch


def _unwrap(graph, weights):
    """-> (CSRGraph view, lane weights or None, content epoch)."""
    if isinstance(graph, DynamicCSRGraph):
        return graph.view(), graph.view_weights(), graph.epoch
    return graph, weights, 0


def sssp_state(graph: Union[CSRGraph, DynamicCSRGraph], sources, *,
               weights=None, config=None) -> Tuple[IncrementalState, int]:
    """From-scratch state build through the batched engines; returns
    ``(state, sweeps)`` so callers can compare repair-vs-scratch cost."""
    view, w, epoch = _unwrap(graph, weights)
    sources = np.asarray(sources, np.int32).ravel()
    if w is not None:
        cfg = config if isinstance(config, WeightedConfig) \
            else WeightedConfig()
        res = weighted_apsp(view, w, sources, config=cfg)
        dist = np.asarray(res.dist, np.float32)
        parent = np.asarray(S.derive_parents(view, res.dist,
                                             weights=jnp.asarray(w)))
    else:
        cfg = config if isinstance(config, EngineConfig) else EngineConfig()
        res = apsp_engine(view, sources, config=cfg)
        dist_i = np.asarray(res.dist)
        dist = np.where(dist_i == UNREACHED, np.inf,
                        dist_i).astype(np.float32)
        parent = np.asarray(S.derive_parents(view, res.dist))
    state = IncrementalState(sources=sources, dist=dist,
                             parent=parent.astype(np.int32),
                             weighted=w is not None, epoch=epoch)
    return state, int(res.sweeps)


@jax.jit
def _resume(src_idx, dst_idx, w_lanes, f0, d0, max_steps) -> S.SweepState:
    """Resume the tropical relaxation from a partial (frontier, dist)
    through THE sweep driver (sparse form; n_forms=2 mirrors the
    weighted engine's accounting layout)."""
    _, sparse = S.tropical_forms(None, src_idx, dst_idx, w_lanes)
    st0 = S.make_state(f0, d0, n_forms=2)
    return S.sweep_loop((sparse, sparse), st0, max_steps=max_steps,
                        forced_dir=1)


def _normalize_pairs(edges, n_cols):
    if edges is None:
        return tuple(np.zeros(0, np.int64) for _ in range(n_cols))
    out = tuple(np.asarray(e).ravel() for e in edges)
    assert len(out) == n_cols, \
        f"expected {n_cols} arrays, got {len(out)}"
    return out


def repair(graph: Union[CSRGraph, DynamicCSRGraph],
           state: IncrementalState, *,
           inserts=None, deletes=None, weights=None,
           max_steps: Optional[int] = None) -> RepairResult:
    """Repair ``state`` against ``graph`` (which must already contain
    the mutations): taint delete subtrees, apply insert improvements,
    resume the sweep from the affected frontier.

    ``inserts`` is ``(src, dst)`` or ``(src, dst, w)`` (w required for
    weighted states — the *current* weight of each inserted/decreased
    edge); ``deletes`` is ``(src, dst)``.  The result is bit-identical
    to a from-scratch run on the mutated graph.
    """
    view, w, epoch = _unwrap(graph, weights)
    n = view.n_nodes
    n_src, n_cols = state.dist.shape
    assert n_cols == n, (n_cols, n)

    if state.weighted:
        assert w is not None, "weighted state needs the mutated weights"
        ins_src, ins_dst, ins_w = _normalize_pairs(
            inserts, 3) if (inserts is not None and len(inserts) == 3) \
            else (*_normalize_pairs(inserts, 2), None)
        assert ins_w is not None or ins_src.size == 0, \
            "weighted repair needs (src, dst, w) inserts"
        if ins_w is None:
            ins_w = np.zeros(0, np.float32)
        w_np = np.asarray(w, np.float32)
        live_w = w_np[np.asarray(view.src) < n]
        assert live_w.size == 0 or live_w.min() > 0, \
            "weighted repair requires strictly positive weights " \
            "(zero-weight cycles break the parent-subtree taint bound)"
    else:
        ins_src, ins_dst = _normalize_pairs(inserts, 2)[:2]
        ins_w = np.ones(ins_src.size, np.float32)
    del_src, del_dst = _normalize_pairs(deletes, 2)

    dist = state.dist.copy()
    parent = state.parent.copy()

    # -- delete classification: taint the cut shortest-path subtrees ----
    tainted = np.zeros(dist.shape, bool)
    for u, v in zip(del_src, del_dst):
        tainted[:, int(v)] |= parent[:, int(v)] == int(u)
    if tainted.any():
        rows = np.arange(n_src)[:, None]
        parc = np.where(parent >= 0, parent, 0)
        while True:
            grown = tainted | (tainted[rows, parc] & (parent >= 0))
            if (grown == tainted).all():
                break
            tainted = grown
        dist[tainted] = np.inf
        parent[tainted] = -1
    n_tainted = int(tainted.sum())

    # -- insert classification: apply immediate improvements ------------
    f0 = np.zeros(dist.shape, bool)
    for u, v, wt in zip(ins_src, ins_dst, ins_w):
        u, v = int(u), int(v)
        cand = dist[:, u] + (float(wt) if state.weighted else 1.0)
        imp = cand < dist[:, v]
        if imp.any():
            dist[imp, v] = cand[imp]
            parent[imp, v] = u
            f0[imp, v] = True

    # -- boundary seeds: untainted tails of edges into the tainted set --
    if n_tainted:
        gsrc = np.asarray(view.src)
        gdst = np.asarray(view.dst)
        live = gsrc < n
        us, vs = gsrc[live], gdst[live]
        contrib = (~tainted[:, us]) & tainted[:, vs]     # (S, m_live)
        for s in range(n_src):
            np.logical_or.at(f0[s], us, contrib[s])
        # (no ~tainted mask on f0: an insert-improved vertex inside the
        # tainted set holds a finite dist that must propagate; tainted
        # seeds still at +inf are inert in the relaxation anyway)

    n_seeded = int(f0.sum())
    new_epoch = epoch if isinstance(graph, DynamicCSRGraph) \
        else state.epoch
    if state.weighted:
        w_lanes = jnp.asarray(w_np)
    else:
        w_lanes = jnp.where(view.src < n, jnp.float32(1.0),
                            jnp.float32(np.inf))

    def _parents(d):
        # parents re-derive from the dist fixpoint — same max-id
        # tie-break as scratch, so equal dist => bit-equal parents
        if state.weighted:
            return np.asarray(S.derive_parents(
                view, jnp.asarray(d), weights=w_lanes)).astype(np.int32)
        di = np.where(np.isinf(d), UNREACHED, d).astype(np.int32)
        return np.asarray(S.derive_parents(
            view, jnp.asarray(di))).astype(np.int32)

    if n_seeded == 0:
        # inert batch: non-improving inserts and/or a tainted region
        # with no untainted in-boundary (provably unreachable -> +inf).
        # Parents still re-derive when the edge set changed: an insert
        # that only TIES an existing distance adds a valid predecessor,
        # which can move the canonical (max-id) parent without moving
        # any distance.
        if ins_src.size or del_src.size:
            parent = _parents(dist)
        out = IncrementalState(sources=state.sources, dist=dist,
                               parent=parent, weighted=state.weighted,
                               epoch=new_epoch)
        return RepairResult(out, 0, n_tainted, 0, False)

    # -- resume through THE driver on the merged operand -----------------
    n_pad = view.n_padded(128)
    d0 = np.full((n_src, n_pad), np.inf, np.float32)
    d0[:, :n] = dist
    f0p = np.zeros((n_src, n_pad), np.int8)
    f0p[:, :n] = f0
    st = _resume(view.src, view.dst, w_lanes, jnp.asarray(f0p),
                 jnp.asarray(d0), jnp.int32(max_steps or n))
    newd = np.asarray(st.dist)[:, :n]

    out = IncrementalState(sources=state.sources,
                           dist=newd.astype(np.float32),
                           parent=_parents(newd),
                           weighted=state.weighted, epoch=new_epoch)
    return RepairResult(out, int(st.sweeps), n_tainted, n_seeded, False)


class IncrementalSSSP:
    """Streaming repair driver bound to a :class:`DynamicCSRGraph`.

    Holds the resumable state for a fixed source set and pulls the
    graph's journalled net deltas on :meth:`update` — repairing
    incrementally when the journal reaches back to the last sync and
    rebuilding from scratch when it doesn't.  ``scratch_sweeps`` /
    ``repair_sweeps`` accumulate the cost of each path for
    repair-vs-scratch accounting (bench_dynamic hard-gates these).
    """

    def __init__(self, graph: DynamicCSRGraph, sources, *, config=None):
        assert isinstance(graph, DynamicCSRGraph), type(graph)
        self.graph = graph
        self.config = config
        self.state, sweeps = sssp_state(graph, sources, config=config)
        self.scratch_sweeps = sweeps
        self.repair_sweeps = 0
        self.rebuilds = 0
        self.repairs = 0

    @property
    def dist(self) -> np.ndarray:
        return self.state.dist

    @property
    def parent(self) -> np.ndarray:
        return self.state.parent

    def dist_int(self) -> np.ndarray:
        return self.state.dist_int()

    def update(self) -> Optional[RepairResult]:
        """Sync with the graph's current epoch.  Returns the
        :class:`RepairResult` (``None`` when already in sync)."""
        if self.graph.epoch == self.state.epoch:
            return None
        delta = self.graph.delta_since(self.state.epoch)
        if delta is None:                 # journal trimmed: full rebuild
            self.state, sweeps = sssp_state(self.graph,
                                            self.state.sources,
                                            config=self.config)
            self.scratch_sweeps += sweeps
            self.rebuilds += 1
            return RepairResult(self.state, sweeps, 0, 0, True)
        ins_src, ins_dst, ins_w, del_src, del_dst = delta
        res = repair(self.graph, self.state,
                     inserts=(ins_src, ins_dst, ins_w)
                     if self.state.weighted else (ins_src, ins_dst),
                     deletes=(del_src, del_dst))
        self.state = res.state
        self.repair_sweeps += res.sweeps
        self.repairs += 1
        return res
