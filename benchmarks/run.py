"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--out BENCH_RESULTS.json]

Prints ``name,us_per_call,derived`` CSV rows and writes a machine-readable
aggregate (default ``BENCH_RESULTS.json``; ``--out ''`` disables) so the
perf trajectory can be tracked run-over-run and uploaded as a CI artifact.
All benchmarks are seeded — two runs on the same machine measure the same
work:

  * bench_sssp        — Tables 7/8 (speedup over GAP-standin / queue BFS)
  * bench_scaling     — Tables 5/6 + Figs 3/4 (batch-parallel efficiency)
  * bench_memory      — §3.4 / Eq. 13 memory model
  * bench_complexity  — Eqs. 5/6/10 work-bound verification
  * bench_batching    — beyond-paper: blocked multi-source GEMM + tile-skip
                        (JSON; tile_skip_fraction rides the hard gate)
  * bench_serving     — serving tier: open-loop Poisson load against the
                        tiered GraphService (row cache -> landmark oracle
                        -> bucketed sweeps); p50/p99/QPS advisory,
                        hit-rate / certified-fraction / labels checksum
                        hard-gated, bit-identity asserted in-bench (JSON)
  * bench_weighted    — paper §5 extension through the tropical engine:
                        fixed-dense vs fixed-sparse vs auto (JSON) + scipy
                        Dijkstra baseline
  * bench_apsp        — direction-optimized batched APSP engine:
                        fixed-push vs fixed-pull vs auto (JSON)
  * bench_sharded     — semiring-generic sharded executor vs the fixed
                        single-device engine (bit-identical asserted,
                        collective overhead measured; JSON)
  * bench_centrality  — counting-semiring analytics bundle: NumPy
                        per-source loop vs jit-batched vs Pallas kernel
                        (betweenness asserted equal, sigma checksum
                        recorded for the hard gate; JSON)
  * bench_dynamic     — streaming tier: locality-heavy interleaved
                        update/query stream over DynamicCSRGraph;
                        frontier-seeded repair vs scratch recompute
                        (bit-identity and repair_sweeps < scratch_sweeps
                        asserted in-bench; sweep totals, epoch counters
                        and query checksum hard-gated; JSON)
  * bench_resume      — resumable-job layer: checkpointed counting-APSP
                        job vs kill-at-half + resume (bit-identity
                        asserted in-bench; dist/sigma checksums and the
                        resumed-chunk accounting hard-gated; JSON)
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

import jax

from . import (bench_apsp, bench_batching, bench_centrality,
               bench_complexity, bench_dynamic, bench_memory, bench_resume,
               bench_scaling, bench_serving, bench_sharded, bench_sssp,
               bench_weighted, regression)


def _csv_rows_to_records(rows):
    records = []
    for row in rows[1:]:                      # skip the header
        name, us, derived = row.split(",", 2)
        # derived-only rows (memory model, work-bound checks) carry no time
        records.append({"name": name,
                        "us_per_call": float(us) if us else None,
                        "derived": derived})
    return records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", type=str, default="BENCH_RESULTS.json",
                    help="aggregate JSON path ('' to disable)")
    ap.add_argument("--check-against", type=str, default=None,
                    metavar="BASELINE.json",
                    help="regression gate: compare this run against a "
                         "committed baseline aggregate and exit non-zero "
                         "on hard regressions (see benchmarks/regression.py)")
    args = ap.parse_args()

    rows = ["name,us_per_call,derived"]
    t0 = time.time()
    bench_sssp.run(n_sources=4 if args.quick else 16, csv=rows)
    bench_scaling.run(csv=rows)
    bench_memory.run(csv=rows)
    bench_complexity.run(csv=rows, n_sources=4 if args.quick else 8)
    batching = bench_batching.run(quick=args.quick,
                                  repeats=2 if args.quick else 3, csv=rows)
    serving = bench_serving.run(quick=args.quick,
                                n_queries=20_000 if args.quick else 100_000,
                                csv=rows)
    weighted = bench_weighted.run(quick=args.quick,
                                  repeats=2 if args.quick else 5, csv=rows)
    apsp = bench_apsp.run(quick=args.quick,
                          repeats=3 if args.quick else 10, csv=rows)
    sharded = bench_sharded.run(quick=args.quick,
                                repeats=2 if args.quick else 5, csv=rows)
    central = bench_centrality.run(quick=args.quick,
                                   repeats=2 if args.quick else 3,
                                   csv=rows)
    dynamic = bench_dynamic.run(quick=args.quick,
                                repeats=2 if args.quick else 3, csv=rows)
    resume = bench_resume.run(quick=args.quick,
                              repeats=2 if args.quick else 3, csv=rows)
    total = time.time() - t0
    print("\n".join(rows))
    print(f"# total {total:.1f}s", file=sys.stderr)

    aggregate = {
        "schema": 2,
        "quick": args.quick,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "total_seconds": total,
        "gate": {"time_tol": regression.DEFAULT_TIME_TOL,
                 "min_gate_seconds": regression.MIN_GATE_SECONDS},
        "rows": _csv_rows_to_records(rows),
        "bench_apsp": apsp,
        "bench_weighted": weighted,
        "bench_sharded": sharded,
        "bench_centrality": central,
        "bench_batching": batching,
        "bench_serving": serving,
        "bench_dynamic": dynamic,
        "bench_resume": resume,
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(aggregate, f, indent=2)
            f.write("\n")
        print(f"# aggregate written to {args.out}", file=sys.stderr)
    if args.check_against:
        if regression.check_against(aggregate, args.check_against):
            sys.exit(1)


if __name__ == "__main__":
    main()
