"""Sharded DAWN APSP over virtual devices — the multi-device execution
path at demo scale (8 host-platform devices).

The semiring-generic sharded executor runs the SAME sweep forms as the
single-device engines, sharded over sources (mesh axis ``data``) and
optionally over vertices (axis ``model``, cross-shard ⊕-reduction per
sweep), for both the boolean (unweighted BFS) and tropical ((min,+)
weighted) semirings.  Results are bit-identical to the single-device
engines — this script asserts it.

MUST run as its own process (device count is locked at jax init):

    PYTHONPATH=src python examples/distributed_dawn.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import numpy as np  # noqa: E402

import repro as dawn  # noqa: E402
from repro.graph import generators as gen  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


def _timed(tag, fn):
    fn()                                    # compile
    t0 = time.perf_counter()
    out = fn()
    out.dist.block_until_ready()
    print(f"{tag:42s}: {(time.perf_counter() - t0) * 1e3:7.1f} ms "
          f"({int(out.sweeps)} sweeps)")
    return out


def main():
    g = gen.rmat(10, 8, directed=False, seed=7)       # n = 1024
    w = np.random.default_rng(0).uniform(0.5, 4.0, g.m_pad).astype(
        np.float32)
    sources = np.arange(32, dtype=np.int32)
    print(f"graph: n={g.n_nodes} m={g.n_edges}, {len(sources)} sources")

    h = dawn.prepare(g, weights=w, mode="dense", source_batch=32)
    hp = dawn.prepare(g, mode="push", source_batch=32)

    single_b = _timed("single-device boolean (push)",
                      lambda: hp.apsp(sources))
    single_t = _timed("single-device tropical (dense)",
                      lambda: h.apsp(sources, semiring="tropical"))

    for shape, axes in [((8,), ("data",)), ((4, 2), ("data", "model"))]:
        mesh = make_mesh(shape, axes)
        tag = "x".join(map(str, shape)) + " " + "/".join(axes)
        res_b = _timed(f"sharded boolean  mesh {tag}",
                       lambda: h.apsp(sources, mesh=mesh))
        res_t = _timed(f"sharded tropical mesh {tag}",
                       lambda: h.apsp(sources, semiring="tropical",
                                      mesh=mesh))
        assert (np.asarray(res_b.dist) == np.asarray(single_b.dist)).all()
        assert (np.asarray(res_t.dist) == np.asarray(single_t.dist)).all()
        assert int(res_b.sweeps) == int(single_b.sweeps)
        assert int(res_t.sweeps) == int(single_t.sweeps)

    print("sharded distances bit-identical to the single-device engines ✓")


if __name__ == "__main__":
    main()
